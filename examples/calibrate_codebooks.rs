//! Walkthrough of the LO-BCQ calibration algorithm (paper §2.2–2.3):
//! iterate block clustering ⇄ Lloyd-Max, watch the monotone MSE trace,
//! compare the proposed k-means++ init against naive random init
//! (Fig. 4), and persist the frozen family.
//!
//! ```bash
//! cargo run --release --example calibrate_codebooks
//! ```

use lobcq::quant::codebook::CodebookFamily;
use lobcq::quant::lobcq::{calibrate_blocks, normalize, CalibOpts, InitMethod, LobcqConfig};
use lobcq::util::rng::{llm_like_sample, Pcg32};

fn main() -> anyhow::Result<()> {
    let cfg = LobcqConfig::new(8, 16, 64);
    let mut rng = Pcg32::seeded(1234);
    let data = llm_like_sample(&mut rng, 64 * 1024, 0.04, 4.0);

    // Normalize per block array (eq. 7–8) and split into blocks.
    let norm = normalize(&data, cfg.la, &cfg);
    let blocks: Vec<&[f32]> = norm.values.chunks_exact(cfg.lb).collect();
    println!("calibrating on {} blocks of length {}", blocks.len(), cfg.lb);

    // Proposed init vs naive init (Fig. 4).
    for (label, init) in [("k-means++ (proposed)", InitMethod::KmeansPp), ("naive random", InitMethod::Random)] {
        let mut crng = Pcg32::seeded(99);
        let res = calibrate_blocks(
            &blocks,
            &cfg,
            CalibOpts { max_iters: 30, rel_tol: 0.0, init },
            &mut crng,
        );
        let first = res.trace.first().unwrap();
        let last = res.trace.last().unwrap();
        println!("\n{label}:");
        println!("  J trace (first 6): {:?}", &res.trace[..res.trace.len().min(6)].iter().map(|j| (j * 1e4).round() / 1e4).collect::<Vec<_>>());
        println!("  J: {first:.5} → {last:.5} over {} iterations (monotone ✓)", res.iters);
        // Monotonicity is the paper's A.2 theorem — verify here too.
        assert!(res.trace.windows(2).all(|w| w[1] <= w[0] * (1.0 + 1e-9) + 1e-12));

        if init == InitMethod::KmeansPp {
            // Quantize codewords to INT6 (paper §2.4 / Table 10) and save.
            let family = res.family.quantize_codewords(cfg.bc);
            println!("  codebooks (INT6 codewords, normalized ±31 domain):");
            for (i, book) in family.books.iter().enumerate().take(4) {
                println!("    C{i}: {:?}", book.levels);
            }
            println!("    … ({} books total, {} bytes)", family.nc(), family.footprint_bytes(cfg.bc));
            let path = std::path::Path::new("/tmp/lobcq_example_codebooks.json");
            family.save(path)?;
            let back = CodebookFamily::load(path)?;
            assert_eq!(back, family);
            println!("  saved + reloaded from {} ✓", path.display());
        }
    }
    Ok(())
}
