//! Perplexity comparison across quantization schemes on the trained
//! tiny-GPT (the Table 2 protocol in miniature), using the PJRT
//! artifacts for the headline variants and the CPU reference forward
//! for a config the artifacts don't carry — demonstrating both paths.
//!
//! ```bash
//! make artifacts && cargo run --release --example eval_perplexity
//! ```

use lobcq::eval::{ppl_cpu, ppl_pjrt, Env, EvalOpts, Scheme};
use lobcq::runtime::Engine;
use lobcq::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let env = Env::load();
    anyhow::ensure!(env.has_artifacts(), "run `make artifacts` first");
    let size = "s";
    let cfg = env.model_config(size)?;
    let weights = env.weights(size)?;
    let opts = EvalOpts { n_windows: 16, ..Default::default() };

    // --- Path 1: PJRT artifacts (the serving numerics) ---
    let mut eng = Engine::from_dir(&env.dir)?;
    let ordered: Vec<Tensor> = weights.ordered(&cfg)?.into_iter().cloned().collect();
    let refs: Vec<&Tensor> = ordered.iter().collect();
    eng.register_weights("w", &cfg, &refs)?;
    let fam = env.family(8, 4, 6)?;
    eng.register_books("nc8", &Env::books_tensor(&fam))?;

    println!("== PJRT artifact path (model {size}) ==");
    for (variant, books) in [("bf16", None), ("lobcq_g64_nc8", Some("nc8")), ("mx4", None), ("mxfp4", None)] {
        let ppl = ppl_pjrt(&mut eng, size, variant, "w", books, &opts)?;
        println!("  {variant:<16} ppl {ppl:.3}");
    }

    // --- Path 2: CPU reference forward (arbitrary configs) ---
    println!("\n== CPU reference path (W4A4, configs without artifacts) ==");
    let base = ppl_cpu(&cfg, &weights, &Scheme::Bf16, &Scheme::Bf16, &opts)?;
    println!("  {:<24} ppl {base:.3}", "BF16");
    for (lb, nc, la) in [(8usize, 4usize, 128usize), (4, 4, 32), (8, 16, 16)] {
        let scheme = env.lobcq(lb, nc, la)?;
        let ppl = ppl_cpu(&cfg, &weights, &scheme, &scheme, &opts)?;
        println!("  {:<24} ppl {ppl:.3} (Δ {:+.3}, {:.3} bits)", scheme.name(), ppl - base, scheme.bits());
    }
    Ok(())
}
