//! Quickstart: quantize a tensor with LO-BCQ and compare against the
//! paper's baselines — no artifacts needed.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use lobcq::quant::baselines::{Mx4Quantizer, Mxfp4Quantizer, Quantizer, VsqQuantizer};
use lobcq::quant::encode::{decode, encode, to_bytes};
use lobcq::quant::lobcq as lq;
use lobcq::quant::lobcq::{CalibOpts, LobcqConfig};
use lobcq::tensor::Tensor;
use lobcq::util::rng::{llm_like_sample, Pcg32};
use lobcq::util::stats::nmse;

fn main() -> anyhow::Result<()> {
    // An LLM-like operand: mostly Gaussian with a heavy outlier tail.
    let mut rng = Pcg32::seeded(42);
    let data = llm_like_sample(&mut rng, 64 * 256, 0.05, 4.0);
    let tensor = Tensor::new(&[64, 256], data);

    // 1. Calibrate LO-BCQ on the tensor (weights quantize against their
    //    own data, paper §3) and fake-quantize.
    let cfg = LobcqConfig::new(8, 8, 64); // L_b=8, N_c=8, L_A=64 → 4.5 bits
    let mut crng = Pcg32::seeded(7);
    let calib = lq::calibrate_tensors(&[&tensor], &cfg, CalibOpts::default(), &mut crng);
    println!(
        "calibrated {} codebooks × {} entries in {} iterations (J: {:.4} → {:.4})",
        cfg.nc,
        cfg.entries(),
        calib.iters,
        calib.trace.first().unwrap(),
        calib.trace.last().unwrap()
    );
    let family = calib.family.quantize_codewords(cfg.bc); // INT6 codewords
    println!("codebook footprint: {} bytes (paper: ≤ 0.19 KB)\n", family.footprint_bytes(cfg.bc));

    // 2. Compare NMSE against the paper's baselines at similar bitwidths.
    let q = lq::fake_quantize(&tensor.data, &cfg, &family);
    println!("{:<16} {:>8} {:>12}", "method", "bits", "NMSE");
    println!("{:<16} {:>8.3} {:>12.3e}", "LO-BCQ", cfg.bitwidth(), nmse(&tensor.data, &q));
    for b in [
        Box::new(Mx4Quantizer::paper_default()) as Box<dyn Quantizer>,
        Box::new(VsqQuantizer::paper_default()),
        Box::new(Mxfp4Quantizer::paper_default()),
    ] {
        let dq = b.quantize(&tensor.data);
        println!("{:<16} {:>8.3} {:>12.3e}", b.name(), b.bits_per_scalar(), nmse(&tensor.data, &dq));
    }

    // 3. The packed block format (Fig. 5): encode → bytes → decode.
    let enc = encode(&tensor.data, &tensor.shape, &cfg, &family);
    let bytes = to_bytes(&enc);
    println!(
        "\npacked: {:.4} bits/scalar measured (eq. 9 says {:.4}); {} bytes total",
        enc.bits_per_scalar(),
        cfg.bitwidth(),
        bytes.len()
    );
    let dec = decode(&enc, &family);
    assert_eq!(dec, q, "packed decode must equal fake-quantize bit-for-bit");
    println!("decode == fake_quantize: bit-exact ✓");
    Ok(())
}
