//! Quickstart: quantize a tensor with LO-BCQ and compare against the
//! paper's baselines — no artifacts needed.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use lobcq::quant::baselines::{Mx4Quantizer, Mxfp4Quantizer, VsqQuantizer};
use lobcq::quant::calib::LobcqQuantizer;
use lobcq::quant::encode::{decode, encode, to_bytes};
use lobcq::quant::lobcq as lq;
use lobcq::quant::lobcq::{CalibOpts, LobcqConfig};
use lobcq::quant::pipeline::{QuantPipeline, QuantPool, QuantScheme};
use lobcq::tensor::Tensor;
use lobcq::util::rng::{llm_like_sample, Pcg32};
use lobcq::util::stats::nmse;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // An LLM-like operand: mostly Gaussian with a heavy outlier tail.
    let mut rng = Pcg32::seeded(42);
    let data = llm_like_sample(&mut rng, 64 * 256, 0.05, 4.0);
    let tensor = Tensor::new(&[64, 256], data);

    // 1. Calibrate LO-BCQ on the tensor (weights quantize against their
    //    own data, paper §3) and fake-quantize.
    let cfg = LobcqConfig::new(8, 8, 64); // L_b=8, N_c=8, L_A=64 → 4.5 bits
    let mut crng = Pcg32::seeded(7);
    let calib = lq::calibrate_tensors(&[&tensor], &cfg, CalibOpts::default(), &mut crng);
    println!(
        "calibrated {} codebooks × {} entries in {} iterations (J: {:.4} → {:.4})",
        cfg.nc,
        cfg.entries(),
        calib.iters,
        calib.trace.first().unwrap(),
        calib.trace.last().unwrap()
    );
    let family = calib.family.quantize_codewords(cfg.bc); // INT6 codewords
    println!("codebook footprint: {} bytes (paper: ≤ 0.19 KB)\n", family.footprint_bytes(cfg.bc));

    // 2. Compare NMSE against the paper's baselines at similar bitwidths.
    //    Every method — LO-BCQ included — is one `QuantScheme` behind the
    //    unified parallel pipeline, so this loop is the whole swap.
    let schemes: Vec<Arc<dyn QuantScheme>> = vec![
        Arc::new(LobcqQuantizer::universal(cfg, family.clone())),
        Arc::new(Mx4Quantizer::paper_default()),
        Arc::new(VsqQuantizer::paper_default()),
        Arc::new(Mxfp4Quantizer::paper_default()),
    ];
    println!("{:<28} {:>8} {:>12}", "method", "bits", "NMSE");
    let mut q = Vec::new();
    for s in &schemes {
        let pipe = QuantPipeline::new(s.clone(), QuantPool::default());
        let dq = pipe.quantize(&tensor.data);
        println!("{:<28} {:>8.3} {:>12.3e}", s.name(), s.bits_per_scalar(), nmse(&tensor.data, &dq));
        if q.is_empty() {
            q = dq; // keep the LO-BCQ output for the packed-format check
        }
    }

    // 3. The packed block format (Fig. 5): encode → bytes → decode.
    let enc = encode(&tensor.data, &tensor.shape, &cfg, &family);
    let bytes = to_bytes(&enc);
    println!(
        "\npacked: {:.4} bits/scalar measured (eq. 9 says {:.4}); {} bytes total",
        enc.bits_per_scalar(),
        cfg.bitwidth(),
        bytes.len()
    );
    let dec = decode(&enc, &family);
    assert_eq!(dec, q, "packed decode must equal fake-quantize bit-for-bit");
    println!("decode == fake_quantize: bit-exact ✓");
    Ok(())
}
