//! End-to-end driver (DESIGN.md deliverable): load the trained tiny-GPT,
//! serve batched requests through the full coordinator with the W4A4
//! LO-BCQ artifact, and report latency/throughput vs the BF16 baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_w4a4
//! ```

use lobcq::coordinator::{BatchPolicy, Limits, PjrtExecutor, Sampling, Server};
use lobcq::data::corpus;
use lobcq::eval::Env;
use lobcq::model::Weights;
use lobcq::runtime::{Manifest, RuntimeService};
use lobcq::tensor::Tensor;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");
    let manifest = Manifest::load(dir)?;
    manifest.check_corpus_parity()?;
    let env = Env::load();

    let size = "m";
    let cfg = env.model_config(size)?;
    println!("model {size}: {} params, vocab {}", cfg.param_count(), cfg.vocab);

    for variant in ["bf16", "lobcq_g64_nc8"] {
        let entry = manifest
            .find(size, variant, 8)
            .ok_or_else(|| anyhow::anyhow!("missing artifact {variant}"))?
            .clone();

        let service = RuntimeService::start(dir)?;
        let client = service.client();
        let weights = Weights::load(&manifest.weights_path(size)?)?;
        let ordered: Vec<Tensor> = weights.ordered(&cfg)?.into_iter().cloned().collect();
        client.register_weights("w", &cfg, ordered)?;
        let books_key = match entry.books_nc {
            Some(nc) => {
                let fam = env.family(nc, 4, 6)?;
                client.register_books("books", Env::books_tensor(&fam))?;
                Some("books".to_string())
            }
            None => None,
        };

        let server = Arc::new(Server::start(
            PjrtExecutor { client, entry: entry.clone(), weights_key: "w".into(), books_key, vocab: manifest.vocab },
            BatchPolicy { max_batch: entry.batch, max_wait: Duration::from_millis(4) },
            Limits { max_prompt: entry.t, max_new: 16, vocab: manifest.vocab as u32 },
            Sampling::Greedy,
        ));

        // 48 concurrent clients, 6 new tokens each.
        let n = 48;
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for i in 0..n {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                let prompt = corpus::generate(31_000 + i as u64, 20);
                s.submit(prompt, 6).unwrap().wait().unwrap()
            }));
        }
        let mut sample = None;
        for h in handles {
            let resp = h.join().unwrap();
            sample.get_or_insert(resp);
        }
        let wall = t0.elapsed().as_secs_f64();
        let snap = server.metrics.snapshot();
        println!("\n== {variant} ==");
        println!("  {} requests in {wall:.2}s ({:.1} req/s, {:.1} tok/s)", n, n as f64 / wall, snap.tokens as f64 / wall);
        println!("  {}", snap.report());
        if let Some(r) = sample {
            println!("  sample generation (req {}): {:?}", r.id, r.tokens);
        }
        if let Ok(s) = Arc::try_unwrap(server) {
            s.shutdown();
        }
    }
    println!("\nThe W4A4 path serves the same workload with ~3.5× smaller operand traffic (16 → 4.5 bits/scalar).");
    Ok(())
}
