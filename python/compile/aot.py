"""AOT build orchestrator: train → calibrate → lower → manifest.

Runs once under ``make artifacts``; the Rust binary is self-contained
afterwards. Interchange is HLO *text* (NOT ``.serialize()``): jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Outputs in ``artifacts/``:
  weights_{s,m,l}.npz / .bin    trained tiny-GPT weights (npz for python,
                                bin for the Rust loader)
  codebooks.json                universal LO-BCQ families (raw levels;
                                consumers apply INT-B_c, paper §3)
  model_{size}_{variant}_b{B}.hlo.txt   weights-as-inputs forwards
  op_lobcq_quant.hlo.txt        standalone quantize op (books as inputs —
                                the Rust↔kernel parity surface)
  op_gemm.hlo.txt               standalone Pallas GEMM
  manifest.json                 everything the Rust side needs to load
"""

import argparse
import json
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, lobcq as L, train as T
from .kernels.gemm import gemm
from .kernels.lobcq_quant import lobcq_fake_quant
from .model import SIZES, QuantSpec, forward_flat, param_names, param_shapes

ART = Path(__file__).resolve().parents[2] / "artifacts"

# The activation-quant graph variants lowered per model size (eval batch).
ACTQ_VARIANTS = [
    ("lobcq_g64_nc2", dict(scheme="lobcq", lb=8, la=64, nc=2)),
    ("lobcq_g64_nc8", dict(scheme="lobcq", lb=8, la=64, nc=8)),
    ("lobcq_g32_nc16", dict(scheme="lobcq", lb=8, la=32, nc=16)),
    ("mx4", dict(scheme="mx4")),
    ("vsq", dict(scheme="vsq")),
    ("mxfp4", dict(scheme="mxfp4")),
]

EVAL_BATCH = 8
SERVE_BATCHES = (1, 8)

# Universal codebook families calibrated from the proxy ("s") model
# weights + activations (paper §4.1 calibrates on GPT3-126M).
FAMILY_SPECS = [(nc, 4) for nc in (1, 2, 4, 8, 16)] + \
               [(nc, 3) for nc in (4, 8)] + [(nc, 2) for nc in (4, 8)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def write_text(path: Path, text: str):
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(text)
    print(f"[aot] wrote {path.name} ({len(text) / 1024:.0f} KiB)", flush=True)


# ---- codebook calibration ----

def calibration_blocks(params_s: dict, lb: int = 8, la: int = 64, max_blocks: int = 4096) -> np.ndarray:
    """Pool normalized blocks from the proxy model's GEMM weights and one
    batch of activations on training data (§4.1)."""
    cfg_norm = L.LobcqConfig(lb=lb, la=la)
    pools = []
    for name, w in params_s.items():
        if w.ndim == 2 and not name.startswith(("embed", "pos")):
            vals, _, _ = L.normalize(np.ascontiguousarray(w.T), cfg_norm)
            pools.append(vals.reshape(-1, lb))
    # Activations: one batch through the proxy model.
    from .model import collect_activation_taps
    toks = np.array(corpus.generate(T.TRAIN_SEED, 16 * 65)).reshape(16, 65)[:, :64].astype(np.int32)
    taps = collect_activation_taps({k: jnp.asarray(v) for k, v in params_s.items()},
                                   jnp.asarray(toks), SIZES["s"])
    for a in taps:
        vals, _, _ = L.normalize(np.ascontiguousarray(a), cfg_norm)
        pools.append(vals.reshape(-1, lb))
    blocks = np.concatenate(pools, axis=0)
    # Deterministic subsample.
    rng = np.random.default_rng(0xB10C)
    idx = rng.permutation(blocks.shape[0])[:max_blocks]
    return blocks[idx]


def calibrate_families(params_s: dict) -> dict:
    blocks = calibration_blocks(params_s)
    fams = {}
    for nc, b in FAMILY_SPECS:
        cfg = L.LobcqConfig(lb=8, la=64, nc=nc, b=b, bc=6)
        res = L.calibrate(blocks, cfg, seed=0x5EED + nc * 10 + b, max_iters=40, rel_tol=1e-5)
        key = f"nc{nc}_b{b}"
        fams[key] = {
            "b": b,
            "nc": nc,
            "books": [[float(x) for x in row] for row in res.books],
            "final_mse": res.trace[-1],
            "iters": len(res.trace),
        }
        print(f"[calib] {key}: J={res.trace[-1]:.5f} after {len(res.trace)} iters", flush=True)
    return fams


def family_books(fams: dict, nc: int, b: int = 4, bc: int = 6) -> np.ndarray:
    raw = np.array(fams[f"nc{nc}_b{b}"]["books"], dtype=np.float32)
    return L.quantize_codewords(raw, bc)


# ---- weights.bin (rust loader format) ----

def write_weights_bin(path: Path, params: dict, names: list):
    import struct

    with open(path, "wb") as f:
        f.write(b"LWTS")
        f.write(struct.pack("<I", 1))
        f.write(struct.pack("<I", len(names)))
        for name in names:
            w = np.ascontiguousarray(params[name], dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", w.ndim))
            for d in w.shape:
                f.write(struct.pack("<I", d))
            f.write(w.tobytes())
    print(f"[aot] wrote {path.name}", flush=True)


# ---- lowering ----

def lower_model(size: str, variant: str, spec: QuantSpec, batch: int, t: int) -> str:
    """Lower one model graph. LO-BCQ variants take the frozen codebooks
    as a graph *input* `(Nc, 16)` right after tokens — both closer to the
    paper's deployment (tiny runtime-resident table) and a workaround for
    xla_extension 0.5.1 mis-executing constant-baked codebooks (decodes
    to zeros; probed in rust integration tests)."""
    cfg = SIZES[size]
    shapes = param_shapes(cfg)
    tok_spec = jax.ShapeDtypeStruct((batch, t), jnp.int32)
    w_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes.values()]

    if spec.scheme == "lobcq":
        nc = len(spec.books)
        books_spec = jax.ShapeDtypeStruct((nc, 1 << 4), jnp.float32)

        def fn(tokens, books, *ws):
            return (forward_flat(ws, tokens, cfg, spec, books_arr=books),)

        lowered = jax.jit(fn).lower(tok_spec, books_spec, *w_specs)
    else:

        def fn(tokens, *ws):
            return (forward_flat(ws, tokens, cfg, spec),)

        lowered = jax.jit(fn).lower(tok_spec, *w_specs)
    return to_hlo_text(lowered)


def lower_ops() -> dict:
    """Standalone op artifacts (parity + micro-bench surfaces)."""
    out = {}

    def quant_fn(x, books):
        return (lobcq_fake_quant(x, books, lb=8, la=64, norm_max=31.0),)

    lowered = jax.jit(quant_fn).lower(
        jax.ShapeDtypeStruct((8, 256), jnp.float32),
        jax.ShapeDtypeStruct((8, 16), jnp.float32))
    out["op_lobcq_quant"] = {"file": "op_lobcq_quant.hlo.txt", "x_shape": [8, 256],
                             "books_shape": [8, 16], "lb": 8, "la": 64, "norm_max": 31.0,
                             "text": to_hlo_text(lowered)}

    def gemm_fn(a, b):
        return (gemm(a, b),)

    lowered = jax.jit(gemm_fn).lower(
        jax.ShapeDtypeStruct((32, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 128), jnp.float32))
    out["op_gemm"] = {"file": "op_gemm.hlo.txt", "a_shape": [32, 256], "b_shape": [256, 128],
                      "text": to_hlo_text(lowered)}
    return out


def make_spec(fams: dict, variant_cfg: dict) -> QuantSpec:
    cfgd = dict(variant_cfg)
    scheme = cfgd.pop("scheme")
    if scheme == "lobcq":
        books = family_books(fams, cfgd.pop("nc"))
        return QuantSpec(scheme="lobcq", books=tuple(map(tuple, books.tolist())), **cfgd)
    return QuantSpec(scheme=scheme)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=str(ART))
    ap.add_argument("--sizes", default="s,m,l")
    ap.add_argument("--skip-actq", action="store_true", help="bf16 artifacts only (fast dev)")
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    sizes = args.sizes.split(",")

    # 1. Train (skips sizes whose weights exist).
    T.main(out_dir=out, sizes=sizes)

    # 2. Calibrate universal codebooks from the proxy model.
    params_s = T.load_params("s", out) if "s" in sizes else T.load_params(sizes[0], out)
    cb_path = out / "codebooks.json"
    if cb_path.exists():
        fams = json.loads(cb_path.read_text())["families"]
        print("[calib] codebooks.json exists, reusing")
    else:
        fams = calibrate_families(params_s)
        cb_path.write_text(json.dumps({"families": fams, "calibrated_on": "s"}, indent=2))

    # 3. Weights in rust format + manifest skeleton.
    manifest = {
        "vocab": corpus.VOCAB,
        "max_t": 64,
        "corpus": {
            "train_seed": T.TRAIN_SEED,
            "val_seed": T.VAL_SEED,
            "val_tokens": T.VAL_TOKENS,
            "val_fingerprint": str(corpus.fingerprint(corpus.generate(T.VAL_SEED, T.VAL_TOKENS))),
        },
        "codebooks": "codebooks.json",
        "models": {},
        "artifacts": [],
        "ops": {},
    }

    for size in sizes:
        cfg = SIZES[size]
        params = T.load_params(size, out)
        names = param_names(cfg)
        write_weights_bin(out / f"weights_{size}.bin", params, names)
        manifest["models"][size] = {
            "d": cfg.d,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "vocab": cfg.vocab,
            "max_t": cfg.max_t,
            "params": cfg.param_count(),
            "weights_bin": f"weights_{size}.bin",
            "weight_names": names,
            "weight_shapes": [list(param_shapes(cfg)[n]) for n in names],
        }

    # 4. Lower model graphs.
    for size in sizes:
        for batch in SERVE_BATCHES:
            name = f"model_{size}_bf16_b{batch}"
            path = out / f"{name}.hlo.txt"
            if not path.exists():
                write_text(path, lower_model(size, "bf16", QuantSpec(), batch, 64))
            manifest["artifacts"].append(
                {"file": path.name, "size": size, "variant": "bf16", "batch": batch, "t": 64})
        if args.skip_actq:
            continue
        for vname, vcfg in ACTQ_VARIANTS:
            spec = make_spec(fams, vcfg)
            name = f"model_{size}_{vname}_b{EVAL_BATCH}"
            path = out / f"{name}.hlo.txt"
            if not path.exists():
                write_text(path, lower_model(size, vname, spec, EVAL_BATCH, 64))
            entry = {"file": path.name, "size": size, "variant": vname,
                     "batch": EVAL_BATCH, "t": 64}
            if spec.scheme == "lobcq":
                entry["books_nc"] = len(spec.books)
            manifest["artifacts"].append(entry)
    # Serving latency variant: quantized decode at batch 1 for "m".
    if not args.skip_actq and "m" in sizes:
        spec = make_spec(fams, dict(ACTQ_VARIANTS[1][1]))
        path = out / "model_m_lobcq_g64_nc8_b1.hlo.txt"
        if not path.exists():
            write_text(path, lower_model("m", "lobcq_g64_nc8", spec, 1, 64))
        manifest["artifacts"].append(
            {"file": path.name, "size": "m", "variant": "lobcq_g64_nc8", "batch": 1, "t": 64,
             "books_nc": len(spec.books)})

    # 5. Standalone ops.
    ops = lower_ops()
    for key, meta in ops.items():
        text = meta.pop("text")
        path = out / meta["file"]
        if not path.exists():
            write_text(path, text)
        manifest["ops"][key] = meta

    (out / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"[aot] manifest with {len(manifest['artifacts'])} model artifacts -> {out / 'manifest.json'}")


if __name__ == "__main__":
    main()
