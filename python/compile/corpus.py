"""Deterministic synthetic corpus — token-exact mirror of
``rust/src/data/corpus.rs``.

A small stochastic grammar over a 168-token vocabulary stands in for
Wikitext (no network in this environment; DESIGN.md §1 documents the
substitution). The grammar has real sequential structure — determiner →
adjective → noun agreement ranges, verb argument frames, Zipf-skewed word
choice — so a tiny trained transformer reaches perplexity far below the
uniform baseline and quantization-induced perplexity deltas are
meaningful.

Token id layout (contiguous ranges):
    0          PAD
    1          BOS
    2..6       determiners   (4)
    6..38      adjectives    (32)
    38..102    nouns         (64)
    102..150   verbs         (48)
    150..166   adverbs       (16)
    166        COMMA
    167        PERIOD
"""

from .pcg import Pcg32

PAD = 0
BOS = 1
DET0, N_DET = 2, 4
ADJ0, N_ADJ = 6, 32
NOUN0, N_NOUN = 38, 64
VERB0, N_VERB = 102, 48
ADV0, N_ADV = 150, 16
COMMA = 166
PERIOD = 167
VOCAB = 168


def zipf(rng: Pcg32, n: int) -> int:
    """Zipf-ish skewed index in [0, n): floor(n * u^2)."""
    u = rng.next_f32()
    i = int(n * u * u)
    return min(i, n - 1)


def noun_phrase(rng: Pcg32, out: list) -> None:
    det = zipf(rng, N_DET)
    out.append(DET0 + det)
    if rng.next_f32() < 0.5:
        # Adjective choice is correlated with the determiner (structure
        # for the model to learn): each det owns a band of 8 adjectives.
        band = det * 8
        out.append(ADJ0 + band + zipf(rng, 8))
    out.append(NOUN0 + zipf(rng, N_NOUN))


def verb_phrase(rng: Pcg32, out: list) -> None:
    verb = zipf(rng, N_VERB)
    out.append(VERB0 + verb)
    u = rng.next_f32()
    if u < 0.6:
        noun_phrase(rng, out)
    elif u < 0.85:
        # Adverb band correlated with the verb.
        out.append(ADV0 + (verb % 4) * 4 + zipf(rng, 4))
    # else: intransitive, nothing.


def sentence(rng: Pcg32, out: list) -> None:
    noun_phrase(rng, out)
    verb_phrase(rng, out)
    if rng.next_f32() < 0.2:
        out.append(COMMA)
        verb_phrase(rng, out)
    out.append(PERIOD)


def generate(seed: int, n_tokens: int) -> list:
    """Generate exactly ``n_tokens`` tokens (BOS + sentences, truncated)."""
    rng = Pcg32(seed, 0xDA7A)
    out = [BOS]
    while len(out) < n_tokens:
        sentence(rng, out)
    return out[:n_tokens]


def fingerprint(tokens) -> int:
    """FNV-1a over token ids — cross-language corpus identity check."""
    h = 0xCBF29CE484222325
    for t in tokens:
        h ^= t
        h = (h * 0x100000001B3) & ((1 << 64) - 1)
    return h
