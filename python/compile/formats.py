"""Low-precision number formats in numpy/jax — mirror of
``rust/src/formats/``. Parity with the Rust codecs is enforced by
``tests/test_parity.py`` on vectors emitted by ``lobcq gen-parity``.

All functions are pure and work on numpy arrays or jnp arrays (the
quantize path uses only ufuncs jnp also provides, so the Pallas kernel
imports these directly).
"""

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class FloatFormat:
    """Finite EeMm float format (see rust formats/float.rs)."""

    name: str
    be: int
    bm: int
    bias: int
    max_value: float

    @property
    def emin(self) -> int:
        return 1 - self.bias

    @property
    def min_subnormal(self) -> float:
        return 2.0 ** (self.emin - self.bm)

    @property
    def bits(self) -> int:
        return 1 + self.be + self.bm


def make_format(name: str, be: int, bm: int, max_value: float | None = None) -> FloatFormat:
    bias = (1 << (be - 1)) - 1 if be >= 1 else 0
    emax = (1 << be) - 1 - bias
    default_max = float((2 << bm) - 1) * 2.0 ** (emax - bm)
    return FloatFormat(name, be, bm, bias, max_value if max_value is not None else default_max)


E1M2 = make_format("E1M2", 1, 2)
E2M1 = make_format("E2M1", 2, 1)
E3M0 = make_format("E3M0", 3, 0)
E4M3 = make_format("E4M3", 4, 3, 448.0)
E5M2 = make_format("E5M2", 5, 2, 57344.0)
E3M3 = make_format("E3M3", 3, 3)
E3M2 = make_format("E3M2", 3, 2)
E4M0 = make_format("E4M0", 4, 0)

BY_NAME = {f.name: f for f in [E1M2, E2M1, E3M0, E4M3, E5M2, E3M3, E3M2, E4M0]}


def quantize_float(x, fmt: FloatFormat, xp=np):
    """Round-to-nearest-even quantization to the EeMm grid with
    saturation — same semantics as rust ``FloatFormat::quantize``.

    ``xp`` selects the array namespace (numpy or jax.numpy) so the same
    code serves ref.py and the Pallas kernel body.
    """
    x = xp.asarray(x, dtype=xp.float32)
    a = xp.abs(x)
    # Bucket exponent, clamped to the subnormal region.
    safe = xp.where(a > 0, a, xp.float32(1.0))
    e = xp.floor(xp.log2(safe))
    e = xp.maximum(e, xp.float32(fmt.emin))
    step = xp.exp2(e - fmt.bm)
    q = xp.round(a / step) * step  # numpy/jax round = ties-to-even
    q = xp.minimum(q, xp.float32(fmt.max_value))
    q = xp.where(a == 0, xp.float32(0.0), q)
    q = xp.where(a >= fmt.max_value, xp.float32(fmt.max_value), q)
    return xp.copysign(q, x)


def quantize_int(x, bits: int, xp=np):
    """Symmetric INT-k round-ties-even with saturation (rust IntFormat)."""
    m = float((1 << (bits - 1)) - 1)
    x = xp.asarray(x, dtype=xp.float32)
    return xp.round(xp.clip(x, -m, m))


def e8m0_floor(x, xp=np):
    """Power-of-two floor scale (MX convention); degenerate -> 2^-127."""
    x = xp.asarray(x, dtype=xp.float32)
    safe = xp.where(x > 0, x, xp.float32(1.0))
    e = xp.clip(xp.floor(xp.log2(safe)), -127.0, 127.0)
    out = xp.exp2(e)
    return xp.where(x > 0, out, xp.float32(2.0 ** -127))


def bf16_round(x):
    """Round f32 to the bf16 grid (RNE on the low 16 bits; numpy only —
    the jax path uses ``astype(jnp.bfloat16)`` which is identical)."""
    x = np.asarray(x, dtype=np.float32)
    bits = x.view(np.uint32)
    rounding_bias = np.uint32(0x7FFF) + ((bits >> np.uint32(16)) & np.uint32(1))
    out_bits = (bits + rounding_bias) & np.uint32(0xFFFF0000)
    return out_bits.view(np.float32)
