"""L1 Pallas kernel: tiled GEMM over (dequantized) operands.

The paper's inference GEMMs consume LO-BCQ-decoded 6-bit-integer
codewords; its own evaluation emulates them in BF16 (§4.1 fn. 3). This
kernel is the MXU half of that pipeline: a classic (TM, TN, TK) tiled
matmul with an f32 accumulator, structured for the TPU systolic array
(DESIGN.md §Hardware-Adaptation). `interpret=True` for CPU-PJRT.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    del n_k


def gemm(a, b, *, tm: int = 32, tn: int = 32, tk: int = 32, interpret: bool = True):
    """`a (M, K) @ b (K, N) -> (M, N)` with zero-padding to tile multiples."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} vs {k2}"

    pm, pk, pn = (-m) % tm, (-k) % tk, (-n) % tn
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    gm, gk, gn = a.shape[0] // tm, a.shape[1] // tk, b.shape[1] // tn

    out = pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], b.shape[1]), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]


def quantized_gemm(x, w, books, *, lb: int, la: int, norm_max: float, interpret: bool = True):
    """The full W4A4 pipeline: LO-BCQ fake-quantize both operands, then
    the tiled GEMM — the composition the serving artifacts lower."""
    from .lobcq_quant import lobcq_fake_quant

    xq = lobcq_fake_quant(x, books, lb=lb, la=la, norm_max=norm_max, interpret=interpret)
    wq = lobcq_fake_quant(w.T, books, lb=lb, la=la, norm_max=norm_max, interpret=interpret).T
    return gemm(xq, wq, interpret=interpret)
