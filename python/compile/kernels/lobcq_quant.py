"""L1 Pallas kernel: fused LO-BCQ fake-quantization (the paper's
deployment hot-spot, §3).

One kernel pass per operand tile performs the full on-the-fly pipeline:
block-array max-reduce → E4M3 relative scale (eq. 7–8) → per-block
codebook selection (eq. 4) → per-scalar nearest-codeword rounding (eq. 2)
→ dequantize. The frozen codebooks (≤ 0.19 KB) ride along as a tiny VMEM-
resident input — exactly the hardware-friendliness claim of the paper.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles rows; each
tile holds `TILE_R` rows of the operand in VMEM. The distance tensor
(TILE_R·K/L_b, N_c, L_b, E) is the dominant VMEM term — see
``vmem_estimate`` below, asserted ≤ 4 MiB in tests for serving shapes.
`interpret=True` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import lobcq_fake_quant_ref, tensor_scale


def _kernel(x_ref, books_ref, sx_ref, o_ref, *, lb: int, la: int, norm_max: float):
    x = x_ref[...]
    books = books_ref[...]
    s_x = sx_ref[0, 0]
    o_ref[...] = lobcq_fake_quant_ref(x, books, s_x, lb=lb, la=la, norm_max=norm_max)


def lobcq_fake_quant(x, books, *, lb: int, la: int, norm_max: float, tile_rows: int = 8,
                     interpret: bool = True):
    """Fake-quantize ``x`` (..., K) with frozen ``books`` via Pallas.

    The per-tensor scale s_X is a global max-reduce computed outside the
    kernel (one cheap XLA reduction); everything per-block-array happens
    inside the tiled kernel.
    """
    x = jnp.asarray(x, dtype=jnp.float32)
    books = jnp.asarray(books, dtype=jnp.float32)
    shape = x.shape
    k = shape[-1]
    assert k % la == 0, f"K={k} must be a multiple of L_A={la}"
    rows = x.size // k
    x2 = x.reshape(rows, k)

    # Pad rows to a multiple of the tile.
    pad = (-rows) % tile_rows
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, k), jnp.float32)], axis=0)
    padded_rows = x2.shape[0]

    s_x = tensor_scale(x, norm_max).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, lb=lb, la=la, norm_max=norm_max),
        grid=(padded_rows // tile_rows,),
        in_specs=[
            pl.BlockSpec((tile_rows, k), lambda i: (i, 0)),
            pl.BlockSpec(books.shape, lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_rows, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_rows, k), jnp.float32),
        interpret=interpret,
    )(x2, books, s_x)

    return out[:rows].reshape(shape)


def vmem_estimate(tile_rows: int, k: int, nc: int, entries: int, lb: int) -> int:
    """Estimated VMEM bytes for one tile (DESIGN.md §Perf): input tile +
    output tile + the (n_blocks, Nc, L_b, E) distance tensor + codebooks."""
    tile = tile_rows * k * 4
    n_blocks = tile_rows * k // lb
    dist = n_blocks * nc * lb * entries * 4
    books = nc * entries * 4
    return 2 * tile + dist + books


def mxu_utilization_note(k: int, d_out: int, nc: int, entries: int, lb: int) -> str:
    """Analytic MXU utilization estimate for the quantize+GEMM pipeline
    (recorded in EXPERIMENTS.md §Perf; interpret-mode wallclock is NOT a
    TPU proxy). The quantizer is VPU work; the GEMM is MXU work. Ratio of
    quantizer FLOPs to GEMM MACs bounds the MXU duty cycle."""
    vpu_flops_per_scalar = nc * entries * 3 / 1  # dist, square, min-tree per scalar
    gemm_macs_per_scalar = d_out  # each A scalar feeds d_out MACs
    duty = gemm_macs_per_scalar / (gemm_macs_per_scalar + vpu_flops_per_scalar)
    return (
        f"quantize VPU ops/scalar≈{vpu_flops_per_scalar:.0f}, "
        f"GEMM MACs/scalar={gemm_macs_per_scalar}, "
        f"MXU duty bound≈{duty:.2%} (overlappable: quantize of tile t+1 "
        f"can run on VPU while MXU consumes tile t)"
    )
