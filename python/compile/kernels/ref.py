"""Pure-jnp reference oracles (L1 correctness baseline).

Every Pallas kernel in this package is `assert_allclose`-checked against
the functions here (pytest + hypothesis sweeps), and these in turn are
checked against the numpy/f64 oracle in ``compile.lobcq`` and the Rust
implementation (parity vectors). All math is f32 to match both the
kernels and the Rust hot path.
"""

import jax.numpy as jnp
import numpy as np

from ..formats import E4M3, quantize_float


def lobcq_fake_quant_ref(x, books, s_x, *, lb: int, la: int, norm_max: float):
    """LO-BCQ fake-quantize (paper eq. 2, 4, 7–8) over the trailing axis.

    x:      (..., K) f32, K % la == 0
    books:  (Nc, E) f32 sorted codeword levels (INT-B_c-quantized)
    s_x:    scalar per-tensor scale (norm_max / max|x|), computed by the
            caller (a global reduction that stays outside the tile kernel)
    Returns the dequantized tensor, same shape.
    """
    x = jnp.asarray(x, dtype=jnp.float32)
    shape = x.shape
    arrays = x.reshape(-1, la)
    amax = jnp.max(jnp.abs(arrays), axis=1)
    s_a = norm_max / jnp.where(amax > 0, amax, 1.0)
    rel = quantize_float(s_a / s_x, E4M3, xp=jnp)
    # Zero block arrays get scale 0 -> exact-zero dequant (matches rust).
    eff = jnp.where(amax > 0, rel * s_x, 0.0).astype(jnp.float32)
    v = arrays * eff[:, None]

    blocks = v.reshape(-1, lb)  # (n, lb)
    # (n, Nc, lb, E) squared distances to every codeword.
    d = blocks[:, None, :, None] - books[None, :, None, :]
    e = d * d
    per_scalar = jnp.min(e, axis=3)  # (n, Nc, lb)
    entry_idx = jnp.argmin(e, axis=3)  # (n, Nc, lb) — first min = lower level
    errs = jnp.sum(per_scalar, axis=2)  # (n, Nc)
    sel = jnp.argmin(errs, axis=1)  # (n,)
    q_all = books[jnp.arange(books.shape[0])[None, :, None], entry_idx]  # (n, Nc, lb)
    q = jnp.take_along_axis(q_all, sel[:, None, None], axis=1)[:, 0, :]  # (n, lb)

    inv = jnp.where(eff != 0, 1.0 / eff, 0.0).astype(jnp.float32)
    out = q.reshape(-1, la) * inv[:, None]
    return out.reshape(shape)


def tensor_scale(x, norm_max: float):
    """Per-tensor scale s_X = norm_max / max|x| (eq. 8 denominator)."""
    amax = jnp.max(jnp.abs(x))
    return jnp.where(amax > 0, norm_max / jnp.where(amax > 0, amax, 1.0), 1.0).astype(jnp.float32)


def lobcq_fake_quant_full_ref(x, books, *, lb: int, la: int, norm_max: float):
    """Convenience: computes s_x internally."""
    return lobcq_fake_quant_ref(x, books, tensor_scale(x, norm_max), lb=lb, la=la, norm_max=norm_max)


def matmul_ref(a, b):
    """f32 matmul oracle for the Pallas GEMM kernel."""
    return jnp.matmul(jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
                      precision="highest")


# ---- baseline quantizers in jnp (model graph variants, §4.1) ----

def mx4_quant_ref(x, *, block_len: int = 16):
    """MX4 proxy: E1M2 scalars + per-block E8M0 floor scale (A.5.1)."""
    from ..formats import E1M2, e8m0_floor

    x = jnp.asarray(x, dtype=jnp.float32)
    shape = x.shape
    blocks = x.reshape(-1, block_len)
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = e8m0_floor(jnp.where(amax > 0, E1M2.max_value / jnp.where(amax > 0, amax, 1.0), 1.0), xp=jnp)
    q = quantize_float(blocks * scale, E1M2, xp=jnp) / scale
    q = jnp.where(amax > 0, q, 0.0)
    return q.reshape(shape)


def mxfp4_quant_ref(x, *, block_len: int = 32):
    """MXFP4: E2M1 scalars + per-block E8M0 floor scale."""
    from ..formats import E2M1, e8m0_floor

    x = jnp.asarray(x, dtype=jnp.float32)
    shape = x.shape
    blocks = x.reshape(-1, block_len)
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = e8m0_floor(jnp.where(amax > 0, E2M1.max_value / jnp.where(amax > 0, amax, 1.0), 1.0), xp=jnp)
    q = quantize_float(blocks * scale, E2M1, xp=jnp) / scale
    q = jnp.where(amax > 0, q, 0.0)
    return q.reshape(shape)


def vsq_quant_ref(x, *, vec_len: int = 16, scalar_bits: int = 4, scale_bits: int = 8):
    """VSQ: INT4 scalars, per-vector scale itself on a UINT8 linear grid
    (A.5) — including the wide-dynamic-range collapse failure mode."""
    x = jnp.asarray(x, dtype=jnp.float32)
    shape = x.shape
    smax = float((1 << (scalar_bits - 1)) - 1)
    vecs = x.reshape(-1, vec_len)
    amax = jnp.max(jnp.abs(vecs), axis=1)
    scales = jnp.where(amax > 0, smax / jnp.where(amax > 0, amax, 1.0), 0.0)
    scale_max = jnp.max(scales)
    levels = float((1 << scale_bits) - 1)
    s2 = jnp.where(scale_max > 0, levels / scale_max, 0.0)
    qs = jnp.where(s2 > 0, jnp.maximum(jnp.round(scales * s2), 0.0) / s2, 0.0)
    q = jnp.round(jnp.clip(vecs * qs[:, None], -smax, smax))
    deq = jnp.where(qs[:, None] > 0, q / jnp.where(qs[:, None] > 0, qs[:, None], 1.0), 0.0)
    return deq.reshape(shape)


def quant_ref_by_name(name: str):
    """Scheme registry used by model.py's activation-quant variants."""
    return {
        "mx4": mx4_quant_ref,
        "mxfp4": mxfp4_quant_ref,
        "vsq": vsq_quant_ref,
    }[name]


def numpy_oracle_check(x, books, cfg):
    """Cross-check helper: f64-accurate numpy result for the same op."""
    from .. import lobcq as L

    return L.fake_quantize(np.asarray(x, np.float32), cfg, np.asarray(books, np.float32))
