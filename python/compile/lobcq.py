"""LO-BCQ calibration in numpy — build-time mirror of
``rust/src/quant/lobcq.rs`` (paper §2.2–2.4).

Used by ``aot.py`` to calibrate the universal codebook families shipped in
``artifacts/codebooks.json`` (raw levels; consumers apply INT-B_c codeword
quantization). The fake-quantize here is the numpy oracle the Pallas
kernel and the Rust implementation are both checked against.
"""

from dataclasses import dataclass, field

import numpy as np

from .formats import E4M3, quantize_float, quantize_int
from .pcg import Pcg32


@dataclass(frozen=True)
class LobcqConfig:
    lb: int = 8
    la: int = 64
    nc: int = 8
    b: int = 4
    bc: int = 6

    @property
    def entries(self) -> int:
        return 1 << self.b

    @property
    def norm_max(self) -> float:
        return float((1 << (self.bc - 1)) - 1)

    @property
    def bitwidth(self) -> float:
        """eq. 9 without the negligible codebook term."""
        return self.b + np.log2(self.nc) / self.lb + 8.0 / self.la


def normalize(data: np.ndarray, cfg: LobcqConfig):
    """Per-block-array normalization (eq. 7–8), f32 semantics matching
    rust ``lobcq::normalize``. Returns (values, eff_scales, s_x)."""
    flat = np.asarray(data, dtype=np.float32).reshape(-1)
    assert flat.size % cfg.la == 0, f"{flat.size} % {cfg.la} != 0"
    nm = np.float32(cfg.norm_max)
    tensor_amax = np.float32(np.max(np.abs(flat))) if flat.size else np.float32(0)
    s_x = nm / tensor_amax if tensor_amax > 0 else np.float32(1.0)
    arrays = flat.reshape(-1, cfg.la)
    amax = np.max(np.abs(arrays), axis=1).astype(np.float32)
    s_a = (nm / np.where(amax > 0, amax, 1)).astype(np.float32)
    rel = quantize_float(s_a / s_x, E4M3).astype(np.float32)
    # All-zero block arrays get scale 0: decode's inverse-scale guard then
    # reproduces exact zeros (mirrors rust + the Pallas kernel).
    eff = np.where(amax > 0, rel * s_x, np.float32(0.0)).astype(np.float32)
    values = (arrays * eff[:, None]).astype(np.float32)
    return values.reshape(-1), eff, np.float32(s_x)


def nearest_index(levels: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Nearest sorted-level index with ties to the LOWER level — identical
    tie rule to rust ``nearest_level_index``."""
    idx = np.searchsorted(levels, x)  # first level >= x ... (left)
    idx = np.clip(idx, 0, len(levels) - 1)
    lo = np.clip(idx - 1, 0, len(levels) - 1)
    take_lo = (idx > 0) & ((x - levels[lo]) <= (levels[idx] - x))
    return np.where(take_lo, lo, idx)


def quantize_with_levels(levels: np.ndarray, x: np.ndarray) -> np.ndarray:
    return levels[nearest_index(levels, x)]


def lloyd_max(data: np.ndarray, init_levels: np.ndarray, max_iters: int = 100, rel_tol: float = 1e-9):
    """1-D Lloyd-Max warm-started from ``init_levels`` (sorted)."""
    data = np.sort(np.asarray(data, dtype=np.float32))
    levels = np.array(init_levels, dtype=np.float32)
    if data.size == 0:
        return levels
    prev = np.inf
    for _ in range(max_iters):
        thr = 0.5 * (levels[:-1] + levels[1:])
        bounds = np.concatenate([[0], np.searchsorted(data, thr), [data.size]])
        for i in range(len(levels)):
            lo, hi = bounds[i], bounds[i + 1]
            if hi > lo:
                levels[i] = np.float32(np.mean(data[lo:hi].astype(np.float64)))
        levels = np.sort(levels)
        mse = float(np.mean((data - quantize_with_levels(levels, data)) ** 2))
        if np.isfinite(prev) and prev - mse <= rel_tol * max(prev, 1e-30):
            break
        prev = mse
    return levels


def quantile_init(data: np.ndarray, k: int) -> np.ndarray:
    data = np.sort(np.asarray(data, dtype=np.float32))
    if data.size == 0:
        return np.arange(k, dtype=np.float32)
    q = (np.arange(k) + 0.5) / k
    levels = data[np.minimum((q * data.size).astype(int), data.size - 1)].astype(np.float32)
    for i in range(1, k):
        if levels[i] <= levels[i - 1]:
            levels[i] = levels[i - 1] + np.float32(1.1920929e-07) * (1 + abs(levels[i - 1]))
    return levels


@dataclass
class CalibResult:
    books: np.ndarray  # (Nc, 2^B) raw (unquantized) levels
    trace: list = field(default_factory=list)


def kmeanspp_seeds(blocks: np.ndarray, k: int, rng: Pcg32) -> list:
    """k-means++ (D² sampling) over block rows."""
    n = blocks.shape[0]
    seeds = [rng.index(n)]
    d2 = np.sum((blocks - blocks[seeds[0]]) ** 2, axis=1).astype(np.float64)
    while len(seeds) < k:
        total = float(d2.sum())
        if total <= 0:
            seeds.append(rng.index(n))
        else:
            x = rng.next_f64() * total
            pick = int(np.searchsorted(np.cumsum(d2), x))
            pick = min(pick, n - 1)
            seeds.append(pick)
        d2 = np.minimum(d2, np.sum((blocks - blocks[seeds[-1]]) ** 2, axis=1))
    return seeds


def block_errors(books: np.ndarray, blocks: np.ndarray, chunk: int = 2048) -> np.ndarray:
    """(n_blocks, Nc) squared error of quantizing each block with each
    codebook; accumulated in float64 to match rust's f64 accumulation.
    Chunked so the (n, Nc, lb, E) distance tensor stays bounded."""
    out = np.empty((blocks.shape[0], books.shape[0]), dtype=np.float64)
    for lo in range(0, blocks.shape[0], chunk):
        sl = blocks[lo:lo + chunk]
        d = sl[:, None, :, None].astype(np.float64) - books[None, :, None, :].astype(np.float64)
        per_scalar = np.min(d * d, axis=3)
        out[lo:lo + chunk] = per_scalar.sum(axis=2)
    return out


def calibrate(blocks: np.ndarray, cfg: LobcqConfig, seed: int = 0, max_iters: int = 100,
              rel_tol: float = 1e-6) -> CalibResult:
    """LO-BCQ iterations (eq. 4–6) on normalized blocks (n, lb)."""
    blocks = np.asarray(blocks, dtype=np.float32)
    n = blocks.shape[0]
    rng = Pcg32(seed, 0xC0FFEE)

    # --- init: kmeans++ seeds -> cluster -> per-cluster Lloyd-Max ---
    seeds = kmeanspp_seeds(blocks, cfg.nc, rng)
    seed_blocks = blocks[seeds]
    d = blocks[:, None, :] - seed_blocks[None, :, :]
    assign = np.argmin(np.sum(d * d, axis=2), axis=1)
    books = np.zeros((cfg.nc, cfg.entries), dtype=np.float32)
    for c in range(cfg.nc):
        members = blocks[assign == c].reshape(-1)
        init = quantile_init(members, cfg.entries)
        books[c] = lloyd_max(members, init)

    trace = []
    total_scalars = blocks.size
    for _ in range(max_iters):
        # step 1: reassign (eq. 4)
        errs = block_errors(books, blocks)
        assign = np.argmin(errs, axis=1)
        # step 2: refit (eq. 6), warm-started
        for c in range(cfg.nc):
            members = blocks[assign == c].reshape(-1)
            if members.size:
                books[c] = lloyd_max(members, books[c])
        sq = 0.0
        for c in range(cfg.nc):
            members = blocks[assign == c].reshape(-1)
            if members.size:
                q = quantize_with_levels(np.sort(books[c]), members)
                sq += float(np.sum((members.astype(np.float64) - q) ** 2))
        j = sq / total_scalars
        if trace and trace[-1] - j <= rel_tol * max(trace[-1], 1e-30):
            trace.append(j)
            break
        trace.append(j)
    books = np.sort(books, axis=1)
    assert n == blocks.shape[0]
    return CalibResult(books=books, trace=trace)


def quantize_codewords(books: np.ndarray, bc: int) -> np.ndarray:
    return np.sort(quantize_int(books, bc), axis=1).astype(np.float32)


def fake_quantize(data: np.ndarray, cfg: LobcqConfig, books: np.ndarray) -> np.ndarray:
    """Numpy oracle: normalize → select codebook per block (f64 errors,
    first-min ties) → nearest codeword (ties to lower) → denormalize.
    Matches rust ``lobcq::fake_quantize`` and the Pallas kernel."""
    shape = np.asarray(data).shape
    values, eff, _ = normalize(data, cfg)
    blocks = values.reshape(-1, cfg.lb)
    errs = block_errors(books, blocks)
    sel = np.argmin(errs, axis=1)
    out = np.empty_like(blocks, dtype=np.float32)
    for c in range(books.shape[0]):
        mask = sel == c
        if mask.any():
            out[mask] = quantize_with_levels(books[c], blocks[mask])
    arrays = out.reshape(-1, cfg.la)
    inv = np.where(eff != 0, np.float32(1.0) / eff, np.float32(0.0)).astype(np.float32)
    return (arrays * inv[:, None]).astype(np.float32).reshape(shape)
