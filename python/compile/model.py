"""L2: tiny decoder-only GPT in JAX with quantized GEMMs.

The paper quantizes the QKV, attention-projection, and fully-connected
GEMMs of GPT3/Llama2/Nemotron4 (§4.1); this model has exactly those GEMM
sites. Three sizes (s/m/l) stand in for the paper's model-size axis
(DESIGN.md §1 substitutions). Weights are *inputs* to the lowered graphs,
so the Rust side can feed weights quantized under any scheme/config; the
activation-quantization variants additionally fake-quantize every GEMM's
activation input in-graph — LO-BCQ via the L1 Pallas kernel, baselines
via their jnp references.
"""

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .corpus import VOCAB
from .kernels import ref as kref
from .kernels.lobcq_quant import lobcq_fake_quant


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d: int
    n_layers: int
    n_heads: int
    vocab: int = VOCAB
    max_t: int = 64

    @property
    def d_ff(self) -> int:
        return 4 * self.d

    @property
    def head_dim(self) -> int:
        assert self.d % self.n_heads == 0
        return self.d // self.n_heads

    def param_count(self) -> int:
        shapes = param_shapes(self)
        return sum(int(np.prod(s)) for s in shapes.values())


SIZES = {
    "s": ModelConfig("s", d=128, n_layers=2, n_heads=4),
    "m": ModelConfig("m", d=256, n_layers=3, n_heads=8),
    "l": ModelConfig("l", d=256, n_layers=6, n_heads=8),
}


def param_shapes(cfg: ModelConfig) -> dict:
    """Ordered name -> shape map. This order is the weights-as-inputs
    calling convention shared with Rust (artifacts/manifest.json)."""
    shapes = {
        "embed": (cfg.vocab, cfg.d),
        "pos": (cfg.max_t, cfg.d),
    }
    for i in range(cfg.n_layers):
        shapes[f"l{i}.ln1.g"] = (cfg.d,)
        shapes[f"l{i}.ln1.b"] = (cfg.d,)
        shapes[f"l{i}.attn.wqkv"] = (cfg.d, 3 * cfg.d)
        shapes[f"l{i}.attn.wo"] = (cfg.d, cfg.d)
        shapes[f"l{i}.ln2.g"] = (cfg.d,)
        shapes[f"l{i}.ln2.b"] = (cfg.d,)
        shapes[f"l{i}.mlp.w1"] = (cfg.d, cfg.d_ff)
        shapes[f"l{i}.mlp.w2"] = (cfg.d_ff, cfg.d)
    shapes["lnf.g"] = (cfg.d,)
    shapes["lnf.b"] = (cfg.d,)
    return shapes


def param_names(cfg: ModelConfig) -> list:
    return list(param_shapes(cfg).keys())


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in param_shapes(cfg).items():
        if name.endswith(".g"):
            params[name] = np.ones(shape, np.float32)
        elif name.endswith(".b"):
            params[name] = np.zeros(shape, np.float32)
        else:
            std = 0.02 if name in ("embed", "pos") else 0.02 / np.sqrt(2 * cfg.n_layers)
            params[name] = (rng.standard_normal(shape) * std).astype(np.float32)
    return params


# ---- quantization plumbing ----

@dataclass(frozen=True)
class QuantSpec:
    """Which scheme (if any) fake-quantizes GEMM *activations* in-graph.

    Weight quantization is done by the caller (Rust feeds pre-quantized
    weights), keeping one graph per activation scheme instead of one per
    (weight scheme × activation scheme) pair.
    """

    scheme: str = "none"  # none | lobcq | mx4 | vsq | mxfp4
    lb: int = 8
    la: int = 64
    norm_max: float = 31.0
    books: tuple = field(default=None, hash=False, compare=False)  # (Nc, E) np array
    use_pallas: bool = True

    def tag(self) -> str:
        if self.scheme == "none":
            return "bf16"
        if self.scheme == "lobcq":
            nc = len(self.books)
            return f"lobcq_g{self.la}_nc{nc}_lb{self.lb}"
        return self.scheme


def make_act_quant(spec: QuantSpec, books_arr=None):
    """Activation fake-quant function (..., K) -> (..., K).

    ``books_arr`` (a traced jnp array) overrides ``spec.books`` so the
    codebooks can be an *input* of the lowered graph. This is both closer
    to the paper's deployment (frozen ≤0.19 KB table resident at runtime)
    and a required workaround: xla_extension 0.5.1 mis-executes the
    kernel when the codebook rides in as a large f32 constant (probed in
    rust/tests — constant-baked books decode to zeros).
    """
    if spec.scheme == "none":
        return lambda x: x
    if spec.scheme == "lobcq":
        books = books_arr if books_arr is not None else jnp.asarray(
            np.asarray(spec.books, np.float32))
        if spec.use_pallas:
            return lambda x: lobcq_fake_quant(
                x, books, lb=spec.lb, la=spec.la, norm_max=spec.norm_max)
        return lambda x: kref.lobcq_fake_quant_full_ref(
            x, books, lb=spec.lb, la=spec.la, norm_max=spec.norm_max)
    return kref.quant_ref_by_name(spec.scheme)


def quantize_weight_np(w: np.ndarray, spec: QuantSpec) -> np.ndarray:
    """Offline weight fake-quant along the reduction (first) axis, numpy.
    Used by python-side sanity checks; Rust does the same in production."""
    if spec.scheme == "none":
        return w
    if spec.scheme == "lobcq":
        from . import lobcq as L

        cfg = L.LobcqConfig(lb=spec.lb, la=spec.la, nc=len(spec.books), b=4, bc=6)
        return L.fake_quantize(np.ascontiguousarray(w.T), cfg, np.asarray(spec.books)).T.copy()
    fn = kref.quant_ref_by_name(spec.scheme)
    return np.asarray(fn(jnp.asarray(np.ascontiguousarray(w.T)))).T.copy()


# ---- forward pass ----

def layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x ** 3)))


def forward(params: dict, tokens, cfg: ModelConfig, spec: QuantSpec = QuantSpec(),
            taps: list = None, books_arr=None):
    """Logits for a (B, T) int32 token batch. ``taps``, when a list, is
    filled with every GEMM's pre-quantization activation (calibration)."""
    act_q = make_act_quant(spec, books_arr)

    def qmatmul(x, w):
        if taps is not None:
            taps.append(x)
        return act_q(x) @ w

    b, t = tokens.shape
    x = params["embed"][tokens] + params["pos"][:t][None, :, :]
    mask = jnp.tril(jnp.ones((t, t), jnp.float32))
    neg = jnp.float32(-1e9)
    for i in range(cfg.n_layers):
        h = layer_norm(x, params[f"l{i}.ln1.g"], params[f"l{i}.ln1.b"])
        qkv = qmatmul(h, params[f"l{i}.attn.wqkv"])  # (B,T,3D)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        hd = cfg.head_dim

        def heads(z):
            return z.reshape(b, t, cfg.n_heads, hd).transpose(0, 2, 1, 3)

        qh, kh, vh = heads(q), heads(k), heads(v)
        att = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / jnp.sqrt(jnp.float32(hd))
        att = jnp.where(mask[None, None, :, :] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", att, vh)
        out = out.transpose(0, 2, 1, 3).reshape(b, t, cfg.d)
        x = x + qmatmul(out, params[f"l{i}.attn.wo"])

        h = layer_norm(x, params[f"l{i}.ln2.g"], params[f"l{i}.ln2.b"])
        h = gelu(qmatmul(h, params[f"l{i}.mlp.w1"]))
        x = x + qmatmul(h, params[f"l{i}.mlp.w2"])

    x = layer_norm(x, params["lnf.g"], params["lnf.b"])
    # Tied LM head (not quantized — the paper quantizes GEMM layers only).
    return x @ params["embed"].T


def forward_flat(flat_weights, tokens, cfg: ModelConfig, spec: QuantSpec = QuantSpec(),
                 books_arr=None):
    """Weights-as-positional-inputs wrapper (the lowered signature)."""
    names = param_names(cfg)
    params = dict(zip(names, flat_weights))
    return forward(params, tokens, cfg, spec, books_arr=books_arr)


def loss_fn(params, tokens, cfg: ModelConfig):
    """Next-token cross entropy over (B, T+1) token windows."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(params, inp, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def perplexity(params, token_windows, cfg: ModelConfig) -> float:
    """Corpus perplexity over (N, T+1) windows (python-side check; the
    production evaluator is Rust + PJRT)."""
    loss = 0.0
    n = 0
    f = jax.jit(partial(loss_fn, cfg=cfg))
    for i in range(0, token_windows.shape[0], 64):
        batch = token_windows[i:i + 64]
        loss += float(f(params, batch)) * batch.shape[0]
        n += batch.shape[0]
    return float(np.exp(loss / n))


def collect_activation_taps(params, tokens, cfg: ModelConfig) -> list:
    """All GEMM input activations for codebook calibration (§4.1: one
    batch of training data through the proxy model)."""
    taps = []
    forward(params, tokens, cfg, QuantSpec(), taps=taps)
    return [np.asarray(t).reshape(-1, t.shape[-1]) for t in taps]
