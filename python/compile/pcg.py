"""PCG32 — bit-exact mirror of ``rust/src/util/rng.rs``.

The synthetic corpus (and anything else that must agree token-exactly
between the build path and the Rust runtime) derives all randomness from
this generator. Parity is enforced by ``tests/test_parity.py`` against
vectors emitted by ``lobcq gen-parity``.
"""

MASK64 = (1 << 64) - 1
PCG_MULT = 6364136223846793005


class Pcg32:
    """PCG-XSH-RR 64/32 (O'Neill 2014)."""

    def __init__(self, seed: int, stream: int = 0):
        self.state = 0
        self.inc = ((stream << 1) | 1) & MASK64
        self.next_u32()
        self.state = (self.state + seed) & MASK64
        self.next_u32()

    def next_u32(self) -> int:
        old = self.state
        self.state = (old * PCG_MULT + self.inc) & MASK64
        xorshifted = ((old >> 18) ^ old) >> 27 & 0xFFFFFFFF
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << ((-rot) & 31))) & 0xFFFFFFFF

    def next_u64(self) -> int:
        return (self.next_u32() << 32) | self.next_u32()

    def next_f32(self) -> float:
        # Matches rust: (next_u32() >> 8) * 2^-24, computed in f32.
        import numpy as np

        return float(np.float32(self.next_u32() >> 8) * np.float32(1.0 / (1 << 24)))

    def next_f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, bound: int) -> int:
        """Lemire-style unbiased bounded draw (mirrors rust exactly)."""
        assert bound > 0
        threshold = (-bound) % (1 << 32) % bound
        while True:
            r = self.next_u32()
            if r >= threshold:
                return r % bound

    def index(self, bound: int) -> int:
        return self.below(bound)
