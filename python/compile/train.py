"""Build-time pre-training of the tiny GPT sizes on the synthetic corpus.

Runs once under ``make artifacts`` (skipped when weights exist). Adam is
hand-rolled (no optax dependency). The loss curve is appended to
``artifacts/train_log_{size}.json`` and summarized in EXPERIMENTS.md.
"""

import json
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import SIZES, ModelConfig, init_params, loss_fn, perplexity

TRAIN_SEED = 1234
VAL_SEED = 5678
TRAIN_TOKENS = 400_000
VAL_TOKENS = 40_000


def windows(tokens: np.ndarray, t: int, stride: int) -> np.ndarray:
    """(N, t+1) next-token-prediction windows."""
    n = (len(tokens) - t - 1) // stride
    return np.stack([tokens[i * stride:i * stride + t + 1] for i in range(n)]).astype(np.int32)


def adam_update(params, grads, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    new_p, new_m, new_v = {}, {}, {}
    t = step + 1
    for k in params:
        new_m[k] = b1 * m[k] + (1 - b1) * grads[k]
        new_v[k] = b2 * v[k] + (1 - b2) * grads[k] ** 2
        mhat = new_m[k] / (1 - b1 ** t)
        vhat = new_v[k] / (1 - b2 ** t)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_p, new_m, new_v


def train_size(cfg: ModelConfig, steps: int, batch: int = 16, lr: float = 3e-3,
               log_every: int = 25, out_dir: Path = Path("../artifacts")) -> dict:
    t0 = time.time()
    train_tok = np.array(corpus.generate(TRAIN_SEED, TRAIN_TOKENS))
    val_tok = np.array(corpus.generate(VAL_SEED, VAL_TOKENS))
    t = cfg.max_t
    train_win = windows(train_tok, t, stride=t // 2)
    val_win = windows(val_tok, t, stride=t)[:256]

    params = {k: jnp.asarray(v) for k, v in init_params(cfg, seed=7).items()}
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(vv) for k, vv in params.items()}

    grad_fn = jax.jit(jax.value_and_grad(partial(loss_fn, cfg=cfg)))
    update = jax.jit(partial(adam_update, lr=lr))

    rng = np.random.default_rng(99)
    log = []
    for step in range(steps):
        idx = rng.integers(0, train_win.shape[0], size=batch)
        loss, grads = grad_fn(params, jnp.asarray(train_win[idx]))
        params, m, v = update(params, grads, m, v, step)
        if step % log_every == 0 or step == steps - 1:
            log.append({"step": step, "loss": float(loss)})
            print(f"[train {cfg.name}] step {step:4d} loss {float(loss):.4f}", flush=True)

    val_ppl = perplexity(params, val_win, cfg)
    uniform_ppl = float(cfg.vocab)
    elapsed = time.time() - t0
    print(f"[train {cfg.name}] val ppl {val_ppl:.3f} (uniform {uniform_ppl}) in {elapsed:.0f}s")

    out_dir.mkdir(parents=True, exist_ok=True)
    np.savez(out_dir / f"weights_{cfg.name}.npz", **{k: np.asarray(v) for k, v in params.items()})
    summary = {
        "size": cfg.name,
        "params": cfg.param_count(),
        "steps": steps,
        "batch": batch,
        "final_loss": log[-1]["loss"],
        "val_ppl": val_ppl,
        "seconds": elapsed,
        "loss_curve": log,
    }
    (out_dir / f"train_log_{cfg.name}.json").write_text(json.dumps(summary, indent=2))
    return summary


def load_params(size: str, out_dir: Path = Path("../artifacts")) -> dict:
    with np.load(out_dir / f"weights_{size}.npz") as z:
        return {k: z[k] for k in z.files}


STEPS = {"s": 500, "m": 350, "l": 250}


def main(out_dir: Path = Path("../artifacts"), sizes=None):
    results = {}
    for name in sizes or SIZES:
        if (out_dir / f"weights_{name}.npz").exists():
            print(f"[train] weights_{name}.npz exists, skipping")
            continue
        results[name] = train_size(SIZES[name], steps=STEPS[name], out_dir=out_dir)
    return results


if __name__ == "__main__":
    main()
