#!/usr/bin/env python3
"""Consolidate lobcq run-records into one comparison report (ISSUE 10).

Every perf measurement in the repo — workload runs from ``lobcq bench
--workload`` / ``lobcq serve-cpu --workload`` and the four ``perf_*``
benches — lands in ``results/raw/`` as one JSON document in the shared
run-record schema (``rust/src/bench/record.rs``, DESIGN.md §Workload
harness):

    { "schema": "lobcq-run-record", "schema_version": 1,
      "kind": "workload" | "bench", "name": ...,
      "config": { flat scalars }, "summary": { metric: {value, dir} },
      "server"/"quant"/"detail": optional sections,
      "system"/"kernel_backend"/"git_rev"/"trace_dropped": env stamp }

This script groups raw records by workload×config, renders one
consolidated table (markdown + JSON), compares every summary metric
against the matching record in ``results/baseline/``, and exits
non-zero when an **enforced** comparison regresses beyond the
threshold.

Perf baselines are only meaningful between comparable environments, so
a comparison is enforced when the raw and baseline stamps are
*compatible* — same ``kernel_backend`` and same ``system.arch`` — and
advisory (reported, never fatal) otherwise. The checked-in baselines
are stamped ``kernel_backend: reference-seed`` precisely so they stay
advisory everywhere until a host re-records them with
``--update-baseline``; ``--strict`` promotes every comparison to
enforced regardless of stamps (what CI uses after re-recording a
self-baseline on the same host).

Usage:
    report_generator.py [--raw DIR] [--baseline DIR]
                        [--out-md PATH] [--out-json PATH]
                        [--threshold PCT] [--strict]
                        [--update-baseline]

Exit codes: 0 ok / no enforced regressions; 1 enforced regression or
malformed input.
"""

import argparse
import json
import os
import shutil
import sys

SCHEMA = "lobcq-run-record"
SCHEMA_VERSION = 1


class RecordError(Exception):
    pass


def load_record(path):
    """Parse + structurally validate one run-record. Raises RecordError."""
    try:
        with open(path) as f:
            rec = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise RecordError(f"{path}: unreadable: {e}") from e
    if rec.get("schema") != SCHEMA:
        raise RecordError(f"{path}: schema {rec.get('schema')!r} != {SCHEMA!r}")
    version = rec.get("schema_version")
    if version != SCHEMA_VERSION:
        raise RecordError(f"{path}: schema_version {version!r} != {SCHEMA_VERSION} (refusing records from the future)")
    if rec.get("kind") not in ("workload", "bench"):
        raise RecordError(f"{path}: kind {rec.get('kind')!r} not workload|bench")
    if not rec.get("name"):
        raise RecordError(f"{path}: missing name")
    if not isinstance(rec.get("config"), dict):
        raise RecordError(f"{path}: config must be an object")
    summary = rec.get("summary")
    if not isinstance(summary, dict):
        raise RecordError(f"{path}: summary must be an object")
    for metric, entry in summary.items():
        if not isinstance(entry, dict) or entry.get("dir") not in ("higher", "lower"):
            raise RecordError(f"{path}: summary metric {metric!r} needs {{value, dir: higher|lower}}")
        if not isinstance(entry.get("value"), (int, float)) or isinstance(entry.get("value"), bool):
            raise RecordError(f"{path}: summary metric {metric!r} needs a numeric value")
    for key in ("system", "kernel_backend", "git_rev", "trace_dropped"):
        if key not in rec:
            raise RecordError(f"{path}: missing stamp key {key!r}")
    rec["_path"] = path
    return rec


def load_dir(dirpath):
    """All *.json records in ``dirpath``, sorted by filename. Missing or
    empty directories load as an empty list (baselines are optional)."""
    records = []
    if not os.path.isdir(dirpath):
        return records
    for name in sorted(os.listdir(dirpath)):
        if name.endswith(".json"):
            records.append(load_record(os.path.join(dirpath, name)))
    return records


def config_str(config):
    """Flat config as a canonical ``k=v`` join — the grouping key half."""
    parts = []
    for k in sorted(config):
        v = config[k]
        if isinstance(v, float) and v == int(v):
            v = int(v)
        parts.append(f"{k}={v}")
    return " ".join(parts)


def group_key(rec):
    """workload×config identity: records compare iff these match."""
    return f"{rec['kind']}/{rec['name']} [{config_str(rec['config'])}]"


def stamps_compatible(a, b):
    """Perf numbers transfer between runs only when the dispatched
    kernel backend and the CPU architecture match."""
    return a.get("kernel_backend") == b.get("kernel_backend") and a.get("system", {}).get("arch") == b.get(
        "system", {}
    ).get("arch")


def compare(raw_records, baseline_records, threshold_pct, strict):
    """Per-metric comparison rows.

    Returns a list of dicts: group, metric, value, dir, baseline,
    delta_pct, enforced, regressed. ``baseline``/``delta_pct`` are None
    when the group or metric has no baseline.
    """
    baseline_by_group = {}
    for rec in baseline_records:
        key = group_key(rec)
        if key in baseline_by_group:
            raise RecordError(f"duplicate baseline for group {key!r} ({rec['_path']})")
        baseline_by_group[key] = rec

    rows = []
    for rec in raw_records:
        key = group_key(rec)
        base = baseline_by_group.get(key)
        for metric in sorted(rec["summary"]):
            entry = rec["summary"][metric]
            value, direction = entry["value"], entry["dir"]
            row = {
                "group": key,
                "kind": rec["kind"],
                "name": rec["name"],
                "metric": metric,
                "value": value,
                "dir": direction,
                "baseline": None,
                "delta_pct": None,
                "enforced": False,
                "regressed": False,
            }
            base_entry = base["summary"].get(metric) if base else None
            if base_entry is not None:
                base_value = base_entry["value"]
                row["baseline"] = base_value
                if base_value != 0:
                    delta = 100.0 * (value - base_value) / abs(base_value)
                else:
                    delta = 0.0 if value == 0 else float("inf")
                row["delta_pct"] = delta
                row["enforced"] = strict or stamps_compatible(rec, base)
                worse = -delta if direction == "higher" else delta
                row["regressed"] = row["enforced"] and worse > threshold_pct
            rows.append(row)
    return rows


def fmt_value(v):
    if v is None:
        return "—"
    if isinstance(v, float) and abs(v) >= 1000:
        return f"{v:,.0f}"
    return f"{v:.4g}" if isinstance(v, float) else str(v)


def render_markdown(rows, threshold_pct, strict):
    lines = [
        "# lobcq consolidated perf report",
        "",
        f"Regression threshold: {threshold_pct:g}% ({'strict: all comparisons enforced' if strict else 'enforced only on stamp-compatible baselines'})",
        "",
        "| group | metric | dir | value | baseline | delta | status |",
        "|---|---|---|---:|---:|---:|---|",
    ]
    for row in rows:
        if row["baseline"] is None:
            delta, status = "—", "no-baseline"
        else:
            delta = f"{row['delta_pct']:+.1f}%"
            if row["regressed"]:
                status = "REGRESSED"
            elif row["enforced"]:
                status = "ok"
            else:
                status = "advisory"
        lines.append(
            f"| {row['group']} | {row['metric']} | {row['dir']} | {fmt_value(row['value'])} "
            f"| {fmt_value(row['baseline'])} | {delta} | {status} |"
        )
    regressed = [r for r in rows if r["regressed"]]
    lines.append("")
    if regressed:
        lines.append(f"**{len(regressed)} regression(s) beyond {threshold_pct:g}%:**")
        lines.extend(f"- {r['group']} :: {r['metric']}: {r['delta_pct']:+.1f}% ({r['dir']} is better)" for r in regressed)
    else:
        lines.append("No enforced regressions.")
    lines.append("")
    return "\n".join(lines)


def update_baseline(raw_records, baseline_dir):
    """Copy every raw record into the baseline dir (filename preserved),
    replacing what was there. This is how a host records a real baseline
    to replace the advisory reference-seed placeholders."""
    os.makedirs(baseline_dir, exist_ok=True)
    for name in os.listdir(baseline_dir):
        if name.endswith(".json"):
            os.unlink(os.path.join(baseline_dir, name))
    for rec in raw_records:
        shutil.copy(rec["_path"], os.path.join(baseline_dir, os.path.basename(rec["_path"])))
    return len(raw_records)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--raw", default="results/raw", help="directory of run-records to report on")
    ap.add_argument("--baseline", default="results/baseline", help="directory of baseline run-records")
    ap.add_argument("--out-md", default="results/report.md", help="consolidated markdown table")
    ap.add_argument("--out-json", default="results/report.json", help="consolidated JSON report")
    ap.add_argument("--threshold", type=float, default=10.0, help="regression threshold in percent (default 10)")
    ap.add_argument(
        "--strict", action="store_true", help="enforce every comparison even across incompatible stamps"
    )
    ap.add_argument(
        "--update-baseline", action="store_true", help="copy the raw records over the baseline dir and exit"
    )
    args = ap.parse_args(argv)

    try:
        raw = load_dir(args.raw)
        if not raw:
            print(f"report_generator: FAIL: no run-records in {args.raw}", file=sys.stderr)
            return 1
        if args.update_baseline:
            n = update_baseline(raw, args.baseline)
            print(f"report_generator: baseline updated with {n} record(s) in {args.baseline}")
            return 0
        baseline = load_dir(args.baseline)
        rows = compare(raw, baseline, args.threshold, args.strict)
    except RecordError as e:
        print(f"report_generator: FAIL: {e}", file=sys.stderr)
        return 1

    md = render_markdown(rows, args.threshold, args.strict)
    report = {
        "schema": "lobcq-perf-report",
        "schema_version": 1,
        "threshold_pct": args.threshold,
        "strict": args.strict,
        "raw_records": len(raw),
        "baseline_records": len(baseline),
        "rows": rows,
        "regressions": [r["group"] + " :: " + r["metric"] for r in rows if r["regressed"]],
    }
    for out_path, text in ((args.out_md, md), (args.out_json, json.dumps(report, indent=2, sort_keys=True) + "\n")):
        d = os.path.dirname(out_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(out_path, "w") as f:
            f.write(text)

    regressed = report["regressions"]
    compared = sum(1 for r in rows if r["baseline"] is not None)
    advisory = sum(1 for r in rows if r["baseline"] is not None and not r["enforced"])
    print(
        f"report_generator: {len(raw)} record(s), {len(rows)} metric(s), {compared} compared "
        f"({advisory} advisory), {len(regressed)} regression(s) — wrote {args.out_md}, {args.out_json}"
    )
    if regressed:
        for g in regressed:
            print(f"report_generator: REGRESSED: {g}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
