"""Synthetic corpus determinism + structure tests."""

import numpy as np

from compile import corpus
from compile.pcg import Pcg32


def test_pcg_reference_values():
    """Pin the PCG32 stream so any drift from the Rust mirror is caught
    even without parity vectors."""
    rng = Pcg32(42, 7)
    vals = [rng.next_u32() for _ in range(4)]
    rng2 = Pcg32(42, 7)
    assert vals == [rng2.next_u32() for _ in range(4)]
    assert all(0 <= v < 2 ** 32 for v in vals)


def test_generate_deterministic():
    a = corpus.generate(123, 1000)
    b = corpus.generate(123, 1000)
    assert a == b
    assert corpus.generate(124, 1000) != a


def test_tokens_in_vocab():
    toks = corpus.generate(5, 5000)
    assert len(toks) == 5000
    assert min(toks) >= 0
    assert max(toks) < corpus.VOCAB
    assert toks[0] == corpus.BOS


def test_grammar_structure():
    """Determiners are always followed by an adjective or a noun — the
    learnable structure the LM exploits."""
    toks = corpus.generate(9, 20000)
    for i, t in enumerate(toks[:-1]):
        if corpus.DET0 <= t < corpus.DET0 + corpus.N_DET:
            nxt = toks[i + 1]
            ok = (corpus.ADJ0 <= nxt < corpus.ADJ0 + corpus.N_ADJ) or (
                corpus.NOUN0 <= nxt < corpus.NOUN0 + corpus.N_NOUN)
            assert ok, (i, t, nxt)


def test_zipf_skew():
    toks = np.array(corpus.generate(11, 50000))
    nouns = toks[(toks >= corpus.NOUN0) & (toks < corpus.NOUN0 + corpus.N_NOUN)] - corpus.NOUN0
    counts = np.bincount(nouns, minlength=corpus.N_NOUN)
    # Head of the distribution much heavier than the tail.
    assert counts[:8].sum() > 3 * counts[-8:].sum()


def test_fingerprint_stability():
    fp = corpus.fingerprint(corpus.generate(5678, 10_000))
    assert fp == corpus.fingerprint(corpus.generate(5678, 10_000))
    assert fp != corpus.fingerprint(corpus.generate(5678, 9_999))
