"""Format codec tests (python mirror of rust formats/)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats as F


def enumerate_non_negative(fmt: F.FloatFormat):
    vals = [0.0]
    for m in range(1, 1 << fmt.bm):
        vals.append(m * fmt.min_subnormal)
    top = (1 << fmt.be) - 1
    for ecode in range(1, top + 1):
        e = ecode - fmt.bias
        for m in range(1 << fmt.bm):
            v = (1.0 + m / (1 << fmt.bm)) * 2.0 ** e
            if v <= fmt.max_value:
                vals.append(v)
    return sorted(set(vals))


@pytest.mark.parametrize("fmt", [F.E1M2, F.E2M1, F.E3M0, F.E3M2, F.E3M3])
def test_quantize_idempotent_on_grid(fmt):
    grid = enumerate_non_negative(fmt)
    full = [-v for v in grid if v > 0] + grid
    x = np.array(full, np.float32)
    q = F.quantize_float(x, fmt)
    np.testing.assert_array_equal(q, x)


def test_e2m1_grid_matches_mxfp4_spec():
    assert enumerate_non_negative(F.E2M1) == [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]


def test_e4m3_saturates_at_448():
    x = np.array([1e9, -1e9, 500.0], np.float32)
    q = F.quantize_float(x, F.E4M3)
    np.testing.assert_array_equal(q, [448.0, -448.0, 448.0])


@pytest.mark.parametrize("fmt", [F.E1M2, F.E2M1, F.E3M0, F.E4M3])
@settings(max_examples=200, deadline=None)
@given(x=st.floats(-1e4, 1e4, allow_nan=False, width=32))
def test_quantize_picks_nearest(fmt, x):
    grid = np.array(enumerate_non_negative(fmt), np.float64)
    grid = np.concatenate([-grid[::-1], grid])
    q = float(F.quantize_float(np.float32(x), fmt))
    best = float(grid[np.argmin(np.abs(grid - np.float64(np.float32(x))))])
    assert abs(q - np.float32(x)) <= abs(best - np.float32(x)) + 1e-7


def test_ties_to_even():
    # E2M1 around 1.0: 1.25 ties {1.0, 1.5} -> 1.0 (even mantissa).
    assert float(F.quantize_float(np.float32(1.25), F.E2M1)) == 1.0
    assert float(F.quantize_float(np.float32(1.75), F.E2M1)) == 2.0


def test_int_codec():
    q = F.quantize_int(np.array([100.0, -100.0, 2.5, 3.5, -2.5], np.float32), 4)
    np.testing.assert_array_equal(q, [7.0, -7.0, 2.0, 4.0, -2.0])


def test_e8m0_floor():
    x = np.array([0.1, 1.0, 1.5, 3.9, 1000.0], np.float32)
    q = F.e8m0_floor(x)
    assert np.all(q <= x + 1e-9)
    assert np.all(q * 2 > x)
    assert np.all(np.log2(q) % 1 == 0)


def test_bf16_round_trip():
    exact = np.array([0.0, 1.0, -2.5, 384.0], np.float32)
    np.testing.assert_array_equal(F.bf16_round(exact), exact)
    # bf16 ulp at 1.0 is 2^-7.
    assert float(F.bf16_round(np.float32(1.0 + 2.0 ** -10))) == 1.0


@settings(max_examples=200, deadline=None)
@given(x=st.floats(allow_nan=False, allow_infinity=False, width=32))
def test_bf16_matches_jax_cast(x):
    import jax.numpy as jnp

    ours = float(F.bf16_round(np.float32(x)))
    jaxs = float(jnp.asarray(np.float32(x)).astype(jnp.bfloat16).astype(jnp.float32))
    assert ours == jaxs
