"""L1 kernel correctness: Pallas vs pure-jnp ref (the CORE correctness
signal), ref vs f64 numpy oracle, GEMM vs jnp matmul. Hypothesis sweeps
shapes and LO-BCQ configurations."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import lobcq as L
from compile.kernels.gemm import gemm, quantized_gemm
from compile.kernels.lobcq_quant import lobcq_fake_quant, vmem_estimate
from compile.kernels.ref import (lobcq_fake_quant_full_ref, matmul_ref,
                                 mx4_quant_ref, mxfp4_quant_ref, vsq_quant_ref)


def make_books(nc: int, entries: int = 16, bc: int = 6, seed: int = 0) -> np.ndarray:
    """Codeword-quantized random-ish but sorted books."""
    rng = np.random.default_rng(seed)
    m = (1 << (bc - 1)) - 1
    raw = rng.uniform(-m, m, size=(nc, entries)).astype(np.float32)
    return L.quantize_codewords(raw, bc)


def make_data(rows: int, k: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, k)).astype(np.float32) * 2.0
    # Sprinkle outliers.
    n_out = max(1, x.size // 50)
    idx = rng.integers(0, x.size, n_out)
    x.reshape(-1)[idx] *= 8.0
    return x


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 24),
    arrays_per_row=st.integers(1, 3),
    lb=st.sampled_from([2, 4, 8]),
    nc=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2 ** 16),
)
def test_pallas_kernel_matches_ref(rows, arrays_per_row, lb, nc, seed):
    la = 64
    k = la * arrays_per_row
    x = make_data(rows, k, seed)
    books = make_books(nc, seed=seed)
    ker = np.asarray(lobcq_fake_quant(x, books, lb=lb, la=la, norm_max=31.0, tile_rows=8))
    ref = np.asarray(lobcq_fake_quant_full_ref(x, books, lb=lb, la=la, norm_max=31.0))
    np.testing.assert_array_equal(ker, ref)


@settings(max_examples=10, deadline=None)
@given(lb=st.sampled_from([4, 8]), nc=st.sampled_from([2, 8]), seed=st.integers(0, 2 ** 16))
def test_ref_matches_numpy_oracle(lb, nc, seed):
    """jnp (f32 error sums) vs numpy (f64): allow rare tie-flips at the
    codebook-selection boundary, require numerics otherwise identical."""
    la = 64
    x = make_data(16, 128, seed)
    books = make_books(nc, seed=seed)
    cfg = L.LobcqConfig(lb=lb, la=la, nc=nc, b=4, bc=6)
    ref = np.asarray(lobcq_fake_quant_full_ref(x, books, lb=lb, la=la, norm_max=cfg.norm_max))
    oracle = L.fake_quantize(x, cfg, books)
    mismatch = np.mean(ref != oracle)
    assert mismatch < 5e-3, f"mismatch fraction {mismatch}"
    # And where they differ, both must be valid low-error quantizations.
    nmse_ref = np.mean((x - ref) ** 2) / np.mean(x ** 2)
    nmse_orc = np.mean((x - oracle) ** 2) / np.mean(x ** 2)
    assert abs(nmse_ref - nmse_orc) < 1e-4


def test_kernel_3d_input_and_padding():
    x = make_data(5, 128, 3).reshape(5, 1, 128)  # odd row count -> padding
    books = make_books(4)
    ker = np.asarray(lobcq_fake_quant(x, books, lb=8, la=64, norm_max=31.0, tile_rows=8))
    ref = np.asarray(lobcq_fake_quant_full_ref(x, books, lb=8, la=64, norm_max=31.0))
    assert ker.shape == x.shape
    np.testing.assert_array_equal(ker, ref)


def test_kernel_zero_tensor():
    x = np.zeros((4, 64), np.float32)
    books = make_books(2)
    out = np.asarray(lobcq_fake_quant(x, books, lb=8, la=64, norm_max=31.0))
    # All-zero input must stay exactly zero (guard paths).
    assert np.allclose(out, 0.0)


def test_quantization_error_bounded():
    x = make_data(16, 256, 11)
    books = make_books(8)
    out = np.asarray(lobcq_fake_quant(x, books, lb=8, la=64, norm_max=31.0))
    nmse = np.mean((x - out) ** 2) / np.mean(x ** 2)
    assert 0 < nmse < 0.05, nmse


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 40), k=st.integers(1, 50), n=st.integers(1, 40),
       seed=st.integers(0, 2 ** 16))
def test_gemm_matches_matmul(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(gemm(a, b, tm=16, tn=16, tk=16))
    want = np.asarray(matmul_ref(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_quantized_gemm_pipeline():
    """W4A4 pipeline with *calibrated* books: output close to f32 GEMM."""
    rng = np.random.default_rng(42)
    x = rng.standard_normal((16, 256)).astype(np.float32)
    w = rng.standard_normal((256, 64)).astype(np.float32)
    cfg = L.LobcqConfig(lb=8, la=64, nc=8)
    blocks, _, _ = L.normalize(np.concatenate([x.reshape(-1), w.T.reshape(-1)]), cfg)
    res = L.calibrate(blocks.reshape(-1, cfg.lb)[:2048], cfg, seed=1, max_iters=10)
    books = L.quantize_codewords(res.books, cfg.bc)
    got = np.asarray(quantized_gemm(x, w, books, lb=8, la=64, norm_max=31.0))
    want = x @ w
    rel = np.linalg.norm(got - want) / np.linalg.norm(want)
    assert rel < 0.15, rel


def test_vmem_budget_for_serving_tile():
    """DESIGN.md §Perf: serving tile VMEM ≤ 4 MiB."""
    bytes_ = vmem_estimate(tile_rows=8, k=256, nc=16, entries=16, lb=8)
    assert bytes_ <= 4 * 1024 * 1024, bytes_


@pytest.mark.parametrize("fn,grp", [(mx4_quant_ref, 16), (mxfp4_quant_ref, 32), (vsq_quant_ref, 16)])
def test_baseline_refs_lossy_but_bounded(fn, grp):
    x = make_data(8, 64, 5)
    q = np.asarray(fn(x))
    assert q.shape == x.shape
    nmse = np.mean((x - q) ** 2) / np.mean(x ** 2)
    assert 0 < nmse < 0.2, nmse
