"""LO-BCQ calibration tests (python mirror of the Rust algorithm)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import lobcq as L


def mixture(n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    out = rng.random(n) < 0.05
    x[out] *= 6.0
    return x


def test_normalize_round_trip():
    cfg = L.LobcqConfig()
    data = mixture(1024, 0)
    vals, eff, s_x = L.normalize(data, cfg)
    back = (vals.reshape(-1, cfg.la) / eff[:, None]).reshape(-1)
    np.testing.assert_allclose(back, data, rtol=1e-5, atol=1e-6)
    assert s_x > 0


def test_normalize_hits_norm_max():
    cfg = L.LobcqConfig()
    vals, _, _ = L.normalize(mixture(512, 1), cfg)
    per_array = np.abs(vals.reshape(-1, cfg.la)).max(axis=1)
    assert np.all(per_array <= cfg.norm_max * 1.07)
    assert np.all(per_array >= cfg.norm_max * 0.9)


def test_calibration_trace_monotone():
    cfg = L.LobcqConfig(nc=4)
    blocks, _, _ = L.normalize(mixture(8192, 2), cfg)
    res = L.calibrate(blocks.reshape(-1, cfg.lb), cfg, seed=3, max_iters=25, rel_tol=0)
    assert len(res.trace) >= 2
    for a, b in zip(res.trace, res.trace[1:]):
        assert b <= a * (1 + 1e-9) + 1e-12, res.trace


def test_more_codebooks_lower_mse():
    data = mixture(16384, 4)
    last = np.inf
    for nc in (1, 4, 16):
        cfg = L.LobcqConfig(nc=nc)
        blocks, _, _ = L.normalize(data, cfg)
        res = L.calibrate(blocks.reshape(-1, cfg.lb), cfg, seed=5, max_iters=25)
        j = res.trace[-1]
        assert j <= last * 1.02, (nc, j, last)
        last = j


def test_codeword_quantization_grid():
    raw = np.array([[-30.7, -10.2, 10.6, 30.9]], np.float32)
    np.testing.assert_array_equal(L.quantize_codewords(raw, 6), [[-31.0, -10.0, 11.0, 31.0]])
    np.testing.assert_array_equal(L.quantize_codewords(raw, 4), [[-7.0, -7.0, 7.0, 7.0]])


def test_fake_quantize_stable_under_requantization():
    """Exact idempotency does NOT hold (re-quantizing re-derives the
    block-array amax, which the first pass perturbed), but the second
    pass must be *stable*: its change is far smaller than the first
    pass's quantization error."""
    cfg = L.LobcqConfig(nc=4)
    data = mixture(2048, 6)
    blocks, _, _ = L.normalize(data, cfg)
    res = L.calibrate(blocks.reshape(-1, cfg.lb), cfg, seed=7, max_iters=15)
    books = L.quantize_codewords(res.books, cfg.bc)
    q1 = L.fake_quantize(data, cfg, books)
    q2 = L.fake_quantize(q1, cfg, books)
    err1 = float(np.mean((data - q1) ** 2))
    err2 = float(np.mean((q1 - q2) ** 2))
    assert err2 < 0.2 * err1, (err1, err2)


def test_zero_block_array_stays_zero():
    cfg = L.LobcqConfig(nc=2)
    data = mixture(256, 8)
    data[:cfg.la] = 0.0
    blocks, eff, _ = L.normalize(data, cfg)
    assert eff[0] == 0.0
    res = L.calibrate(blocks.reshape(-1, cfg.lb), cfg, seed=9, max_iters=8)
    books = L.quantize_codewords(res.books, cfg.bc)
    q = L.fake_quantize(data, cfg, books)
    assert np.all(q[:cfg.la] == 0.0)


def test_nearest_index_tie_to_lower():
    levels = np.array([-1.0, 0.0, 2.0], np.float32)
    x = np.array([-0.5, 1.0, -5.0, 5.0], np.float32)
    idx = L.nearest_index(levels, x)
    np.testing.assert_array_equal(idx, [0, 1, 0, 2])  # ties -> lower level


@settings(max_examples=15, deadline=None)
@given(nc=st.sampled_from([2, 4]), seed=st.integers(0, 1 << 16), n_arrays=st.integers(2, 16))
def test_fake_quantize_shape_and_finite(nc, seed, n_arrays):
    cfg = L.LobcqConfig(nc=nc, la=32, lb=4)
    data = mixture(32 * n_arrays, seed)
    blocks, _, _ = L.normalize(data, cfg)
    res = L.calibrate(blocks.reshape(-1, cfg.lb)[:512], cfg, seed=seed, max_iters=8)
    books = L.quantize_codewords(res.books, cfg.bc)
    q = L.fake_quantize(data, cfg, books)
    assert q.shape == data.shape
    assert np.all(np.isfinite(q))


def test_bitwidth_eq9():
    assert abs(L.LobcqConfig(lb=8, la=64, nc=8).bitwidth - 4.5) < 1e-9
    assert abs(L.LobcqConfig(lb=8, la=128, nc=2).bitwidth - 4.1875) < 1e-9
