"""L2 model tests: shapes, quant variant plumbing, trainability signal."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import corpus
from compile.model import (SIZES, QuantSpec, collect_activation_taps, forward,
                           forward_flat, init_params, loss_fn, param_names,
                           param_shapes)


def toks(b, t, seed=0):
    return jnp.asarray(np.array(corpus.generate(seed, b * (t + 1))[:b * t]).reshape(b, t),
                       dtype=jnp.int32)


def test_param_shapes_and_count():
    cfg = SIZES["s"]
    shapes = param_shapes(cfg)
    assert shapes["embed"] == (cfg.vocab, cfg.d)
    assert shapes["l0.attn.wqkv"] == (cfg.d, 3 * cfg.d)
    assert cfg.param_count() == sum(int(np.prod(s)) for s in shapes.values())
    # All GEMM reduction dims divisible by the largest block array (128)
    # so every quant config in the paper's grid applies.
    for name, s in shapes.items():
        if len(s) == 2 and not name.startswith(("embed", "pos")):
            assert s[0] % 128 == 0, (name, s)


def test_forward_shapes_and_finite():
    cfg = SIZES["s"]
    params = {k: jnp.asarray(v) for k, v in init_params(cfg).items()}
    logits = forward(params, toks(2, 16), cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_forward_flat_matches_dict():
    cfg = SIZES["s"]
    params = init_params(cfg)
    names = param_names(cfg)
    t = toks(1, 8)
    a = forward({k: jnp.asarray(v) for k, v in params.items()}, t, cfg)
    b = forward_flat([jnp.asarray(params[n]) for n in names], t, cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_causality():
    """Changing a future token must not change past logits."""
    cfg = SIZES["s"]
    params = {k: jnp.asarray(v) for k, v in init_params(cfg).items()}
    t1 = toks(1, 16, seed=1)
    t2 = np.asarray(t1).copy()
    t2[0, -1] = (t2[0, -1] + 1) % cfg.vocab
    l1 = np.asarray(forward(params, t1, cfg))
    l2 = np.asarray(forward(params, jnp.asarray(t2), cfg))
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], atol=1e-5)
    assert not np.allclose(l1[0, -1], l2[0, -1])


def test_activation_taps_count():
    cfg = SIZES["s"]
    params = {k: jnp.asarray(v) for k, v in init_params(cfg).items()}
    taps = collect_activation_taps(params, toks(2, 16), cfg)
    # 4 GEMMs per layer: qkv, wo, w1, w2.
    assert len(taps) == 4 * cfg.n_layers
    assert taps[0].shape == (2 * 16, cfg.d)


def test_quant_variants_change_logits_boundedly():
    cfg = SIZES["s"]
    params = {k: jnp.asarray(v) for k, v in init_params(cfg).items()}
    t = toks(2, 16, seed=2)
    base = np.asarray(forward(params, t, cfg))
    books = np.sort(np.linspace(-31, 31, 16, dtype=np.float32))[None].repeat(8, 0)
    for spec in [
        QuantSpec(scheme="lobcq", books=tuple(map(tuple, books.tolist())), use_pallas=False),
        QuantSpec(scheme="mx4"),
        QuantSpec(scheme="mxfp4"),
    ]:
        q = np.asarray(forward(params, t, cfg, spec))
        assert q.shape == base.shape
        rel = np.linalg.norm(q - base) / np.linalg.norm(base)
        assert 0 < rel < 0.5, (spec.scheme, rel)


def test_lobcq_pallas_variant_matches_ref_variant():
    cfg = SIZES["s"]
    params = {k: jnp.asarray(v) for k, v in init_params(cfg).items()}
    t = toks(1, 16, seed=3)
    books = np.sort(np.linspace(-31, 31, 16, dtype=np.float32))[None].repeat(4, 0)
    bt = tuple(map(tuple, books.tolist()))
    a = np.asarray(forward(params, t, cfg, QuantSpec(scheme="lobcq", books=bt, use_pallas=True)))
    b = np.asarray(forward(params, t, cfg, QuantSpec(scheme="lobcq", books=bt, use_pallas=False)))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_one_grad_step_reduces_loss():
    cfg = SIZES["s"]
    params = {k: jnp.asarray(v) for k, v in init_params(cfg).items()}
    batch = jnp.asarray(np.array(corpus.generate(7, 4 * 17)).reshape(4, 17), jnp.int32)
    l0, g = jax.value_and_grad(lambda p: loss_fn(p, batch, cfg))(params)
    params2 = {k: params[k] - 0.5 * g[k] for k in params}
    l1 = loss_fn(params2, batch, cfg)
    assert float(l1) < float(l0)
