"""Cross-language parity: Rust emits test vectors (``lobcq gen-parity``),
python must reproduce them exactly (PCG stream, corpus tokens, format
codecs) or near-exactly (LO-BCQ fake-quantize — f32/f64 selector ties).

Skipped when artifacts/parity.json has not been generated yet
(``make parity``)."""

import json
from pathlib import Path

import numpy as np
import pytest

from compile import corpus, formats as F, lobcq as L
from compile.pcg import Pcg32

PARITY = Path(__file__).resolve().parents[2] / "artifacts" / "parity.json"

pytestmark = pytest.mark.skipif(not PARITY.exists(),
                                reason="artifacts/parity.json missing (run `make parity`)")


@pytest.fixture(scope="module")
def vectors():
    return json.loads(PARITY.read_text())


def test_pcg_stream(vectors):
    for case in vectors["pcg"]:
        rng = Pcg32(case["seed"], case["stream"])
        got = [rng.next_u32() for _ in range(len(case["u32"]))]
        assert got == case["u32"]


def test_pcg_floats(vectors):
    for case in vectors["pcg_f32"]:
        rng = Pcg32(case["seed"], case["stream"])
        got = np.array([rng.next_f32() for _ in range(len(case["f32"]))], np.float32)
        np.testing.assert_array_equal(got, np.array(case["f32"], np.float32))


def test_corpus_tokens(vectors):
    case = vectors["corpus"]
    toks = corpus.generate(case["seed"], case["n"])
    assert toks[:64] == case["head"]
    # Fingerprint travels as a string (u64 exceeds f64-exact JSON range).
    assert corpus.fingerprint(toks) == int(case["fingerprint"])


def test_float_formats(vectors):
    for case in vectors["formats"]:
        fmt = F.BY_NAME[case["format"]]
        x = np.array(case["x"], np.float32)
        want = np.array(case["q"], np.float32)
        got = F.quantize_float(x, fmt)
        np.testing.assert_array_equal(got, want, err_msg=case["format"])


def test_int_format(vectors):
    case = vectors["int4"]
    got = F.quantize_int(np.array(case["x"], np.float32), 4)
    np.testing.assert_array_equal(got, np.array(case["q"], np.float32))


def test_lobcq_fake_quantize(vectors):
    """Given the same frozen books, python and rust dequantize (near-)
    identically; tie-flips at the f32/f64 selector boundary are allowed
    at < 0.5% of scalars with matching overall NMSE."""
    case = vectors["lobcq"]
    cfg = L.LobcqConfig(lb=case["lb"], la=case["la"], nc=case["nc"], b=case["b"], bc=case["bc"])
    books = np.array(case["books"], np.float32)
    x = np.array(case["x"], np.float32)
    want = np.array(case["q"], np.float32)
    got = L.fake_quantize(x, cfg, books)
    mismatch = float(np.mean(got != want))
    assert mismatch < 5e-3, f"mismatch fraction {mismatch}"
    nmse_rs = float(np.mean((x - want) ** 2) / np.mean(x ** 2))
    nmse_py = float(np.mean((x - got) ** 2) / np.mean(x ** 2))
    assert abs(nmse_rs - nmse_py) < 1e-5
