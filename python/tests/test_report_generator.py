"""report_generator regression-gate tests on synthetic run-records.

These never run the rust side: they hand-author records in the shared
schema (rust/src/bench/record.rs) and check the consolidation, the
stamp-compatibility gating, and the exit codes.
"""

import copy
import json
import os

import pytest

import report_generator as rg


def record(name="steady-decode", kind="workload", config=None, metrics=None, backend="scalar", arch="x86_64"):
    summary = {}
    for metric, (value, direction) in (metrics or {"tok_per_s": (800.0, "higher")}).items():
        summary[metric] = {"value": value, "dir": direction}
    return {
        "schema": rg.SCHEMA,
        "schema_version": rg.SCHEMA_VERSION,
        "kind": kind,
        "name": name,
        "config": config if config is not None else {"lanes": 4, "kv": "bcq"},
        "summary": summary,
        "system": {"os": "linux", "arch": arch, "cores": 8},
        "kernel_backend": backend,
        "git_rev": "deadbeef",
        "trace_dropped": 0,
        "metrics": {},
    }


def write_records(dirpath, records):
    os.makedirs(dirpath, exist_ok=True)
    for i, rec in enumerate(records):
        with open(os.path.join(dirpath, f"rec{i}.json"), "w") as f:
            json.dump(rec, f)


def run(tmp_path, raw, baseline=None, extra=()):
    raw_dir = str(tmp_path / "raw")
    base_dir = str(tmp_path / "baseline")
    write_records(raw_dir, raw)
    if baseline is not None:
        write_records(base_dir, baseline)
    argv = [
        "--raw", raw_dir,
        "--baseline", base_dir,
        "--out-md", str(tmp_path / "report.md"),
        "--out-json", str(tmp_path / "report.json"),
        *extra,
    ]
    code = rg.main(argv)
    report = None
    if (tmp_path / "report.json").exists():
        report = json.loads((tmp_path / "report.json").read_text())
    return code, report


def test_no_baseline_is_ok(tmp_path):
    code, report = run(tmp_path, [record()])
    assert code == 0
    assert report["rows"][0]["baseline"] is None
    assert report["regressions"] == []


def test_matching_baseline_within_threshold_passes(tmp_path):
    base = record(metrics={"tok_per_s": (800.0, "higher")})
    raw = record(metrics={"tok_per_s": (780.0, "higher")})  # -2.5% < 10%
    code, report = run(tmp_path, [raw], [base])
    assert code == 0
    row = report["rows"][0]
    assert row["enforced"] and not row["regressed"]
    assert row["delta_pct"] == pytest.approx(-2.5)


def test_regression_on_higher_metric_fails(tmp_path):
    base = record(metrics={"tok_per_s": (800.0, "higher")})
    raw = record(metrics={"tok_per_s": (600.0, "higher")})  # -25%
    code, report = run(tmp_path, [raw], [base])
    assert code == 1
    assert report["regressions"] == ["workload/steady-decode [kv=bcq lanes=4] :: tok_per_s"]


def test_regression_on_lower_metric_fails(tmp_path):
    base = record(metrics={"p99_itl_us": (1000.0, "lower")})
    raw = record(metrics={"p99_itl_us": (1300.0, "lower")})  # +30% latency
    code, _ = run(tmp_path, [raw], [base])
    assert code == 1


def test_improvement_never_fails(tmp_path):
    base = record(metrics={"p99_itl_us": (1000.0, "lower"), "tok_per_s": (800.0, "higher")})
    raw = record(metrics={"p99_itl_us": (500.0, "lower"), "tok_per_s": (1600.0, "higher")})
    code, report = run(tmp_path, [raw], [base])
    assert code == 0
    assert all(not r["regressed"] for r in report["rows"])


def test_incompatible_stamp_is_advisory(tmp_path):
    """The checked-in reference-seed baselines must never gate a real
    host — the comparison shows up but cannot fail the run."""
    base = record(backend="reference-seed", metrics={"tok_per_s": (10_000.0, "higher")})
    raw = record(backend="scalar", metrics={"tok_per_s": (100.0, "higher")})
    code, report = run(tmp_path, [raw], [base])
    assert code == 0
    row = report["rows"][0]
    assert row["baseline"] is not None and not row["enforced"] and not row["regressed"]


def test_strict_enforces_incompatible_stamps(tmp_path):
    base = record(backend="reference-seed", metrics={"tok_per_s": (10_000.0, "higher")})
    raw = record(backend="scalar", metrics={"tok_per_s": (100.0, "higher")})
    code, _ = run(tmp_path, [raw], [base], extra=["--strict"])
    assert code == 1


def test_different_config_is_a_different_group(tmp_path):
    """A lanes=8 run never compares against a lanes=4 baseline."""
    base = record(config={"lanes": 4}, metrics={"tok_per_s": (10_000.0, "higher")})
    raw = record(config={"lanes": 8}, metrics={"tok_per_s": (100.0, "higher")})
    code, report = run(tmp_path, [raw], [base])
    assert code == 0
    assert report["rows"][0]["baseline"] is None


def test_threshold_flag(tmp_path):
    base = record(metrics={"tok_per_s": (800.0, "higher")})
    raw = record(metrics={"tok_per_s": (760.0, "higher")})  # -5%
    assert run(tmp_path, [raw], [base], extra=["--threshold", "2"])[0] == 1
    assert run(tmp_path, [raw], [base], extra=["--threshold", "8"])[0] == 0


def test_malformed_record_fails(tmp_path):
    bad = record()
    bad["schema_version"] = 99
    code, _ = run(tmp_path, [bad])
    assert code == 1


def test_bad_metric_entry_fails(tmp_path):
    bad = record()
    bad["summary"]["tok_per_s"] = {"value": 1.0}  # no dir
    assert run(tmp_path, [bad])[0] == 1
    bad2 = record()
    bad2["summary"]["tok_per_s"] = {"value": "fast", "dir": "higher"}
    assert run(tmp_path, [bad2])[0] == 1


def test_empty_raw_dir_fails(tmp_path):
    assert run(tmp_path, [])[0] == 1


def test_markdown_report_is_written(tmp_path):
    base = record(metrics={"tok_per_s": (800.0, "higher")})
    raw = record(metrics={"tok_per_s": (600.0, "higher")})
    code, _ = run(tmp_path, [raw], [base])
    assert code == 1
    md = (tmp_path / "report.md").read_text()
    assert "REGRESSED" in md and "tok_per_s" in md


def test_update_baseline_round_trips(tmp_path):
    """--update-baseline then a re-run of the same records: every
    comparison enforced (same stamp) with zero delta."""
    raw = [record(metrics={"tok_per_s": (800.0, "higher")})]
    raw_dir = str(tmp_path / "raw")
    base_dir = str(tmp_path / "baseline")
    write_records(raw_dir, raw)
    common = ["--raw", raw_dir, "--baseline", base_dir,
              "--out-md", str(tmp_path / "report.md"), "--out-json", str(tmp_path / "report.json")]
    assert rg.main(common + ["--update-baseline"]) == 0
    assert rg.main(common + ["--strict"]) == 0
    report = json.loads((tmp_path / "report.json").read_text())
    row = report["rows"][0]
    assert row["enforced"] and row["delta_pct"] == 0.0


def test_duplicate_baseline_group_rejected(tmp_path):
    base = record()
    code, _ = run(tmp_path, [record()], [base, copy.deepcopy(base)])
    assert code == 1
