#!/usr/bin/env python3
"""Validate the serve-cpu observability artifacts (CI smoke leg, ISSUE 8).

Usage:
    validate_trace.py TRACE_JSON LIFECYCLE_JSONL METRICS_JSON

Checks, in order:
  1. TRACE_JSON is valid Chrome trace-event JSON: a non-empty
     ``traceEvents`` list where every event carries name/cat/ph/ts/pid/tid,
     "X" (complete) events carry ``dur``, "i" (instant) events carry the
     global scope marker, and the request / sched / model / layer / op /
     lifecycle categories all appear. When speculative decoding ran
     (``op``/``verify`` or ``op``/``rollback`` spans present), every such
     span must nest inside some ``sched``/``step`` interval — speculation
     is a property of a scheduler step, never free-floating work. The
     export must report zero ring-buffer drops (``otherData.dropped_events``):
     a lossy trace silently hides the spans these checks exist to audit.
  2. LIFECYCLE_JSONL is one JSON object per line (ts_us/event/request/arg),
     sorted by timestamp, and conserves requests: every admitted request id
     reaches exactly one terminal event (finished, shed-deadline, shed-kv,
     or failed). Non-terminal streams (staged/chunked/preempted/
     speculation) pass through unconstrained.
  3. METRICS_JSON carries the server sections (latency, occupancy,
     admission, kv, prefix, panel), non-empty per-layer activation-NMSE
     telemetry, KV-encode NMSE samples, codebook-selector occupancy, and
     the registry / kernel_backend / system stamps, and a zero
     ``trace_dropped`` count. A ``server.speculation`` section, when
     present, must carry the draft/accept/rollback counters.

Exits non-zero with a one-line reason on the first failure.
"""

import json
import sys

TERMINALS = {"finished", "shed-deadline", "shed-kv", "failed"}
REQUIRED_CATS = {"request", "sched", "model", "layer", "op", "lifecycle"}


def fail(msg):
    print(f"validate_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_chrome_trace(path):
    with open(path) as f:
        trace = json.load(f)
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: traceEvents missing or empty")
    cats = set()
    for ev in events:
        for key in ("name", "cat", "ph", "ts", "pid", "tid"):
            if key not in ev:
                fail(f"{path}: event missing `{key}`: {ev}")
        if ev["ph"] == "X":
            if "dur" not in ev:
                fail(f"{path}: complete event missing `dur`: {ev}")
        elif ev["ph"] == "i":
            if ev.get("s") != "g":
                fail(f"{path}: instant event missing global scope: {ev}")
        else:
            fail(f"{path}: unexpected phase {ev['ph']!r}")
        cats.add(ev["cat"])
    missing = REQUIRED_CATS - cats
    if missing:
        fail(f"{path}: no events in categories {sorted(missing)} (saw {sorted(cats)})")
    dropped = trace.get("otherData", {}).get("dropped_events", 0)
    try:
        dropped = int(dropped)
    except (TypeError, ValueError):
        fail(f"{path}: otherData.dropped_events is not a count: {dropped!r}")
    if dropped > 0:
        fail(f"{path}: trace ring dropped {dropped} events — raise the ring capacity or drain more often")
    check_spec_nesting(path, events)
    return len(events)


def check_spec_nesting(path, events):
    """Every op/verify and op/rollback span must lie inside a sched/step
    span on the same pid/tid (2 us slack for timestamp truncation).
    Vacuously true for non-speculative runs."""
    steps = {}
    for ev in events:
        if ev["cat"] == "sched" and ev["name"] == "step" and ev["ph"] == "X":
            key = (ev["pid"], ev["tid"])
            steps.setdefault(key, []).append((ev["ts"], ev["ts"] + ev["dur"]))
    n_spec = 0
    for ev in events:
        if ev["cat"] != "op" or ev["name"] not in ("verify", "rollback") or ev["ph"] != "X":
            continue
        n_spec += 1
        lo, hi = ev["ts"], ev["ts"] + ev["dur"]
        key = (ev["pid"], ev["tid"])
        if not any(s - 2 <= lo and hi <= e + 2 for s, e in steps.get(key, [])):
            fail(f"{path}: op/{ev['name']} span at ts={lo} not nested in any sched/step")
    return n_spec


def check_lifecycle(path):
    admitted, terminal_counts = set(), {}
    last_ts, lines = -1, 0
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            for key in ("ts_us", "event", "request", "arg"):
                if key not in row:
                    fail(f"{path}: line missing `{key}`: {row}")
            if row["ts_us"] < last_ts:
                fail(f"{path}: lifecycle log not sorted by ts_us at {row}")
            last_ts = row["ts_us"]
            if row["event"] == "admitted":
                admitted.add(row["request"])
            if row["event"] in TERMINALS:
                terminal_counts[row["request"]] = terminal_counts.get(row["request"], 0) + 1
            lines += 1
    if lines == 0:
        fail(f"{path}: lifecycle log is empty")
    if not admitted:
        fail(f"{path}: no `admitted` events")
    for rid in sorted(admitted):
        n = terminal_counts.get(rid, 0)
        if n != 1:
            fail(f"{path}: request {rid} admitted but has {n} terminal events (want 1)")
    return lines, len(admitted)


def check_metrics(path):
    with open(path) as f:
        m = json.load(f)
    server = m.get("server")
    if not isinstance(server, dict):
        fail(f"{path}: no `server` section")
    for key in ("latency", "occupancy", "admission", "kv", "prefix", "panel"):
        if key not in server:
            fail(f"{path}: server section missing `{key}`")
    spec = server.get("speculation")
    if spec is not None:
        for key in ("steps", "drafted", "accepted", "wasted", "rollbacks"):
            if key not in spec:
                fail(f"{path}: server.speculation missing `{key}`")
    quant = m.get("quant")
    if not isinstance(quant, dict):
        fail(f"{path}: no `quant` section")
    act = quant.get("act")
    if not isinstance(act, dict) or not act:
        fail(f"{path}: quant.act has no per-layer activation-NMSE entries")
    for name, acc in act.items():
        if "nmse" not in acc or "samples" not in acc:
            fail(f"{path}: quant.act[{name!r}] missing nmse/samples")
    if quant.get("kv", {}).get("samples", 0) <= 0:
        fail(f"{path}: no KV-encode NMSE samples")
    if quant.get("selectors", {}).get("total", 0) <= 0:
        fail(f"{path}: no codebook-selector occupancy counts")
    for key in ("registry", "kernel_backend", "system"):
        if key not in m:
            fail(f"{path}: missing `{key}` stamp")
    if m.get("trace_dropped", 0) > 0:
        fail(f"{path}: trace_dropped = {m['trace_dropped']} — the span ring overflowed during the run")
    return len(act)


def main():
    if len(sys.argv) != 4:
        fail("usage: validate_trace.py TRACE_JSON LIFECYCLE_JSONL METRICS_JSON")
    trace_p, events_p, metrics_p = sys.argv[1:4]
    n_events = check_chrome_trace(trace_p)
    n_lines, n_requests = check_lifecycle(events_p)
    n_layers = check_metrics(metrics_p)
    print(
        f"validate_trace: OK — {n_events} trace events, {n_lines} lifecycle lines "
        f"({n_requests} admitted requests conserved), {n_layers} act-NMSE layers"
    )


if __name__ == "__main__":
    main()
