//! Unified experiment bench harness: one target replacing the former 16
//! per-table/figure stub files. Dispatches by experiment id through
//! `eval::experiments::run` (see DESIGN.md §3 for the experiment index
//! and EXPERIMENTS.md for recorded results).
//!
//! Selection and workload:
//! - `LOBCQ_EXP=tab2,fig4 cargo bench` runs a subset (default: all);
//! - quick workloads by default, `LOBCQ_BENCH_FULL=1` for paper scale;
//! - experiments whose artifacts are missing are reported as SKIPPED
//!   (exit stays 0 so `cargo bench` is usable pre-`make artifacts`);
//!   `LOBCQ_BENCH_STRICT=1` turns any failure into a non-zero exit.

use lobcq::eval::experiments::ALL_EXPERIMENTS;
use lobcq::eval::{experiments, Env};

fn main() {
    let quick = std::env::var("LOBCQ_BENCH_FULL").map(|v| v != "1").unwrap_or(true);
    let strict = std::env::var("LOBCQ_BENCH_STRICT").map(|v| v == "1").unwrap_or(false);
    let filter = std::env::var("LOBCQ_EXP").ok();
    let ids: Vec<String> = match &filter {
        Some(list) => list.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
        None => ALL_EXPERIMENTS.iter().map(|s| s.to_string()).collect(),
    };

    let env = Env::load();
    let mut failures = 0usize;
    for id in &ids {
        let t0 = std::time::Instant::now();
        match experiments::run(id, &env, quick) {
            Ok(report) => {
                println!("{report}");
                println!("[{id}] completed in {:.2}s (quick={quick})\n", t0.elapsed().as_secs_f64());
            }
            Err(e) => {
                failures += 1;
                println!("[{id}] SKIPPED/FAILED: {e:#}\n");
            }
        }
    }
    println!("== {}/{} experiments completed ==", ids.len() - failures, ids.len());
    if strict && failures > 0 {
        std::process::exit(1);
    }
}
