//! Regenerates paper experiment `fig4` (see DESIGN.md §3 for the
//! experiment index and EXPERIMENTS.md for recorded results).
//! Quick workload under plain `cargo bench`; LOBCQ_BENCH_FULL=1 for
//! paper-scale.
fn main() {
    lobcq::eval::experiments::bench_entry("fig4");
}
