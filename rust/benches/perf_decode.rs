//! §Perf decode bench — emits `BENCH_decode.json`.
//!
//! Measures decode tokens/sec at generation shapes for three engines
//! over the same model and token stream (teacher-forced from the
//! deterministic corpus):
//!
//! - `full_recompute`: the pre-ISSUE-3 serving behaviour — every decode
//!   step re-runs the full fixed-shape forward over the whole history
//!   (O(t²) attention per token, `batch·t·vocab` logits materialized);
//! - `cached_f32`: prefill + `decode_step` against the paged KV16 cache
//!   (O(t) attention per token, frontier-only logits);
//! - `cached_bcq`: same, with the cache stored LO-BCQ-encoded (KV4,
//!   ~4.9 bits/scalar at head_dim 64).
//!
//! Also reports peak cache bytes for both cache modes, a `batch4` lane
//! throughput for the cached-encoded engine, a KV4-vs-KV16 perplexity
//! ablation (teacher-forced NLL over a corpus stream — the
//! EXPERIMENTS.md "KV cache" entry), and the ISSUE-4 **lane sweep**:
//! {1, 4, 16} live lanes decoding in lockstep, per-lane serial
//! `decode_step` loop vs one fused `decode_step_batch` per step — the
//! batched step streams each packed weight panel once per step instead
//! of once per lane, which is where decode throughput scaling with
//! batch size comes from (EXPERIMENTS.md lane-scaling table).
//!
//! Acceptance: cached decode beats full recompute at T ≥ 256, the
//! encoded cache stores K/V at ≤ 5 bits/scalar (ISSUE 3), the fused
//! batched step beats the per-lane loop at ≥ 4 lanes (ISSUE 4), and
//! encoded-domain attention (per-page K^T/V panels scored through the
//! SIMD GEMM driver) beats gather-then-dot on the BCQ cache (ISSUE 6 —
//! both paths bit-verified against each other before timing).

#![allow(clippy::needless_range_loop)]

use lobcq::coordinator::{
    run_continuous_opts, BatchPolicy, Batcher, ContinuousOpts, DecodeSession, DrafterKind, KvCacheOpts,
    Request, Sampling, ServerMetrics, SpecStats,
};
use lobcq::data::corpus;
use lobcq::eval::Scheme;
use lobcq::kvcache::{KvLayout, KvQuantizer, KvStore, PagedKvCache};
use lobcq::model::decode::{
    decode_step, decode_step_batch, decode_step_batch_spec, prefill, AttnPath, DecodeScratch,
};
use lobcq::model::forward::{forward, forward_logits_at};
use lobcq::model::{ModelConfig, Weights};
use lobcq::quant::pipeline::QuantPool;
use lobcq::tensor::Tensor;
use lobcq::util::json::Json;
use lobcq::util::rng::Pcg32;
use std::time::{Duration, Instant};

/// Serving-shaped toy model: head_dim 64 (the ≤5 bits/scalar shape).
fn model() -> (ModelConfig, Weights) {
    let cfg = ModelConfig {
        name: "decode-bench".into(),
        d: 128,
        n_layers: 2,
        n_heads: 2,
        vocab: corpus::VOCAB as usize,
        max_t: 384,
    };
    let mut rng = Pcg32::seeded(0xDECB);
    let mut tensors = std::collections::BTreeMap::new();
    for (name, shape) in cfg.param_shapes() {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = if name.ends_with(".g") {
            vec![1.0; n]
        } else if name.ends_with(".b") {
            vec![0.0; n]
        } else {
            (0..n).map(|_| rng.normal() * 0.05).collect()
        };
        tensors.insert(name, Tensor::new(&shape, data));
    }
    (cfg, Weights::new(tensors))
}

fn kv_quantizer(cfg: &ModelConfig, w: &Weights) -> KvQuantizer {
    let hd = cfg.head_dim();
    let sample = &w.get("l0.attn.wqkv").unwrap().data;
    KvQuantizer::calibrated(hd, &sample[..hd * 128], 0xDECC).unwrap()
}

fn cache(cfg: &ModelConfig, w: &Weights, encoded: bool, slots: usize) -> PagedKvCache {
    let store = if encoded { KvStore::Encoded(kv_quantizer(cfg, w)) } else { KvStore::F32 };
    PagedKvCache::new(KvLayout::for_model(cfg, 16, slots), store).unwrap()
}

/// Generate `gen` tokens after a `t0`-token prompt by re-running the full
/// forward each step (frontier logits only — even the baseline gets the
/// PR's logits slimming, so the win measured is the attention recompute).
fn run_full_recompute(cfg: &ModelConfig, w: &Weights, stream: &[u32], t0: usize, gen: usize) -> f64 {
    let start = Instant::now();
    for s in 0..gen {
        let len = t0 + s;
        let frontier = [len - 1];
        let logits = forward_logits_at(cfg, w, &stream[..len], 1, None, &frontier).unwrap();
        assert!(logits.data[0].is_finite());
    }
    gen as f64 / start.elapsed().as_secs_f64()
}

/// Prefill `t0` tokens, then decode `gen` teacher-forced tokens.
/// Returns (tokens/sec over the decode phase, peak cache bytes).
fn run_cached(cfg: &ModelConfig, w: &Weights, stream: &[u32], t0: usize, gen: usize, encoded: bool) -> (f64, usize) {
    let mut kv = cache(cfg, w, encoded, 1);
    let slot = kv.alloc_slot().unwrap();
    let mut scratch = DecodeScratch::new();
    prefill(cfg, w, &mut kv, slot, &stream[..t0], None).unwrap();
    let start = Instant::now();
    for s in 0..gen {
        let logits = decode_step(cfg, w, &mut kv, slot, stream[t0 + s], None, &mut scratch).unwrap();
        assert!(logits[0].is_finite());
    }
    let tps = gen as f64 / start.elapsed().as_secs_f64();
    (tps, kv.peak_bytes())
}

/// 4 lanes decoding round-robin (the continuous-batching inner shape).
fn run_cached_batch4(cfg: &ModelConfig, w: &Weights, stream: &[u32], t0: usize, gen: usize) -> f64 {
    let mut kv = cache(cfg, w, true, 4);
    let mut scratch = DecodeScratch::new();
    let slots: Vec<_> = (0..4)
        .map(|_| {
            let s = kv.alloc_slot().unwrap();
            prefill(cfg, w, &mut kv, s, &stream[..t0], None).unwrap();
            s
        })
        .collect();
    let start = Instant::now();
    for s in 0..gen {
        for &slot in &slots {
            decode_step(cfg, w, &mut kv, slot, stream[t0 + s], None, &mut scratch).unwrap();
        }
    }
    (4 * gen) as f64 / start.elapsed().as_secs_f64()
}

/// `lanes` requests decoding in lockstep after identical `t0`-token
/// prefills, f32 KV cache: either the per-lane serial loop (`batched =
/// false`: one `decode_step` per lane per step — the pre-ISSUE-4
/// scheduler shape) or one fused `decode_step_batch` per step. Returns
/// aggregate tokens/sec over the decode phase. (`main` cross-checks the
/// fused step bit-exact against the per-lane engine before timing, so
/// the bench can't silently measure a divergent path.)
fn run_lanes(cfg: &ModelConfig, w: &Weights, stream: &[u32], t0: usize, gen: usize, lanes: usize, batched: bool) -> f64 {
    let mut kv = cache(cfg, w, false, lanes);
    let mut scratch = DecodeScratch::new();
    let slots: Vec<_> = (0..lanes)
        .map(|_| {
            let s = kv.alloc_slot().unwrap();
            prefill(cfg, w, &mut kv, s, &stream[..t0], None).unwrap();
            s
        })
        .collect();
    let start = Instant::now();
    if batched {
        let mut tokens = vec![0u32; lanes];
        for s in 0..gen {
            tokens.fill(stream[t0 + s]);
            let logits = decode_step_batch(cfg, w, &mut kv, &slots, &tokens, None, &mut scratch).unwrap();
            assert!(logits[0].is_finite());
        }
    } else {
        for s in 0..gen {
            for &slot in &slots {
                let logits = decode_step(cfg, w, &mut kv, slot, stream[t0 + s], None, &mut scratch).unwrap();
                assert!(logits[0].is_finite());
            }
        }
    }
    (lanes * gen) as f64 / start.elapsed().as_secs_f64()
}

/// Cached-BCQ decode with the attention path pinned: encoded-domain
/// per-page panels through the SIMD GEMM driver vs gather-then-dot
/// (the ISSUE 6 ablation). Prefill runs outside the timed region; the
/// decode loop reuses one scratch so the panel cache reaches steady
/// state (frontier-page-only re-decodes) before most timed steps.
fn run_attn_path(cfg: &ModelConfig, w: &Weights, stream: &[u32], t0: usize, gen: usize, path: AttnPath) -> f64 {
    let mut kv = cache(cfg, w, true, 1);
    let slot = kv.alloc_slot().unwrap();
    let mut scratch = DecodeScratch::new();
    scratch.set_attn_path(path);
    prefill(cfg, w, &mut kv, slot, &stream[..t0], None).unwrap();
    let start = Instant::now();
    for s in 0..gen {
        let logits = decode_step(cfg, w, &mut kv, slot, stream[t0 + s], None, &mut scratch).unwrap();
        assert!(logits[0].is_finite());
    }
    gen as f64 / start.elapsed().as_secs_f64()
}

/// Teacher-forced speculative decode along the stream (BCQ cache): each
/// fused stacked-verify call feeds the frontier plus the next `k`
/// stream tokens as the draft, so every draft token is "accepted" and
/// one weight pass advances `1 + k` positions — the full-acceptance
/// upper bound for the spec path. Cache writes are identical to
/// [`run_cached`]'s one-token loop (`main` bit-verifies the fused rows
/// against sequential `decode_step` before timing).
fn run_spec_teacher(cfg: &ModelConfig, w: &Weights, stream: &[u32], t0: usize, gen: usize, k: usize) -> f64 {
    let mut kv = cache(cfg, w, true, 1);
    let slot = kv.alloc_slot().unwrap();
    let mut scratch = DecodeScratch::new();
    prefill(cfg, w, &mut kv, slot, &stream[..t0], None).unwrap();
    let start = Instant::now();
    let mut s = 0usize;
    while s < gen {
        let take = k.min(gen - s - 1);
        let draft = stream[t0 + s + 1..t0 + s + 1 + take].to_vec();
        let logits =
            decode_step_batch_spec(cfg, w, &mut kv, &[slot], &[stream[t0 + s]], &[draft], None, &mut scratch)
                .unwrap();
        assert!(logits[0].is_finite());
        s += 1 + take;
    }
    gen as f64 / start.elapsed().as_secs_f64()
}

/// End-to-end speculative serving: 8 repetitive-corpus requests over a
/// 4-lane BCQ-cache [`DecodeSession`] through the continuous scheduler,
/// n-gram drafter (`spec_k == 0` = speculation off). Greedy decode on a
/// toy model settles into a cycle the n-gram drafter learns, so this
/// measures realistic accept-some/reject-some traffic, not the
/// teacher-forced upper bound. Returns (emitted tokens/sec, per-request
/// tokens sorted by id — the parity gate, and the speculation stats).
fn run_sched_spec(cfg: &ModelConfig, w: &Weights, spec_k: usize) -> (f64, Vec<(u64, Vec<u32>)>, Option<SpecStats>) {
    let kv = KvCacheOpts { page_tokens: 16, encoded: true, prefix_cache_bytes: None, page_budget: None };
    let mut sess = DecodeSession::new(cfg.clone(), w, &Scheme::Bf16, QuantPool::serial(), 4, kv).unwrap();
    let b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::ZERO, queue_cap: None });
    for i in 0..8u64 {
        let prompt = corpus::repetitive(0xDECE ^ i, 12, 48);
        assert!(b.push(Request::new(i + 1, prompt, 48)).is_accepted());
    }
    b.close();
    let drafter = if spec_k == 0 { DrafterKind::Off } else { DrafterKind::NGram };
    let opts = ContinuousOpts { prefill_chunk: usize::MAX, spec_k, drafter };
    let metrics = ServerMetrics::new();
    let mut out: Vec<(u64, Vec<u32>)> = Vec::new();
    let start = Instant::now();
    run_continuous_opts(&mut sess, &b, opts, Sampling::Greedy, Some(&metrics), |id, r| {
        out.push((id, r.expect("bench request failed").tokens));
    });
    let elapsed = start.elapsed().as_secs_f64();
    out.sort();
    let emitted: usize = out.iter().map(|(_, t)| t.len()).sum();
    (emitted as f64 / elapsed, out, metrics.snapshot().spec)
}

/// Teacher-forced perplexity of a corpus stream through prefill + decode
/// (positions `t0-1 .. t0+gen-1` score the next stream token).
fn decode_ppl(cfg: &ModelConfig, w: &Weights, stream: &[u32], t0: usize, gen: usize, encoded: bool) -> f64 {
    let mut kv = cache(cfg, w, encoded, 1);
    let slot = kv.alloc_slot().unwrap();
    let mut scratch = DecodeScratch::new();
    let mut nll = 0.0f64;
    let mut count = 0usize;
    let first = prefill(cfg, w, &mut kv, slot, &stream[..t0], None).unwrap();
    nll -= lobcq::eval::perplexity::log_softmax_at(&first, stream[t0] as usize);
    count += 1;
    for s in 0..gen {
        let logits = decode_step(cfg, w, &mut kv, slot, stream[t0 + s], None, &mut scratch).unwrap();
        nll -= lobcq::eval::perplexity::log_softmax_at(&logits, stream[t0 + s + 1] as usize);
        count += 1;
    }
    (nll / count as f64).exp()
}

fn main() {
    let (cfg, w) = model();
    // Pre-warm the shared LM-head panel so no engine pays the one-time
    // transpose+pack inside its timed region.
    let _ = w.packed_transposed("embed");
    let stream: Vec<u32> = corpus::generate(0xDECD, 384).into_iter().map(|t| t % cfg.vocab as u32).collect();

    println!("# perf_decode — full-recompute vs cached (f32) vs cached (BCQ)\n");
    let mut shapes_json = Vec::new();
    let mut acceptance = Json::obj();
    let gen = 24usize;
    let mut peak_f32 = 0usize;
    let mut peak_bcq = 0usize;
    for &t0 in &[64usize, 256] {
        // Sanity: cached f32 logits equal the full forward at this shape
        // (cheap spot check so the bench can't silently measure a
        // divergent path).
        {
            let mut kv = cache(&cfg, &w, false, 1);
            let slot = kv.alloc_slot().unwrap();
            let mut scr = DecodeScratch::new();
            prefill(&cfg, &w, &mut kv, slot, &stream[..t0], None).unwrap();
            let got = decode_step(&cfg, &w, &mut kv, slot, stream[t0], None, &mut scr).unwrap();
            let full = forward(&cfg, &w, &stream[..t0 + 1], 1, None).unwrap();
            for (c, &g) in got.iter().enumerate() {
                let want = full.at(t0, c);
                assert!((g - want).abs() <= 1e-4 * (1.0 + want.abs()), "parity drift at t0={t0} col {c}");
            }
        }

        let full_tps = run_full_recompute(&cfg, &w, &stream, t0, gen);
        let (f32_tps, f32_peak) = run_cached(&cfg, &w, &stream, t0, gen, false);
        let (bcq_tps, bcq_peak) = run_cached(&cfg, &w, &stream, t0, gen, true);
        peak_f32 = peak_f32.max(f32_peak);
        peak_bcq = peak_bcq.max(bcq_peak);
        println!(
            "T0={t0:>4} gen={gen}:  full {full_tps:8.1} tok/s   cached-f32 {f32_tps:8.1}   cached-bcq {bcq_tps:8.1}   (cache {f32_peak} vs {bcq_peak} bytes)"
        );
        shapes_json.push(
            Json::obj()
                .with("prompt_tokens", Json::Num(t0 as f64))
                .with("gen_tokens", Json::Num(gen as f64))
                .with(
                    "tokens_per_s",
                    Json::obj()
                        .with("full_recompute", Json::Num(full_tps))
                        .with("cached_f32", Json::Num(f32_tps))
                        .with("cached_bcq", Json::Num(bcq_tps)),
                )
                .with(
                    "peak_cache_bytes",
                    Json::obj().with("f32", Json::Num(f32_peak as f64)).with("bcq", Json::Num(bcq_peak as f64)),
                ),
        );
        if t0 == 256 {
            let speedup = f32_tps / full_tps;
            acceptance.set("cached_vs_full_recompute_t256", Json::Num(speedup));
            acceptance.set("cached_target", Json::Num(1.0));
            println!("\ncached-f32 vs full-recompute @T0=256: {speedup:.2}x (target > 1x)");
            if speedup <= 1.0 {
                eprintln!("WARNING: cached decode not faster than full recompute on this host");
            }
        }
    }

    let batch4_tps = run_cached_batch4(&cfg, &w, &stream, 64, gen);
    println!("batch4 cached-bcq @T0=64: {batch4_tps:.1} tok/s (4 lanes round-robin)");

    // ---- lane sweep: per-lane serial loop vs one fused step ----
    // Parity gate first: one fused step over 2 ragged lanes must be
    // bit-identical to the per-lane engine.
    {
        let mut kv_a = cache(&cfg, &w, false, 2);
        let mut kv_b = cache(&cfg, &w, false, 2);
        let (mut sa, mut sb) = (DecodeScratch::new(), DecodeScratch::new());
        let mut slots = Vec::new();
        for t0 in [24usize, 40] {
            let a = kv_a.alloc_slot().unwrap();
            let b = kv_b.alloc_slot().unwrap();
            prefill(&cfg, &w, &mut kv_a, a, &stream[..t0], None).unwrap();
            prefill(&cfg, &w, &mut kv_b, b, &stream[..t0], None).unwrap();
            slots.push(a);
        }
        let toks = [stream[40], stream[41]];
        let fused = decode_step_batch(&cfg, &w, &mut kv_b, &slots, &toks, None, &mut sb)
            .unwrap()
            .to_vec();
        for (i, &slot) in slots.iter().enumerate() {
            let lone = decode_step(&cfg, &w, &mut kv_a, slot, toks[i], None, &mut sa).unwrap();
            for (c, (&g, &want)) in fused[i * cfg.vocab..(i + 1) * cfg.vocab].iter().zip(&lone).enumerate() {
                assert_eq!(g.to_bits(), want.to_bits(), "lane-sweep parity drift: lane {i} col {c}");
            }
        }
    }
    println!("\n# lane sweep — per-lane serial vs fused batched step (f32 KV, T0=64)");
    let mut lane_json = Vec::new();
    let mut batched_x4 = 0.0f64;
    for &lanes in &[1usize, 4, 16] {
        let serial_tps = run_lanes(&cfg, &w, &stream, 64, gen, lanes, false);
        let batched_tps = run_lanes(&cfg, &w, &stream, 64, gen, lanes, true);
        let speedup = batched_tps / serial_tps;
        if lanes == 4 {
            batched_x4 = speedup;
        }
        println!("lanes={lanes:>2}: per-lane {serial_tps:8.1} tok/s   batched {batched_tps:8.1} tok/s   ({speedup:.2}x)");
        lane_json.push(
            Json::obj()
                .with("lanes", Json::Num(lanes as f64))
                .with("per_lane_tokens_per_s", Json::Num(serial_tps))
                .with("batched_tokens_per_s", Json::Num(batched_tps))
                .with("speedup", Json::Num(speedup)),
        );
    }
    acceptance.set("batched_vs_per_lane_x4", Json::Num(batched_x4));
    acceptance.set("batched_target", Json::Num(1.0));
    println!("batched vs per-lane @4 lanes: {batched_x4:.2}x (target > 1x)");
    if batched_x4 <= 1.0 {
        eprintln!("WARNING: fused batched decode not faster than the per-lane loop at 4 lanes");
    }

    // ---- encoded-domain attention vs gather-then-dot (BCQ cache) ----
    // Parity gate first: both attention paths must produce bit-identical
    // logits over a prefill + multi-step decode on the encoded cache.
    {
        let mut kv_e = cache(&cfg, &w, true, 1);
        let mut kv_g = cache(&cfg, &w, true, 1);
        let se = kv_e.alloc_slot().unwrap();
        let sg = kv_g.alloc_slot().unwrap();
        let (mut scr_e, mut scr_g) = (DecodeScratch::new(), DecodeScratch::new());
        scr_e.set_attn_path(AttnPath::Encoded);
        scr_g.set_attn_path(AttnPath::Gather);
        prefill(&cfg, &w, &mut kv_e, se, &stream[..40], None).unwrap();
        prefill(&cfg, &w, &mut kv_g, sg, &stream[..40], None).unwrap();
        for s in 0..8 {
            let enc = decode_step(&cfg, &w, &mut kv_e, se, stream[40 + s], None, &mut scr_e).unwrap();
            let gat = decode_step(&cfg, &w, &mut kv_g, sg, stream[40 + s], None, &mut scr_g).unwrap();
            for (c, (&x, &y)) in enc.iter().zip(&gat).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "attn-path parity drift: step {s} col {c}");
            }
        }
    }
    let enc_attn_tps = run_attn_path(&cfg, &w, &stream, 256, gen, AttnPath::Encoded);
    let gat_attn_tps = run_attn_path(&cfg, &w, &stream, 256, gen, AttnPath::Gather);
    let attn_ratio = enc_attn_tps / gat_attn_tps;
    println!(
        "\nencoded-attn vs gather-attn @T0=256 (bcq cache): encoded {enc_attn_tps:8.1} tok/s   gather {gat_attn_tps:8.1} tok/s   ({attn_ratio:.2}x)"
    );
    acceptance.set("encoded_attn_vs_decode_attn", Json::Num(attn_ratio));
    if attn_ratio < 1.0 {
        eprintln!("WARNING: encoded-domain attention slower than gather-then-dot on this host");
    }

    // Encoded-cache bit budget (analytic and measured).
    let kv_bits = kv_quantizer(&cfg, &w).bits_per_scalar();
    acceptance.set("kv_bits_per_scalar", Json::Num(kv_bits));
    acceptance.set("kv_bits_target", Json::Num(5.0));
    println!("encoded KV bits/scalar: {kv_bits:.3} (target <= 5)");
    if kv_bits > 5.0 {
        eprintln!("WARNING: encoded KV cache exceeds the 5 bits/scalar budget");
    }

    // KV4-vs-KV16 perplexity ablation (teacher-forced corpus stream).
    let ppl16 = decode_ppl(&cfg, &w, &stream, 32, 96, false);
    let ppl4 = decode_ppl(&cfg, &w, &stream, 32, 96, true);
    println!("decode ppl: KV16 {ppl16:.4}  KV4 {ppl4:.4}  (delta {:+.4})", ppl4 - ppl16);

    // ---- speculative decoding: stacked verify vs one-token steps ----
    // (ISSUE 9.) Parity gate first: one fused stacked-verify step over
    // frontier + 3 drafted tokens must be bit-identical to feeding the
    // same four tokens through sequential `decode_step`s.
    {
        let mut kv_a = cache(&cfg, &w, true, 1);
        let mut kv_b = cache(&cfg, &w, true, 1);
        let sa_slot = kv_a.alloc_slot().unwrap();
        let sb_slot = kv_b.alloc_slot().unwrap();
        let (mut sa, mut sb) = (DecodeScratch::new(), DecodeScratch::new());
        prefill(&cfg, &w, &mut kv_a, sa_slot, &stream[..40], None).unwrap();
        prefill(&cfg, &w, &mut kv_b, sb_slot, &stream[..40], None).unwrap();
        let draft: Vec<u32> = stream[41..44].to_vec();
        let fused =
            decode_step_batch_spec(&cfg, &w, &mut kv_b, &[sb_slot], &[stream[40]], &[draft], None, &mut sb)
                .unwrap()
                .to_vec();
        for r in 0..4usize {
            let lone = decode_step(&cfg, &w, &mut kv_a, sa_slot, stream[40 + r], None, &mut sa).unwrap();
            for (c, (&g, &want)) in fused[r * cfg.vocab..(r + 1) * cfg.vocab].iter().zip(&lone).enumerate() {
                assert_eq!(g.to_bits(), want.to_bits(), "spec parity drift: row {r} col {c}");
            }
        }
    }
    println!("\n# speculative decoding — stacked verify vs one-token steps (bcq cache, T0=64)");
    let (spec_base_tps, _) = run_cached(&cfg, &w, &stream, 64, gen, true);
    let mut teacher_json = Vec::new();
    for &k in &[2usize, 4] {
        let tps = run_spec_teacher(&cfg, &w, &stream, 64, gen, k);
        println!(
            "teacher-forced k={k}: {tps:8.1} tok/s vs one-token {spec_base_tps:8.1} ({:.2}x, full acceptance)",
            tps / spec_base_tps
        );
        teacher_json.push(
            Json::obj()
                .with("k", Json::Num(k as f64))
                .with("tokens_per_s", Json::Num(tps))
                .with("speedup_vs_one_token", Json::Num(tps / spec_base_tps)),
        );
    }
    // End-to-end scheduler rows: spec-off vs n-gram at k ∈ {2, 4} on the
    // repetitive corpus. Every speculated run is parity-gated against the
    // spec-off run before its timing is trusted.
    let (off_tps, off_tokens, _) = run_sched_spec(&cfg, &w, 0);
    let mut sched_spec_json = Vec::new();
    let mut spec_vs_baseline = 0.0f64;
    for &k in &[2usize, 4] {
        let (tps, toks, stats) = run_sched_spec(&cfg, &w, k);
        assert_eq!(toks, off_tokens, "speculated scheduler run diverged from spec-off at k={k}");
        let st = stats.expect("speculated run recorded no speculation stats");
        println!(
            "scheduler ngram k={k}: {tps:8.1} tok/s vs spec-off {off_tps:8.1} ({:.2}x)   acceptance mean {:.0}% p50 {:.0}%   rollbacks {}",
            tps / off_tps,
            st.acceptance_mean_pct,
            st.acceptance_p50_pct,
            st.rollbacks
        );
        if k == 4 {
            spec_vs_baseline = tps / off_tps;
        }
        sched_spec_json.push(
            Json::obj()
                .with("k", Json::Num(k as f64))
                .with("tokens_per_s", Json::Num(tps))
                .with("speedup_vs_spec_off", Json::Num(tps / off_tps))
                .with("acceptance_mean_pct", Json::Num(st.acceptance_mean_pct))
                .with("acceptance_p50_pct", Json::Num(st.acceptance_p50_pct))
                .with("drafted", Json::Num(st.drafted as f64))
                .with("accepted", Json::Num(st.accepted as f64))
                .with("wasted", Json::Num(st.wasted as f64))
                .with("rollbacks", Json::Num(st.rollbacks as f64)),
        );
    }
    acceptance.set("spec_vs_baseline", Json::Num(spec_vs_baseline));
    acceptance.set("spec_target", Json::Num(1.0));
    println!("speculation vs spec-off @k=4 (repetitive corpus): {spec_vs_baseline:.2}x (target > 1x)");
    if spec_vs_baseline <= 1.0 {
        eprintln!("WARNING: speculative decoding not faster than spec-off on this host/workload");
    }

    // ---- span-tracing overhead (ISSUE 8 gate) ----
    // Disabled cost: one relaxed load per probe, measured directly over a
    // tight guard-construct/drop loop; the gate is that cost, times the
    // probes a decode token actually crosses, as a share of the token
    // time — analytic, so timing noise between two full runs can't flip
    // it. Enabled cost is then measured for real (this runs LAST among
    // the timed sections: rings stay allocated once tracing was on).
    let (disabled_tps, _) = run_cached(&cfg, &w, &stream, 64, gen, true);
    let probe_ns = {
        assert!(!lobcq::obs::trace::enabled(), "tracing on before the disabled-cost measurement");
        let iters = 4_000_000u64;
        let start = Instant::now();
        for i in 0..iters {
            let mut g = lobcq::obs::trace::span_id("op", "probe", i);
            g.set_arg(i);
        }
        start.elapsed().as_secs_f64() * 1e9 / iters as f64
    };
    // Probes per decode token: per layer one layer span + qkv/attn/wo/mlp
    // op spans, plus the lm-head span and the scheduler step span.
    let probes_per_token = (5 * cfg.n_layers + 2) as f64;
    let token_ns = 1e9 / disabled_tps;
    let disabled_overhead_pct = 100.0 * probes_per_token * probe_ns / token_ns;
    lobcq::obs::trace::enable();
    let (enabled_tps, _) = run_cached(&cfg, &w, &stream, 64, gen, true);
    lobcq::obs::trace::disable();
    let enabled_overhead_pct = 100.0 * (disabled_tps / enabled_tps - 1.0);
    println!(
        "\ntrace overhead: disabled probe {probe_ns:.1}ns x{probes_per_token:.0}/token = \
         {disabled_overhead_pct:.4}% of a token (target < 1%); enabled: {enabled_overhead_pct:+.1}% \
         ({disabled_tps:.1} -> {enabled_tps:.1} tok/s)"
    );
    acceptance.set("trace_disabled_overhead_pct", Json::Num(disabled_overhead_pct));
    acceptance.set("trace_disabled_overhead_target_pct", Json::Num(1.0));
    if disabled_overhead_pct >= 1.0 {
        eprintln!("WARNING: disabled-tracing probe overhead above 1% of a decode token");
    }

    let mut report = Json::obj()
        .with("bench", Json::Str("perf_decode".into()))
        .with(
            "trace_overhead",
            Json::obj()
                .with("probe_disabled_ns", Json::Num(probe_ns))
                .with("probes_per_token", Json::Num(probes_per_token))
                .with("disabled_overhead_pct", Json::Num(disabled_overhead_pct))
                .with("enabled_tokens_per_s", Json::Num(enabled_tps))
                .with("disabled_tokens_per_s", Json::Num(disabled_tps))
                .with("enabled_overhead_pct", Json::Num(enabled_overhead_pct)),
        )
        .with(
            "attn_path",
            Json::obj()
                .with("encoded_tokens_per_s", Json::Num(enc_attn_tps))
                .with("gather_tokens_per_s", Json::Num(gat_attn_tps))
                .with("speedup", Json::Num(attn_ratio)),
        )
        .with(
            "speculation",
            Json::obj()
                .with("one_token_tokens_per_s", Json::Num(spec_base_tps))
                .with("teacher_forced", Json::Arr(teacher_json))
                .with("spec_off_tokens_per_s", Json::Num(off_tps))
                .with("scheduler", Json::Arr(sched_spec_json)),
        )
        .with("shapes", Json::Arr(shapes_json))
        .with("batch4_cached_bcq_tokens_per_s", Json::Num(batch4_tps))
        .with("lane_sweep", Json::Arr(lane_json))
        .with(
            "kv_ablation",
            Json::obj()
                .with("kv16_ppl", Json::Num(ppl16))
                .with("kv4_ppl", Json::Num(ppl4))
                .with("delta", Json::Num(ppl4 - ppl16)),
        )
        .with(
            "peak_cache_bytes",
            Json::obj().with("f32", Json::Num(peak_f32 as f64)).with("bcq", Json::Num(peak_bcq as f64)),
        )
        .with("acceptance", acceptance.clone());
    lobcq::obs::report::stamp(&mut report);
    let path = std::path::Path::new("BENCH_decode.json");
    report.to_file(path).expect("write BENCH_decode.json");
    println!("\nreport written to {}", path.display());

    // Shared run-record (results/raw/) in the same schema the workload
    // harness emits, for report_generator.py consolidation.
    use lobcq::bench::Direction;
    let rec = lobcq::bench::RunRecord::bench("decode")
        .config(
            Json::obj()
                .with("d", Json::Num(cfg.d as f64))
                .with("n_layers", Json::Num(cfg.n_layers as f64))
                .with("kv", Json::Str("bcq".into())),
        )
        .metric("batch4_cached_bcq_tokens_per_s", batch4_tps, Direction::Higher)
        .metric("encoded_attn_speedup", attn_ratio, Direction::Higher)
        .metric("spec_vs_baseline", spec_vs_baseline, Direction::Higher)
        .metric("kv4_ppl_delta", ppl4 - ppl16, Direction::Lower)
        .metric("trace_disabled_overhead_pct", disabled_overhead_pct, Direction::Lower)
        .detail(report.clone());
    let rp = rec
        .write_into(&lobcq::bench::record::raw_dir(), "bench_decode")
        .expect("write decode run-record");
    println!("run-record written to {}", rp.display());
}
