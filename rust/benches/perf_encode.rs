//! §Perf L3 micro-bench: the on-the-fly quantization hot path.
//!
//! Measures (a) LO-BCQ fake-quantize (normalize → select → round →
//! denormalize), (b) the packed-format encode (Fig. 5 bitstream), and
//! (c) decode, in scalars/second — the paper's claim that tiny frozen
//! codebooks make dynamic activation quantization cheap. Target
//! (DESIGN.md §8): ≥ 100 M scalars/s/core for the fake-quantize path.
//! Before/after numbers live in EXPERIMENTS.md §Perf.
//!
//! The final section compares the legacy serving shape (single-thread,
//! one fresh Vec per call) against the unified pipeline (8 workers,
//! pooled in-place buffers) on a [4096 × 4096] fake-quantize, and checks
//! the zero-allocation steady state via the scratch pool's counter.

use lobcq::quant::calib::LobcqQuantizer;
use lobcq::quant::encode::{decode, encode};
use lobcq::quant::lobcq::{fake_quantize, LobcqConfig};
use lobcq::quant::pipeline::{QuantPipeline, QuantPool, QuantScheme};
use lobcq::util::rng::{llm_like_sample, Pcg32};
use lobcq::util::timer::{black_box, Bencher};
use std::sync::Arc;

fn main() {
    let env = lobcq::eval::Env::load();
    let cfg = LobcqConfig::new(8, 8, 64);
    let fam = env.family(8, 4, 6).expect("family");

    let mut rng = Pcg32::seeded(0xBE7C);
    let sizes = [4 * 1024usize, 64 * 1024, 512 * 1024];
    let b = Bencher::default();

    println!("# perf_encode — LO-BCQ hot path (g64, Nc=8, B=4)\n");
    for &n in &sizes {
        let x = llm_like_sample(&mut rng, n, 0.05, 4.0);
        let shape = [n / 64, 64];

        let r = b.run(&format!("fake_quantize/{n}"), || {
            black_box(fake_quantize(black_box(&x), &cfg, &fam));
        });
        println!("{}", r.throughput(n as f64, "scalars"));

        let r = b.run(&format!("encode_packed/{n}"), || {
            black_box(encode(black_box(&x), &shape, &cfg, &fam));
        });
        println!("{}", r.throughput(n as f64, "scalars"));

        let enc = encode(&x, &shape, &cfg, &fam);
        let r = b.run(&format!("decode_packed/{n}"), || {
            black_box(decode(black_box(&enc), &fam));
        });
        println!("{}", r.throughput(n as f64, "scalars"));
    }

    // Codebook-selection microcosm: the eq. 4 argmin over Nc books.
    let x = llm_like_sample(&mut rng, 64 * 1024, 0.05, 4.0);
    let norm = lobcq::quant::lobcq::normalize(&x, cfg.la, &cfg);
    let blocks: Vec<&[f32]> = norm.values.chunks_exact(cfg.lb).collect();
    let r = b.run("select_only/64k", || {
        let mut acc = 0usize;
        for blk in &blocks {
            acc += fam.select(blk);
        }
        black_box(acc);
    });
    println!("{}", r.throughput(x.len() as f64, "scalars"));

    // ---- pipeline vs legacy serving shape (ISSUE 1 acceptance) ----
    // [4096 x 4096] activation tensor; legacy = 1 worker + a fresh Vec
    // per call, pipeline = 8 workers + pooled in-place buffers.
    let n = 4096 * 4096;
    println!("\n# pipeline vs legacy — [4096 x 4096] fake-quantize\n");
    let x = llm_like_sample(&mut rng, n, 0.05, 4.0);
    let scheme: Arc<dyn QuantScheme> = Arc::new(LobcqQuantizer::universal(cfg, fam.clone()));
    let qb = Bencher::quick();

    let serial = QuantPool::serial();
    let legacy = qb.run("legacy: 1 worker, alloc per call", || {
        let mut out = vec![0.0f32; n];
        serial.quantize_into(&*scheme, black_box(&x), &mut out);
        black_box(out);
    });
    println!("{}", legacy.throughput(n as f64, "scalars"));

    let pipe = QuantPipeline::new(scheme.clone(), QuantPool::with_workers(8));
    // Warm up the scratch pool, then verify steady-state allocations.
    let buf = pipe.quantize_pooled(&x);
    pipe.recycle(buf);
    let allocs_warm = pipe.scratch_allocations();
    let par = qb.run("pipeline: 8 workers, pooled in-place", || {
        let buf = pipe.quantize_pooled(black_box(&x));
        pipe.recycle(black_box(buf));
    });
    println!("{}", par.throughput(n as f64, "scalars"));
    let allocs_delta = pipe.scratch_allocations() - allocs_warm;

    let speedup = legacy.median_s() / par.median_s();
    println!("\nspeedup: {speedup:.2}x (target >= 2x), steady-state allocations: {allocs_delta} (target 0)");
    if speedup < 2.0 || allocs_delta != 0 {
        eprintln!("WARNING: pipeline acceptance target missed on this host");
    }

    use lobcq::util::json::Json;
    let mut report = Json::obj()
        .with("bench", Json::Str("perf_encode".into()))
        .with(
            "pipeline_vs_legacy",
            Json::obj()
                .with("speedup", Json::Num(speedup))
                .with("target_speedup", Json::Num(2.0))
                .with("steady_state_allocations", Json::Num(allocs_delta as f64))
                .with("legacy_scalars_per_s", Json::Num(n as f64 / legacy.median_s()))
                .with("pipeline_scalars_per_s", Json::Num(n as f64 / par.median_s())),
        );
    lobcq::obs::report::stamp(&mut report);
    let path = std::path::Path::new("BENCH_encode.json");
    report.to_file(path).expect("write BENCH_encode.json");
    println!("report written to {}", path.display());
}
