//! §Perf GEMM bench — emits `BENCH_gemm.json`.
//!
//! Measures GFLOP/s at the serving shapes (decode m=1, prefill m=128)
//! and the 1024×1024×1024 acceptance shape for four paths:
//!
//! - `naive`: the seed `matmul_par` (threaded scalar ikj loop with the
//!   `a == 0.0` skip branch), reimplemented here verbatim as the
//!   baseline;
//! - `blocked`: the cache-blocked register-tiled kernel
//!   (`kernels::gemm_packed`, B packed once — the steady-state serving
//!   shape);
//! - `encoded`: the encoded-domain qgemm straight from LO-BCQ codes;
//! - `decode_then_gemm`: decode the packed tensor to a full f32 weight
//!   every call, then run the **new blocked kernel** on it. This is
//!   deliberately the strongest f32 alternative (not the seed scalar
//!   loop), so "encoded beats decode-then-f32-matmul" is a conservative
//!   claim: qgemm wins by skipping the full-tensor materialization +
//!   pack, not by racing a slow matmul;
//! - `blocked_scalar`: the same blocked driver pinned to the scalar
//!   micro-kernel oracle — the SIMD dispatch speedup is
//!   `blocked / blocked_scalar` (bit-identical outputs, gated below).
//!
//! Acceptance (ISSUE 2): blocked ≥ 4x naive at 1024³, and encoded beats
//! decode-then-f32-matmul. ISSUE 6 adds `simd_vs_scalar` (informative
//! when the host has no SIMD backend: the ratio is ~1.0 by definition).

#![allow(clippy::needless_range_loop)]

use lobcq::kernels::{
    backend_name, gemm_into_flat_with_backend, gemm_packed, KernelBackend, PackedB, QuantLinear,
};
use lobcq::quant::calib::calibrate_universal;
use lobcq::quant::encode::{decode, encode};
use lobcq::quant::lobcq::{CalibOpts, LobcqConfig};
use lobcq::tensor::Tensor;
use lobcq::util::json::Json;
use lobcq::util::rng::{llm_like_sample, Pcg32};
use lobcq::util::timer::{black_box, Bencher};

/// The seed kernel this PR replaces, kept verbatim as the baseline.
fn naive_matmul_par(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2);
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    if m * n * k < 1 << 18 || threads == 1 {
        return a.matmul(b);
    }
    let mut out = vec![0.0f32; m * n];
    let chunk = m.div_ceil(threads);
    std::thread::scope(|s| {
        for (ti, out_chunk) in out.chunks_mut(chunk * n).enumerate() {
            let a = &a;
            let b = &b;
            s.spawn(move || {
                let row0 = ti * chunk;
                for (r, orow) in out_chunk.chunks_mut(n).enumerate() {
                    let arow = a.row(row0 + r);
                    for (kk, &av) in arow.iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        let brow = b.row(kk);
                        for (o, &bv) in orow.iter_mut().zip(brow) {
                            *o += av * bv;
                        }
                    }
                }
            });
        }
    });
    Tensor::new(&[m, n], out)
}

fn gflops(m: usize, n: usize, k: usize, secs: f64) -> f64 {
    (2.0 * m as f64 * n as f64 * k as f64) / secs / 1e9
}

fn main() {
    let cfg = LobcqConfig::new(8, 8, 64);
    let mut rng = Pcg32::seeded(0x6E66);

    // One shared [1024, 1024] weight: dense, packed, and encoded forms.
    let (k, n) = (1024usize, 1024usize);
    let kmajor = llm_like_sample(&mut rng, k * n, 0.05, 4.0);
    let sample = Tensor::new(&[k * n / cfg.la, cfg.la], kmajor.clone());
    let fam = calibrate_universal(&[&sample], &cfg, CalibOpts { max_iters: 15, ..Default::default() }, 0x6E66);
    let mut dense = Tensor::zeros(&[k, n]);
    for c in 0..n {
        for r in 0..k {
            dense.data[r * n + c] = kmajor[c * k + r];
        }
    }
    let packed = PackedB::pack(&dense);
    let ql = QuantLinear::from_kmajor(&kmajor, k, n, cfg, &fam).unwrap();
    let enc = encode(&kmajor, &[n, k], &cfg, &fam);

    let b = Bencher::quick();
    let mut shapes_json = Vec::new();
    let mut acceptance = Json::obj();

    println!("# perf_gemm — f32-blocked vs naive vs encoded-domain\n");
    for &(tag, m) in &[("decode", 1usize), ("prefill", 128), ("square", 1024)] {
        let a = Tensor::from_fn(&[m, k], |_| rng.normal());

        let naive = b.run(&format!("naive/{tag}"), || {
            black_box(naive_matmul_par(black_box(&a), black_box(&dense)));
        });
        let blocked = b.run(&format!("blocked/{tag}"), || {
            black_box(gemm_packed(black_box(&a), black_box(&packed)));
        });
        // Same driver, scalar micro-kernel pinned — and gate the
        // dispatch contract (bitwise identity) before trusting either
        // timing.
        let mut out_simd = vec![0.0f32; m * n];
        let mut out_scalar = vec![0.0f32; m * n];
        let mut scratch = Vec::new();
        gemm_into_flat_with_backend(lobcq::kernels::active_backend(), &a.data, m, k, &packed, &mut out_simd, &mut scratch);
        gemm_into_flat_with_backend(KernelBackend::Scalar, &a.data, m, k, &packed, &mut out_scalar, &mut scratch);
        for (i, (x, y)) in out_simd.iter().zip(&out_scalar).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "SIMD/scalar divergence at {tag} elem {i}");
        }
        let blocked_scalar = b.run(&format!("blocked_scalar/{tag}"), || {
            gemm_into_flat_with_backend(
                KernelBackend::Scalar,
                black_box(&a.data),
                m,
                k,
                black_box(&packed),
                &mut out_scalar,
                &mut scratch,
            );
            black_box(&out_scalar);
        });
        let encoded = b.run(&format!("encoded/{tag}"), || {
            black_box(ql.qgemm(black_box(&a)));
        });
        let decode_then = b.run(&format!("decode_then_gemm/{tag}"), || {
            // Materialize f32 weights from the packed format on every
            // call, then run the blocked kernel — the strongest
            // decode-first baseline.
            let w = Tensor::new(&[k, n], {
                let flat = decode(black_box(&enc), &fam);
                let mut out = vec![0.0f32; k * n];
                for c in 0..n {
                    for r in 0..k {
                        out[r * n + c] = flat[c * k + r];
                    }
                }
                out
            });
            black_box(lobcq::kernels::gemm(black_box(&a), &w));
        });

        let gf = |r: &lobcq::util::timer::BenchResult| gflops(m, n, k, r.median_s());
        let (g_naive, g_blocked, g_scalar, g_encoded, g_decode) =
            (gf(&naive), gf(&blocked), gf(&blocked_scalar), gf(&encoded), gf(&decode_then));
        println!("{tag:>8} (m={m:>4}):  naive {g_naive:7.2}  blocked {g_blocked:7.2}  blocked-scalar {g_scalar:7.2}  encoded {g_encoded:7.2}  decode-then-gemm {g_decode:7.2}  GFLOP/s");

        shapes_json.push(
            Json::obj()
                .with("name", Json::Str(tag.into()))
                .with("m", Json::Num(m as f64))
                .with("n", Json::Num(n as f64))
                .with("k", Json::Num(k as f64))
                .with(
                    "gflops",
                    Json::obj()
                        .with("naive", Json::Num(g_naive))
                        .with("blocked", Json::Num(g_blocked))
                        .with("blocked_scalar", Json::Num(g_scalar))
                        .with("encoded", Json::Num(g_encoded))
                        .with("decode_then_gemm", Json::Num(g_decode)),
                ),
        );

        if tag == "square" {
            let speedup = g_blocked / g_naive;
            acceptance.set("blocked_vs_naive_1024", Json::Num(speedup));
            acceptance.set("blocked_target", Json::Num(4.0));
            println!("\nblocked vs naive @1024^3: {speedup:.2}x (target >= 4x)");
            if speedup < 4.0 {
                eprintln!("WARNING: blocked-kernel acceptance target missed on this host");
            }
            let simd_ratio = g_blocked / g_scalar;
            acceptance.set("simd_vs_scalar", Json::Num(simd_ratio));
            println!("simd ({}) vs scalar @1024^3: {simd_ratio:.2}x", backend_name());
            if simd_ratio < 0.95 {
                eprintln!("WARNING: SIMD micro-kernel slower than the scalar oracle on this host");
            }
        }
        if tag == "decode" {
            let ratio = g_encoded / g_decode;
            acceptance.set("encoded_vs_decode_then_gemm_decode_shape", Json::Num(ratio));
            if ratio < 1.0 {
                eprintln!("WARNING: encoded-domain qgemm slower than decode-then-gemm at decode shape");
            }
        }
    }

    let mut report = Json::obj()
        .with("bench", Json::Str("perf_gemm".into()))
        .with("shapes", Json::Arr(shapes_json))
        .with("acceptance", acceptance.clone());
    lobcq::obs::report::stamp(&mut report);
    let path = std::path::Path::new("BENCH_gemm.json");
    report.to_file(path).expect("write BENCH_gemm.json");
    println!("\nreport written to {}", path.display());

    // Shared run-record (results/raw/): the same schema the workload
    // harness emits, so report_generator.py consolidates benches and
    // serving runs into one trajectory.
    let mut rec = lobcq::bench::RunRecord::bench("gemm")
        .config(Json::obj().with("k", Json::Num(1024.0)).with("n", Json::Num(1024.0)))
        .detail(report.clone());
    use lobcq::bench::Direction;
    for key in ["blocked_vs_naive_1024", "simd_vs_scalar", "encoded_vs_decode_then_gemm_decode_shape"] {
        if let Some(v) = acceptance.opt(key).and_then(|x| x.as_f64().ok()) {
            rec = rec.metric(key, v, Direction::Higher);
        }
    }
    let rp = rec
        .write_into(&lobcq::bench::record::raw_dir(), "bench_gemm")
        .expect("write gemm run-record");
    println!("run-record written to {}", rp.display());
}
