//! §Perf prefix-cache bench — emits `BENCH_prefix.json`.
//!
//! Measures **time-to-first-token** (≈ prefill wall time through the
//! `DecodeSession` engine, admission matching included) on the
//! shared-prefix workload `data::corpus::shared_prefix_workload`
//! generates: N requests drawing from K system prompts of 256 tokens
//! plus request-unique suffixes — the traffic shape where cross-request
//! KV reuse pays.
//!
//! Protocol per K ∈ {1, 8}: a **cold** engine (prefix cache off) serves
//! every request paying the full prefill; a **warm** engine (prefix
//! cache on) is seeded with one un-timed request per distinct prefix,
//! then serves the same N requests — each should adopt ~256 cached
//! tokens and prefill only its suffix. Before timing, the bench
//! cross-checks one warm-hit prefill bit-exact against the cold engine
//! (f32 and BCQ KV stores), so it can never silently measure a
//! divergent path.
//!
//! Acceptance: `warm_ttft_speedup` (K=1, BCQ KV) ≥ 2× — with a
//! 256-token prefix and a 16-token suffix the warm engine computes
//! ~6% of the positions, and attention over the adopted prefix is the
//! only O(prefix) work left.

#![allow(clippy::needless_range_loop)]

use lobcq::coordinator::{DecodeEngine, DecodeSession, KvCacheOpts};
use lobcq::data::corpus;
use lobcq::eval::Scheme;
use lobcq::model::{ModelConfig, Weights};
use lobcq::quant::pipeline::QuantPool;
use lobcq::tensor::Tensor;
use lobcq::util::json::Json;
use lobcq::util::rng::Pcg32;
use std::time::Instant;

const PREFIX_TOKENS: usize = 256;
const SUFFIX_TOKENS: usize = 16;
const REQUESTS: usize = 12;
const PAGE_TOKENS: usize = 16;

/// Serving-shaped toy model: head_dim 64 (the ≤5 bits/scalar shape).
fn model() -> (ModelConfig, Weights) {
    let cfg = ModelConfig {
        name: "prefix-bench".into(),
        d: 128,
        n_layers: 2,
        n_heads: 2,
        vocab: corpus::VOCAB as usize,
        max_t: 384,
    };
    let mut rng = Pcg32::seeded(0x9F1C);
    let mut tensors = std::collections::BTreeMap::new();
    for (name, shape) in cfg.param_shapes() {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = if name.ends_with(".g") {
            vec![1.0; n]
        } else if name.ends_with(".b") {
            vec![0.0; n]
        } else {
            (0..n).map(|_| rng.normal() * 0.05).collect()
        };
        tensors.insert(name, Tensor::new(&shape, data));
    }
    (cfg, Weights::new(tensors))
}

fn session(cfg: &ModelConfig, w: &Weights, encoded_kv: bool, prefix_budget: Option<usize>) -> DecodeSession {
    let kv = KvCacheOpts { page_tokens: PAGE_TOKENS, encoded: encoded_kv, prefix_cache_bytes: prefix_budget, page_budget: None };
    DecodeSession::new(cfg.clone(), w, &Scheme::Bf16, QuantPool::serial(), 1, kv).unwrap()
}

/// Serve each prompt once (prefill + release), returning the mean
/// prefill wall time in µs.
fn serve_all(s: &mut DecodeSession, prompts: &[Vec<u32>]) -> f64 {
    let mut total_us = 0.0f64;
    for p in prompts {
        let t0 = Instant::now();
        let (lane, logits) = s.prefill(p).unwrap();
        total_us += t0.elapsed().as_secs_f64() * 1e6;
        assert!(logits[0].is_finite());
        s.release(lane);
    }
    total_us / prompts.len() as f64
}

fn main() {
    let (cfg, w) = model();
    let _ = w.packed_transposed("embed"); // pre-warm the shared LM-head panel

    // ---- parity gate: a warm hit must be bit-identical to cold ----
    for encoded_kv in [false, true] {
        let mut warm = session(&cfg, &w, encoded_kv, Some(64 << 20));
        let mut cold = session(&cfg, &w, encoded_kv, None);
        let wl = corpus::shared_prefix_workload(0x9F1D, 1, 2, 64, 8);
        let seed_prompt = &wl.requests[0].1;
        let (lane, _) = warm.prefill(seed_prompt).unwrap();
        warm.release(lane);
        let probe = &wl.requests[1].1;
        let (wl_lane, wlog) = warm.prefill(probe).unwrap();
        assert!(warm.prefix_stats().unwrap().hits >= 1, "parity probe missed the cache");
        let (cl_lane, clog) = cold.prefill(probe).unwrap();
        for (c, (&g, &x)) in wlog.iter().zip(&clog).enumerate() {
            assert_eq!(g.to_bits(), x.to_bits(), "warm/cold divergence (encoded_kv={encoded_kv}) at col {c}");
        }
        warm.release(wl_lane);
        cold.release(cl_lane);
    }
    println!("# perf_prefix — warm (prefix-cache hit) vs cold TTFT, prefix {PREFIX_TOKENS} suffix {SUFFIX_TOKENS}\n");

    let mut shapes_json = Vec::new();
    let mut acceptance = Json::obj();
    let mut speedup_k1 = 0.0f64;
    for &k in &[1usize, 8] {
        let wl = corpus::shared_prefix_workload(0x9F1E + k as u64, k, REQUESTS, PREFIX_TOKENS, SUFFIX_TOKENS);
        let prompts: Vec<Vec<u32>> = wl.requests.iter().map(|(_, p)| p.clone()).collect();

        // Cold: no prefix cache, every request pays the full prefill.
        let mut cold = session(&cfg, &w, true, None);
        let cold_ttft_us = serve_all(&mut cold, &prompts);

        // Warm: seed one request per distinct prefix (un-timed), then
        // serve the same N requests off the tree.
        let mut warm = session(&cfg, &w, true, Some(64 << 20));
        for prefix in &wl.prefixes {
            let mut seed_prompt = prefix.clone();
            seed_prompt.push(corpus::PERIOD);
            let (lane, _) = warm.prefill(&seed_prompt).unwrap();
            warm.release(lane);
        }
        let before = warm.prefix_stats().unwrap();
        let warm_ttft_us = serve_all(&mut warm, &prompts);
        let after = warm.prefix_stats().unwrap();
        let hits = after.hits - before.hits;
        let saved = after.saved_tokens - before.saved_tokens;
        let hit_rate = hits as f64 / REQUESTS as f64;
        let saved_per_req = saved as f64 / REQUESTS as f64;

        let speedup = cold_ttft_us / warm_ttft_us;
        if k == 1 {
            speedup_k1 = speedup;
        }
        println!(
            "K={k}: cold {cold_ttft_us:9.0}µs  warm {warm_ttft_us:9.0}µs  ({speedup:.2}x)  hit-rate {hit_rate:.2}  saved {saved_per_req:.0} tok/req"
        );
        assert!(hits as usize == REQUESTS, "K={k}: {hits}/{REQUESTS} warm requests hit");
        assert!(
            saved_per_req >= (PREFIX_TOKENS - PAGE_TOKENS) as f64,
            "K={k}: warm requests adopted only {saved_per_req} tokens"
        );
        shapes_json.push(
            Json::obj()
                .with("k_prefixes", Json::Num(k as f64))
                .with("requests", Json::Num(REQUESTS as f64))
                .with("prefix_tokens", Json::Num(PREFIX_TOKENS as f64))
                .with("suffix_tokens", Json::Num(SUFFIX_TOKENS as f64))
                .with("cold_ttft_us", Json::Num(cold_ttft_us))
                .with("warm_ttft_us", Json::Num(warm_ttft_us))
                .with("warm_speedup", Json::Num(speedup))
                .with("hit_rate", Json::Num(hit_rate))
                .with("saved_prefill_tokens_per_request", Json::Num(saved_per_req))
                .with(
                    "prefix_cache",
                    Json::obj()
                        .with("resident_bytes", Json::Num(after.resident_bytes as f64))
                        .with("resident_chunks", Json::Num(after.resident_chunks as f64))
                        .with("evicted_bytes", Json::Num(after.evicted_bytes as f64)),
                ),
        );
    }

    acceptance.set("warm_ttft_speedup", Json::Num(speedup_k1));
    acceptance.set("warm_ttft_target", Json::Num(2.0));
    println!("\nwarm vs cold TTFT @K=1: {speedup_k1:.2}x (target >= 2x)");
    if speedup_k1 < 2.0 {
        eprintln!("WARNING: warm-hit prefill less than 2x faster than cold on this host");
    }

    let mut report = Json::obj()
        .with("bench", Json::Str("perf_prefix".into()))
        .with("shapes", Json::Arr(shapes_json))
        .with("acceptance", acceptance.clone());
    lobcq::obs::report::stamp(&mut report);
    let path = std::path::Path::new("BENCH_prefix.json");
    report.to_file(path).expect("write BENCH_prefix.json");
    println!("\nreport written to {}", path.display());

    // Shared run-record (results/raw/) in the same schema the workload
    // harness emits, for report_generator.py consolidation.
    let rec = lobcq::bench::RunRecord::bench("prefix")
        .config(
            Json::obj()
                .with("prefix_tokens", Json::Num(PREFIX_TOKENS as f64))
                .with("suffix_tokens", Json::Num(SUFFIX_TOKENS as f64))
                .with("requests", Json::Num(REQUESTS as f64)),
        )
        .metric("warm_ttft_speedup", speedup_k1, lobcq::bench::Direction::Higher)
        .detail(report.clone());
    let rp = rec
        .write_into(&lobcq::bench::record::raw_dir(), "bench_prefix")
        .expect("write prefix run-record");
    println!("run-record written to {}", rp.display());
}
