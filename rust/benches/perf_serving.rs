//! §Perf serving SLO bench — emits `BENCH_serving.json`.
//!
//! Measures the **inter-token latency (ITL) tail of live decode lanes
//! when a long prompt lands mid-batch**, the stall chunked prefill
//! exists to bound. Scenario (CPU-feasible scaling of "a 4k prompt into
//! a live 8-lane batch"): 8 short-prompt requests fill every engine
//! lane; one retires early, freeing a lane for a 384-token prompt that
//! was waiting in the queue. Inline admission prefills all 384 tokens
//! in one scheduler iteration — every live lane's next token waits the
//! whole prefill. Chunked admission (`prefill_chunk = 16`) interleaves
//! one chunk per iteration with the fused decode step, so live lanes
//! stall at most one chunk.
//!
//! ITL is measured exactly: a wrapper engine timestamps the end of
//! every fused `decode_batch` call, and the gap between consecutive
//! step-ends — including any prefill work the scheduler interleaved —
//! is one per-step ITL sample for the lanes that were live.
//!
//! Both runs must produce token-identical output (the chunking seam is
//! bit-exact); the bench asserts that before timing means anything.
//!
//! Acceptance: `p99_itl_chunked_vs_inline` ≤ 0.5 — chunked admission
//! must at least halve the p99 ITL of the co-resident lanes (in
//! practice the ratio is ~chunk/prompt, far below the gate).

use lobcq::coordinator::{
    run_continuous_opts, BatchPolicy, Batcher, ContinuousOpts, DecodeEngine, DecodeSession, KvCacheOpts,
    PrefillProgress, Request, Response, Sampling,
};
use lobcq::data::corpus;
use lobcq::eval::Scheme;
use lobcq::kvcache::KvStats;
use lobcq::model::{ModelConfig, Weights};
use lobcq::prefixcache::PrefixStats;
use lobcq::quant::pipeline::QuantPool;
use lobcq::tensor::Tensor;
use lobcq::util::json::Json;
use lobcq::util::rng::Pcg32;
use std::time::{Duration, Instant};

const LANES: usize = 8;
const LONG_PROMPT: usize = 384;
const CHUNK: usize = 16;

/// Serving-shaped toy model (head_dim 64, BCQ-encoded KV).
fn model() -> (ModelConfig, Weights) {
    let cfg = ModelConfig {
        name: "serving-bench".into(),
        d: 128,
        n_layers: 2,
        n_heads: 2,
        vocab: corpus::VOCAB as usize,
        max_t: 512,
    };
    let mut rng = Pcg32::seeded(0x5E41);
    let mut tensors = std::collections::BTreeMap::new();
    for (name, shape) in cfg.param_shapes() {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = if name.ends_with(".g") {
            vec![1.0; n]
        } else if name.ends_with(".b") {
            vec![0.0; n]
        } else {
            (0..n).map(|_| rng.normal() * 0.05).collect()
        };
        tensors.insert(name, Tensor::new(&shape, data));
    }
    (cfg, Weights::new(tensors))
}

/// Delegating engine that timestamps every fused decode step: the gap
/// between consecutive step-ends is one ITL sample for the live lanes,
/// and it includes whatever prefill work the scheduler ran in between.
struct TimedEngine {
    inner: DecodeSession,
    last_step_end: Option<Instant>,
    gaps_us: Vec<f64>,
}

impl DecodeEngine for TimedEngine {
    fn max_concurrency(&self) -> usize {
        self.inner.max_concurrency()
    }
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }
    fn max_tokens(&self) -> usize {
        self.inner.max_tokens()
    }
    fn begin_prefill(&mut self, prompt: &[u32]) -> anyhow::Result<usize> {
        self.inner.begin_prefill(prompt)
    }
    fn prefill_chunk(&mut self, lane: usize, prompt: &[u32], max_tokens: usize) -> anyhow::Result<PrefillProgress> {
        self.inner.prefill_chunk(lane, prompt, max_tokens)
    }
    fn relieve_pressure(&mut self) -> usize {
        self.inner.relieve_pressure()
    }
    fn decode(&mut self, lane: usize, token: u32) -> anyhow::Result<Vec<f32>> {
        self.inner.decode(lane, token)
    }
    fn decode_batch(&mut self, lanes: &[usize], tokens: &[u32]) -> Vec<anyhow::Result<Vec<f32>>> {
        let out = self.inner.decode_batch(lanes, tokens);
        let end = Instant::now();
        if let Some(prev) = self.last_step_end {
            self.gaps_us.push((end - prev).as_secs_f64() * 1e6);
        }
        self.last_step_end = Some(end);
        out
    }
    fn release(&mut self, lane: usize) {
        self.inner.release(lane)
    }
    fn kv_stats(&self) -> Option<KvStats> {
        self.inner.kv_stats()
    }
    fn prefix_stats(&self) -> Option<PrefixStats> {
        self.inner.prefix_stats()
    }
}

/// 8 lane-filling decoders (one retires early, freeing a lane) plus the
/// long prompt waiting in the queue.
fn workload() -> Vec<(Vec<u32>, usize)> {
    let mut reqs = vec![(corpus::generate(0xA0, 8), 6)]; // early retirer
    for i in 1..LANES {
        reqs.push((corpus::generate(0xA0 + i as u64, 8), 32));
    }
    reqs.push((corpus::generate(0xBB, LONG_PROMPT), 4));
    reqs
}

struct RunResult {
    gaps_us: Vec<f64>, // sorted ascending
    tokens_by_id: Vec<(u64, Vec<u32>)>,
    wall_s: f64,
    total_tokens: usize,
}

fn run(cfg: &ModelConfig, w: &Weights, prefill_chunk: usize) -> RunResult {
    let kv = KvCacheOpts { page_tokens: 16, encoded: true, prefix_cache_bytes: None, page_budget: None };
    let session = DecodeSession::new(cfg.clone(), w, &Scheme::Bf16, QuantPool::serial(), LANES, kv).unwrap();
    let mut engine = TimedEngine { inner: session, last_step_end: None, gaps_us: Vec::new() };
    let b = Batcher::new(BatchPolicy { max_batch: LANES, max_wait: Duration::ZERO, queue_cap: None });
    for (i, (prompt, max_new)) in workload().into_iter().enumerate() {
        assert!(b.push(Request::new(i as u64 + 1, prompt, max_new)).is_accepted());
    }
    b.close();
    let mut out: Vec<(u64, anyhow::Result<Response>)> = Vec::new();
    let t0 = Instant::now();
    run_continuous_opts(
        &mut engine,
        &b,
        ContinuousOpts { prefill_chunk, ..ContinuousOpts::default() },
        Sampling::Greedy,
        None,
        |id, r| out.push((id, r)),
    );
    let wall_s = t0.elapsed().as_secs_f64();
    let mut tokens_by_id: Vec<(u64, Vec<u32>)> = out
        .into_iter()
        .map(|(id, r)| (id, r.expect("uncontended bench request failed").tokens))
        .collect();
    tokens_by_id.sort();
    let total_tokens = tokens_by_id.iter().map(|(_, t)| t.len()).sum();
    let mut gaps_us = engine.gaps_us;
    gaps_us.sort_by(|a, b| a.total_cmp(b));
    RunResult { gaps_us, tokens_by_id, wall_s, total_tokens }
}

fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * q).ceil() as usize]
}

fn stats_json(r: &RunResult) -> Json {
    Json::obj()
        .with("itl_p50_us", Json::Num(pct(&r.gaps_us, 0.50)))
        .with("itl_p99_us", Json::Num(pct(&r.gaps_us, 0.99)))
        .with("itl_max_us", Json::Num(pct(&r.gaps_us, 1.0)))
        .with("itl_samples", Json::Num(r.gaps_us.len() as f64))
        .with("wall_s", Json::Num(r.wall_s))
        .with("tokens", Json::Num(r.total_tokens as f64))
        .with("tok_per_s", Json::Num(r.total_tokens as f64 / r.wall_s))
}

fn main() {
    let (cfg, w) = model();
    let _ = w.packed_transposed("embed"); // pre-warm the shared LM-head panel
    println!(
        "# perf_serving — live-lane ITL while a {LONG_PROMPT}-token prompt lands in an \
         {LANES}-lane batch: inline vs chunked ({CHUNK}-token) prefill\n"
    );

    let inline = run(&cfg, &w, usize::MAX);
    let chunked = run(&cfg, &w, CHUNK);

    // Parity gate: chunking is a latency knob, never an output knob.
    assert_eq!(
        inline.tokens_by_id, chunked.tokens_by_id,
        "chunked prefill changed decoded tokens — the seam is not bit-exact"
    );

    let inline_p99 = pct(&inline.gaps_us, 0.99);
    let chunked_p99 = pct(&chunked.gaps_us, 0.99);
    let ratio = chunked_p99 / inline_p99;
    println!(
        "inline : p50 {:8.0}µs  p99 {:8.0}µs  max {:8.0}µs  ({} steps, {:.1} tok/s)",
        pct(&inline.gaps_us, 0.5),
        inline_p99,
        pct(&inline.gaps_us, 1.0),
        inline.gaps_us.len(),
        inline.total_tokens as f64 / inline.wall_s,
    );
    println!(
        "chunked: p50 {:8.0}µs  p99 {:8.0}µs  max {:8.0}µs  ({} steps, {:.1} tok/s)",
        pct(&chunked.gaps_us, 0.5),
        chunked_p99,
        pct(&chunked.gaps_us, 1.0),
        chunked.gaps_us.len(),
        chunked.total_tokens as f64 / chunked.wall_s,
    );
    println!("\np99 ITL chunked/inline: {ratio:.3} (target <= 0.5)");
    if ratio > 0.5 {
        eprintln!("WARNING: chunked prefill did not halve the p99 ITL on this host");
    }

    let mut report = Json::obj()
        .with("bench", Json::Str("perf_serving".into()))
        .with(
            "scenario",
            Json::obj()
                .with("lanes", Json::Num(LANES as f64))
                .with("long_prompt_tokens", Json::Num(LONG_PROMPT as f64))
                .with("prefill_chunk", Json::Num(CHUNK as f64))
                .with("kv_store", Json::Str("bcq".into())),
        )
        .with("inline", stats_json(&inline))
        .with("chunked", stats_json(&chunked))
        .with(
            "acceptance",
            Json::obj()
                .with("p99_itl_chunked_vs_inline", Json::Num(ratio))
                .with("p99_itl_target", Json::Num(0.5)),
        );
    lobcq::obs::report::stamp(&mut report);
    let path = std::path::Path::new("BENCH_serving.json");
    report.to_file(path).expect("write BENCH_serving.json");
    println!("report written to {}", path.display());

    // Shared run-record (results/raw/) in the same schema the workload
    // harness emits, for report_generator.py consolidation.
    use lobcq::bench::Direction;
    let rec = lobcq::bench::RunRecord::bench("serving")
        .config(
            Json::obj()
                .with("lanes", Json::Num(LANES as f64))
                .with("long_prompt_tokens", Json::Num(LONG_PROMPT as f64))
                .with("prefill_chunk", Json::Num(CHUNK as f64))
                .with("kv", Json::Str("bcq".into())),
        )
        .metric("p99_itl_chunked_vs_inline", ratio, Direction::Lower)
        .metric("chunked_p99_itl_us", chunked_p99, Direction::Lower)
        .metric("chunked_tok_per_s", chunked.total_tokens as f64 / chunked.wall_s, Direction::Higher)
        .detail(report.clone());
    let rp = rec
        .write_into(&lobcq::bench::record::raw_dir(), "bench_serving")
        .expect("write serving run-record");
    println!("run-record written to {}", rp.display());
}
