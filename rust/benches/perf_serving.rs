//! §Perf L3 end-to-end: serving latency/throughput through the full
//! coordinator (router → batcher → PJRT W4A4 artifact), comparing the
//! BF16 and LO-BCQ variants and several batching policies. Skips with a
//! notice when artifacts are missing. Results → EXPERIMENTS.md §Perf.

use lobcq::coordinator::{BatchPolicy, Limits, PjrtExecutor, Sampling, Server};
use lobcq::data::corpus;
use lobcq::eval::Env;
use lobcq::model::Weights;
use lobcq::runtime::{Manifest, RuntimeService};
use lobcq::tensor::Tensor;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP perf_serving: run `make artifacts` first");
        return;
    }
    let quick = std::env::var("LOBCQ_BENCH_FULL").map(|v| v != "1").unwrap_or(true);
    let n_requests = if quick { 32 } else { 128 };

    let manifest = Manifest::load(dir).expect("manifest");
    let env = Env::load();
    println!("# perf_serving — coordinator end-to-end (model m, {n_requests} requests × 4 new tokens)\n");

    for (variant, label) in [("bf16", "BF16"), ("lobcq_g64_nc8", "LO-BCQ W4A4 (g64, Nc=8)")] {
        for max_batch in [1usize, 8] {
            let Some(entry) = manifest.find("m", variant, max_batch).cloned() else {
                continue;
            };
            let service = RuntimeService::start(dir).expect("runtime");
            let client = service.client();
            let cfg = env.model_config("m").unwrap();
            let weights = Weights::load(&manifest.weights_path("m").unwrap()).unwrap();
            let ordered: Vec<Tensor> = weights.ordered(&cfg).unwrap().into_iter().cloned().collect();
            client.register_weights("w", &cfg, ordered).unwrap();
            let books_key = entry.books_nc.map(|nc| {
                let fam = env.family(nc, 4, 6).unwrap();
                client.register_books("books", Env::books_tensor(&fam)).unwrap();
                "books".to_string()
            });
            let exec = PjrtExecutor {
                client,
                entry: entry.clone(),
                weights_key: "w".into(),
                books_key,
                vocab: manifest.vocab,
            };
            let server = Arc::new(Server::start(
                exec,
                BatchPolicy { max_batch, max_wait: Duration::from_millis(4) },
                Limits { max_prompt: 64, max_new: 16, vocab: manifest.vocab as u32 },
                Sampling::Greedy,
            ));

            let t0 = Instant::now();
            let mut handles = Vec::new();
            for i in 0..n_requests {
                let s = server.clone();
                handles.push(std::thread::spawn(move || {
                    let prompt = corpus::generate(7_000 + i as u64, 16);
                    s.submit(prompt, 4).unwrap().wait().unwrap()
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            let wall = t0.elapsed().as_secs_f64();
            let snap = server.metrics.snapshot();
            println!(
                "{label:<28} batch≤{max_batch}: {:.1} req/s, {:.1} tok/s | {}",
                n_requests as f64 / wall,
                snap.tokens as f64 / wall,
                snap.report()
            );
            if let Ok(s) = Arc::try_unwrap(server) {
                s.shutdown();
            }
        }
    }
}
