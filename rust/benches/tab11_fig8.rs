//! Regenerates Table 11 + Figure 8 (per-tensor FP vs Lloyd-Max).
fn main() {
    lobcq::eval::experiments::bench_entry("tab11");
}
