//! Deterministic expansion of a [`WorkloadSpec`] into a timed request
//! trace.
//!
//! Same spec + same seed ⇒ byte-identical trace (prompts, arrival
//! offsets, generation budgets), every time, on every host — enforced
//! by `tests/workload_harness.rs`. All randomness flows through one
//! seeded [`Pcg32`] on a dedicated stream, and prompt token content
//! comes from the shared `data::corpus` generators so workload traffic
//! is drawn from the same distribution the parity tests and benches
//! already use.

use super::spec::{ArrivalKind, WorkloadSpec};
use crate::data::corpus;
use crate::util::rng::Pcg32;

/// RNG stream id for trace expansion (disjoint from the corpus
/// streams so a workload seed never aliases a corpus seed).
const TRACE_STREAM: u64 = 0xBE4C;

/// One request in a trace: when it arrives and what it asks for.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TimedRequest {
    /// Arrival offset from run start, in microseconds. Zero for every
    /// request under closed-loop arrivals (clients re-submit on
    /// completion instead of on a clock).
    pub at_us: u64,
    /// Prompt token ids (corpus vocabulary; the runner folds them into
    /// the serving model's vocab).
    pub prompt: Vec<u32>,
    /// Generation budget in tokens.
    pub max_new: usize,
    /// Index of the shared system prompt this request extends, when
    /// the spec declares `prefix_k > 0`.
    pub prefix_id: Option<usize>,
}

/// A fully expanded workload: the requests plus a content fingerprint
/// that run-records carry so two runs can be checked for having
/// served the identical trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestTrace {
    pub requests: Vec<TimedRequest>,
    /// FNV-1a over every request's `(at_us, max_new, prompt)`.
    pub fingerprint: u64,
}

impl RequestTrace {
    pub fn total_prompt_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.prompt.len()).sum()
    }

    pub fn total_gen_budget(&self) -> usize {
        self.requests.iter().map(|r| r.max_new).sum()
    }
}

fn fnv_fold(h: &mut u64, x: u64) {
    *h ^= x;
    *h = h.wrapping_mul(0x100000001B3);
}

fn trace_fingerprint(requests: &[TimedRequest]) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for r in requests {
        fnv_fold(&mut h, r.at_us);
        fnv_fold(&mut h, r.max_new as u64);
        for &t in &r.prompt {
            fnv_fold(&mut h, t as u64);
        }
        // Separator keeps (len-3 prompt, len-2 prompt) distinct from
        // (len-2, len-3) splits of the same token stream.
        fnv_fold(&mut h, u64::MAX);
    }
    h
}

/// Arrival offsets for `n` requests under the spec's arrival pattern.
fn arrivals(spec: &WorkloadSpec, n: usize, rng: &mut Pcg32) -> Vec<u64> {
    match spec.arrival {
        ArrivalKind::Closed => vec![0; n],
        ArrivalKind::Poisson => {
            // Exponential inter-arrival gaps at rate_rps, cumulated.
            let mut t_us = 0.0f64;
            (0..n)
                .map(|_| {
                    let u = rng.next_f64().min(1.0 - 1e-12);
                    t_us += -(1.0 - u).ln() * 1e6 / spec.rate_rps;
                    t_us as u64
                })
                .collect()
        }
        ArrivalKind::Bursty => (0..n)
            .map(|i| (i / spec.burst_size) as u64 * spec.burst_gap_ms * 1000)
            .collect(),
    }
}

/// Expand `spec` into its request trace. Draw order is fixed —
/// arrivals, then per-request (prefix choice, prompt length,
/// generation length) — so adding requests never perturbs earlier
/// ones' arrival clock.
pub fn expand(spec: &WorkloadSpec) -> anyhow::Result<RequestTrace> {
    spec.validate()?;
    let mut rng = Pcg32::new(spec.seed, TRACE_STREAM);
    let at = arrivals(spec, spec.requests, &mut rng);

    // Shared system prompts, when the spec asks for prefix sharing.
    let prefixes: Vec<Vec<u32>> = (0..spec.prefix_k)
        .map(|j| corpus::generate(spec.seed ^ (0x5151 + j as u64), spec.prefix_len))
        .collect();

    let mut requests = Vec::with_capacity(spec.requests);
    for (i, &at_us) in at.iter().enumerate() {
        let plen = spec.prompt_len.sample(&mut rng);
        let max_new = spec.gen_len.sample(&mut rng);
        let (prompt, prefix_id) = if spec.prefix_k > 0 {
            let j = rng.index(spec.prefix_k);
            let mut prompt = prefixes[j].clone();
            // validate() guarantees plen > prefix_len, so every request
            // keeps a non-empty unique suffix past its system prompt.
            let suffix = corpus::unique_prompt(spec.seed, i, plen - spec.prefix_len + 1);
            prompt.extend_from_slice(&suffix[1..]); // skip the generator's BOS
            (prompt, Some(j))
        } else if spec.repetitive {
            (corpus::repetitive(spec.seed ^ ((i as u64) << 8), spec.repeat_period, plen), None)
        } else {
            (corpus::unique_prompt(spec.seed, i, plen), None)
        };
        requests.push(TimedRequest { at_us, prompt, max_new, prefix_id });
    }
    let fingerprint = trace_fingerprint(&requests);
    Ok(RequestTrace { requests, fingerprint })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::spec::LenDist;

    fn spec(text: &str) -> WorkloadSpec {
        WorkloadSpec::parse(text).unwrap()
    }

    #[test]
    fn same_seed_same_trace() {
        let s = spec("requests = 12\narrival = poisson\nrate_rps = 500\nprompt_len = 8..24\ngen_len = 2..6");
        let a = expand(&s).unwrap();
        let b = expand(&s).unwrap();
        assert_eq!(a, b);
        let mut s2 = s.clone();
        s2.seed += 1;
        let c = expand(&s2).unwrap();
        assert_ne!(a.fingerprint, c.fingerprint);
    }

    #[test]
    fn closed_loop_arrivals_are_all_zero() {
        let t = expand(&spec("requests = 8")).unwrap();
        assert!(t.requests.iter().all(|r| r.at_us == 0));
    }

    #[test]
    fn poisson_arrivals_nondecreasing_and_rate_scaled() {
        let t = expand(&spec("requests = 64\narrival = poisson\nrate_rps = 1000")).unwrap();
        let at: Vec<u64> = t.requests.iter().map(|r| r.at_us).collect();
        assert!(at.windows(2).all(|w| w[0] <= w[1]), "arrivals must be sorted");
        // 64 arrivals at 1000 rps ⇒ mean span ~64 ms; allow wide slack.
        let span_ms = *at.last().unwrap() as f64 / 1000.0;
        assert!((10.0..400.0).contains(&span_ms), "span {span_ms} ms implausible for 1000 rps");
    }

    #[test]
    fn bursty_arrivals_group_into_bursts() {
        let t = expand(&spec("requests = 10\narrival = bursty\nburst_size = 4\nburst_gap_ms = 20")).unwrap();
        let at: Vec<u64> = t.requests.iter().map(|r| r.at_us).collect();
        assert_eq!(&at[..4], &[0, 0, 0, 0]);
        assert_eq!(&at[4..8], &[20_000; 4]);
        assert_eq!(&at[8..], &[40_000, 40_000]);
    }

    #[test]
    fn length_distributions_hit_their_bounds() {
        let s = spec("requests = 200\nprompt_len = 8..12\ngen_len = 2..4");
        let t = expand(&s).unwrap();
        let mut seen_plen = std::collections::BTreeSet::new();
        for r in &t.requests {
            assert!((8..=12).contains(&r.prompt.len()), "prompt len {}", r.prompt.len());
            assert!((2..=4).contains(&r.max_new), "gen len {}", r.max_new);
            seen_plen.insert(r.prompt.len());
        }
        // 200 draws over 5 lengths must cover the extremes.
        assert!(seen_plen.contains(&8) && seen_plen.contains(&12), "bounds never drawn: {seen_plen:?}");
    }

    #[test]
    fn fixed_lengths_are_exact() {
        let s = spec("requests = 6\nprompt_len = 16\ngen_len = 5");
        assert_eq!(s.prompt_len, LenDist::Fixed(16));
        for r in &expand(&s).unwrap().requests {
            assert_eq!(r.prompt.len(), 16);
            assert_eq!(r.max_new, 5);
        }
    }

    #[test]
    fn prefix_sharing_shares_exact_prefixes() {
        let t = expand(&spec("requests = 24\nprefix_k = 3\nprefix_len = 8\nprompt_len = 16")).unwrap();
        let mut used = [false; 3];
        let mut by_prefix: std::collections::BTreeMap<usize, Vec<&Vec<u32>>> = Default::default();
        for r in &t.requests {
            let j = r.prefix_id.expect("prefix workload must tag requests");
            used[j] = true;
            by_prefix.entry(j).or_default().push(&r.prompt);
        }
        assert!(used.iter().filter(|&&u| u).count() >= 2, "sampler never varied its prefix");
        for (_, prompts) in by_prefix {
            for w in prompts.windows(2) {
                assert_eq!(&w[0][..8], &w[1][..8], "same prefix id, different system prompt");
            }
            if prompts.len() >= 2 {
                assert_ne!(prompts[0], prompts[1], "suffixes not unique");
            }
        }
    }

    #[test]
    fn repetitive_prompts_are_periodic() {
        let t = expand(&spec("requests = 4\nrepetitive = true\nrepeat_period = 6\nprompt_len = 30")).unwrap();
        for r in &t.requests {
            for i in 1..r.prompt.len() - 6 {
                assert_eq!(r.prompt[i], r.prompt[i + 6], "aperiodic at {i}");
            }
        }
    }
}
