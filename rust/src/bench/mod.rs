//! Declarative workload harness + consolidated perf reporting
//! (DESIGN.md §Workload harness).
//!
//! Turns the bespoke per-bench sweeps into one corpus of **versioned
//! run-records**:
//!
//! - [`spec`] — a [`spec::WorkloadSpec`] parsed from a simple
//!   `key = value` file (`workloads/*.toml`): lanes, arrival pattern
//!   (closed-loop / open-loop Poisson / bursty), prompt/gen length
//!   distributions, prefix-sharing K, KV mode (`bcq`|`f32`), weight
//!   mode (`encoded`|`dense`), speculation (`spec_k`/drafter), seed.
//! - [`factory`] — deterministically expands a spec into a timed
//!   request trace ([`factory::RequestTrace`]): same spec + seed ⇒
//!   byte-identical prompts and arrival offsets, every time.
//! - [`record`] — the shared run-record schema
//!   ([`record::SCHEMA`]/[`record::SCHEMA_VERSION`]): one JSON per run
//!   carrying the resolved config, a flat `summary` of headline
//!   metrics (each tagged with its better-direction), the full
//!   `ServerMetrics` snapshot where one exists, `obs::quant_stats`
//!   NMSE telemetry, and the `obs::report` stamp
//!   (system/kernel backend/git rev/registry).
//! - [`runner`] — builds a server from a spec, drives the trace
//!   through `Server::submit_with`, and sweeps one key over a value
//!   list (`lobcq bench --workload <spec> --sweep key=v1,v2,…`),
//!   emitting one run-record per point into `results/raw/`.
//!
//! `python/report_generator.py` consolidates `results/raw/*.json`
//! into one comparison table and gates regressions against the
//! checked-in `results/baseline/` snapshot.

pub mod factory;
pub mod record;
pub mod runner;
pub mod spec;

pub use factory::{expand, RequestTrace, TimedRequest};
pub use record::{Direction, RunRecord};
pub use runner::{run_sweep, run_workload, DriveStats, SweepSpec};
pub use spec::{ArrivalKind, KvMode, LenDist, WeightMode, WorkloadSpec};
