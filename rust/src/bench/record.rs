//! The shared run-record schema (DESIGN.md §Workload harness).
//!
//! Every perf measurement in the repo — workload runs from the sweep
//! runner and the four `perf_*` benches — lands as one JSON document
//! of this shape, so `python/report_generator.py` can consolidate them
//! into a single trajectory:
//!
//! ```text
//! {
//!   "schema": "lobcq-run-record", "schema_version": 1,
//!   "kind": "workload" | "bench",
//!   "name": "steady-decode",
//!   "config": { flat scalars — the grouping key for baselines },
//!   "summary": { "tok_per_s": {"value": 812.0, "dir": "higher"},
//!                "p99_itl_us": {"value": 1500.0, "dir": "lower"}, … },
//!   "server":  <ServerMetrics::to_json() snapshot>      (optional),
//!   "quant":   <obs::quant_stats snapshot>              (optional),
//!   "detail":  { bench-specific sections, free-form }   (optional),
//!   "system"/"kernel_backend"/"git_rev"/"metrics"/"trace_dropped":
//!       the obs::report::stamp block
//! }
//! ```
//!
//! `summary` metrics carry their better-direction inline so the report
//! generator never needs a hard-coded metric table; `config` is flat
//! (strings/numbers/bools only) so workload×config grouping is a plain
//! string join. Bump [`SCHEMA_VERSION`] on any incompatible change —
//! the report generator refuses records from the future.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

pub const SCHEMA: &str = "lobcq-run-record";
pub const SCHEMA_VERSION: u64 = 1;

/// Which way a metric is better.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Higher,
    Lower,
}

impl Direction {
    pub fn name(self) -> &'static str {
        match self {
            Direction::Higher => "higher",
            Direction::Lower => "lower",
        }
    }
}

/// Builder for one run-record. Assemble sections, then [`RunRecord::to_json`]
/// (pure — for determinism tests) or [`RunRecord::write`] (stamps and
/// persists).
#[derive(Debug, Clone)]
pub struct RunRecord {
    kind: &'static str,
    name: String,
    config: Json,
    summary: Json,
    server: Option<Json>,
    quant: Option<Json>,
    detail: Option<Json>,
}

impl RunRecord {
    /// A record for a declarative workload run.
    pub fn workload(name: &str) -> RunRecord {
        Self::new("workload", name)
    }

    /// A record for a `perf_*` bench.
    pub fn bench(name: &str) -> RunRecord {
        Self::new("bench", name)
    }

    fn new(kind: &'static str, name: &str) -> RunRecord {
        RunRecord {
            kind,
            name: name.to_string(),
            config: Json::obj(),
            summary: Json::obj(),
            server: None,
            quant: None,
            detail: None,
        }
    }

    /// Set the whole config object (must be a flat JSON object).
    pub fn config(mut self, config: Json) -> RunRecord {
        self.config = config;
        self
    }

    /// Add one config key (benches build their config incrementally).
    pub fn config_kv(mut self, key: &str, value: Json) -> RunRecord {
        self.config.set(key, value);
        self
    }

    /// Add one headline metric with its better-direction.
    pub fn metric(mut self, name: &str, value: f64, dir: Direction) -> RunRecord {
        self.summary.set(
            name,
            Json::obj().with("dir", Json::Str(dir.name().into())).with("value", Json::Num(value)),
        );
        self
    }

    pub fn server(mut self, snapshot: Json) -> RunRecord {
        self.server = Some(snapshot);
        self
    }

    pub fn quant(mut self, snapshot: Json) -> RunRecord {
        self.quant = Some(snapshot);
        self
    }

    pub fn detail(mut self, detail: Json) -> RunRecord {
        self.detail = Some(detail);
        self
    }

    /// The record body, without the environment stamp — byte-identical
    /// for identical inputs (what the determinism tests compare).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .with("schema", Json::Str(SCHEMA.into()))
            .with("schema_version", Json::Num(SCHEMA_VERSION as f64))
            .with("kind", Json::Str(self.kind.into()))
            .with("name", Json::Str(self.name.clone()))
            .with("config", self.config.clone())
            .with("summary", self.summary.clone());
        if let Some(s) = &self.server {
            j.set("server", s.clone());
        }
        if let Some(q) = &self.quant {
            j.set("quant", q.clone());
        }
        if let Some(d) = &self.detail {
            j.set("detail", d.clone());
        }
        j
    }

    /// Stamp with `obs::report::stamp` and write to `path`
    /// (parent directories are created).
    pub fn write(&self, path: &Path) -> anyhow::Result<()> {
        let mut j = self.to_json();
        crate::obs::report::stamp(&mut j);
        validate(&j).map_err(|e| anyhow::anyhow!("refusing to write malformed record: {e}"))?;
        j.to_file(path)
    }

    /// Stamp and write into `dir` under `<slug>.json`; returns the path.
    pub fn write_into(&self, dir: &Path, slug: &str) -> anyhow::Result<PathBuf> {
        let path = dir.join(format!("{}.json", sanitize(slug)));
        self.write(&path)?;
        Ok(path)
    }
}

/// Where run-records land by default: `results/raw/`, overridable via
/// `LOBCQ_RAW_DIR` (the CI smoke leg points benches and workload runs
/// at a scratch directory this way).
pub fn raw_dir() -> PathBuf {
    std::env::var("LOBCQ_RAW_DIR").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("results/raw"))
}

/// Filesystem-safe slug: alnum kept, everything else folded to `-`
/// (runs collapsed, edges trimmed). `_` is kept so the runner's
/// `name__key-value` convention survives.
pub fn sanitize(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut last_dash = true; // trim leading dashes
    for c in s.chars() {
        if c.is_ascii_alphanumeric() || c == '_' || c == '.' {
            out.push(c);
            last_dash = false;
        } else if !last_dash {
            out.push('-');
            last_dash = true;
        }
    }
    while out.ends_with('-') {
        out.pop();
    }
    if out.is_empty() {
        out.push_str("run");
    }
    out
}

/// Structural schema check — shared by the writer (refuses to emit a
/// malformed record) and the harness tests (assert every sweep output
/// round-trips).
pub fn validate(j: &Json) -> Result<(), String> {
    let schema =
        j.opt("schema").and_then(|s| s.as_str().ok()).ok_or_else(|| "missing schema".to_string())?;
    if schema != SCHEMA {
        return Err(format!("schema '{schema}' != '{SCHEMA}'"));
    }
    let version = j
        .opt("schema_version")
        .and_then(|v| v.as_u64().ok())
        .ok_or_else(|| "missing schema_version".to_string())?;
    if version != SCHEMA_VERSION {
        return Err(format!("schema_version {version} != {SCHEMA_VERSION}"));
    }
    let kind = j.opt("kind").and_then(|s| s.as_str().ok()).ok_or_else(|| "missing kind".to_string())?;
    if kind != "workload" && kind != "bench" {
        return Err(format!("kind '{kind}' not workload|bench"));
    }
    match j.opt("name").and_then(|s| s.as_str().ok()) {
        Some(n) if !n.is_empty() => {}
        _ => return Err("missing name".into()),
    }
    match j.get("config") {
        Ok(Json::Obj(_)) => {}
        _ => return Err("config must be an object".into()),
    }
    let summary = match j.get("summary") {
        Ok(Json::Obj(m)) => m,
        _ => return Err("summary must be an object".into()),
    };
    for (k, v) in summary {
        let value = v.opt("value").and_then(|x| x.as_f64().ok());
        let dir = v.opt("dir").and_then(|x| x.as_str().ok());
        if value.is_none() || !matches!(dir, Some("higher") | Some("lower")) {
            return Err(format!("summary metric '{k}' needs {{value, dir: higher|lower}}"));
        }
    }
    for key in ["system", "kernel_backend", "git_rev", "trace_dropped"] {
        if j.get(key).is_err() {
            return Err(format!("missing stamp key '{key}'"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunRecord {
        RunRecord::workload("steady-decode")
            .config(Json::obj().with("lanes", Json::Num(4.0)))
            .metric("tok_per_s", 812.5, Direction::Higher)
            .metric("p99_itl_us", 1500.0, Direction::Lower)
            .server(Json::obj().with("requests", Json::Num(16.0)))
    }

    #[test]
    fn body_is_deterministic_and_stamped_record_validates() {
        assert_eq!(sample().to_json().to_string_compact(), sample().to_json().to_string_compact());
        let mut j = sample().to_json();
        assert!(validate(&j).is_err(), "unstamped record must not validate");
        crate::obs::report::stamp(&mut j);
        validate(&j).unwrap();
        // Round-trips through text.
        validate(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
    }

    #[test]
    fn validate_rejects_malformed() {
        let mut j = sample().to_json();
        crate::obs::report::stamp(&mut j);
        let mut wrong_ver = j.clone();
        wrong_ver.set("schema_version", Json::Num(99.0));
        assert!(validate(&wrong_ver).is_err());
        let mut wrong_kind = j.clone();
        wrong_kind.set("kind", Json::Str("vibes".into()));
        assert!(validate(&wrong_kind).is_err());
        let mut bad_metric = j.clone();
        bad_metric.set("summary", Json::obj().with("x", Json::obj().with("value", Json::Num(1.0))));
        assert!(validate(&bad_metric).is_err(), "metric without dir must fail");
    }

    #[test]
    fn sanitize_makes_safe_slugs() {
        assert_eq!(sanitize("steady-decode__lanes-4"), "steady-decode__lanes-4");
        assert_eq!(sanitize("a b/c..8"), "a-b-c..8");
        assert_eq!(sanitize("--weird--"), "weird");
        assert_eq!(sanitize("///"), "run");
    }
}
