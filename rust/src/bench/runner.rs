//! Workload execution + sweep orchestration.
//!
//! [`build_server`] turns a [`WorkloadSpec`] into a live continuous
//! decode server (same construction path as `lobcq serve-cpu`, just
//! spec-driven); [`drive`] plays a [`RequestTrace`] into it honouring
//! the arrival pattern — closed-loop clients or open-loop timed
//! submits; [`run_workload`] composes the two and emits one stamped
//! run-record; [`run_sweep`] repeats that for every value of a swept
//! key (`lobcq bench --workload <spec> --sweep key=v1,v2,…`).

use super::factory::{expand, RequestTrace};
use super::record::{sanitize, Direction, RunRecord};
use super::spec::{ArrivalKind, WeightMode, WorkloadSpec};
use crate::coordinator::{
    BatchPolicy, ContinuousOpts, DecodeSession, DrafterKind, KvCacheOpts, Limits, Priority, Sampling,
    Server,
};
use crate::data::corpus;
use crate::eval::Env;
use crate::quant::pipeline::QuantPool;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Deterministic random tiny-GPT over the corpus vocab — the model
/// every artifact-less run (workloads, `serve-cpu`, CI smoke) serves.
pub fn demo_model() -> (crate::model::ModelConfig, crate::model::Weights) {
    let cfg = crate::model::ModelConfig {
        name: "cpu-demo".into(),
        d: 64,
        n_layers: 2,
        n_heads: 2,
        vocab: corpus::VOCAB as usize,
        max_t: 64,
    };
    let mut rng = Pcg32::seeded(0xCDE);
    let mut tensors = std::collections::BTreeMap::new();
    for (name, shape) in cfg.param_shapes() {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = if name.ends_with(".g") {
            vec![1.0; n]
        } else if name.ends_with(".b") {
            vec![0.0; n]
        } else {
            (0..n).map(|_| rng.normal() * 0.05).collect()
        };
        tensors.insert(name, crate::tensor::Tensor::new(&shape, data));
    }
    (cfg, crate::model::Weights::new(tensors))
}

/// Build the continuous-engine server a spec describes. Artifacts are
/// used when present under `artifacts`; otherwise the [`demo_model`]
/// serves. Returns the server and its vocab (prompts are folded into
/// it at submit time).
pub fn build_server(spec: &WorkloadSpec, artifacts: &Path) -> anyhow::Result<(Server, u32)> {
    spec.validate()?;
    let env = Env::load_from(artifacts.to_path_buf());
    let scheme = match spec.weights {
        // Encoded-domain W4A4 qgemm over packed BCQ codes.
        WeightMode::Encoded => env.lobcq(8, 8, 64)?,
        // Dense f32 GEMM reference path.
        WeightMode::Dense => crate::eval::Scheme::Bf16,
    };
    let (cfg, weights) = match (env.model_config("s"), env.weights("s")) {
        (Ok(c), Ok(w)) => (c, w),
        _ => demo_model(),
    };
    let max_prompt = cfg.max_t.saturating_sub(1);
    anyhow::ensure!(
        spec.prompt_len.max() <= max_prompt,
        "workload '{}': prompt_len max {} exceeds the model's prompt budget {} (max_t {})",
        spec.name,
        spec.prompt_len.max(),
        max_prompt,
        cfg.max_t
    );
    let vocab = cfg.vocab as u32;
    let kv = KvCacheOpts {
        page_tokens: spec.page_tokens,
        encoded: spec.kv.encoded(),
        prefix_cache_bytes: spec.prefix_cache_bytes,
        page_budget: (spec.kv_pages > 0).then_some(spec.kv_pages),
    };
    let session =
        DecodeSession::new(cfg.clone(), &weights, &scheme, QuantPool::default(), spec.lanes, kv)?;
    let server = Server::start_continuous_with(
        session,
        Limits { max_prompt, max_new: spec.gen_len.max().max(1), vocab },
        Sampling::Greedy,
        BatchPolicy {
            max_batch: spec.lanes,
            max_wait: Duration::from_millis(spec.max_wait_ms),
            queue_cap: (spec.queue_cap > 0).then_some(spec.queue_cap),
        },
        ContinuousOpts {
            prefill_chunk: if spec.prefill_chunk == 0 { usize::MAX } else { spec.prefill_chunk },
            spec_k: spec.spec_k,
            drafter: DrafterKind::parse(&spec.drafter)?,
        },
    );
    Ok((server, vocab))
}

/// Outcome counts from driving one trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriveStats {
    /// Requests that completed with a response.
    pub ok: usize,
    /// Requests rejected at admission or shed/failed before completing.
    pub failed: usize,
    /// Wall-clock for the whole trace, seconds.
    pub wall_s: f64,
}

/// Play `trace` into `server`. Closed-loop arrivals run `spec.lanes`
/// client threads that each submit their next request the moment the
/// previous one finishes; open-loop arrivals (poisson/bursty) give
/// every request its own thread that submits at its trace offset
/// regardless of completions — the load keeps coming when the server
/// falls behind, which is the point.
pub fn drive(server: &Server, trace: &RequestTrace, spec: &WorkloadSpec, vocab: u32) -> DriveStats {
    let deadline = (spec.deadline_ms > 0).then(|| Duration::from_millis(spec.deadline_ms));
    let submit = |r: &super::factory::TimedRequest| -> bool {
        let prompt: Vec<u32> = r.prompt.iter().map(|&x| x % vocab).collect();
        match server.submit_with(prompt, r.max_new, Priority::Normal, deadline) {
            Ok(ticket) => ticket.wait().is_ok(),
            Err(_) => false,
        }
    };
    let t0 = Instant::now();
    let ok = AtomicUsize::new(0);
    match spec.arrival {
        ArrivalKind::Closed => {
            let next = AtomicUsize::new(0);
            std::thread::scope(|s| {
                for _ in 0..spec.lanes.min(trace.requests.len()) {
                    s.spawn(|| loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        let Some(r) = trace.requests.get(i) else { break };
                        if submit(r) {
                            ok.fetch_add(1, Ordering::Relaxed);
                        }
                    });
                }
            });
        }
        ArrivalKind::Poisson | ArrivalKind::Bursty => {
            std::thread::scope(|s| {
                for r in &trace.requests {
                    s.spawn(|| {
                        let due = Duration::from_micros(r.at_us);
                        if let Some(wait) = due.checked_sub(t0.elapsed()) {
                            std::thread::sleep(wait);
                        }
                        if submit(r) {
                            ok.fetch_add(1, Ordering::Relaxed);
                        }
                    });
                }
            });
        }
    }
    let ok = ok.into_inner();
    DriveStats { ok, failed: trace.requests.len() - ok, wall_s: t0.elapsed().as_secs_f64() }
}

/// Run one workload end-to-end and write its stamped run-record as
/// `<out_dir>/<slug>.json`. Quant telemetry is reset at entry so the
/// record's NMSE section reflects this run alone.
pub fn run_workload(
    spec: &WorkloadSpec,
    artifacts: &Path,
    out_dir: &Path,
    slug: &str,
) -> anyhow::Result<PathBuf> {
    crate::obs::quant_stats::enable();
    crate::obs::quant_stats::reset();
    let trace = expand(spec)?;
    let (server, vocab) = build_server(spec, artifacts)?;
    let stats = drive(&server, &trace, spec, vocab);
    let snapshot = server.metrics.snapshot();
    server.shutdown();

    let ok_rate =
        if trace.requests.is_empty() { 0.0 } else { stats.ok as f64 / trace.requests.len() as f64 };
    let record = RunRecord::workload(&spec.name)
        .config(
            spec.to_config_json()
                // u64 fingerprints exceed f64-exact range; carry as text.
                .with("trace_fingerprint", Json::Str(trace.fingerprint.to_string())),
        )
        .metric("tok_per_s", snapshot.tokens_per_s, Direction::Higher)
        .metric("ttft_p99_us", snapshot.ttft_p99_us, Direction::Lower)
        .metric("itl_p50_us", snapshot.itl_p50_us, Direction::Lower)
        .metric("itl_p99_us", snapshot.itl_p99_us, Direction::Lower)
        .metric("total_p95_us", snapshot.total_p95_us, Direction::Lower)
        .metric("ok_rate", ok_rate, Direction::Higher)
        .server(snapshot.to_json())
        .quant(crate::obs::quant_stats::snapshot_json())
        .detail(
            Json::obj()
                .with("ok", Json::Num(stats.ok as f64))
                .with("failed", Json::Num(stats.failed as f64))
                .with("wall_s", Json::Num(stats.wall_s))
                .with("trace_requests", Json::Num(trace.requests.len() as f64))
                .with("trace_prompt_tokens", Json::Num(trace.total_prompt_tokens() as f64))
                .with("trace_gen_budget", Json::Num(trace.total_gen_budget() as f64)),
        );
    let path = record.write_into(out_dir, slug)?;
    crate::log_info!(
        "[workload {}] {} ok / {} failed in {:.2}s — {:.1} tok/s → {}",
        spec.name,
        stats.ok,
        stats.failed,
        stats.wall_s,
        snapshot.tokens_per_s,
        path.display()
    );
    Ok(path)
}

/// One swept key and the values to run it at, from `--sweep key=v1,v2,…`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SweepSpec {
    pub key: String,
    pub values: Vec<String>,
}

impl SweepSpec {
    pub fn parse(s: &str) -> anyhow::Result<SweepSpec> {
        let (key, vals) = s
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--sweep wants key=v1,v2,… (got '{s}')"))?;
        let values: Vec<String> =
            vals.split(',').map(str::trim).filter(|v| !v.is_empty()).map(str::to_string).collect();
        anyhow::ensure!(!values.is_empty(), "--sweep {key}= needs at least one value");
        Ok(SweepSpec { key: key.trim().to_string(), values })
    }
}

/// Expand the sweep into per-point specs (base spec with one key
/// rewritten) and run each, one record per point. Without a sweep the
/// base spec runs once. Returns the written record paths.
pub fn run_sweep(
    base: &WorkloadSpec,
    sweep: Option<&SweepSpec>,
    artifacts: &Path,
    out_dir: &Path,
) -> anyhow::Result<Vec<PathBuf>> {
    let Some(sweep) = sweep else {
        return Ok(vec![run_workload(base, artifacts, out_dir, &base.name)?]);
    };
    let mut paths = Vec::with_capacity(sweep.values.len());
    for value in &sweep.values {
        let mut spec = base.clone();
        spec.apply(&sweep.key, value)
            .map_err(|e| anyhow::anyhow!("sweep point {}={value}: {e}", sweep.key))?;
        spec.validate()
            .map_err(|e| anyhow::anyhow!("sweep point {}={value}: {e}", sweep.key))?;
        let slug = format!("{}__{}-{}", spec.name, sweep.key, sanitize(value));
        paths.push(run_workload(&spec, artifacts, out_dir, &slug)?);
    }
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_spec_parses_lists() {
        let s = SweepSpec::parse("lanes=1,4").unwrap();
        assert_eq!(s.key, "lanes");
        assert_eq!(s.values, vec!["1", "4"]);
        let s = SweepSpec::parse("prompt_len = 8..16, 32 ").unwrap();
        assert_eq!(s.key, "prompt_len");
        assert_eq!(s.values, vec!["8..16", "32"]);
        assert!(SweepSpec::parse("lanes").is_err());
        assert!(SweepSpec::parse("lanes=").is_err());
    }

    #[test]
    fn demo_model_is_deterministic_and_serves_corpus_vocab() {
        let (cfg, w) = demo_model();
        assert_eq!(cfg.vocab, corpus::VOCAB as usize);
        let (cfg2, w2) = demo_model();
        assert_eq!(cfg.param_count(), cfg2.param_count());
        let name = cfg.param_shapes()[0].0.clone();
        assert_eq!(w.get(&name).unwrap().data, w2.get(&name).unwrap().data);
    }

    #[test]
    fn build_server_rejects_oversized_prompts() {
        let spec =
            WorkloadSpec::parse("requests = 1\nprompt_len = 4096\nweights = dense").unwrap();
        let err = build_server(&spec, Path::new("definitely-missing-artifacts")).unwrap_err();
        assert!(err.to_string().contains("prompt budget"), "{err}");
    }
}
