//! `WorkloadSpec` — the declarative workload grammar.
//!
//! A spec is a plain-text file of `key = value` lines (`#` starts a
//! comment; values may be double-quoted). The same `apply(key, value)`
//! path handles both file parsing and `--sweep key=v1,v2,…` overrides,
//! so a sweep point is exactly "the file with one key rewritten".
//!
//! Grammar (all keys optional; defaults in [`WorkloadSpec::default`]):
//!
//! ```text
//! name          = steady-decode        # record/group id (file stem if absent)
//! seed          = 42                   # drives every random draw
//! lanes         = 4                    # decode lanes / closed-loop clients
//! requests      = 24
//! arrival       = closed | poisson | bursty
//! rate_rps      = 100.0                # poisson: mean arrivals per second
//! burst_size    = 4                    # bursty: requests per burst
//! burst_gap_ms  = 20                   # bursty: gap between bursts
//! prompt_len    = 16 | 8..24           # fixed or uniform-inclusive tokens
//! gen_len       = 8  | 2..8
//! prefix_k      = 0                    # >0: K shared system prompts
//! prefix_len    = 16                   # tokens per shared prefix
//! repetitive    = true | false         # periodic prompts (speculation-friendly)
//! repeat_period = 8
//! kv            = bcq | f32
//! weights       = encoded | dense
//! spec_k        = 0                    # speculative draft depth (0 = off)
//! drafter       = ngram | off
//! prefill_chunk = 0                    # 0 = inline whole-prompt prefill
//! page_tokens   = 16
//! prefix_cache  = 16m | off            # bytes, k/m/g suffix
//! queue_cap     = 0                    # 0 = unbounded admission queue
//! deadline_ms   = 0                    # 0 = no deadline
//! kv_pages      = 0                    # 0 = unbounded KV page budget
//! max_wait_ms   = 4
//! ```

use crate::util::json::Json;
use std::path::Path;

/// Request arrival pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// `lanes` closed-loop clients, each submitting its next request as
    /// soon as the previous one finishes (arrival offsets all zero).
    Closed,
    /// Open-loop Poisson process at `rate_rps` (exponential gaps).
    Poisson,
    /// Open-loop bursts of `burst_size` back-to-back requests every
    /// `burst_gap_ms`.
    Bursty,
}

impl ArrivalKind {
    pub fn name(self) -> &'static str {
        match self {
            ArrivalKind::Closed => "closed",
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Bursty => "bursty",
        }
    }
}

/// Prompt / generation length distribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LenDist {
    Fixed(usize),
    /// Uniform over `lo..=hi`.
    Uniform(usize, usize),
}

impl LenDist {
    pub fn parse(v: &str) -> anyhow::Result<LenDist> {
        if let Some((lo, hi)) = v.split_once("..") {
            let lo: usize = lo.trim().parse().map_err(|e| anyhow::anyhow!("bad range start '{lo}': {e}"))?;
            let hi: usize = hi.trim().parse().map_err(|e| anyhow::anyhow!("bad range end '{hi}': {e}"))?;
            anyhow::ensure!(lo >= 1 && lo <= hi, "length range {lo}..{hi} must satisfy 1 <= lo <= hi");
            Ok(if lo == hi { LenDist::Fixed(lo) } else { LenDist::Uniform(lo, hi) })
        } else {
            let n: usize = v.trim().parse().map_err(|e| anyhow::anyhow!("bad length '{v}': {e}"))?;
            anyhow::ensure!(n >= 1, "length must be >= 1");
            Ok(LenDist::Fixed(n))
        }
    }

    pub fn min(&self) -> usize {
        match *self {
            LenDist::Fixed(n) => n,
            LenDist::Uniform(lo, _) => lo,
        }
    }

    pub fn max(&self) -> usize {
        match *self {
            LenDist::Fixed(n) => n,
            LenDist::Uniform(_, hi) => hi,
        }
    }

    /// One draw; consumes exactly one RNG step for `Uniform` and none
    /// for `Fixed` (keeps fixed-length traces independent of the dist).
    pub fn sample(&self, rng: &mut crate::util::rng::Pcg32) -> usize {
        match *self {
            LenDist::Fixed(n) => n,
            LenDist::Uniform(lo, hi) => lo + (rng.next_u32() as usize) % (hi - lo + 1),
        }
    }

    fn render(&self) -> String {
        match *self {
            LenDist::Fixed(n) => n.to_string(),
            LenDist::Uniform(lo, hi) => format!("{lo}..{hi}"),
        }
    }
}

/// KV-cache store mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvMode {
    Bcq,
    F32,
}

impl KvMode {
    pub fn name(self) -> &'static str {
        match self {
            KvMode::Bcq => "bcq",
            KvMode::F32 => "f32",
        }
    }

    pub fn encoded(self) -> bool {
        self == KvMode::Bcq
    }
}

/// Weight-path mode: encoded-domain W4A4 qgemm vs dense f32 GEMM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightMode {
    Encoded,
    Dense,
}

impl WeightMode {
    pub fn name(self) -> &'static str {
        match self {
            WeightMode::Encoded => "encoded",
            WeightMode::Dense => "dense",
        }
    }
}

/// One declarative workload (see module docs for the grammar).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    pub name: String,
    pub seed: u64,
    pub lanes: usize,
    pub requests: usize,
    pub arrival: ArrivalKind,
    pub rate_rps: f64,
    pub burst_size: usize,
    pub burst_gap_ms: u64,
    pub prompt_len: LenDist,
    pub gen_len: LenDist,
    pub prefix_k: usize,
    pub prefix_len: usize,
    pub repetitive: bool,
    pub repeat_period: usize,
    pub kv: KvMode,
    pub weights: WeightMode,
    pub spec_k: usize,
    pub drafter: String,
    pub prefill_chunk: usize,
    pub page_tokens: usize,
    pub prefix_cache_bytes: Option<usize>,
    pub queue_cap: usize,
    pub deadline_ms: u64,
    pub kv_pages: usize,
    pub max_wait_ms: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            name: "workload".into(),
            seed: 42,
            lanes: 4,
            requests: 16,
            arrival: ArrivalKind::Closed,
            rate_rps: 100.0,
            burst_size: 4,
            burst_gap_ms: 20,
            prompt_len: LenDist::Fixed(16),
            gen_len: LenDist::Fixed(8),
            prefix_k: 0,
            prefix_len: 16,
            repetitive: false,
            repeat_period: 8,
            kv: KvMode::Bcq,
            weights: WeightMode::Encoded,
            spec_k: 0,
            drafter: "ngram".into(),
            prefill_chunk: 0,
            page_tokens: 16,
            prefix_cache_bytes: Some(16 << 20),
            queue_cap: 0,
            deadline_ms: 0,
            kv_pages: 0,
            max_wait_ms: 4,
        }
    }
}

fn parse_num<T: std::str::FromStr>(key: &str, v: &str) -> anyhow::Result<T>
where
    T::Err: std::fmt::Display,
{
    v.trim().parse::<T>().map_err(|e| anyhow::anyhow!("bad value for {key}: '{v}' ({e})"))
}

/// Byte budget: integer with optional binary `k`/`m`/`g` suffix, or
/// `off` → `None` (mirrors the CLI's `--prefix-cache` grammar).
fn parse_bytes(key: &str, v: &str) -> anyhow::Result<Option<usize>> {
    let v = v.trim();
    if v.eq_ignore_ascii_case("off") {
        return Ok(None);
    }
    let (digits, shift) = match v.chars().last() {
        Some('k') | Some('K') => (&v[..v.len() - 1], 10u32),
        Some('m') | Some('M') => (&v[..v.len() - 1], 20),
        Some('g') | Some('G') => (&v[..v.len() - 1], 30),
        Some(_) => (v, 0),
        None => anyhow::bail!("empty value for {key}"),
    };
    let n: usize = parse_num(key, digits)?;
    n.checked_shl(shift)
        .filter(|&b| b >> shift == n)
        .map(Some)
        .ok_or_else(|| anyhow::anyhow!("byte budget for {key} overflows usize"))
}

impl WorkloadSpec {
    /// Apply one `key = value` assignment (file line or sweep override).
    pub fn apply(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        let v = value.trim().trim_matches('"');
        match key {
            "name" => self.name = v.to_string(),
            "seed" => self.seed = parse_num(key, v)?,
            "lanes" => self.lanes = parse_num(key, v)?,
            "requests" => self.requests = parse_num(key, v)?,
            "arrival" => {
                self.arrival = match v {
                    "closed" => ArrivalKind::Closed,
                    "poisson" => ArrivalKind::Poisson,
                    "bursty" => ArrivalKind::Bursty,
                    other => anyhow::bail!("unknown arrival '{other}' (closed|poisson|bursty)"),
                }
            }
            "rate_rps" => self.rate_rps = parse_num(key, v)?,
            "burst_size" => self.burst_size = parse_num(key, v)?,
            "burst_gap_ms" => self.burst_gap_ms = parse_num(key, v)?,
            "prompt_len" => self.prompt_len = LenDist::parse(v)?,
            "gen_len" => self.gen_len = LenDist::parse(v)?,
            "prefix_k" => self.prefix_k = parse_num(key, v)?,
            "prefix_len" => self.prefix_len = parse_num(key, v)?,
            "repetitive" => {
                self.repetitive = match v {
                    "true" => true,
                    "false" => false,
                    other => anyhow::bail!("bad bool for repetitive: '{other}'"),
                }
            }
            "repeat_period" => self.repeat_period = parse_num(key, v)?,
            "kv" => {
                self.kv = match v {
                    "bcq" => KvMode::Bcq,
                    "f32" => KvMode::F32,
                    other => anyhow::bail!("unknown kv mode '{other}' (bcq|f32)"),
                }
            }
            "weights" => {
                self.weights = match v {
                    "encoded" => WeightMode::Encoded,
                    "dense" => WeightMode::Dense,
                    other => anyhow::bail!("unknown weight mode '{other}' (encoded|dense)"),
                }
            }
            "spec_k" => self.spec_k = parse_num(key, v)?,
            "drafter" => {
                anyhow::ensure!(v == "ngram" || v == "off", "unknown drafter '{v}' (ngram|off)");
                self.drafter = v.to_string();
            }
            "prefill_chunk" => self.prefill_chunk = parse_num(key, v)?,
            "page_tokens" => self.page_tokens = parse_num(key, v)?,
            "prefix_cache" => self.prefix_cache_bytes = parse_bytes(key, v)?,
            "queue_cap" => self.queue_cap = parse_num(key, v)?,
            "deadline_ms" => self.deadline_ms = parse_num(key, v)?,
            "kv_pages" => self.kv_pages = parse_num(key, v)?,
            "max_wait_ms" => self.max_wait_ms = parse_num(key, v)?,
            other => anyhow::bail!("unknown workload key '{other}'"),
        }
        Ok(())
    }

    /// Parse a spec from `key = value` text (see module docs).
    pub fn parse(text: &str) -> anyhow::Result<WorkloadSpec> {
        let mut spec = WorkloadSpec::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected 'key = value', got '{raw}'", lineno + 1))?;
            spec.apply(key.trim(), value)
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Load a spec file; `name` defaults to the file stem when the file
    /// doesn't set it.
    pub fn load(path: &Path) -> anyhow::Result<WorkloadSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read workload spec {}: {e}", path.display()))?;
        let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("workload");
        let mut spec = WorkloadSpec { name: stem.to_string(), ..WorkloadSpec::default() };
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("{}:{}: expected 'key = value', got '{raw}'", path.display(), lineno + 1)
            })?;
            spec.apply(key.trim(), value)
                .map_err(|e| anyhow::anyhow!("{}:{}: {e}", path.display(), lineno + 1))?;
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Structural sanity — called after parsing and after sweep
    /// overrides, so a bad point fails fast instead of mid-run.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.name.is_empty(), "workload needs a name");
        anyhow::ensure!(self.lanes >= 1, "lanes must be >= 1");
        anyhow::ensure!(self.requests >= 1, "requests must be >= 1");
        anyhow::ensure!(self.page_tokens >= 1, "page_tokens must be >= 1");
        anyhow::ensure!(self.repeat_period >= 1, "repeat_period must be >= 1");
        if self.arrival == ArrivalKind::Poisson {
            anyhow::ensure!(self.rate_rps > 0.0, "poisson arrivals need rate_rps > 0");
        }
        if self.arrival == ArrivalKind::Bursty {
            anyhow::ensure!(self.burst_size >= 1, "bursty arrivals need burst_size >= 1");
        }
        if self.prefix_k > 0 {
            anyhow::ensure!(self.prefix_len >= 1, "prefix_k > 0 needs prefix_len >= 1");
            anyhow::ensure!(
                self.prompt_len.min() > self.prefix_len,
                "prompt_len (min {}) must exceed prefix_len {} so every request keeps a unique suffix",
                self.prompt_len.min(),
                self.prefix_len
            );
            anyhow::ensure!(!self.repetitive, "prefix_k and repetitive are mutually exclusive");
        }
        Ok(())
    }

    /// The resolved config as a flat JSON object — the run-record's
    /// grouping key (`python/report_generator.py` matches baselines on
    /// it), so every field is always present in canonical form.
    pub fn to_config_json(&self) -> Json {
        Json::obj()
            .with("arrival", Json::Str(self.arrival.name().into()))
            .with("burst_gap_ms", Json::Num(self.burst_gap_ms as f64))
            .with("burst_size", Json::Num(self.burst_size as f64))
            .with("deadline_ms", Json::Num(self.deadline_ms as f64))
            .with("drafter", Json::Str(self.drafter.clone()))
            .with("gen_len", Json::Str(self.gen_len.render()))
            .with("kv", Json::Str(self.kv.name().into()))
            .with("kv_pages", Json::Num(self.kv_pages as f64))
            .with("lanes", Json::Num(self.lanes as f64))
            .with("max_wait_ms", Json::Num(self.max_wait_ms as f64))
            .with("page_tokens", Json::Num(self.page_tokens as f64))
            .with(
                "prefix_cache_bytes",
                match self.prefix_cache_bytes {
                    Some(b) => Json::Num(b as f64),
                    None => Json::Num(0.0),
                },
            )
            .with("prefill_chunk", Json::Num(self.prefill_chunk as f64))
            .with("prefix_k", Json::Num(self.prefix_k as f64))
            .with("prefix_len", Json::Num(self.prefix_len as f64))
            .with("prompt_len", Json::Str(self.prompt_len.render()))
            .with("queue_cap", Json::Num(self.queue_cap as f64))
            .with("rate_rps", Json::Num(self.rate_rps))
            .with("repeat_period", Json::Num(self.repeat_period as f64))
            .with("repetitive", Json::Bool(self.repetitive))
            .with("requests", Json::Num(self.requests as f64))
            .with("seed", Json::Num(self.seed as f64))
            .with("spec_k", Json::Num(self.spec_k as f64))
            .with("weights", Json::Str(self.weights.name().into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let text = "\
# a comment line
name = bursty-test
seed = 7
lanes = 2
arrival = bursty   # trailing comment
burst_size = 3
burst_gap_ms = 10
prompt_len = 8..24
gen_len = 4
kv = f32
weights = dense
prefix_cache = off
";
        let s = WorkloadSpec::parse(text).unwrap();
        assert_eq!(s.name, "bursty-test");
        assert_eq!(s.seed, 7);
        assert_eq!(s.arrival, ArrivalKind::Bursty);
        assert_eq!((s.burst_size, s.burst_gap_ms), (3, 10));
        assert_eq!(s.prompt_len, LenDist::Uniform(8, 24));
        assert_eq!(s.gen_len, LenDist::Fixed(4));
        assert_eq!(s.kv, KvMode::F32);
        assert_eq!(s.weights, WeightMode::Dense);
        assert_eq!(s.prefix_cache_bytes, None);
    }

    #[test]
    fn unknown_key_and_bad_values_rejected() {
        assert!(WorkloadSpec::parse("nope = 1").is_err());
        assert!(WorkloadSpec::parse("arrival = random").is_err());
        assert!(WorkloadSpec::parse("prompt_len = 9..3").is_err());
        assert!(WorkloadSpec::parse("lanes = zero").is_err());
        assert!(WorkloadSpec::parse("lanes 4").is_err(), "missing '=' must fail");
    }

    #[test]
    fn validate_prefix_and_repetitive_rules() {
        // Prefix must leave room for a unique suffix.
        assert!(WorkloadSpec::parse("prefix_k = 2\nprefix_len = 16\nprompt_len = 16").is_err());
        assert!(WorkloadSpec::parse("prefix_k = 2\nprefix_len = 8\nprompt_len = 16").is_ok());
        assert!(WorkloadSpec::parse("prefix_k = 2\nprefix_len = 8\nprompt_len = 16\nrepetitive = true").is_err());
    }

    #[test]
    fn sweep_override_is_one_apply() {
        let mut s = WorkloadSpec::parse("name = t\nlanes = 1").unwrap();
        s.apply("lanes", "8").unwrap();
        assert_eq!(s.lanes, 8);
        let j = s.to_config_json();
        assert_eq!(j.get("lanes").unwrap().as_usize().unwrap(), 8);
    }

    #[test]
    fn config_json_is_total_and_deterministic() {
        let a = WorkloadSpec::default().to_config_json();
        let b = WorkloadSpec::default().to_config_json();
        assert_eq!(a.to_string_compact(), b.to_string_compact());
        for key in ["arrival", "lanes", "prompt_len", "gen_len", "kv", "weights", "seed", "spec_k"] {
            assert!(a.get(key).is_ok(), "config json missing {key}");
        }
    }

    #[test]
    fn len_dist_samples_stay_in_bounds() {
        let d = LenDist::parse("8..24").unwrap();
        let mut rng = crate::util::rng::Pcg32::seeded(1);
        for _ in 0..200 {
            let n = d.sample(&mut rng);
            assert!((8..=24).contains(&n), "sample {n} out of bounds");
        }
        assert_eq!(LenDist::parse("5..5").unwrap(), LenDist::Fixed(5));
    }
}
