//! Dynamic batcher: collects admitted requests into batches of at most
//! `max_batch`, waiting at most `max_wait` for the batch to fill —
//! the standard latency/throughput knob of serving systems (vLLM-style).
//!
//! SLO machinery (DESIGN.md §Scheduling):
//!
//! - **Bounded admission**: an optional `queue_cap` turns `push` into
//!   backpressure — a full queue rejects with [`PushOutcome::QueueFull`]
//!   instead of growing without bound. Requeues from the scheduler
//!   ([`push_front`](Batcher::push_front)) bypass the cap: those
//!   requests were already admitted once.
//! - **Two-level priority FIFO**: high-priority requests drain before
//!   normal ones at every pop; order within each class stays FIFO.
//! - **Deadline shedding at pop time**: a request whose deadline passed
//!   while queued is never handed to the scheduler — it moves to an
//!   internal shed bin the worker drains
//!   ([`drain_shed`](Batcher::drain_shed)) to deliver the terminal
//!   shed error. Shedding at pop (not push) catches deadlines that
//!   expire *while waiting*, which is where queueing delay actually
//!   kills an SLO.
//!
//! Invariants (property-tested): FIFO order within a priority class, no
//! request dropped or duplicated across pops + shed bin, batch size ≤
//! max_batch, and a non-empty queue never waits longer than `max_wait`
//! once the first request of a batch has arrived.

use super::request::{Priority, Request};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Admission-queue capacity (`None` = unbounded, the pre-SLO
    /// behaviour). Counts queued requests only, not the shed bin.
    pub queue_cap: Option<usize>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5), queue_cap: None }
    }
}

/// Result of a producer-side [`push`](Batcher::push).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushOutcome {
    Accepted,
    /// Bounded queue at capacity; the request was NOT enqueued.
    QueueFull,
    /// Queue closed (shutdown); the request was NOT enqueued.
    Closed,
}

impl PushOutcome {
    pub fn is_accepted(self) -> bool {
        self == PushOutcome::Accepted
    }
}

/// Result of a blocking consumer-side [`pop`](Batcher::pop).
#[derive(Debug)]
pub enum PopResult {
    /// A live (unexpired) request.
    Req(Request),
    /// No live request, but deadline-expired ones just moved to the
    /// shed bin — the caller must [`drain_shed`](Batcher::drain_shed)
    /// and deliver their terminal errors before polling again (pop
    /// never blocks while shed deliveries are pending).
    Shed,
    /// Closed and fully drained.
    Closed,
}

#[derive(Debug, Default)]
struct QueueState {
    high: VecDeque<Request>,
    normal: VecDeque<Request>,
    /// Deadline-expired requests awaiting terminal-error delivery.
    shed: Vec<Request>,
    closed: bool,
}

impl QueueState {
    fn queued(&self) -> usize {
        self.high.len() + self.normal.len()
    }

    /// Pop the highest-priority live request, moving deadline-expired
    /// ones encountered on the way into the shed bin.
    fn pop_live(&mut self, now: Instant) -> Option<Request> {
        loop {
            let r = match self.high.pop_front() {
                Some(r) => r,
                None => self.normal.pop_front()?,
            };
            if r.expired(now) {
                self.shed.push(r);
            } else {
                return Some(r);
            }
        }
    }
}

/// Thread-safe dynamic batching queue.
#[derive(Debug)]
pub struct Batcher {
    state: Mutex<QueueState>,
    cv: Condvar,
    policy: BatchPolicy,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { state: Mutex::new(QueueState::default()), cv: Condvar::new(), policy }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue a request (producer side), subject to the capacity bound.
    pub fn push(&self, req: Request) -> PushOutcome {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return PushOutcome::Closed;
        }
        if let Some(cap) = self.policy.queue_cap {
            if st.queued() >= cap {
                return PushOutcome::QueueFull;
            }
        }
        match req.priority {
            Priority::High => st.high.push_back(req),
            Priority::Normal => st.normal.push_back(req),
        }
        self.cv.notify_one();
        PushOutcome::Accepted
    }

    /// Requeue a deferred or preempted request at the **front** of its
    /// priority class (scheduler side). Bypasses the capacity bound —
    /// the request was already admitted once and must terminate — and
    /// works even on a closed queue, so shutdown still drains it.
    pub fn push_front(&self, req: Request) {
        let mut st = self.state.lock().unwrap();
        match req.priority {
            Priority::High => st.high.push_front(req),
            Priority::Normal => st.normal.push_front(req),
        }
        self.cv.notify_one();
    }

    /// Close the queue: producers are rejected, consumers drain what is
    /// left and then receive `Closed`/`None`.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.cv.notify_all();
    }

    /// Queued (not yet popped or shed) requests — one lock acquisition.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queued()
    }

    /// One lock acquisition, not a `len()` round trip.
    pub fn is_empty(&self) -> bool {
        self.state.lock().unwrap().queued() == 0
    }

    /// Take every deadline-expired request shed so far. The worker
    /// delivers each one's terminal shed error; draining is how the
    /// "exactly one terminal event per request" invariant covers the
    /// shed path.
    pub fn drain_shed(&self) -> Vec<Request> {
        std::mem::take(&mut self.state.lock().unwrap().shed)
    }

    /// Blocking pop (continuous-batching admission: the worker blocks
    /// here only when it has no active lanes). Never blocks while shed
    /// deliveries are pending — see [`PopResult::Shed`].
    pub fn pop(&self) -> PopResult {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(r) = st.pop_live(Instant::now()) {
                return PopResult::Req(r);
            }
            if !st.shed.is_empty() {
                return PopResult::Shed;
            }
            if st.closed {
                return PopResult::Closed;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Non-blocking single-request pop (mid-batch backfill into a freed
    /// lane: never stall live lanes waiting for new arrivals). Expired
    /// requests encountered are shed; the caller's per-iteration
    /// `drain_shed` delivers them.
    pub fn try_pop(&self) -> Option<Request> {
        self.state.lock().unwrap().pop_live(Instant::now())
    }

    /// Take the next batch (consumer side). Blocks until at least one
    /// live request is available, then waits up to `max_wait` for the
    /// batch to fill (returning early if it does). Returns `None` when
    /// closed and drained. Returns an **empty** batch only when the
    /// call's progress was moving expired requests to the shed bin —
    /// the caller drains and re-polls. A competing consumer draining
    /// the queue during the fill window restarts the first-request
    /// wait instead of yielding a spurious empty batch.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let mut st = self.state.lock().unwrap();
        'restart: loop {
            // Wait for a first request (or shed progress, or shutdown).
            loop {
                if st.queued() > 0 {
                    break;
                }
                if !st.shed.is_empty() {
                    return Some(Vec::new());
                }
                if st.closed {
                    return None;
                }
                st = self.cv.wait(st).unwrap();
            }
            // Fill window: wait until max_batch or deadline. Every wake
            // re-checks the deadline; a wake that finds the queue
            // drained (competing consumer) restarts the outer wait.
            let deadline = Instant::now() + self.policy.max_wait;
            while st.queued() < self.policy.max_batch && !st.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (next, timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
                st = next;
                if timeout.timed_out() {
                    break;
                }
                if st.queued() == 0 {
                    continue 'restart;
                }
            }
            let now = Instant::now();
            let mut out = Vec::new();
            while out.len() < self.policy.max_batch {
                match st.pop_live(now) {
                    Some(r) => out.push(r),
                    None => break,
                }
            }
            if out.is_empty() {
                // Everything queued had expired: surface the shed
                // progress (or restart if a competitor raced us).
                if !st.shed.is_empty() {
                    return Some(out);
                }
                continue 'restart;
            }
            return Some(out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};
    use std::sync::Arc;
    use std::time::Instant;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1], 1)
    }

    fn policy(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait: Duration::from_millis(wait_ms), queue_cap: None }
    }

    fn pop_req(b: &Batcher) -> Option<Request> {
        match b.pop() {
            PopResult::Req(r) => Some(r),
            _ => None,
        }
    }

    #[test]
    fn batches_respect_max_batch_and_fifo() {
        let b = Batcher::new(policy(3, 0));
        for i in 0..7 {
            assert!(b.push(req(i)).is_accepted());
        }
        let ids: Vec<Vec<u64>> = (0..3)
            .map(|_| b.next_batch().unwrap().iter().map(|r| r.id).collect())
            .collect();
        assert_eq!(ids, vec![vec![0, 1, 2], vec![3, 4, 5], vec![6]]);
    }

    #[test]
    fn close_drains_then_none() {
        let b = Batcher::new(policy(4, 0));
        b.push(req(1));
        b.close();
        assert_eq!(b.push(req(2)), PushOutcome::Closed, "push after close accepted");
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn full_batch_returns_before_deadline() {
        let b = Batcher::new(policy(2, 10_000)); // absurd wait
        b.push(req(1));
        b.push(req(2));
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert!(t.elapsed() < Duration::from_millis(1000), "waited despite full batch");
    }

    #[test]
    fn waits_for_stragglers() {
        let b = Arc::new(Batcher::new(policy(2, 200)));
        let b2 = b.clone();
        b.push(req(1));
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            b2.push(req(2));
        });
        let batch = b.next_batch().unwrap();
        h.join().unwrap();
        assert_eq!(batch.len(), 2, "straggler not included");
    }

    #[test]
    fn consumer_blocks_until_first_push() {
        let b = Arc::new(Batcher::new(policy(2, 1)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.push(req(9));
        let got = h.join().unwrap().unwrap();
        assert_eq!(got[0].id, 9);
    }

    #[test]
    fn pop_and_try_pop_are_fifo_and_respect_close() {
        let b = Batcher::new(policy(4, 0));
        assert!(b.try_pop().is_none(), "empty try_pop returned a request");
        b.push(req(1));
        b.push(req(2));
        assert_eq!(b.try_pop().unwrap().id, 1);
        assert_eq!(pop_req(&b).unwrap().id, 2);
        b.close();
        assert!(matches!(b.pop(), PopResult::Closed), "pop after close+drain should be Closed");
        // Blocking pop wakes on push from another thread.
        let b = Arc::new(Batcher::new(policy(4, 0)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || match b2.pop() {
            PopResult::Req(r) => r.id,
            other => panic!("expected a request, got {other:?}"),
        });
        std::thread::sleep(Duration::from_millis(20));
        b.push(req(9));
        assert_eq!(h.join().unwrap(), 9);
    }

    #[test]
    fn capacity_bound_rejects_but_push_front_bypasses() {
        let b = Batcher::new(BatchPolicy { queue_cap: Some(2), ..policy(4, 0) });
        assert!(b.push(req(1)).is_accepted());
        assert!(b.push(req(2)).is_accepted());
        assert_eq!(b.push(req(3)), PushOutcome::QueueFull);
        assert_eq!(b.len(), 2, "rejected push grew the queue");
        // A requeue is not a new admission: it must go through even at
        // capacity, and land at the FRONT of its class.
        b.push_front(req(9));
        assert_eq!(b.len(), 3);
        assert_eq!(b.try_pop().unwrap().id, 9, "requeue not at the front");
        // Draining back under cap re-opens admission.
        assert!(b.push(req(4)).is_accepted());
        // push_front works after close too (shutdown must still drain).
        b.close();
        b.push_front(req(10));
        assert_eq!(b.try_pop().unwrap().id, 10);
    }

    #[test]
    fn high_priority_drains_first_fifo_within_class() {
        let b = Batcher::new(policy(8, 0));
        b.push(req(1));
        b.push(req(2).with_priority(Priority::High));
        b.push(req(3));
        b.push(req(4).with_priority(Priority::High));
        let ids: Vec<u64> = b.next_batch().unwrap().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 4, 1, 3], "two-level FIFO violated");
    }

    #[test]
    fn expired_requests_shed_at_pop_not_decoded() {
        let b = Batcher::new(policy(4, 0));
        let past = Instant::now() - Duration::from_millis(1);
        b.push(req(1).with_deadline(Some(past)));
        b.push(req(2));
        b.push(req(3).with_deadline(Some(past)));
        // Pop skips the expired ones and returns the live request.
        assert_eq!(pop_req(&b).unwrap().id, 2);
        let shed: Vec<u64> = b.drain_shed().iter().map(|r| r.id).collect();
        assert_eq!(shed, vec![1, 3], "expired requests not shed at pop");
        assert!(b.drain_shed().is_empty(), "shed bin not drained");
        // All-expired queue: pop reports Shed instead of blocking, and
        // next_batch surfaces an empty batch for the same reason.
        b.push(req(4).with_deadline(Some(past)));
        assert!(matches!(b.pop(), PopResult::Shed));
        assert_eq!(b.drain_shed().len(), 1);
        b.push(req(5).with_deadline(Some(past)));
        assert_eq!(b.next_batch().unwrap().len(), 0, "expired-only queue must yield shed progress");
        assert_eq!(b.drain_shed().len(), 1);
    }

    #[test]
    fn next_batch_restarts_on_competing_consumer_drain() {
        // A try_pop consumer stealing the queue mid-fill-window must not
        // make next_batch return an empty batch.
        let b = Arc::new(Batcher::new(policy(4, 120)));
        b.push(req(1));
        let b2 = b.clone();
        let consumer = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(30));
        // Steal the only request, then wake the batching consumer.
        let stolen = b.try_pop();
        b.push(req(2));
        let got = consumer.join().unwrap().unwrap();
        assert!(!got.is_empty(), "next_batch returned an empty batch");
        let mut ids: Vec<u64> = got.iter().map(|r| r.id).collect();
        if let Some(s) = stolen {
            ids.push(s.id);
        }
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2], "request lost between consumers");
    }

    #[test]
    fn prop_no_drop_no_duplicate_fifo() {
        forall(80, "batcher conservation + order", |rng| {
            let max_batch = 1 + rng.index(6);
            let b = Batcher::new(policy(max_batch, 0));
            let n = 1 + rng.index(40);
            for i in 0..n as u64 {
                b.push(req(i));
            }
            b.close();
            let mut seen = Vec::new();
            while let Some(batch) = b.next_batch() {
                ensure(batch.len() <= max_batch, || "batch too large".into())?;
                seen.extend(batch.iter().map(|r| r.id));
            }
            ensure(seen.len() == n, || format!("dropped/extra: {} vs {n}", seen.len()))?;
            ensure(seen.windows(2).all(|w| w[0] < w[1]), || "order violated".into())
        });
    }

    #[test]
    fn prop_conservation_with_priorities_deadlines_and_cap() {
        forall(60, "batcher SLO conservation", |rng| {
            let cap = 1 + rng.index(12);
            let b = Batcher::new(BatchPolicy { queue_cap: Some(cap), ..policy(1 + rng.index(4), 0) });
            let n = 1 + rng.index(30);
            let past = Instant::now() - Duration::from_millis(1);
            let mut accepted = 0usize;
            for i in 0..n as u64 {
                let mut r = req(i);
                if rng.index(3) == 0 {
                    r = r.with_priority(Priority::High);
                }
                if rng.index(4) == 0 {
                    r = r.with_deadline(Some(past));
                }
                if b.push(r).is_accepted() {
                    accepted += 1;
                }
            }
            ensure(accepted <= cap, || format!("cap {cap} breached: {accepted}"))?;
            b.close();
            let mut terminal = 0usize;
            while let Some(batch) = b.next_batch() {
                terminal += batch.len();
                terminal += b.drain_shed().len();
            }
            terminal += b.drain_shed().len();
            ensure(terminal == accepted, || {
                format!("conservation broken: {terminal} terminal events for {accepted} accepted")
            })
        });
    }
}
