//! Dynamic batcher: collects admitted requests into batches of at most
//! `max_batch`, waiting at most `max_wait` for the batch to fill —
//! the standard latency/throughput knob of serving systems (vLLM-style).
//!
//! Invariants (property-tested): FIFO order within a batch stream, no
//! request dropped, no request duplicated, batch size ≤ max_batch, and a
//! non-empty queue never waits longer than `max_wait` once the first
//! request of a batch has arrived.

use super::request::Request;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

#[derive(Debug, Default)]
struct QueueState {
    queue: VecDeque<Request>,
    closed: bool,
}

/// Thread-safe dynamic batching queue.
#[derive(Debug)]
pub struct Batcher {
    state: Mutex<QueueState>,
    cv: Condvar,
    policy: BatchPolicy,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { state: Mutex::new(QueueState::default()), cv: Condvar::new(), policy }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Enqueue a request (producer side). Returns false if closed.
    pub fn push(&self, req: Request) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return false;
        }
        st.queue.push_back(req);
        self.cv.notify_one();
        true
    }

    /// Close the queue: producers are rejected, consumers drain what is
    /// left and then receive `None`.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking single-request pop (continuous-batching admission: the
    /// worker blocks here only when it has no active lanes). Returns
    /// `None` when the queue is closed and drained.
    pub fn pop(&self) -> Option<Request> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(r) = st.queue.pop_front() {
                return Some(r);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Non-blocking single-request pop (mid-batch backfill into a freed
    /// lane: never stall live lanes waiting for new arrivals).
    pub fn try_pop(&self) -> Option<Request> {
        self.state.lock().unwrap().queue.pop_front()
    }

    /// Take the next batch (consumer side). Blocks until at least one
    /// request is available, then waits up to `max_wait` for the batch to
    /// fill (returning early if it does). Returns `None` when closed and
    /// drained.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let mut st = self.state.lock().unwrap();
        // Wait for a first request (or shutdown).
        loop {
            if !st.queue.is_empty() {
                break;
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
        // Fill window: wait until max_batch or deadline.
        let deadline = Instant::now() + self.policy.max_wait;
        while st.queue.len() < self.policy.max_batch && !st.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (next, timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = next;
            if timeout.timed_out() {
                break;
            }
        }
        let n = st.queue.len().min(self.policy.max_batch);
        Some(st.queue.drain(..n).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};
    use std::sync::Arc;
    use std::time::Instant;

    fn req(id: u64) -> Request {
        Request { id, prompt: vec![1], max_new: 1, submitted_at: Instant::now() }
    }

    fn policy(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait: Duration::from_millis(wait_ms) }
    }

    #[test]
    fn batches_respect_max_batch_and_fifo() {
        let b = Batcher::new(policy(3, 0));
        for i in 0..7 {
            assert!(b.push(req(i)));
        }
        let ids: Vec<Vec<u64>> = (0..3)
            .map(|_| b.next_batch().unwrap().iter().map(|r| r.id).collect())
            .collect();
        assert_eq!(ids, vec![vec![0, 1, 2], vec![3, 4, 5], vec![6]]);
    }

    #[test]
    fn close_drains_then_none() {
        let b = Batcher::new(policy(4, 0));
        b.push(req(1));
        b.close();
        assert!(!b.push(req(2)), "push after close accepted");
        assert_eq!(b.next_batch().unwrap().len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn full_batch_returns_before_deadline() {
        let b = Batcher::new(policy(2, 10_000)); // absurd wait
        b.push(req(1));
        b.push(req(2));
        let t = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert!(t.elapsed() < Duration::from_millis(1000), "waited despite full batch");
    }

    #[test]
    fn waits_for_stragglers() {
        let b = Arc::new(Batcher::new(policy(2, 200)));
        let b2 = b.clone();
        b.push(req(1));
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            b2.push(req(2));
        });
        let batch = b.next_batch().unwrap();
        h.join().unwrap();
        assert_eq!(batch.len(), 2, "straggler not included");
    }

    #[test]
    fn consumer_blocks_until_first_push() {
        let b = Arc::new(Batcher::new(policy(2, 1)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.push(req(9));
        let got = h.join().unwrap().unwrap();
        assert_eq!(got[0].id, 9);
    }

    #[test]
    fn pop_and_try_pop_are_fifo_and_respect_close() {
        let b = Batcher::new(policy(4, 0));
        assert!(b.try_pop().is_none(), "empty try_pop returned a request");
        b.push(req(1));
        b.push(req(2));
        assert_eq!(b.try_pop().unwrap().id, 1);
        assert_eq!(b.pop().unwrap().id, 2);
        b.close();
        assert!(b.pop().is_none(), "pop after close+drain should be None");
        // Blocking pop wakes on push from another thread.
        let b = Arc::new(Batcher::new(policy(4, 0)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.pop());
        std::thread::sleep(Duration::from_millis(20));
        b.push(req(9));
        assert_eq!(h.join().unwrap().unwrap().id, 9);
    }

    #[test]
    fn prop_no_drop_no_duplicate_fifo() {
        forall(80, "batcher conservation + order", |rng| {
            let max_batch = 1 + rng.index(6);
            let b = Batcher::new(policy(max_batch, 0));
            let n = 1 + rng.index(40);
            for i in 0..n as u64 {
                b.push(req(i));
            }
            b.close();
            let mut seen = Vec::new();
            while let Some(batch) = b.next_batch() {
                ensure(batch.len() <= max_batch, || "batch too large".into())?;
                seen.extend(batch.iter().map(|r| r.id));
            }
            ensure(seen.len() == n, || format!("dropped/extra: {} vs {n}", seen.len()))?;
            ensure(seen.windows(2).all(|w| w[0] < w[1]), || "order violated".into())
        });
    }
}
