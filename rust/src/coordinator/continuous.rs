//! Continuous-batching decode loop (vLLM-style iteration-level
//! scheduling) over a [`DecodeEngine`].
//!
//! Unlike [`run_batch`](super::scheduler::run_batch) — which holds every
//! lane until the *longest* request's `max_new` — this loop interleaves
//! requests at token granularity: each iteration advances **every**
//! active lane by one token through a single fused
//! [`decode_batch`](DecodeEngine::decode_batch) call (one activation
//! quantization, each projection GEMM launched once per step — the
//! packed weight panels stream once for the whole batch, not once per
//! lane). Finished requests release their KV-cache slot immediately,
//! and the freed lane is **backfilled** from the admission queue
//! mid-batch (`Batcher::try_pop`, non-blocking, so live lanes are never
//! stalled waiting for arrivals). The worker blocks only when it has
//! nothing to decode at all.
//!
//! Engine errors are per-lane: a failed prefill or a lane's slot in the
//! fused step fails that one request and frees its lane; the rest of
//! the batch keeps decoding (the fixed-batch path can only fail the
//! whole batch).

use super::batcher::Batcher;
use super::metrics::ServerMetrics;
use super::request::{Request, Response};
use super::scheduler::{sample_from_logits, Sampling};
use super::session::DecodeEngine;
use std::time::Instant;

/// One in-flight request bound to an engine lane.
struct Lane {
    req: Request,
    lane: usize,
    /// Number of tokens this request may generate (its `max_new`, capped
    /// by the engine's per-lane token capacity).
    budget: usize,
    generated: Vec<u32>,
    picked_at: Instant,
    first_token_at: Instant,
    last_step_at: Instant,
    decode_us: f64,
    max_batch_seen: usize,
}

/// Drive the engine until the batcher is closed and drained and every
/// active lane has finished. `deliver` receives each request's terminal
/// event — `Ok(Response)` or the per-request error. When `metrics` is
/// given, every fused step records its batch occupancy and the engine's
/// KV-cache page stats.
pub fn run_continuous<E: DecodeEngine + ?Sized>(
    engine: &mut E,
    batcher: &Batcher,
    sampling: Sampling,
    metrics: Option<&ServerMetrics>,
    mut deliver: impl FnMut(u64, anyhow::Result<Response>),
) {
    let mut active: Vec<Lane> = Vec::new();
    // Per-step staging, reused across iterations.
    let mut step_idx: Vec<usize> = Vec::new(); // indices into `active`
    let mut step_lanes: Vec<usize> = Vec::new(); // engine lane ids
    let mut step_tokens: Vec<u32> = Vec::new();
    loop {
        // ---- admission: fill free lanes. Block only when idle. ----
        while active.len() < engine.max_concurrency() {
            let next = if active.is_empty() { batcher.pop() } else { batcher.try_pop() };
            let Some(req) = next else {
                if active.is_empty() {
                    // pop() returned None => closed and drained => done.
                    // Snapshot the caches one last time: the final lane
                    // releases freed pages and published prefixes after
                    // the last step's metrics were recorded, so without
                    // this the summary would print pre-shutdown
                    // occupancy.
                    record_engine_stats(engine, metrics);
                    return;
                }
                break; // nothing queued right now; keep decoding
            };
            admit(engine, req, sampling, &mut active, &mut deliver);
        }
        if active.is_empty() {
            // Admission failed (e.g. prefill error on the only request);
            // loop back to blocking pop.
            continue;
        }
        let cur = active.len();
        for lane in active.iter_mut() {
            lane.max_batch_seen = lane.max_batch_seen.max(cur);
        }

        // ---- ONE fused decode step across every live lane ----
        let mut finished: Vec<usize> = Vec::new();
        step_idx.clear();
        step_lanes.clear();
        step_tokens.clear();
        for (idx, lane) in active.iter().enumerate() {
            if lane.generated.len() >= lane.budget {
                finished.push(idx);
                continue;
            }
            step_idx.push(idx);
            step_lanes.push(lane.lane);
            step_tokens.push(*lane.generated.last().unwrap());
        }
        if !step_idx.is_empty() {
            if let Some(m) = metrics {
                m.record_step_occupancy(step_idx.len());
            }
            let t0 = Instant::now();
            let results = engine.decode_batch(&step_lanes, &step_tokens);
            // The step's wall time is shared work; attribute an equal
            // share to each participating lane.
            let share_us = t0.elapsed().as_secs_f64() * 1e6 / step_idx.len() as f64;
            let stepped_at = Instant::now();
            debug_assert_eq!(results.len(), step_idx.len());
            for (&idx, result) in step_idx.iter().zip(results) {
                let lane = &mut active[idx];
                match result {
                    Ok(logits) => {
                        lane.decode_us += share_us;
                        lane.last_step_at = stepped_at;
                        let step = lane.req.prompt.len() + lane.generated.len();
                        lane.generated.push(sample_from_logits(&logits, sampling, lane.req.id, step));
                        if lane.generated.len() >= lane.budget {
                            finished.push(idx);
                        }
                    }
                    Err(e) => {
                        deliver(lane.req.id, Err(anyhow::anyhow!("decode failed: {e}")));
                        lane.generated.clear(); // mark dead: the retire loop below
                        finished.push(idx); // releases the lane, delivers nothing
                    }
                }
            }
            record_engine_stats(engine, metrics);
        }

        // ---- retire finished lanes (slots free => next admission pass
        // backfills them). Budget-finished and step-finished indices
        // interleave, so order them before the descending swap_remove
        // sweep. ----
        finished.sort_unstable();
        for idx in finished.into_iter().rev() {
            let lane = active.swap_remove(idx);
            engine.release(lane.lane);
            if lane.generated.is_empty() {
                continue; // errored above; already delivered
            }
            let done = Instant::now();
            let n = lane.generated.len();
            let itl_us = if n > 1 {
                (lane.last_step_at - lane.first_token_at).as_secs_f64() * 1e6 / (n - 1) as f64
            } else {
                0.0
            };
            deliver(
                lane.req.id,
                Ok(Response {
                    id: lane.req.id,
                    tokens: lane.generated,
                    queue_us: (lane.picked_at - lane.req.submitted_at).as_secs_f64() * 1e6,
                    execute_us: lane.decode_us,
                    ttft_us: (lane.first_token_at - lane.req.submitted_at).as_secs_f64() * 1e6,
                    itl_us,
                    total_us: (done - lane.req.submitted_at).as_secs_f64() * 1e6,
                    batch_size: lane.max_batch_seen,
                }),
            );
        }
    }
}

/// Record the engine's cache snapshots (KV occupancy + prefix-cache
/// counters) — one definition shared by the per-step and final-drain
/// sites, so a new engine-side stat can't be wired into one and
/// silently skew the other.
fn record_engine_stats<E: DecodeEngine + ?Sized>(engine: &E, metrics: Option<&ServerMetrics>) {
    let Some(m) = metrics else { return };
    if let Some(kv) = engine.kv_stats() {
        m.record_kv_stats(kv);
    }
    if let Some(ps) = engine.prefix_stats() {
        m.record_prefix_stats(ps);
    }
}

fn admit<E: DecodeEngine + ?Sized>(
    engine: &mut E,
    req: Request,
    sampling: Sampling,
    active: &mut Vec<Lane>,
    deliver: &mut impl FnMut(u64, anyhow::Result<Response>),
) {
    let picked_at = Instant::now();
    // Generating n tokens appends cache positions up to
    // prompt + n - 1; cap the budget at the engine's lane capacity.
    let cap = engine.max_tokens().saturating_sub(req.prompt.len()) + 1;
    let budget = req.max_new.min(cap).max(1);
    let t0 = Instant::now();
    match engine.prefill(&req.prompt) {
        Ok((lane, logits)) => {
            let prefill_us = t0.elapsed().as_secs_f64() * 1e6;
            let first_token_at = Instant::now();
            let first = sample_from_logits(&logits, sampling, req.id, req.prompt.len());
            active.push(Lane {
                req,
                lane,
                budget,
                generated: vec![first],
                picked_at,
                first_token_at,
                last_step_at: first_token_at,
                decode_us: prefill_us,
                max_batch_seen: 0,
            });
        }
        Err(e) => deliver(req.id, Err(anyhow::anyhow!("prefill failed: {e}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::session::MockDecodeEngine;
    use std::time::{Duration, Instant};

    fn req(id: u64, prompt: Vec<u32>, max_new: usize) -> Request {
        Request { id, prompt, max_new, submitted_at: Instant::now() }
    }

    fn drive(engine: &mut MockDecodeEngine, reqs: Vec<Request>) -> Vec<(u64, anyhow::Result<Response>)> {
        let b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::ZERO });
        for r in reqs {
            assert!(b.push(r));
        }
        b.close();
        let mut out = Vec::new();
        run_continuous(engine, &b, Sampling::Greedy, None, |id, r| out.push((id, r)));
        out
    }

    #[test]
    fn follows_successor_rule_and_answers_everything() {
        let mut e = MockDecodeEngine::new(4, 32);
        let out = drive(
            &mut e,
            vec![req(1, vec![5], 4), req(2, vec![9, 10], 3), req(3, vec![1], 1)],
        );
        assert_eq!(out.len(), 3);
        let get = |id: u64| {
            out.iter().find(|(i, _)| *i == id).unwrap().1.as_ref().unwrap().clone()
        };
        // Mock predicts tok+1: prefill samples the first token.
        assert_eq!(get(1).tokens, vec![6, 7, 8, 9]);
        assert_eq!(get(2).tokens, vec![11, 12, 13]);
        assert_eq!(get(3).tokens, vec![2]);
        assert_eq!(get(3).itl_us, 0.0, "single-token response has an ITL");
        assert!(get(1).ttft_us > 0.0);
        assert_eq!(e.releases, 3);
        // 3 prefills + decodes: req1 needs 3 steps, req2 needs 2, req3 0.
        assert_eq!(e.prefills, 3);
        assert_eq!(e.decodes, 5);
        // Fused stepping: co-live lanes decode in ONE engine call per
        // step, never one call per lane. Step 1 ran lanes 1+2 together.
        assert!(e.batch_calls < e.decodes, "every decode got its own engine call");
        assert_eq!(e.max_batch_lanes, 2, "co-live lanes not stepped together");
    }

    #[test]
    fn records_occupancy_and_shares_step_time() {
        use crate::coordinator::metrics::ServerMetrics;
        let m = ServerMetrics::new();
        let b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::ZERO });
        assert!(b.push(req(1, vec![1], 3)));
        assert!(b.push(req(2, vec![2], 3)));
        b.close();
        let mut e = MockDecodeEngine::new(4, 32);
        let mut out = Vec::new();
        run_continuous(&mut e, &b, Sampling::Greedy, Some(&m), |id, r| out.push((id, r)));
        assert_eq!(out.len(), 2);
        let s = m.snapshot();
        // Both lanes admitted before the first step: 2 steps at
        // occupancy 2 (each generates 2 more tokens after prefill).
        assert_eq!(s.occupancy_hist, vec![(2, 2)]);
        assert!((s.mean_occupancy - 2.0).abs() < 1e-9);
        assert!(s.kv.is_none(), "mock engine grew a KV cache");
    }

    #[test]
    fn backfills_freed_lanes_mid_batch() {
        // 2 lanes, 5 requests: short requests finish and free lanes that
        // later requests reuse while the long one is still decoding.
        let mut e = MockDecodeEngine::new(2, 64);
        let reqs = vec![
            req(1, vec![1], 8), // long
            req(2, vec![2], 1), // finishes at admission
            req(3, vec![3], 2),
            req(4, vec![4], 2),
            req(5, vec![5], 1),
        ];
        let out = drive(&mut e, reqs);
        assert_eq!(out.len(), 5);
        assert!(out.iter().all(|(_, r)| r.is_ok()));
        assert_eq!(e.max_live_seen, 2, "never used both lanes");
        assert_eq!(e.releases, 5, "lanes leaked");
        // The long request saw company: batch_size reflects sharing.
        let long = out.iter().find(|(i, _)| *i == 1).unwrap().1.as_ref().unwrap();
        assert_eq!(long.tokens.len(), 8);
        assert!(long.batch_size >= 2, "no backfill observed");
        // FIFO admission: request 5 must not be answered before 2.
        let pos = |id: u64| out.iter().position(|(i, _)| *i == id).unwrap();
        assert!(pos(2) < pos(5));
    }

    #[test]
    fn poisoned_request_fails_alone() {
        let mut e = MockDecodeEngine::new(2, 32);
        // Request 1 decodes from token 5 -> 6 -> poisoned at decode(6).
        e.poison_token = Some(6);
        let out = drive(&mut e, vec![req(1, vec![5], 4), req(2, vec![20], 3)]);
        let r1 = &out.iter().find(|(i, _)| *i == 1).unwrap().1;
        let r2 = &out.iter().find(|(i, _)| *i == 2).unwrap().1;
        assert!(r1.is_err(), "poisoned request succeeded");
        assert_eq!(r2.as_ref().unwrap().tokens, vec![21, 22, 23], "healthy lane dragged down");
        assert_eq!(e.releases, 2, "errored lane leaked");
    }

    #[test]
    fn budget_is_capped_by_engine_capacity() {
        let mut e = MockDecodeEngine::new(1, 32);
        e.max_tokens = 4;
        // prompt 3 tokens + budget cap => 4 - 3 + 1 = 2 tokens max.
        let out = drive(&mut e, vec![req(1, vec![1, 2, 3], 10)]);
        assert_eq!(out[0].1.as_ref().unwrap().tokens.len(), 2);
    }
}
