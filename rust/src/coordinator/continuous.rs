//! Continuous-batching decode loop (vLLM-style iteration-level
//! scheduling) over a [`DecodeEngine`].
//!
//! Unlike [`run_batch`](super::scheduler::run_batch) — which holds every
//! lane until the *longest* request's `max_new` — this loop interleaves
//! requests at token granularity: each iteration advances **every**
//! active lane by one token through a single fused
//! [`decode_batch`](DecodeEngine::decode_batch) call (one activation
//! quantization, each projection GEMM launched once per step — the
//! packed weight panels stream once for the whole batch, not once per
//! lane). Finished requests release their KV-cache slot immediately,
//! and the freed lane is **backfilled** from the admission queue
//! mid-batch (`Batcher::try_pop`, non-blocking, so live lanes are never
//! stalled waiting for arrivals). The worker blocks only when it has
//! nothing to decode at all.
//!
//! SLO machinery (DESIGN.md §Scheduling):
//!
//! - **Chunked prefill** ([`ContinuousOpts::prefill_chunk`]): admission
//!   stages a prompt ([`DecodeEngine::begin_prefill`]) and the loop
//!   spends at most one chunk of prefill compute per iteration,
//!   interleaved with the fused decode step — live lanes stall at most
//!   one chunk behind a long prompt, and the result is bit-identical to
//!   inline prefill (K/V at position `p` depends only on tokens
//!   `..= p`).
//! - **Deadline shedding**: the batcher sheds expired requests at pop
//!   time; the loop drains the shed bin every iteration and delivers
//!   each one's terminal [`ShedError`].
//! - **Graceful degradation**: a typed [`KvPressure`] failure (prefill
//!   chunk or fused decode step — both pre-check pages, so nothing
//!   advanced and the step replays bit-exactly) walks a ladder instead
//!   of panicking: evict the engine's prefix cache → defer the newest
//!   still-prefilling admission → preempt the lowest-priority newest
//!   decoding lane (deterministic sampling makes the replay
//!   bit-identical) → shed the sole remaining lane explicitly.
//!
//! Engine errors are per-lane: a failed prefill or a lane's slot in the
//! fused step fails that one request and frees its lane; the rest of
//! the batch keeps decoding (the fixed-batch path can only fail the
//! whole batch).

use super::batcher::{Batcher, PopResult};
use super::drafter::{Drafter, DrafterKind};
use super::metrics::ServerMetrics;
use super::request::{Request, Response, ShedError, ShedReason};
use super::scheduler::{sample_from_logits, Sampling};
use super::session::{DecodeEngine, PrefillProgress};
use crate::kvcache::KvPressure;
use std::sync::OnceLock;
use std::time::Instant;

/// Knobs for the continuous loop.
#[derive(Debug, Clone, Copy)]
pub struct ContinuousOpts {
    /// Maximum prompt tokens prefilled per scheduler iteration.
    /// `usize::MAX` = inline admission (finish each staged prompt
    /// before the next decode step — the historical behaviour); a
    /// finite chunk bounds how long live decode lanes stall behind a
    /// long prompt. Output is bit-identical either way.
    pub prefill_chunk: usize,
    /// Maximum draft tokens verified per lane per step (`0` =
    /// speculation off). Emitted tokens are **bit-identical** at any
    /// value: drafts are greedily verified against the real model's
    /// logits and rejected tails are rolled back, so `spec_k` only
    /// trades verify-row compute for multi-token steps. Defaults from
    /// `LOBCQ_SPEC_K` (read once).
    pub spec_k: usize,
    /// Which drafter each lane gets ([`DrafterKind::Off`] disables
    /// speculation regardless of `spec_k`).
    pub drafter: DrafterKind,
}

impl Default for ContinuousOpts {
    fn default() -> Self {
        // Read once, like the kernel backend's LOBCQ_FORCE_SCALAR — the
        // CI leg forces speculation over the whole suite this way.
        static SPEC_K: OnceLock<usize> = OnceLock::new();
        let spec_k = *SPEC_K.get_or_init(|| {
            std::env::var("LOBCQ_SPEC_K").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
        });
        ContinuousOpts { prefill_chunk: usize::MAX, spec_k, drafter: DrafterKind::default() }
    }
}

/// Where a lane is in its lifecycle.
enum LaneState {
    /// Prompt staged; chunks still being fed in. Nothing generated yet.
    Prefilling,
    /// Prompt fully cached; `generated` is non-empty.
    Decoding,
}

/// One in-flight request bound to an engine lane.
struct Lane {
    req: Request,
    lane: usize,
    /// Number of tokens this request may generate (its `max_new`, capped
    /// by the engine's per-lane token capacity).
    budget: usize,
    state: LaneState,
    generated: Vec<u32>,
    /// Admission order (monotone): preemption picks the *newest* victim
    /// within the lowest priority class — it has the least sunk work.
    admit_seq: u64,
    picked_at: Instant,
    first_token_at: Instant,
    last_step_at: Instant,
    decode_us: f64,
    max_batch_seen: usize,
    /// Per-lane draft source when speculation is on. Observes the
    /// lane's committed stream only (prompt + emitted tokens) — never
    /// rolled-back draft positions.
    drafter: Option<Box<dyn Drafter>>,
    /// Draft tokens proposed / greedily accepted over this request's
    /// lifetime (the per-request acceptance rate at retirement).
    drafted: usize,
    accepted: usize,
}

/// Drive the engine with default options — inline prefill, the
/// historical contract. See [`run_continuous_opts`].
pub fn run_continuous<E: DecodeEngine + ?Sized>(
    engine: &mut E,
    batcher: &Batcher,
    sampling: Sampling,
    metrics: Option<&ServerMetrics>,
    deliver: impl FnMut(u64, anyhow::Result<Response>),
) {
    run_continuous_opts(engine, batcher, ContinuousOpts::default(), sampling, metrics, deliver)
}

/// Drive the engine until the batcher is closed and drained and every
/// active lane has finished. `deliver` receives each request's terminal
/// event — `Ok(Response)`, the per-request error, or a typed
/// [`ShedError`] — **exactly once per admitted request**, including
/// deferred/preempted requests (requeued, they terminate on a later
/// pass). When `metrics` is given, every fused step records its batch
/// occupancy, queue depth, and the engine's KV-cache page stats.
pub fn run_continuous_opts<E: DecodeEngine + ?Sized>(
    engine: &mut E,
    batcher: &Batcher,
    opts: ContinuousOpts,
    sampling: Sampling,
    metrics: Option<&ServerMetrics>,
    mut deliver: impl FnMut(u64, anyhow::Result<Response>),
) {
    let mut active: Vec<Lane> = Vec::new();
    let mut admit_seq: u64 = 0;
    // Set when the pressure ladder displaced a lane: admitting more work
    // would meet the same wall, so admission holds until a lane retires
    // (frees pages) or the loop runs dry.
    let mut admission_paused = false;
    // Speculation runs only when configured on AND the engine has the
    // stacked-verify/rollback pair; everything else is the plain step.
    let spec_on =
        opts.spec_k > 0 && opts.drafter != DrafterKind::Off && engine.supports_speculation();
    let drafter_kind = if spec_on { Some(opts.drafter) } else { None };
    // Per-step staging, reused across iterations (draft buffers are
    // recycled slot-by-slot so steady-state speculation allocates
    // nothing here either).
    let mut step_idx: Vec<usize> = Vec::new(); // indices into `active`
    let mut step_lanes: Vec<usize> = Vec::new(); // engine lane ids
    let mut step_tokens: Vec<u32> = Vec::new();
    let mut step_drafts: Vec<Vec<u32>> = Vec::new();
    let mut step_emitted: Vec<usize> = Vec::new();
    loop {
        // ---- terminal shed deliveries (deadline-expired at pop) ----
        deliver_shed(batcher, metrics, &mut deliver);
        if let Some(m) = metrics {
            m.record_queue_depth(batcher.len());
        }

        // ---- admission: fill free lanes. Block only when idle. ----
        if active.is_empty() {
            admission_paused = false; // nothing left to free pages; must admit
        }
        while !admission_paused && active.len() < engine.max_concurrency() {
            let req = if active.is_empty() {
                match batcher.pop() {
                    PopResult::Req(r) => r,
                    PopResult::Shed => {
                        deliver_shed(batcher, metrics, &mut deliver);
                        continue;
                    }
                    PopResult::Closed => {
                        // Closed and drained => done. Snapshot the caches
                        // one last time: the final lane releases freed
                        // pages and published prefixes after the last
                        // step's metrics were recorded, so without this
                        // the summary would print pre-shutdown occupancy.
                        record_engine_stats(engine, metrics);
                        deliver_shed(batcher, metrics, &mut deliver);
                        return;
                    }
                }
            } else {
                match batcher.try_pop() {
                    Some(r) => r,
                    None => break, // nothing queued right now; keep decoding
                }
            };
            admit(engine, req, drafter_kind, &mut admit_seq, &mut active, &mut deliver);
        }
        if active.is_empty() {
            // Admission failed (e.g. prefill error on the only request);
            // loop back to blocking pop.
            continue;
        }

        // ---- prefill work. Inline mode runs every staged prompt to
        // completion (a request is decodable the iteration it is
        // admitted); chunked mode spends ONE chunk on the oldest staged
        // prompt, so the decode step below never waits longer than one
        // chunk. ----
        let mut pressured = if opts.prefill_chunk == usize::MAX {
            let mut hit = false;
            let mut i = 0;
            while i < active.len() {
                if !matches!(active[i].state, LaneState::Prefilling) {
                    i += 1; // Done lanes advance past; error-removed lanes re-test `i`
                    continue;
                }
                if advance_prefill(engine, &mut active, i, usize::MAX, sampling, &mut deliver) {
                    hit = true;
                    break;
                }
            }
            hit
        } else if let Some(i) = oldest_prefilling(&active) {
            advance_prefill(engine, &mut active, i, opts.prefill_chunk, sampling, &mut deliver)
        } else {
            false
        };

        let cur = active.len();
        for lane in active.iter_mut() {
            lane.max_batch_seen = lane.max_batch_seen.max(cur);
        }

        // ---- ONE fused decode step across every decoding lane; with
        // speculation on, each lane also stages up to spec_k draft
        // tokens as extra verify rows of the same fused call ----
        let mut finished: Vec<usize> = Vec::new();
        step_idx.clear();
        step_lanes.clear();
        step_tokens.clear();
        let mut drafted_this_step = 0usize;
        if !pressured {
            let mut draft_span =
                if spec_on { Some(crate::obs::trace::span("op", "draft")) } else { None };
            let engine_cap = engine.max_tokens();
            for (idx, lane) in active.iter_mut().enumerate() {
                if matches!(lane.state, LaneState::Prefilling) {
                    continue; // still chunking its prompt in
                }
                if lane.generated.len() >= lane.budget {
                    finished.push(idx);
                    continue;
                }
                step_idx.push(idx);
                step_lanes.push(lane.lane);
                step_tokens.push(*lane.generated.last().unwrap());
                let di = step_idx.len() - 1;
                if step_drafts.len() == di {
                    step_drafts.push(Vec::new());
                }
                step_drafts[di].clear();
                if spec_on {
                    // The cache holds everything but the pending
                    // frontier; cap the draft so budget and lane
                    // capacity can absorb frontier + k + bonus token.
                    let cache_len = lane.req.prompt.len() + lane.generated.len() - 1;
                    let k = opts
                        .spec_k
                        .min(lane.budget - lane.generated.len() - 1)
                        .min(engine_cap.saturating_sub(cache_len + 1));
                    if k > 0 {
                        if let Some(d) = lane.drafter.as_deref_mut() {
                            d.draft(k, &mut step_drafts[di]);
                        }
                    }
                    drafted_this_step += step_drafts[di].len();
                }
            }
            if let Some(s) = draft_span.as_mut() {
                s.set_arg(drafted_this_step as u64);
            }
        }
        if !step_idx.is_empty() {
            let step_rows = step_idx.len() + drafted_this_step;
            if let Some(m) = metrics {
                // Occupancy counts verify rows: the fused GEMMs run at
                // M = rows, which is the utilization the histogram is for.
                m.record_step_occupancy(step_rows);
            }
            let mut step_span = crate::obs::trace::span("sched", "step");
            step_span.set_arg(step_rows as u64);
            let t0 = Instant::now();
            let results = if drafted_this_step > 0 {
                let mut verify_span = crate::obs::trace::span("op", "verify");
                verify_span.set_arg(step_rows as u64);
                engine.decode_batch_spec(&step_lanes, &step_tokens, &step_drafts[..step_idx.len()])
            } else {
                engine.decode_batch(&step_lanes, &step_tokens)
            };
            debug_assert_eq!(results.len(), step_idx.len());
            if results
                .iter()
                .any(|r| matches!(r, Err(e) if e.downcast_ref::<KvPressure>().is_some()))
            {
                // Page pressure fails the whole step with NOTHING
                // consumed (the engine pre-checks the step's pages —
                // draft rows included — before appending), so dropping
                // every result and replaying after relief is bit-exact.
                pressured = true;
                finished.clear();
            } else {
                let step_us = t0.elapsed().as_secs_f64() * 1e6;
                let stepped_at = Instant::now();
                let vocab = engine.vocab();
                step_emitted.clear();
                let (mut step_drafted, mut step_accepted, mut rollbacks) = (0usize, 0usize, 0usize);
                for (si, (&idx, result)) in step_idx.iter().zip(results).enumerate() {
                    let lane = &mut active[idx];
                    match result {
                        Ok(logits) => {
                            // Row r holds the logits after the lane's
                            // r-th fed token; greedily verify the draft
                            // row by row. The sampling step index is the
                            // same prompt+generated count a plain decode
                            // step would use at that position, so the
                            // emitted sequence is bit-identical.
                            let rows = logits.len() / vocab;
                            let k = rows - 1;
                            debug_assert_eq!(k, step_drafts[si].len());
                            let mut emitted = 0usize;
                            for m in 0..rows {
                                let row = &logits[m * vocab..(m + 1) * vocab];
                                let step = lane.req.prompt.len() + lane.generated.len();
                                let t = sample_from_logits(row, sampling, lane.req.id, step);
                                lane.generated.push(t);
                                if let Some(d) = lane.drafter.as_deref_mut() {
                                    d.observe(t);
                                }
                                emitted += 1;
                                if m < k && t != step_drafts[si][m] {
                                    break; // rejection: rows past m are garbage
                                }
                            }
                            lane.last_step_at = stepped_at;
                            let mut dead = false;
                            if k > 0 {
                                let j = emitted - 1; // accepted draft prefix
                                lane.drafted += k;
                                lane.accepted += j;
                                step_drafted += k;
                                step_accepted += j;
                                crate::obs::trace::lifecycle("speculation", lane.req.id, j as u64);
                                if j < k {
                                    // Erase the rejected tail: the cache
                                    // keeps exactly the positions behind
                                    // the pending frontier, same as a
                                    // lane that never speculated.
                                    let keep = lane.req.prompt.len() + lane.generated.len() - 1;
                                    let _rb =
                                        crate::obs::trace::span_id("op", "rollback", lane.req.id);
                                    rollbacks += 1;
                                    if let Err(e) = engine.truncate(lane.lane, keep) {
                                        crate::obs::trace::lifecycle("failed", lane.req.id, 0);
                                        deliver(
                                            lane.req.id,
                                            Err(anyhow::anyhow!("speculative rollback failed: {e}")),
                                        );
                                        lane.generated.clear();
                                        finished.push(idx);
                                        dead = true;
                                    }
                                }
                            }
                            step_emitted.push(if dead { 0 } else { emitted });
                            if !dead && lane.generated.len() >= lane.budget {
                                finished.push(idx);
                            }
                        }
                        Err(e) => {
                            crate::obs::trace::lifecycle("failed", lane.req.id, 0);
                            deliver(lane.req.id, Err(anyhow::anyhow!("decode failed: {e}")));
                            lane.generated.clear(); // mark dead: the retire loop below
                            finished.push(idx); // releases the lane, delivers nothing
                            step_emitted.push(0);
                        }
                    }
                }
                // The step's wall time is shared work; attribute it per
                // EMITTED token, so a verify step that accepted j tokens
                // books step_time * (j+1)/total to that lane — honest
                // per-token latency under speculation (single-token
                // steps degenerate to the old equal share).
                let total_emitted: usize = step_emitted.iter().sum();
                if total_emitted > 0 {
                    let per_tok = step_us / total_emitted as f64;
                    for (&idx, &em) in step_idx.iter().zip(&step_emitted) {
                        if em > 0 {
                            active[idx].decode_us += per_tok * em as f64;
                        }
                    }
                }
                if step_drafted > 0 {
                    if let Some(m) = metrics {
                        m.record_spec_step(step_drafted, step_accepted, rollbacks);
                    }
                }
            }
            drop(step_span); // bound the step span to the fused engine call
            record_engine_stats(engine, metrics);
        }
        if pressured {
            relieve_kv_pressure(engine, &mut active, batcher, metrics, &mut admission_paused, &mut deliver);
            continue;
        }

        // ---- retire finished lanes (slots free => next admission pass
        // backfills them). Budget-finished and step-finished indices
        // interleave, so order them before the descending swap_remove
        // sweep. ----
        finished.sort_unstable();
        for idx in finished.into_iter().rev() {
            let lane = active.swap_remove(idx);
            engine.release(lane.lane);
            admission_paused = false; // freed pages: re-open admission
            if lane.generated.is_empty() {
                continue; // errored above; already delivered
            }
            let done = Instant::now();
            let n = lane.generated.len();
            let itl_us = if n > 1 {
                (lane.last_step_at - lane.first_token_at).as_secs_f64() * 1e6 / (n - 1) as f64
            } else {
                0.0
            };
            crate::obs::trace::lifecycle("finished", lane.req.id, n as u64);
            crate::obs::trace::complete("request", "request", lane.req.id, n as u64, lane.req.submitted_at);
            if lane.drafted > 0 {
                if let Some(m) = metrics {
                    m.record_spec_acceptance(lane.accepted as f64 / lane.drafted as f64);
                }
            }
            deliver(
                lane.req.id,
                Ok(Response {
                    id: lane.req.id,
                    priority: lane.req.priority,
                    tokens: lane.generated,
                    queue_us: (lane.picked_at - lane.req.submitted_at).as_secs_f64() * 1e6,
                    execute_us: lane.decode_us,
                    ttft_us: (lane.first_token_at - lane.req.submitted_at).as_secs_f64() * 1e6,
                    itl_us,
                    total_us: (done - lane.req.submitted_at).as_secs_f64() * 1e6,
                    batch_size: lane.max_batch_seen,
                }),
            );
        }
    }
}

/// Deliver the terminal error for every deadline-shed request.
fn deliver_shed(
    batcher: &Batcher,
    metrics: Option<&ServerMetrics>,
    deliver: &mut impl FnMut(u64, anyhow::Result<Response>),
) {
    for r in batcher.drain_shed() {
        if let Some(m) = metrics {
            m.record_shed(ShedReason::DeadlineExpired);
        }
        crate::obs::trace::lifecycle("shed-deadline", r.id, 0);
        deliver(r.id, Err(ShedError { id: r.id, reason: ShedReason::DeadlineExpired }.into()));
    }
}

/// Graceful-degradation ladder for a typed KV-pressure event. Each rung
/// either frees capacity for a retry (the failed chunk/step replays
/// bit-exactly — nothing was consumed) or displaces work:
///
/// 1. **Evict** the engine's prefix cache (cached-but-unpinned pages).
/// 2. **Defer** the newest still-prefilling admission — requeued at the
///    front of its class, it has no generated tokens to lose.
/// 3. **Preempt** the lowest-priority newest decoding lane — requeued
///    for full replay; deterministic sampling regenerates its tokens
///    bit-identically.
/// 4. **Shed** the sole remaining lane with a typed [`ShedError`]: one
///    lane holding every page and still failing means the request
///    simply does not fit the budget. Never panic, never spin.
///
/// Rungs 2 and 3 pause admission until a lane retires, so the displaced
/// request is not immediately readmitted into the same wall.
fn relieve_kv_pressure<E: DecodeEngine + ?Sized>(
    engine: &mut E,
    active: &mut Vec<Lane>,
    batcher: &Batcher,
    metrics: Option<&ServerMetrics>,
    admission_paused: &mut bool,
    deliver: &mut impl FnMut(u64, anyhow::Result<Response>),
) {
    if engine.relieve_pressure() > 0 {
        return;
    }
    if active.len() > 1 {
        let (idx, deferred) = match newest_prefilling(active) {
            Some(i) => (i, true),
            None => (preempt_victim(active), false),
        };
        let lane = active.remove(idx);
        engine.release(lane.lane);
        crate::obs::trace::lifecycle(
            if deferred { "deferred" } else { "preempted" },
            lane.req.id,
            lane.generated.len() as u64,
        );
        batcher.push_front(lane.req);
        *admission_paused = true;
        if let Some(m) = metrics {
            if deferred {
                m.record_deferred();
            } else {
                m.record_preempted();
            }
        }
        return;
    }
    if let Some(lane) = active.pop() {
        engine.release(lane.lane);
        if let Some(m) = metrics {
            m.record_shed(ShedReason::KvPressure);
        }
        crate::obs::trace::lifecycle("shed-kv", lane.req.id, 0);
        deliver(lane.req.id, Err(ShedError { id: lane.req.id, reason: ShedReason::KvPressure }.into()));
    }
}

fn newest_prefilling(active: &[Lane]) -> Option<usize> {
    active
        .iter()
        .enumerate()
        .filter(|(_, l)| matches!(l.state, LaneState::Prefilling))
        .max_by_key(|(_, l)| l.admit_seq)
        .map(|(i, _)| i)
}

fn oldest_prefilling(active: &[Lane]) -> Option<usize> {
    active
        .iter()
        .enumerate()
        .filter(|(_, l)| matches!(l.state, LaneState::Prefilling))
        .min_by_key(|(_, l)| l.admit_seq)
        .map(|(i, _)| i)
}

/// Lowest priority class first, newest admission within it (least sunk
/// decode work to throw away). Only called with `active` non-empty.
fn preempt_victim(active: &[Lane]) -> usize {
    active
        .iter()
        .enumerate()
        .min_by_key(|(_, l)| (l.req.priority, std::cmp::Reverse(l.admit_seq)))
        .map(|(i, _)| i)
        .unwrap()
}

/// Feed one chunk of prefill to `active[idx]`. Returns `true` on a
/// typed KV-pressure failure (lane left intact and retryable — the
/// caller walks the ladder). Other errors terminate the request and
/// remove the lane here.
fn advance_prefill<E: DecodeEngine + ?Sized>(
    engine: &mut E,
    active: &mut Vec<Lane>,
    idx: usize,
    chunk: usize,
    sampling: Sampling,
    deliver: &mut impl FnMut(u64, anyhow::Result<Response>),
) -> bool {
    let t0 = Instant::now();
    let lane = &mut active[idx];
    match engine.prefill_chunk(lane.lane, &lane.req.prompt, chunk) {
        Ok(PrefillProgress::Pending { done }) => {
            crate::obs::trace::lifecycle("chunked", lane.req.id, done as u64);
            lane.decode_us += t0.elapsed().as_secs_f64() * 1e6;
            false
        }
        Ok(PrefillProgress::Done(logits)) => {
            crate::obs::trace::lifecycle("staged", lane.req.id, lane.req.prompt.len() as u64);
            lane.decode_us += t0.elapsed().as_secs_f64() * 1e6;
            let now = Instant::now();
            lane.first_token_at = now;
            lane.last_step_at = now;
            let first = sample_from_logits(&logits, sampling, lane.req.id, lane.req.prompt.len());
            lane.generated.push(first);
            if let Some(d) = lane.drafter.as_deref_mut() {
                d.observe(first);
            }
            lane.state = LaneState::Decoding;
            false
        }
        Err(e) => {
            if e.downcast_ref::<KvPressure>().is_some() {
                return true;
            }
            let lane = active.remove(idx);
            engine.release(lane.lane);
            crate::obs::trace::lifecycle("failed", lane.req.id, 0);
            deliver(lane.req.id, Err(anyhow::anyhow!("prefill failed: {e}")));
            false
        }
    }
}

/// Record the engine's cache snapshots (KV occupancy + prefix-cache
/// counters) — one definition shared by the per-step and final-drain
/// sites, so a new engine-side stat can't be wired into one and
/// silently skew the other.
fn record_engine_stats<E: DecodeEngine + ?Sized>(engine: &E, metrics: Option<&ServerMetrics>) {
    let Some(m) = metrics else { return };
    if let Some(kv) = engine.kv_stats() {
        m.record_kv_stats(kv);
    }
    if let Some(ps) = engine.prefix_stats() {
        m.record_prefix_stats(ps);
    }
    if let Some((hits, decodes)) = engine.panel_stats() {
        m.record_panel_stats(hits, decodes);
    }
}

fn admit<E: DecodeEngine + ?Sized>(
    engine: &mut E,
    req: Request,
    drafter_kind: Option<DrafterKind>,
    admit_seq: &mut u64,
    active: &mut Vec<Lane>,
    deliver: &mut impl FnMut(u64, anyhow::Result<Response>),
) {
    let picked_at = Instant::now();
    // Generating n tokens appends cache positions up to
    // prompt + n - 1; cap the budget at the engine's lane capacity.
    let cap = engine.max_tokens().saturating_sub(req.prompt.len()) + 1;
    let budget = req.max_new.min(cap).max(1);
    // A deferred/preempted request re-admits: it may log "admitted"
    // more than once, but still reaches exactly one terminal event.
    // (Its drafter is rebuilt from scratch each time — fed the prompt
    // here and each emitted token later, so a preempted replay observes
    // the identical stream.)
    crate::obs::trace::lifecycle("admitted", req.id, req.prompt.len() as u64);
    let mut drafter = drafter_kind.and_then(|k| k.build());
    if let Some(d) = drafter.as_deref_mut() {
        for &t in &req.prompt {
            d.observe(t);
        }
    }
    match engine.begin_prefill(&req.prompt) {
        Ok(lane) => {
            *admit_seq += 1;
            active.push(Lane {
                req,
                lane,
                budget,
                state: LaneState::Prefilling,
                generated: Vec::new(),
                admit_seq: *admit_seq,
                picked_at,
                first_token_at: picked_at,
                last_step_at: picked_at,
                decode_us: 0.0,
                max_batch_seen: 0,
                drafter,
                drafted: 0,
                accepted: 0,
            });
        }
        Err(e) => {
            crate::obs::trace::lifecycle("failed", req.id, 0);
            deliver(req.id, Err(anyhow::anyhow!("prefill failed: {e}")));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::session::MockDecodeEngine;
    use std::time::{Duration, Instant};

    fn req(id: u64, prompt: Vec<u32>, max_new: usize) -> Request {
        Request::new(id, prompt, max_new)
    }

    fn zero_wait() -> BatchPolicy {
        BatchPolicy { max_batch: 8, max_wait: Duration::ZERO, queue_cap: None }
    }

    fn chunked_opts(chunk: usize) -> ContinuousOpts {
        ContinuousOpts { prefill_chunk: chunk, ..ContinuousOpts::default() }
    }

    fn spec_opts(k: usize, drafter: DrafterKind) -> ContinuousOpts {
        ContinuousOpts { prefill_chunk: usize::MAX, spec_k: k, drafter }
    }

    fn drive(engine: &mut MockDecodeEngine, reqs: Vec<Request>) -> Vec<(u64, anyhow::Result<Response>)> {
        drive_opts(engine, reqs, ContinuousOpts::default(), None)
    }

    fn drive_opts(
        engine: &mut MockDecodeEngine,
        reqs: Vec<Request>,
        opts: ContinuousOpts,
        metrics: Option<&crate::coordinator::metrics::ServerMetrics>,
    ) -> Vec<(u64, anyhow::Result<Response>)> {
        let b = Batcher::new(zero_wait());
        for r in reqs {
            assert!(b.push(r).is_accepted());
        }
        b.close();
        let mut out = Vec::new();
        run_continuous_opts(engine, &b, opts, Sampling::Greedy, metrics, |id, r| out.push((id, r)));
        out
    }

    #[test]
    fn follows_successor_rule_and_answers_everything() {
        let mut e = MockDecodeEngine::new(4, 32);
        let out = drive(
            &mut e,
            vec![req(1, vec![5], 4), req(2, vec![9, 10], 3), req(3, vec![1], 1)],
        );
        assert_eq!(out.len(), 3);
        let get = |id: u64| {
            out.iter().find(|(i, _)| *i == id).unwrap().1.as_ref().unwrap().clone()
        };
        // Mock predicts tok+1: prefill samples the first token.
        assert_eq!(get(1).tokens, vec![6, 7, 8, 9]);
        assert_eq!(get(2).tokens, vec![11, 12, 13]);
        assert_eq!(get(3).tokens, vec![2]);
        assert_eq!(get(3).itl_us, 0.0, "single-token response has an ITL");
        assert!(get(1).ttft_us > 0.0);
        assert_eq!(e.releases, 3);
        // 3 prefills + decodes: req1 needs 3 steps, req2 needs 2, req3 0.
        assert_eq!(e.prefills, 3);
        assert_eq!(e.decodes, 5);
        // Fused stepping: co-live lanes decode in ONE engine call per
        // step, never one call per lane. Step 1 ran lanes 1+2 together.
        assert!(e.batch_calls < e.decodes, "every decode got its own engine call");
        assert_eq!(e.max_batch_lanes, 2, "co-live lanes not stepped together");
    }

    #[test]
    fn records_occupancy_and_shares_step_time() {
        use crate::coordinator::metrics::ServerMetrics;
        let m = ServerMetrics::new();
        let b = Batcher::new(zero_wait());
        assert!(b.push(req(1, vec![1], 3)).is_accepted());
        assert!(b.push(req(2, vec![2], 3)).is_accepted());
        b.close();
        let mut e = MockDecodeEngine::new(4, 32);
        let mut out = Vec::new();
        run_continuous(&mut e, &b, Sampling::Greedy, Some(&m), |id, r| out.push((id, r)));
        assert_eq!(out.len(), 2);
        let s = m.snapshot();
        // Both lanes admitted before the first step: 2 steps at
        // occupancy 2 (each generates 2 more tokens after prefill).
        assert_eq!(s.occupancy_hist, vec![(2, 2)]);
        assert!((s.mean_occupancy - 2.0).abs() < 1e-9);
        assert!(s.kv.is_none(), "mock engine grew a KV cache");
    }

    #[test]
    fn backfills_freed_lanes_mid_batch() {
        // 2 lanes, 5 requests: short requests finish and free lanes that
        // later requests reuse while the long one is still decoding.
        let mut e = MockDecodeEngine::new(2, 64);
        let reqs = vec![
            req(1, vec![1], 8), // long
            req(2, vec![2], 1), // finishes at admission
            req(3, vec![3], 2),
            req(4, vec![4], 2),
            req(5, vec![5], 1),
        ];
        let out = drive(&mut e, reqs);
        assert_eq!(out.len(), 5);
        assert!(out.iter().all(|(_, r)| r.is_ok()));
        assert_eq!(e.max_live_seen, 2, "never used both lanes");
        assert_eq!(e.releases, 5, "lanes leaked");
        // The long request saw company: batch_size reflects sharing.
        let long = out.iter().find(|(i, _)| *i == 1).unwrap().1.as_ref().unwrap();
        assert_eq!(long.tokens.len(), 8);
        assert!(long.batch_size >= 2, "no backfill observed");
        // FIFO admission: request 5 must not be answered before 2.
        let pos = |id: u64| out.iter().position(|(i, _)| *i == id).unwrap();
        assert!(pos(2) < pos(5));
    }

    #[test]
    fn poisoned_request_fails_alone() {
        let mut e = MockDecodeEngine::new(2, 32);
        // Request 1 decodes from token 5 -> 6 -> poisoned at decode(6).
        e.poison_token = Some(6);
        let out = drive(&mut e, vec![req(1, vec![5], 4), req(2, vec![20], 3)]);
        let r1 = &out.iter().find(|(i, _)| *i == 1).unwrap().1;
        let r2 = &out.iter().find(|(i, _)| *i == 2).unwrap().1;
        assert!(r1.is_err(), "poisoned request succeeded");
        assert_eq!(r2.as_ref().unwrap().tokens, vec![21, 22, 23], "healthy lane dragged down");
        assert_eq!(e.releases, 2, "errored lane leaked");
    }

    #[test]
    fn budget_is_capped_by_engine_capacity() {
        let mut e = MockDecodeEngine::new(1, 32);
        e.max_tokens = 4;
        // prompt 3 tokens + budget cap => 4 - 3 + 1 = 2 tokens max.
        let out = drive(&mut e, vec![req(1, vec![1, 2, 3], 10)]);
        assert_eq!(out[0].1.as_ref().unwrap().tokens.len(), 2);
    }

    #[test]
    fn chunked_prefill_matches_inline_token_for_token() {
        let reqs = || {
            vec![
                req(1, (0..7).map(|i| i * 3 % 32).collect(), 4),
                req(2, vec![9, 10, 11], 3),
                req(3, vec![1], 2),
            ]
        };
        let mut inline = MockDecodeEngine::new(2, 32);
        let mut chunked = MockDecodeEngine::new(2, 32);
        let a = drive(&mut inline, reqs());
        let b = drive_opts(&mut chunked, reqs(), chunked_opts(2), None);
        assert!(chunked.chunk_calls > inline.chunk_calls, "chunking never split a prompt");
        for id in [1u64, 2, 3] {
            let find = |o: &[(u64, anyhow::Result<Response>)]| {
                o.iter().find(|(i, _)| *i == id).unwrap().1.as_ref().unwrap().tokens.clone()
            };
            assert_eq!(find(&a), find(&b), "request {id} diverged under chunked prefill");
        }
        assert_eq!(chunked.releases, 3, "chunked run leaked lanes");
    }

    #[test]
    fn deadline_expired_request_is_shed_with_typed_error() {
        use crate::coordinator::metrics::ServerMetrics;
        let m = ServerMetrics::new();
        let mut e = MockDecodeEngine::new(2, 32);
        let past = Instant::now() - Duration::from_millis(1);
        let out = drive_opts(
            &mut e,
            vec![req(1, vec![5], 2).with_deadline(Some(past)), req(2, vec![9], 2)],
            ContinuousOpts::default(),
            Some(&m),
        );
        assert_eq!(out.len(), 2, "shed request got no terminal event");
        let r1 = out.iter().find(|(i, _)| *i == 1).unwrap().1.as_ref().expect_err("expired decoded");
        let shed = r1.downcast_ref::<ShedError>().expect("shed error lost its type");
        assert_eq!(shed.reason, ShedReason::DeadlineExpired);
        assert!(out.iter().find(|(i, _)| *i == 2).unwrap().1.is_ok());
        assert_eq!(e.prefills, 1, "expired request reached the engine");
    }

    #[test]
    fn kv_pressure_relieves_evictable_pool_then_recovers() {
        let mut e = MockDecodeEngine::new(2, 32);
        e.kv_capacity = Some(6);
        e.kv_evictable = 2; // mock "prefix cache" — rung 1 reclaims this
        let out = drive(&mut e, vec![req(1, vec![1], 4), req(2, vec![2, 3], 2)]);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|(_, r)| r.is_ok()), "pressure leaked into a response error");
        assert_eq!(e.relieve_calls, 1, "rung 1 not exercised exactly once");
        assert_eq!((e.releases, e.kv_used()), (2, 0), "lanes or KV leaked");
    }

    #[test]
    fn kv_pressure_preempts_newest_and_replays_bit_identically() {
        use crate::coordinator::metrics::ServerMetrics;
        let m = ServerMetrics::new();
        let mut e = MockDecodeEngine::new(2, 32);
        // Both lanes fit their prefill, but the second co-decoded step
        // busts the budget: rung 3 preempts the newest lane (no
        // prefilling lanes exist, no evictable pool).
        e.kv_capacity = Some(5);
        let out = drive_opts(
            &mut e,
            vec![req(1, vec![1], 4), req(2, vec![7], 4)],
            ContinuousOpts::default(),
            Some(&m),
        );
        assert_eq!(out.len(), 2);
        // The preempted request replays from scratch and — deterministic
        // sampling — regenerates the exact same successor chain.
        let get = |id: u64| out.iter().find(|(i, _)| *i == id).unwrap().1.as_ref().unwrap().clone();
        assert_eq!(get(1).tokens, vec![2, 3, 4, 5]);
        assert_eq!(get(2).tokens, vec![8, 9, 10, 11]);
        assert_eq!(e.prefills, 3, "victim not readmitted via requeue");
        assert_eq!(e.releases, 3, "preempted lane leaked");
        assert_eq!(m.snapshot().preempted, 1);
        assert_eq!(e.kv_used(), 0);
    }

    #[test]
    fn sole_oversized_request_is_shed_not_panicked() {
        use crate::coordinator::metrics::ServerMetrics;
        let m = ServerMetrics::new();
        let mut e = MockDecodeEngine::new(2, 32);
        e.kv_capacity = Some(3);
        let out = drive_opts(
            &mut e,
            vec![req(1, (0..5).collect(), 4)], // 5 prompt tokens > 3-token budget
            chunked_opts(2),
            Some(&m),
        );
        assert_eq!(out.len(), 1, "shed request got no terminal event");
        let err = out[0].1.as_ref().expect_err("over-budget request succeeded");
        let shed = err.downcast_ref::<ShedError>().expect("terminal shed lost its type");
        assert_eq!(shed.reason, ShedReason::KvPressure);
        assert_eq!(e.releases, e.prefills, "shed lane leaked");
        assert_eq!(e.kv_used(), 0, "shed lane's KV not reclaimed");
        assert_eq!(m.snapshot().shed_kv, 1);
    }

    #[test]
    fn pressure_during_chunked_prefill_defers_the_admission() {
        use crate::coordinator::metrics::ServerMetrics;
        let m = ServerMetrics::new();
        let mut e = MockDecodeEngine::new(2, 32);
        // Request 1's growing decode state plus request 2's chunked-in
        // prompt bust the budget mid-prefill: the staged admission is
        // deferred (requeued, its partial KV freed), request 1 runs to
        // completion, and request 2 is readmitted and finishes — one
        // terminal event each, no leaks, no panic.
        e.kv_capacity = Some(6);
        let out = drive_opts(
            &mut e,
            vec![req(1, vec![1], 6), req(2, vec![4, 5, 6, 7], 1)],
            chunked_opts(2),
            Some(&m),
        );
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|(_, r)| r.is_ok()), "deferred request never completed");
        let get = |id: u64| out.iter().find(|(i, _)| *i == id).unwrap().1.as_ref().unwrap().clone();
        assert_eq!(get(1).tokens, vec![2, 3, 4, 5, 6, 7]);
        assert_eq!(get(2).tokens, vec![8]);
        assert_eq!(m.snapshot().deferred, 1, "staged admission not deferred under pressure");
        assert_eq!(e.prefills, 3, "deferred request not readmitted via requeue");
        assert_eq!(e.releases, e.prefills, "lane leak across defer/readmit");
        assert_eq!(e.kv_used(), 0);
    }

    #[test]
    fn ngram_speculation_is_bit_identical_with_high_acceptance() {
        use crate::coordinator::metrics::ServerMetrics;
        let m = ServerMetrics::new();
        // Vocab 8: the mock's successor stream wraps after one lap, so
        // the n-gram drafter learns the cycle and then drafts the exact
        // continuation the model will emit — full acceptance, multi-token
        // steps, zero rollbacks.
        let mut plain = MockDecodeEngine::new(1, 8);
        let a =
            drive_opts(&mut plain, vec![req(1, vec![5], 16)], spec_opts(0, DrafterKind::Off), None);
        let mut spec = MockDecodeEngine::new(1, 8);
        let b = drive_opts(
            &mut spec,
            vec![req(1, vec![5], 16)],
            spec_opts(4, DrafterKind::NGram),
            Some(&m),
        );
        let ta = &a[0].1.as_ref().unwrap().tokens;
        let tb = &b[0].1.as_ref().unwrap().tokens;
        assert_eq!(ta, tb, "speculation changed the emitted sequence");
        assert_eq!(tb.len(), 16);
        assert!(spec.spec_calls > 0, "no speculative step ran");
        assert_eq!(spec.truncate_calls, 0, "perfect drafts still rolled back");
        // Multi-token steps mean fewer engine calls for the same tokens.
        assert!(
            spec.batch_calls + spec.spec_calls < plain.batch_calls,
            "{}+{} spec-run calls vs {} plain",
            spec.batch_calls,
            spec.spec_calls,
            plain.batch_calls
        );
        let s = m.snapshot();
        let sp = s.spec.expect("speculative run published no spec stats");
        assert_eq!((sp.steps, sp.drafted, sp.accepted), (2, 6, 6));
        assert_eq!((sp.wasted, sp.rollbacks, sp.lanes), (0, 0, 1));
        assert!((sp.acceptance_mean_pct - 100.0).abs() < 1e-9, "{}", sp.acceptance_mean_pct);
        // Occupancy counts verify rows, not lanes: a 1-lane run with
        // k=4 drafts shows fused steps wider than the lane count.
        assert!(
            s.occupancy_hist.iter().any(|&(rows, _)| rows > 1),
            "verify rows missing from occupancy: {:?}",
            s.occupancy_hist
        );
    }

    #[test]
    fn always_wrong_drafter_rolls_back_and_stays_bit_identical() {
        use crate::coordinator::metrics::ServerMetrics;
        let m = ServerMetrics::new();
        let reqs = || vec![req(1, vec![5], 4), req(2, vec![9, 10], 3)];
        let mut plain = MockDecodeEngine::new(2, 32);
        let a = drive_opts(&mut plain, reqs(), spec_opts(0, DrafterKind::Off), None);
        // Token 31 never appears in either successor stream, so every
        // draft is fully rejected and every speculative step rolls back.
        let mut spec = MockDecodeEngine::new(2, 32);
        let wrong = DrafterKind::AlwaysWrong { token: 31 };
        let b = drive_opts(&mut spec, reqs(), spec_opts(3, wrong), Some(&m));
        for id in [1u64, 2] {
            let find = |o: &[(u64, anyhow::Result<Response>)]| {
                o.iter().find(|(i, _)| *i == id).unwrap().1.as_ref().unwrap().tokens.clone()
            };
            assert_eq!(find(&a), find(&b), "request {id} diverged under adversarial drafting");
        }
        assert!(spec.spec_calls > 0, "no speculative step ran");
        assert!(spec.truncate_calls > 0, "full rejection never rolled back");
        assert_eq!((spec.releases, spec.kv_used()), (2, 0), "rollback leaked lanes or KV");
        let sp = m.snapshot().spec.expect("no spec stats");
        assert_eq!(sp.accepted, 0, "always-wrong drafts got accepted");
        assert_eq!(sp.wasted, sp.drafted);
        assert_eq!(sp.rollbacks, spec.truncate_calls as u64);
        assert_eq!(sp.lanes, 2);
        assert_eq!(sp.acceptance_mean_pct, 0.0);
    }

    #[test]
    fn kv_pressure_during_verify_step_replays_bit_exactly() {
        use crate::coordinator::metrics::ServerMetrics;
        let m = ServerMetrics::new();
        let mut e = MockDecodeEngine::new(2, 32);
        // Both prefills fit, but the first co-decoded verify step needs
        // 2 lanes x (1 frontier + 2 draft) = 6 rows on top of 2 cached
        // tokens > 7: the engine pre-checks and consumes NOTHING, the
        // ladder preempts the newest lane, and both requests still emit
        // the exact successor chains.
        e.kv_capacity = Some(7);
        let wrong = DrafterKind::AlwaysWrong { token: 31 };
        let out = drive_opts(
            &mut e,
            vec![req(1, vec![1], 4), req(2, vec![7], 4)],
            spec_opts(2, wrong),
            Some(&m),
        );
        assert_eq!(out.len(), 2);
        let get = |id: u64| out.iter().find(|(i, _)| *i == id).unwrap().1.as_ref().unwrap().clone();
        assert_eq!(get(1).tokens, vec![2, 3, 4, 5]);
        assert_eq!(get(2).tokens, vec![8, 9, 10, 11]);
        let s = m.snapshot();
        assert_eq!(s.preempted, 1, "verify-step pressure never walked the ladder");
        assert!(s.spec.unwrap().rollbacks > 0, "rejections stopped rolling back after relief");
        assert!(e.truncate_calls > 0);
        assert_eq!(e.releases, 3, "preempted lane leaked");
        assert_eq!(e.kv_used(), 0);
    }

    #[test]
    fn per_token_itl_attribution_under_speculation() {
        use crate::coordinator::metrics::ServerMetrics;
        // An accepted multi-token step books its wall time across every
        // emitted token, so ITL under speculation reflects per-token
        // cost, not per-step cost. With full acceptance the execute time
        // still sums to the steps' wall time (smoke-level: positive and
        // finite, exact timing is wall-clock).
        let m = ServerMetrics::new();
        let mut e = MockDecodeEngine::new(1, 8);
        let opts = spec_opts(4, DrafterKind::NGram);
        let out = drive_opts(&mut e, vec![req(1, vec![5], 16)], opts, Some(&m));
        let r = out[0].1.as_ref().unwrap();
        assert_eq!(r.tokens.len(), 16);
        assert!(r.execute_us > 0.0 && r.execute_us.is_finite());
        assert!(r.itl_us > 0.0, "multi-token response lost its ITL");
        let s = m.snapshot();
        assert!(s.itl_p50_us > 0.0);
    }
}
