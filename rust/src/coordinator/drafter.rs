//! Draft-token proposal for speculative decoding (DESIGN.md
//! §Speculative decoding). A [`Drafter`] is the cheap half of the
//! drafter/verifier loop: per lane, it watches the token stream (prompt
//! + everything generated so far) and proposes up to `k` continuation
//! tokens that the fused stacked-verify step
//! ([`decode_step_batch_spec`](crate::model::decode::decode_step_batch_spec))
//! then checks in one W4A4 forward. Drafters only ever *propose* —
//! verification is greedy against the real model's logits, so a bad
//! drafter costs wasted verify rows, never a wrong token: emitted
//! sequences stay bit-identical to non-speculative decode regardless of
//! what is drafted.
//!
//! The trait is deliberately minimal (observe tokens, emit a draft) so
//! a reduced-layer self-draft model can slot in behind the same seam
//! later; today's implementation is [`NGramDrafter`], a suffix-lookup
//! (bigram) table over the lane's own history — free to build, and
//! effective exactly on the repetitive continuations where speculation
//! pays (code, templated text, the bench's looped corpus).

use std::collections::HashMap;

/// Per-lane draft-token source. One instance per lane: `observe` feeds
/// it every token the lane has committed (prompt tokens at admission,
/// then each accepted/corrected token as it is emitted), `draft`
/// proposes up to `k` tokens extending that history.
pub trait Drafter: Send {
    /// Feed one committed token of this lane's stream. Called for every
    /// prompt token and every emitted token, in order — including
    /// tokens that replaced a rejected draft, so the drafter's view
    /// never contains rolled-back tokens.
    fn observe(&mut self, token: u32);

    /// Propose up to `k` tokens continuing the observed stream into
    /// `out` (cleared first). Fewer than `k` — including zero — is
    /// always legal; an empty draft makes the scheduler fall back to
    /// the plain fused step for that round.
    fn draft(&mut self, k: usize, out: &mut Vec<u32>);
}

/// Suffix-lookup drafter: remembers, for every token, the token that
/// most recently followed it, and drafts by walking that successor map
/// from the frontier — proposing the continuation the lane itself
/// produced last time it was at this token. Last occurrence wins, so
/// the table adapts as the stream drifts. O(1) per observe, O(k) per
/// draft, one map entry per distinct token seen.
#[derive(Debug, Default)]
pub struct NGramDrafter {
    /// token → the token that most recently followed it.
    next: HashMap<u32, u32>,
    /// Most recently observed token (the frontier the draft extends).
    last: Option<u32>,
}

impl NGramDrafter {
    pub fn new() -> NGramDrafter {
        NGramDrafter::default()
    }
}

impl Drafter for NGramDrafter {
    fn observe(&mut self, token: u32) {
        if let Some(prev) = self.last {
            self.next.insert(prev, token);
        }
        self.last = Some(token);
    }

    fn draft(&mut self, k: usize, out: &mut Vec<u32>) {
        out.clear();
        let Some(mut cur) = self.last else { return };
        for _ in 0..k {
            // Walk the successor chain speculatively — each hop assumes
            // the previous proposal is accepted, which is exactly what
            // the stacked verify checks position by position.
            match self.next.get(&cur) {
                Some(&nxt) => {
                    out.push(nxt);
                    cur = nxt;
                }
                None => break,
            }
        }
    }
}

/// Adversarial drafter for tests: always proposes `k` copies of a fixed
/// token, so on any stream where the model never emits that token every
/// draft is fully rejected and every speculative step exercises the
/// rollback path. The bit-exactness property tests lean on it — a
/// system that survives an always-wrong drafter unchanged survives any
/// drafter.
#[derive(Debug)]
pub struct AlwaysWrongDrafter {
    pub token: u32,
}

impl Drafter for AlwaysWrongDrafter {
    fn observe(&mut self, _token: u32) {}

    fn draft(&mut self, k: usize, out: &mut Vec<u32>) {
        out.clear();
        out.resize(k, self.token);
    }
}

/// Which drafter a serving run builds per lane — the `--drafter` CLI
/// knob. `Off` disables speculation even when `spec_k > 0`.
/// `AlwaysWrong` is test-only (not parseable from the CLI): it forces a
/// full rejection + rollback on every speculative step, the adversarial
/// half of the bit-exactness property tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DrafterKind {
    #[default]
    NGram,
    Off,
    AlwaysWrong { token: u32 },
}

impl DrafterKind {
    pub fn name(self) -> &'static str {
        match self {
            DrafterKind::NGram => "ngram",
            DrafterKind::Off => "off",
            DrafterKind::AlwaysWrong { .. } => "always-wrong",
        }
    }

    /// Parse the `--drafter` argument.
    pub fn parse(s: &str) -> anyhow::Result<DrafterKind> {
        match s {
            "ngram" => Ok(DrafterKind::NGram),
            "off" => Ok(DrafterKind::Off),
            _ => anyhow::bail!("unknown drafter {s:?} (expected ngram|off)"),
        }
    }

    /// Build one lane's drafter, fed nothing yet.
    pub fn build(self) -> Option<Box<dyn Drafter>> {
        match self {
            DrafterKind::NGram => Some(Box::new(NGramDrafter::new())),
            DrafterKind::Off => None,
            DrafterKind::AlwaysWrong { token } => Some(Box::new(AlwaysWrongDrafter { token })),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ngram_drafts_repetitive_continuations() {
        let mut d = NGramDrafter::new();
        for &t in &[1u32, 2, 3, 1, 2, 3, 1] {
            d.observe(t);
        }
        let mut out = Vec::new();
        d.draft(4, &mut out);
        // Frontier is 1; the cycle 1→2→3→1 replays for as many tokens
        // as asked.
        assert_eq!(out, vec![2, 3, 1, 2]);
        d.draft(2, &mut out);
        assert_eq!(out, vec![2, 3]);
    }

    #[test]
    fn ngram_last_occurrence_wins_and_cold_start_is_empty() {
        let mut d = NGramDrafter::new();
        let mut out = vec![99];
        d.draft(3, &mut out);
        assert!(out.is_empty(), "cold drafter must propose nothing");
        for &t in &[5u32, 6, 5, 7] {
            d.observe(t);
        }
        d.draft(1, &mut out);
        assert!(out.is_empty(), "7 has no recorded successor");
        d.observe(5);
        d.draft(2, &mut out);
        // 5's successor was updated from 6 to 7 by the later occurrence.
        assert_eq!(out, vec![7, 5]);
    }

    #[test]
    fn successor_streams_never_self_draft() {
        // MockDecodeEngine emits strictly increasing successor tokens;
        // an n-gram drafter observing such a stream finds no repeated
        // frontier and proposes nothing — the property that makes the
        // LOBCQ_SPEC_K CI leg a no-op for non-repetitive mock tests.
        let mut d = NGramDrafter::new();
        for t in 10u32..20 {
            d.observe(t);
        }
        let mut out = Vec::new();
        d.draft(4, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn always_wrong_drafts_k_copies() {
        let mut d = AlwaysWrongDrafter { token: 42 };
        d.observe(1);
        let mut out = Vec::new();
        d.draft(3, &mut out);
        assert_eq!(out, vec![42, 42, 42]);
    }

    #[test]
    fn kind_parses_and_builds() {
        assert_eq!(DrafterKind::parse("ngram").unwrap(), DrafterKind::NGram);
        assert_eq!(DrafterKind::parse("off").unwrap(), DrafterKind::Off);
        assert!(DrafterKind::parse("oracle").is_err());
        // The test-only kind must never be CLI-reachable.
        assert!(DrafterKind::parse("always-wrong").is_err());
        assert!(DrafterKind::NGram.build().is_some());
        assert!(DrafterKind::Off.build().is_none());
        assert!(DrafterKind::AlwaysWrong { token: 3 }.build().is_some());
    }
}
