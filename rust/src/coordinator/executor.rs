//! Step executor abstraction: one fixed-shape forward pass per decode
//! step. The production impl wraps the PJRT `RuntimeClient` (behind the
//! `pjrt` feature); [`CpuExecutor`] serves through the CPU reference
//! forward with on-the-fly activation quantization from the unified
//! pipeline; the mock drives coordinator unit/property tests with no
//! artifacts required.

#[cfg(feature = "pjrt")]
use crate::runtime::{ArtifactEntry, RuntimeClient};
use crate::runtime::Logits;

/// Executes a (batch, t) token forward and returns logits. `tokens` is
/// row-major batch*t; implementations have a FIXED (batch, t) shape —
/// the scheduler pads partial batches.
pub trait StepExecutor: Send {
    fn batch(&self) -> usize;
    fn t(&self) -> usize;
    fn vocab(&self) -> usize;
    fn step(&self, tokens: &[u32]) -> anyhow::Result<Logits>;

    /// Last-position-only step: logits for each lane's frontier position
    /// (`frontier[i]` for lane `i`, `frontier.len() ≤ batch`), returned
    /// as a `(frontier.len(), 1, vocab)` container. The decode loop
    /// samples only the frontier, so the full `batch·t·vocab` logits of
    /// [`step`](Self::step) are waste there. Default: full step + row
    /// gather (mocks, PJRT); the CPU executor overrides with a forward
    /// that skips the non-frontier LM-head rows entirely.
    fn step_last(&self, tokens: &[u32], frontier: &[usize]) -> anyhow::Result<Logits> {
        anyhow::ensure!(frontier.len() <= self.batch(), "more frontier lanes than batch");
        let full = self.step(tokens)?;
        let v = self.vocab();
        let mut data = Vec::with_capacity(frontier.len() * v);
        for (i, &p) in frontier.iter().enumerate() {
            anyhow::ensure!(p < full.t, "frontier {p} >= t {}", full.t);
            data.extend_from_slice(&full.data[(i * full.t + p) * v..(i * full.t + p + 1) * v]);
        }
        Ok(Logits { data, batch: frontier.len(), t: 1, vocab: v })
    }
}

/// PJRT-backed executor bound to one artifact + registered weight/book
/// keys (see `RuntimeClient::register_weights` / `register_books`).
#[cfg(feature = "pjrt")]
pub struct PjrtExecutor {
    pub client: RuntimeClient,
    pub entry: ArtifactEntry,
    pub weights_key: String,
    pub books_key: Option<String>,
    pub vocab: usize,
}

#[cfg(feature = "pjrt")]
impl StepExecutor for PjrtExecutor {
    fn batch(&self) -> usize {
        self.entry.batch
    }

    fn t(&self) -> usize {
        self.entry.t
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn step(&self, tokens: &[u32]) -> anyhow::Result<Logits> {
        self.client.run_model(&self.entry, &self.weights_key, self.books_key.as_deref(), tokens.to_vec())
    }
}

/// CPU serving executor: the reference forward with weights pre-quantized
/// offline and activations quantized **on the fly** at every GEMM input
/// through the shared [`QuantPipeline`] — the same `QuantScheme` object
/// calibration and every eval table exercise (paper §3's deployment mode,
/// artifact-free). The pipeline's scratch pool is retained across steps,
/// so steady-state serving performs zero quantization allocations.
///
/// Weight handling prefers the **encoded domain**: schemes with a packed
/// code format (LO-BCQ) compile every GEMM weight to `QuantLinear` codes
/// at construction and the forward runs `qgemm` directly on them — the
/// quantized weights never exist as f32 tensors, matching the W4A4
/// deployment story (§1, §5). Schemes without a code format fall back to
/// fake-quantized dense weights; logits are bit-exact between the paths.
pub struct CpuExecutor {
    cfg: crate::model::ModelConfig,
    /// Pre-quantized weights: encoded-domain codes when the scheme
    /// supports them, fake-quantized dense tensors otherwise.
    weights: crate::model::Weights,
    act: Option<crate::quant::pipeline::QuantPipeline>,
    batch: usize,
    t: usize,
    encoded: bool,
}

impl CpuExecutor {
    /// Build from a model + scheme: compiles/quantizes the GEMM weights
    /// offline and binds the activation pipeline (None for BF16).
    pub fn new(
        cfg: crate::model::ModelConfig,
        weights: &crate::model::Weights,
        scheme: &crate::eval::Scheme,
        pool: crate::quant::pipeline::QuantPool,
        batch: usize,
        t: usize,
    ) -> anyhow::Result<CpuExecutor> {
        anyhow::ensure!(batch >= 1 && t >= 1 && t <= cfg.max_t, "bad executor shape ({batch}, {t})");
        let (qw, encoded) = scheme.serving_weights(&cfg, weights, pool);
        let act = scheme.act_pipeline(pool);
        Ok(CpuExecutor { cfg, weights: qw, act, batch, t, encoded })
    }

    /// Name of the bound activation pipeline (serving logs).
    pub fn act_scheme_name(&self) -> String {
        self.act.as_ref().map(|p| p.name()).unwrap_or_else(|| "BF16".into())
    }

    /// How GEMM weights are held (serving logs).
    pub fn weight_mode(&self) -> &'static str {
        crate::eval::scheme::weight_mode_name(self.encoded)
    }
}

impl StepExecutor for CpuExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn t(&self) -> usize {
        self.t
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn step(&self, tokens: &[u32]) -> anyhow::Result<Logits> {
        anyhow::ensure!(tokens.len() == self.batch * self.t, "bad token count");
        let logits = crate::model::forward::forward(
            &self.cfg,
            &self.weights,
            tokens,
            self.batch,
            self.act.as_ref(),
        )?;
        Ok(Logits { data: logits.data, batch: self.batch, t: self.t, vocab: self.cfg.vocab })
    }

    /// Logits-slimming path: the transformer stack runs full-shape, but
    /// the tied-LM-head GEMM — the largest single product at decode
    /// shapes (`d × vocab`) — runs over one row per lane instead of
    /// `batch·t`.
    fn step_last(&self, tokens: &[u32], frontier: &[usize]) -> anyhow::Result<Logits> {
        anyhow::ensure!(tokens.len() == self.batch * self.t, "bad token count");
        anyhow::ensure!(frontier.len() <= self.batch, "more frontier lanes than batch");
        let logits = crate::model::forward::forward_logits_at(
            &self.cfg,
            &self.weights,
            tokens,
            self.batch,
            self.act.as_ref(),
            frontier,
        )?;
        Ok(Logits { data: logits.data, batch: frontier.len(), t: 1, vocab: self.cfg.vocab })
    }
}

/// Deterministic mock: logits prefer `(last_token + 1) % vocab`, with a
/// configurable artificial delay — enough structure for scheduler tests
/// to verify batching, routing, and timing behaviour.
pub struct MockExecutor {
    pub batch: usize,
    pub t: usize,
    pub vocab: usize,
    pub delay: std::time::Duration,
    pub calls: std::sync::atomic::AtomicUsize,
}

impl MockExecutor {
    pub fn new(batch: usize, t: usize, vocab: usize) -> MockExecutor {
        MockExecutor {
            batch,
            t,
            vocab,
            delay: std::time::Duration::ZERO,
            calls: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    pub fn call_count(&self) -> usize {
        self.calls.load(std::sync::atomic::Ordering::SeqCst)
    }
}

impl StepExecutor for MockExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn t(&self) -> usize {
        self.t
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn step(&self, tokens: &[u32]) -> anyhow::Result<Logits> {
        self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        anyhow::ensure!(tokens.len() == self.batch * self.t, "bad token count");
        let mut data = vec![0.0f32; self.batch * self.t * self.vocab];
        for b in 0..self.batch {
            for p in 0..self.t {
                let tok = tokens[b * self.t + p] as usize;
                let want = (tok + 1) % self.vocab;
                data[(b * self.t + p) * self.vocab + want] = 10.0;
            }
        }
        Ok(Logits { data, batch: self.batch, t: self.t, vocab: self.vocab })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_prefers_successor_token() {
        let m = MockExecutor::new(1, 4, 10);
        let logits = m.step(&[3, 4, 5, 6]).unwrap();
        // argmax at position 1 should be 5.
        let row = &logits.data[1 * 10..2 * 10];
        let argmax = row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert_eq!(argmax, 5);
        assert_eq!(m.call_count(), 1);
    }

    #[test]
    fn mock_validates_shape() {
        let m = MockExecutor::new(2, 4, 10);
        assert!(m.step(&[1, 2, 3]).is_err());
    }

    #[test]
    fn cpu_executor_serves_quantized_forward() {
        use crate::eval::scheme::mx4;
        use crate::model::forward::tests_support::{random_weights, tiny_cfg};
        use crate::quant::pipeline::QuantPool;

        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 31);
        let t = 8;
        let exec =
            CpuExecutor::new(cfg.clone(), &w, &mx4(), QuantPool::serial(), 2, t).unwrap();
        assert_eq!(exec.vocab(), cfg.vocab);
        assert_eq!(exec.act_scheme_name(), "MX4 (g16)");
        let tokens: Vec<u32> = (0..2 * t).map(|i| (i % cfg.vocab) as u32).collect();
        let logits = exec.step(&tokens).unwrap();
        assert_eq!(logits.data.len(), 2 * t * cfg.vocab);
        assert!(logits.data.iter().all(|v| v.is_finite()));

        // The quantized executor must differ from the BF16 one (the
        // activation hook is live) but stay finite and bounded.
        let base = CpuExecutor::new(cfg.clone(), &w, &crate::eval::Scheme::Bf16, QuantPool::serial(), 2, t)
            .unwrap();
        let base_logits = base.step(&tokens).unwrap();
        let diff: f32 =
            logits.data.iter().zip(&base_logits.data).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 0.0, "quantization had no effect");
    }

    #[test]
    fn cpu_executor_serves_encoded_domain_lobcq() {
        use crate::model::forward::tests_support::{random_weights, tiny_cfg};
        use crate::quant::calib::calibrate_universal;
        use crate::quant::lobcq::{CalibOpts, LobcqConfig};
        use crate::quant::pipeline::QuantPool;

        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 33);
        let qcfg = LobcqConfig::new(8, 4, 64);
        let fam = calibrate_universal(
            &[w.get("l0.mlp.w1").unwrap()],
            &qcfg,
            CalibOpts { max_iters: 8, ..Default::default() },
            3,
        );
        let scheme = crate::eval::Scheme::lobcq(qcfg, fam);
        let exec = CpuExecutor::new(cfg.clone(), &w, &scheme, QuantPool::serial(), 1, 8).unwrap();
        assert_eq!(exec.weight_mode(), "encoded-domain (qgemm on LO-BCQ codes)");
        let tokens: Vec<u32> = (0..8).map(|i| (i % cfg.vocab) as u32).collect();
        let logits = exec.step(&tokens).unwrap();
        assert!(logits.data.iter().all(|v| v.is_finite()));
        // Baselines without a code format fall back to dense weights.
        let dense = CpuExecutor::new(cfg, &w, &crate::eval::scheme::mx4(), QuantPool::serial(), 1, 8).unwrap();
        assert_eq!(dense.weight_mode(), "dense (fake-quantized f32)");
    }

    #[test]
    fn step_last_matches_full_step_rows_bitwise() {
        use crate::eval::scheme::mx4;
        use crate::model::forward::tests_support::{random_weights, tiny_cfg};
        use crate::quant::pipeline::QuantPool;

        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 35);
        let t = 8;
        // Quantized executor: the slim path must agree even with the
        // activation hook live (same whole-tensor prepare, same rows).
        let exec = CpuExecutor::new(cfg.clone(), &w, &mx4(), QuantPool::serial(), 2, t).unwrap();
        let tokens: Vec<u32> = (0..2 * t).map(|i| (i * 3 % cfg.vocab) as u32).collect();
        let full = exec.step(&tokens).unwrap();
        let frontier = [2usize, 7];
        let slim = exec.step_last(&tokens, &frontier).unwrap();
        assert_eq!((slim.batch, slim.t, slim.vocab), (2, 1, cfg.vocab));
        for (i, &p) in frontier.iter().enumerate() {
            for c in 0..cfg.vocab {
                let a = slim.data[i * cfg.vocab + c];
                let b = full.data[(i * t + p) * cfg.vocab + c];
                assert_eq!(a.to_bits(), b.to_bits(), "lane {i} pos {p} col {c}");
            }
        }
        // Default-impl path (mock) gathers the same rows.
        let m = MockExecutor::new(2, t, cfg.vocab);
        let slim = m.step_last(&tokens, &frontier).unwrap();
        let full = m.step(&tokens).unwrap();
        assert_eq!(slim.data[0..cfg.vocab], full.data[2 * cfg.vocab..3 * cfg.vocab]);
        assert!(m.step_last(&tokens, &[99, 0]).is_err(), "frontier past t accepted");
    }

    #[test]
    fn cpu_executor_through_full_server() {
        use crate::coordinator::{BatchPolicy, Limits, Sampling, Server};
        use crate::model::forward::tests_support::{random_weights, tiny_cfg};
        use crate::quant::pipeline::QuantPool;

        let cfg = tiny_cfg();
        let vocab = cfg.vocab as u32;
        let w = random_weights(&cfg, 32);
        let scheme = crate::eval::scheme::vsq();
        let exec = CpuExecutor::new(cfg, &w, &scheme, QuantPool::serial(), 4, 16).unwrap();
        let s = Server::start(
            exec,
            BatchPolicy { max_batch: 4, max_wait: std::time::Duration::from_millis(2), queue_cap: None },
            Limits { max_prompt: 8, max_new: 4, vocab },
            Sampling::Greedy,
        );
        let mut tickets = Vec::new();
        for i in 0..6u32 {
            tickets.push(s.submit(vec![i % vocab, (i + 3) % vocab], 3).unwrap());
        }
        for t in tickets {
            let resp = t.wait().unwrap();
            assert_eq!(resp.tokens.len(), 3);
            assert!(resp.tokens.iter().all(|&tok| tok < vocab));
        }
        s.shutdown();
    }
}
