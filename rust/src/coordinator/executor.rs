//! Step executor abstraction: one fixed-shape forward pass per decode
//! step. The production impl wraps the PJRT [`RuntimeClient`]; the mock
//! drives coordinator unit/property tests with no artifacts required.

use crate::runtime::{ArtifactEntry, Logits, RuntimeClient};

/// Executes a (batch, t) token forward and returns logits. `tokens` is
/// row-major batch*t; implementations have a FIXED (batch, t) shape —
/// the scheduler pads partial batches.
pub trait StepExecutor: Send {
    fn batch(&self) -> usize;
    fn t(&self) -> usize;
    fn vocab(&self) -> usize;
    fn step(&self, tokens: &[u32]) -> anyhow::Result<Logits>;
}

/// PJRT-backed executor bound to one artifact + registered weight/book
/// keys (see `RuntimeClient::register_weights` / `register_books`).
pub struct PjrtExecutor {
    pub client: RuntimeClient,
    pub entry: ArtifactEntry,
    pub weights_key: String,
    pub books_key: Option<String>,
    pub vocab: usize,
}

impl StepExecutor for PjrtExecutor {
    fn batch(&self) -> usize {
        self.entry.batch
    }

    fn t(&self) -> usize {
        self.entry.t
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn step(&self, tokens: &[u32]) -> anyhow::Result<Logits> {
        self.client.run_model(&self.entry, &self.weights_key, self.books_key.as_deref(), tokens.to_vec())
    }
}

/// Deterministic mock: logits prefer `(last_token + 1) % vocab`, with a
/// configurable artificial delay — enough structure for scheduler tests
/// to verify batching, routing, and timing behaviour.
pub struct MockExecutor {
    pub batch: usize,
    pub t: usize,
    pub vocab: usize,
    pub delay: std::time::Duration,
    pub calls: std::sync::atomic::AtomicUsize,
}

impl MockExecutor {
    pub fn new(batch: usize, t: usize, vocab: usize) -> MockExecutor {
        MockExecutor {
            batch,
            t,
            vocab,
            delay: std::time::Duration::ZERO,
            calls: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    pub fn call_count(&self) -> usize {
        self.calls.load(std::sync::atomic::Ordering::SeqCst)
    }
}

impl StepExecutor for MockExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn t(&self) -> usize {
        self.t
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn step(&self, tokens: &[u32]) -> anyhow::Result<Logits> {
        self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        anyhow::ensure!(tokens.len() == self.batch * self.t, "bad token count");
        let mut data = vec![0.0f32; self.batch * self.t * self.vocab];
        for b in 0..self.batch {
            for p in 0..self.t {
                let tok = tokens[b * self.t + p] as usize;
                let want = (tok + 1) % self.vocab;
                data[(b * self.t + p) * self.vocab + want] = 10.0;
            }
        }
        Ok(Logits { data, batch: self.batch, t: self.t, vocab: self.vocab })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_prefers_successor_token() {
        let m = MockExecutor::new(1, 4, 10);
        let logits = m.step(&[3, 4, 5, 6]).unwrap();
        // argmax at position 1 should be 5.
        let row = &logits.data[1 * 10..2 * 10];
        let argmax = row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        assert_eq!(argmax, 5);
        assert_eq!(m.call_count(), 1);
    }

    #[test]
    fn mock_validates_shape() {
        let m = MockExecutor::new(2, 4, 10);
        assert!(m.step(&[1, 2, 3]).is_err());
    }
}
