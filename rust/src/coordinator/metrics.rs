//! Serving metrics: latency histograms per stage, token throughput, and
//! batch-occupancy statistics — the quantities the §Perf serving bench
//! reports (p50/p95/p99 latency, tokens/s, batch fill), plus the two
//! decode-engine stage latencies: **time-to-first-token** (submit →
//! first sampled token, i.e. queue + prefill) and **inter-token
//! latency** (mean decode-step spacing) — recorded separately so the
//! decode bench and `serve-cpu` logs can report prefill and decode
//! behaviour independently.
//!
//! Both scheduling paths additionally record a **decode batch-occupancy
//! histogram** — live lanes per decode step — the number that tells you
//! how much of the fused step's panel-streaming amortization the
//! workload actually realized — and the continuous path samples the
//! paged KV cache's page occupancy (pages in use / high-water mark)
//! plus, when the prefix cache is on, its hit-rate / saved-prefill /
//! eviction counters, all printed in the `serve-cpu` summary.

use super::request::{Priority, Response, ShedReason};
use crate::kvcache::KvStats;
use crate::prefixcache::PrefixStats;
use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;
use std::sync::Mutex;
use std::time::Instant;

#[derive(Debug)]
struct Inner {
    queue: LatencyHistogram,
    execute: LatencyHistogram,
    ttft: LatencyHistogram,
    itl: LatencyHistogram,
    total: LatencyHistogram,
    /// Per-priority-class TTFT/ITL (indexed by [`Priority::index`]) —
    /// the split that shows whether the two-level FIFO and preemption
    /// policy actually bought the high class better latency.
    ttft_by_prio: [LatencyHistogram; 2],
    itl_by_prio: [LatencyHistogram; 2],
    done_by_prio: [u64; 2],
    batch_sizes: Vec<usize>,
    /// `occupancy[n-1]` = decode steps that ran with `n` live lanes.
    occupancy: Vec<u64>,
    /// Latest KV-cache snapshot (peaks are cumulative inside it).
    kv: Option<KvStats>,
    /// Latest prefix-cache snapshot (counters are cumulative inside it).
    prefix: Option<PrefixStats>,
    /// Latest decoded-panel cache counters `(hits, decodes)` from the
    /// encoded-attention fast path (cumulative inside the cache).
    panel: Option<(u64, u64)>,
    // Speculative decoding (drafter/verifier loop) counters — all zero
    // unless at least one step actually drafted.
    /// Fused steps that carried at least one drafted verify row.
    spec_steps: u64,
    /// Draft tokens proposed across all speculative steps.
    spec_drafted: u64,
    /// Draft tokens accepted by greedy verification.
    spec_accepted: u64,
    /// Rejected speculative steps that rolled the KV cache back.
    spec_rollbacks: u64,
    /// Per-lane lifetime acceptance rate, recorded at retirement as a
    /// percent in [0, 100] (log buckets are coarse but the exact mean
    /// rides along in the histogram's sum).
    spec_acceptance: LatencyHistogram,
    // SLO counters: every admitted-then-displaced fate is counted, so
    // (responses + sheds) reconciles against accepted admissions.
    /// Pushes rejected at the admission cap (`QueueFull`).
    rejected: u64,
    /// Requests shed because their deadline expired while queued.
    shed_deadline: u64,
    /// Requests shed terminally by the KV-pressure ladder.
    shed_kv: u64,
    /// Still-prefilling admissions requeued under KV pressure.
    deferred: u64,
    /// Decoding lanes preempted (requeued for replay) under KV pressure.
    preempted: u64,
    queue_depth_sum: u64,
    queue_depth_samples: u64,
    queue_depth_max: usize,
    tokens_out: u64,
    requests_done: u64,
    started: Option<Instant>,
}

#[derive(Debug)]
pub struct ServerMetrics {
    inner: Mutex<Inner>,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerMetrics {
    pub fn new() -> ServerMetrics {
        ServerMetrics {
            inner: Mutex::new(Inner {
                queue: LatencyHistogram::new(),
                execute: LatencyHistogram::new(),
                ttft: LatencyHistogram::new(),
                itl: LatencyHistogram::new(),
                total: LatencyHistogram::new(),
                ttft_by_prio: [LatencyHistogram::new(), LatencyHistogram::new()],
                itl_by_prio: [LatencyHistogram::new(), LatencyHistogram::new()],
                done_by_prio: [0, 0],
                batch_sizes: Vec::new(),
                occupancy: Vec::new(),
                kv: None,
                prefix: None,
                panel: None,
                spec_steps: 0,
                spec_drafted: 0,
                spec_accepted: 0,
                spec_rollbacks: 0,
                spec_acceptance: LatencyHistogram::new(),
                rejected: 0,
                shed_deadline: 0,
                shed_kv: 0,
                deferred: 0,
                preempted: 0,
                queue_depth_sum: 0,
                queue_depth_samples: 0,
                queue_depth_max: 0,
                tokens_out: 0,
                requests_done: 0,
                started: None,
            }),
        }
    }

    /// One decode step ran with `live_lanes` lanes (both scheduling
    /// paths call this once per step).
    pub fn record_step_occupancy(&self, live_lanes: usize) {
        if live_lanes == 0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        if g.occupancy.len() < live_lanes {
            g.occupancy.resize(live_lanes, 0);
        }
        g.occupancy[live_lanes - 1] += 1;
    }

    /// Latest KV-cache occupancy snapshot (the stats carry their own
    /// high-water marks, so keeping the most recent one is lossless).
    pub fn record_kv_stats(&self, stats: KvStats) {
        self.inner.lock().unwrap().kv = Some(stats);
    }

    /// Latest prefix-cache snapshot (hit/saved/evicted counters are
    /// cumulative inside it, so the most recent one is lossless).
    pub fn record_prefix_stats(&self, stats: PrefixStats) {
        self.inner.lock().unwrap().prefix = Some(stats);
    }

    /// Latest decoded-panel cache counters (cumulative `hits` out of
    /// `decodes` panel fetches; the most recent pair is lossless).
    pub fn record_panel_stats(&self, hits: u64, decodes: u64) {
        self.inner.lock().unwrap().panel = Some((hits, decodes));
    }

    /// One fused step carried speculative verify rows: `drafted` tokens
    /// were proposed across its lanes, `accepted` of them survived
    /// greedy verification, and `rollbacks` lanes truncated a rejected
    /// tail out of the KV cache.
    pub fn record_spec_step(&self, drafted: usize, accepted: usize, rollbacks: usize) {
        let mut g = self.inner.lock().unwrap();
        g.spec_steps += 1;
        g.spec_drafted += drafted as u64;
        g.spec_accepted += accepted as u64;
        g.spec_rollbacks += rollbacks as u64;
    }

    /// A lane that drafted at least once retired with the given lifetime
    /// acceptance rate (accepted / drafted, in [0, 1]).
    pub fn record_spec_acceptance(&self, rate: f64) {
        self.inner.lock().unwrap().spec_acceptance.record_us(rate * 100.0);
    }

    pub fn record_response(&self, resp: &Response) {
        let mut g = self.inner.lock().unwrap();
        g.started.get_or_insert_with(Instant::now);
        g.queue.record_us(resp.queue_us);
        g.execute.record_us(resp.execute_us);
        g.ttft.record_us(resp.ttft_us);
        let p = resp.priority.index();
        g.ttft_by_prio[p].record_us(resp.ttft_us);
        if resp.tokens.len() > 1 {
            // ITL is undefined for single-token responses.
            g.itl.record_us(resp.itl_us);
            g.itl_by_prio[p].record_us(resp.itl_us);
        }
        g.done_by_prio[p] += 1;
        g.total.record_us(resp.total_us);
        g.batch_sizes.push(resp.batch_size);
        g.tokens_out += resp.tokens.len() as u64;
        g.requests_done += 1;
    }

    /// A push bounced off the admission cap (`PushOutcome::QueueFull`).
    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// A request received a terminal shed error.
    pub fn record_shed(&self, reason: ShedReason) {
        let mut g = self.inner.lock().unwrap();
        match reason {
            ShedReason::DeadlineExpired => g.shed_deadline += 1,
            ShedReason::KvPressure => g.shed_kv += 1,
        }
    }

    /// A still-prefilling admission was requeued under KV pressure.
    pub fn record_deferred(&self) {
        self.inner.lock().unwrap().deferred += 1;
    }

    /// A decoding lane was preempted (requeued for replay) under KV
    /// pressure.
    pub fn record_preempted(&self) {
        self.inner.lock().unwrap().preempted += 1;
    }

    /// Admission-queue depth sample (once per scheduler iteration).
    pub fn record_queue_depth(&self, depth: usize) {
        let mut g = self.inner.lock().unwrap();
        g.queue_depth_sum += depth as u64;
        g.queue_depth_samples += 1;
        g.queue_depth_max = g.queue_depth_max.max(depth);
    }

    /// Side effect: the snapshot is also published to the global metrics
    /// registry (section `server`), so `--metrics-out` and bench stamps
    /// see the latest serving state without a second wiring path.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let elapsed = g.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        let mean_batch = if g.batch_sizes.is_empty() {
            0.0
        } else {
            g.batch_sizes.iter().sum::<usize>() as f64 / g.batch_sizes.len() as f64
        };
        let steps: u64 = g.occupancy.iter().sum();
        let mean_occupancy = if steps == 0 {
            0.0
        } else {
            g.occupancy.iter().enumerate().map(|(i, &c)| (i + 1) as u64 * c).sum::<u64>() as f64
                / steps as f64
        };
        let by_priority = [Priority::Normal, Priority::High].map(|p| {
            let i = p.index();
            PrioritySlo {
                class: p.name(),
                requests: g.done_by_prio[i],
                ttft_p50_us: g.ttft_by_prio[i].percentile_us(50.0),
                ttft_p99_us: g.ttft_by_prio[i].percentile_us(99.0),
                itl_p50_us: g.itl_by_prio[i].percentile_us(50.0),
                itl_p99_us: g.itl_by_prio[i].percentile_us(99.0),
            }
        });
        let spec = if g.spec_steps > 0 || g.spec_acceptance.count() > 0 {
            Some(SpecStats {
                steps: g.spec_steps,
                drafted: g.spec_drafted,
                accepted: g.spec_accepted,
                wasted: g.spec_drafted - g.spec_accepted,
                rollbacks: g.spec_rollbacks,
                lanes: g.spec_acceptance.count(),
                acceptance_mean_pct: g.spec_acceptance.mean_us(),
                acceptance_p50_pct: g.spec_acceptance.percentile_us(50.0),
            })
        } else {
            None
        };
        let snap = MetricsSnapshot {
            occupancy_hist: g
                .occupancy
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(|(i, &c)| (i + 1, c))
                .collect(),
            mean_occupancy,
            kv: g.kv,
            prefix: g.prefix,
            panel: g.panel,
            spec,
            rejected: g.rejected,
            shed_deadline: g.shed_deadline,
            shed_kv: g.shed_kv,
            deferred: g.deferred,
            preempted: g.preempted,
            queue_depth_mean: if g.queue_depth_samples == 0 {
                0.0
            } else {
                g.queue_depth_sum as f64 / g.queue_depth_samples as f64
            },
            queue_depth_max: g.queue_depth_max,
            by_priority,
            requests: g.requests_done,
            tokens: g.tokens_out,
            tokens_per_s: if elapsed > 0.0 { g.tokens_out as f64 / elapsed } else { 0.0 },
            queue_p50_us: g.queue.percentile_us(50.0),
            queue_p99_us: g.queue.percentile_us(99.0),
            exec_p50_us: g.execute.percentile_us(50.0),
            exec_p99_us: g.execute.percentile_us(99.0),
            ttft_p50_us: g.ttft.percentile_us(50.0),
            ttft_p99_us: g.ttft.percentile_us(99.0),
            itl_p50_us: g.itl.percentile_us(50.0),
            itl_p99_us: g.itl.percentile_us(99.0),
            total_p50_us: g.total.percentile_us(50.0),
            total_p95_us: g.total.percentile_us(95.0),
            total_p99_us: g.total.percentile_us(99.0),
            mean_batch,
        };
        crate::obs::registry::publish("server", snap.to_json());
        snap
    }
}

/// Speculative-decoding counters: how much was drafted, how much of it
/// survived verification, and how often the KV cache had to roll back.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpecStats {
    /// Fused steps that carried at least one drafted verify row.
    pub steps: u64,
    /// Draft tokens proposed (== extra verify rows computed).
    pub drafted: u64,
    /// Draft tokens accepted by greedy verification.
    pub accepted: u64,
    /// `drafted - accepted` — verify rows computed and discarded.
    pub wasted: u64,
    /// KV-cache rollbacks (one per rejected speculative lane-step).
    pub rollbacks: u64,
    /// Retired lanes contributing to the acceptance-rate histogram.
    pub lanes: u64,
    /// Mean lifetime acceptance rate over retired lanes, in percent.
    pub acceptance_mean_pct: f64,
    /// Median lifetime acceptance rate over retired lanes, in percent
    /// (log-bucket approximation).
    pub acceptance_p50_pct: f64,
}

/// Per-priority-class SLO latencies.
#[derive(Debug, Clone, Copy)]
pub struct PrioritySlo {
    pub class: &'static str,
    pub requests: u64,
    pub ttft_p50_us: f64,
    pub ttft_p99_us: f64,
    pub itl_p50_us: f64,
    pub itl_p99_us: f64,
}

#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    /// `(live_lanes, steps)` pairs, ascending, zero-count rows dropped.
    pub occupancy_hist: Vec<(usize, u64)>,
    pub mean_occupancy: f64,
    /// Latest KV-cache occupancy (continuous engine only).
    pub kv: Option<KvStats>,
    /// Latest prefix-cache counters (continuous engine with the prefix
    /// cache on).
    pub prefix: Option<PrefixStats>,
    /// Decoded-panel cache `(hits, decodes)` — encoded-attention engines
    /// only.
    pub panel: Option<(u64, u64)>,
    /// Speculative-decoding counters — present once any step drafted.
    pub spec: Option<SpecStats>,
    /// Pushes rejected at the admission cap.
    pub rejected: u64,
    /// Requests shed for a queue-expired deadline.
    pub shed_deadline: u64,
    /// Requests shed terminally by the KV-pressure ladder.
    pub shed_kv: u64,
    /// Admissions deferred (requeued mid-prefill) under KV pressure.
    pub deferred: u64,
    /// Decoding lanes preempted for replay under KV pressure.
    pub preempted: u64,
    pub queue_depth_mean: f64,
    pub queue_depth_max: usize,
    /// `[normal, high]` latency split.
    pub by_priority: [PrioritySlo; 2],
    pub requests: u64,
    pub tokens: u64,
    pub tokens_per_s: f64,
    pub queue_p50_us: f64,
    pub queue_p99_us: f64,
    pub exec_p50_us: f64,
    pub exec_p99_us: f64,
    pub ttft_p50_us: f64,
    pub ttft_p99_us: f64,
    pub itl_p50_us: f64,
    pub itl_p99_us: f64,
    pub total_p50_us: f64,
    pub total_p95_us: f64,
    pub total_p99_us: f64,
    pub mean_batch: f64,
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} tokens={} throughput={:.1} tok/s | total p50={:.0}µs p95={:.0}µs p99={:.0}µs | \
             queue p50={:.0}µs p99={:.0}µs | exec p50={:.0}µs p99={:.0}µs | \
             ttft p50={:.0}µs p99={:.0}µs | itl p50={:.0}µs p99={:.0}µs | mean batch={:.2}",
            self.requests,
            self.tokens,
            self.tokens_per_s,
            self.total_p50_us,
            self.total_p95_us,
            self.total_p99_us,
            self.queue_p50_us,
            self.queue_p99_us,
            self.exec_p50_us,
            self.exec_p99_us,
            self.ttft_p50_us,
            self.ttft_p99_us,
            self.itl_p50_us,
            self.itl_p99_us,
            self.mean_batch
        );
        if !self.occupancy_hist.is_empty() {
            s.push_str(&format!(" | decode occupancy mean={:.2} [", self.mean_occupancy));
            for (i, (lanes, steps)) in self.occupancy_hist.iter().enumerate() {
                if i > 0 {
                    s.push(' ');
                }
                s.push_str(&format!("{lanes}:{steps}"));
            }
            s.push(']');
        }
        if let Some(kv) = &self.kv {
            s.push_str(&format!(
                " | kv pages={}/{} (peak {}) bytes={} (peak {})",
                kv.pages_in_use, kv.pages_capacity, kv.pages_peak, kv.state_bytes, kv.peak_bytes
            ));
        }
        if let Some(p) = &self.prefix {
            s.push_str(&format!(
                " | prefix hits={}/{} ({:.0}%) saved-tokens={} evicted-bytes={} resident={}B in {} chunks",
                p.hits,
                p.lookups,
                100.0 * p.hit_rate(),
                p.saved_tokens,
                p.evicted_bytes,
                p.resident_bytes,
                p.resident_chunks
            ));
        }
        if let Some((hits, decodes)) = self.panel {
            if decodes > 0 {
                s.push_str(&format!(
                    " | panel hits={}/{} ({:.0}%)",
                    hits,
                    decodes,
                    100.0 * hits as f64 / decodes as f64
                ));
            }
        }
        if let Some(sp) = &self.spec {
            let rate =
                if sp.drafted > 0 { 100.0 * sp.accepted as f64 / sp.drafted as f64 } else { 0.0 };
            s.push_str(&format!(
                " | spec steps={} accepted={}/{} ({:.0}%) wasted={} rollbacks={} \
                 lane-acceptance mean={:.0}% p50={:.0}%",
                sp.steps,
                sp.accepted,
                sp.drafted,
                rate,
                sp.wasted,
                sp.rollbacks,
                sp.acceptance_mean_pct,
                sp.acceptance_p50_pct
            ));
        }
        if self.rejected + self.shed_deadline + self.shed_kv + self.deferred + self.preempted > 0
            || self.queue_depth_max > 0
        {
            s.push_str(&format!(
                " | slo rejected={} shed-deadline={} shed-kv={} deferred={} preempted={} \
                 queue-depth mean={:.2} max={}",
                self.rejected,
                self.shed_deadline,
                self.shed_kv,
                self.deferred,
                self.preempted,
                self.queue_depth_mean,
                self.queue_depth_max
            ));
        }
        // The per-priority split only says something once both classes
        // ran (a single-class workload would just repeat the global
        // numbers).
        if self.by_priority.iter().all(|p| p.requests > 0) {
            for p in &self.by_priority {
                s.push_str(&format!(
                    " | {}: n={} ttft p50={:.0}µs p99={:.0}µs itl p50={:.0}µs p99={:.0}µs",
                    p.class, p.requests, p.ttft_p50_us, p.ttft_p99_us, p.itl_p50_us, p.itl_p99_us
                ));
            }
        }
        s
    }

    /// Machine-readable form of the snapshot for `--metrics-out` and the
    /// bench reports; field names mirror the struct, nested sections for
    /// the optional cache stats.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("requests", Json::Num(self.requests as f64));
        j.set("tokens", Json::Num(self.tokens as f64));
        j.set("tokens_per_s", Json::Num(self.tokens_per_s));
        let mut lat = Json::obj();
        lat.set("queue_p50_us", Json::Num(self.queue_p50_us));
        lat.set("queue_p99_us", Json::Num(self.queue_p99_us));
        lat.set("exec_p50_us", Json::Num(self.exec_p50_us));
        lat.set("exec_p99_us", Json::Num(self.exec_p99_us));
        lat.set("ttft_p50_us", Json::Num(self.ttft_p50_us));
        lat.set("ttft_p99_us", Json::Num(self.ttft_p99_us));
        lat.set("itl_p50_us", Json::Num(self.itl_p50_us));
        lat.set("itl_p99_us", Json::Num(self.itl_p99_us));
        lat.set("total_p50_us", Json::Num(self.total_p50_us));
        lat.set("total_p95_us", Json::Num(self.total_p95_us));
        lat.set("total_p99_us", Json::Num(self.total_p99_us));
        j.set("latency", lat);
        let mut occ = Json::obj();
        occ.set("mean", Json::Num(self.mean_occupancy));
        occ.set("mean_batch", Json::Num(self.mean_batch));
        occ.set(
            "hist",
            Json::Arr(
                self.occupancy_hist
                    .iter()
                    .map(|&(lanes, steps)| {
                        Json::obj()
                            .with("lanes", Json::Num(lanes as f64))
                            .with("steps", Json::Num(steps as f64))
                    })
                    .collect(),
            ),
        );
        j.set("occupancy", occ);
        let mut adm = Json::obj();
        adm.set("rejected", Json::Num(self.rejected as f64));
        adm.set("shed_deadline", Json::Num(self.shed_deadline as f64));
        adm.set("shed_kv", Json::Num(self.shed_kv as f64));
        adm.set("deferred", Json::Num(self.deferred as f64));
        adm.set("preempted", Json::Num(self.preempted as f64));
        adm.set("queue_depth_mean", Json::Num(self.queue_depth_mean));
        adm.set("queue_depth_max", Json::Num(self.queue_depth_max as f64));
        j.set("admission", adm);
        if let Some(kv) = &self.kv {
            let mut k = Json::obj();
            k.set("live_slots", Json::Num(kv.live_slots as f64));
            k.set("pages_in_use", Json::Num(kv.pages_in_use as f64));
            k.set("pages_peak", Json::Num(kv.pages_peak as f64));
            k.set("pages_capacity", Json::Num(kv.pages_capacity as f64));
            if let Some(b) = kv.pages_budget {
                k.set("pages_budget", Json::Num(b as f64));
            }
            k.set("state_bytes", Json::Num(kv.state_bytes as f64));
            k.set("peak_bytes", Json::Num(kv.peak_bytes as f64));
            j.set("kv", k);
        }
        if let Some(p) = &self.prefix {
            let mut pj = Json::obj();
            pj.set("lookups", Json::Num(p.lookups as f64));
            pj.set("hits", Json::Num(p.hits as f64));
            pj.set("hit_rate", Json::Num(p.hit_rate()));
            pj.set("saved_tokens", Json::Num(p.saved_tokens as f64));
            pj.set("published_chunks", Json::Num(p.published_chunks as f64));
            pj.set("evicted_bytes", Json::Num(p.evicted_bytes as f64));
            pj.set("resident_bytes", Json::Num(p.resident_bytes as f64));
            pj.set("resident_chunks", Json::Num(p.resident_chunks as f64));
            j.set("prefix", pj);
        }
        if let Some((hits, decodes)) = self.panel {
            let mut pj = Json::obj();
            pj.set("hits", Json::Num(hits as f64));
            pj.set("decodes", Json::Num(decodes as f64));
            j.set("panel", pj);
        }
        if let Some(sp) = &self.spec {
            let mut sj = Json::obj();
            sj.set("steps", Json::Num(sp.steps as f64));
            sj.set("drafted", Json::Num(sp.drafted as f64));
            sj.set("accepted", Json::Num(sp.accepted as f64));
            sj.set("wasted", Json::Num(sp.wasted as f64));
            sj.set("rollbacks", Json::Num(sp.rollbacks as f64));
            sj.set("lanes", Json::Num(sp.lanes as f64));
            sj.set("acceptance_mean_pct", Json::Num(sp.acceptance_mean_pct));
            sj.set("acceptance_p50_pct", Json::Num(sp.acceptance_p50_pct));
            j.set("speculation", sj);
        }
        j.set(
            "by_priority",
            Json::Arr(
                self.by_priority
                    .iter()
                    .map(|p| {
                        Json::obj()
                            .with("class", Json::Str(p.class.to_string()))
                            .with("requests", Json::Num(p.requests as f64))
                            .with("ttft_p50_us", Json::Num(p.ttft_p50_us))
                            .with("ttft_p99_us", Json::Num(p.ttft_p99_us))
                            .with("itl_p50_us", Json::Num(p.itl_p50_us))
                            .with("itl_p99_us", Json::Num(p.itl_p99_us))
                    })
                    .collect(),
            ),
        );
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(tokens: usize, queue: f64, exec: f64, ttft: f64, itl: f64, total: f64, batch: usize) -> Response {
        Response {
            id: 1,
            priority: Priority::Normal,
            tokens: vec![0; tokens],
            queue_us: queue,
            execute_us: exec,
            ttft_us: ttft,
            itl_us: itl,
            total_us: total,
            batch_size: batch,
        }
    }

    #[test]
    fn records_and_snapshots() {
        let m = ServerMetrics::new();
        m.record_response(&resp(8, 100.0, 2000.0, 700.0, 180.0, 2200.0, 4));
        m.record_response(&resp(8, 200.0, 2100.0, 800.0, 190.0, 2400.0, 4));
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.tokens, 16);
        assert!(s.total_p50_us >= 2000.0);
        assert!(s.ttft_p50_us >= 700.0 && s.ttft_p99_us >= s.ttft_p50_us);
        assert!(s.itl_p50_us >= 180.0);
        assert_eq!(s.mean_batch, 4.0);
        let r = s.report();
        assert!(r.contains("requests=2") && r.contains("ttft") && r.contains("itl"), "{r}");
    }

    #[test]
    fn percentiles_interpolate_within_histogram_buckets() {
        let m = ServerMetrics::new();
        // TTFT uniform over 1ms..100ms in 1ms steps: exact p99 is 99ms.
        // The log-bucket layout puts that rank in the (79.4ms, 100ms]
        // bucket, so a bucket-upper-bound readout would report 100ms
        // (+1.0%); the interpolated readout must land within 0.5%.
        for i in 1..=100u32 {
            let t = i as f64 * 1000.0;
            m.record_response(&resp(4, 10.0, 50.0, t, 100.0, t + 500.0, 1));
        }
        let s = m.snapshot();
        let exact = 99_000.0;
        assert!(
            (s.ttft_p99_us - exact).abs() / exact < 0.005,
            "ttft p99 {} vs exact {exact} — bucket-bound readout overstates the tail",
            s.ttft_p99_us
        );
        assert!(s.ttft_p99_us < 99_500.0, "p99 {} sits at the bucket bound", s.ttft_p99_us);
        assert!(s.ttft_p50_us <= s.ttft_p99_us);
    }

    #[test]
    fn single_token_responses_skip_itl() {
        let m = ServerMetrics::new();
        m.record_response(&resp(1, 10.0, 50.0, 60.0, 0.0, 80.0, 1));
        let s = m.snapshot();
        assert_eq!(s.requests, 1);
        assert_eq!(s.itl_p50_us, 0.0, "single-token response polluted the ITL histogram");
        assert!(s.ttft_p50_us > 0.0);
    }

    #[test]
    fn occupancy_histogram_and_kv_stats_flow_to_report() {
        let m = ServerMetrics::new();
        assert!(m.snapshot().occupancy_hist.is_empty());
        for lanes in [1usize, 4, 4, 4, 2, 0] {
            m.record_step_occupancy(lanes); // 0 is ignored
        }
        m.record_kv_stats(crate::kvcache::KvStats {
            live_slots: 2,
            pages_in_use: 6,
            pages_peak: 8,
            pages_capacity: 8,
            pages_budget: None,
            state_bytes: 1024,
            peak_bytes: 2048,
        });
        let s = m.snapshot();
        assert_eq!(s.occupancy_hist, vec![(1, 1), (2, 1), (4, 3)]);
        assert!((s.mean_occupancy - 15.0 / 5.0).abs() < 1e-9);
        let kv = s.kv.unwrap();
        assert_eq!((kv.pages_in_use, kv.pages_peak), (6, 8));
        let r = s.report();
        assert!(r.contains("occupancy mean=3.00") && r.contains("4:3"), "{r}");
        assert!(r.contains("kv pages=6/8 (peak 8)"), "{r}");
    }

    #[test]
    fn slo_counters_and_priority_split_flow_to_report() {
        let m = ServerMetrics::new();
        let s = m.snapshot();
        assert_eq!((s.rejected, s.shed_deadline, s.shed_kv, s.deferred, s.preempted), (0, 0, 0, 0, 0));
        assert!(!s.report().contains("slo"), "idle metrics printed an SLO line");
        m.record_rejected();
        m.record_shed(ShedReason::DeadlineExpired);
        m.record_shed(ShedReason::DeadlineExpired);
        m.record_shed(ShedReason::KvPressure);
        m.record_deferred();
        m.record_preempted();
        m.record_queue_depth(3);
        m.record_queue_depth(7);
        // One completed request per class lights up the priority split.
        m.record_response(&resp(4, 10.0, 50.0, 200.0, 30.0, 300.0, 2));
        let mut high = resp(4, 5.0, 50.0, 100.0, 20.0, 200.0, 2);
        high.priority = Priority::High;
        m.record_response(&high);
        let s = m.snapshot();
        assert_eq!((s.rejected, s.shed_deadline, s.shed_kv), (1, 2, 1));
        assert_eq!((s.deferred, s.preempted), (1, 1));
        assert!((s.queue_depth_mean - 5.0).abs() < 1e-9);
        assert_eq!(s.queue_depth_max, 7);
        assert_eq!(s.by_priority[0].class, "normal");
        assert_eq!(s.by_priority[1].requests, 1);
        assert!(s.by_priority[1].ttft_p50_us <= s.by_priority[0].ttft_p50_us);
        let r = s.report();
        assert!(r.contains("shed-deadline=2") && r.contains("shed-kv=1"), "{r}");
        assert!(r.contains("queue-depth mean=5.00 max=7"), "{r}");
        assert!(r.contains("high: n=1") && r.contains("normal: n=1"), "{r}");
    }

    #[test]
    fn panel_stats_and_json_snapshot() {
        let m = ServerMetrics::new();
        assert!(m.snapshot().panel.is_none());
        assert!(!m.snapshot().report().contains("panel"), "panel line printed with no panel cache");
        m.record_panel_stats(30, 40);
        m.record_step_occupancy(2);
        m.record_rejected();
        m.record_response(&resp(4, 10.0, 50.0, 200.0, 30.0, 300.0, 2));
        let s = m.snapshot();
        assert_eq!(s.panel, Some((30, 40)));
        assert!(s.report().contains("panel hits=30/40 (75%)"), "{}", s.report());
        // The JSON snapshot must round-trip through the parser and carry
        // every section the trace validator looks for.
        let j = crate::util::json::Json::parse(&s.to_json().to_string_compact()).unwrap();
        assert_eq!(j.get("requests").unwrap().as_u64().unwrap(), 1);
        assert_eq!(j.get("admission").unwrap().get("rejected").unwrap().as_u64().unwrap(), 1);
        assert_eq!(j.get("panel").unwrap().get("hits").unwrap().as_u64().unwrap(), 30);
        assert_eq!(j.get("occupancy").unwrap().get("hist").unwrap().as_arr().unwrap().len(), 1);
        assert!(j.get("latency").unwrap().get("ttft_p50_us").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.opt("kv").is_none() && j.opt("prefix").is_none());
    }

    #[test]
    fn spec_counters_flow_to_report_and_json() {
        let m = ServerMetrics::new();
        let s = m.snapshot();
        assert!(s.spec.is_none(), "idle metrics grew a speculation section");
        assert!(!s.report().contains("spec"), "{}", s.report());
        assert!(s.to_json().opt("speculation").is_none());
        // Two speculative steps: 3-of-4 accepted then 0-of-2 (rollback).
        m.record_spec_step(4, 3, 0);
        m.record_spec_step(2, 0, 1);
        m.record_spec_acceptance(0.5);
        m.record_spec_acceptance(1.0);
        let s = m.snapshot();
        let sp = s.spec.unwrap();
        assert_eq!((sp.steps, sp.drafted, sp.accepted), (2, 6, 3));
        assert_eq!((sp.wasted, sp.rollbacks, sp.lanes), (3, 1, 2));
        assert!((sp.acceptance_mean_pct - 75.0).abs() < 1e-9, "{}", sp.acceptance_mean_pct);
        let r = s.report();
        assert!(r.contains("spec steps=2 accepted=3/6 (50%)"), "{r}");
        assert!(r.contains("wasted=3 rollbacks=1"), "{r}");
        let j = crate::util::json::Json::parse(&s.to_json().to_string_compact()).unwrap();
        let sj = j.get("speculation").unwrap();
        assert_eq!(sj.get("drafted").unwrap().as_u64().unwrap(), 6);
        assert_eq!(sj.get("rollbacks").unwrap().as_u64().unwrap(), 1);
        assert!(sj.get("acceptance_mean_pct").unwrap().as_f64().unwrap() > 70.0);
    }

    #[test]
    fn prefix_stats_flow_to_report() {
        let m = ServerMetrics::new();
        assert!(m.snapshot().prefix.is_none());
        assert!(!m.snapshot().report().contains("prefix"), "prefix line printed with no prefix cache");
        m.record_prefix_stats(crate::prefixcache::PrefixStats {
            lookups: 8,
            hits: 6,
            saved_tokens: 96,
            published_chunks: 5,
            evicted_bytes: 4096,
            resident_bytes: 2048,
            resident_chunks: 3,
        });
        let s = m.snapshot();
        let p = s.prefix.unwrap();
        assert!((p.hit_rate() - 0.75).abs() < 1e-12);
        let r = s.report();
        assert!(r.contains("prefix hits=6/8 (75%)"), "{r}");
        assert!(r.contains("saved-tokens=96") && r.contains("evicted-bytes=4096"), "{r}");
    }
}
