//! Serving metrics: latency histograms per stage, token throughput, and
//! batch-occupancy statistics — the quantities the §Perf serving bench
//! reports (p50/p95/p99 latency, tokens/s, batch fill).

use crate::util::stats::LatencyHistogram;
use std::sync::Mutex;
use std::time::Instant;

#[derive(Debug)]
struct Inner {
    queue: LatencyHistogram,
    execute: LatencyHistogram,
    total: LatencyHistogram,
    batch_sizes: Vec<usize>,
    tokens_out: u64,
    requests_done: u64,
    started: Option<Instant>,
}

#[derive(Debug)]
pub struct ServerMetrics {
    inner: Mutex<Inner>,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerMetrics {
    pub fn new() -> ServerMetrics {
        ServerMetrics {
            inner: Mutex::new(Inner {
                queue: LatencyHistogram::new(),
                execute: LatencyHistogram::new(),
                total: LatencyHistogram::new(),
                batch_sizes: Vec::new(),
                tokens_out: 0,
                requests_done: 0,
                started: None,
            }),
        }
    }

    pub fn record_response(&self, queue_us: f64, execute_us: f64, total_us: f64, tokens: usize, batch: usize) {
        let mut g = self.inner.lock().unwrap();
        g.started.get_or_insert_with(Instant::now);
        g.queue.record_us(queue_us);
        g.execute.record_us(execute_us);
        g.total.record_us(total_us);
        g.batch_sizes.push(batch);
        g.tokens_out += tokens as u64;
        g.requests_done += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let elapsed = g.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        let mean_batch = if g.batch_sizes.is_empty() {
            0.0
        } else {
            g.batch_sizes.iter().sum::<usize>() as f64 / g.batch_sizes.len() as f64
        };
        MetricsSnapshot {
            requests: g.requests_done,
            tokens: g.tokens_out,
            tokens_per_s: if elapsed > 0.0 { g.tokens_out as f64 / elapsed } else { 0.0 },
            queue_p50_us: g.queue.percentile_us(50.0),
            queue_p99_us: g.queue.percentile_us(99.0),
            exec_p50_us: g.execute.percentile_us(50.0),
            exec_p99_us: g.execute.percentile_us(99.0),
            total_p50_us: g.total.percentile_us(50.0),
            total_p95_us: g.total.percentile_us(95.0),
            total_p99_us: g.total.percentile_us(99.0),
            mean_batch,
        }
    }
}

#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub tokens: u64,
    pub tokens_per_s: f64,
    pub queue_p50_us: f64,
    pub queue_p99_us: f64,
    pub exec_p50_us: f64,
    pub exec_p99_us: f64,
    pub total_p50_us: f64,
    pub total_p95_us: f64,
    pub total_p99_us: f64,
    pub mean_batch: f64,
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        format!(
            "requests={} tokens={} throughput={:.1} tok/s | total p50={:.0}µs p95={:.0}µs p99={:.0}µs | \
             queue p50={:.0}µs p99={:.0}µs | exec p50={:.0}µs p99={:.0}µs | mean batch={:.2}",
            self.requests,
            self.tokens,
            self.tokens_per_s,
            self.total_p50_us,
            self.total_p95_us,
            self.total_p99_us,
            self.queue_p50_us,
            self.queue_p99_us,
            self.exec_p50_us,
            self.exec_p99_us,
            self.mean_batch
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = ServerMetrics::new();
        m.record_response(100.0, 2000.0, 2200.0, 8, 4);
        m.record_response(200.0, 2100.0, 2400.0, 8, 4);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.tokens, 16);
        assert!(s.total_p50_us >= 2000.0);
        assert_eq!(s.mean_batch, 4.0);
        assert!(s.report().contains("requests=2"));
    }
}
