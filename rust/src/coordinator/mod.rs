//! L3 serving coordinator — the system wrapper around the paper's
//! contribution: requests flow router → dynamic batcher → scheduler →
//! fixed-shape PJRT executor running the W4A4 graphs, with the frozen
//! ≤0.19 KB codebook family resident in the runtime (paper §3's
//! "activation quantization on the fly" deployment).

pub mod batcher;
pub mod continuous;
pub mod drafter;
pub mod executor;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod session;

pub use batcher::{BatchPolicy, Batcher, PopResult, PushOutcome};
pub use continuous::{run_continuous, run_continuous_opts, ContinuousOpts};
pub use drafter::{AlwaysWrongDrafter, Drafter, DrafterKind, NGramDrafter};
#[cfg(feature = "pjrt")]
pub use executor::PjrtExecutor;
pub use executor::{CpuExecutor, MockExecutor, StepExecutor};
pub use metrics::{MetricsSnapshot, PrioritySlo, ServerMetrics, SpecStats};
pub use request::{AdmitError, Limits, Priority, Request, Response, ShedError, ShedReason};
pub use scheduler::{run_batch, Sampling};
pub use server::{Server, Ticket};
pub use session::{DecodeEngine, DecodeSession, KvCacheOpts, MockDecodeEngine, PrefillProgress};
