//! Request/response types for the serving coordinator.

use std::time::Instant;

/// Scheduling class: two levels are enough for a two-level FIFO — high
/// drains before normal at every pop, and normal lanes are the first
/// preemption victims under KV pressure.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    #[default]
    Normal,
    High,
}

impl Priority {
    /// Dense index for per-priority metrics tables.
    pub fn index(self) -> usize {
        match self {
            Priority::Normal => 0,
            Priority::High => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

/// Inference request: a token prompt plus generation length, carrying
/// its SLO envelope (priority class + optional absolute deadline).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub submitted_at: Instant,
    pub priority: Priority,
    /// Absolute deadline: a request still queued past this instant is
    /// shed at pop time instead of decoded (`None` = no deadline).
    pub deadline: Option<Instant>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new: usize) -> Request {
        Request {
            id,
            prompt,
            max_new,
            submitted_at: Instant::now(),
            priority: Priority::Normal,
            deadline: None,
        }
    }

    pub fn with_priority(mut self, priority: Priority) -> Request {
        self.priority = priority;
        self
    }

    pub fn with_deadline(mut self, deadline: Option<Instant>) -> Request {
        self.deadline = deadline;
        self
    }

    /// Whether the deadline has passed as of `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.map(|d| now >= d).unwrap_or(false)
    }
}

/// Why a request was shed instead of answered. Carried as the typed
/// source of the terminal `anyhow::Error`, so clients can branch on
/// shed-vs-fault via `Error::downcast_ref::<ShedError>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// Deadline passed while queued; shed at pop time, never decoded.
    DeadlineExpired,
    /// Displaced under KV-page pressure with nothing left to yield —
    /// the pressure ladder (evict → defer → preempt) was exhausted.
    KvPressure,
}

/// Terminal shed event for one request (load shedding, not a fault).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShedError {
    pub id: u64,
    pub reason: ShedReason,
}

impl std::fmt::Display for ShedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.reason {
            ShedReason::DeadlineExpired => write!(f, "request {} shed: deadline expired in queue", self.id),
            ShedReason::KvPressure => write!(f, "request {} shed: KV page budget exhausted", self.id),
        }
    }
}

impl std::error::Error for ShedError {}

/// Completed response with per-stage timing.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Scheduling class the request ran under (per-priority SLO metrics
    /// key off this).
    pub priority: Priority,
    /// Generated tokens (not including the prompt).
    pub tokens: Vec<u32>,
    /// Time from submit to batch pickup.
    pub queue_us: f64,
    /// Time spent in model execution (sum over decode steps).
    pub execute_us: f64,
    /// Time from submit to the first generated token (queue + prefill).
    pub ttft_us: f64,
    /// Mean inter-token latency across the decode phase (0 when a single
    /// token was generated).
    pub itl_us: f64,
    /// End-to-end latency.
    pub total_us: f64,
    /// Batch size this request was served in.
    pub batch_size: usize,
}

/// Validation limits enforced by the router.
#[derive(Debug, Clone, Copy)]
pub struct Limits {
    pub max_prompt: usize,
    pub max_new: usize,
    pub vocab: u32,
}

#[derive(Debug, PartialEq, Eq)]
pub enum AdmitError {
    EmptyPrompt,
    PromptTooLong(usize, usize),
    TooManyTokens(usize, usize),
    BadToken(u32, u32),
    /// Admission queue at capacity — bounded-queue backpressure, the
    /// client should retry later.
    QueueFull(usize),
    Shutdown,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::EmptyPrompt => write!(f, "empty prompt"),
            AdmitError::PromptTooLong(n, lim) => write!(f, "prompt length {n} exceeds limit {lim}"),
            AdmitError::TooManyTokens(n, lim) => write!(f, "max_new {n} exceeds limit {lim}"),
            AdmitError::BadToken(tok, vocab) => write!(f, "token {tok} outside vocabulary {vocab}"),
            AdmitError::QueueFull(cap) => write!(f, "admission queue full (capacity {cap})"),
            AdmitError::Shutdown => write!(f, "server shutting down"),
        }
    }
}

impl std::error::Error for AdmitError {}

/// Validate a request against the limits (router admission check).
pub fn validate(prompt: &[u32], max_new: usize, limits: &Limits) -> Result<(), AdmitError> {
    if prompt.is_empty() {
        return Err(AdmitError::EmptyPrompt);
    }
    if prompt.len() > limits.max_prompt {
        return Err(AdmitError::PromptTooLong(prompt.len(), limits.max_prompt));
    }
    if max_new == 0 || max_new > limits.max_new {
        return Err(AdmitError::TooManyTokens(max_new, limits.max_new));
    }
    if let Some(&bad) = prompt.iter().find(|&&t| t >= limits.vocab) {
        return Err(AdmitError::BadToken(bad, limits.vocab));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn limits() -> Limits {
        Limits { max_prompt: 48, max_new: 16, vocab: 168 }
    }

    #[test]
    fn accepts_valid() {
        assert!(validate(&[1, 2, 3], 4, &limits()).is_ok());
    }

    #[test]
    fn rejects_invalid() {
        let l = limits();
        assert_eq!(validate(&[], 4, &l), Err(AdmitError::EmptyPrompt));
        assert!(matches!(validate(&vec![1; 100], 4, &l), Err(AdmitError::PromptTooLong(100, 48))));
        assert!(matches!(validate(&[1], 0, &l), Err(AdmitError::TooManyTokens(0, 16))));
        assert!(matches!(validate(&[1, 200], 4, &l), Err(AdmitError::BadToken(200, 168))));
    }

    #[test]
    fn request_builders_and_expiry() {
        use std::time::{Duration, Instant};
        let r = Request::new(7, vec![1, 2], 4);
        assert_eq!((r.priority, r.deadline), (Priority::Normal, None));
        assert!(!r.expired(Instant::now() + Duration::from_secs(3600)), "no deadline never expires");
        let now = Instant::now();
        let r = r.with_priority(Priority::High).with_deadline(Some(now + Duration::from_millis(50)));
        assert_eq!(r.priority, Priority::High);
        assert!(!r.expired(now));
        assert!(r.expired(now + Duration::from_millis(50)));
        assert!(Priority::High > Priority::Normal, "ordering drives the two-level FIFO");
        assert_eq!((Priority::Normal.index(), Priority::High.index()), (0, 1));
    }

    #[test]
    fn shed_error_is_typed_and_downcastable() {
        let e: anyhow::Error = ShedError { id: 9, reason: ShedReason::DeadlineExpired }.into();
        let s = e.downcast_ref::<ShedError>().expect("shed error lost its type through anyhow");
        assert_eq!((s.id, s.reason), (9, ShedReason::DeadlineExpired));
        assert!(e.to_string().contains("deadline expired"), "{e}");
        let e: anyhow::Error = ShedError { id: 3, reason: ShedReason::KvPressure }.into();
        assert!(e.to_string().contains("KV page budget"), "{e}");
    }
}
