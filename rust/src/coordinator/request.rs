//! Request/response types for the serving coordinator.

use std::time::Instant;

/// Inference request: a token prompt plus generation length.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    pub submitted_at: Instant,
}

/// Completed response with per-stage timing.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Generated tokens (not including the prompt).
    pub tokens: Vec<u32>,
    /// Time from submit to batch pickup.
    pub queue_us: f64,
    /// Time spent in model execution (sum over decode steps).
    pub execute_us: f64,
    /// Time from submit to the first generated token (queue + prefill).
    pub ttft_us: f64,
    /// Mean inter-token latency across the decode phase (0 when a single
    /// token was generated).
    pub itl_us: f64,
    /// End-to-end latency.
    pub total_us: f64,
    /// Batch size this request was served in.
    pub batch_size: usize,
}

/// Validation limits enforced by the router.
#[derive(Debug, Clone, Copy)]
pub struct Limits {
    pub max_prompt: usize,
    pub max_new: usize,
    pub vocab: u32,
}

#[derive(Debug, PartialEq, Eq)]
pub enum AdmitError {
    EmptyPrompt,
    PromptTooLong(usize, usize),
    TooManyTokens(usize, usize),
    BadToken(u32, u32),
    Shutdown,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::EmptyPrompt => write!(f, "empty prompt"),
            AdmitError::PromptTooLong(n, lim) => write!(f, "prompt length {n} exceeds limit {lim}"),
            AdmitError::TooManyTokens(n, lim) => write!(f, "max_new {n} exceeds limit {lim}"),
            AdmitError::BadToken(tok, vocab) => write!(f, "token {tok} outside vocabulary {vocab}"),
            AdmitError::Shutdown => write!(f, "server shutting down"),
        }
    }
}

impl std::error::Error for AdmitError {}

/// Validate a request against the limits (router admission check).
pub fn validate(prompt: &[u32], max_new: usize, limits: &Limits) -> Result<(), AdmitError> {
    if prompt.is_empty() {
        return Err(AdmitError::EmptyPrompt);
    }
    if prompt.len() > limits.max_prompt {
        return Err(AdmitError::PromptTooLong(prompt.len(), limits.max_prompt));
    }
    if max_new == 0 || max_new > limits.max_new {
        return Err(AdmitError::TooManyTokens(max_new, limits.max_new));
    }
    if let Some(&bad) = prompt.iter().find(|&&t| t >= limits.vocab) {
        return Err(AdmitError::BadToken(bad, limits.vocab));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn limits() -> Limits {
        Limits { max_prompt: 48, max_new: 16, vocab: 168 }
    }

    #[test]
    fn accepts_valid() {
        assert!(validate(&[1, 2, 3], 4, &limits()).is_ok());
    }

    #[test]
    fn rejects_invalid() {
        let l = limits();
        assert_eq!(validate(&[], 4, &l), Err(AdmitError::EmptyPrompt));
        assert!(matches!(validate(&vec![1; 100], 4, &l), Err(AdmitError::PromptTooLong(100, 48))));
        assert!(matches!(validate(&[1], 0, &l), Err(AdmitError::TooManyTokens(0, 16))));
        assert!(matches!(validate(&[1, 200], 4, &l), Err(AdmitError::BadToken(200, 168))));
    }
}
