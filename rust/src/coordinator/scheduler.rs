//! Batch decode scheduler: takes one dynamic batch, runs autoregressive
//! decode steps on the fixed-shape executor (padding partial batches),
//! and produces per-request responses with stage timings.
//!
//! Decode uses a sliding context window of the executor's `t`: the model
//! artifacts are full-sequence forwards, so each step re-scores the
//! window and reads only each sequence's frontier logits
//! (`StepExecutor::step_last` — the full `batch·t·vocab` tensor is never
//! materialized). This is the fixed-shape PJRT-compatible path; the CPU
//! serving default is the incremental KV-cached engine in
//! `coordinator::continuous` / `coordinator::session`, which makes
//! per-token work O(current length) instead of a full-window re-score —
//! and, with the prefix cache on, skips prefill for prompt prefixes
//! another request already paid for (admission-time longest-prefix
//! match; no equivalent exists here, since this path keeps no KV state
//! between steps at all).

use super::executor::StepExecutor;
use super::metrics::ServerMetrics;
use super::request::{Request, Response};
use crate::data::corpus::PAD;
use std::time::Instant;

/// Sampling policy for generated tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sampling {
    Greedy,
    /// Top-k sampling with a deterministic per-request seed.
    TopK(usize),
}

/// Decode one batch of requests to completion. Returns responses in the
/// same order as `batch`. When `metrics` is given, every executor step
/// records its batch occupancy (sequences still generating — the same
/// live-lanes-per-step histogram the continuous path keeps, so the two
/// scheduling paths are directly comparable in the serve summary).
pub fn run_batch<E: StepExecutor + ?Sized>(
    exec: &E,
    batch: &[Request],
    sampling: Sampling,
    metrics: Option<&ServerMetrics>,
) -> anyhow::Result<Vec<Response>> {
    assert!(!batch.is_empty());
    assert!(batch.len() <= exec.batch(), "batch {} exceeds executor {}", batch.len(), exec.batch());
    let (b_exec, t) = (exec.batch(), exec.t());
    let picked_at = Instant::now();

    // Per-sequence state: full token history (prompt + generated).
    let mut seqs: Vec<Vec<u32>> = batch.iter().map(|r| r.prompt.clone()).collect();
    let max_new = batch.iter().map(|r| r.max_new).max().unwrap();
    let mut execute_us = 0.0f64;
    // End time of each decode step (TTFT = step 0, ITL = later spacing).
    let mut step_ends: Vec<Instant> = Vec::with_capacity(max_new);

    for _step in 0..max_new {
        if let Some(m) = metrics {
            let live = batch
                .iter()
                .enumerate()
                .filter(|(i, r)| seqs[*i].len() - r.prompt.len() < r.max_new)
                .count();
            m.record_step_occupancy(live);
        }
        // Build the fixed-shape token tensor: right-aligned... we LEFT-pack
        // each sequence's last `t` tokens and remember frontier positions.
        let mut tokens = vec![PAD; b_exec * t];
        let mut frontier = vec![0usize; batch.len()];
        for (i, seq) in seqs.iter().enumerate() {
            let ctx = if seq.len() > t { &seq[seq.len() - t..] } else { &seq[..] };
            tokens[i * t..i * t + ctx.len()].copy_from_slice(ctx);
            frontier[i] = ctx.len() - 1;
        }
        let t0 = Instant::now();
        // Frontier-only logits: only the sampled positions materialize
        // (the executor skips the other batch·t LM-head rows).
        let logits = exec.step_last(&tokens, &frontier)?;
        execute_us += t0.elapsed().as_secs_f64() * 1e6;
        step_ends.push(Instant::now());

        for (i, req) in batch.iter().enumerate() {
            if seqs[i].len() - req.prompt.len() >= req.max_new {
                continue; // this sequence is done; others may still decode
            }
            let next = pick_token(&logits, i, 0, sampling, req.id, seqs[i].len());
            seqs[i].push(next);
        }
    }

    let done = Instant::now();
    Ok(batch
        .iter()
        .enumerate()
        .map(|(i, req)| {
            let queue_us = (picked_at - req.submitted_at).as_secs_f64() * 1e6;
            let n = req.max_new;
            // First sampled token lands at the end of step 0. (step_ends
            // is empty only for a degenerate all-max_new=0 batch, which
            // the router rejects but this public fn must not panic on.)
            let ttft_us = step_ends
                .first()
                .map(|e| (*e - req.submitted_at).as_secs_f64() * 1e6)
                .unwrap_or(0.0);
            let itl_us = if n > 1 {
                (step_ends[n - 1] - step_ends[0]).as_secs_f64() * 1e6 / (n - 1) as f64
            } else {
                0.0
            };
            Response {
                id: req.id,
                priority: req.priority,
                tokens: seqs[i][req.prompt.len()..].to_vec(),
                queue_us,
                execute_us,
                ttft_us,
                itl_us,
                total_us: (done - req.submitted_at).as_secs_f64() * 1e6,
                batch_size: batch.len(),
            }
        })
        .collect())
}

fn pick_token(
    logits: &crate::runtime::Logits,
    row: usize,
    pos: usize,
    sampling: Sampling,
    req_id: u64,
    step: usize,
) -> u32 {
    let v = logits.vocab;
    let slice = &logits.data[(row * logits.t + pos) * v..(row * logits.t + pos + 1) * v];
    sample_from_logits(slice, sampling, req_id, step)
}

/// Sample one token from a vocab-length logits slice — shared by the
/// fixed-batch scheduler above and the continuous decode loop
/// (`coordinator::continuous`). Deterministic per (request, step).
pub(crate) fn sample_from_logits(slice: &[f32], sampling: Sampling, req_id: u64, step: usize) -> u32 {
    let v = slice.len();
    match sampling {
        Sampling::Greedy => argmax(slice) as u32,
        Sampling::TopK(k) => {
            let mut idx: Vec<usize> = (0..v).collect();
            // total_cmp, not partial_cmp().unwrap(): logits come from
            // the engine, and a NaN (bad weights, poisoned lane) must
            // not panic the worker thread mid-serve.
            idx.sort_by(|&a, &b| slice[b].total_cmp(&slice[a]));
            idx.truncate(k.max(1));
            // Softmax over the top-k, sampled with a per-(request, step)
            // deterministic stream.
            let max = slice[idx[0]] as f64;
            let weights: Vec<f64> = idx.iter().map(|&i| ((slice[i] as f64) - max).exp()).collect();
            let total: f64 = weights.iter().sum();
            let mut rng = crate::util::rng::Pcg32::new(req_id ^ (step as u64) << 17, 0x5A);
            let mut x = rng.next_f64() * total;
            for (w, &i) in weights.iter().zip(&idx) {
                if x < *w {
                    return i as u32;
                }
                x -= w;
            }
            idx[idx.len() - 1] as u32
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::MockExecutor;
    use crate::util::prop::{ensure, forall};

    fn req(id: u64, prompt: Vec<u32>, max_new: usize) -> Request {
        Request::new(id, prompt, max_new)
    }

    #[test]
    fn sampling_survives_nan_logits() {
        // A poisoned lane can hand the sampler NaN logits; neither
        // sampling mode may panic the worker thread over it.
        let logits = [1.0f32, f32::NAN, 0.5, f32::NAN];
        let g = sample_from_logits(&logits, Sampling::Greedy, 1, 0);
        assert!((g as usize) < logits.len());
        let t = sample_from_logits(&logits, Sampling::TopK(3), 1, 0);
        assert!((t as usize) < logits.len());
    }

    #[test]
    fn greedy_decode_follows_mock_successor_rule() {
        let exec = MockExecutor::new(4, 16, 32);
        let batch = vec![req(1, vec![5], 4), req(2, vec![9, 10], 3)];
        let out = run_batch(&exec, &batch, Sampling::Greedy, None).unwrap();
        // Mock predicts tok+1: from 5 -> 6,7,8,9; from 10 -> 11,12,13.
        assert_eq!(out[0].tokens, vec![6, 7, 8, 9]);
        assert_eq!(out[1].tokens, vec![11, 12, 13]);
        assert_eq!(out[0].batch_size, 2);
        // One executor call per decode step of the longest request.
        assert_eq!(exec.call_count(), 4);
    }

    #[test]
    fn shorter_requests_stop_early() {
        let exec = MockExecutor::new(2, 8, 32);
        let batch = vec![req(1, vec![1], 1), req(2, vec![1], 5)];
        let out = run_batch(&exec, &batch, Sampling::Greedy, None).unwrap();
        assert_eq!(out[0].tokens.len(), 1);
        assert_eq!(out[1].tokens.len(), 5);
    }

    #[test]
    fn context_window_slides() {
        // Prompt longer than t still decodes (uses last t tokens).
        let exec = MockExecutor::new(1, 4, 32);
        let batch = vec![req(1, vec![1, 2, 3, 4, 5, 6], 2)];
        let out = run_batch(&exec, &batch, Sampling::Greedy, None).unwrap();
        assert_eq!(out[0].tokens, vec![7, 8]);
    }

    #[test]
    fn run_batch_records_step_occupancy() {
        use crate::coordinator::metrics::ServerMetrics;
        let exec = MockExecutor::new(4, 16, 32);
        let m = ServerMetrics::new();
        let batch = vec![req(1, vec![5], 3), req(2, vec![9], 1)];
        run_batch(&exec, &batch, Sampling::Greedy, Some(&m)).unwrap();
        // 3 executor steps: both sequences live at step 0, only the
        // longer request still generating at steps 1-2.
        assert_eq!(m.snapshot().occupancy_hist, vec![(1, 2), (2, 1)]);
    }

    #[test]
    fn topk_is_deterministic_and_valid() {
        let exec = MockExecutor::new(1, 8, 32);
        let batch = vec![req(7, vec![3], 6)];
        let a = run_batch(&exec, &batch, Sampling::TopK(3), None).unwrap();
        let b = run_batch(&exec, &batch, Sampling::TopK(3), None).unwrap();
        assert_eq!(a[0].tokens, b[0].tokens);
        assert!(a[0].tokens.iter().all(|&t| t < 32));
    }

    #[test]
    fn prop_response_lengths_and_ids() {
        forall(81, "scheduler response shape", |rng| {
            let exec = MockExecutor::new(8, 16, 64);
            let n = 1 + rng.index(8);
            let batch: Vec<Request> = (0..n)
                .map(|i| {
                    let plen = 1 + rng.index(10);
                    let prompt: Vec<u32> = (0..plen).map(|_| rng.below(64)).collect();
                    req(i as u64, prompt, 1 + rng.index(6))
                })
                .collect();
            let out = run_batch(&exec, &batch, Sampling::Greedy, None).map_err(|e| e.to_string())?;
            ensure(out.len() == n, || "response count".into())?;
            for (r, q) in out.iter().zip(&batch) {
                ensure(r.id == q.id, || "id mismatch".into())?;
                ensure(r.tokens.len() == q.max_new, || "length mismatch".into())?;
                ensure(r.tokens.iter().all(|&t| t < 64), || "token out of vocab".into())?;
            }
            Ok(())
        });
    }
}
