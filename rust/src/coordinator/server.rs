//! The serving front-end: router (admission + id assignment) → dynamic
//! batcher → scheduler worker → response delivery. One worker thread per
//! executor (the PJRT engine serializes executions anyway; multiple
//! workers make sense with multiple executors/variants).
//!
//! SLO plumbing: `submit_with` carries a priority class and an optional
//! relative deadline into the bounded admission queue. A full queue
//! rejects at submit time (`AdmitError::QueueFull`); a deadline that
//! expires while queued resolves the ticket with a typed [`ShedError`]
//! instead of hanging the client — every accepted ticket gets exactly
//! one terminal event.

use super::batcher::{BatchPolicy, Batcher, PushOutcome};
use super::continuous::{run_continuous_opts, ContinuousOpts};
use super::executor::StepExecutor;
use super::metrics::ServerMetrics;
use super::request::{validate, AdmitError, Limits, Priority, Request, Response, ShedError, ShedReason};
use super::scheduler::{run_batch, Sampling};
use super::session::DecodeEngine;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Ticket returned on submit; blocks for the response.
pub struct Ticket {
    pub id: u64,
    rx: mpsc::Receiver<anyhow::Result<Response>>,
}

impl Ticket {
    pub fn wait(self) -> anyhow::Result<Response> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("server dropped response channel"))?
    }
}

type ReplyMap = Arc<Mutex<HashMap<u64, mpsc::Sender<anyhow::Result<Response>>>>>;

/// Deliver terminal shed errors for every deadline-expired request the
/// batcher binned. The fixed-batch worker calls this around each batch
/// (the continuous scheduler drains the bin itself and routes sheds
/// through its deliver callback, so only this path needs it).
fn deliver_shed(batcher: &Batcher, replies: &ReplyMap, metrics: &ServerMetrics) {
    for req in batcher.drain_shed() {
        metrics.record_shed(ShedReason::DeadlineExpired);
        if let Some(tx) = replies.lock().unwrap().remove(&req.id) {
            let _ = tx.send(Err(ShedError { id: req.id, reason: ShedReason::DeadlineExpired }.into()));
        }
    }
}

/// The serving coordinator.
pub struct Server {
    batcher: Arc<Batcher>,
    replies: ReplyMap,
    next_id: AtomicU64,
    limits: Limits,
    pub metrics: Arc<ServerMetrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start a server over an executor. The executor moves to the worker
    /// thread (PJRT handles are not Sync; `PjrtExecutor` holds a channel
    /// client so this is cheap).
    pub fn start<E: StepExecutor + 'static>(
        exec: E,
        policy: BatchPolicy,
        limits: Limits,
        sampling: Sampling,
    ) -> Server {
        let batcher = Arc::new(Batcher::new(policy));
        let replies: ReplyMap = Arc::new(Mutex::new(HashMap::new()));
        let metrics = Arc::new(ServerMetrics::new());

        let b = batcher.clone();
        let r = replies.clone();
        let m = metrics.clone();
        let worker = std::thread::Builder::new()
            .name("lobcq-worker".into())
            .spawn(move || {
                while let Some(batch) = b.next_batch() {
                    // An empty batch signals shed-only progress: expired
                    // requests need their terminal errors delivered even
                    // though there is nothing to decode.
                    deliver_shed(&b, &r, &m);
                    if batch.is_empty() {
                        continue;
                    }
                    let result = run_batch(&exec, &batch, sampling, Some(&m));
                    let mut guard = r.lock().unwrap();
                    match result {
                        Ok(responses) => {
                            for resp in responses {
                                m.record_response(&resp);
                                if let Some(tx) = guard.remove(&resp.id) {
                                    let _ = tx.send(Ok(resp));
                                }
                            }
                        }
                        Err(e) => {
                            // Fail every request of the batch with the error.
                            for req in &batch {
                                if let Some(tx) = guard.remove(&req.id) {
                                    let _ = tx.send(Err(anyhow::anyhow!("batch failed: {e}")));
                                }
                            }
                        }
                    }
                }
                // Shutdown drain: anything expired after the last batch
                // still owes its ticket a terminal event.
                deliver_shed(&b, &r, &m);
            })
            .expect("spawn worker");

        Server { batcher, replies, next_id: AtomicU64::new(1), limits, metrics, workers: vec![worker] }
    }

    /// Start a server over a stateful [`DecodeEngine`] with the
    /// continuous-batching scheduler and the default admission policy
    /// (unbounded queue, inline prefill).
    pub fn start_continuous<E: DecodeEngine + 'static>(
        engine: E,
        limits: Limits,
        sampling: Sampling,
    ) -> Server {
        Server::start_continuous_with(
            engine,
            limits,
            sampling,
            BatchPolicy::default(),
            ContinuousOpts::default(),
        )
    }

    /// Start a continuous-batching server with explicit admission policy
    /// (`queue_cap` bounds the queue) and scheduler options
    /// (`prefill_chunk` bounds per-iteration prefill work).
    pub fn start_continuous_with<E: DecodeEngine + 'static>(
        mut engine: E,
        limits: Limits,
        sampling: Sampling,
        policy: BatchPolicy,
        opts: ContinuousOpts,
    ) -> Server {
        let batcher = Arc::new(Batcher::new(policy));
        let replies: ReplyMap = Arc::new(Mutex::new(HashMap::new()));
        let metrics = Arc::new(ServerMetrics::new());

        let b = batcher.clone();
        let r = replies.clone();
        let m = metrics.clone();
        let worker = std::thread::Builder::new()
            .name("lobcq-decode-worker".into())
            .spawn(move || {
                run_continuous_opts(&mut engine, &b, opts, sampling, Some(&m), |id, result| {
                    if let Ok(resp) = &result {
                        m.record_response(resp);
                    }
                    if let Some(tx) = r.lock().unwrap().remove(&id) {
                        let _ = tx.send(result);
                    }
                });
            })
            .expect("spawn decode worker");

        Server { batcher, replies, next_id: AtomicU64::new(1), limits, metrics, workers: vec![worker] }
    }

    /// Router entry point: validate, assign id, enqueue at normal
    /// priority with no deadline.
    pub fn submit(&self, prompt: Vec<u32>, max_new: usize) -> Result<Ticket, AdmitError> {
        self.submit_with(prompt, max_new, Priority::Normal, None)
    }

    /// Router entry point with the full SLO envelope: scheduling class
    /// plus an optional deadline relative to now. A request still queued
    /// past its deadline is shed (its ticket resolves with a typed
    /// [`ShedError`]) rather than decoded late.
    pub fn submit_with(
        &self,
        prompt: Vec<u32>,
        max_new: usize,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> Result<Ticket, AdmitError> {
        validate(&prompt, max_new, &self.limits)?;
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = mpsc::channel();
        self.replies.lock().unwrap().insert(id, tx);
        let req = Request::new(id, prompt, max_new)
            .with_priority(priority)
            .with_deadline(deadline.map(|d| Instant::now() + d));
        match self.batcher.push(req) {
            PushOutcome::Accepted => Ok(Ticket { id, rx }),
            PushOutcome::QueueFull => {
                self.replies.lock().unwrap().remove(&id);
                self.metrics.record_rejected();
                Err(AdmitError::QueueFull(self.batcher.policy().queue_cap.unwrap_or(0)))
            }
            PushOutcome::Closed => {
                self.replies.lock().unwrap().remove(&id);
                Err(AdmitError::Shutdown)
            }
        }
    }

    /// Graceful shutdown: drain the queue, join workers.
    pub fn shutdown(mut self) {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.batcher.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::MockExecutor;
    use crate::coordinator::session::MockDecodeEngine;
    use std::time::Duration;

    fn server(max_batch: usize, wait_ms: u64) -> Server {
        Server::start(
            MockExecutor::new(8, 16, 64),
            BatchPolicy { max_batch, max_wait: Duration::from_millis(wait_ms), queue_cap: None },
            Limits { max_prompt: 12, max_new: 8, vocab: 64 },
            Sampling::Greedy,
        )
    }

    #[test]
    fn end_to_end_single_request() {
        let s = server(4, 1);
        let resp = s.submit(vec![5], 3).unwrap().wait().unwrap();
        assert_eq!(resp.tokens, vec![6, 7, 8]);
        assert!(resp.total_us > 0.0);
        s.shutdown();
    }

    #[test]
    fn concurrent_requests_all_answered() {
        let s = Arc::new(server(8, 5));
        let mut handles = Vec::new();
        for i in 0..24u32 {
            let s2 = s.clone();
            handles.push(std::thread::spawn(move || {
                let prompt = vec![(i % 60) as u32];
                s2.submit(prompt, 2).unwrap().wait().unwrap()
            }));
        }
        let mut ids = Vec::new();
        for h in handles {
            let resp = h.join().unwrap();
            assert_eq!(resp.tokens.len(), 2);
            ids.push(resp.id);
        }
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 24, "duplicate or missing responses");
        let snap = s.metrics.snapshot();
        assert_eq!(snap.requests, 24);
        assert!(snap.mean_batch > 1.0, "batching never kicked in: {}", snap.mean_batch);
        match Arc::try_unwrap(s) {
            Ok(s) => s.shutdown(),
            Err(_) => panic!("server still referenced"),
        }
    }

    #[test]
    fn continuous_server_end_to_end() {
        let s = Arc::new(Server::start_continuous(
            MockDecodeEngine::new(2, 64),
            Limits { max_prompt: 12, max_new: 8, vocab: 64 },
            Sampling::Greedy,
        ));
        let mut handles = Vec::new();
        for i in 0..9u32 {
            let s2 = s.clone();
            handles.push(std::thread::spawn(move || {
                s2.submit(vec![(i % 60) as u32], 3).unwrap().wait().unwrap()
            }));
        }
        for h in handles {
            let resp = h.join().unwrap();
            assert_eq!(resp.tokens.len(), 3);
            // Successor rule: first token = prompt+1.
            assert_eq!(resp.tokens[1], (resp.tokens[0] + 1) % 64);
            assert!(resp.ttft_us > 0.0 && resp.ttft_us <= resp.total_us);
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.requests, 9);
        assert!(snap.mean_occupancy >= 1.0, "no decode-step occupancy recorded: {}", snap.mean_occupancy);
        assert!(!snap.occupancy_hist.is_empty());
        match Arc::try_unwrap(s) {
            Ok(s) => s.shutdown(),
            Err(_) => panic!("server still referenced"),
        }
    }

    #[test]
    fn router_rejects_invalid() {
        let s = server(2, 0);
        assert!(s.submit(vec![], 2).is_err());
        assert!(s.submit(vec![1; 99], 2).is_err());
        assert!(s.submit(vec![99], 2).is_err()); // out-of-vocab token 99 < 64? no: 99 >= 64
        s.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let s = server(2, 0);
        let b = s.batcher.clone();
        b.close();
        assert_eq!(s.submit(vec![1], 1).err(), Some(AdmitError::Shutdown));
        s.shutdown();
    }

    #[test]
    fn full_queue_rejects_at_submit_with_typed_error() {
        // queue_cap 0 makes every push hit the bound deterministically,
        // independent of how fast the worker drains.
        let s = Server::start_continuous_with(
            MockDecodeEngine::new(2, 64),
            Limits { max_prompt: 12, max_new: 8, vocab: 64 },
            Sampling::Greedy,
            BatchPolicy { max_batch: 8, max_wait: Duration::ZERO, queue_cap: Some(0) },
            ContinuousOpts::default(),
        );
        let err = s.submit(vec![1], 1).err().expect("bounded queue must reject");
        assert_eq!(err, AdmitError::QueueFull(0));
        assert_eq!(s.metrics.snapshot().rejected, 1);
        s.shutdown();
    }

    #[test]
    fn expired_deadline_resolves_ticket_with_shed_error() {
        // Deadline of zero: expired by the time the worker pops it, so
        // the ticket must resolve with a typed shed error (not hang,
        // not decode).
        let s = Server::start_continuous(
            MockDecodeEngine::new(2, 64),
            Limits { max_prompt: 12, max_new: 8, vocab: 64 },
            Sampling::Greedy,
        );
        let t = s
            .submit_with(vec![3], 2, Priority::High, Some(Duration::ZERO))
            .expect("admission accepts; shedding happens at pop time");
        let err = t.wait().err().expect("expired request must not produce tokens");
        let shed = err.downcast_ref::<ShedError>().expect("terminal error must stay typed");
        assert_eq!(shed.reason, ShedReason::DeadlineExpired);
        let snap = s.metrics.snapshot();
        assert_eq!((snap.shed_deadline, snap.requests), (1, 0));
        s.shutdown();
    }

    #[test]
    fn fixed_batch_worker_delivers_shed_errors() {
        // The legacy fixed-batch path must honour deadlines too: an
        // empty next_batch() signals shed progress and the worker owes
        // the ticket its terminal error.
        let s = server(4, 0);
        let t = s
            .submit_with(vec![5], 2, Priority::Normal, Some(Duration::ZERO))
            .expect("admission accepts");
        let err = t.wait().err().expect("expired request must not decode");
        assert!(err.downcast_ref::<ShedError>().is_some(), "untyped shed error: {err}");
        assert_eq!(s.metrics.snapshot().shed_deadline, 1);
        s.shutdown();
    }
}
