//! The serving front-end: router (admission + id assignment) → dynamic
//! batcher → scheduler worker → response delivery. One worker thread per
//! executor (the PJRT engine serializes executions anyway; multiple
//! workers make sense with multiple executors/variants).

use super::batcher::{BatchPolicy, Batcher};
use super::continuous::run_continuous;
use super::executor::StepExecutor;
use super::metrics::ServerMetrics;
use super::request::{validate, AdmitError, Limits, Request, Response};
use super::scheduler::{run_batch, Sampling};
use super::session::DecodeEngine;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Ticket returned on submit; blocks for the response.
pub struct Ticket {
    pub id: u64,
    rx: mpsc::Receiver<anyhow::Result<Response>>,
}

impl Ticket {
    pub fn wait(self) -> anyhow::Result<Response> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("server dropped response channel"))?
    }
}

type ReplyMap = Arc<Mutex<HashMap<u64, mpsc::Sender<anyhow::Result<Response>>>>>;

/// The serving coordinator.
pub struct Server {
    batcher: Arc<Batcher>,
    replies: ReplyMap,
    next_id: AtomicU64,
    limits: Limits,
    pub metrics: Arc<ServerMetrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start a server over an executor. The executor moves to the worker
    /// thread (PJRT handles are not Sync; `PjrtExecutor` holds a channel
    /// client so this is cheap).
    pub fn start<E: StepExecutor + 'static>(
        exec: E,
        policy: BatchPolicy,
        limits: Limits,
        sampling: Sampling,
    ) -> Server {
        let batcher = Arc::new(Batcher::new(policy));
        let replies: ReplyMap = Arc::new(Mutex::new(HashMap::new()));
        let metrics = Arc::new(ServerMetrics::new());

        let b = batcher.clone();
        let r = replies.clone();
        let m = metrics.clone();
        let worker = std::thread::Builder::new()
            .name("lobcq-worker".into())
            .spawn(move || {
                while let Some(batch) = b.next_batch() {
                    let result = run_batch(&exec, &batch, sampling, Some(&m));
                    let mut guard = r.lock().unwrap();
                    match result {
                        Ok(responses) => {
                            for resp in responses {
                                m.record_response(&resp);
                                if let Some(tx) = guard.remove(&resp.id) {
                                    let _ = tx.send(Ok(resp));
                                }
                            }
                        }
                        Err(e) => {
                            // Fail every request of the batch with the error.
                            for req in &batch {
                                if let Some(tx) = guard.remove(&req.id) {
                                    let _ = tx.send(Err(anyhow::anyhow!("batch failed: {e}")));
                                }
                            }
                        }
                    }
                }
            })
            .expect("spawn worker");

        Server { batcher, replies, next_id: AtomicU64::new(1), limits, metrics, workers: vec![worker] }
    }

    /// Start a server over a stateful [`DecodeEngine`] with the
    /// continuous-batching scheduler: requests are admitted into engine
    /// lanes as they free up (token-granular backfill) instead of being
    /// held in fixed batches. No `BatchPolicy` — concurrency is the
    /// engine's lane count and admission is immediate.
    pub fn start_continuous<E: DecodeEngine + 'static>(
        mut engine: E,
        limits: Limits,
        sampling: Sampling,
    ) -> Server {
        let batcher = Arc::new(Batcher::new(BatchPolicy::default()));
        let replies: ReplyMap = Arc::new(Mutex::new(HashMap::new()));
        let metrics = Arc::new(ServerMetrics::new());

        let b = batcher.clone();
        let r = replies.clone();
        let m = metrics.clone();
        let worker = std::thread::Builder::new()
            .name("lobcq-decode-worker".into())
            .spawn(move || {
                run_continuous(&mut engine, &b, sampling, Some(&m), |id, result| {
                    if let Ok(resp) = &result {
                        m.record_response(resp);
                    }
                    if let Some(tx) = r.lock().unwrap().remove(&id) {
                        let _ = tx.send(result);
                    }
                });
            })
            .expect("spawn decode worker");

        Server { batcher, replies, next_id: AtomicU64::new(1), limits, metrics, workers: vec![worker] }
    }

    /// Router entry point: validate, assign id, enqueue.
    pub fn submit(&self, prompt: Vec<u32>, max_new: usize) -> Result<Ticket, AdmitError> {
        validate(&prompt, max_new, &self.limits)?;
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let (tx, rx) = mpsc::channel();
        self.replies.lock().unwrap().insert(id, tx);
        let ok = self.batcher.push(Request { id, prompt, max_new, submitted_at: Instant::now() });
        if !ok {
            self.replies.lock().unwrap().remove(&id);
            return Err(AdmitError::Shutdown);
        }
        Ok(Ticket { id, rx })
    }

    /// Graceful shutdown: drain the queue, join workers.
    pub fn shutdown(mut self) {
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.batcher.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::MockExecutor;
    use std::time::Duration;

    fn server(max_batch: usize, wait_ms: u64) -> Server {
        Server::start(
            MockExecutor::new(8, 16, 64),
            BatchPolicy { max_batch, max_wait: Duration::from_millis(wait_ms) },
            Limits { max_prompt: 12, max_new: 8, vocab: 64 },
            Sampling::Greedy,
        )
    }

    #[test]
    fn end_to_end_single_request() {
        let s = server(4, 1);
        let resp = s.submit(vec![5], 3).unwrap().wait().unwrap();
        assert_eq!(resp.tokens, vec![6, 7, 8]);
        assert!(resp.total_us > 0.0);
        s.shutdown();
    }

    #[test]
    fn concurrent_requests_all_answered() {
        let s = Arc::new(server(8, 5));
        let mut handles = Vec::new();
        for i in 0..24u32 {
            let s2 = s.clone();
            handles.push(std::thread::spawn(move || {
                let prompt = vec![(i % 60) as u32];
                s2.submit(prompt, 2).unwrap().wait().unwrap()
            }));
        }
        let mut ids = Vec::new();
        for h in handles {
            let resp = h.join().unwrap();
            assert_eq!(resp.tokens.len(), 2);
            ids.push(resp.id);
        }
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 24, "duplicate or missing responses");
        let snap = s.metrics.snapshot();
        assert_eq!(snap.requests, 24);
        assert!(snap.mean_batch > 1.0, "batching never kicked in: {}", snap.mean_batch);
        match Arc::try_unwrap(s) {
            Ok(s) => s.shutdown(),
            Err(_) => panic!("server still referenced"),
        }
    }

    #[test]
    fn continuous_server_end_to_end() {
        use crate::coordinator::session::MockDecodeEngine;
        let s = Arc::new(Server::start_continuous(
            MockDecodeEngine::new(2, 64),
            Limits { max_prompt: 12, max_new: 8, vocab: 64 },
            Sampling::Greedy,
        ));
        let mut handles = Vec::new();
        for i in 0..9u32 {
            let s2 = s.clone();
            handles.push(std::thread::spawn(move || {
                s2.submit(vec![(i % 60) as u32], 3).unwrap().wait().unwrap()
            }));
        }
        for h in handles {
            let resp = h.join().unwrap();
            assert_eq!(resp.tokens.len(), 3);
            // Successor rule: first token = prompt+1.
            assert_eq!(resp.tokens[1], (resp.tokens[0] + 1) % 64);
            assert!(resp.ttft_us > 0.0 && resp.ttft_us <= resp.total_us);
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.requests, 9);
        assert!(snap.mean_occupancy >= 1.0, "no decode-step occupancy recorded: {}", snap.mean_occupancy);
        assert!(!snap.occupancy_hist.is_empty());
        match Arc::try_unwrap(s) {
            Ok(s) => s.shutdown(),
            Err(_) => panic!("server still referenced"),
        }
    }

    #[test]
    fn router_rejects_invalid() {
        let s = server(2, 0);
        assert!(s.submit(vec![], 2).is_err());
        assert!(s.submit(vec![1; 99], 2).is_err());
        assert!(s.submit(vec![99], 2).is_err()); // out-of-vocab token 99 < 64? no: 99 >= 64
        s.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let s = server(2, 0);
        let b = s.batcher.clone();
        b.close();
        assert_eq!(s.submit(vec![1], 1).err(), Some(AdmitError::Shutdown));
        s.shutdown();
    }
}
