//! Stateful decode engines: the lane-oriented counterpart of
//! [`StepExecutor`](super::executor::StepExecutor). A [`DecodeEngine`]
//! owns per-lane sequence state (for the CPU engine, a slot in the paged
//! KV cache), so generating a token is **O(current length)** — prefill
//! once, then one decode call per token — instead of the fixed-shape
//! executor's full-window re-score. Lanes are released the moment a
//! request finishes, which is what the continuous batcher exploits to
//! backfill admitted requests mid-batch.
//!
//! The scheduler's hot call is [`DecodeEngine::decode_batch`]: one
//! **fused** forward advancing every live lane by one token (single
//! activation-quantization pass, each projection GEMM launched once per
//! step), with per-lane results so one bad request fails alone.

use crate::eval::Scheme;
use crate::kvcache::{KvLayout, KvPressure, KvQuantizer, KvStats, KvStore, PagedKvCache, SlotId};
use crate::model::decode::{
    decode_step, decode_step_batch, decode_step_batch_spec, prefill_from, validate_decode_lane, DecodeScratch,
};
use crate::model::{ModelConfig, Weights};
use crate::prefixcache::{PrefixCache, PrefixStats};
use crate::quant::pipeline::{QuantPipeline, QuantPool};

/// Progress of a chunked prefill (see [`DecodeEngine::prefill_chunk`]).
#[derive(Debug)]
pub enum PrefillProgress {
    /// More prompt tokens remain; `done` are cached so far.
    Pending { done: usize },
    /// Prefill complete: the prompt's last-position logits.
    Done(Vec<f32>),
}

/// A stateful incremental decoder with `max_concurrency` independent
/// lanes. `begin_prefill` claims a lane and stages a prompt;
/// `prefill_chunk` advances the staged prefill by a bounded number of
/// tokens (the chunked-admission seam — live decode lanes stall at most
/// one chunk); `decode` advances one lane by one token and returns the
/// new position's logits; `release` frees the lane for the next request.
pub trait DecodeEngine: Send {
    /// Concurrent lanes (the continuous scheduler's admission bound).
    fn max_concurrency(&self) -> usize;
    fn vocab(&self) -> usize;
    /// Per-lane token capacity (prompt + generated).
    fn max_tokens(&self) -> usize;
    /// Claim a lane and stage `prompt` for prefill — adopt any cached
    /// prefix, but run **no forward compute** yet. Pair with
    /// [`prefill_chunk`](Self::prefill_chunk) calls until `Done`.
    fn begin_prefill(&mut self, prompt: &[u32]) -> anyhow::Result<usize>;
    /// Advance the staged prefill by at most `max_tokens` prompt tokens
    /// (at least one — `0` is treated as `1` so every call makes
    /// progress). K/V at position `p` depends only on `prompt[..=p]`,
    /// so any chunking is **bit-identical** to one inline prefill. An
    /// error leaves the lane intact at its pre-call token count: a
    /// KV-pressure failure can be retried with the *same* call once
    /// pages free up. Callers that give up must `release` the lane.
    fn prefill_chunk(&mut self, lane: usize, prompt: &[u32], max_tokens: usize) -> anyhow::Result<PrefillProgress>;
    /// Claim a lane, run the whole prompt inline, return `(lane,
    /// last-position logits)` — `begin_prefill` plus one maximal chunk.
    /// On error the lane is released (no leak), matching the historical
    /// inline-prefill contract.
    fn prefill(&mut self, prompt: &[u32]) -> anyhow::Result<(usize, Vec<f32>)> {
        let lane = self.begin_prefill(prompt)?;
        loop {
            match self.prefill_chunk(lane, prompt, usize::MAX) {
                Ok(PrefillProgress::Done(logits)) => return Ok((lane, logits)),
                Ok(PrefillProgress::Pending { .. }) => {}
                Err(e) => {
                    self.release(lane);
                    return Err(e);
                }
            }
        }
    }
    /// Best-effort reclamation under KV-page pressure — rung one of the
    /// scheduler's pressure ladder. Engines with a prefix cache evict
    /// it; others have nothing to give back. Returns bytes freed (`0` =
    /// nothing reclaimed, the scheduler moves to the next rung).
    fn relieve_pressure(&mut self) -> usize {
        0
    }
    /// Feed `token` to `lane`; returns the next position's logits.
    fn decode(&mut self, lane: usize, token: u32) -> anyhow::Result<Vec<f32>>;
    /// Advance **every** listed lane by one token in one scheduler step,
    /// returning one result per lane (order-aligned with `lanes`) so an
    /// errored lane fails alone. Engines with a fused forward
    /// ([`DecodeSession`]) override this to run a **single batched
    /// step** — one activation-quantization pass, each projection GEMM
    /// once per step instead of once per lane. The default is the
    /// serial per-lane loop (same results, lane by lane).
    fn decode_batch(&mut self, lanes: &[usize], tokens: &[u32]) -> Vec<anyhow::Result<Vec<f32>>> {
        assert_eq!(lanes.len(), tokens.len(), "lanes/tokens length mismatch");
        lanes.iter().zip(tokens).map(|(&l, &t)| self.decode(l, t)).collect()
    }
    /// Whether this engine implements the speculative pair
    /// ([`decode_batch_spec`](Self::decode_batch_spec) /
    /// [`truncate`](Self::truncate)). The scheduler only drafts for
    /// engines that do; everything else stays on the plain fused step.
    fn supports_speculation(&self) -> bool {
        false
    }
    /// Stacked-verify step: advance every listed lane by its frontier
    /// token **plus** its speculative draft, returning per-lane results
    /// where `Ok` holds `(1 + drafts[i].len()) * vocab` concatenated
    /// logit rows — row `r` is the logits after the lane's `r`-th fed
    /// token, so the caller greedily verifies the draft against rows
    /// `0..k` and rolls rejected tail tokens back with
    /// [`truncate`](Self::truncate). With every draft empty this **is**
    /// [`decode_batch`](Self::decode_batch) (the default delegates), so
    /// a speculative scheduler degrades to plain decode for free on
    /// rounds where the drafter has nothing to say.
    fn decode_batch_spec(&mut self, lanes: &[usize], tokens: &[u32], drafts: &[Vec<u32>]) -> Vec<anyhow::Result<Vec<f32>>> {
        assert_eq!(lanes.len(), tokens.len(), "lanes/tokens length mismatch");
        assert_eq!(lanes.len(), drafts.len(), "lanes/drafts length mismatch");
        if drafts.iter().all(|d| d.is_empty()) {
            return self.decode_batch(lanes, tokens);
        }
        lanes.iter().map(|_| Err(anyhow::anyhow!("engine does not support speculative decode"))).collect()
    }
    /// Rewind `lane`'s cached history to its first `len` tokens — the
    /// rollback half of speculative decode, erasing rejected draft
    /// positions so the lane is indistinguishable from one that never
    /// speculated (prefix publishing included). Engines without KV
    /// rollback refuse.
    fn truncate(&mut self, lane: usize, len: usize) -> anyhow::Result<()> {
        let _ = (lane, len);
        anyhow::bail!("engine does not support KV truncation")
    }
    /// Free a lane (idempotent).
    fn release(&mut self, lane: usize);
    /// KV-cache occupancy snapshot for the serving metrics (engines
    /// without a paged cache return `None`).
    fn kv_stats(&self) -> Option<KvStats> {
        None
    }
    /// Prefix-cache counters (hit rate / saved prefill tokens / evicted
    /// bytes) for the serving metrics; `None` when the engine has no
    /// prefix cache.
    fn prefix_stats(&self) -> Option<PrefixStats> {
        None
    }
    /// Decoded-panel cache counters `(hits, decodes)` from the
    /// encoded-attention fast path; `None` when the engine has no panel
    /// cache (mocks, gather-only engines).
    fn panel_stats(&self) -> Option<(u64, u64)> {
        None
    }
}

/// KV-cache configuration for [`DecodeSession`].
#[derive(Debug, Clone)]
pub struct KvCacheOpts {
    /// Tokens per page.
    pub page_tokens: usize,
    /// Store cached K/V LO-BCQ-encoded (~4.9 bits/scalar at head_dim 64)
    /// instead of f32.
    pub encoded: bool,
    /// Byte budget for the cross-request prefix cache (`None` = off):
    /// released slots publish their full KV pages into a radix tree and
    /// admissions adopt the longest cached prefix, prefilling only the
    /// uncached suffix.
    pub prefix_cache_bytes: Option<usize>,
    /// Hard cap on KV pages the pool may materialize (`None` =
    /// unbounded). Under the cap, appends fail with a typed
    /// [`KvPressure`] instead of growing — the scheduler's graceful-
    /// degradation ladder (evict prefix cache → defer admission →
    /// preempt) keys off that error.
    pub page_budget: Option<usize>,
}

impl Default for KvCacheOpts {
    fn default() -> Self {
        KvCacheOpts { page_tokens: 16, encoded: false, prefix_cache_bytes: None, page_budget: None }
    }
}

/// CPU decode engine: quantized weights (encoded-domain when the scheme
/// supports it), on-the-fly activation quantization, and a paged —
/// optionally BCQ-encoded — KV cache shared by all lanes, with optional
/// cross-request prefix reuse through a radix tree over published
/// pages.
pub struct DecodeSession {
    cfg: ModelConfig,
    weights: Weights,
    act: Option<QuantPipeline>,
    cache: PagedKvCache,
    /// Cross-request prefix tree (admission-time longest-prefix match,
    /// publish on release). `None` when disabled.
    prefix: Option<PrefixCache>,
    /// Tokens fed to each slot so far (prompt + generated tokens whose
    /// K/V has been appended) — the key material a release publishes
    /// alongside the slot's pages. Indexed by slot id; empty when the
    /// slot is dead.
    slot_tokens: Vec<Vec<u32>>,
    /// Prefix-cache tokens adopted at `begin_prefill`, per slot — the
    /// hit is recorded only once the chunked prefill completes (only
    /// then was the work actually saved).
    adopted: Vec<usize>,
    scratch: DecodeScratch,
    encoded_weights: bool,
}

impl DecodeSession {
    /// Build from a model + scheme, mirroring `CpuExecutor::new`'s weight
    /// handling, plus the KV cache. In encoded-KV mode the cache's
    /// codebooks are calibrated once from rows of the first QKV
    /// projection (the proxy-statistics protocol of §4.1 — K/V entries
    /// are projections of the same distribution).
    pub fn new(
        cfg: ModelConfig,
        weights: &Weights,
        scheme: &Scheme,
        pool: QuantPool,
        max_concurrency: usize,
        kv: KvCacheOpts,
    ) -> anyhow::Result<DecodeSession> {
        anyhow::ensure!(max_concurrency >= 1, "need at least one lane");
        let store = if kv.encoded {
            let hd = cfg.head_dim();
            let wqkv = weights.get("l0.attn.wqkv")?;
            let n = (hd * 256).min(wqkv.data.len() / hd * hd);
            anyhow::ensure!(n >= hd, "wqkv too small to calibrate a KV quantizer");
            KvStore::Encoded(KvQuantizer::calibrated(hd, &wqkv.data[..n], 0xCA11)?)
        } else {
            KvStore::F32
        };
        let layout = KvLayout::for_model(&cfg, kv.page_tokens, max_concurrency);
        let mut cache = PagedKvCache::new(layout, store)?;
        cache.set_page_budget(kv.page_budget);
        let prefix = kv
            .prefix_cache_bytes
            .map(|budget| PrefixCache::new(kv.page_tokens, cfg.n_layers * cfg.n_heads, budget));
        let (qw, encoded_weights) = scheme.serving_weights(&cfg, weights, pool);
        let act = scheme.act_pipeline(pool);
        Ok(DecodeSession {
            cfg,
            weights: qw,
            act,
            cache,
            prefix,
            slot_tokens: vec![Vec::new(); max_concurrency],
            adopted: vec![0; max_concurrency],
            scratch: DecodeScratch::new(),
            encoded_weights,
        })
    }

    pub fn act_scheme_name(&self) -> String {
        self.act.as_ref().map(|p| p.name()).unwrap_or_else(|| "BF16".into())
    }

    pub fn weight_mode(&self) -> &'static str {
        crate::eval::scheme::weight_mode_name(self.encoded_weights)
    }

    /// "KV16 (f32 pages)" / "KV4 (BCQ-encoded pages, …)".
    pub fn kv_mode(&self) -> String {
        self.cache.store_name()
    }

    /// "off" / "on (budget N bytes)" — for the serve startup line.
    pub fn prefix_mode(&self) -> String {
        match &self.prefix {
            None => "off".into(),
            Some(t) => format!("on (budget {} bytes)", t.budget_bytes()),
        }
    }

    pub fn cache(&self) -> &PagedKvCache {
        &self.cache
    }

    /// Adjust the KV page budget live (`None` = unbounded).
    pub fn set_page_budget(&mut self, budget: Option<usize>) {
        self.cache.set_page_budget(budget);
    }
}

impl DecodeEngine for DecodeSession {
    fn max_concurrency(&self) -> usize {
        self.cache.layout().max_slots
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn max_tokens(&self) -> usize {
        self.cache.layout().max_tokens
    }

    /// Admission: claim a slot and (when the prefix cache is on) match
    /// the longest cached prefix and pin its pages — a warm hit turns an
    /// O(prompt²) prefill into an O(suffix) one, bit-identical to the
    /// cold path. No forward compute runs here; `prefill_chunk` drives
    /// it. A CoW adoption that would bust the page budget falls back to
    /// adopting only the zero-cost full pages (pressure, if real,
    /// resurfaces at the first chunk where the scheduler's ladder
    /// handles it).
    fn begin_prefill(&mut self, prompt: &[u32]) -> anyhow::Result<usize> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let slot: SlotId = self.cache.alloc_slot()?;
        self.adopted[slot] = 0;
        if let Some(tree) = self.prefix.as_mut() {
            let m = tree.match_prefix(prompt);
            if m.matched_tokens > 0 {
                let partial = m.partial.as_ref().map(|(g, n)| (g.as_slice(), *n));
                match self.cache.adopt_prefix(slot, &m.full, partial) {
                    Ok(()) => self.adopted[slot] = m.matched_tokens,
                    Err(e) if e.downcast_ref::<KvPressure>().is_some() => {
                        // Only the partial page's CoW copy costs pages;
                        // full-page adoption is refcount-only and can
                        // never be the thing under pressure.
                        if !m.full.is_empty() && self.cache.adopt_prefix(slot, &m.full, None).is_ok() {
                            self.adopted[slot] = m.full.len() * self.cache.layout().page_tokens;
                        }
                    }
                    Err(e) => {
                        self.cache.free_slot(slot);
                        return Err(e);
                    }
                }
            }
        }
        Ok(slot)
    }

    /// One budget-sized slice of prefill work. The resume offset is the
    /// slot's cached length itself (adopted prefix + completed chunks),
    /// so a KV-pressure failure — which `prefill_from` pre-checks before
    /// touching the cache — leaves the lane retryable at the exact same
    /// position.
    fn prefill_chunk(&mut self, lane: usize, prompt: &[u32], max_tokens: usize) -> anyhow::Result<PrefillProgress> {
        anyhow::ensure!(self.cache.is_live(lane), "prefill_chunk on a dead lane {lane}");
        let offset = self.cache.seq_len(lane);
        anyhow::ensure!(
            offset < prompt.len(),
            "prefill_chunk past the prompt ({offset} of {} tokens cached)",
            prompt.len()
        );
        let end = prompt.len().min(offset.saturating_add(max_tokens.max(1)));
        let logits = prefill_from(
            &self.cfg,
            &self.weights,
            &mut self.cache,
            lane,
            &prompt[..end],
            offset,
            self.act.as_ref(),
            &mut self.scratch,
        )?;
        if end < prompt.len() {
            return Ok(PrefillProgress::Pending { done: end });
        }
        if self.adopted[lane] > 0 {
            // Only now was the adopted prefill work actually saved.
            if let Some(tree) = self.prefix.as_mut() {
                tree.record_hit(self.adopted[lane]);
            }
        }
        self.slot_tokens[lane] = prompt.to_vec();
        Ok(PrefillProgress::Done(logits))
    }

    /// Pressure-ladder rung one: force-evict the whole prefix cache
    /// (drop the byte budget to zero, trim, restore), returning the
    /// bytes it gave back to the page pool.
    fn relieve_pressure(&mut self) -> usize {
        let Some(tree) = self.prefix.as_mut() else { return 0 };
        let budget = tree.budget_bytes();
        tree.set_budget_bytes(0);
        let freed = tree.evict_to_budget(self.cache.pool_mut());
        tree.set_budget_bytes(budget);
        freed
    }

    fn decode(&mut self, lane: usize, token: u32) -> anyhow::Result<Vec<f32>> {
        let out = decode_step(&self.cfg, &self.weights, &mut self.cache, lane, token, self.act.as_ref(), &mut self.scratch)?;
        // The fed token's K/V is now cached: record it so a later
        // publish pairs every cached position with its token id.
        self.slot_tokens[lane].push(token);
        Ok(out)
    }

    /// The serving hot path: one fused forward over every live lane.
    /// Lane-local failures (dead/full lane, bad token, duplicate) are
    /// screened out **per lane** first, so the fused step runs over the
    /// healthy subset and a bad request never poisons its step-mates.
    fn decode_batch(&mut self, lanes: &[usize], tokens: &[u32]) -> Vec<anyhow::Result<Vec<f32>>> {
        assert_eq!(lanes.len(), tokens.len(), "lanes/tokens length mismatch");
        let mut out: Vec<anyhow::Result<Vec<f32>>> = Vec::with_capacity(lanes.len());
        let mut valid: Vec<usize> = Vec::new(); // indices into `lanes`
        // Screen each lane with the SAME check the fused step enforces
        // (one source of truth — `model::decode::validate_decode_lane`),
        // so a lane that would fail the batched call fails alone here.
        for (i, &tok) in tokens.iter().enumerate() {
            match validate_decode_lane(&self.cfg, &self.cache, lanes, i, tok) {
                Ok(_pos) => {
                    valid.push(i);
                    out.push(Ok(Vec::new())); // placeholder, filled below
                }
                Err(e) => out.push(Err(e)),
            }
        }
        if valid.is_empty() {
            return out;
        }
        let slots: Vec<SlotId> = valid.iter().map(|&i| lanes[i]).collect();
        let toks: Vec<u32> = valid.iter().map(|&i| tokens[i]).collect();
        let fused = decode_step_batch(
            &self.cfg,
            &self.weights,
            &mut self.cache,
            &slots,
            &toks,
            self.act.as_ref(),
            &mut self.scratch,
        );
        match fused {
            Ok(logits) => {
                let v = self.cfg.vocab;
                for (j, &i) in valid.iter().enumerate() {
                    out[i] = Ok(logits[j * v..(j + 1) * v].to_vec());
                    self.slot_tokens[lanes[i]].push(tokens[i]);
                }
            }
            Err(e) => {
                // Post-screening the fused step can only fail on an
                // engine-level fault; surface it on every participant
                // (screened-out lanes keep their own errors). KV
                // pressure stays **typed** per lane — the scheduler's
                // degradation ladder downcasts for it — and, because
                // `decode_step_batch` pre-checks the whole step's pages
                // before appending anything, no lane advanced: the same
                // step can be replayed bit-exactly after relief.
                if let Some(p) = e.downcast_ref::<KvPressure>() {
                    for &i in &valid {
                        out[i] = Err((*p).into());
                    }
                } else {
                    for &i in &valid {
                        out[i] = Err(anyhow::anyhow!("batched decode failed: {e}"));
                    }
                }
            }
        }
        out
    }

    fn supports_speculation(&self) -> bool {
        true
    }

    /// Speculative hot path: the same per-lane screening as
    /// [`decode_batch`](Self::decode_batch) — extended with the
    /// draft-specific checks the fused call enforces (draft tokens in
    /// vocab, stacked rows within capacity) so a bad draft fails alone —
    /// then **one** fused stacked-verify forward over the healthy
    /// subset. Every fed token's K/V is cached on success, so the slot
    /// token history records frontier + draft per lane; the scheduler
    /// rewinds rejected tails via [`truncate`](Self::truncate) before
    /// anything can observe them.
    fn decode_batch_spec(&mut self, lanes: &[usize], tokens: &[u32], drafts: &[Vec<u32>]) -> Vec<anyhow::Result<Vec<f32>>> {
        assert_eq!(lanes.len(), tokens.len(), "lanes/tokens length mismatch");
        assert_eq!(lanes.len(), drafts.len(), "lanes/drafts length mismatch");
        let cap = self.cache.layout().max_tokens.min(self.cfg.max_t);
        let mut out: Vec<anyhow::Result<Vec<f32>>> = Vec::with_capacity(lanes.len());
        let mut valid: Vec<usize> = Vec::new(); // indices into `lanes`
        for (i, &tok) in tokens.iter().enumerate() {
            let lane_ok = validate_decode_lane(&self.cfg, &self.cache, lanes, i, tok).and_then(|pos| {
                for &t in &drafts[i] {
                    anyhow::ensure!((t as usize) < self.cfg.vocab, "draft token {t} out of vocab");
                }
                anyhow::ensure!(
                    pos + 1 + drafts[i].len() <= cap,
                    "draft of {} overruns capacity at position {pos}",
                    drafts[i].len()
                );
                Ok(())
            });
            match lane_ok {
                Ok(()) => {
                    valid.push(i);
                    out.push(Ok(Vec::new())); // placeholder, filled below
                }
                Err(e) => out.push(Err(e)),
            }
        }
        if valid.is_empty() {
            return out;
        }
        let slots: Vec<SlotId> = valid.iter().map(|&i| lanes[i]).collect();
        let toks: Vec<u32> = valid.iter().map(|&i| tokens[i]).collect();
        let drs: Vec<Vec<u32>> = valid.iter().map(|&i| drafts[i].clone()).collect();
        let fused = decode_step_batch_spec(
            &self.cfg,
            &self.weights,
            &mut self.cache,
            &slots,
            &toks,
            &drs,
            self.act.as_ref(),
            &mut self.scratch,
        );
        match fused {
            Ok(logits) => {
                let v = self.cfg.vocab;
                let mut row = 0usize;
                for (j, &i) in valid.iter().enumerate() {
                    let rows = 1 + drs[j].len();
                    out[i] = Ok(logits[row * v..(row + rows) * v].to_vec());
                    self.slot_tokens[lanes[i]].push(tokens[i]);
                    self.slot_tokens[lanes[i]].extend_from_slice(&drs[j]);
                    row += rows;
                }
            }
            Err(e) => {
                // Same atomicity contract as decode_batch: the fused
                // step pre-reserves every stacked row's pages, so no
                // lane advanced and typed KV pressure replays exactly.
                if let Some(p) = e.downcast_ref::<KvPressure>() {
                    for &i in &valid {
                        out[i] = Err((*p).into());
                    }
                } else {
                    for &i in &valid {
                        out[i] = Err(anyhow::anyhow!("speculative decode failed: {e}"));
                    }
                }
            }
        }
        out
    }

    /// KV rollback for a rejected draft tail: truncate the paged cache
    /// (tail pages freed, boundary page rewritten in place — which bumps
    /// its pool generation, invalidating any decoded panel over it) and
    /// rewind the slot's token history in lockstep, so a later `release`
    /// can never publish rolled-back tokens into the prefix tree.
    fn truncate(&mut self, lane: usize, len: usize) -> anyhow::Result<()> {
        self.cache.truncate(lane, len)?;
        self.slot_tokens[lane].truncate(len);
        Ok(())
    }

    /// Free a lane — but first publish its full KV pages into the
    /// prefix tree, so the history this request paid to compute serves
    /// the next request with the same prefix. Publishing happens while
    /// the slot still holds its references (the tree retains novel
    /// pages; `free_slot` then drops the slot's references, leaving the
    /// tree as the surviving holder), after which the tree is trimmed
    /// back to its byte budget.
    fn release(&mut self, lane: usize) {
        if self.cache.is_live(lane) {
            if let Some(tree) = self.prefix.as_mut() {
                let tokens = &self.slot_tokens[lane];
                // Only a history whose every cached position has a known
                // token id is publishable (a mid-token engine fault can
                // leave them out of step — then the pages just die with
                // the slot as before).
                if tokens.len() == self.cache.seq_len(lane) {
                    let groups = self.cache.full_page_groups(lane);
                    if !groups.is_empty() {
                        tree.publish(tokens, &groups, self.cache.pool_mut());
                    }
                }
            }
            self.slot_tokens[lane].clear();
            self.adopted[lane] = 0;
        }
        self.cache.free_slot(lane);
        if let Some(tree) = self.prefix.as_mut() {
            tree.evict_to_budget(self.cache.pool_mut());
        }
    }

    fn kv_stats(&self) -> Option<KvStats> {
        Some(self.cache.stats())
    }

    fn prefix_stats(&self) -> Option<PrefixStats> {
        self.prefix.as_ref().map(|t| t.stats())
    }

    fn panel_stats(&self) -> Option<(u64, u64)> {
        let p = self.scratch.panel_cache();
        Some((p.hit_count(), p.decode_count()))
    }
}

/// Deterministic mock engine for continuous-scheduler tests: logits
/// prefer `(last_token + 1) % vocab`, lanes are bounded, and every
/// lifecycle event is recorded so tests can assert backfill behaviour.
/// An optional token-denominated KV budget (`kv_capacity`) simulates
/// page pressure — each cached prompt/decode token costs one unit, and
/// exceeding the budget fails with the same typed [`KvPressure`] the
/// real cache raises — so scheduler tests can exercise the degradation
/// ladder without a model.
pub struct MockDecodeEngine {
    pub lanes: usize,
    pub vocab: usize,
    pub max_tokens: usize,
    live: Vec<bool>,
    /// Running count of live lanes, and the high-water mark.
    pub max_live_seen: usize,
    pub prefills: usize,
    pub decodes: usize,
    pub releases: usize,
    /// Fused `decode_batch` calls, and the widest one seen — scheduler
    /// tests assert the loop steps lanes in one call, not one-by-one.
    pub batch_calls: usize,
    pub max_batch_lanes: usize,
    /// `prefill_chunk` calls (chunked-admission tests).
    pub chunk_calls: usize,
    /// `relieve_pressure` calls (ladder-order tests).
    pub relieve_calls: usize,
    /// Speculative `decode_batch_spec` calls with a nonempty draft, and
    /// the widest stacked-row count seen.
    pub spec_calls: usize,
    pub max_spec_rows: usize,
    /// `truncate` (rollback) calls.
    pub truncate_calls: usize,
    /// Token the engine should fail decode on (error-path tests).
    pub poison_token: Option<u32>,
    /// Simulated KV budget in tokens (`None` = unbounded).
    pub kv_capacity: Option<usize>,
    /// Tokens the mock "prefix cache" holds: counted against the
    /// budget, reclaimed in full by `relieve_pressure`.
    pub kv_evictable: usize,
    /// Cached tokens per lane (returned to the budget on release).
    kv_per_lane: Vec<usize>,
    /// Prompt tokens prefilled so far per lane (chunk resume offset).
    prefill_done: Vec<usize>,
}

impl MockDecodeEngine {
    pub fn new(lanes: usize, vocab: usize) -> MockDecodeEngine {
        MockDecodeEngine {
            lanes,
            vocab,
            max_tokens: usize::MAX,
            live: vec![false; lanes],
            max_live_seen: 0,
            prefills: 0,
            decodes: 0,
            releases: 0,
            batch_calls: 0,
            max_batch_lanes: 0,
            chunk_calls: 0,
            relieve_calls: 0,
            spec_calls: 0,
            max_spec_rows: 0,
            truncate_calls: 0,
            poison_token: None,
            kv_capacity: None,
            kv_evictable: 0,
            kv_per_lane: vec![0; lanes],
            prefill_done: vec![0; lanes],
        }
    }

    fn successor_logits(&self, token: u32) -> Vec<f32> {
        let mut l = vec![0.0f32; self.vocab];
        l[(token as usize + 1) % self.vocab] = 10.0;
        l
    }

    /// Total simulated KV tokens resident (lanes + evictable pool).
    pub fn kv_used(&self) -> usize {
        self.kv_per_lane.iter().sum::<usize>() + self.kv_evictable
    }

    /// Charge `n` tokens to `lane`, failing typed when the budget
    /// can't cover them (nothing consumed on failure).
    fn try_consume(&mut self, lane: usize, n: usize) -> anyhow::Result<()> {
        if let Some(cap) = self.kv_capacity {
            let used = self.kv_used();
            if used + n > cap {
                return Err(KvPressure { needed: n, headroom: cap.saturating_sub(used) }.into());
            }
        }
        self.kv_per_lane[lane] += n;
        Ok(())
    }
}

impl DecodeEngine for MockDecodeEngine {
    fn max_concurrency(&self) -> usize {
        self.lanes
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn max_tokens(&self) -> usize {
        self.max_tokens
    }

    fn begin_prefill(&mut self, prompt: &[u32]) -> anyhow::Result<usize> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let lane = self
            .live
            .iter()
            .position(|l| !l)
            .ok_or_else(|| anyhow::anyhow!("no free mock lanes"))?;
        self.live[lane] = true;
        self.prefill_done[lane] = 0;
        self.prefills += 1;
        let live_now = self.live.iter().filter(|&&l| l).count();
        self.max_live_seen = self.max_live_seen.max(live_now);
        Ok(lane)
    }

    fn prefill_chunk(&mut self, lane: usize, prompt: &[u32], max_tokens: usize) -> anyhow::Result<PrefillProgress> {
        anyhow::ensure!(self.live[lane], "prefill_chunk on a dead mock lane");
        self.chunk_calls += 1;
        let done = self.prefill_done[lane];
        anyhow::ensure!(done < prompt.len(), "prefill_chunk past the prompt");
        let take = (prompt.len() - done).min(max_tokens.max(1));
        self.try_consume(lane, take)?;
        self.prefill_done[lane] = done + take;
        if done + take < prompt.len() {
            Ok(PrefillProgress::Pending { done: done + take })
        } else {
            Ok(PrefillProgress::Done(self.successor_logits(*prompt.last().unwrap())))
        }
    }

    fn relieve_pressure(&mut self) -> usize {
        self.relieve_calls += 1;
        std::mem::take(&mut self.kv_evictable)
    }

    fn decode(&mut self, lane: usize, token: u32) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(self.live[lane], "decode on a dead mock lane");
        if self.poison_token == Some(token) {
            anyhow::bail!("poisoned token {token}");
        }
        self.try_consume(lane, 1)?;
        self.decodes += 1;
        Ok(self.successor_logits(token))
    }

    /// Records the fused-call shape (one call per scheduler step) while
    /// keeping the default's per-lane isolation semantics: a poisoned
    /// lane errors alone, its step-mates still decode. Mirrors the real
    /// fused step's atomicity under KV pressure: the whole step's token
    /// cost is pre-checked, and on a shortfall every live lane gets the
    /// typed error with **nothing consumed** — the step replays exactly.
    fn decode_batch(&mut self, lanes: &[usize], tokens: &[u32]) -> Vec<anyhow::Result<Vec<f32>>> {
        assert_eq!(lanes.len(), tokens.len(), "lanes/tokens length mismatch");
        self.batch_calls += 1;
        self.max_batch_lanes = self.max_batch_lanes.max(lanes.len());
        if let Some(cap) = self.kv_capacity {
            let need = lanes.iter().filter(|&&l| self.live.get(l).copied().unwrap_or(false)).count();
            let used = self.kv_used();
            if used + need > cap {
                let p = KvPressure { needed: need, headroom: cap.saturating_sub(used) };
                return lanes.iter().map(|_| Err(p.into())).collect();
            }
        }
        lanes.iter().zip(tokens).map(|(&l, &t)| self.decode(l, t)).collect()
    }

    fn supports_speculation(&self) -> bool {
        true
    }

    /// Mock stacked verify: row `r`'s logits are the successor of the
    /// lane's `r`-th fed token (so drafting `token + 1, token + 2, …` is
    /// always fully accepted, anything else rejects at its first wrong
    /// position). Mirrors the real step's atomicity: the whole step's
    /// row cost is pre-checked against the KV budget, and a shortfall
    /// fails every lane typed with **nothing consumed**. An all-empty
    /// draft set goes through `decode_batch` so plain-step counters
    /// stay comparable across spec-on/off runs.
    fn decode_batch_spec(&mut self, lanes: &[usize], tokens: &[u32], drafts: &[Vec<u32>]) -> Vec<anyhow::Result<Vec<f32>>> {
        assert_eq!(lanes.len(), tokens.len(), "lanes/tokens length mismatch");
        assert_eq!(lanes.len(), drafts.len(), "lanes/drafts length mismatch");
        if drafts.iter().all(|d| d.is_empty()) {
            return self.decode_batch(lanes, tokens);
        }
        self.spec_calls += 1;
        let total_rows: usize = drafts.iter().map(|d| 1 + d.len()).sum();
        self.max_spec_rows = self.max_spec_rows.max(total_rows);
        self.max_batch_lanes = self.max_batch_lanes.max(lanes.len());
        if let Some(cap) = self.kv_capacity {
            let need: usize = lanes
                .iter()
                .zip(drafts)
                .filter(|(&l, _)| self.live.get(l).copied().unwrap_or(false))
                .map(|(_, d)| 1 + d.len())
                .sum();
            let used = self.kv_used();
            if used + need > cap {
                let p = KvPressure { needed: need, headroom: cap.saturating_sub(used) };
                return lanes.iter().map(|_| Err(p.into())).collect();
            }
        }
        lanes
            .iter()
            .zip(tokens)
            .zip(drafts)
            .map(|((&l, &t), d)| {
                anyhow::ensure!(self.live.get(l).copied().unwrap_or(false), "decode on a dead mock lane");
                let mut rows = Vec::with_capacity((1 + d.len()) * self.vocab);
                for &fed in std::iter::once(&t).chain(d) {
                    if self.poison_token == Some(fed) {
                        anyhow::bail!("poisoned token {fed}");
                    }
                    rows.extend_from_slice(&self.successor_logits(fed));
                }
                self.kv_per_lane[l] += 1 + d.len();
                self.decodes += 1 + d.len();
                Ok(rows)
            })
            .collect()
    }

    fn truncate(&mut self, lane: usize, len: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.live.get(lane).copied().unwrap_or(false), "truncate on a dead mock lane");
        anyhow::ensure!(len <= self.kv_per_lane[lane], "truncate to {len} of {} mock tokens", self.kv_per_lane[lane]);
        self.truncate_calls += 1;
        self.kv_per_lane[lane] = len;
        Ok(())
    }

    fn release(&mut self, lane: usize) {
        if self.live[lane] {
            self.live[lane] = false;
            self.kv_per_lane[lane] = 0;
            self.prefill_done[lane] = 0;
            self.releases += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::tests_support::{random_weights, tiny_cfg};

    #[test]
    fn session_generates_and_recycles_lanes() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 51);
        let scheme = crate::eval::scheme::mx4();
        let mut s =
            DecodeSession::new(cfg.clone(), &w, &scheme, QuantPool::serial(), 2, KvCacheOpts::default())
                .unwrap();
        assert_eq!(s.vocab(), cfg.vocab);
        assert_eq!(s.max_concurrency(), 2);
        let (a, la) = s.prefill(&[1, 2, 3]).unwrap();
        let (b, _) = s.prefill(&[4]).unwrap();
        assert_ne!(a, b);
        assert!(s.prefill(&[5]).is_err(), "over-admitted");
        assert_eq!(la.len(), cfg.vocab);
        let step = s.decode(a, 7).unwrap();
        assert_eq!(step.len(), cfg.vocab);
        assert!(step.iter().all(|x| x.is_finite()));
        s.release(a);
        s.release(a); // idempotent
        let (c, _) = s.prefill(&[6, 7]).unwrap();
        assert_eq!(c, a, "freed lane not reused");
        s.release(b);
        s.release(c);
        assert_eq!(s.cache().live_slot_count(), 0);
    }

    #[test]
    fn session_encoded_kv_mode_reports_and_serves() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 52);
        let mut s = DecodeSession::new(
            cfg,
            &w,
            &Scheme::Bf16,
            QuantPool::serial(),
            1,
            KvCacheOpts { page_tokens: 4, encoded: true, ..KvCacheOpts::default() },
        )
        .unwrap();
        assert!(s.kv_mode().starts_with("KV4"), "{}", s.kv_mode());
        let (lane, _) = s.prefill(&[1, 2, 3, 4, 5]).unwrap();
        let out = s.decode(lane, 9).unwrap();
        assert!(out.iter().all(|x| x.is_finite()));
        assert!(s.cache().bits_per_scalar() <= 8.0);
    }

    #[test]
    fn batched_decode_matches_per_lane_decode_bitwise() {
        // Twin sessions over the same weights/scheme: one stepped lane
        // by lane, one through the fused decode_batch. Logits must agree
        // to the bit, and the fused step must resolve each projection
        // GEMM once (not once per lane).
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 54);
        let scheme = crate::eval::scheme::mx4();
        let mk = || {
            DecodeSession::new(cfg.clone(), &w, &scheme, QuantPool::serial(), 3, KvCacheOpts::default())
                .unwrap()
        };
        let mut serial = mk();
        let mut batched = mk();
        let prompts: [&[u32]; 3] = [&[1, 2, 3], &[4], &[5, 6]];
        let mut lanes_s = Vec::new();
        let mut lanes_b = Vec::new();
        for p in prompts {
            lanes_s.push(serial.prefill(p).unwrap().0);
            lanes_b.push(batched.prefill(p).unwrap().0);
        }
        for step in 0..3u32 {
            let tokens: Vec<u32> = (0..3).map(|i| (step * 5 + i + 7) % 40).collect();
            let before = batched.weights.gemm_resolutions();
            let fused = batched.decode_batch(&lanes_b, &tokens);
            assert_eq!(
                batched.weights.gemm_resolutions() - before,
                cfg.n_layers * 4,
                "fused step launched per-lane GEMMs"
            );
            for (i, r) in fused.iter().enumerate() {
                let lone = serial.decode(lanes_s[i], tokens[i]).unwrap();
                let got = r.as_ref().unwrap();
                for (c, (&g, &want)) in got.iter().zip(&lone).enumerate() {
                    assert_eq!(g.to_bits(), want.to_bits(), "step {step} lane {i} col {c}");
                }
            }
        }
        let stats = batched.kv_stats().unwrap();
        assert_eq!(stats.live_slots, 3);
        assert!(stats.pages_in_use > 0 && stats.pages_peak >= stats.pages_in_use);
    }

    #[test]
    fn batched_decode_isolates_bad_lanes() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 55);
        let mut s =
            DecodeSession::new(cfg.clone(), &w, &Scheme::Bf16, QuantPool::serial(), 3, KvCacheOpts::default())
                .unwrap();
        let (a, _) = s.prefill(&[1, 2]).unwrap();
        let (b, _) = s.prefill(&[3]).unwrap();
        s.release(b); // dead lane in the middle of the step
        let out = s.decode_batch(&[a, b, 99], &[5, 6, 7]);
        assert_eq!(out.len(), 3);
        assert!(out[0].is_ok(), "healthy lane dragged down: {:?}", out[0].as_ref().err());
        assert!(out[1].is_err(), "dead lane decoded");
        assert!(out[2].is_err(), "out-of-range lane decoded");
        assert_eq!(out[0].as_ref().unwrap().len(), cfg.vocab);
        // The healthy lane advanced exactly one position.
        assert_eq!(s.cache().seq_len(a), 3);
    }

    #[test]
    fn mock_decode_batch_records_and_isolates() {
        let mut e = MockDecodeEngine::new(3, 16);
        e.poison_token = Some(9);
        let (a, _) = e.prefill(&[1]).unwrap();
        let (b, _) = e.prefill(&[2]).unwrap();
        let out = e.decode_batch(&[a, b], &[3, 9]);
        assert_eq!(e.batch_calls, 1);
        assert_eq!(e.max_batch_lanes, 2);
        assert!(out[0].is_ok() && out[1].is_err(), "poison not isolated");
    }

    #[test]
    fn prefix_cache_reuses_published_pages_across_requests() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 56);
        let kv =
            KvCacheOpts { page_tokens: 4, prefix_cache_bytes: Some(1 << 20), ..KvCacheOpts::default() };
        let mut warm =
            DecodeSession::new(cfg.clone(), &w, &Scheme::Bf16, QuantPool::serial(), 1, kv.clone()).unwrap();
        let mut cold = DecodeSession::new(
            cfg.clone(),
            &w,
            &Scheme::Bf16,
            QuantPool::serial(),
            1,
            KvCacheOpts { prefix_cache_bytes: None, ..kv },
        )
        .unwrap();
        assert!(warm.prefix_mode().starts_with("on"), "{}", warm.prefix_mode());
        assert_eq!(cold.prefix_mode(), "off");

        let shared: Vec<u32> = (0..9).map(|i| (i * 3 + 1) % 40).collect();
        let mk_prompt = |suffix: &[u32]| -> Vec<u32> {
            shared.iter().copied().chain(suffix.iter().copied()).collect()
        };
        // Request A seeds the tree (2 full pages published on release).
        let (a, _) = warm.prefill(&mk_prompt(&[20, 21])).unwrap();
        let tok = warm.decode(a, 22).unwrap();
        assert!(tok.iter().all(|x| x.is_finite()));
        warm.release(a);
        let s = warm.prefix_stats().unwrap();
        assert_eq!(s.published_chunks, 3, "9+2 prompt +1 decode at pt=4: 3 full pages");
        assert_eq!((s.lookups, s.hits), (1, 0), "first request can't hit an empty tree");

        // Request B shares the 9-token prefix: the match covers the two
        // full shared pages plus one CoW token, and the logits are
        // bit-identical to the cold engine.
        let prompt_b = mk_prompt(&[30, 31, 32]);
        let (b, warm_logits) = warm.prefill(&prompt_b).unwrap();
        let s = warm.prefix_stats().unwrap();
        assert_eq!((s.lookups, s.hits), (2, 1), "shared prefix missed");
        assert_eq!(s.saved_tokens, 9, "2 full pages + 1 CoW token should be adopted");
        let (c, cold_logits) = cold.prefill(&prompt_b).unwrap();
        for (col, (&g, &x)) in warm_logits.iter().zip(&cold_logits).enumerate() {
            assert_eq!(g.to_bits(), x.to_bits(), "warm-hit logits diverged at col {col}");
        }
        // Decode after a warm hit stays bit-identical too.
        let wd = warm.decode(b, 33).unwrap();
        let cd = cold.decode(c, 33).unwrap();
        for (col, (&g, &x)) in wd.iter().zip(&cd).enumerate() {
            assert_eq!(g.to_bits(), x.to_bits(), "post-hit decode diverged at col {col}");
        }
        warm.release(b);
        cold.release(c);
        assert_eq!(warm.cache().live_slot_count(), 0);
    }

    #[test]
    fn prefix_cache_eviction_respects_budget() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 57);
        // A zero-byte budget: everything published is evicted as soon as
        // no slot holds it, so every request misses but nothing leaks
        // and nothing double-frees.
        let kv = KvCacheOpts { page_tokens: 4, prefix_cache_bytes: Some(0), ..KvCacheOpts::default() };
        let mut s = DecodeSession::new(cfg, &w, &Scheme::Bf16, QuantPool::serial(), 1, kv).unwrap();
        let prompt: Vec<u32> = (0..8).map(|i| i % 40).collect();
        for _ in 0..3 {
            let (lane, _) = s.prefill(&prompt).unwrap();
            s.release(lane);
        }
        let st = s.prefix_stats().unwrap();
        assert_eq!(st.hits, 0, "zero-budget tree retained pages");
        assert_eq!(st.resident_bytes, 0);
        assert!(st.evicted_bytes > 0);
        assert_eq!(s.cache().stats().pages_in_use, 0, "pages leaked past eviction");
    }

    #[test]
    fn chunked_prefill_matches_inline_bitwise() {
        // Hardest engine path — encoded weights AND BCQ-encoded KV:
        // driving admission through 3-token chunks must land on exactly
        // the same cache state and logits as one inline prefill.
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 58);
        let scheme = crate::eval::scheme::mx4();
        let kv = KvCacheOpts { page_tokens: 4, encoded: true, ..KvCacheOpts::default() };
        let mk = |kv: KvCacheOpts| {
            DecodeSession::new(cfg.clone(), &w, &scheme, QuantPool::serial(), 1, kv).unwrap()
        };
        let mut inline = mk(kv.clone());
        let mut chunked = mk(kv);
        let prompt: Vec<u32> = (0..11).map(|i| (i * 7 + 2) % 40).collect();
        let (li, inline_logits) = inline.prefill(&prompt).unwrap();
        let lc = chunked.begin_prefill(&prompt).unwrap();
        let mut dones = Vec::new();
        let chunk_logits = loop {
            match chunked.prefill_chunk(lc, &prompt, 3).unwrap() {
                PrefillProgress::Pending { done } => dones.push(done),
                PrefillProgress::Done(logits) => break logits,
            }
        };
        assert_eq!(dones, vec![3, 6, 9], "chunk boundaries drifted");
        for (col, (&a, &b)) in chunk_logits.iter().zip(&inline_logits).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "prefill logits diverged at col {col}");
        }
        for step in 0..2u32 {
            let a = chunked.decode(lc, 5 + step).unwrap();
            let b = inline.decode(li, 5 + step).unwrap();
            for (col, (&x, &y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "post-chunk decode step {step} col {col}");
            }
        }
    }

    #[test]
    fn decode_batch_pressure_is_typed_and_replayable() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 59);
        let kv = KvCacheOpts { page_tokens: 4, ..KvCacheOpts::default() };
        let mut free =
            DecodeSession::new(cfg.clone(), &w, &Scheme::Bf16, QuantPool::serial(), 1, kv.clone()).unwrap();
        let mut tight =
            DecodeSession::new(cfg.clone(), &w, &Scheme::Bf16, QuantPool::serial(), 1, kv).unwrap();
        // A 4-token prompt exactly fills the first page group...
        let prompt = [1u32, 2, 3, 4];
        let (lf, _) = free.prefill(&prompt).unwrap();
        let used = free.kv_stats().unwrap().pages_in_use;
        tight.set_page_budget(Some(used));
        let (lt, _) = tight.prefill(&prompt).unwrap();
        // ...so the next decode token needs fresh pages the budget
        // denies: the fused path must surface the *typed* pressure and
        // consume nothing.
        let out = tight.decode_batch(&[lt], &[9]);
        let err = out[0].as_ref().expect_err("budget-busting decode succeeded");
        assert!(err.downcast_ref::<KvPressure>().is_some(), "pressure lost its type: {err}");
        assert_eq!(tight.cache().seq_len(lt), 4, "failed step advanced the lane");
        // After relief (budget lifted) the very same step replays and
        // matches an unconstrained twin bit-for-bit.
        tight.set_page_budget(None);
        let replay = tight.decode_batch(&[lt], &[9]);
        let a = replay[0].as_ref().unwrap();
        let b = free.decode(lf, 9).unwrap();
        for (col, (&x, &y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "replayed step diverged at col {col}");
        }
    }

    #[test]
    fn relieve_pressure_evicts_prefix_cache_once() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 60);
        let kv = KvCacheOpts { page_tokens: 4, prefix_cache_bytes: Some(1 << 20), ..KvCacheOpts::default() };
        let mut s = DecodeSession::new(cfg, &w, &Scheme::Bf16, QuantPool::serial(), 1, kv).unwrap();
        let prompt: Vec<u32> = (0..8).map(|i| i % 40).collect();
        let (lane, _) = s.prefill(&prompt).unwrap();
        s.release(lane);
        assert!(s.cache().stats().pages_in_use > 0, "released pages not retained by the tree");
        let freed = s.relieve_pressure();
        assert!(freed > 0, "eviction freed nothing");
        assert_eq!(s.cache().stats().pages_in_use, 0, "tree still holds pages after relief");
        assert_eq!(s.relieve_pressure(), 0, "second relief found pages to free");
        // The budget was restored: later publishes are retained again.
        let (lane, _) = s.prefill(&prompt).unwrap();
        s.release(lane);
        assert!(s.cache().stats().pages_in_use > 0, "budget not restored after relief");
    }

    #[test]
    fn mock_chunked_prefill_and_step_atomic_pressure() {
        let mut e = MockDecodeEngine::new(2, 16);
        e.kv_capacity = Some(6);
        e.kv_evictable = 2;
        let a = e.begin_prefill(&[1, 2, 3]).unwrap();
        assert!(matches!(e.prefill_chunk(a, &[1, 2, 3], 2).unwrap(), PrefillProgress::Pending { done: 2 }));
        assert!(matches!(e.prefill_chunk(a, &[1, 2, 3], 2).unwrap(), PrefillProgress::Done(_)));
        let b = e.begin_prefill(&[7]).unwrap();
        assert!(matches!(e.prefill_chunk(b, &[7], usize::MAX).unwrap(), PrefillProgress::Done(_)));
        assert_eq!(e.kv_used(), 6, "3 + 1 prompt tokens + 2 evictable");
        // Whole-step pre-check: capacity has room for 0 of the 2 tokens
        // this step needs, so BOTH lanes fail typed and NOTHING is
        // consumed (the step must replay identically after relief).
        let out = e.decode_batch(&[a, b], &[3, 7]);
        for r in &out {
            let err = r.as_ref().expect_err("over-budget step decoded");
            let p = err.downcast_ref::<KvPressure>().expect("pressure lost its type");
            assert_eq!((p.needed, p.headroom), (2, 0));
        }
        assert_eq!((e.kv_used(), e.decodes), (6, 0), "failed step consumed KV");
        assert_eq!(e.relieve_pressure(), 2, "evictable pool not reclaimed");
        let out = e.decode_batch(&[a, b], &[3, 7]);
        assert!(out.iter().all(|r| r.is_ok()), "relieved step still failed");
        e.release(a);
        e.release(b);
        assert_eq!(e.kv_used(), 0, "released lanes leaked mock KV");
    }

    #[test]
    fn spec_batch_matches_plain_decode_and_rolls_back() {
        // Engine-level speculation contract on the hardest path (encoded
        // weights + BCQ KV): a stacked-verify call returns per-row
        // logits bit-identical to plain per-step decode_batch, and after
        // truncating the rejected tail the session is bit-identical to a
        // twin that never speculated — including what release publishes.
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 61);
        let scheme = crate::eval::scheme::mx4();
        let kv = KvCacheOpts { page_tokens: 4, encoded: true, ..KvCacheOpts::default() };
        let mk = || {
            DecodeSession::new(cfg.clone(), &w, &scheme, QuantPool::serial(), 1, kv.clone()).unwrap()
        };
        let (mut plain, mut spec) = (mk(), mk());
        assert!(spec.supports_speculation());
        let (lp, _) = plain.prefill(&[1, 2, 3]).unwrap();
        let (ls, _) = spec.prefill(&[1, 2, 3]).unwrap();
        // Frontier 4, draft [5, 30]: verify row-by-row against the plain
        // twin fed the same tokens one step at a time.
        let drafts = vec![vec![5u32, 30]];
        let out = spec.decode_batch_spec(&[ls], &[4], &drafts);
        let rows = out[0].as_ref().unwrap();
        assert_eq!(rows.len(), 3 * cfg.vocab);
        for (r, &tok) in [4u32, 5, 30].iter().enumerate() {
            let want = plain.decode(lp, tok).unwrap();
            for (c, (&g, &x)) in rows[r * cfg.vocab..(r + 1) * cfg.vocab].iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), x.to_bits(), "row {r} col {c}");
            }
        }
        // Reject everything after the accepted first draft token: both
        // twins should now hold [1,2,3,4,5].
        spec.truncate(ls, 5).unwrap();
        plain.truncate(lp, 5).unwrap();
        assert_eq!(spec.cache().seq_len(ls), 5);
        let a = spec.decode(ls, 7).unwrap();
        let b = plain.decode(lp, 7).unwrap();
        for (c, (&g, &x)) in a.iter().zip(&b).enumerate() {
            assert_eq!(g.to_bits(), x.to_bits(), "post-rollback decode col {c}");
        }
        spec.release(ls);
        plain.release(lp);
    }

    #[test]
    fn spec_rollback_never_publishes_rejected_tokens() {
        // A slot that speculated and rolled back must publish exactly
        // the history a never-speculated twin would: a later request
        // matching the rolled-back continuation must MISS.
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 62);
        let kv = KvCacheOpts {
            page_tokens: 2,
            prefix_cache_bytes: Some(1 << 20),
            ..KvCacheOpts::default()
        };
        let mut s = DecodeSession::new(cfg, &w, &Scheme::Bf16, QuantPool::serial(), 1, kv).unwrap();
        let (lane, _) = s.prefill(&[1, 2, 3]).unwrap();
        // Feed frontier 4 + rejected draft [8, 9], keep only the frontier.
        let out = s.decode_batch_spec(&[lane], &[4], &[vec![8, 9]]);
        assert!(out[0].is_ok());
        s.truncate(lane, 4).unwrap();
        s.release(lane);
        // [1,2,3,4] (two full pt=2 page groups) is publishable; the
        // rolled-back [..,8] continuation must not be.
        let (l2, _) = s.prefill(&[1, 2, 3, 4, 8, 9]).unwrap();
        let st = s.prefix_stats().unwrap();
        assert_eq!(st.saved_tokens, 4, "prefix tree knows rolled-back tokens");
        s.release(l2);
    }

    #[test]
    fn spec_batch_screens_bad_drafts_per_lane() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 63);
        let mut s =
            DecodeSession::new(cfg.clone(), &w, &Scheme::Bf16, QuantPool::serial(), 2, KvCacheOpts::default())
                .unwrap();
        let (a, _) = s.prefill(&[1, 2]).unwrap();
        let (b, _) = s.prefill(&[3]).unwrap();
        // Lane b's draft has an out-of-vocab token: it must fail alone
        // while lane a's speculative rows still come back.
        let out = s.decode_batch_spec(&[a, b], &[4, 5], &[vec![6], vec![999]]);
        assert!(out[0].is_ok(), "healthy lane dragged down: {:?}", out[0].as_ref().err());
        assert!(out[1].is_err(), "out-of-vocab draft accepted");
        assert_eq!(out[0].as_ref().unwrap().len(), 2 * cfg.vocab);
        assert_eq!(s.cache().seq_len(a), 4, "frontier + draft cached");
        assert_eq!(s.cache().seq_len(b), 1, "failed lane advanced");
        // Truncate misuse is refused without mutating.
        assert!(s.truncate(a, 99).is_err());
        assert_eq!(s.cache().seq_len(a), 4);
    }

    #[test]
    fn mock_spec_batch_verifies_and_truncates() {
        let mut e = MockDecodeEngine::new(2, 16);
        let (a, _) = e.prefill(&[1]).unwrap();
        let (b, _) = e.prefill(&[2]).unwrap();
        // Successor drafts are fully accepted; a wrong draft shows the
        // mismatch at its row so a scheduler can verify greedily.
        let out = e.decode_batch_spec(&[a, b], &[3, 5], &[vec![4, 5], vec![9]]);
        assert_eq!((e.spec_calls, e.max_spec_rows), (1, 5));
        let rows_a = out[0].as_ref().unwrap();
        assert_eq!(rows_a.len(), 3 * 16);
        assert_eq!(rows_a[4], 10.0, "row 0 must prefer successor 4");
        assert_eq!(rows_a[16 + 5], 10.0, "row 1 must prefer successor 5");
        let rows_b = out[1].as_ref().unwrap();
        assert_eq!(rows_b[6], 10.0, "row 0 prefers 6, so draft 9 rejects");
        // Roll lane b back to its pre-step cache (1 prompt token + the
        // frontier), as a scheduler that rejected the draft would.
        assert_eq!(e.kv_used(), 4 + 3, "1+3 rows on a, 1+2 rows on b");
        e.truncate(b, 2).unwrap();
        assert_eq!(e.truncate_calls, 1);
        assert_eq!(e.kv_used(), 4 + 2, "rollback must return draft tokens");
        // All-empty drafts route through the plain batch path.
        let before = e.batch_calls;
        let out = e.decode_batch_spec(&[a], &[6], &[vec![]]);
        assert!(out[0].is_ok());
        assert_eq!(e.batch_calls, before + 1, "empty drafts must use decode_batch");
        assert_eq!(e.spec_calls, 1);
        // Atomic pressure: a step too wide for the budget fails typed
        // with nothing consumed.
        e.kv_capacity = Some(e.kv_used() + 2);
        let used = e.kv_used();
        let out = e.decode_batch_spec(&[a, b], &[7, 8], &[vec![8, 9], vec![9]]);
        for r in &out {
            let err = r.as_ref().expect_err("over-budget spec step decoded");
            assert!(err.downcast_ref::<KvPressure>().is_some(), "pressure lost its type: {err}");
        }
        assert_eq!(e.kv_used(), used, "failed spec step consumed KV");
    }

    #[test]
    fn failed_prefill_releases_its_lane() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 53);
        let mut s =
            DecodeSession::new(cfg, &w, &Scheme::Bf16, QuantPool::serial(), 1, KvCacheOpts::default())
                .unwrap();
        assert!(s.prefill(&[9999]).is_err(), "out-of-vocab prompt accepted");
        assert_eq!(s.cache().live_slot_count(), 0, "failed prefill leaked its lane");
        assert!(s.prefill(&[1]).is_ok());
    }
}
