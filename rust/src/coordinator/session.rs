//! Stateful decode engines: the lane-oriented counterpart of
//! [`StepExecutor`](super::executor::StepExecutor). A [`DecodeEngine`]
//! owns per-lane sequence state (for the CPU engine, a slot in the paged
//! KV cache), so generating a token is **O(current length)** — prefill
//! once, then one `decode` call per token — instead of the fixed-shape
//! executor's full-window re-score. Lanes are released the moment a
//! request finishes, which is what the continuous batcher exploits to
//! backfill admitted requests mid-batch.

use crate::eval::Scheme;
use crate::kvcache::{KvLayout, KvQuantizer, KvStore, PagedKvCache, SlotId};
use crate::model::decode::{decode_step, prefill, DecodeScratch};
use crate::model::{ModelConfig, Weights};
use crate::quant::pipeline::{QuantPipeline, QuantPool};

/// A stateful incremental decoder with `max_concurrency` independent
/// lanes. `prefill` claims a lane and returns the prompt's last-position
/// logits; `decode` advances one lane by one token and returns the new
/// position's logits; `release` frees the lane for the next request.
pub trait DecodeEngine: Send {
    /// Concurrent lanes (the continuous scheduler's admission bound).
    fn max_concurrency(&self) -> usize;
    fn vocab(&self) -> usize;
    /// Per-lane token capacity (prompt + generated).
    fn max_tokens(&self) -> usize;
    /// Claim a lane, run the prompt, return `(lane, last-position logits)`.
    fn prefill(&mut self, prompt: &[u32]) -> anyhow::Result<(usize, Vec<f32>)>;
    /// Feed `token` to `lane`; returns the next position's logits.
    fn decode(&mut self, lane: usize, token: u32) -> anyhow::Result<Vec<f32>>;
    /// Free a lane (idempotent).
    fn release(&mut self, lane: usize);
}

/// KV-cache configuration for [`DecodeSession`].
#[derive(Debug, Clone)]
pub struct KvCacheOpts {
    /// Tokens per page.
    pub page_tokens: usize,
    /// Store cached K/V LO-BCQ-encoded (~4.9 bits/scalar at head_dim 64)
    /// instead of f32.
    pub encoded: bool,
}

impl Default for KvCacheOpts {
    fn default() -> Self {
        KvCacheOpts { page_tokens: 16, encoded: false }
    }
}

/// CPU decode engine: quantized weights (encoded-domain when the scheme
/// supports it), on-the-fly activation quantization, and a paged —
/// optionally BCQ-encoded — KV cache shared by all lanes.
pub struct DecodeSession {
    cfg: ModelConfig,
    weights: Weights,
    act: Option<QuantPipeline>,
    cache: PagedKvCache,
    scratch: DecodeScratch,
    encoded_weights: bool,
}

impl DecodeSession {
    /// Build from a model + scheme, mirroring `CpuExecutor::new`'s weight
    /// handling, plus the KV cache. In encoded-KV mode the cache's
    /// codebooks are calibrated once from rows of the first QKV
    /// projection (the proxy-statistics protocol of §4.1 — K/V entries
    /// are projections of the same distribution).
    pub fn new(
        cfg: ModelConfig,
        weights: &Weights,
        scheme: &Scheme,
        pool: QuantPool,
        max_concurrency: usize,
        kv: KvCacheOpts,
    ) -> anyhow::Result<DecodeSession> {
        anyhow::ensure!(max_concurrency >= 1, "need at least one lane");
        let store = if kv.encoded {
            let hd = cfg.head_dim();
            let wqkv = weights.get("l0.attn.wqkv")?;
            let n = (hd * 256).min(wqkv.data.len() / hd * hd);
            anyhow::ensure!(n >= hd, "wqkv too small to calibrate a KV quantizer");
            KvStore::Encoded(KvQuantizer::calibrated(hd, &wqkv.data[..n], 0xCA11)?)
        } else {
            KvStore::F32
        };
        let layout = KvLayout::for_model(&cfg, kv.page_tokens, max_concurrency);
        let cache = PagedKvCache::new(layout, store)?;
        let (qw, encoded_weights) = scheme.serving_weights(&cfg, weights, pool);
        let act = scheme.act_pipeline(pool);
        Ok(DecodeSession { cfg, weights: qw, act, cache, scratch: DecodeScratch::new(), encoded_weights })
    }

    pub fn act_scheme_name(&self) -> String {
        self.act.as_ref().map(|p| p.name()).unwrap_or_else(|| "BF16".into())
    }

    pub fn weight_mode(&self) -> &'static str {
        crate::eval::scheme::weight_mode_name(self.encoded_weights)
    }

    /// "KV16 (f32 pages)" / "KV4 (BCQ-encoded pages, …)".
    pub fn kv_mode(&self) -> String {
        self.cache.store_name()
    }

    pub fn cache(&self) -> &PagedKvCache {
        &self.cache
    }
}

impl DecodeEngine for DecodeSession {
    fn max_concurrency(&self) -> usize {
        self.cache.layout().max_slots
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn max_tokens(&self) -> usize {
        self.cache.layout().max_tokens
    }

    fn prefill(&mut self, prompt: &[u32]) -> anyhow::Result<(usize, Vec<f32>)> {
        let slot: SlotId = self.cache.alloc_slot()?;
        match prefill(&self.cfg, &self.weights, &mut self.cache, slot, prompt, self.act.as_ref()) {
            Ok(logits) => Ok((slot, logits)),
            Err(e) => {
                // A failed prefill must not leak the lane.
                self.cache.free_slot(slot);
                Err(e)
            }
        }
    }

    fn decode(&mut self, lane: usize, token: u32) -> anyhow::Result<Vec<f32>> {
        decode_step(&self.cfg, &self.weights, &mut self.cache, lane, token, self.act.as_ref(), &mut self.scratch)
    }

    fn release(&mut self, lane: usize) {
        self.cache.free_slot(lane);
    }
}

/// Deterministic mock engine for continuous-scheduler tests: logits
/// prefer `(last_token + 1) % vocab`, lanes are bounded, and every
/// lifecycle event is recorded so tests can assert backfill behaviour.
pub struct MockDecodeEngine {
    pub lanes: usize,
    pub vocab: usize,
    pub max_tokens: usize,
    live: Vec<bool>,
    /// Running count of live lanes, and the high-water mark.
    pub max_live_seen: usize,
    pub prefills: usize,
    pub decodes: usize,
    pub releases: usize,
    /// Token the engine should fail decode on (error-path tests).
    pub poison_token: Option<u32>,
}

impl MockDecodeEngine {
    pub fn new(lanes: usize, vocab: usize) -> MockDecodeEngine {
        MockDecodeEngine {
            lanes,
            vocab,
            max_tokens: usize::MAX,
            live: vec![false; lanes],
            max_live_seen: 0,
            prefills: 0,
            decodes: 0,
            releases: 0,
            poison_token: None,
        }
    }

    fn successor_logits(&self, token: u32) -> Vec<f32> {
        let mut l = vec![0.0f32; self.vocab];
        l[(token as usize + 1) % self.vocab] = 10.0;
        l
    }
}

impl DecodeEngine for MockDecodeEngine {
    fn max_concurrency(&self) -> usize {
        self.lanes
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn max_tokens(&self) -> usize {
        self.max_tokens
    }

    fn prefill(&mut self, prompt: &[u32]) -> anyhow::Result<(usize, Vec<f32>)> {
        let lane = self
            .live
            .iter()
            .position(|l| !l)
            .ok_or_else(|| anyhow::anyhow!("no free mock lanes"))?;
        self.live[lane] = true;
        self.prefills += 1;
        let live_now = self.live.iter().filter(|&&l| l).count();
        self.max_live_seen = self.max_live_seen.max(live_now);
        Ok((lane, self.successor_logits(*prompt.last().unwrap())))
    }

    fn decode(&mut self, lane: usize, token: u32) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(self.live[lane], "decode on a dead mock lane");
        if self.poison_token == Some(token) {
            anyhow::bail!("poisoned token {token}");
        }
        self.decodes += 1;
        Ok(self.successor_logits(token))
    }

    fn release(&mut self, lane: usize) {
        if self.live[lane] {
            self.live[lane] = false;
            self.releases += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::tests_support::{random_weights, tiny_cfg};

    #[test]
    fn session_generates_and_recycles_lanes() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 51);
        let scheme = crate::eval::scheme::mx4();
        let mut s =
            DecodeSession::new(cfg.clone(), &w, &scheme, QuantPool::serial(), 2, KvCacheOpts::default())
                .unwrap();
        assert_eq!(s.vocab(), cfg.vocab);
        assert_eq!(s.max_concurrency(), 2);
        let (a, la) = s.prefill(&[1, 2, 3]).unwrap();
        let (b, _) = s.prefill(&[4]).unwrap();
        assert_ne!(a, b);
        assert!(s.prefill(&[5]).is_err(), "over-admitted");
        assert_eq!(la.len(), cfg.vocab);
        let step = s.decode(a, 7).unwrap();
        assert_eq!(step.len(), cfg.vocab);
        assert!(step.iter().all(|x| x.is_finite()));
        s.release(a);
        s.release(a); // idempotent
        let (c, _) = s.prefill(&[6, 7]).unwrap();
        assert_eq!(c, a, "freed lane not reused");
        s.release(b);
        s.release(c);
        assert_eq!(s.cache().live_slot_count(), 0);
    }

    #[test]
    fn session_encoded_kv_mode_reports_and_serves() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 52);
        let mut s = DecodeSession::new(
            cfg,
            &w,
            &Scheme::Bf16,
            QuantPool::serial(),
            1,
            KvCacheOpts { page_tokens: 4, encoded: true },
        )
        .unwrap();
        assert!(s.kv_mode().starts_with("KV4"), "{}", s.kv_mode());
        let (lane, _) = s.prefill(&[1, 2, 3, 4, 5]).unwrap();
        let out = s.decode(lane, 9).unwrap();
        assert!(out.iter().all(|x| x.is_finite()));
        assert!(s.cache().bits_per_scalar() <= 8.0);
    }

    #[test]
    fn failed_prefill_releases_its_lane() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 53);
        let mut s =
            DecodeSession::new(cfg, &w, &Scheme::Bf16, QuantPool::serial(), 1, KvCacheOpts::default())
                .unwrap();
        assert!(s.prefill(&[9999]).is_err(), "out-of-vocab prompt accepted");
        assert_eq!(s.cache().live_slot_count(), 0, "failed prefill leaked its lane");
        assert!(s.prefill(&[1]).is_ok());
    }
}
