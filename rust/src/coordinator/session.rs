//! Stateful decode engines: the lane-oriented counterpart of
//! [`StepExecutor`](super::executor::StepExecutor). A [`DecodeEngine`]
//! owns per-lane sequence state (for the CPU engine, a slot in the paged
//! KV cache), so generating a token is **O(current length)** — prefill
//! once, then one decode call per token — instead of the fixed-shape
//! executor's full-window re-score. Lanes are released the moment a
//! request finishes, which is what the continuous batcher exploits to
//! backfill admitted requests mid-batch.
//!
//! The scheduler's hot call is [`DecodeEngine::decode_batch`]: one
//! **fused** forward advancing every live lane by one token (single
//! activation-quantization pass, each projection GEMM launched once per
//! step), with per-lane results so one bad request fails alone.

use crate::eval::Scheme;
use crate::kvcache::{KvLayout, KvQuantizer, KvStats, KvStore, PagedKvCache, SlotId};
use crate::model::decode::{decode_step, decode_step_batch, prefill_from, validate_decode_lane, DecodeScratch};
use crate::model::{ModelConfig, Weights};
use crate::prefixcache::{PrefixCache, PrefixStats};
use crate::quant::pipeline::{QuantPipeline, QuantPool};

/// A stateful incremental decoder with `max_concurrency` independent
/// lanes. `prefill` claims a lane and returns the prompt's last-position
/// logits; `decode` advances one lane by one token and returns the new
/// position's logits; `release` frees the lane for the next request.
pub trait DecodeEngine: Send {
    /// Concurrent lanes (the continuous scheduler's admission bound).
    fn max_concurrency(&self) -> usize;
    fn vocab(&self) -> usize;
    /// Per-lane token capacity (prompt + generated).
    fn max_tokens(&self) -> usize;
    /// Claim a lane, run the prompt, return `(lane, last-position logits)`.
    fn prefill(&mut self, prompt: &[u32]) -> anyhow::Result<(usize, Vec<f32>)>;
    /// Feed `token` to `lane`; returns the next position's logits.
    fn decode(&mut self, lane: usize, token: u32) -> anyhow::Result<Vec<f32>>;
    /// Advance **every** listed lane by one token in one scheduler step,
    /// returning one result per lane (order-aligned with `lanes`) so an
    /// errored lane fails alone. Engines with a fused forward
    /// ([`DecodeSession`]) override this to run a **single batched
    /// step** — one activation-quantization pass, each projection GEMM
    /// once per step instead of once per lane. The default is the
    /// serial per-lane loop (same results, lane by lane).
    fn decode_batch(&mut self, lanes: &[usize], tokens: &[u32]) -> Vec<anyhow::Result<Vec<f32>>> {
        assert_eq!(lanes.len(), tokens.len(), "lanes/tokens length mismatch");
        lanes.iter().zip(tokens).map(|(&l, &t)| self.decode(l, t)).collect()
    }
    /// Free a lane (idempotent).
    fn release(&mut self, lane: usize);
    /// KV-cache occupancy snapshot for the serving metrics (engines
    /// without a paged cache return `None`).
    fn kv_stats(&self) -> Option<KvStats> {
        None
    }
    /// Prefix-cache counters (hit rate / saved prefill tokens / evicted
    /// bytes) for the serving metrics; `None` when the engine has no
    /// prefix cache.
    fn prefix_stats(&self) -> Option<PrefixStats> {
        None
    }
}

/// KV-cache configuration for [`DecodeSession`].
#[derive(Debug, Clone)]
pub struct KvCacheOpts {
    /// Tokens per page.
    pub page_tokens: usize,
    /// Store cached K/V LO-BCQ-encoded (~4.9 bits/scalar at head_dim 64)
    /// instead of f32.
    pub encoded: bool,
    /// Byte budget for the cross-request prefix cache (`None` = off):
    /// released slots publish their full KV pages into a radix tree and
    /// admissions adopt the longest cached prefix, prefilling only the
    /// uncached suffix.
    pub prefix_cache_bytes: Option<usize>,
}

impl Default for KvCacheOpts {
    fn default() -> Self {
        KvCacheOpts { page_tokens: 16, encoded: false, prefix_cache_bytes: None }
    }
}

/// CPU decode engine: quantized weights (encoded-domain when the scheme
/// supports it), on-the-fly activation quantization, and a paged —
/// optionally BCQ-encoded — KV cache shared by all lanes, with optional
/// cross-request prefix reuse through a radix tree over published
/// pages.
pub struct DecodeSession {
    cfg: ModelConfig,
    weights: Weights,
    act: Option<QuantPipeline>,
    cache: PagedKvCache,
    /// Cross-request prefix tree (admission-time longest-prefix match,
    /// publish on release). `None` when disabled.
    prefix: Option<PrefixCache>,
    /// Tokens fed to each slot so far (prompt + generated tokens whose
    /// K/V has been appended) — the key material a release publishes
    /// alongside the slot's pages. Indexed by slot id; empty when the
    /// slot is dead.
    slot_tokens: Vec<Vec<u32>>,
    scratch: DecodeScratch,
    encoded_weights: bool,
}

impl DecodeSession {
    /// Build from a model + scheme, mirroring `CpuExecutor::new`'s weight
    /// handling, plus the KV cache. In encoded-KV mode the cache's
    /// codebooks are calibrated once from rows of the first QKV
    /// projection (the proxy-statistics protocol of §4.1 — K/V entries
    /// are projections of the same distribution).
    pub fn new(
        cfg: ModelConfig,
        weights: &Weights,
        scheme: &Scheme,
        pool: QuantPool,
        max_concurrency: usize,
        kv: KvCacheOpts,
    ) -> anyhow::Result<DecodeSession> {
        anyhow::ensure!(max_concurrency >= 1, "need at least one lane");
        let store = if kv.encoded {
            let hd = cfg.head_dim();
            let wqkv = weights.get("l0.attn.wqkv")?;
            let n = (hd * 256).min(wqkv.data.len() / hd * hd);
            anyhow::ensure!(n >= hd, "wqkv too small to calibrate a KV quantizer");
            KvStore::Encoded(KvQuantizer::calibrated(hd, &wqkv.data[..n], 0xCA11)?)
        } else {
            KvStore::F32
        };
        let layout = KvLayout::for_model(&cfg, kv.page_tokens, max_concurrency);
        let cache = PagedKvCache::new(layout, store)?;
        let prefix = kv
            .prefix_cache_bytes
            .map(|budget| PrefixCache::new(kv.page_tokens, cfg.n_layers * cfg.n_heads, budget));
        let (qw, encoded_weights) = scheme.serving_weights(&cfg, weights, pool);
        let act = scheme.act_pipeline(pool);
        Ok(DecodeSession {
            cfg,
            weights: qw,
            act,
            cache,
            prefix,
            slot_tokens: vec![Vec::new(); max_concurrency],
            scratch: DecodeScratch::new(),
            encoded_weights,
        })
    }

    pub fn act_scheme_name(&self) -> String {
        self.act.as_ref().map(|p| p.name()).unwrap_or_else(|| "BF16".into())
    }

    pub fn weight_mode(&self) -> &'static str {
        crate::eval::scheme::weight_mode_name(self.encoded_weights)
    }

    /// "KV16 (f32 pages)" / "KV4 (BCQ-encoded pages, …)".
    pub fn kv_mode(&self) -> String {
        self.cache.store_name()
    }

    /// "off" / "on (budget N bytes)" — for the serve startup line.
    pub fn prefix_mode(&self) -> String {
        match &self.prefix {
            None => "off".into(),
            Some(t) => format!("on (budget {} bytes)", t.budget_bytes()),
        }
    }

    pub fn cache(&self) -> &PagedKvCache {
        &self.cache
    }
}

impl DecodeEngine for DecodeSession {
    fn max_concurrency(&self) -> usize {
        self.cache.layout().max_slots
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn max_tokens(&self) -> usize {
        self.cache.layout().max_tokens
    }

    /// Admission: match the longest cached prefix (when the prefix
    /// cache is on), pin its pages into the fresh slot, and prefill
    /// **only the uncached suffix** — a warm hit turns an O(prompt²)
    /// prefill into an O(suffix) one, bit-identical to the cold path.
    fn prefill(&mut self, prompt: &[u32]) -> anyhow::Result<(usize, Vec<f32>)> {
        let slot: SlotId = self.cache.alloc_slot()?;
        let mut offset = 0usize;
        if let Some(tree) = self.prefix.as_mut() {
            let m = tree.match_prefix(prompt);
            if m.matched_tokens > 0 {
                let partial = m.partial.as_ref().map(|(g, n)| (g.as_slice(), *n));
                if let Err(e) = self.cache.adopt_prefix(slot, &m.full, partial) {
                    // Frees any references the partial adoption took.
                    self.cache.free_slot(slot);
                    return Err(e);
                }
                offset = m.matched_tokens;
            }
        }
        match prefill_from(
            &self.cfg,
            &self.weights,
            &mut self.cache,
            slot,
            prompt,
            offset,
            self.act.as_ref(),
            &mut self.scratch,
        ) {
            Ok(logits) => {
                if offset > 0 {
                    // Only now was the prefill work actually saved.
                    if let Some(tree) = self.prefix.as_mut() {
                        tree.record_hit(offset);
                    }
                }
                self.slot_tokens[slot] = prompt.to_vec();
                Ok((slot, logits))
            }
            Err(e) => {
                // A failed prefill must not leak the lane (or publish a
                // half-filled history).
                self.cache.free_slot(slot);
                Err(e)
            }
        }
    }

    fn decode(&mut self, lane: usize, token: u32) -> anyhow::Result<Vec<f32>> {
        let out = decode_step(&self.cfg, &self.weights, &mut self.cache, lane, token, self.act.as_ref(), &mut self.scratch)?;
        // The fed token's K/V is now cached: record it so a later
        // publish pairs every cached position with its token id.
        self.slot_tokens[lane].push(token);
        Ok(out)
    }

    /// The serving hot path: one fused forward over every live lane.
    /// Lane-local failures (dead/full lane, bad token, duplicate) are
    /// screened out **per lane** first, so the fused step runs over the
    /// healthy subset and a bad request never poisons its step-mates.
    fn decode_batch(&mut self, lanes: &[usize], tokens: &[u32]) -> Vec<anyhow::Result<Vec<f32>>> {
        assert_eq!(lanes.len(), tokens.len(), "lanes/tokens length mismatch");
        let mut out: Vec<anyhow::Result<Vec<f32>>> = Vec::with_capacity(lanes.len());
        let mut valid: Vec<usize> = Vec::new(); // indices into `lanes`
        // Screen each lane with the SAME check the fused step enforces
        // (one source of truth — `model::decode::validate_decode_lane`),
        // so a lane that would fail the batched call fails alone here.
        for (i, &tok) in tokens.iter().enumerate() {
            match validate_decode_lane(&self.cfg, &self.cache, lanes, i, tok) {
                Ok(_pos) => {
                    valid.push(i);
                    out.push(Ok(Vec::new())); // placeholder, filled below
                }
                Err(e) => out.push(Err(e)),
            }
        }
        if valid.is_empty() {
            return out;
        }
        let slots: Vec<SlotId> = valid.iter().map(|&i| lanes[i]).collect();
        let toks: Vec<u32> = valid.iter().map(|&i| tokens[i]).collect();
        let fused = decode_step_batch(
            &self.cfg,
            &self.weights,
            &mut self.cache,
            &slots,
            &toks,
            self.act.as_ref(),
            &mut self.scratch,
        );
        match fused {
            Ok(logits) => {
                let v = self.cfg.vocab;
                for (j, &i) in valid.iter().enumerate() {
                    out[i] = Ok(logits[j * v..(j + 1) * v].to_vec());
                    self.slot_tokens[lanes[i]].push(tokens[i]);
                }
            }
            Err(e) => {
                // Post-screening the fused step can only fail on an
                // engine-level fault; surface it on every participant
                // (screened-out lanes keep their own errors).
                for &i in &valid {
                    out[i] = Err(anyhow::anyhow!("batched decode failed: {e}"));
                }
            }
        }
        out
    }

    /// Free a lane — but first publish its full KV pages into the
    /// prefix tree, so the history this request paid to compute serves
    /// the next request with the same prefix. Publishing happens while
    /// the slot still holds its references (the tree retains novel
    /// pages; `free_slot` then drops the slot's references, leaving the
    /// tree as the surviving holder), after which the tree is trimmed
    /// back to its byte budget.
    fn release(&mut self, lane: usize) {
        if self.cache.is_live(lane) {
            if let Some(tree) = self.prefix.as_mut() {
                let tokens = &self.slot_tokens[lane];
                // Only a history whose every cached position has a known
                // token id is publishable (a mid-token engine fault can
                // leave them out of step — then the pages just die with
                // the slot as before).
                if tokens.len() == self.cache.seq_len(lane) {
                    let groups = self.cache.full_page_groups(lane);
                    if !groups.is_empty() {
                        tree.publish(tokens, &groups, self.cache.pool_mut());
                    }
                }
            }
            self.slot_tokens[lane].clear();
        }
        self.cache.free_slot(lane);
        if let Some(tree) = self.prefix.as_mut() {
            tree.evict_to_budget(self.cache.pool_mut());
        }
    }

    fn kv_stats(&self) -> Option<KvStats> {
        Some(self.cache.stats())
    }

    fn prefix_stats(&self) -> Option<PrefixStats> {
        self.prefix.as_ref().map(|t| t.stats())
    }
}

/// Deterministic mock engine for continuous-scheduler tests: logits
/// prefer `(last_token + 1) % vocab`, lanes are bounded, and every
/// lifecycle event is recorded so tests can assert backfill behaviour.
pub struct MockDecodeEngine {
    pub lanes: usize,
    pub vocab: usize,
    pub max_tokens: usize,
    live: Vec<bool>,
    /// Running count of live lanes, and the high-water mark.
    pub max_live_seen: usize,
    pub prefills: usize,
    pub decodes: usize,
    pub releases: usize,
    /// Fused `decode_batch` calls, and the widest one seen — scheduler
    /// tests assert the loop steps lanes in one call, not one-by-one.
    pub batch_calls: usize,
    pub max_batch_lanes: usize,
    /// Token the engine should fail decode on (error-path tests).
    pub poison_token: Option<u32>,
}

impl MockDecodeEngine {
    pub fn new(lanes: usize, vocab: usize) -> MockDecodeEngine {
        MockDecodeEngine {
            lanes,
            vocab,
            max_tokens: usize::MAX,
            live: vec![false; lanes],
            max_live_seen: 0,
            prefills: 0,
            decodes: 0,
            releases: 0,
            batch_calls: 0,
            max_batch_lanes: 0,
            poison_token: None,
        }
    }

    fn successor_logits(&self, token: u32) -> Vec<f32> {
        let mut l = vec![0.0f32; self.vocab];
        l[(token as usize + 1) % self.vocab] = 10.0;
        l
    }
}

impl DecodeEngine for MockDecodeEngine {
    fn max_concurrency(&self) -> usize {
        self.lanes
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn max_tokens(&self) -> usize {
        self.max_tokens
    }

    fn prefill(&mut self, prompt: &[u32]) -> anyhow::Result<(usize, Vec<f32>)> {
        let lane = self
            .live
            .iter()
            .position(|l| !l)
            .ok_or_else(|| anyhow::anyhow!("no free mock lanes"))?;
        self.live[lane] = true;
        self.prefills += 1;
        let live_now = self.live.iter().filter(|&&l| l).count();
        self.max_live_seen = self.max_live_seen.max(live_now);
        Ok((lane, self.successor_logits(*prompt.last().unwrap())))
    }

    fn decode(&mut self, lane: usize, token: u32) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(self.live[lane], "decode on a dead mock lane");
        if self.poison_token == Some(token) {
            anyhow::bail!("poisoned token {token}");
        }
        self.decodes += 1;
        Ok(self.successor_logits(token))
    }

    /// Records the fused-call shape (one call per scheduler step) while
    /// keeping the default's per-lane isolation semantics: a poisoned
    /// lane errors alone, its step-mates still decode.
    fn decode_batch(&mut self, lanes: &[usize], tokens: &[u32]) -> Vec<anyhow::Result<Vec<f32>>> {
        assert_eq!(lanes.len(), tokens.len(), "lanes/tokens length mismatch");
        self.batch_calls += 1;
        self.max_batch_lanes = self.max_batch_lanes.max(lanes.len());
        lanes.iter().zip(tokens).map(|(&l, &t)| self.decode(l, t)).collect()
    }

    fn release(&mut self, lane: usize) {
        if self.live[lane] {
            self.live[lane] = false;
            self.releases += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::tests_support::{random_weights, tiny_cfg};

    #[test]
    fn session_generates_and_recycles_lanes() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 51);
        let scheme = crate::eval::scheme::mx4();
        let mut s =
            DecodeSession::new(cfg.clone(), &w, &scheme, QuantPool::serial(), 2, KvCacheOpts::default())
                .unwrap();
        assert_eq!(s.vocab(), cfg.vocab);
        assert_eq!(s.max_concurrency(), 2);
        let (a, la) = s.prefill(&[1, 2, 3]).unwrap();
        let (b, _) = s.prefill(&[4]).unwrap();
        assert_ne!(a, b);
        assert!(s.prefill(&[5]).is_err(), "over-admitted");
        assert_eq!(la.len(), cfg.vocab);
        let step = s.decode(a, 7).unwrap();
        assert_eq!(step.len(), cfg.vocab);
        assert!(step.iter().all(|x| x.is_finite()));
        s.release(a);
        s.release(a); // idempotent
        let (c, _) = s.prefill(&[6, 7]).unwrap();
        assert_eq!(c, a, "freed lane not reused");
        s.release(b);
        s.release(c);
        assert_eq!(s.cache().live_slot_count(), 0);
    }

    #[test]
    fn session_encoded_kv_mode_reports_and_serves() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 52);
        let mut s = DecodeSession::new(
            cfg,
            &w,
            &Scheme::Bf16,
            QuantPool::serial(),
            1,
            KvCacheOpts { page_tokens: 4, encoded: true, prefix_cache_bytes: None },
        )
        .unwrap();
        assert!(s.kv_mode().starts_with("KV4"), "{}", s.kv_mode());
        let (lane, _) = s.prefill(&[1, 2, 3, 4, 5]).unwrap();
        let out = s.decode(lane, 9).unwrap();
        assert!(out.iter().all(|x| x.is_finite()));
        assert!(s.cache().bits_per_scalar() <= 8.0);
    }

    #[test]
    fn batched_decode_matches_per_lane_decode_bitwise() {
        // Twin sessions over the same weights/scheme: one stepped lane
        // by lane, one through the fused decode_batch. Logits must agree
        // to the bit, and the fused step must resolve each projection
        // GEMM once (not once per lane).
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 54);
        let scheme = crate::eval::scheme::mx4();
        let mk = || {
            DecodeSession::new(cfg.clone(), &w, &scheme, QuantPool::serial(), 3, KvCacheOpts::default())
                .unwrap()
        };
        let mut serial = mk();
        let mut batched = mk();
        let prompts: [&[u32]; 3] = [&[1, 2, 3], &[4], &[5, 6]];
        let mut lanes_s = Vec::new();
        let mut lanes_b = Vec::new();
        for p in prompts {
            lanes_s.push(serial.prefill(p).unwrap().0);
            lanes_b.push(batched.prefill(p).unwrap().0);
        }
        for step in 0..3u32 {
            let tokens: Vec<u32> = (0..3).map(|i| (step * 5 + i + 7) % 40).collect();
            let before = batched.weights.gemm_resolutions();
            let fused = batched.decode_batch(&lanes_b, &tokens);
            assert_eq!(
                batched.weights.gemm_resolutions() - before,
                cfg.n_layers * 4,
                "fused step launched per-lane GEMMs"
            );
            for (i, r) in fused.iter().enumerate() {
                let lone = serial.decode(lanes_s[i], tokens[i]).unwrap();
                let got = r.as_ref().unwrap();
                for (c, (&g, &want)) in got.iter().zip(&lone).enumerate() {
                    assert_eq!(g.to_bits(), want.to_bits(), "step {step} lane {i} col {c}");
                }
            }
        }
        let stats = batched.kv_stats().unwrap();
        assert_eq!(stats.live_slots, 3);
        assert!(stats.pages_in_use > 0 && stats.pages_peak >= stats.pages_in_use);
    }

    #[test]
    fn batched_decode_isolates_bad_lanes() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 55);
        let mut s =
            DecodeSession::new(cfg.clone(), &w, &Scheme::Bf16, QuantPool::serial(), 3, KvCacheOpts::default())
                .unwrap();
        let (a, _) = s.prefill(&[1, 2]).unwrap();
        let (b, _) = s.prefill(&[3]).unwrap();
        s.release(b); // dead lane in the middle of the step
        let out = s.decode_batch(&[a, b, 99], &[5, 6, 7]);
        assert_eq!(out.len(), 3);
        assert!(out[0].is_ok(), "healthy lane dragged down: {:?}", out[0].as_ref().err());
        assert!(out[1].is_err(), "dead lane decoded");
        assert!(out[2].is_err(), "out-of-range lane decoded");
        assert_eq!(out[0].as_ref().unwrap().len(), cfg.vocab);
        // The healthy lane advanced exactly one position.
        assert_eq!(s.cache().seq_len(a), 3);
    }

    #[test]
    fn mock_decode_batch_records_and_isolates() {
        let mut e = MockDecodeEngine::new(3, 16);
        e.poison_token = Some(9);
        let (a, _) = e.prefill(&[1]).unwrap();
        let (b, _) = e.prefill(&[2]).unwrap();
        let out = e.decode_batch(&[a, b], &[3, 9]);
        assert_eq!(e.batch_calls, 1);
        assert_eq!(e.max_batch_lanes, 2);
        assert!(out[0].is_ok() && out[1].is_err(), "poison not isolated");
    }

    #[test]
    fn prefix_cache_reuses_published_pages_across_requests() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 56);
        let kv = KvCacheOpts { page_tokens: 4, encoded: false, prefix_cache_bytes: Some(1 << 20) };
        let mut warm =
            DecodeSession::new(cfg.clone(), &w, &Scheme::Bf16, QuantPool::serial(), 1, kv.clone()).unwrap();
        let mut cold = DecodeSession::new(
            cfg.clone(),
            &w,
            &Scheme::Bf16,
            QuantPool::serial(),
            1,
            KvCacheOpts { prefix_cache_bytes: None, ..kv },
        )
        .unwrap();
        assert!(warm.prefix_mode().starts_with("on"), "{}", warm.prefix_mode());
        assert_eq!(cold.prefix_mode(), "off");

        let shared: Vec<u32> = (0..9).map(|i| (i * 3 + 1) % 40).collect();
        let mk_prompt = |suffix: &[u32]| -> Vec<u32> {
            shared.iter().copied().chain(suffix.iter().copied()).collect()
        };
        // Request A seeds the tree (2 full pages published on release).
        let (a, _) = warm.prefill(&mk_prompt(&[20, 21])).unwrap();
        let tok = warm.decode(a, 22).unwrap();
        assert!(tok.iter().all(|x| x.is_finite()));
        warm.release(a);
        let s = warm.prefix_stats().unwrap();
        assert_eq!(s.published_chunks, 3, "9+2 prompt +1 decode at pt=4: 3 full pages");
        assert_eq!((s.lookups, s.hits), (1, 0), "first request can't hit an empty tree");

        // Request B shares the 9-token prefix: the match covers the two
        // full shared pages plus one CoW token, and the logits are
        // bit-identical to the cold engine.
        let prompt_b = mk_prompt(&[30, 31, 32]);
        let (b, warm_logits) = warm.prefill(&prompt_b).unwrap();
        let s = warm.prefix_stats().unwrap();
        assert_eq!((s.lookups, s.hits), (2, 1), "shared prefix missed");
        assert_eq!(s.saved_tokens, 9, "2 full pages + 1 CoW token should be adopted");
        let (c, cold_logits) = cold.prefill(&prompt_b).unwrap();
        for (col, (&g, &x)) in warm_logits.iter().zip(&cold_logits).enumerate() {
            assert_eq!(g.to_bits(), x.to_bits(), "warm-hit logits diverged at col {col}");
        }
        // Decode after a warm hit stays bit-identical too.
        let wd = warm.decode(b, 33).unwrap();
        let cd = cold.decode(c, 33).unwrap();
        for (col, (&g, &x)) in wd.iter().zip(&cd).enumerate() {
            assert_eq!(g.to_bits(), x.to_bits(), "post-hit decode diverged at col {col}");
        }
        warm.release(b);
        cold.release(c);
        assert_eq!(warm.cache().live_slot_count(), 0);
    }

    #[test]
    fn prefix_cache_eviction_respects_budget() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 57);
        // A zero-byte budget: everything published is evicted as soon as
        // no slot holds it, so every request misses but nothing leaks
        // and nothing double-frees.
        let kv = KvCacheOpts { page_tokens: 4, encoded: false, prefix_cache_bytes: Some(0) };
        let mut s = DecodeSession::new(cfg, &w, &Scheme::Bf16, QuantPool::serial(), 1, kv).unwrap();
        let prompt: Vec<u32> = (0..8).map(|i| i % 40).collect();
        for _ in 0..3 {
            let (lane, _) = s.prefill(&prompt).unwrap();
            s.release(lane);
        }
        let st = s.prefix_stats().unwrap();
        assert_eq!(st.hits, 0, "zero-budget tree retained pages");
        assert_eq!(st.resident_bytes, 0);
        assert!(st.evicted_bytes > 0);
        assert_eq!(s.cache().stats().pages_in_use, 0, "pages leaked past eviction");
    }

    #[test]
    fn failed_prefill_releases_its_lane() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 53);
        let mut s =
            DecodeSession::new(cfg, &w, &Scheme::Bf16, QuantPool::serial(), 1, KvCacheOpts::default())
                .unwrap();
        assert!(s.prefill(&[9999]).is_err(), "out-of-vocab prompt accepted");
        assert_eq!(s.cache().live_slot_count(), 0, "failed prefill leaked its lane");
        assert!(s.prefill(&[1]).is_ok());
    }
}
