//! Stateful decode engines: the lane-oriented counterpart of
//! [`StepExecutor`](super::executor::StepExecutor). A [`DecodeEngine`]
//! owns per-lane sequence state (for the CPU engine, a slot in the paged
//! KV cache), so generating a token is **O(current length)** — prefill
//! once, then one decode call per token — instead of the fixed-shape
//! executor's full-window re-score. Lanes are released the moment a
//! request finishes, which is what the continuous batcher exploits to
//! backfill admitted requests mid-batch.
//!
//! The scheduler's hot call is [`DecodeEngine::decode_batch`]: one
//! **fused** forward advancing every live lane by one token (single
//! activation-quantization pass, each projection GEMM launched once per
//! step), with per-lane results so one bad request fails alone.

use crate::eval::Scheme;
use crate::kvcache::{KvLayout, KvQuantizer, KvStats, KvStore, PagedKvCache, SlotId};
use crate::model::decode::{decode_step, decode_step_batch, prefill, validate_decode_lane, DecodeScratch};
use crate::model::{ModelConfig, Weights};
use crate::quant::pipeline::{QuantPipeline, QuantPool};

/// A stateful incremental decoder with `max_concurrency` independent
/// lanes. `prefill` claims a lane and returns the prompt's last-position
/// logits; `decode` advances one lane by one token and returns the new
/// position's logits; `release` frees the lane for the next request.
pub trait DecodeEngine: Send {
    /// Concurrent lanes (the continuous scheduler's admission bound).
    fn max_concurrency(&self) -> usize;
    fn vocab(&self) -> usize;
    /// Per-lane token capacity (prompt + generated).
    fn max_tokens(&self) -> usize;
    /// Claim a lane, run the prompt, return `(lane, last-position logits)`.
    fn prefill(&mut self, prompt: &[u32]) -> anyhow::Result<(usize, Vec<f32>)>;
    /// Feed `token` to `lane`; returns the next position's logits.
    fn decode(&mut self, lane: usize, token: u32) -> anyhow::Result<Vec<f32>>;
    /// Advance **every** listed lane by one token in one scheduler step,
    /// returning one result per lane (order-aligned with `lanes`) so an
    /// errored lane fails alone. Engines with a fused forward
    /// ([`DecodeSession`]) override this to run a **single batched
    /// step** — one activation-quantization pass, each projection GEMM
    /// once per step instead of once per lane. The default is the
    /// serial per-lane loop (same results, lane by lane).
    fn decode_batch(&mut self, lanes: &[usize], tokens: &[u32]) -> Vec<anyhow::Result<Vec<f32>>> {
        assert_eq!(lanes.len(), tokens.len(), "lanes/tokens length mismatch");
        lanes.iter().zip(tokens).map(|(&l, &t)| self.decode(l, t)).collect()
    }
    /// Free a lane (idempotent).
    fn release(&mut self, lane: usize);
    /// KV-cache occupancy snapshot for the serving metrics (engines
    /// without a paged cache return `None`).
    fn kv_stats(&self) -> Option<KvStats> {
        None
    }
}

/// KV-cache configuration for [`DecodeSession`].
#[derive(Debug, Clone)]
pub struct KvCacheOpts {
    /// Tokens per page.
    pub page_tokens: usize,
    /// Store cached K/V LO-BCQ-encoded (~4.9 bits/scalar at head_dim 64)
    /// instead of f32.
    pub encoded: bool,
}

impl Default for KvCacheOpts {
    fn default() -> Self {
        KvCacheOpts { page_tokens: 16, encoded: false }
    }
}

/// CPU decode engine: quantized weights (encoded-domain when the scheme
/// supports it), on-the-fly activation quantization, and a paged —
/// optionally BCQ-encoded — KV cache shared by all lanes.
pub struct DecodeSession {
    cfg: ModelConfig,
    weights: Weights,
    act: Option<QuantPipeline>,
    cache: PagedKvCache,
    scratch: DecodeScratch,
    encoded_weights: bool,
}

impl DecodeSession {
    /// Build from a model + scheme, mirroring `CpuExecutor::new`'s weight
    /// handling, plus the KV cache. In encoded-KV mode the cache's
    /// codebooks are calibrated once from rows of the first QKV
    /// projection (the proxy-statistics protocol of §4.1 — K/V entries
    /// are projections of the same distribution).
    pub fn new(
        cfg: ModelConfig,
        weights: &Weights,
        scheme: &Scheme,
        pool: QuantPool,
        max_concurrency: usize,
        kv: KvCacheOpts,
    ) -> anyhow::Result<DecodeSession> {
        anyhow::ensure!(max_concurrency >= 1, "need at least one lane");
        let store = if kv.encoded {
            let hd = cfg.head_dim();
            let wqkv = weights.get("l0.attn.wqkv")?;
            let n = (hd * 256).min(wqkv.data.len() / hd * hd);
            anyhow::ensure!(n >= hd, "wqkv too small to calibrate a KV quantizer");
            KvStore::Encoded(KvQuantizer::calibrated(hd, &wqkv.data[..n], 0xCA11)?)
        } else {
            KvStore::F32
        };
        let layout = KvLayout::for_model(&cfg, kv.page_tokens, max_concurrency);
        let cache = PagedKvCache::new(layout, store)?;
        let (qw, encoded_weights) = scheme.serving_weights(&cfg, weights, pool);
        let act = scheme.act_pipeline(pool);
        Ok(DecodeSession { cfg, weights: qw, act, cache, scratch: DecodeScratch::new(), encoded_weights })
    }

    pub fn act_scheme_name(&self) -> String {
        self.act.as_ref().map(|p| p.name()).unwrap_or_else(|| "BF16".into())
    }

    pub fn weight_mode(&self) -> &'static str {
        crate::eval::scheme::weight_mode_name(self.encoded_weights)
    }

    /// "KV16 (f32 pages)" / "KV4 (BCQ-encoded pages, …)".
    pub fn kv_mode(&self) -> String {
        self.cache.store_name()
    }

    pub fn cache(&self) -> &PagedKvCache {
        &self.cache
    }

}

impl DecodeEngine for DecodeSession {
    fn max_concurrency(&self) -> usize {
        self.cache.layout().max_slots
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn max_tokens(&self) -> usize {
        self.cache.layout().max_tokens
    }

    fn prefill(&mut self, prompt: &[u32]) -> anyhow::Result<(usize, Vec<f32>)> {
        let slot: SlotId = self.cache.alloc_slot()?;
        match prefill(&self.cfg, &self.weights, &mut self.cache, slot, prompt, self.act.as_ref()) {
            Ok(logits) => Ok((slot, logits)),
            Err(e) => {
                // A failed prefill must not leak the lane.
                self.cache.free_slot(slot);
                Err(e)
            }
        }
    }

    fn decode(&mut self, lane: usize, token: u32) -> anyhow::Result<Vec<f32>> {
        decode_step(&self.cfg, &self.weights, &mut self.cache, lane, token, self.act.as_ref(), &mut self.scratch)
    }

    /// The serving hot path: one fused forward over every live lane.
    /// Lane-local failures (dead/full lane, bad token, duplicate) are
    /// screened out **per lane** first, so the fused step runs over the
    /// healthy subset and a bad request never poisons its step-mates.
    fn decode_batch(&mut self, lanes: &[usize], tokens: &[u32]) -> Vec<anyhow::Result<Vec<f32>>> {
        assert_eq!(lanes.len(), tokens.len(), "lanes/tokens length mismatch");
        let mut out: Vec<anyhow::Result<Vec<f32>>> = Vec::with_capacity(lanes.len());
        let mut valid: Vec<usize> = Vec::new(); // indices into `lanes`
        // Screen each lane with the SAME check the fused step enforces
        // (one source of truth — `model::decode::validate_decode_lane`),
        // so a lane that would fail the batched call fails alone here.
        for (i, &tok) in tokens.iter().enumerate() {
            match validate_decode_lane(&self.cfg, &self.cache, lanes, i, tok) {
                Ok(_pos) => {
                    valid.push(i);
                    out.push(Ok(Vec::new())); // placeholder, filled below
                }
                Err(e) => out.push(Err(e)),
            }
        }
        if valid.is_empty() {
            return out;
        }
        let slots: Vec<SlotId> = valid.iter().map(|&i| lanes[i]).collect();
        let toks: Vec<u32> = valid.iter().map(|&i| tokens[i]).collect();
        let fused = decode_step_batch(
            &self.cfg,
            &self.weights,
            &mut self.cache,
            &slots,
            &toks,
            self.act.as_ref(),
            &mut self.scratch,
        );
        match fused {
            Ok(logits) => {
                let v = self.cfg.vocab;
                for (j, &i) in valid.iter().enumerate() {
                    out[i] = Ok(logits[j * v..(j + 1) * v].to_vec());
                }
            }
            Err(e) => {
                // Post-screening the fused step can only fail on an
                // engine-level fault; surface it on every participant
                // (screened-out lanes keep their own errors).
                for &i in &valid {
                    out[i] = Err(anyhow::anyhow!("batched decode failed: {e}"));
                }
            }
        }
        out
    }

    fn release(&mut self, lane: usize) {
        self.cache.free_slot(lane);
    }

    fn kv_stats(&self) -> Option<KvStats> {
        Some(self.cache.stats())
    }
}

/// Deterministic mock engine for continuous-scheduler tests: logits
/// prefer `(last_token + 1) % vocab`, lanes are bounded, and every
/// lifecycle event is recorded so tests can assert backfill behaviour.
pub struct MockDecodeEngine {
    pub lanes: usize,
    pub vocab: usize,
    pub max_tokens: usize,
    live: Vec<bool>,
    /// Running count of live lanes, and the high-water mark.
    pub max_live_seen: usize,
    pub prefills: usize,
    pub decodes: usize,
    pub releases: usize,
    /// Fused `decode_batch` calls, and the widest one seen — scheduler
    /// tests assert the loop steps lanes in one call, not one-by-one.
    pub batch_calls: usize,
    pub max_batch_lanes: usize,
    /// Token the engine should fail decode on (error-path tests).
    pub poison_token: Option<u32>,
}

impl MockDecodeEngine {
    pub fn new(lanes: usize, vocab: usize) -> MockDecodeEngine {
        MockDecodeEngine {
            lanes,
            vocab,
            max_tokens: usize::MAX,
            live: vec![false; lanes],
            max_live_seen: 0,
            prefills: 0,
            decodes: 0,
            releases: 0,
            batch_calls: 0,
            max_batch_lanes: 0,
            poison_token: None,
        }
    }

    fn successor_logits(&self, token: u32) -> Vec<f32> {
        let mut l = vec![0.0f32; self.vocab];
        l[(token as usize + 1) % self.vocab] = 10.0;
        l
    }
}

impl DecodeEngine for MockDecodeEngine {
    fn max_concurrency(&self) -> usize {
        self.lanes
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn max_tokens(&self) -> usize {
        self.max_tokens
    }

    fn prefill(&mut self, prompt: &[u32]) -> anyhow::Result<(usize, Vec<f32>)> {
        let lane = self
            .live
            .iter()
            .position(|l| !l)
            .ok_or_else(|| anyhow::anyhow!("no free mock lanes"))?;
        self.live[lane] = true;
        self.prefills += 1;
        let live_now = self.live.iter().filter(|&&l| l).count();
        self.max_live_seen = self.max_live_seen.max(live_now);
        Ok((lane, self.successor_logits(*prompt.last().unwrap())))
    }

    fn decode(&mut self, lane: usize, token: u32) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(self.live[lane], "decode on a dead mock lane");
        if self.poison_token == Some(token) {
            anyhow::bail!("poisoned token {token}");
        }
        self.decodes += 1;
        Ok(self.successor_logits(token))
    }

    /// Records the fused-call shape (one call per scheduler step) while
    /// keeping the default's per-lane isolation semantics: a poisoned
    /// lane errors alone, its step-mates still decode.
    fn decode_batch(&mut self, lanes: &[usize], tokens: &[u32]) -> Vec<anyhow::Result<Vec<f32>>> {
        assert_eq!(lanes.len(), tokens.len(), "lanes/tokens length mismatch");
        self.batch_calls += 1;
        self.max_batch_lanes = self.max_batch_lanes.max(lanes.len());
        lanes.iter().zip(tokens).map(|(&l, &t)| self.decode(l, t)).collect()
    }

    fn release(&mut self, lane: usize) {
        if self.live[lane] {
            self.live[lane] = false;
            self.releases += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::tests_support::{random_weights, tiny_cfg};

    #[test]
    fn session_generates_and_recycles_lanes() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 51);
        let scheme = crate::eval::scheme::mx4();
        let mut s =
            DecodeSession::new(cfg.clone(), &w, &scheme, QuantPool::serial(), 2, KvCacheOpts::default())
                .unwrap();
        assert_eq!(s.vocab(), cfg.vocab);
        assert_eq!(s.max_concurrency(), 2);
        let (a, la) = s.prefill(&[1, 2, 3]).unwrap();
        let (b, _) = s.prefill(&[4]).unwrap();
        assert_ne!(a, b);
        assert!(s.prefill(&[5]).is_err(), "over-admitted");
        assert_eq!(la.len(), cfg.vocab);
        let step = s.decode(a, 7).unwrap();
        assert_eq!(step.len(), cfg.vocab);
        assert!(step.iter().all(|x| x.is_finite()));
        s.release(a);
        s.release(a); // idempotent
        let (c, _) = s.prefill(&[6, 7]).unwrap();
        assert_eq!(c, a, "freed lane not reused");
        s.release(b);
        s.release(c);
        assert_eq!(s.cache().live_slot_count(), 0);
    }

    #[test]
    fn session_encoded_kv_mode_reports_and_serves() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 52);
        let mut s = DecodeSession::new(
            cfg,
            &w,
            &Scheme::Bf16,
            QuantPool::serial(),
            1,
            KvCacheOpts { page_tokens: 4, encoded: true },
        )
        .unwrap();
        assert!(s.kv_mode().starts_with("KV4"), "{}", s.kv_mode());
        let (lane, _) = s.prefill(&[1, 2, 3, 4, 5]).unwrap();
        let out = s.decode(lane, 9).unwrap();
        assert!(out.iter().all(|x| x.is_finite()));
        assert!(s.cache().bits_per_scalar() <= 8.0);
    }

    #[test]
    fn batched_decode_matches_per_lane_decode_bitwise() {
        // Twin sessions over the same weights/scheme: one stepped lane
        // by lane, one through the fused decode_batch. Logits must agree
        // to the bit, and the fused step must resolve each projection
        // GEMM once (not once per lane).
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 54);
        let scheme = crate::eval::scheme::mx4();
        let mk = || {
            DecodeSession::new(cfg.clone(), &w, &scheme, QuantPool::serial(), 3, KvCacheOpts::default())
                .unwrap()
        };
        let mut serial = mk();
        let mut batched = mk();
        let prompts: [&[u32]; 3] = [&[1, 2, 3], &[4], &[5, 6]];
        let mut lanes_s = Vec::new();
        let mut lanes_b = Vec::new();
        for p in prompts {
            lanes_s.push(serial.prefill(p).unwrap().0);
            lanes_b.push(batched.prefill(p).unwrap().0);
        }
        for step in 0..3u32 {
            let tokens: Vec<u32> = (0..3).map(|i| (step * 5 + i + 7) % 40).collect();
            let before = batched.weights.gemm_resolutions();
            let fused = batched.decode_batch(&lanes_b, &tokens);
            assert_eq!(
                batched.weights.gemm_resolutions() - before,
                cfg.n_layers * 4,
                "fused step launched per-lane GEMMs"
            );
            for (i, r) in fused.iter().enumerate() {
                let lone = serial.decode(lanes_s[i], tokens[i]).unwrap();
                let got = r.as_ref().unwrap();
                for (c, (&g, &want)) in got.iter().zip(&lone).enumerate() {
                    assert_eq!(g.to_bits(), want.to_bits(), "step {step} lane {i} col {c}");
                }
            }
        }
        let stats = batched.kv_stats().unwrap();
        assert_eq!(stats.live_slots, 3);
        assert!(stats.pages_in_use > 0 && stats.pages_peak >= stats.pages_in_use);
    }

    #[test]
    fn batched_decode_isolates_bad_lanes() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 55);
        let mut s =
            DecodeSession::new(cfg.clone(), &w, &Scheme::Bf16, QuantPool::serial(), 3, KvCacheOpts::default())
                .unwrap();
        let (a, _) = s.prefill(&[1, 2]).unwrap();
        let (b, _) = s.prefill(&[3]).unwrap();
        s.release(b); // dead lane in the middle of the step
        let out = s.decode_batch(&[a, b, 99], &[5, 6, 7]);
        assert_eq!(out.len(), 3);
        assert!(out[0].is_ok(), "healthy lane dragged down: {:?}", out[0].as_ref().err());
        assert!(out[1].is_err(), "dead lane decoded");
        assert!(out[2].is_err(), "out-of-range lane decoded");
        assert_eq!(out[0].as_ref().unwrap().len(), cfg.vocab);
        // The healthy lane advanced exactly one position.
        assert_eq!(s.cache().seq_len(a), 3);
    }

    #[test]
    fn mock_decode_batch_records_and_isolates() {
        let mut e = MockDecodeEngine::new(3, 16);
        e.poison_token = Some(9);
        let (a, _) = e.prefill(&[1]).unwrap();
        let (b, _) = e.prefill(&[2]).unwrap();
        let out = e.decode_batch(&[a, b], &[3, 9]);
        assert_eq!(e.batch_calls, 1);
        assert_eq!(e.max_batch_lanes, 2);
        assert!(out[0].is_ok() && out[1].is_err(), "poison not isolated");
    }

    #[test]
    fn failed_prefill_releases_its_lane() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 53);
        let mut s =
            DecodeSession::new(cfg, &w, &Scheme::Bf16, QuantPool::serial(), 1, KvCacheOpts::default())
                .unwrap();
        assert!(s.prefill(&[9999]).is_err(), "out-of-vocab prompt accepted");
        assert_eq!(s.cache().live_slot_count(), 0, "failed prefill leaked its lane");
        assert!(s.prefill(&[1]).is_ok());
    }
}
