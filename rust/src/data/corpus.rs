//! Deterministic synthetic corpus — token-exact mirror of
//! `python/compile/corpus.py` (see that file for the token layout and the
//! substitution rationale in DESIGN.md §1).
//!
//! Token-exactness across the two languages is enforced by
//! `tests/test_parity.py` (fingerprints + head tokens) and by the
//! manifest's `val_fingerprint`, which the evaluator checks before
//! computing perplexity.

use crate::util::rng::Pcg32;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const DET0: u32 = 2;
pub const N_DET: u32 = 4;
pub const ADJ0: u32 = 6;
pub const N_ADJ: u32 = 32;
pub const NOUN0: u32 = 38;
pub const N_NOUN: u32 = 64;
pub const VERB0: u32 = 102;
pub const N_VERB: u32 = 48;
pub const ADV0: u32 = 150;
pub const N_ADV: u32 = 16;
pub const COMMA: u32 = 166;
pub const PERIOD: u32 = 167;
pub const VOCAB: u32 = 168;

/// The RNG stream id the corpus generator uses (matches python 0xDA7A).
const CORPUS_STREAM: u64 = 0xDA7A;

/// Zipf-ish skewed index in [0, n): floor(n * u^2).
fn zipf(rng: &mut Pcg32, n: u32) -> u32 {
    let u = rng.next_f32();
    ((n as f32 * u * u) as u32).min(n - 1)
}

fn noun_phrase(rng: &mut Pcg32, out: &mut Vec<u32>) {
    let det = zipf(rng, N_DET);
    out.push(DET0 + det);
    if rng.next_f32() < 0.5 {
        let band = det * 8;
        out.push(ADJ0 + band + zipf(rng, 8));
    }
    out.push(NOUN0 + zipf(rng, N_NOUN));
}

fn verb_phrase(rng: &mut Pcg32, out: &mut Vec<u32>) {
    let verb = zipf(rng, N_VERB);
    out.push(VERB0 + verb);
    let u = rng.next_f32();
    if u < 0.6 {
        noun_phrase(rng, out);
    } else if u < 0.85 {
        out.push(ADV0 + (verb % 4) * 4 + zipf(rng, 4));
    }
}

fn sentence(rng: &mut Pcg32, out: &mut Vec<u32>) {
    noun_phrase(rng, out);
    verb_phrase(rng, out);
    if rng.next_f32() < 0.2 {
        out.push(COMMA);
        verb_phrase(rng, out);
    }
    out.push(PERIOD);
}

/// Generate exactly `n_tokens` tokens (BOS + sentences, truncated).
pub fn generate(seed: u64, n_tokens: usize) -> Vec<u32> {
    let mut rng = Pcg32::new(seed, CORPUS_STREAM);
    let mut out = vec![BOS];
    while out.len() < n_tokens {
        sentence(&mut rng, &mut out);
    }
    out.truncate(n_tokens);
    out
}

/// FNV-1a over token ids — matches `corpus.fingerprint` in python.
pub fn fingerprint(tokens: &[u32]) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for &t in tokens {
        h ^= t as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    h
}

/// A deterministic serving workload with shared system prompts: `k`
/// distinct prefixes and `n` requests, each a sampled prefix plus a
/// request-unique suffix — the traffic shape the prefix cache exists
/// for (`benches/perf_prefix.rs`, `tests/prefix_parity.rs`, and the
/// `serve-cpu` synthetic swarm all draw from here, so they measure the
/// same distribution).
pub struct SharedPrefixWorkload {
    /// The `k` system prompts, each `prefix_len` tokens.
    pub prefixes: Vec<Vec<u32>>,
    /// Per request: (index into `prefixes`, full prompt of
    /// `prefix_len + suffix_len` tokens).
    pub requests: Vec<(usize, Vec<u32>)>,
}

/// Build a [`SharedPrefixWorkload`]: prefixes and suffixes come from
/// the grammar generator on seed-derived streams, and each request
/// samples its prefix with the seeded RNG — fully deterministic in
/// `(seed, k, n, prefix_len, suffix_len)`.
pub fn shared_prefix_workload(
    seed: u64,
    k: usize,
    n: usize,
    prefix_len: usize,
    suffix_len: usize,
) -> SharedPrefixWorkload {
    assert!(k >= 1 && prefix_len >= 1 && suffix_len >= 1);
    let prefixes: Vec<Vec<u32>> =
        (0..k).map(|j| generate(seed ^ (0x5151 + j as u64), prefix_len)).collect();
    let mut rng = Pcg32::new(seed, 0x5AFE);
    let requests = (0..n)
        .map(|i| {
            let j = (rng.next_u32() as usize) % k;
            let mut prompt = prefixes[j].clone();
            // Suffixes start past the generator's BOS so they diverge
            // from token one.
            let suffix = generate(seed ^ 0xD1FF ^ ((i as u64) << 8), suffix_len + 1);
            prompt.extend_from_slice(&suffix[1..]);
            (j, prompt)
        })
        .collect();
    SharedPrefixWorkload { prefixes, requests }
}

/// A request-unique prompt: `len` grammar tokens (BOS first) on a
/// stream derived from `(seed, i)` — the same derivation
/// [`shared_prefix_workload`] uses for its suffixes, exposed so the
/// workload factory (`bench::factory`) draws unique prompts from the
/// same distribution the swarm suffixes come from.
pub fn unique_prompt(seed: u64, i: usize, len: usize) -> Vec<u32> {
    assert!(len >= 1, "unique_prompt needs len >= 1");
    generate(seed ^ 0xD1FF ^ ((i as u64) << 8), len)
}

/// A pathologically repetitive stream for the speculative-decoding
/// benches: one grammar-generated `period`-token phrase tiled out to
/// `n_tokens` (BOS first, like [`generate`]). After one period every
/// token's successor is fixed, so an n-gram drafter converges to full
/// acceptance — the workload shape speculation is supposed to win on.
pub fn repetitive(seed: u64, period: usize, n_tokens: usize) -> Vec<u32> {
    assert!(period >= 1, "repetitive stream needs a positive period");
    let phrase = generate(seed, period + 1);
    let mut out = vec![BOS];
    while out.len() < n_tokens {
        out.extend_from_slice(&phrase[1..]);
    }
    out.truncate(n_tokens);
    out
}

/// Split a token stream into (N, t+1) next-token windows (stride = t).
pub fn windows(tokens: &[u32], t: usize) -> Vec<Vec<u32>> {
    let mut out = Vec::new();
    let mut i = 0;
    while i + t + 1 <= tokens.len() {
        out.push(tokens[i..i + t + 1].to_vec());
        i += t;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(generate(123, 1000), generate(123, 1000));
        assert_ne!(generate(124, 1000), generate(123, 1000));
    }

    #[test]
    fn tokens_in_vocab_and_bos_first() {
        let toks = generate(5, 5000);
        assert_eq!(toks.len(), 5000);
        assert_eq!(toks[0], BOS);
        assert!(toks.iter().all(|&t| t < VOCAB));
    }

    #[test]
    fn grammar_structure_det_then_adj_or_noun() {
        let toks = generate(9, 20000);
        for w in toks.windows(2) {
            if (DET0..DET0 + N_DET).contains(&w[0]) {
                let nxt = w[1];
                assert!(
                    (ADJ0..ADJ0 + N_ADJ).contains(&nxt) || (NOUN0..NOUN0 + N_NOUN).contains(&nxt),
                    "det followed by {nxt}"
                );
            }
        }
    }

    #[test]
    fn zipf_skew() {
        let toks = generate(11, 50000);
        let mut counts = [0usize; N_NOUN as usize];
        for &t in &toks {
            if (NOUN0..NOUN0 + N_NOUN).contains(&t) {
                counts[(t - NOUN0) as usize] += 1;
            }
        }
        let head: usize = counts[..8].iter().sum();
        let tail: usize = counts[N_NOUN as usize - 8..].iter().sum();
        assert!(head > 3 * tail, "head {head} tail {tail}");
    }

    #[test]
    fn fingerprint_stability() {
        let fp = fingerprint(&generate(5678, 10_000));
        assert_eq!(fp, fingerprint(&generate(5678, 10_000)));
        assert_ne!(fp, fingerprint(&generate(5678, 9_999)));
    }

    #[test]
    fn shared_prefix_workload_is_deterministic_and_shares_exactly() {
        let a = shared_prefix_workload(42, 3, 16, 12, 5);
        let b = shared_prefix_workload(42, 3, 16, 12, 5);
        assert_eq!(a.prefixes, b.prefixes);
        assert_eq!(a.requests, b.requests);
        assert_ne!(shared_prefix_workload(43, 3, 16, 12, 5).requests, a.requests);
        assert_eq!(a.prefixes.len(), 3);
        assert_eq!(a.requests.len(), 16);
        let mut used = [false; 3];
        for (j, prompt) in &a.requests {
            assert_eq!(prompt.len(), 17);
            assert!(prompt.iter().all(|&t| t < VOCAB));
            assert_eq!(&prompt[..12], &a.prefixes[*j][..], "request lost its system prompt");
            used[*j] = true;
        }
        // 16 draws over 3 prefixes must spread (a constant sampler
        // would collapse onto one).
        assert!(used.iter().filter(|&&u| u).count() >= 2, "sampler never varied its prefix");
        // Same-prefix requests differ (unique suffixes).
        let same: Vec<&Vec<u32>> =
            a.requests.iter().filter(|(j, _)| *j == 0).map(|(_, p)| p).collect();
        if same.len() >= 2 {
            assert_ne!(same[0], same[1], "suffixes not unique");
        }
    }

    #[test]
    fn unique_prompts_are_unique_and_deterministic() {
        let a = unique_prompt(42, 0, 24);
        assert_eq!(a, unique_prompt(42, 0, 24));
        assert_eq!(a.len(), 24);
        assert_eq!(a[0], BOS);
        assert!(a.iter().all(|&t| t < VOCAB));
        assert_ne!(a, unique_prompt(42, 1, 24));
        assert_ne!(a, unique_prompt(43, 0, 24));
    }

    #[test]
    fn repetitive_stream_tiles_one_phrase() {
        let period = 12;
        let toks = repetitive(77, period, 100);
        assert_eq!(toks.len(), 100);
        assert_eq!(toks[0], BOS);
        assert!(toks.iter().all(|&t| t < VOCAB));
        assert_eq!(toks, repetitive(77, period, 100), "not deterministic");
        // Past the leading BOS the stream is exactly periodic.
        for i in 1..100 - period {
            assert_eq!(toks[i], toks[i + period], "aperiodic at {i}");
        }
        assert_ne!(repetitive(78, period, 100), toks);
    }

    #[test]
    fn windows_cover_stream() {
        let toks = generate(1, 1000);
        let w = windows(&toks, 64);
        assert!(!w.is_empty());
        assert!(w.iter().all(|x| x.len() == 65));
        assert_eq!(w[0][0], toks[0]);
        assert_eq!(w[1][0], toks[64]);
    }
}
