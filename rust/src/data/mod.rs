//! Workload data: the synthetic corpus (Wikitext stand-in) and the
//! synthetic downstream tasks (LM-harness / MMLU stand-ins). Both are
//! deterministic mirrors of the python generators — see DESIGN.md §1.

pub mod corpus;
pub mod tasks;
