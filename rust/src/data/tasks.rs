//! Synthetic downstream tasks — the LM-evaluation-harness / MMLU analog
//! (DESIGN.md §1 substitutions; paper §4.2.2–4.2.3).
//!
//! Each task instance is a cloze question: a grammatical prefix, one
//! correct continuation token, and `n_choices - 1` distractors of the
//! same syntactic category. The evaluator scores each choice by the LM's
//! log-probability (the answer-ranking protocol of the real harnesses)
//! and reports accuracy. Five task flavors differ in which category is
//! predicted and how much context is given — mirroring the spread of
//! RA/BQ/HS/PQ/WG difficulty.

use super::corpus;
use crate::util::rng::Pcg32;

/// A single cloze item: score `prefix + choice` for each choice; the
/// model is correct when the true choice has the highest log-prob.
#[derive(Debug, Clone)]
pub struct ClozeItem {
    pub prefix: Vec<u32>,
    pub choices: Vec<u32>,
    pub answer: usize,
}

/// Task flavors (analogy to the paper's five LM-harness tasks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Predict the noun after det+adj ("RA" analog: 4 choices).
    NounAfterAdj,
    /// Predict adj band membership given det ("BQ" analog: 2 choices).
    AdjBand,
    /// Predict the adverb band for a verb ("HS" analog: 4 choices).
    AdverbForVerb,
    /// Predict the continuation category after a noun ("PQ": 2 choices).
    VerbVsPeriod,
    /// Long-context noun repetition ("WG" analog: 2 choices).
    NounRecall,
}

pub const ALL_TASKS: [TaskKind; 5] = [
    TaskKind::NounAfterAdj,
    TaskKind::AdjBand,
    TaskKind::AdverbForVerb,
    TaskKind::VerbVsPeriod,
    TaskKind::NounRecall,
];

impl TaskKind {
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::NounAfterAdj => "RA*",
            TaskKind::AdjBand => "BQ*",
            TaskKind::AdverbForVerb => "HS*",
            TaskKind::VerbVsPeriod => "PQ*",
            TaskKind::NounRecall => "WG*",
        }
    }
}

/// Build `n` cloze items for a task. Prefixes are drawn from freshly
/// generated corpus text so they match the training distribution; the
/// distractors are category-consistent, so only a model that learned the
/// conditional statistics beats chance.
pub fn build_items(kind: TaskKind, n: usize, seed: u64, max_prefix: usize) -> Vec<ClozeItem> {
    let mut rng = Pcg32::new(seed, 0x7A5C);
    let mut items = Vec::with_capacity(n);
    let mut guard = 0usize;
    while items.len() < n && guard < n * 200 {
        guard += 1;
        // A fresh snippet of corpus text to serve as context.
        let snippet = corpus::generate(rng.next_u64(), max_prefix.max(16));
        if let Some(item) = make_item(kind, &snippet, max_prefix, &mut rng) {
            items.push(item);
        }
    }
    items
}

fn category(t: u32) -> Option<&'static str> {
    use corpus::*;
    if (DET0..DET0 + N_DET).contains(&t) {
        Some("det")
    } else if (ADJ0..ADJ0 + N_ADJ).contains(&t) {
        Some("adj")
    } else if (NOUN0..NOUN0 + N_NOUN).contains(&t) {
        Some("noun")
    } else if (VERB0..VERB0 + N_VERB).contains(&t) {
        Some("verb")
    } else if (ADV0..ADV0 + N_ADV).contains(&t) {
        Some("adv")
    } else {
        None
    }
}

fn distractors(answer: u32, base: u32, n_cat: u32, k: usize, rng: &mut Pcg32) -> Vec<u32> {
    let mut out = Vec::with_capacity(k);
    while out.len() < k {
        let cand = base + rng.below(n_cat);
        if cand != answer && !out.contains(&cand) {
            out.push(cand);
        }
    }
    out
}

fn make_item(kind: TaskKind, snippet: &[u32], max_prefix: usize, rng: &mut Pcg32) -> Option<ClozeItem> {
    use corpus::*;
    // Find a position whose token matches the task's target category and
    // whose prefix is non-trivial.
    for (i, &t) in snippet.iter().enumerate().skip(4) {
        if i >= max_prefix {
            break;
        }
        let prefix = snippet[..i].to_vec();
        let (answer_tok, mut wrong) = match kind {
            TaskKind::NounAfterAdj => {
                if category(t) != Some("noun") || category(snippet[i - 1]) != Some("adj") {
                    continue;
                }
                (t, distractors(t, NOUN0, N_NOUN, 3, rng))
            }
            TaskKind::AdjBand => {
                if category(t) != Some("adj") || category(snippet[i - 1]) != Some("det") {
                    continue;
                }
                // Distractor: adjective from a *different* det band.
                let det = snippet[i - 1] - DET0;
                let other_band = (det + 1 + rng.below(N_DET - 1)) % N_DET;
                (t, vec![ADJ0 + other_band * 8 + rng.below(8)])
            }
            TaskKind::AdverbForVerb => {
                if category(t) != Some("adv") {
                    continue;
                }
                (t, distractors(t, ADV0, N_ADV, 3, rng))
            }
            TaskKind::VerbVsPeriod => {
                if category(t) != Some("verb") || category(snippet[i - 1]) != Some("noun") {
                    continue;
                }
                // Wrong continuation: another determiner (ungrammatical here).
                (t, vec![DET0 + rng.below(N_DET)])
            }
            TaskKind::NounRecall => {
                if category(t) != Some("noun") || i < 8 {
                    continue;
                }
                // Distractor noun that did NOT appear in the prefix.
                let mut cand;
                let mut tries = 0;
                loop {
                    cand = NOUN0 + rng.below(N_NOUN);
                    if cand != t && !prefix.contains(&cand) {
                        break;
                    }
                    tries += 1;
                    if tries > 64 {
                        return None;
                    }
                }
                (t, vec![cand])
            }
        };
        // Shuffle answer among choices deterministically.
        let answer_pos = rng.index(wrong.len() + 1);
        let mut choices = Vec::with_capacity(wrong.len() + 1);
        for (j, w) in wrong.drain(..).enumerate() {
            let _ = j;
            choices.push(w);
        }
        choices.insert(answer_pos, answer_tok);
        return Some(ClozeItem { prefix, choices, answer: answer_pos });
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn items_well_formed() {
        for kind in ALL_TASKS {
            let items = build_items(kind, 50, 42, 48);
            assert!(items.len() >= 40, "{:?}: only {} items", kind, items.len());
            for it in &items {
                assert!(!it.prefix.is_empty());
                assert!(it.prefix.len() < 48);
                assert!(it.choices.len() >= 2);
                assert!(it.answer < it.choices.len());
                // Distractors distinct from the answer.
                let ans = it.choices[it.answer];
                assert_eq!(it.choices.iter().filter(|&&c| c == ans).count(), 1);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = build_items(TaskKind::NounAfterAdj, 10, 7, 48);
        let b = build_items(TaskKind::NounAfterAdj, 10, 7, 48);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prefix, y.prefix);
            assert_eq!(x.choices, y.choices);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn answer_position_varies() {
        let items = build_items(TaskKind::NounAfterAdj, 100, 3, 48);
        let first = items[0].answer;
        assert!(items.iter().any(|i| i.answer != first), "answer position constant");
    }

    #[test]
    fn noun_recall_distractor_not_in_prefix() {
        for it in build_items(TaskKind::NounRecall, 30, 9, 48) {
            for (i, &c) in it.choices.iter().enumerate() {
                if i != it.answer {
                    assert!(!it.prefix.contains(&c));
                }
            }
        }
    }
}
