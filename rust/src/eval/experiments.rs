//! Experiment drivers: one function per paper table/figure, each
//! producing a markdown report with the same rows/series the paper
//! reports (workloads scaled to the tiny-GPT testbed — DESIGN.md §3
//! documents the expected *shape*, EXPERIMENTS.md records measurements).
//!
//! Invoked from `lobcq bench --exp <id>` and from `cargo bench`.

use crate::data::corpus;
use crate::eval::perplexity::{ppl_cpu, EvalOpts};
use crate::eval::scheme::{is_gemm_weight, mx4, mxfp4, vsq, Scheme};
use crate::eval::setup::Env;
use crate::eval::tasks_eval::{harness_suite, mmlu_accuracy};
use crate::formats::{E1M2, E2M1, E3M0, E3M2, E3M3, E4M0};
use crate::model::{forward, Weights};
use crate::quant::calib::{CalibScope, LobcqQuantizer};
use crate::quant::lobcq::{calibrate_blocks, normalize, normalized_blocks, CalibOpts, InitMethod, LobcqConfig};
use crate::quant::metrics::{bitwidth_table1, compression_factor};
use crate::quant::pipeline::{QuantPipeline, QuantScheme};
use crate::util::rng::Pcg32;
use crate::util::stats::nmse;
use std::fmt::Write as _;
use std::sync::Arc;

pub const ALL_EXPERIMENTS: &[&str] = &[
    "tab1", "tab2", "tab3", "tab4", "tab5", "tab6", "tab7", "tab8", "tab9", "tab10", "tab11",
    "fig1", "fig4", "fig6", "fig7", "fig8", "fig9",
];

/// Run one experiment by id.
pub fn run(id: &str, env: &Env, quick: bool) -> anyhow::Result<String> {
    match id {
        "tab1" => tab1(),
        "tab2" => tab2(env, quick),
        "tab3" => tab3(env, quick),
        "tab4" => tab4(env, quick),
        "tab5" => tab5(env, quick),
        "tab6" => tab6(env, quick),
        "tab7" => tab7(env, quick),
        "tab8" => tab8(env, quick),
        "tab9" => tab9(env, quick),
        "tab10" => tab10(env, quick),
        "tab11" | "fig8" => tab11_fig8(env, quick),
        "fig1" => fig1(env, quick),
        "fig4" => fig4(env),
        "fig6" => fig6(env),
        "fig7" => fig7(env),
        "fig9" => fig9(env),
        other => anyhow::bail!("unknown experiment '{other}' (known: {ALL_EXPERIMENTS:?})"),
    }
}

/// Entry point shared by the `benches/` targets (`cargo bench` runs each
/// experiment in quick mode; set `LOBCQ_BENCH_FULL=1` for paper-scale
/// workloads). Prints the report and exits non-zero on failure so bench
/// runs surface regressions.
pub fn bench_entry(id: &str) {
    let quick = std::env::var("LOBCQ_BENCH_FULL").map(|v| v != "1").unwrap_or(true);
    let env = Env::load();
    let t0 = std::time::Instant::now();
    match run(id, &env, quick) {
        Ok(report) => {
            println!("{report}");
            println!("[{id}] completed in {:.2}s (quick={quick})", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            crate::log_error!("[{id}] FAILED: {e:#}");
            std::process::exit(1);
        }
    }
}

fn opts(quick: bool) -> EvalOpts {
    EvalOpts { n_windows: if quick { 8 } else { 32 }, ..EvalOpts::default() }
}

/// Load a model and apply the function-preserving outlier injection
/// (`eval::outliers`): tiny transformers lack the LLM outlier channels
/// the paper's evaluation stresses, so every experiment runs on the
/// injected model — its BF16 function (and PPL) is unchanged.
fn need_weights(env: &Env, size: &str) -> anyhow::Result<(crate::model::ModelConfig, Weights)> {
    let cfg = env.model_config(size)?;
    let w = env.weights(size)?;
    let wi = crate::eval::outliers::inject_outliers(&cfg, &w, crate::eval::outliers::OutlierSpec::default());
    Ok((cfg, wi))
}

/// ---- Table 1: configuration bitwidths (exact analytic grid) ----
pub fn tab1() -> anyhow::Result<String> {
    let mut s = String::from("# Table 1 — LO-BCQ bitwidths (eq. 9, exact)\n\n");
    writeln!(s, "| L_A \\ (L_b, N_c) | (8,2) | (8,4) | (8,8) | (8,16) | (4,2) | (4,4) | (2,2) |")?;
    writeln!(s, "|---|---|---|---|---|---|---|---|")?;
    for la in [128usize, 64, 32, 16] {
        let cells: Vec<String> = [(8usize, 2usize), (8, 4), (8, 8), (8, 16), (4, 2), (4, 4), (2, 2)]
            .iter()
            .map(|&(lb, nc)| format!("{:.4}", bitwidth_table1(nc, lb, la)))
            .collect();
        writeln!(s, "| {la} | {} |", cells.join(" | "))?;
    }
    Ok(s)
}

/// The W4A4 scheme set used by Tables 2/6/7 and Fig. 1.
fn w4a4_schemes(env: &Env) -> anyhow::Result<Vec<Scheme>> {
    Ok(vec![
        env.lobcq(8, 2, 64)?,
        env.lobcq(8, 8, 64)?,
        env.lobcq(8, 16, 32)?,
        mx4(),
        vsq(),
        mxfp4(),
    ])
}

/// ---- Table 2: W4A4 perplexity across model sizes ----
pub fn tab2(env: &Env, quick: bool) -> anyhow::Result<String> {
    let mut s = String::from(
        "# Table 2 — W4A4 perplexity (CPU reference forward; weights+activations quantized)\n\n\
         | Model | BF16 | MX4 (4.5b) | VSQ (4.5b) | MXFP4 (4.25b) | LO-BCQ g64 Nc2 (4.25b) | LO-BCQ g64 Nc8 (4.5b) | LO-BCQ g32 Nc16 (4.75b) |\n\
         |---|---|---|---|---|---|---|---|\n",
    );
    let sizes: &[&str] = if quick { &["s"] } else { &["s", "m", "l"] };
    for size in sizes {
        let (cfg, w) = need_weights(env, size)?;
        let base = ppl_cpu(&cfg, &w, &Scheme::Bf16, &Scheme::Bf16, &opts(quick))?;
        let mut row = format!("| {size} ({}p) | {base:.3} ", cfg.param_count());
        for scheme in [mx4(), vsq(), mxfp4(), env.lobcq(8, 2, 64)?, env.lobcq(8, 8, 64)?, env.lobcq(8, 16, 32)?] {
            let ppl = ppl_cpu(&cfg, &w, &scheme, &scheme, &opts(quick))?;
            write!(row, "| {ppl:.3} (+{:.3}) ", ppl - base)?;
        }
        writeln!(s, "{row}|")?;
    }
    s.push_str("\nPaper shape: LO-BCQ Δ ≪ MX4/VSQ/MXFP4 Δ at equal bitwidth; Δ shrinks as Nc grows.\n");
    Ok(s)
}

/// ---- Table 3: g128 W4A4 ΔPPL, Nc sweep ----
pub fn tab3(env: &Env, quick: bool) -> anyhow::Result<String> {
    let (cfg, w) = need_weights(env, "m")?;
    let base = ppl_cpu(&cfg, &w, &Scheme::Bf16, &Scheme::Bf16, &opts(quick))?;
    let mut s = String::from(
        "# Table 3 — W4A4 ΔPPL at group size 128 (paper: SmoothQuant 77.65, OmniQuant 9.14, QuaRot 0.46, Atom 0.56 on Llama2-7B)\n\n\
         | Method | bitwidth | ΔPPL (m) |\n|---|---|---|\n",
    );
    for nc in [2usize, 4, 8, 16] {
        let scheme = env.lobcq(8, nc, 128)?;
        let ppl = ppl_cpu(&cfg, &w, &scheme, &scheme, &opts(quick))?;
        writeln!(s, "| LO-BCQ (Nc={nc}) | {:.2} | {:+.3} |", scheme.bits(), ppl - base)?;
    }
    writeln!(s, "\nBF16 baseline PPL: {base:.3}. Expected shape: ΔPPL decreases with Nc.")?;
    Ok(s)
}

/// ---- Table 4: weight-only (W4A16) g128 + task accuracies ----
pub fn tab4(env: &Env, quick: bool) -> anyhow::Result<String> {
    let (cfg, w) = need_weights(env, "m")?;
    let base = ppl_cpu(&cfg, &w, &Scheme::Bf16, &Scheme::Bf16, &opts(quick))?;
    let items = if quick { 30 } else { 80 };
    let mut s = String::from(
        "# Table 4 — weight-only (W4A16) LO-BCQ g128 (paper compares GPTQ/AWQ/QuiP#/AQLM)\n\n\
         | Nc | bitwidth | ΔPPL | PQ* | WG* | HS* |\n|---|---|---|---|---|---|\n",
    );
    for nc in [2usize, 4, 8, 16] {
        let scheme = env.lobcq(8, nc, 128)?;
        let ppl = ppl_cpu(&cfg, &w, &scheme, &Scheme::Bf16, &opts(quick))?;
        let (rows, _) = harness_suite(&cfg, &w, &scheme, &Scheme::Bf16, items, 17)?;
        let get = |n: &str| rows.iter().find(|(name, _)| name == n).map(|(_, a)| a * 100.0).unwrap();
        writeln!(
            s,
            "| {nc} | {:.2} | {:+.3} | {:.1} | {:.1} | {:.1} |",
            scheme.bits(),
            ppl - base,
            get("PQ*"),
            get("WG*"),
            get("HS*")
        )?;
    }
    writeln!(s, "\nBF16 baseline PPL {base:.3}. Shape: small ΔPPL, shrinking with Nc; accuracies ≈ baseline.")?;
    Ok(s)
}

/// ---- Table 5: sub-4-bit weight-only ----
pub fn tab5(env: &Env, quick: bool) -> anyhow::Result<String> {
    let (cfg, w) = need_weights(env, "m")?;
    let base = ppl_cpu(&cfg, &w, &Scheme::Bf16, &Scheme::Bf16, &opts(quick))?;
    let mut s = String::from(
        "# Table 5 — sub-4-bit weight-only LO-BCQ (paper compares QuIP#/AQLM)\n\n\
         | B | Nc | bitwidth | PPL (Δ) |\n|---|---|---|---|\n",
    );
    writeln!(s, "| 16 (BF16) | - | 16 | {base:.3} |")?;
    for (b, nc) in [(3u32, 4usize), (3, 8), (2, 4), (2, 8)] {
        let scheme = env.lobcq_bits(8, nc, 64, b, 6)?;
        let ppl = ppl_cpu(&cfg, &w, &scheme, &Scheme::Bf16, &opts(quick))?;
        writeln!(s, "| {b} | {nc} | {:.3} | {ppl:.3} ({:+.3}) |", scheme.bits(), ppl - base)?;
    }
    s.push_str("\nShape: W3 degrades mildly, W2 clearly more; Nc=8 beats Nc=4 at both widths.\n");
    Ok(s)
}

/// ---- Table 6: LM-harness analog, 0-shot accuracy ----
pub fn tab6(env: &Env, quick: bool) -> anyhow::Result<String> {
    let items = if quick { 30 } else { 100 };
    let sizes: &[&str] = if quick { &["s"] } else { &["s", "m"] };
    let mut s = String::from(
        "# Table 6 — downstream task accuracy (5 synthetic cloze tasks, answer-ranking)\n\n\
         | Model | Method | RA* | BQ* | HS* | PQ* | WG* | Avg (Δ%) |\n|---|---|---|---|---|---|---|---|\n",
    );
    for size in sizes {
        let (cfg, w) = need_weights(env, size)?;
        let (_, base_avg) = harness_suite(&cfg, &w, &Scheme::Bf16, &Scheme::Bf16, items, 23)?;
        let mut all: Vec<(String, Scheme)> = vec![("BF16".into(), Scheme::Bf16)];
        for sc in w4a4_schemes(env)? {
            all.push((sc.name(), sc));
        }
        for (name, scheme) in all {
            let (rows, avg) = harness_suite(&cfg, &w, &scheme, &scheme, items, 23)?;
            let cells: Vec<String> = rows.iter().map(|(_, a)| format!("{:.1}", a * 100.0)).collect();
            writeln!(
                s,
                "| {size} | {name} | {} | {:.1} ({:+.2}) |",
                cells.join(" | "),
                avg * 100.0,
                (base_avg - avg) * 100.0
            )?;
        }
    }
    s.push_str("\nShape: LO-BCQ Δ% < 1 and below MX4/VSQ/MXFP4 at equal bitwidth.\n");
    Ok(s)
}

/// ---- Table 7: MMLU analog (long-context multi-choice) ----
pub fn tab7(env: &Env, quick: bool) -> anyhow::Result<String> {
    let n = if quick { 40 } else { 150 };
    let sizes: &[&str] = if quick { &["s"] } else { &["s", "m", "l"] };
    let mut s = String::from(
        "# Table 7 — MMLU-analog accuracy (long-context noun recall)\n\n| Method |",
    );
    for size in sizes {
        write!(s, " {size} |")?;
    }
    s.push('\n');
    writeln!(s, "|---|{}", "---|".repeat(sizes.len()))?;
    let mut all: Vec<(String, Scheme)> = vec![("BF16".into(), Scheme::Bf16)];
    for sc in w4a4_schemes(env)? {
        all.push((sc.name(), sc));
    }
    for (name, scheme) in all {
        write!(s, "| {name} |")?;
        for size in sizes {
            let (cfg, w) = need_weights(env, size)?;
            let acc = mmlu_accuracy(&cfg, &w, &scheme, &scheme, n, 29)?;
            write!(s, " {:.1} |", acc * 100.0)?;
        }
        s.push('\n');
    }
    Ok(s)
}

/// ---- Table 8: (L_b, N_c, L_A) ablation grid ----
pub fn tab8(env: &Env, quick: bool) -> anyhow::Result<String> {
    let size = "m";
    let (cfg, w) = need_weights(env, size)?;
    let base = ppl_cpu(&cfg, &w, &Scheme::Bf16, &Scheme::Bf16, &opts(quick))?;
    let grid: Vec<(usize, usize)> = if quick {
        vec![(8, 2), (8, 16), (4, 2)]
    } else {
        vec![(8, 2), (8, 4), (8, 8), (8, 16), (4, 2), (4, 4), (2, 2)]
    };
    let mut s = format!(
        "# Table 8 — PPL across LO-BCQ configurations (model {size}, BF16 PPL {base:.3})\n\n| L_A \\ (L_b,N_c) |"
    );
    for &(lb, nc) in &grid {
        write!(s, " ({lb},{nc}) |")?;
    }
    s.push('\n');
    writeln!(s, "|---|{}", "---|".repeat(grid.len()))?;
    for la in [64usize, 32, 16] {
        write!(s, "| {la} |")?;
        for &(lb, nc) in &grid {
            let scheme = env.lobcq(lb, nc, la)?;
            let ppl = ppl_cpu(&cfg, &w, &scheme, &scheme, &opts(quick))?;
            write!(s, " {ppl:.3} |")?;
        }
        s.push('\n');
    }
    s.push_str("\nShape: PPL improves with Nc↑ and L_A↓; L_b<8 gives diminishing returns at fixed bitwidth.\n");
    Ok(s)
}

/// ---- Table 9: universal vs layerwise calibration ----
pub fn tab9(env: &Env, quick: bool) -> anyhow::Result<String> {
    let (cfg, w) = need_weights(env, "s")?;
    let base = ppl_cpu(&cfg, &w, &Scheme::Bf16, &Scheme::Bf16, &opts(quick))?;
    let ncs: Vec<usize> = if quick { vec![2, 8] } else { vec![2, 4, 8, 16] };
    let las: Vec<usize> = if quick { vec![64] } else { vec![64, 32, 16] };
    let mut s = format!(
        "# Table 9 — universal vs layerwise codebooks (model s, BF16 PPL {base:.3}, W4A4, L_b=8)\n\n\
         | L_A | scope |"
    );
    for nc in &ncs {
        write!(s, " Nc={nc} |")?;
    }
    s.push('\n');
    writeln!(s, "|---|---|{}", "---|".repeat(ncs.len()))?;
    for &la in &las {
        for scope in ["universal", "layerwise"] {
            write!(s, "| {la} | {scope} |")?;
            for &nc in &ncs {
                let scheme = match scope {
                    "universal" => env.lobcq(8, nc, la)?,
                    // Layerwise: the same QuantScheme impl, refitting
                    // codebooks per tensor in its prepare() pass — the
                    // unified pipeline makes this a one-line swap.
                    _ => Scheme::quant(Arc::new(LobcqQuantizer::layerwise(
                        LobcqConfig::new(8, nc, la),
                        0xCA11B,
                    ))),
                };
                let ppl = ppl_cpu(&cfg, &w, &scheme, &scheme, &opts(quick))?;
                write!(s, " {ppl:.3} |")?;
            }
            s.push('\n');
        }
    }
    s.push_str("\nShape: layerwise ≈ universal for Nc > 4 (paper's justification for freezing universal books).\n");
    Ok(s)
}

/// ---- Table 10: codeword bits (INT4 vs INT6 vs INT8) ----
pub fn tab10(env: &Env, quick: bool) -> anyhow::Result<String> {
    let (cfg, w) = need_weights(env, "s")?;
    let base = ppl_cpu(&cfg, &w, &Scheme::Bf16, &Scheme::Bf16, &opts(quick))?;
    let mut s = format!(
        "# Table 10 — codeword integer width (model s, g128, W4A4, BF16 PPL {base:.3})\n\n\
         | Nc | INT4 | INT6 | INT8 |\n|---|---|---|---|\n"
    );
    for nc in [2usize, 4, 8, 16] {
        write!(s, "| {nc} |")?;
        for bc in [4u32, 6, 8] {
            let scheme = env.lobcq_bits(8, nc, 128, 4, bc)?;
            let ppl = ppl_cpu(&cfg, &w, &scheme, &scheme, &opts(quick))?;
            write!(s, " {ppl:.3} |")?;
        }
        s.push('\n');
    }
    s.push_str("\nShape: INT6 ≈ INT8, INT4 clearly worse (paper's basis for B_c = 6).\n");
    Ok(s)
}

/// ---- Table 11 + Fig 8: per-tensor FP vs Lloyd-Max ----
pub fn tab11_fig8(env: &Env, quick: bool) -> anyhow::Result<String> {
    let (cfg, w) = need_weights(env, "s")?;
    let base = ppl_cpu(&cfg, &w, &Scheme::Bf16, &Scheme::Bf16, &opts(quick))?;
    let mut s = format!(
        "# Table 11 / Fig 8 — per-tensor FP vs Lloyd-Max (weight-only, model s, BF16 PPL {base:.3})\n\n\
         | bits | FP format | FP PPL | Lloyd-Max PPL | FP wNMSE | LM wNMSE |\n|---|---|---|---|---|---|\n"
    );
    // Weight NMSE measured on the first GEMM tensor (Fig. 8's lens).
    let probe = w.get("l0.attn.wqkv")?;
    for (bits, fmt) in [(7u32, E3M3), (6, E3M2), (5, E4M0)] {
        let fp = Scheme::fp_tensor(fmt);
        let lm = Scheme::lloyd_max(bits);
        let fp_ppl = ppl_cpu(&cfg, &w, &fp, &Scheme::Bf16, &opts(quick))?;
        let lm_ppl = ppl_cpu(&cfg, &w, &lm, &Scheme::Bf16, &opts(quick))?;
        let fp_nmse = nmse(&probe.data, &fp.quantize_flat(&probe.data));
        let lm_nmse = nmse(&probe.data, &lm.quantize_flat(&probe.data));
        writeln!(
            s,
            "| {bits} | {} | {fp_ppl:.3} | {lm_ppl:.3} | {fp_nmse:.2e} | {lm_nmse:.2e} |",
            fmt.name
        )?;
    }
    s.push_str("\nShape: Lloyd-Max ≤ FP at every width; the gap explodes at 5 bits (E4M0 collapse).\n");
    Ok(s)
}

/// ---- Fig 1: ΔPPL vs compression factor scatter ----
pub fn fig1(env: &Env, quick: bool) -> anyhow::Result<String> {
    let (cfg, w) = need_weights(env, "s")?;
    let base = ppl_cpu(&cfg, &w, &Scheme::Bf16, &Scheme::Bf16, &opts(quick))?;
    let mut s = format!(
        "# Fig 1 — ΔPPL vs compression factor (model s, BF16 PPL {base:.3})\n\n\
         | Method | bits/scalar | compression× | ΔPPL |\n|---|---|---|---|\n"
    );
    let mut schemes = w4a4_schemes(env)?;
    schemes.push(env.lobcq(8, 4, 128)?);
    for scheme in schemes {
        let ppl = ppl_cpu(&cfg, &w, &scheme, &scheme, &opts(quick))?;
        let bits = scheme.bits();
        // Equal-weight A and W per the paper's metric.
        let cf = compression_factor(1000, bits, 1000, bits);
        writeln!(s, "| {} | {bits:.3} | {cf:.2} | {:+.3} |", scheme.name(), ppl - base)?;
    }
    s.push_str("\nShape: LO-BCQ sits on the Pareto frontier — lowest ΔPPL at every compression level.\n");
    Ok(s)
}

/// Gather the normalized calibration blocks for figure experiments.
fn fig_blocks(env: &Env, cfg_q: &LobcqConfig) -> anyhow::Result<Vec<f32>> {
    let data: Vec<f32> = match env.weights("s") {
        Ok(w) => w.get("l0.mlp.w1")?.transpose2().data,
        Err(_) => {
            let mut rng = Pcg32::seeded(0xF16);
            crate::util::rng::llm_like_sample(&mut rng, 64 * 1024, 0.04, 4.0)
        }
    };
    let norm = normalize(&data, cfg_q.la, cfg_q);
    Ok(norm.values)
}

/// ---- Fig 4: k-means++ vs naive init convergence ----
pub fn fig4(env: &Env) -> anyhow::Result<String> {
    let cfg = LobcqConfig::new(8, 16, 64);
    let values = fig_blocks(env, &cfg)?;
    let blocks: Vec<&[f32]> = values.chunks_exact(cfg.lb).collect();
    let mut s = String::from("# Fig 4 — NMSE vs iteration: proposed (k-means++) vs naive init (L_A=64, Nc=16)\n\n| iter | kmeans++ | naive |\n|---|---|---|\n");
    let denom = crate::util::stats::sum_sq(&values) / values.len() as f64;
    let run = |init| {
        let mut rng = Pcg32::seeded(0xF1604);
        calibrate_blocks(&blocks, &cfg, CalibOpts { max_iters: 25, rel_tol: 0.0, init }, &mut rng)
            .trace
            .iter()
            .map(|j| j / denom)
            .collect::<Vec<f64>>()
    };
    let pp = run(InitMethod::KmeansPp);
    let naive = run(InitMethod::Random);
    for i in 0..pp.len().max(naive.len()) {
        let a = pp.get(i).or(pp.last()).unwrap();
        let b = naive.get(i).or(naive.last()).unwrap();
        writeln!(s, "| {i} | {a:.5} | {b:.5} |")?;
    }
    let (fa, fb) = (*pp.last().unwrap(), *naive.last().unwrap());
    writeln!(s, "\nfinal: kmeans++ {fa:.5} vs naive {fb:.5} (expected: kmeans++ ≤ naive)")?;
    anyhow::ensure!(fa <= fb * 1.05, "kmeans++ init failed to match/beat naive");
    Ok(s)
}

/// ---- Fig 6: codebooks vs FP4 formats + per-layer NMSE ----
pub fn fig6(env: &Env) -> anyhow::Result<String> {
    let (cfg, w) = need_weights(env, "m")?;
    let fam = env.family(16, 4, 6)?;
    let mut s = String::from("# Fig 6 — LO-BCQ codebooks (left) and per-layer weight NMSE (right)\n\n## Codebook levels (INT6 codewords, normalized domain)\n\n");
    for (i, book) in fam.books.iter().enumerate() {
        writeln!(s, "- C{i}: {:?}", book.levels)?;
    }
    s.push_str("\n## Per-layer NMSE (first 20 GEMM tensors)\n\n| layer | LO-BCQ (g64,Nc16) | E1M2 (g16) | E2M1 (g16) | E3M0 (g16) |\n|---|---|---|---|---|\n");
    let lob = env.lobcq(8, 16, 64)?;
    let fp_block = |fmt: crate::formats::FloatFormat, data: &[f32]| -> f64 {
        // Per-16-block max-scaled FP4 (the MX-style comparison).
        let mut out = Vec::with_capacity(data.len());
        for b in data.chunks(16) {
            let amax = crate::util::stats::amax(b);
            if amax == 0.0 {
                out.extend_from_slice(b);
                continue;
            }
            let scale = fmt.max_value / amax;
            out.extend(b.iter().map(|&x| fmt.quantize(x * scale) / scale));
        }
        nmse(data, &out)
    };
    let mut count = 0;
    let mut wins = 0;
    for (name, _) in cfg.param_shapes() {
        if !is_gemm_weight(&name) || count >= 20 {
            continue;
        }
        count += 1;
        let data = w.get(&name)?.transpose2().data;
        let e_lob = nmse(&data, &lob.quantize_flat(&data));
        let e1 = fp_block(E1M2, &data);
        let e2 = fp_block(E2M1, &data);
        let e3 = fp_block(E3M0, &data);
        if e_lob <= e1.min(e2).min(e3) {
            wins += 1;
        }
        writeln!(s, "| {name} | {e_lob:.2e} | {e1:.2e} | {e2:.2e} | {e3:.2e} |")?;
    }
    writeln!(s, "\nLO-BCQ lowest-NMSE on {wins}/{count} layers (paper: LO-BCQ below all FP4 formats).")?;
    Ok(s)
}

/// ---- Fig 7: universal vs layerwise NMSE on activations ----
pub fn fig7(env: &Env) -> anyhow::Result<String> {
    let (cfg, w) = need_weights(env, "m")?;
    // Capture every GEMM input activation on one corpus batch, via an
    // identity pipeline hook (the capture tap sees whole tensors: the
    // FnScheme adapter is marked unshardable).
    let taps: Arc<std::sync::Mutex<Vec<Vec<f32>>>> = Arc::new(std::sync::Mutex::new(Vec::new()));
    let tap_sink = taps.clone();
    let capture = QuantPipeline::from_fn("capture", move |src, dst| {
        tap_sink.lock().unwrap().push(src.to_vec());
        dst.copy_from_slice(src);
    });
    let tokens = corpus::generate(1234, 8 * 64);
    forward(&cfg, &w, &tokens, 8, Some(&capture))?;
    drop(capture);
    let taps = std::mem::take(&mut *taps.lock().unwrap());

    let univ = env.lobcq(8, 8, 64)?;
    let lcfg = LobcqConfig::new(8, 8, 64);
    let mut s = String::from(
        "# Fig 7 — universal vs layerwise codebook NMSE on GEMM input activations\n\n\
         | tap | universal | layerwise |\n|---|---|---|\n",
    );
    let mut worst_ratio = 0.0f64;
    for (i, act) in taps.iter().take(30).enumerate() {
        let e_u = nmse(act, &univ.quantize_flat(act));
        let lq = LobcqQuantizer { cfg: lcfg, scope: CalibScope::Layerwise, family: None, seed: i as u64 };
        let e_l = nmse(act, &lq.quantize(act));
        worst_ratio = worst_ratio.max(e_u / e_l.max(1e-12));
        writeln!(s, "| {i} | {e_u:.2e} | {e_l:.2e} |")?;
    }
    writeln!(s, "\nworst universal/layerwise NMSE ratio: {worst_ratio:.2} (paper: comparable, ≈1)")?;
    Ok(s)
}

/// ---- Fig 9: NMSE vs iterations across configs + baselines ----
pub fn fig9(env: &Env) -> anyhow::Result<String> {
    let base_cfg = LobcqConfig::new(8, 8, 64);
    let values = fig_blocks(env, &base_cfg)?;
    let denom = crate::util::stats::sum_sq(&values) / values.len() as f64;
    let mut s = String::from("# Fig 9 — NMSE vs iteration for several (L_b, Nc), with MXFP4/VSQ reference lines\n\n");
    // Reference lines: baselines on the *denormalized* data.
    let raw: Vec<f32> = {
        let (cfgm, w) = need_weights(env, "s").or_else(|_| anyhow::bail!("need artifacts"))?;
        let _ = cfgm;
        w.get("l0.mlp.w1")?.transpose2().data
    };
    writeln!(s, "- MXFP4 NMSE: {:.5}", nmse(&raw, &mxfp4().quantize_flat(&raw)))?;
    writeln!(s, "- VSQ NMSE:   {:.5}\n", nmse(&raw, &vsq().quantize_flat(&raw)))?;
    writeln!(s, "| iter | (8,2) | (8,16) | (4,4) | (2,2) |")?;
    writeln!(s, "|---|---|---|---|---|")?;
    let mut traces = Vec::new();
    for (lb, nc) in [(8usize, 2usize), (8, 16), (4, 4), (2, 2)] {
        let cfg = LobcqConfig::new(lb, nc, 64);
        let norm = normalize(&raw, cfg.la, &cfg);
        let blocks = normalized_blocks(&norm, cfg.lb);
        let mut rng = Pcg32::seeded(0xF19);
        let trace = calibrate_blocks(&blocks, &cfg, CalibOpts { max_iters: 20, rel_tol: 0.0, init: InitMethod::KmeansPp }, &mut rng).trace;
        let d = crate::util::stats::sum_sq(&norm.values) / norm.values.len() as f64;
        traces.push(trace.iter().map(|j| j / d).collect::<Vec<f64>>());
    }
    let rows = traces.iter().map(|t| t.len()).max().unwrap();
    for i in 0..rows {
        write!(s, "| {i} |")?;
        for t in &traces {
            write!(s, " {:.5} |", t.get(i).or(t.last()).unwrap())?;
        }
        s.push('\n');
    }
    let _ = denom;
    s.push_str("\nShape: monotone traces; more codebooks / shorter blocks converge lower.\n");
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tab1_is_pure_and_complete() {
        let s = tab1().unwrap();
        assert!(s.contains("4.1875"));
        assert!(s.contains("| 16 |"));
    }

    #[test]
    fn unknown_experiment_errors() {
        let env = Env::load_from(std::path::PathBuf::from("/nonexistent"));
        assert!(run("tab99", &env, true).is_err());
    }

    #[test]
    fn fig4_runs_without_artifacts() {
        // Uses the synthetic fallback when no artifacts exist.
        let env = Env::load_from(std::path::PathBuf::from("/nonexistent"));
        let s = fig4(&env).unwrap();
        assert!(s.contains("kmeans++"));
    }
}
