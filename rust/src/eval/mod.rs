//! Evaluation harness: perplexity + downstream-task accuracy evaluators
//! and one driver per paper table/figure (see DESIGN.md §3 for the
//! experiment index and EXPERIMENTS.md for measured results).

pub mod experiments;
pub mod outliers;
pub mod perplexity;
pub mod scheme;
pub mod setup;
pub mod tasks_eval;

#[cfg(feature = "pjrt")]
pub use perplexity::ppl_pjrt;
pub use perplexity::{ppl_cpu, EvalOpts};
pub use scheme::Scheme;
pub use setup::Env;
