//! Function-preserving outlier injection.
//!
//! Large LLMs develop *outlier channels*: a few activation dimensions
//! with magnitudes 10–100× the rest (Dettmers et al. 2022; the paper's
//! §4.2.1 discussion of VSQ's Llama2-7B blow-up hinges on them). Tiny
//! synthetic-corpus transformers do not develop this phenomenon, so
//! naive W4A4 evaluation on them under-stresses every quantizer and
//! compresses the differences the paper's tables measure.
//!
//! This module restores the phenomenon *without changing the function
//! computed*: pick a fraction of channels and scale them by `alpha` on
//! the producer side (LN gain/bias columns, or V-projection columns)
//! while scaling the consumer weight rows by `1/alpha`. In exact
//! arithmetic the logits are identical (diagonal rescaling through a
//! linear map); in BF16/f32 the baseline perplexity moves by rounding
//! noise only (asserted in tests) — but the *quantizers* now face
//! realistic outlier-bearing operands on three of the four GEMM inputs
//! (the MLP-down input is left natural: GELU is not scale-equivariant).
//!
//! DESIGN.md §1 records this as part of the model-substitution argument.

use crate::model::{ModelConfig, Weights};
use crate::util::rng::Pcg32;

/// Injection parameters. Defaults mirror measured LLM outlier stats:
/// ~3% of channels at ~16× magnitude.
#[derive(Debug, Clone, Copy)]
pub struct OutlierSpec {
    pub frac: f32,
    pub alpha: f32,
    pub seed: u64,
}

impl Default for OutlierSpec {
    fn default() -> Self {
        OutlierSpec { frac: 0.04, alpha: 16.0, seed: 0x0071 }
    }
}

fn pick_channels(rng: &mut Pcg32, n: usize, frac: f32) -> Vec<usize> {
    let k = ((n as f32 * frac).round() as usize).max(1);
    rng.sample_indices(n, k)
}

/// Scale column `j` of a row-major (rows, cols) tensor by `a`.
fn scale_col(t: &mut crate::tensor::Tensor, j: usize, a: f32) {
    let cols = t.cols();
    let rows = t.rows();
    for r in 0..rows {
        t.data[r * cols + j] *= a;
    }
}

/// Scale row `j` by `a`.
fn scale_row(t: &mut crate::tensor::Tensor, j: usize, a: f32) {
    for v in t.row_mut(j) {
        *v *= a;
    }
}

/// Apply the rescaling to a weight set. Returns the transformed copy.
pub fn inject_outliers(cfg: &ModelConfig, w: &Weights, spec: OutlierSpec) -> Weights {
    let mut out = w.clone();
    let mut rng = Pcg32::new(spec.seed, 0x0071E8);
    let d = cfg.d;
    for i in 0..cfg.n_layers {
        // (1) ln1 gain/bias channel j × α  ⇒  wqkv row j × 1/α.
        let chans = pick_channels(&mut rng, d, spec.frac);
        {
            let g = out.tensor_mut(&format!("l{i}.ln1.g")).unwrap();
            for &j in &chans {
                g.data[j] *= spec.alpha;
            }
            let b = out.tensor_mut(&format!("l{i}.ln1.b")).unwrap();
            for &j in &chans {
                b.data[j] *= spec.alpha;
            }
            let wqkv = out.tensor_mut(&format!("l{i}.attn.wqkv")).unwrap();
            for &j in &chans {
                scale_row(wqkv, j, 1.0 / spec.alpha);
            }
        }
        // (2) V output channel j × α  ⇒  wo row j × 1/α. (V occupies
        // columns [2d, 3d) of wqkv; attention mixes tokens, not channels,
        // so the scale rides through to wo's input rows.)
        let chans = pick_channels(&mut rng, d, spec.frac);
        {
            let wqkv = out.tensor_mut(&format!("l{i}.attn.wqkv")).unwrap();
            for &j in &chans {
                scale_col(wqkv, 2 * d + j, spec.alpha);
            }
            let wo = out.tensor_mut(&format!("l{i}.attn.wo")).unwrap();
            for &j in &chans {
                scale_row(wo, j, 1.0 / spec.alpha);
            }
        }
        // (3) ln2 channel j × α  ⇒  mlp.w1 row j × 1/α.
        let chans = pick_channels(&mut rng, d, spec.frac);
        {
            let g = out.tensor_mut(&format!("l{i}.ln2.g")).unwrap();
            for &j in &chans {
                g.data[j] *= spec.alpha;
            }
            let b = out.tensor_mut(&format!("l{i}.ln2.b")).unwrap();
            for &j in &chans {
                b.data[j] *= spec.alpha;
            }
            let w1 = out.tensor_mut(&format!("l{i}.mlp.w1")).unwrap();
            for &j in &chans {
                scale_row(w1, j, 1.0 / spec.alpha);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::perplexity::{ppl_cpu, EvalOpts};
    use crate::eval::scheme::Scheme;
    use crate::model::forward;
    use crate::model::forward::tests_support::random_weights;

    fn cfg() -> ModelConfig {
        ModelConfig { name: "t".into(), d: 32, n_layers: 2, n_heads: 2, vocab: 168, max_t: 32 }
    }

    #[test]
    fn function_preserving_in_f32() {
        let c = cfg();
        let w = random_weights(&c, 31);
        let wi = inject_outliers(&c, &w, OutlierSpec::default());
        let tokens = crate::data::corpus::generate(3, 16);
        let a = forward(&c, &w, &tokens, 1, None).unwrap();
        let b = forward(&c, &wi, &tokens, 1, None).unwrap();
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 2e-3 * (1.0 + x.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn baseline_ppl_unchanged_but_quantized_stressed() {
        let c = cfg();
        let w = random_weights(&c, 32);
        let wi = inject_outliers(&c, &w, OutlierSpec::default());
        let opts = EvalOpts { n_windows: 4, t: 32, batch: 2, val_seed: 5678 };
        let base = ppl_cpu(&c, &w, &Scheme::Bf16, &Scheme::Bf16, &opts).unwrap();
        let base_i = ppl_cpu(&c, &wi, &Scheme::Bf16, &Scheme::Bf16, &opts).unwrap();
        assert!((base - base_i).abs() / base < 0.01, "{base} vs {base_i}");
        // The injected model stresses a coarse quantizer more.
        let q = crate::eval::scheme::vsq();
        let qv = ppl_cpu(&c, &wi, &q, &q, &opts).unwrap();
        let qv_plain = ppl_cpu(&c, &w, &q, &q, &opts).unwrap();
        assert!(qv > qv_plain * 0.9, "injection should not make VSQ easier: {qv} vs {qv_plain}");
    }

    #[test]
    fn deterministic() {
        let c = cfg();
        let w = random_weights(&c, 33);
        let a = inject_outliers(&c, &w, OutlierSpec::default());
        let b = inject_outliers(&c, &w, OutlierSpec::default());
        assert_eq!(a.get("l0.ln1.g").unwrap().data, b.get("l0.ln1.g").unwrap().data);
    }
}
