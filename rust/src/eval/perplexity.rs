//! Perplexity evaluation over the synthetic validation corpus, via
//! either the CPU reference forward (configuration sweeps) or a PJRT
//! artifact (headline tables / serving parity).

use crate::data::corpus;
use crate::eval::scheme::Scheme;
use crate::model::{forward, ModelConfig, Weights};
use crate::quant::pipeline::QuantPool;
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;

/// Evaluation workload: windows of `t` tokens from the validation stream.
#[derive(Debug, Clone, Copy)]
pub struct EvalOpts {
    pub val_seed: u64,
    pub n_windows: usize,
    pub t: usize,
    pub batch: usize,
}

impl Default for EvalOpts {
    fn default() -> Self {
        EvalOpts { val_seed: 5678, n_windows: 32, t: 64, batch: 8 }
    }
}

fn val_windows(opts: &EvalOpts) -> Vec<Vec<u32>> {
    let toks = corpus::generate(opts.val_seed, opts.n_windows * opts.t + 1 + opts.t);
    let mut w = corpus::windows(&toks, opts.t);
    w.truncate(opts.n_windows);
    w
}

/// Mean NLL → PPL from per-position log-probs.
fn ppl_from_nll(nll: f64, count: usize) -> f64 {
    (nll / count.max(1) as f64).exp()
}

/// Perplexity via the CPU reference forward: weights quantized offline by
/// `scheme`, activations quantized by the scheme's hook (W4A4 when both;
/// pass `Scheme::Bf16` in `act_scheme` for weight-only W4A16 rows).
pub fn ppl_cpu(
    cfg: &ModelConfig,
    weights: &Weights,
    weight_scheme: &Scheme,
    act_scheme: &Scheme,
    opts: &EvalOpts,
) -> anyhow::Result<f64> {
    // Warm the tied-LM-head panel on the *source* weights before the
    // per-scheme clone: clones share cached panels by Arc, so a config
    // sweep calling ppl_cpu per grid point transposes-and-packs the
    // [vocab, d] embedding exactly once instead of once per grid point.
    let _ = weights.packed_transposed("embed");
    let qw = match weight_scheme.encode_weights(cfg, weights) {
        // Encoded-domain weights when the scheme has a code format (the
        // same path serving takes; logits are bit-exact either way).
        Some(enc) => enc,
        None => weight_scheme.quantize_weights(cfg, weights),
    };
    // One pipeline for the whole eval: its scratch pool is reused across
    // every window batch, so only the first forward allocates.
    let pipe = act_scheme.act_pipeline(QuantPool::default());
    let hook_ref: crate::model::forward::ActQuant = pipe.as_ref();
    let windows = val_windows(opts);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for chunk in windows.chunks(opts.batch) {
        let batch = chunk.len();
        let mut tokens = Vec::with_capacity(batch * opts.t);
        for w in chunk {
            tokens.extend_from_slice(&w[..opts.t]);
        }
        let logits = forward(cfg, &qw, &tokens, batch, hook_ref)?;
        let vocab = cfg.vocab;
        for (b, w) in chunk.iter().enumerate() {
            for p in 0..opts.t - 1 {
                let row = logits.row(b * opts.t + p);
                nll -= log_softmax_at(row, w[p + 1] as usize);
                count += 1;
            }
            // Last position predicts the window's +1 token.
            let row = logits.row(b * opts.t + opts.t - 1);
            nll -= log_softmax_at(row, w[opts.t] as usize);
            count += 1;
            let _ = vocab;
        }
    }
    Ok(ppl_from_nll(nll, count))
}

/// Perplexity via a PJRT artifact (weights must be registered; LO-BCQ
/// variants additionally need a registered books key).
#[cfg(feature = "pjrt")]
pub fn ppl_pjrt(
    eng: &mut Engine,
    size: &str,
    variant: &str,
    weights_key: &str,
    books_key: Option<&str>,
    opts: &EvalOpts,
) -> anyhow::Result<f64> {
    let entry = eng
        .manifest
        .find(size, variant, opts.batch)
        .ok_or_else(|| anyhow::anyhow!("no artifact {size}/{variant}/b{}", opts.batch))?
        .clone();
    anyhow::ensure!(entry.t == opts.t, "artifact t {} != opts.t {}", entry.t, opts.t);
    let windows = val_windows(opts);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for chunk in windows.chunks(opts.batch) {
        // Pad partial chunks by repeating the first window (scored rows
        // are limited to the real ones).
        let mut tokens = Vec::with_capacity(opts.batch * opts.t);
        for i in 0..opts.batch {
            let w = chunk.get(i).unwrap_or(&chunk[0]);
            tokens.extend_from_slice(&w[..opts.t]);
        }
        let logits = eng.run_model(&entry, weights_key, books_key, &tokens)?;
        for (b, w) in chunk.iter().enumerate() {
            for p in 0..opts.t - 1 {
                nll -= logits.log_prob(b, p, w[p + 1]);
                count += 1;
            }
            nll -= logits.log_prob(b, opts.t - 1, w[opts.t]);
            count += 1;
        }
    }
    Ok(ppl_from_nll(nll, count))
}

pub fn log_softmax_at(row: &[f32], idx: usize) -> f64 {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let logsum: f64 = row.iter().map(|&x| ((x as f64) - max).exp()).sum::<f64>().ln() + max;
    row[idx] as f64 - logsum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::tests_support::{random_weights, tiny_cfg};

    fn opts() -> EvalOpts {
        EvalOpts { val_seed: 5678, n_windows: 4, t: 16, batch: 2 }
    }

    #[test]
    fn random_model_ppl_near_uniform() {
        // Untrained weights: PPL should be near vocab size (log-uniform).
        let cfg = tiny_cfg(); // vocab 40, but corpus tokens reach 167 — clamp
        // Use a corpus-compatible tiny config instead.
        let cfg = ModelConfig { vocab: 168, ..cfg };
        let w = random_weights(&cfg, 11);
        let ppl = ppl_cpu(&cfg, &w, &Scheme::Bf16, &Scheme::Bf16, &opts()).unwrap();
        assert!(ppl > 60.0 && ppl < 400.0, "ppl {ppl}");
    }

    #[test]
    fn quantized_ppl_at_least_baseline_shape() {
        let cfg = ModelConfig { vocab: 168, ..tiny_cfg() };
        let w = random_weights(&cfg, 12);
        let base = ppl_cpu(&cfg, &w, &Scheme::Bf16, &Scheme::Bf16, &opts()).unwrap();
        let q = crate::eval::scheme::mx4();
        let quant = ppl_cpu(&cfg, &w, &q, &q, &opts()).unwrap();
        // Untrained nets can wobble either way, but stay within a band.
        assert!(quant > base * 0.5 && quant < base * 2.0, "{quant} vs {base}");
    }

    #[test]
    fn log_softmax_normalized() {
        let row = [0.0f32, 1.0, -2.0];
        let total: f64 = (0..3).map(|i| log_softmax_at(&row, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
