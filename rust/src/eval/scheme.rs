//! Quantization scheme registry for the evaluation harness: one enum
//! that can (a) fake-quantize a model's GEMM weights offline and (b)
//! provide the on-the-fly activation hook for the CPU forward — so every
//! table swaps schemes uniformly.

use crate::formats::FloatFormat;
use crate::model::{ModelConfig, Weights};
use crate::quant::baselines::{
    FpTensorQuantizer, LloydMaxTensorQuantizer, Mx4Quantizer, Mxfp4Quantizer, Quantizer, VsqQuantizer,
};
use crate::quant::codebook::CodebookFamily;
use crate::quant::lobcq::{fake_quantize, LobcqConfig};
use crate::tensor::Tensor;

/// A weight/activation quantization scheme instance.
#[derive(Clone)]
pub enum Scheme {
    Bf16,
    /// LO-BCQ with a frozen (universal) family.
    Lobcq { cfg: LobcqConfig, family: CodebookFamily },
    Mx4(Mx4Quantizer),
    Vsq(VsqQuantizer),
    Mxfp4(Mxfp4Quantizer),
    /// Per-tensor FP format (Table 11 / Fig. 8).
    FpTensor(FloatFormat),
    /// Per-tensor Lloyd-Max (Table 11 / Fig. 8).
    LloydMax { bits: u32 },
}

impl Scheme {
    pub fn name(&self) -> String {
        match self {
            Scheme::Bf16 => "BF16".into(),
            Scheme::Lobcq { cfg, .. } => {
                format!("LO-BCQ (g{}, Nc={}, Lb={}, B={})", cfg.la, cfg.nc, cfg.lb, cfg.b)
            }
            Scheme::Mx4(q) => q.name(),
            Scheme::Vsq(q) => q.name(),
            Scheme::Mxfp4(q) => q.name(),
            Scheme::FpTensor(f) => format!("FP per-tensor ({})", f.name),
            Scheme::LloydMax { bits } => format!("Lloyd-Max per-tensor ({bits}b)"),
        }
    }

    /// Effective bits per scalar (eq. 9 for LO-BCQ; scheme-native else).
    pub fn bits(&self) -> f64 {
        match self {
            Scheme::Bf16 => 16.0,
            Scheme::Lobcq { cfg, .. } => cfg.bitwidth(),
            Scheme::Mx4(q) => q.bits_per_scalar(),
            Scheme::Vsq(q) => q.bits_per_scalar(),
            Scheme::Mxfp4(q) => q.bits_per_scalar(),
            Scheme::FpTensor(f) => f.bits() as f64,
            Scheme::LloydMax { bits } => *bits as f64,
        }
    }

    /// Fake-quantize a flat slice along contiguous groups (reduction dim).
    pub fn quantize_flat(&self, data: &[f32]) -> Vec<f32> {
        match self {
            Scheme::Bf16 => {
                let mut v = data.to_vec();
                crate::formats::bf16_round_slice(&mut v);
                v
            }
            Scheme::Lobcq { cfg, family } => fake_quantize(data, cfg, family),
            Scheme::Mx4(q) => q.quantize(data),
            Scheme::Vsq(q) => q.quantize(data),
            Scheme::Mxfp4(q) => q.quantize(data),
            Scheme::FpTensor(f) => FpTensorQuantizer::new(*f).quantize(data),
            Scheme::LloydMax { bits } => LloydMaxTensorQuantizer::new(*bits).quantize(data),
        }
    }

    /// Fake-quantize all GEMM weights of a model along the reduction
    /// dimension (mirror of python `quantize_weight_np`): transpose so K
    /// is contiguous, quantize, transpose back. Embeddings / LN params
    /// are untouched (paper §4.1 quantizes GEMM layers only).
    pub fn quantize_weights(&self, cfg: &ModelConfig, w: &Weights) -> Weights {
        if matches!(self, Scheme::Bf16) {
            return w.clone();
        }
        let mut out = w.clone();
        for (name, _) in cfg.param_shapes() {
            if !is_gemm_weight(&name) {
                continue;
            }
            let t = out.tensors.get(&name).unwrap();
            let tt = t.transpose2();
            let q = self.quantize_flat(&tt.data);
            let qt = Tensor::new(&tt.shape, q).transpose2();
            out.tensors.insert(name, qt);
        }
        out
    }

    /// Activation hook for the CPU forward (None for BF16 — the eval
    /// baseline leaves activations in f32/BF16, matching the artifacts).
    pub fn act_hook(&self) -> Option<Box<dyn Fn(&[f32]) -> Vec<f32> + Sync + Send>> {
        match self {
            Scheme::Bf16 => None,
            other => {
                let s = other.clone();
                Some(Box::new(move |x: &[f32]| s.quantize_flat(x)))
            }
        }
    }
}

/// GEMM weights are the 2-D non-embedding parameters.
pub fn is_gemm_weight(name: &str) -> bool {
    name.contains(".attn.w") || name.contains(".mlp.w")
}

/// Paper-default baseline instances.
pub fn mx4() -> Scheme {
    Scheme::Mx4(Mx4Quantizer::paper_default())
}

pub fn vsq() -> Scheme {
    Scheme::Vsq(VsqQuantizer::paper_default())
}

pub fn mxfp4() -> Scheme {
    Scheme::Mxfp4(Mxfp4Quantizer::paper_default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::tests_support::{random_weights, tiny_cfg};

    #[test]
    fn gemm_weight_detection() {
        assert!(is_gemm_weight("l0.attn.wqkv"));
        assert!(is_gemm_weight("l3.mlp.w2"));
        assert!(!is_gemm_weight("embed"));
        assert!(!is_gemm_weight("l0.ln1.g"));
        assert!(!is_gemm_weight("pos"));
    }

    #[test]
    fn quantize_weights_touches_only_gemms() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 9);
        let q = mx4().quantize_weights(&cfg, &w);
        assert_eq!(q.get("embed").unwrap().data, w.get("embed").unwrap().data);
        assert_ne!(
            q.get("l0.attn.wqkv").unwrap().data,
            w.get("l0.attn.wqkv").unwrap().data
        );
        // Shapes preserved through the transpose round trip.
        assert_eq!(q.get("l0.mlp.w1").unwrap().shape, w.get("l0.mlp.w1").unwrap().shape);
    }

    #[test]
    fn scheme_bits() {
        assert_eq!(mx4().bits(), 4.5);
        assert_eq!(mxfp4().bits(), 4.25);
        assert_eq!(Scheme::Bf16.bits(), 16.0);
    }
}
