//! Quantization scheme registry for the evaluation harness and the
//! serving coordinator: a thin constructor layer over
//! `Arc<dyn QuantScheme>` (the one trait LO-BCQ and every baseline
//! implement) that can (a) fake-quantize a model's GEMM weights offline
//! and (b) hand out the parallel activation [`QuantPipeline`] consumed by
//! the CPU forward and the CPU executor — so every table and the serving
//! path exercise identical quantization code.

use crate::formats::FloatFormat;
use crate::model::{ModelConfig, Weights};
use crate::quant::baselines::{
    FpTensorQuantizer, LloydMaxTensorQuantizer, Mx4Quantizer, Mxfp4Quantizer, VsqQuantizer,
};
use crate::quant::calib::LobcqQuantizer;
use crate::quant::codebook::CodebookFamily;
use crate::quant::lobcq::LobcqConfig;
use crate::quant::pipeline::{Bf16Scheme, QuantPipeline, QuantPool, QuantScheme};
use crate::tensor::Tensor;
use std::sync::Arc;

/// A weight/activation quantization scheme instance.
#[derive(Clone)]
pub enum Scheme {
    /// The 16-bit eval baseline: weights untouched, no activation hook
    /// (matching the BF16 artifacts).
    Bf16,
    /// Any scheme from the unified pipeline (LO-BCQ + all baselines).
    Quant(Arc<dyn QuantScheme>),
}

impl Scheme {
    /// Wrap an arbitrary pipeline scheme.
    pub fn quant(q: Arc<dyn QuantScheme>) -> Scheme {
        Scheme::Quant(q)
    }

    /// LO-BCQ with a frozen (universal) family.
    pub fn lobcq(cfg: LobcqConfig, family: CodebookFamily) -> Scheme {
        Scheme::Quant(Arc::new(LobcqQuantizer::universal(cfg, family)))
    }

    /// Per-tensor FP format (Table 11 / Fig. 8).
    pub fn fp_tensor(format: FloatFormat) -> Scheme {
        Scheme::Quant(Arc::new(FpTensorQuantizer::new(format)))
    }

    /// Per-tensor Lloyd-Max (Table 11 / Fig. 8).
    pub fn lloyd_max(bits: u32) -> Scheme {
        Scheme::Quant(Arc::new(LloydMaxTensorQuantizer::new(bits)))
    }

    pub fn name(&self) -> String {
        match self {
            Scheme::Bf16 => "BF16".into(),
            Scheme::Quant(q) => q.name(),
        }
    }

    /// Effective bits per scalar (eq. 9 for LO-BCQ; scheme-native else).
    pub fn bits(&self) -> f64 {
        match self {
            Scheme::Bf16 => 16.0,
            Scheme::Quant(q) => q.bits_per_scalar(),
        }
    }

    /// Fake-quantize a flat slice along contiguous groups (reduction
    /// dim). Allocating convenience over the serial pipeline path.
    pub fn quantize_flat(&self, data: &[f32]) -> Vec<f32> {
        match self {
            Scheme::Bf16 => Bf16Scheme.quantize(data),
            Scheme::Quant(q) => q.quantize(data),
        }
    }

    /// Fake-quantize all GEMM weights of a model along the reduction
    /// dimension (mirror of python `quantize_weight_np`). Embeddings /
    /// LN params are untouched (paper §4.1 quantizes GEMM layers only).
    ///
    /// The reduction dim (K) is the row index of a `[k, n]` GEMM weight,
    /// so quantization groups run down columns: we gather the K-major
    /// strided view into one reused scratch buffer, run the parallel
    /// in-place pipeline on it, and scatter straight back — replacing the
    /// old transpose → Vec → transpose chain (three full-tensor
    /// allocations per weight) with two pooled buffers for the whole
    /// model.
    pub fn quantize_weights(&self, cfg: &ModelConfig, w: &Weights) -> Weights {
        self.quantize_weights_with(cfg, w, QuantPool::default())
    }

    /// [`quantize_weights`](Self::quantize_weights) with an explicit
    /// worker pool (serving honors its configured `--workers` here too).
    pub fn quantize_weights_with(&self, cfg: &ModelConfig, w: &Weights, pool: QuantPool) -> Weights {
        let q = match self {
            Scheme::Bf16 => return w.clone(),
            Scheme::Quant(q) => q,
        };
        let mut out = w.clone();
        let mut gathered: Vec<f32> = Vec::new();
        let mut quantized: Vec<f32> = Vec::new();
        for (name, _) in cfg.param_shapes() {
            if !is_gemm_weight(&name) {
                continue;
            }
            let t = out.get(&name).unwrap();
            let (k, n) = (t.shape[0], t.shape[1]);
            let len = k * n;
            gathered.clear();
            gathered.resize(len, 0.0);
            quantized.clear();
            quantized.resize(len, 0.0);
            // Gather: gathered[c*k + r] = t[r, c] (K contiguous per column).
            for r in 0..k {
                let row = &t.data[r * n..(r + 1) * n];
                for (c, &v) in row.iter().enumerate() {
                    gathered[c * k + r] = v;
                }
            }
            pool.quantize_into(&**q, &gathered, &mut quantized);
            let mut qt = Tensor::zeros(&t.shape);
            for c in 0..n {
                let col = &quantized[c * k..(c + 1) * k];
                for (r, &v) in col.iter().enumerate() {
                    qt.data[r * n + c] = v;
                }
            }
            // Through the invalidating insert: any packed panel cached
            // for the unquantized tensor must not survive the swap.
            out.insert(&name, qt);
        }
        out
    }

    /// Compile every GEMM weight to the **encoded domain**: the dense
    /// tensor is replaced by a `kernels::QuantLinear` (packed LO-BCQ
    /// codes + planar metadata), so the quantized weights never exist as
    /// f32 tensors — the forward computes GEMMs straight from the codes.
    /// Returns `None` when the scheme has no packed code format (the
    /// caller falls back to [`quantize_weights`](Self::quantize_weights));
    /// logits are bit-exact between the two paths (kernel parity suite).
    pub fn encode_weights(&self, cfg: &ModelConfig, w: &Weights) -> Option<Weights> {
        let q = match self {
            Scheme::Bf16 => return None,
            Scheme::Quant(q) => q,
        };
        // Cheap capability gate before cloning anything: the dense
        // fallback path (all baselines) pays zero cost here.
        if !q.supports_encoded_weights() {
            return None;
        }
        let mut out = w.clone();
        let mut gathered: Vec<f32> = Vec::new();
        for (name, _) in cfg.param_shapes() {
            if !is_gemm_weight(&name) {
                continue;
            }
            let t = w.get(&name).ok()?;
            let (k, n) = (t.shape[0], t.shape[1]);
            gathered.clear();
            gathered.resize(k * n, 0.0);
            for r in 0..k {
                let row = &t.data[r * n..(r + 1) * n];
                for (c, &v) in row.iter().enumerate() {
                    gathered[c * k + r] = v;
                }
            }
            let ql = q.encode_weight(&gathered, k, n)?;
            out.set_encoded(&name, Arc::new(ql));
            // The codes ARE the weight now; drop the dense copy.
            out.remove_tensor(&name);
        }
        Some(out)
    }

    /// Compile this scheme's GEMM weights for serving: the encoded
    /// domain when the scheme has a packed code format, fake-quantized
    /// dense tensors otherwise. Returns the weight set and whether the
    /// encoded path was taken — the one decision both serving engines
    /// (`CpuExecutor` and `DecodeSession`) share.
    pub fn serving_weights(&self, cfg: &ModelConfig, w: &Weights, pool: QuantPool) -> (Weights, bool) {
        match self.encode_weights(cfg, w) {
            Some(qw) => (qw, true),
            None => (self.quantize_weights_with(cfg, w, pool), false),
        }
    }

    /// Activation pipeline for the CPU forward / CPU executor (None for
    /// BF16 — the eval baseline leaves activations in f32/BF16, matching
    /// the artifacts). The returned pipeline owns a scratch pool, so a
    /// caller that keeps it across forwards quantizes with zero
    /// steady-state allocations.
    pub fn act_pipeline(&self, pool: QuantPool) -> Option<QuantPipeline> {
        match self {
            Scheme::Bf16 => None,
            Scheme::Quant(q) => Some(QuantPipeline::new(q.clone(), pool)),
        }
    }
}

/// GEMM weights are the 2-D non-embedding parameters.
pub fn is_gemm_weight(name: &str) -> bool {
    name.contains(".attn.w") || name.contains(".mlp.w")
}

/// Serving-log label for [`Scheme::serving_weights`]' second return —
/// one definition so the batch and continuous engines can't drift.
pub fn weight_mode_name(encoded: bool) -> &'static str {
    if encoded {
        "encoded-domain (qgemm on LO-BCQ codes)"
    } else {
        "dense (fake-quantized f32)"
    }
}

/// Paper-default baseline instances.
pub fn mx4() -> Scheme {
    Scheme::Quant(Arc::new(Mx4Quantizer::paper_default()))
}

pub fn vsq() -> Scheme {
    Scheme::Quant(Arc::new(VsqQuantizer::paper_default()))
}

pub fn mxfp4() -> Scheme {
    Scheme::Quant(Arc::new(Mxfp4Quantizer::paper_default()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::tests_support::{random_weights, tiny_cfg};

    #[test]
    fn gemm_weight_detection() {
        assert!(is_gemm_weight("l0.attn.wqkv"));
        assert!(is_gemm_weight("l3.mlp.w2"));
        assert!(!is_gemm_weight("embed"));
        assert!(!is_gemm_weight("l0.ln1.g"));
        assert!(!is_gemm_weight("pos"));
    }

    #[test]
    fn quantize_weights_touches_only_gemms() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 9);
        let q = mx4().quantize_weights(&cfg, &w);
        assert_eq!(q.get("embed").unwrap().data, w.get("embed").unwrap().data);
        assert_ne!(
            q.get("l0.attn.wqkv").unwrap().data,
            w.get("l0.attn.wqkv").unwrap().data
        );
        // Shapes preserved through the gather/scatter round trip.
        assert_eq!(q.get("l0.mlp.w1").unwrap().shape, w.get("l0.mlp.w1").unwrap().shape);
    }

    #[test]
    fn quantize_weights_matches_transpose_reference() {
        // The strided gather/scatter path must equal the original
        // transpose → quantize_flat → transpose composition bit-for-bit.
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 21);
        for scheme in [mx4(), vsq(), mxfp4()] {
            let fast = scheme.quantize_weights(&cfg, &w);
            for (name, _) in cfg.param_shapes() {
                if !is_gemm_weight(&name) {
                    continue;
                }
                let t = w.get(&name).unwrap();
                let tt = t.transpose2();
                let want = Tensor::new(&tt.shape, scheme.quantize_flat(&tt.data)).transpose2();
                let got = fast.get(&name).unwrap();
                assert_eq!(got.shape, want.shape);
                for (a, b) in got.data.iter().zip(&want.data) {
                    assert!(a == b, "{}: {} vs {} ({})", scheme.name(), a, b, name);
                }
            }
        }
    }

    #[test]
    fn encode_weights_gated_on_scheme_support() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 33);
        // Baselines have no packed code format.
        assert!(mx4().encode_weights(&cfg, &w).is_none());
        assert!(Scheme::Bf16.encode_weights(&cfg, &w).is_none());
        // LO-BCQ compiles every GEMM weight to codes and drops the dense
        // tensors; non-GEMM params are untouched.
        let qcfg = crate::quant::lobcq::LobcqConfig::new(8, 4, 64);
        let fam = crate::quant::calib::calibrate_universal(
            &[w.get("l0.mlp.w1").unwrap()],
            &qcfg,
            crate::quant::lobcq::CalibOpts { max_iters: 8, ..Default::default() },
            7,
        );
        let scheme = Scheme::lobcq(qcfg, fam);
        let enc = scheme.encode_weights(&cfg, &w).unwrap();
        assert!(enc.has_encoded());
        assert!(enc.get("l0.attn.wqkv").is_err(), "dense GEMM tensor survived");
        assert!(enc.encoded("l0.attn.wqkv").is_some());
        assert_eq!(enc.get("embed").unwrap().data, w.get("embed").unwrap().data);
        // Shape bookkeeping still validates.
        enc.validate(&cfg).unwrap();
    }

    #[test]
    fn scheme_bits() {
        assert_eq!(mx4().bits(), 4.5);
        assert_eq!(mxfp4().bits(), 4.25);
        assert_eq!(Scheme::Bf16.bits(), 16.0);
    }

    #[test]
    fn act_pipeline_gating() {
        assert!(Scheme::Bf16.act_pipeline(QuantPool::serial()).is_none());
        let p = mx4().act_pipeline(QuantPool::serial()).unwrap();
        let x: Vec<f32> = (0..64).map(|i| i as f32 / 7.0 - 4.0).collect();
        assert_eq!(p.quantize(&x), mx4().quantize_flat(&x));
    }
}
