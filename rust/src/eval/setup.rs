//! Evaluation environment: loads the artifacts (manifest, trained
//! weights, universal codebook families) once and hands out schemes.
//! Falls back to rust-side calibration when `artifacts/` is absent so
//! unit tests and quickstart examples work pre-`make artifacts`.

use crate::model::{ModelConfig, Weights};
use crate::quant::calib::{calibrate_universal, sample_rows};
use crate::quant::codebook::CodebookFamily;
use crate::quant::lobcq::{CalibOpts, LobcqConfig};
use crate::runtime::Manifest;
use crate::tensor::Tensor;
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Mutex;

pub struct Env {
    pub dir: PathBuf,
    pub manifest: Option<Manifest>,
    family_cache: Mutex<HashMap<String, CodebookFamily>>,
    weights_cache: Mutex<HashMap<String, Weights>>,
}

impl Env {
    pub fn load() -> Env {
        Self::load_from(Manifest::default_dir())
    }

    pub fn load_from(dir: PathBuf) -> Env {
        let manifest = Manifest::load(&dir).ok();
        Env { dir, manifest, family_cache: Mutex::new(HashMap::new()), weights_cache: Mutex::new(HashMap::new()) }
    }

    pub fn has_artifacts(&self) -> bool {
        self.manifest.is_some()
    }

    pub fn model_config(&self, size: &str) -> anyhow::Result<ModelConfig> {
        let m = self.manifest.as_ref().ok_or_else(|| anyhow::anyhow!("no artifacts"))?;
        m.models
            .get(size)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("unknown model size '{size}'"))
    }

    pub fn weights(&self, size: &str) -> anyhow::Result<Weights> {
        if let Some(w) = self.weights_cache.lock().unwrap().get(size) {
            return Ok(w.clone());
        }
        let m = self.manifest.as_ref().ok_or_else(|| anyhow::anyhow!("no artifacts"))?;
        let w = Weights::load(&m.weights_path(size)?)?;
        w.validate(&self.model_config(size)?)?;
        self.weights_cache.lock().unwrap().insert(size.to_string(), w.clone());
        Ok(w)
    }

    /// Universal family for (nc, b), codeword-quantized to INT-`bc`.
    /// Prefers the python-calibrated `codebooks.json`; falls back to
    /// rust calibration on the proxy model weights (or synthetic data
    /// when no artifacts exist at all).
    pub fn family(&self, nc: usize, b: u32, bc: u32) -> anyhow::Result<CodebookFamily> {
        let key = format!("nc{nc}_b{b}_bc{bc}");
        if let Some(f) = self.family_cache.lock().unwrap().get(&key) {
            return Ok(f.clone());
        }
        let fam = match self.load_family_json(nc, b) {
            Ok(raw) => raw.quantize_codewords(bc),
            Err(_) => self.calibrate_fallback(nc, b, bc)?,
        };
        self.family_cache.lock().unwrap().insert(key, fam.clone());
        Ok(fam)
    }

    fn load_family_json(&self, nc: usize, b: u32) -> anyhow::Result<CodebookFamily> {
        let j = Json::from_file(&self.dir.join("codebooks.json"))?;
        let fam = j.get("families")?.get(&format!("nc{nc}_b{b}"))?;
        CodebookFamily::from_json(fam)
    }

    fn calibrate_fallback(&self, nc: usize, b: u32, bc: u32) -> anyhow::Result<CodebookFamily> {
        let cfg = LobcqConfig::new(8, nc, 64).with_bits(b).with_codeword_bits(bc);
        let samples: Vec<Tensor> = if let Ok(w) = self.weights("s") {
            let gemms: Vec<&Tensor> = self
                .model_config("s")?
                .param_shapes()
                .iter()
                .filter(|(n, _)| crate::eval::scheme::is_gemm_weight(n))
                .map(|(n, _)| w.get(n).unwrap())
                .collect();
            sample_rows(&gemms, 32, 0xCA11)
        } else {
            let mut rng = crate::util::rng::Pcg32::seeded(0xCA11);
            vec![Tensor::new(&[64, 256], crate::util::rng::llm_like_sample(&mut rng, 64 * 256, 0.04, 4.0))]
        };
        let refs: Vec<&Tensor> = samples.iter().collect();
        Ok(calibrate_universal(&refs, &cfg, CalibOpts::default(), 0x5EED))
    }

    /// Universal family calibrated on the *outlier-injected* proxy model
    /// (the evaluation distribution — paper §4.1 calibrates on real model
    /// data, which carries LLM outlier channels; see `eval::outliers`).
    /// Falls back to the plain family when no artifacts exist.
    pub fn family_for_eval(&self, nc: usize, b: u32, bc: u32) -> anyhow::Result<CodebookFamily> {
        let key = format!("inj_nc{nc}_b{b}_bc{bc}");
        if let Some(f) = self.family_cache.lock().unwrap().get(&key) {
            return Ok(f.clone());
        }
        let fam = match (self.weights("s"), self.model_config("s")) {
            (Ok(w), Ok(cfgm)) => {
                let wi = crate::eval::outliers::inject_outliers(
                    &cfgm,
                    &w,
                    crate::eval::outliers::OutlierSpec::default(),
                );
                let cfg = LobcqConfig::new(8, nc, 64).with_bits(b).with_codeword_bits(bc);
                // Reduction-dim orientation: transpose each GEMM weight.
                let gemms: Vec<Tensor> = cfgm
                    .param_shapes()
                    .iter()
                    .filter(|(n, _)| crate::eval::scheme::is_gemm_weight(n))
                    .map(|(n, _)| wi.get(n).unwrap().transpose2())
                    .collect();
                let refs: Vec<&Tensor> = gemms.iter().collect();
                let sampled = sample_rows(&refs, 24, 0xCA11);
                let srefs: Vec<&Tensor> = sampled.iter().collect();
                calibrate_universal(&srefs, &cfg, CalibOpts::default(), 0x5EED)
            }
            _ => self.family(nc, b, bc)?,
        };
        self.family_cache.lock().unwrap().insert(key, fam.clone());
        Ok(fam)
    }

    /// LO-BCQ scheme at a grid point, using the eval-distribution family.
    pub fn lobcq(&self, lb: usize, nc: usize, la: usize) -> anyhow::Result<crate::eval::scheme::Scheme> {
        self.lobcq_bits(lb, nc, la, 4, 6)
    }

    pub fn lobcq_bits(&self, lb: usize, nc: usize, la: usize, b: u32, bc: u32) -> anyhow::Result<crate::eval::scheme::Scheme> {
        let cfg = LobcqConfig::new(lb, nc, la).with_bits(b).with_codeword_bits(bc);
        cfg.validate()?;
        Ok(crate::eval::scheme::Scheme::lobcq(cfg, self.family_for_eval(nc, b, bc)?))
    }

    /// Flatten a family into the (Nc, entries) tensor the PJRT graphs take.
    pub fn books_tensor(family: &CodebookFamily) -> Tensor {
        let entries = family.books[0].len();
        let rows: Vec<f32> = family.books.iter().flat_map(|b| b.levels.clone()).collect();
        Tensor::new(&[family.nc(), entries], rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fallback_family_without_artifacts() {
        let env = Env::load_from(PathBuf::from("/nonexistent-artifacts"));
        assert!(!env.has_artifacts());
        let fam = env.family(4, 4, 6).unwrap();
        assert_eq!(fam.nc(), 4);
        assert_eq!(fam.books[0].len(), 16);
        // Cached second call.
        let fam2 = env.family(4, 4, 6).unwrap();
        assert_eq!(fam, fam2);
    }

    #[test]
    fn lobcq_scheme_construction() {
        let env = Env::load_from(PathBuf::from("/nonexistent-artifacts"));
        let s = env.lobcq(8, 4, 64).unwrap();
        assert!((s.bits() - 4.375).abs() < 1e-9);
        assert!(env.lobcq(8, 3, 64).is_err(), "non-pow2 Nc accepted");
    }

    #[test]
    fn books_tensor_shape() {
        let env = Env::load_from(PathBuf::from("/nonexistent-artifacts"));
        let fam = env.family(2, 4, 6).unwrap();
        let t = Env::books_tensor(&fam);
        assert_eq!(t.shape, vec![2, 16]);
    }
}
