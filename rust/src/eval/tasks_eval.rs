//! Downstream-task accuracy evaluation (paper §4.2.2–4.2.3 analog): the
//! answer-ranking protocol — score each choice token by the model's
//! log-probability at the prefix frontier; accuracy = fraction of items
//! where the true choice ranks first.

use crate::data::tasks::{ClozeItem, TaskKind};
use crate::eval::perplexity::log_softmax_at;
use crate::eval::scheme::Scheme;
use crate::model::{forward, ModelConfig, Weights};

/// Accuracy of one task under a (weight, activation) scheme pair.
pub fn task_accuracy(
    cfg: &ModelConfig,
    weights: &Weights,
    weight_scheme: &Scheme,
    act_scheme: &Scheme,
    items: &[ClozeItem],
) -> anyhow::Result<f64> {
    anyhow::ensure!(!items.is_empty(), "no task items");
    let qw = weight_scheme.quantize_weights(cfg, weights);
    let pipe = act_scheme.act_pipeline(crate::quant::pipeline::QuantPool::default());
    let hook_ref: crate::model::forward::ActQuant = pipe.as_ref();

    let mut correct = 0usize;
    // Batch items: each item needs logits at its prefix frontier. Pack up
    // to 8 prefixes per forward, padded to the longest in the pack.
    for pack in items.chunks(8) {
        let t = pack.iter().map(|i| i.prefix.len()).max().unwrap();
        let batch = pack.len();
        let mut tokens = vec![crate::data::corpus::PAD; batch * t];
        for (b, item) in pack.iter().enumerate() {
            // Right-align so the frontier is always position t-1 (causal
            // attention over left-pad sees PAD prefix; acceptable since
            // every item in a pack shares the convention).
            let off = t - item.prefix.len();
            tokens[b * t + off..(b + 1) * t].copy_from_slice(&item.prefix);
        }
        let logits = forward(cfg, &qw, &tokens, batch, hook_ref)?;
        for (b, item) in pack.iter().enumerate() {
            let row = logits.row(b * t + t - 1);
            let best = item
                .choices
                .iter()
                .enumerate()
                .max_by(|(_, &x), (_, &y)| {
                    log_softmax_at(row, x as usize)
                        .partial_cmp(&log_softmax_at(row, y as usize))
                        .unwrap()
                })
                .unwrap()
                .0;
            if best == item.answer {
                correct += 1;
            }
        }
    }
    Ok(correct as f64 / items.len() as f64)
}

/// Run all five LM-harness-analog tasks; returns (name, accuracy) rows
/// plus the average.
pub fn harness_suite(
    cfg: &ModelConfig,
    weights: &Weights,
    weight_scheme: &Scheme,
    act_scheme: &Scheme,
    items_per_task: usize,
    seed: u64,
) -> anyhow::Result<(Vec<(String, f64)>, f64)> {
    let mut rows = Vec::new();
    let mut sum = 0.0;
    for kind in crate::data::tasks::ALL_TASKS {
        let items = crate::data::tasks::build_items(kind, items_per_task, seed, 48);
        let acc = task_accuracy(cfg, weights, weight_scheme, act_scheme, &items)?;
        sum += acc;
        rows.push((kind.name().to_string(), acc));
    }
    let n = rows.len() as f64;
    Ok((rows, sum / n))
}

/// The MMLU analog: the hardest multi-choice task with longer context.
pub fn mmlu_accuracy(
    cfg: &ModelConfig,
    weights: &Weights,
    weight_scheme: &Scheme,
    act_scheme: &Scheme,
    n_items: usize,
    seed: u64,
) -> anyhow::Result<f64> {
    let items = crate::data::tasks::build_items(TaskKind::NounRecall, n_items, seed, 60);
    task_accuracy(cfg, weights, weight_scheme, act_scheme, &items)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{build_items, TaskKind};
    use crate::model::forward::tests_support::random_weights;
    use crate::model::ModelConfig;

    fn cfg() -> ModelConfig {
        ModelConfig { name: "t".into(), d: 32, n_layers: 2, n_heads: 2, vocab: 168, max_t: 64 }
    }

    #[test]
    fn random_model_near_chance() {
        let c = cfg();
        let w = random_weights(&c, 21);
        let items = build_items(TaskKind::NounAfterAdj, 60, 5, 48);
        let acc = task_accuracy(&c, &w, &Scheme::Bf16, &Scheme::Bf16, &items).unwrap();
        // 4 choices -> chance 0.25; untrained model should be near it.
        assert!(acc > 0.05 && acc < 0.6, "acc {acc}");
    }

    #[test]
    fn harness_suite_runs_all_tasks() {
        let c = cfg();
        let w = random_weights(&c, 22);
        let (rows, avg) = harness_suite(&c, &w, &Scheme::Bf16, &Scheme::Bf16, 10, 3).unwrap();
        assert_eq!(rows.len(), 5);
        assert!(avg > 0.0 && avg <= 1.0);
    }
}
