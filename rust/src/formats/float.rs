//! Generic low-precision floating-point codec (the paper's `EeMm` formats,
//! appendix A.4.2).
//!
//! A format is parameterized by exponent bits `be`, mantissa bits `bm`, and
//! an exponent bias (default `2^(be-1) - 1`). All encodings are finite —
//! out-of-range values saturate to ±max, matching how inference
//! quantization uses these formats (paper eq. 13–14). The E4M3 preset
//! follows the OCP FP8 convention (max = 448, the top mantissa pattern at
//! the top exponent being reserved), expressed here via a `max_value`
//! override.
//!
//! Quantization is round-to-nearest with ties-to-even on the mantissa grid,
//! including gradual underflow (subnormals), which is what `jnp` and the
//! python mirror (`python/compile/formats.py`) produce — the two are
//! parity-tested on shared JSON vectors.

/// A finite low-precision float format.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FloatFormat {
    /// Exponent bits (>= 1).
    pub be: u32,
    /// Mantissa bits (>= 0).
    pub bm: u32,
    /// Exponent bias.
    pub bias: i32,
    /// Largest representable magnitude (saturation point).
    pub max_value: f32,
    /// Display name, e.g. "E4M3".
    pub name: &'static str,
}

impl FloatFormat {
    /// Build a format with the conventional bias `2^(be-1)-1` and the
    /// all-finite maximum `2^emax * (2 - 2^-bm)`.
    pub const fn new(name: &'static str, be: u32, bm: u32) -> FloatFormat {
        let bias = if be >= 1 { (1 << (be - 1)) - 1 } else { 0 };
        let emax = ((1 << be) - 1) - bias - 0; // top exponent code, finite
        // max = 2^emax * (2 - 2^-bm)
        let frac_num = (2 << bm) - 1; // (2 - 2^-bm) * 2^bm
        let max_value = (frac_num as f32) * pow2i(emax - bm as i32);
        FloatFormat { be, bm, bias, max_value, name }
    }

    /// Override the maximum (used by the OCP E4M3 preset).
    pub const fn with_max(mut self, max_value: f32) -> FloatFormat {
        self.max_value = max_value;
        self
    }

    /// Minimum normal exponent (unbiased).
    pub fn emin(&self) -> i32 {
        1 - self.bias
    }

    /// Smallest positive subnormal step.
    pub fn min_subnormal(&self) -> f32 {
        pow2(self.emin() - self.bm as i32)
    }

    /// Total bit width including sign.
    pub fn bits(&self) -> u32 {
        1 + self.be + self.bm
    }

    /// Round a value to the nearest representable (ties to even), with
    /// saturation at ±max_value. NaN maps to 0 (defensive; operands are
    /// finite in this library).
    pub fn quantize(&self, x: f32) -> f32 {
        if x.is_nan() {
            return 0.0;
        }
        let a = x.abs();
        if a == 0.0 {
            return 0.0;
        }
        if a >= self.max_value {
            return self.max_value.copysign(x);
        }
        // Unbiased exponent of the *bucket* the value falls in.
        let e = (a.log2().floor() as i32).clamp(self.emin(), i32::MAX);
        // Mantissa grid step for that bucket (subnormal bucket when
        // a < 2^emin uses the emin step).
        let step = pow2(e - self.bm as i32);
        let q = (a / step).round_ties_even() * step;
        // Rounding up may promote to the next binade (e.g. 1.96 -> 2.0);
        // that is still exactly representable, so no fixup needed beyond
        // the saturation check above.
        let q = q.min(self.max_value);
        q.copysign(x)
    }

    /// Quantize a slice in place.
    pub fn quantize_slice(&self, xs: &mut [f32]) {
        for v in xs.iter_mut() {
            *v = self.quantize(*v);
        }
    }

    /// Encode a value to its bit pattern: `[sign | exponent | mantissa]`,
    /// `bits()` wide. The value is quantized first, so any finite f32 is
    /// accepted. Used by the packed LO-BCQ block format (Fig. 5) to store
    /// per-block-array scale factors as raw E4M3 bytes.
    pub fn encode_bits(&self, x: f32) -> u16 {
        assert!(self.bits() <= 16, "encode_bits supports formats up to 16 bits");
        let q = self.quantize(x);
        let sign = if q.is_sign_negative() { 1u16 } else { 0 };
        let a = q.abs();
        let (ecode, mcode) = if a == 0.0 {
            (0u16, 0u16)
        } else {
            let e = (a.log2().floor() as i32).max(self.emin());
            if a < pow2(self.emin()) {
                // Subnormal: exponent code 0, mantissa counts min-subnormal steps.
                (0, (a / self.min_subnormal()).round() as u16)
            } else {
                let frac = a / pow2(e); // in [1, 2)
                let m = ((frac - 1.0) * (1u32 << self.bm) as f32).round() as u16;
                ((e + self.bias) as u16, m)
            }
        };
        (sign << (self.be + self.bm)) | (ecode << self.bm) | mcode
    }

    /// Decode a bit pattern produced by [`encode_bits`](Self::encode_bits).
    pub fn decode_bits(&self, code: u16) -> f32 {
        let mmask = (1u16 << self.bm) - 1;
        let emask = (1u16 << self.be) - 1;
        let m = code & mmask;
        let e = (code >> self.bm) & emask;
        let sign = (code >> (self.be + self.bm)) & 1;
        let a = if e == 0 {
            m as f32 * self.min_subnormal()
        } else {
            (1.0 + m as f32 / (1u32 << self.bm) as f32) * pow2(e as i32 - self.bias)
        };
        let a = a.min(self.max_value);
        if sign == 1 {
            -a
        } else {
            a
        }
    }

    /// Enumerate all non-negative representable values in ascending order
    /// (small formats only; used for codebook comparisons, Fig. 6, and
    /// exhaustive codec tests).
    pub fn enumerate_non_negative(&self) -> Vec<f32> {
        assert!(self.bits() <= 10, "enumerate only for small formats");
        let mut vals = vec![0.0f32];
        // Subnormals: m / 2^bm * 2^emin for m = 1..2^bm
        for m in 1..(1u32 << self.bm) {
            vals.push(m as f32 * self.min_subnormal());
        }
        // Normals.
        let top_code = (1i32 << self.be) - 1;
        for ecode in 1..=top_code {
            let e = ecode - self.bias;
            for m in 0..(1u32 << self.bm) {
                let v = (1.0 + m as f32 / (1u32 << self.bm) as f32) * pow2(e);
                if v <= self.max_value {
                    vals.push(v);
                }
            }
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        vals
    }

    /// All representable values (negatives, zero, positives), ascending.
    pub fn enumerate_all(&self) -> Vec<f32> {
        let pos = self.enumerate_non_negative();
        let mut all: Vec<f32> = pos.iter().rev().filter(|&&v| v > 0.0).map(|&v| -v).collect();
        all.extend(pos);
        all
    }
}

/// 2^e as f32 for small |e| (const-friendly integer variant).
const fn pow2i(e: i32) -> f32 {
    // Constructed via bit pattern to stay const: only valid for normal
    // range, which all our formats' emax satisfy.
    if e >= -126 && e <= 127 {
        f32::from_bits(((e + 127) as u32) << 23)
    } else if e < -126 {
        0.0
    } else {
        f32::INFINITY
    }
}

/// 2^e as f32 including subnormal results.
pub fn pow2(e: i32) -> f32 {
    if e >= -126 {
        pow2i(e)
    } else if e >= -149 {
        f32::from_bits(1u32 << (e + 149))
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::presets::*;

    #[test]
    fn pow2_matches_std() {
        for e in -150..=127 {
            assert_eq!(pow2(e), 2f64.powi(e) as f32, "e={e}");
        }
    }

    #[test]
    fn e2m1_values_match_mxfp4_spec() {
        // MXFP4 / E2M1 representable magnitudes: 0, 0.5, 1, 1.5, 2, 3, 4, 6.
        let vals = E2M1.enumerate_non_negative();
        assert_eq!(vals, vec![0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]);
        assert_eq!(E2M1.max_value, 6.0);
    }

    #[test]
    fn e1m2_values() {
        // E1M2: bias 0, emin = 1, subnormal step 2^(1-2) = 0.5... check count.
        let vals = E1M2.enumerate_non_negative();
        assert_eq!(vals.len(), 8); // 0 + 3 subnormals + 4 normals at e=1
        assert_eq!(vals[0], 0.0);
        assert_eq!(*vals.last().unwrap(), E1M2.max_value);
    }

    #[test]
    fn e3m0_powers_of_two() {
        let vals = E3M0.enumerate_non_negative();
        // Pure exponent format: 0, then subnormal step, then powers of 2.
        for w in vals.windows(2).skip(1) {
            if w[0] > 0.0 {
                assert_eq!(w[1] / w[0], 2.0, "{:?}", w);
            }
        }
    }

    #[test]
    fn e4m3_ocp_max_is_448() {
        assert_eq!(E4M3.max_value, 448.0);
        assert_eq!(E4M3.quantize(1e9), 448.0);
        assert_eq!(E4M3.quantize(-1e9), -448.0);
    }

    #[test]
    fn quantize_is_idempotent_on_enumerated_values() {
        for fmt in [E1M2, E2M1, E3M0, E3M2, E3M3] {
            for v in fmt.enumerate_all() {
                assert_eq!(fmt.quantize(v), v, "{} value {v}", fmt.name);
            }
        }
    }

    #[test]
    fn quantize_picks_nearest() {
        for fmt in [E1M2, E2M1, E3M0, E3M2] {
            let grid = fmt.enumerate_all();
            let mut x = -fmt.max_value * 1.5;
            while x < fmt.max_value * 1.5 {
                let q = fmt.quantize(x);
                let best = grid
                    .iter()
                    .cloned()
                    .min_by(|a, b| (a - x).abs().partial_cmp(&(b - x).abs()).unwrap())
                    .unwrap();
                assert!(
                    (q - x).abs() <= (best - x).abs() + 1e-7,
                    "{}: quantize({x}) = {q}, nearest = {best}",
                    fmt.name
                );
                x += fmt.max_value / 257.0;
            }
        }
    }

    #[test]
    fn ties_round_to_even_mantissa() {
        // In E2M1 the grid around 1.0 is {1.0, 1.5}: 1.25 is a tie ->
        // rounds to 1.0 (even mantissa 0) not 1.5 (odd mantissa 1).
        assert_eq!(E2M1.quantize(1.25), 1.0);
        // 1.75 ties between 1.5 and 2.0 -> 2.0 (mantissa 0).
        assert_eq!(E2M1.quantize(1.75), 2.0);
    }

    #[test]
    fn subnormal_flush_behaviour() {
        // Values below half the min subnormal round to zero.
        for fmt in [E2M1, E4M3] {
            let tiny = fmt.min_subnormal() * 0.49;
            assert_eq!(fmt.quantize(tiny), 0.0, "{}", fmt.name);
            let keep = fmt.min_subnormal() * 0.51;
            assert_eq!(fmt.quantize(keep), fmt.min_subnormal(), "{}", fmt.name);
        }
    }

    #[test]
    fn sign_symmetric() {
        let mut rng = crate::util::rng::Pcg32::seeded(11);
        for fmt in [E1M2, E2M1, E3M0, E4M3, E5M2] {
            for _ in 0..500 {
                let x = rng.normal() * 8.0;
                assert_eq!(fmt.quantize(x), -fmt.quantize(-x), "{} x={x}", fmt.name);
            }
        }
    }

    #[test]
    fn encode_decode_round_trip_all_values() {
        for fmt in [E1M2, E2M1, E3M0, E3M2, E3M3, E4M3, E5M2] {
            for v in fmt.enumerate_all() {
                let code = fmt.encode_bits(v);
                assert!(code < (1 << fmt.bits()), "{}: code {code} too wide", fmt.name);
                let back = fmt.decode_bits(code);
                assert_eq!(back, v, "{}: {v} -> {code:#x} -> {back}", fmt.name);
            }
        }
    }

    #[test]
    fn encode_bits_of_arbitrary_equals_quantize() {
        let mut rng = crate::util::rng::Pcg32::seeded(16);
        for fmt in [E2M1, E4M3] {
            for _ in 0..2000 {
                let x = rng.normal() * 50.0;
                assert_eq!(fmt.decode_bits(fmt.encode_bits(x)), fmt.quantize(x), "{} x={x}", fmt.name);
            }
        }
    }

    #[test]
    fn negative_zero_encodes_sign() {
        // -0.0 carries the sign bit but decodes equal to 0.0.
        let code = E4M3.encode_bits(-0.0);
        assert_eq!(E4M3.decode_bits(code), 0.0);
    }

    #[test]
    fn bits_accounting() {
        assert_eq!(E2M1.bits(), 4);
        assert_eq!(E1M2.bits(), 4);
        assert_eq!(E3M0.bits(), 4);
        assert_eq!(E4M3.bits(), 8);
        assert_eq!(E3M3.bits(), 7);
    }
}
