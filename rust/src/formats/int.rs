//! Symmetric signed integer codec (INT-k), paper appendix A.4.1.
//!
//! Quantization levels are the integers in `[-(2^{k-1}-1), 2^{k-1}-1]` —
//! the symmetric range used by VSQ and by LO-BCQ's INT-`B_c` codeword
//! quantization (the most negative two's-complement code is unused, as is
//! standard for symmetric DNN quantization). Rounding is
//! nearest-ties-to-even; out-of-range values saturate.

/// Symmetric INT-k format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IntFormat {
    /// Total bits including sign (2..=16).
    pub bits: u32,
}

impl IntFormat {
    pub const fn new(bits: u32) -> IntFormat {
        assert!(bits >= 2 && bits <= 16);
        IntFormat { bits }
    }

    /// Largest representable level, `2^{k-1} - 1` (paper eq. 7 numerator).
    pub fn max_level(&self) -> i32 {
        (1 << (self.bits - 1)) - 1
    }

    /// Round to nearest integer level with saturation; returns the level.
    pub fn encode(&self, x: f32) -> i32 {
        if x.is_nan() {
            return 0;
        }
        let m = self.max_level() as f32;
        x.clamp(-m, m).round_ties_even() as i32
    }

    /// Encoded level back to f32.
    pub fn decode(&self, level: i32) -> f32 {
        debug_assert!(level.abs() <= self.max_level());
        level as f32
    }

    /// Quantize to the integer grid (encode∘decode).
    pub fn quantize(&self, x: f32) -> f32 {
        self.decode(self.encode(x))
    }

    /// Quantize a slice in place.
    pub fn quantize_slice(&self, xs: &mut [f32]) {
        for v in xs.iter_mut() {
            *v = self.quantize(*v);
        }
    }

    /// Max-scaled quantize-dequantize of a slice (the VSQ per-vector
    /// scheme, appendix A.5): scale so max|x| hits the top level, round,
    /// rescale back. Returns the scale used.
    pub fn quantize_maxscaled(&self, xs: &mut [f32]) -> f32 {
        let amax = crate::util::stats::amax(xs);
        if amax == 0.0 {
            return 1.0;
        }
        let scale = self.max_level() as f32 / amax;
        for v in xs.iter_mut() {
            *v = self.quantize(*v * scale) / scale;
        }
        scale
    }
}

pub const INT4: IntFormat = IntFormat::new(4);
pub const INT6: IntFormat = IntFormat::new(6);
pub const INT8: IntFormat = IntFormat::new(8);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_levels() {
        assert_eq!(INT4.max_level(), 7);
        assert_eq!(INT6.max_level(), 31);
        assert_eq!(INT8.max_level(), 127);
    }

    #[test]
    fn saturation() {
        assert_eq!(INT4.encode(100.0), 7);
        assert_eq!(INT4.encode(-100.0), -7);
    }

    #[test]
    fn ties_to_even() {
        assert_eq!(INT8.encode(2.5), 2);
        assert_eq!(INT8.encode(3.5), 4);
        assert_eq!(INT8.encode(-2.5), -2);
    }

    #[test]
    fn round_trip_integers() {
        for lvl in -7..=7 {
            assert_eq!(INT4.encode(lvl as f32), lvl);
            assert_eq!(INT4.quantize(lvl as f32), lvl as f32);
        }
    }

    #[test]
    fn maxscaled_hits_top_level() {
        let mut xs = vec![0.1f32, -0.25, 0.5];
        INT4.quantize_maxscaled(&mut xs);
        // max element maps exactly to ±max_level/scale = original max.
        assert_eq!(xs[2], 0.5);
        // all within range
        assert!(xs.iter().all(|v| v.abs() <= 0.5));
    }

    #[test]
    fn maxscaled_zero_vector_noop() {
        let mut xs = vec![0.0f32; 4];
        let s = INT4.quantize_maxscaled(&mut xs);
        assert_eq!(s, 1.0);
        assert!(xs.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn quantize_error_bounded_by_half_step() {
        let mut rng = crate::util::rng::Pcg32::seeded(12);
        for _ in 0..1000 {
            let x = rng.range_f32(-7.0, 7.0);
            let q = INT4.quantize(x);
            assert!((q - x).abs() <= 0.5 + 1e-6);
        }
    }
}
