//! Number formats (paper appendix A.4): generic `EeMm` floating-point
//! codecs, symmetric INT-k, E8M0 power-of-two scales, and BF16 rounding.
//!
//! Everything here is deterministic, allocation-free on the quantize path,
//! and mirrored by `python/compile/formats.py` (parity-tested through the
//! shared JSON vectors in `make test`).

pub mod float;
pub mod int;
pub mod presets;

pub use float::FloatFormat;
pub use int::{IntFormat, INT4, INT6, INT8};
pub use presets::{bf16_round, bf16_round_slice, by_name, E8M0};
pub use presets::{E1M2, E2M1, E3M0, E3M2, E3M3, E4M0, E4M3, E5M2, FP4_FORMATS};
