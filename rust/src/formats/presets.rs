//! Named format presets used throughout the paper.
//!
//! - `E1M2` — proxy for MX4 (paper A.5.1 conservatively bounds MX4 by E1M2).
//! - `E2M1` — MXFP4 scalar format.
//! - `E3M0` — 4-bit pure-exponent format (Fig. 6 comparison).
//! - `E4M3` — OCP FP8, the LO-BCQ per-block-array scale-factor format
//!   (paper §2.4; max 448 per the OCP convention).
//! - `E5M2` — OCP FP8 alternate (used in ablations).
//! - `E3M3`, `E3M2`, `E4M0` — appendix A.1 / Table 11 per-tensor formats.
//! - `E8M0` — power-of-two scale format used by MX/MXFP block scales.

use super::float::FloatFormat;

pub const E1M2: FloatFormat = FloatFormat::new("E1M2", 1, 2);
pub const E2M1: FloatFormat = FloatFormat::new("E2M1", 2, 1);
pub const E3M0: FloatFormat = FloatFormat::new("E3M0", 3, 0);
pub const E4M3: FloatFormat = FloatFormat::new("E4M3", 4, 3).with_max(448.0);
pub const E5M2: FloatFormat = FloatFormat::new("E5M2", 5, 2).with_max(57344.0);
pub const E3M3: FloatFormat = FloatFormat::new("E3M3", 3, 3);
pub const E3M2: FloatFormat = FloatFormat::new("E3M2", 3, 2);
pub const E4M0: FloatFormat = FloatFormat::new("E4M0", 4, 0);

/// All 4-bit float formats compared against LO-BCQ codebooks in Fig. 6.
pub const FP4_FORMATS: [FloatFormat; 3] = [E1M2, E2M1, E3M0];

/// Look up a preset by name (CLI / config surface).
pub fn by_name(name: &str) -> Option<FloatFormat> {
    match name.to_ascii_uppercase().as_str() {
        "E1M2" => Some(E1M2),
        "E2M1" => Some(E2M1),
        "E3M0" => Some(E3M0),
        "E4M3" => Some(E4M3),
        "E5M2" => Some(E5M2),
        "E3M3" => Some(E3M3),
        "E3M2" => Some(E3M2),
        "E4M0" => Some(E4M0),
        _ => None,
    }
}

/// E8M0: pure power-of-two scale (8 exponent bits, bias 127, no sign, no
/// mantissa). Used for MX / MXFP per-block-array scale factors. Following
/// the MX convention, encoding takes `floor(log2(x))` — the shared scale
/// must not overshoot the block maximum or the top element would clip.
#[derive(Debug, Clone, Copy)]
pub struct E8M0;

impl E8M0 {
    pub const BITS: u32 = 8;

    /// Quantize a positive scale to an exact power of two (floor mode).
    /// Zero and negatives map to the smallest representable scale.
    pub fn quantize_floor(x: f32) -> f32 {
        if !(x > 0.0) || !x.is_finite() {
            return super::float::pow2(-127);
        }
        let e = x.log2().floor() as i32;
        super::float::pow2(e.clamp(-127, 127))
    }

    /// Nearest-power-of-two variant (used in ablations).
    pub fn quantize_nearest(x: f32) -> f32 {
        if !(x > 0.0) || !x.is_finite() {
            return super::float::pow2(-127);
        }
        let lo = Self::quantize_floor(x);
        let hi = lo * 2.0;
        if (x - lo).abs() <= (hi - x).abs() {
            lo
        } else {
            hi.min(super::float::pow2(127))
        }
    }
}

/// BF16 rounding (round-to-nearest-even on the low 16 bits of an f32).
/// The paper's unquantized baseline format and its "fake quantization"
/// compute precision (§4.1 footnote 3).
pub fn bf16_round(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    let bits = x.to_bits();
    let rounding_bias = 0x7FFF + ((bits >> 16) & 1);
    f32::from_bits((bits.wrapping_add(rounding_bias)) & 0xFFFF_0000)
}

/// BF16-round a slice in place.
pub fn bf16_round_slice(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = bf16_round(*v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("e4m3").unwrap().name, "E4M3");
        assert!(by_name("E9M9").is_none());
    }

    #[test]
    fn e8m0_floor_is_power_of_two_below() {
        for x in [0.1f32, 1.0, 1.5, 2.0, 3.9, 1000.0] {
            let q = E8M0::quantize_floor(x);
            assert!(q <= x, "{q} > {x}");
            assert!(q * 2.0 > x, "floor too small for {x}");
            assert_eq!(q.log2().fract(), 0.0);
        }
    }

    #[test]
    fn e8m0_nearest() {
        assert_eq!(E8M0::quantize_nearest(3.1), 4.0);
        assert_eq!(E8M0::quantize_nearest(2.9), 2.0);
        assert_eq!(E8M0::quantize_nearest(2.0), 2.0);
    }

    #[test]
    fn e8m0_degenerate_inputs() {
        assert!(E8M0::quantize_floor(0.0) > 0.0);
        assert!(E8M0::quantize_floor(-1.0) > 0.0);
        assert!(E8M0::quantize_floor(f32::NAN) > 0.0);
    }

    #[test]
    fn bf16_round_trip_exact_values() {
        // Values with <= 8 significand bits are exact in bf16.
        for x in [0.0f32, 1.0, -2.5, 0.15625, 384.0] {
            assert_eq!(bf16_round(x), x);
        }
    }

    #[test]
    fn bf16_rounds_to_nearest() {
        // bf16 has 7 explicit mantissa bits: ulp at 1.0 is 2^-7.
        assert_eq!(bf16_round(1.0 + 2f32.powi(-10)), 1.0);
        // 1 + 3*2^-9 is closer to 1 + 2^-7 than to 1.0.
        assert_eq!(bf16_round(1.0 + 3.0 * 2f32.powi(-9)), 1.0 + 2f32.powi(-7));
    }

    #[test]
    fn bf16_error_bound() {
        let mut rng = crate::util::rng::Pcg32::seeded(13);
        for _ in 0..2000 {
            let x = rng.normal() * 100.0;
            let q = bf16_round(x);
            // Relative error <= 2^-8 (half ulp of the 8-bit significand).
            assert!((q - x).abs() <= x.abs() * 2f32.powi(-8) + f32::MIN_POSITIVE);
        }
    }
}
