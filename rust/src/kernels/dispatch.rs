//! Runtime-dispatched SIMD micro-kernels (DESIGN.md §SIMD dispatch).
//!
//! The blocked GEMM driver (`kernels::gemm`) funnels every tile update
//! through one micro-kernel: an `MR × NR` accumulator tile updated as
//! `acc[i][j] += a[i][k] * b[k][j]` for `k` ascending. This module
//! provides three implementations of that update and picks one at
//! runtime:
//!
//! - [`KernelBackend::Scalar`] — the original portable kernel, kept
//!   verbatim as the **parity oracle** every SIMD path is tested
//!   against;
//! - [`KernelBackend::Avx2`] — x86-64, two 8-lane `__m256` registers per
//!   tile row;
//! - [`KernelBackend::Neon`] — aarch64, four 4-lane `float32x4_t`
//!   registers per tile row.
//!
//! **Bit-exactness contract.** The SIMD kernels vectorize across the
//! `NR` *column* lanes only. Each C element still sees the exact scalar
//! recurrence — one IEEE-754 f32 multiply and one add per `k` step, `k`
//! strictly ascending — because lanes of a vector multiply/add round
//! independently and no `k` reduction is ever split across lanes. Two
//! things would silently break this and are deliberately avoided:
//! FMA-style fused intrinsics (`_mm256_fmadd_ps`, `vfmaq_f32`), which
//! skip the intermediate rounding of the product, and horizontal-sum
//! reassociation (accumulating partial sums per lane and folding at the
//! end). With both ruled out, scalar and SIMD paths produce bitwise
//! identical output for bitwise identical inputs — pinned by the
//! microkernel tests here, `tests/simd_parity.rs`, and every existing
//! parity suite run under `LOBCQ_FORCE_SCALAR=1` in CI.
//!
//! Selection: [`active_backend`] probes the CPU once (`OnceLock`) via
//! `is_x86_feature_detected!` / `is_aarch64_feature_detected!`, with the
//! `LOBCQ_FORCE_SCALAR=1` environment override forcing the oracle.
//! Explicitly requested backends (benches, parity tests) are sanitized
//! through [`KernelBackend::sanitize`] so a backend value for a feature
//! the CPU lacks can never reach an intrinsic.

use super::gemm::{MR, NR};
use std::sync::OnceLock;

// The SIMD kernels hardcode the register split of an NR-wide tile row
// (2 × 8 lanes on AVX2, 4 × 4 lanes on NEON).
const _: () = assert!(NR == 16 && MR == 4, "SIMD micro-kernels assume the 4x16 tile");

/// Which micro-kernel implementation the GEMM driver runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelBackend {
    /// Portable scalar kernel — the parity oracle, available everywhere.
    Scalar,
    /// x86-64 AVX2 (8-lane f32 vectors).
    Avx2,
    /// aarch64 NEON (4-lane f32 vectors).
    Neon,
}

impl KernelBackend {
    /// Lowercase name for logs / bench JSON (`scalar` / `avx2` / `neon`).
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Avx2 => "avx2",
            KernelBackend::Neon => "neon",
        }
    }

    /// Whether the current CPU can run this backend.
    pub fn supported(self) -> bool {
        match self {
            KernelBackend::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            KernelBackend::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            KernelBackend::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            #[allow(unreachable_patterns)]
            _ => false,
        }
    }

    /// This backend if the CPU supports it, else the scalar oracle. The
    /// GEMM driver entry sanitizes every explicit backend request through
    /// this, so [`microkernel`] can assume `Avx2`/`Neon` imply the
    /// feature is present.
    pub fn sanitize(self) -> KernelBackend {
        if self.supported() {
            self
        } else {
            KernelBackend::Scalar
        }
    }
}

/// `LOBCQ_FORCE_SCALAR` semantics: set-and-nonzero forces the scalar
/// path (unset, empty, or `0` leave detection on).
fn force_scalar(val: Option<&str>) -> bool {
    matches!(val, Some(v) if !v.is_empty() && v != "0")
}

/// The backend every default GEMM entry point uses: best supported ISA,
/// probed once per process, honoring `LOBCQ_FORCE_SCALAR=1`. The picked
/// backend is published to the metrics registry at resolution time, so
/// every `--metrics-out` snapshot and bench stamp records which ISA the
/// numbers came from.
pub fn active_backend() -> KernelBackend {
    static ACTIVE: OnceLock<KernelBackend> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let picked = detect_backend();
        use crate::util::json::Json;
        crate::obs::registry::publish(
            "kernel",
            Json::obj().with("backend", Json::Str(picked.name().into())),
        );
        picked
    })
}

fn detect_backend() -> KernelBackend {
    if force_scalar(std::env::var("LOBCQ_FORCE_SCALAR").ok().as_deref()) {
        return KernelBackend::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    if KernelBackend::Avx2.supported() {
        return KernelBackend::Avx2;
    }
    #[cfg(target_arch = "aarch64")]
    if KernelBackend::Neon.supported() {
        return KernelBackend::Neon;
    }
    KernelBackend::Scalar
}

/// Name of the active backend, for the serve summary and bench JSON.
pub fn backend_name() -> &'static str {
    active_backend().name()
}

/// One `MR × NR` register-tile update over `kc` reduction steps, routed
/// to the selected backend. `a` is the full row-major A operand with
/// leading dimension `lda`; the tile covers rows `i0 .. i0 + mr`,
/// reduction columns `k0 .. k0 + kc`, against a `kc × NR` row-major
/// `panel` of B. All backends accumulate per element as sequential
/// `acc += a * b` over ascending `k` — see the module docs for why that
/// makes them bitwise interchangeable.
#[inline]
pub(crate) fn microkernel(
    backend: KernelBackend,
    a: &[f32],
    lda: usize,
    i0: usize,
    k0: usize,
    kc: usize,
    panel: &[f32],
    acc: &mut [[f32; NR]; MR],
    mr: usize,
) {
    debug_assert!(panel.len() >= kc * NR);
    debug_assert!(mr >= 1 && mr <= MR);
    debug_assert!(kc == 0 || a.len() >= (i0 + mr - 1) * lda + k0 + kc);
    match backend {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: backend values reach the driver through `sanitize`, so
        // Avx2 implies the CPU reports avx2; bounds are checked above.
        KernelBackend::Avx2 => unsafe { avx2_microkernel(a, lda, i0, k0, kc, panel, acc, mr) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above for NEON.
        KernelBackend::Neon => unsafe { neon_microkernel(a, lda, i0, k0, kc, panel, acc, mr) },
        _ => scalar_microkernel(a, lda, i0, k0, kc, panel, acc, mr),
    }
}

/// The portable kernel (moved verbatim from `kernels::gemm`): plain
/// sequential `acc += a * b` over `k` (no `mul_add`) — f32 adds/muls are
/// exactly specified by IEEE-754, so every caller gets bitwise identical
/// results for bitwise identical panels.
#[inline]
pub(crate) fn scalar_microkernel(
    a: &[f32],
    lda: usize,
    i0: usize,
    k0: usize,
    kc: usize,
    panel: &[f32],
    acc: &mut [[f32; NR]; MR],
    mr: usize,
) {
    debug_assert!(panel.len() >= kc * NR);
    if mr == MR {
        // Fast path: constant trip counts, four rows live in registers.
        let r0 = &a[i0 * lda + k0..i0 * lda + k0 + kc];
        let r1 = &a[(i0 + 1) * lda + k0..(i0 + 1) * lda + k0 + kc];
        let r2 = &a[(i0 + 2) * lda + k0..(i0 + 2) * lda + k0 + kc];
        let r3 = &a[(i0 + 3) * lda + k0..(i0 + 3) * lda + k0 + kc];
        for (kk, b) in panel.chunks_exact(NR).take(kc).enumerate() {
            let b: &[f32; NR] = b.try_into().unwrap();
            let (a0, a1, a2, a3) = (r0[kk], r1[kk], r2[kk], r3[kk]);
            for j in 0..NR {
                acc[0][j] += a0 * b[j];
                acc[1][j] += a1 * b[j];
                acc[2][j] += a2 * b[j];
                acc[3][j] += a3 * b[j];
            }
        }
    } else {
        // Edge tile (m % MR rows): same update order, variable row count.
        for (i, acc_row) in acc.iter_mut().enumerate().take(mr) {
            let ri = &a[(i0 + i) * lda + k0..(i0 + i) * lda + k0 + kc];
            for (kk, b) in panel.chunks_exact(NR).take(kc).enumerate() {
                let ai = ri[kk];
                for j in 0..NR {
                    acc_row[j] += ai * b[j];
                }
            }
        }
    }
}

/// AVX2 tile update: each of the `mr` rows keeps its 16 accumulator
/// columns in two `__m256` registers; per `k` step the broadcast A
/// element multiplies the panel row with separate `_mm256_mul_ps` +
/// `_mm256_add_ps` (never `_mm256_fmadd_ps` — fusing would skip the
/// product rounding and break scalar parity).
///
/// # Safety
/// Caller must guarantee the CPU supports AVX2 and that the slice bounds
/// asserted in [`microkernel`] hold.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn avx2_microkernel(
    a: &[f32],
    lda: usize,
    i0: usize,
    k0: usize,
    kc: usize,
    panel: &[f32],
    acc: &mut [[f32; NR]; MR],
    mr: usize,
) {
    use std::arch::x86_64::*;
    let mut c = [[_mm256_setzero_ps(); 2]; MR];
    for i in 0..mr {
        c[i][0] = _mm256_loadu_ps(acc[i].as_ptr());
        c[i][1] = _mm256_loadu_ps(acc[i].as_ptr().add(8));
    }
    for kk in 0..kc {
        let bp = panel.as_ptr().add(kk * NR);
        let b0 = _mm256_loadu_ps(bp);
        let b1 = _mm256_loadu_ps(bp.add(8));
        for i in 0..mr {
            let ai = _mm256_set1_ps(*a.get_unchecked((i0 + i) * lda + k0 + kk));
            c[i][0] = _mm256_add_ps(c[i][0], _mm256_mul_ps(ai, b0));
            c[i][1] = _mm256_add_ps(c[i][1], _mm256_mul_ps(ai, b1));
        }
    }
    for i in 0..mr {
        _mm256_storeu_ps(acc[i].as_mut_ptr(), c[i][0]);
        _mm256_storeu_ps(acc[i].as_mut_ptr().add(8), c[i][1]);
    }
}

/// NEON tile update: four `float32x4_t` registers per row; separate
/// `vmulq_f32` + `vaddq_f32` (never `vmlaq_f32`/`vfmaq_f32`, which fuse
/// into FMLA and change rounding).
///
/// # Safety
/// Caller must guarantee the CPU supports NEON and that the slice bounds
/// asserted in [`microkernel`] hold.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn neon_microkernel(
    a: &[f32],
    lda: usize,
    i0: usize,
    k0: usize,
    kc: usize,
    panel: &[f32],
    acc: &mut [[f32; NR]; MR],
    mr: usize,
) {
    use std::arch::aarch64::*;
    let mut c = [[vdupq_n_f32(0.0); 4]; MR];
    for i in 0..mr {
        for r in 0..4 {
            c[i][r] = vld1q_f32(acc[i].as_ptr().add(4 * r));
        }
    }
    for kk in 0..kc {
        let bp = panel.as_ptr().add(kk * NR);
        let b = [vld1q_f32(bp), vld1q_f32(bp.add(4)), vld1q_f32(bp.add(8)), vld1q_f32(bp.add(12))];
        for i in 0..mr {
            let ai = vdupq_n_f32(*a.get_unchecked((i0 + i) * lda + k0 + kk));
            for r in 0..4 {
                c[i][r] = vaddq_f32(c[i][r], vmulq_f32(ai, b[r]));
            }
        }
    }
    for i in 0..mr {
        for r in 0..4 {
            vst1q_f32(acc[i].as_mut_ptr().add(4 * r), c[i][r]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KC;
    use crate::util::rng::Pcg32;

    #[test]
    fn force_scalar_env_semantics() {
        assert!(!force_scalar(None));
        assert!(!force_scalar(Some("")));
        assert!(!force_scalar(Some("0")));
        assert!(force_scalar(Some("1")));
        assert!(force_scalar(Some("true")));
    }

    #[test]
    fn active_backend_is_supported_and_named() {
        let b = active_backend();
        assert!(b.supported(), "active backend {b:?} not supported on this CPU");
        assert!(["scalar", "avx2", "neon"].contains(&backend_name()));
    }

    #[test]
    fn sanitize_keeps_scalar_and_demotes_unsupported() {
        assert_eq!(KernelBackend::Scalar.sanitize(), KernelBackend::Scalar);
        for b in [KernelBackend::Avx2, KernelBackend::Neon] {
            let s = b.sanitize();
            assert!(s.supported());
            if !b.supported() {
                assert_eq!(s, KernelBackend::Scalar);
            }
        }
    }

    #[test]
    fn simd_microkernels_bitwise_match_scalar_oracle() {
        // Every supported SIMD backend against the oracle, across edge
        // row counts, ragged kc (including kc = KC), and a nonzero
        // starting accumulator (the driver accumulates across KC blocks).
        let mut rng = Pcg32::seeded(0x51D0);
        for backend in [KernelBackend::Avx2, KernelBackend::Neon] {
            if !backend.supported() {
                continue;
            }
            for &kc in &[1usize, 2, 7, 33, 255, KC] {
                for mr in 1..=MR {
                    let lda = kc + 3; // exercise lda > kc addressing
                    let a: Vec<f32> = (0..MR * lda + kc).map(|_| rng.normal()).collect();
                    let panel: Vec<f32> = (0..kc * NR).map(|_| rng.normal()).collect();
                    let mut want = [[0.0f32; NR]; MR];
                    for row in want.iter_mut() {
                        for v in row.iter_mut() {
                            *v = rng.normal();
                        }
                    }
                    let mut got = want;
                    scalar_microkernel(&a, lda, 0, 0, kc, &panel, &mut want, mr);
                    microkernel(backend, &a, lda, 0, 0, kc, &panel, &mut got, mr);
                    for i in 0..MR {
                        for j in 0..NR {
                            assert_eq!(
                                got[i][j].to_bits(),
                                want[i][j].to_bits(),
                                "{backend:?} kc={kc} mr={mr} acc[{i}][{j}]: {} vs {}",
                                got[i][j],
                                want[i][j]
                            );
                        }
                    }
                }
            }
        }
    }
}
