//! Cache-blocked, register-tiled f32 GEMM (`C = A · B`).
//!
//! Replaces the branchy scalar triple-loop that used to live in
//! `model::forward::matmul_par`: the inner loop here is a fixed-shape
//! `MR × NR` tile update over a packed B panel — no per-element branch,
//! constant trip counts, contiguous loads. The tile update itself lives
//! in `kernels::dispatch`, which picks a hand-written AVX2/NEON
//! micro-kernel at runtime (scalar fallback kept as the parity oracle);
//! every backend follows the same per-element accumulation order, so the
//! choice never changes a bit of output.
//!
//! Layout:
//! - B is packed once into [`PackedB`] panels of `NR` columns: panel `p`
//!   stores `B[k, p·NR + j]` at `p·K·NR + k·NR + j`, zero-padding the last
//!   panel. A row of a panel is exactly the `NR` values one tile update
//!   consumes, so the micro-kernel streams it linearly.
//! - The driver walks `panel → KC-block → MR-row-tile`, accumulating an
//!   `MR × NR` register tile and adding it into C after each `KC` block.
//!   `KC · NR` floats (16 KB at the defaults) is the only working set
//!   besides the A rows, so panels stay L1/L2-resident.
//!
//! The same driver serves the encoded-domain path: [`PanelProvider`]
//! abstracts "give me the f32 panel for (columns j0.., rows k0..)" — the
//! f32 path borrows a pre-packed slice, the quantized path
//! (`kernels::qgemm`) decodes LO-BCQ codes into a scratch panel. Both run
//! the identical micro-kernel in the identical order, so encoded-domain
//! GEMM is **bit-exact** with dense GEMM over the fake-quantized weights
//! (asserted in `rust/tests/kernel_parity.rs`).
//!
//! Threading splits B's panels across `std::thread::scope` workers, each
//! computing a private column stripe that is merged at the end (C is
//! row-major, so column stripes cannot be handed out as `&mut` chunks).
//! Column-parallelism keeps panel decode work disjoint per worker on the
//! encoded path and parallelizes the `m = 1` decode shape, which
//! row-splitting cannot.

use super::dispatch::{self, KernelBackend};
use crate::tensor::Tensor;

/// Micro-kernel rows (register-tile height).
pub const MR: usize = 4;
/// Micro-kernel columns (register-tile width = packed panel width).
pub const NR: usize = 16;
/// K-dimension cache block: one panel block is `KC × NR` floats (16 KB).
pub const KC: usize = 256;

/// Problems below this many multiply-adds run single-threaded (spawn cost
/// dominates small operands; same rationale as `QuantPool::min_parallel`).
const PAR_THRESHOLD: usize = 1 << 17;

/// Source of packed B panels for the shared GEMM driver.
///
/// `panel` returns the `kc × NR` slice for panel column block `j0`
/// (a multiple of `NR`) and reduction rows `k0 .. k0 + kc`, laid out
/// row-major (`row k, then NR columns`), with columns `>= n` zero-filled.
/// Implementations either borrow from pre-packed storage ([`PackedB`]) or
/// materialize into `scratch` (the encoded-domain decoder).
pub trait PanelProvider: Sync {
    /// Reduction length (rows of B).
    fn k(&self) -> usize;
    /// Output columns (columns of B).
    fn n(&self) -> usize;
    /// The f32 panel for `(j0, k0, kc)`; `scratch` has room for `KC * NR`.
    fn panel<'a>(&'a self, j0: usize, k0: usize, kc: usize, scratch: &'a mut Vec<f32>) -> &'a [f32];
}

/// B packed into `NR`-column panels (see module docs for the layout).
#[derive(Debug, Clone, PartialEq)]
pub struct PackedB {
    k: usize,
    n: usize,
    /// `ceil(n / NR)` panels, each `k × NR`, last panel zero-padded.
    data: Vec<f32>,
}

impl PackedB {
    /// Pack a row-major `[k, n]` matrix.
    pub fn pack(b: &Tensor) -> PackedB {
        assert_eq!(b.rank(), 2, "PackedB::pack needs rank-2, got {:?}", b.shape);
        Self::pack_flat(&b.data, b.shape[0], b.shape[1])
    }

    /// Pack flat row-major `[k, n]` data.
    pub fn pack_flat(data: &[f32], k: usize, n: usize) -> PackedB {
        assert_eq!(data.len(), k * n);
        Self::pack_from(k, n, |kk, j| data[kk * n + j])
    }

    /// Pack `B = btᵀ` from a row-major `[n, k]` matrix — row `j` of `bt`
    /// becomes column `j` of B. This is how the tied LM head packs the
    /// embedding (`logits = x · embedᵀ`) without materializing a
    /// transposed copy.
    pub fn from_rows(bt: &Tensor) -> PackedB {
        assert_eq!(bt.rank(), 2, "PackedB::from_rows needs rank-2, got {:?}", bt.shape);
        Self::from_rows_flat(&bt.data, bt.shape[0], bt.shape[1])
    }

    /// [`from_rows`](Self::from_rows) over flat data: `n` rows of length
    /// `k`, each row a column of B.
    pub fn from_rows_flat(data: &[f32], n: usize, k: usize) -> PackedB {
        assert_eq!(data.len(), n * k);
        Self::pack_from(k, n, |kk, j| data[j * k + kk])
    }

    fn pack_from(k: usize, n: usize, at: impl Fn(usize, usize) -> f32) -> PackedB {
        assert!(k > 0 && n > 0, "empty B ({k} x {n})");
        let n_panels = n.div_ceil(NR);
        let mut data = vec![0.0f32; n_panels * k * NR];
        for pj in 0..n_panels {
            let base = pj * k * NR;
            let j0 = pj * NR;
            let jmax = NR.min(n - j0);
            for kk in 0..k {
                let row = &mut data[base + kk * NR..base + kk * NR + jmax];
                for (jr, slot) in row.iter_mut().enumerate() {
                    *slot = at(kk, j0 + jr);
                }
            }
        }
        PackedB { k, n, data }
    }

    /// Packed footprint in f32 elements (zero-padding included).
    pub fn packed_len(&self) -> usize {
        self.data.len()
    }
}

impl PanelProvider for PackedB {
    fn k(&self) -> usize {
        self.k
    }

    fn n(&self) -> usize {
        self.n
    }

    fn panel<'a>(&'a self, j0: usize, k0: usize, kc: usize, _scratch: &'a mut Vec<f32>) -> &'a [f32] {
        let base = (j0 / NR) * self.k * NR + k0 * NR;
        &self.data[base..base + kc * NR]
    }
}

/// Serial driver over a panel range: `out` is an `m × ldc` column stripe
/// whose first column corresponds to panel `panels.start` (so `ldc` is
/// the stripe width, `n` for a full-width call). `out` must be zeroed (or
/// hold a partial sum to accumulate onto). Every tile update runs the
/// `backend` micro-kernel (`kernels::dispatch`); all backends are
/// bitwise interchangeable by the accumulation-order contract.
#[allow(clippy::too_many_arguments)]
fn gemm_block<P: PanelProvider + ?Sized>(
    backend: KernelBackend,
    a: &[f32],
    lda: usize,
    m: usize,
    p: &P,
    panels: std::ops::Range<usize>,
    out: &mut [f32],
    ldc: usize,
    scratch: &mut Vec<f32>,
) {
    let k = p.k();
    let n = p.n();
    let col0 = panels.start * NR;
    for pj in panels {
        let j0 = pj * NR;
        let jmax = NR.min(n - j0);
        let mut k0 = 0;
        while k0 < k {
            let kc = KC.min(k - k0);
            let panel = p.panel(j0, k0, kc, scratch);
            let mut i0 = 0;
            while i0 < m {
                let mr = MR.min(m - i0);
                let mut acc = [[0.0f32; NR]; MR];
                dispatch::microkernel(backend, a, lda, i0, k0, kc, panel, &mut acc, mr);
                for (i, acc_row) in acc.iter().enumerate().take(mr) {
                    let orow = &mut out[(i0 + i) * ldc + (j0 - col0)..(i0 + i) * ldc + (j0 - col0) + jmax];
                    for (o, &v) in orow.iter_mut().zip(acc_row) {
                        *o += v;
                    }
                }
                i0 += mr;
            }
            k0 += kc;
        }
    }
}

/// `out = a [m,k] · B [k,n]` through any panel provider; `out` is
/// overwritten. The workhorse behind [`gemm`], [`gemm_packed`], and
/// `QuantLinear::qgemm` — flat-slice API so the attention loops can reuse
/// caller-owned buffers without allocating.
pub fn gemm_into_flat<P: PanelProvider + ?Sized>(a: &[f32], m: usize, k: usize, p: &P, out: &mut [f32]) {
    let mut scratch = Vec::new();
    gemm_into_flat_with(a, m, k, p, out, &mut scratch);
}

/// [`gemm_into_flat`] with a caller-owned panel-scratch buffer: the
/// serial path (every decode-shaped product) reuses `scratch` instead of
/// allocating a `KC × NR` panel buffer per call, which is what makes the
/// batched decode loop allocation-free in steady state. Problems above
/// the parallel threshold still fan out across threads (worker stripes
/// are per-call); results are bitwise identical either way. Runs the
/// runtime-detected micro-kernel ([`dispatch::active_backend`]).
pub fn gemm_into_flat_with<P: PanelProvider + ?Sized>(
    a: &[f32],
    m: usize,
    k: usize,
    p: &P,
    out: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    gemm_into_flat_with_backend(dispatch::active_backend(), a, m, k, p, out, scratch)
}

/// [`gemm_into_flat_with`] with an explicit micro-kernel backend — the
/// entry the scalar-vs-SIMD parity tests and benches pin both paths
/// through. Unsupported backends are demoted to the scalar oracle, so
/// this is safe to call with any [`KernelBackend`] on any CPU; all
/// backends are bitwise interchangeable (`tests/simd_parity.rs`).
pub fn gemm_into_flat_with_backend<P: PanelProvider + ?Sized>(
    backend: KernelBackend,
    a: &[f32],
    m: usize,
    k: usize,
    p: &P,
    out: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    let backend = backend.sanitize();
    assert_eq!(a.len(), m * k, "A is {m} x {k} but has {} elements", a.len());
    assert_eq!(k, p.k(), "inner dim mismatch: A cols {k} vs B rows {}", p.k());
    let n = p.n();
    assert_eq!(out.len(), m * n, "C is {m} x {n} but has {} elements", out.len());
    out.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let n_panels = n.div_ceil(NR);
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    if threads <= 1 || n_panels <= 1 || m * n * k < PAR_THRESHOLD {
        scratch.resize(KC * NR, 0.0);
        gemm_block(backend, a, k, m, p, 0..n_panels, out, n, scratch);
        return;
    }
    // Column-parallel: each worker owns a contiguous panel range and a
    // private stripe; stripes are merged serially below (a memcpy-speed
    // pass, negligible next to the 2mnk flops).
    let workers = threads.min(n_panels);
    let chunk = n_panels.div_ceil(workers);
    let stripes: Vec<(usize, Vec<f32>)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let p_lo = w * chunk;
                let p_hi = ((w + 1) * chunk).min(n_panels);
                s.spawn(move || {
                    if p_lo >= p_hi {
                        return (0usize, Vec::new());
                    }
                    let col0 = p_lo * NR;
                    let cols = (p_hi * NR).min(n) - col0;
                    let mut stripe = vec![0.0f32; m * cols];
                    let mut scratch = vec![0.0f32; KC * NR];
                    gemm_block(backend, a, k, m, p, p_lo..p_hi, &mut stripe, cols, &mut scratch);
                    (col0, stripe)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (col0, stripe) in stripes {
        if stripe.is_empty() {
            continue;
        }
        let cols = stripe.len() / m;
        for i in 0..m {
            out[i * n + col0..i * n + col0 + cols].copy_from_slice(&stripe[i * cols..(i + 1) * cols]);
        }
    }
}

/// Blocked GEMM against a pre-packed B: `a [m,k] · B -> [m,n]`. Leading
/// dims of `a` are folded (rank > 2 activations multiply per row, same as
/// the old `matmul_par`).
pub fn gemm_packed(a: &Tensor, b: &PackedB) -> Tensor {
    let k = a.cols();
    let m = a.len() / k;
    let mut out = vec![0.0f32; m * b.n()];
    gemm_into_flat(&a.data, m, k, b, &mut out);
    Tensor::new(&[m, b.n()], out)
}

/// One-shot blocked GEMM (packs B, then multiplies). Drop-in for the old
/// `matmul_par`; callers that reuse B should pack once and call
/// [`gemm_packed`].
pub fn gemm(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(b.rank(), 2);
    gemm_packed(a, &PackedB::pack(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn rand_tensor(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
        Tensor::from_fn(shape, |_| rng.normal())
    }

    fn assert_close(got: &Tensor, want: &Tensor, tag: &str) {
        assert_eq!(got.shape, want.shape, "{tag}: shape");
        for (i, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
            assert!(
                (g - w).abs() <= 2e-4 * (1.0 + w.abs()),
                "{tag}: element {i}: {g} vs {w}"
            );
        }
    }

    #[test]
    fn blocked_matches_reference_across_ragged_shapes() {
        let mut rng = Pcg32::seeded(0x6E77);
        // m, k, n deliberately not multiples of MR/NR/KC; m=1 = decode.
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (1, 64, 48),
            (3, 17, 5),
            (4, 16, 16),
            (7, 33, 19),
            (37, 64, 53),
            (64, 300, 21),
            (5, 257, 129),
        ] {
            let a = rand_tensor(&mut rng, &[m, k]);
            let b = rand_tensor(&mut rng, &[k, n]);
            assert_close(&gemm(&a, &b), &a.matmul(&b), &format!("{m}x{k}x{n}"));
        }
    }

    #[test]
    fn gemm_matches_scalar_matmul_reference() {
        // Ported from the old `model::forward::matmul_par` test when that
        // wrapper was removed: the one-shot kernel entry point against
        // the scalar `Tensor::matmul` reference at its historical shape.
        let mut rng = Pcg32::seeded(5);
        let a = rand_tensor(&mut rng, &[37, 64]);
        let b = rand_tensor(&mut rng, &[64, 53]);
        let serial = a.matmul(&b);
        let par = gemm(&a, &b);
        for (x, y) in serial.data.iter().zip(&par.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn from_rows_is_pack_of_transpose() {
        let mut rng = Pcg32::seeded(0x6E78);
        let bt = rand_tensor(&mut rng, &[13, 29]); // B = btᵀ is 29 x 13
        assert_eq!(PackedB::from_rows(&bt), PackedB::pack(&bt.transpose2()));
    }

    #[test]
    fn rank3_a_folds_rows() {
        let mut rng = Pcg32::seeded(0x6E79);
        let a3 = rand_tensor(&mut rng, &[2, 3, 8]);
        let b = rand_tensor(&mut rng, &[8, 5]);
        let a2 = Tensor::new(&[6, 8], a3.data.clone());
        assert_eq!(gemm(&a3, &b).data, gemm(&a2, &b).data);
    }

    #[test]
    fn parallel_equals_serial_block() {
        // Big enough to cross PAR_THRESHOLD; the column-split + merge must
        // be bitwise identical to one serial full-width pass (threading
        // never changes any element's accumulation order).
        let mut rng = Pcg32::seeded(0x6E7A);
        let (m, k, n) = (24, 130, 200);
        let a = rand_tensor(&mut rng, &[m, k]);
        let b = rand_tensor(&mut rng, &[k, n]);
        let pb = PackedB::pack(&b);
        let par = gemm_packed(&a, &pb);
        let mut serial = vec![0.0f32; m * n];
        let mut scratch = vec![0.0f32; KC * NR];
        gemm_block(dispatch::active_backend(), &a.data, k, m, &pb, 0..n.div_ceil(NR), &mut serial, n, &mut scratch);
        for (x, y) in par.data.iter().zip(&serial) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn scratch_threaded_entry_matches_and_reuses_capacity() {
        let mut rng = Pcg32::seeded(0x6E7B);
        let (m, k, n) = (4, 48, 33);
        let a = rand_tensor(&mut rng, &[m, k]);
        let b = rand_tensor(&mut rng, &[k, n]);
        let pb = PackedB::pack(&b);
        let want = gemm_packed(&a, &pb);
        let mut out = vec![0.0f32; m * n];
        let mut scratch = Vec::new();
        gemm_into_flat_with(&a.data, m, k, &pb, &mut out, &mut scratch);
        for (x, y) in out.iter().zip(&want.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // Second call must not grow the scratch buffer again.
        let cap = scratch.capacity();
        gemm_into_flat_with(&a.data, m, k, &pb, &mut out, &mut scratch);
        assert_eq!(scratch.capacity(), cap, "panel scratch reallocated on reuse");
    }

    #[test]
    #[should_panic(expected = "inner dim mismatch")]
    fn dim_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        let _ = gemm(&a, &b);
    }
}
