//! CPU GEMM kernel subsystem: the fast path every forward-pass matmul in
//! the repo goes through (DESIGN.md §Kernels).
//!
//! Two entry tiers over one shared blocked driver:
//!
//! - [`gemm`] / [`gemm_packed`] / [`gemm_into_flat`]: cache-blocked,
//!   register-tiled f32 GEMM over packed B panels ([`PackedB`]). Replaces
//!   the old scalar `matmul_par` triple-loop everywhere — projections,
//!   attention score/context products, and the tied LM head.
//! - [`QuantLinear::qgemm`]: the encoded-domain path — GEMM computed
//!   directly on packed LO-BCQ codes through per-block 16-entry value
//!   LUTs; the quantized weight never materializes as a full f32 tensor.
//!   Bit-exact with `gemm` over fake-quantized weights because both feed
//!   the identical micro-kernel (the paper's Fig. 1 dataflow: codes +
//!   tiny frozen codebooks in, scaled products out).
//!
//! The tile update itself is runtime-dispatched ([`dispatch`]): scalar
//! oracle everywhere, hand-written AVX2 (x86-64) / NEON (aarch64)
//! micro-kernels when the CPU has them, all bitwise interchangeable by
//! the accumulation-order contract. Every later backend (PJRT custom
//! calls) plugs in at the [`PanelProvider`] seam.

pub mod dispatch;
pub mod gemm;
pub mod qgemm;

pub use dispatch::{active_backend, backend_name, KernelBackend};
pub use gemm::{
    gemm, gemm_into_flat, gemm_into_flat_with, gemm_into_flat_with_backend, gemm_packed, PackedB,
    PanelProvider, KC, MR, NR,
};
pub use qgemm::QuantLinear;
