//! Encoded-domain GEMM: compute `x · W` directly on packed LO-BCQ codes.
//!
//! [`QuantLinear`] is a GEMM weight compiled to the planar encoded layout
//! (`quant::encode::PlanarCodes`) in **K-major** order: flat position
//! `p = col · k + row` for a `[k, n]` weight, i.e. each output column's
//! reduction run is contiguous — the same orientation the quantization
//! pipeline groups on (blocks decompose the reduction dimension, paper
//! A.5). The quantized weight exists only as
//!
//! - one u8 codeword index per scalar (`codes`),
//! - one u8 codebook selector per block (`sels`),
//! - one f32 *inverse* effective scale per block array (`inv_scales`,
//!   decoded once from the E4M3 codes at build time),
//!
//! ~9 bits/scalar of state versus 32 for a dequantized tensor. At GEMM
//! time the shared blocked driver (`kernels::gemm`) asks for one
//! `KC × NR` panel at a time and [`QuantLinear`] materializes it by
//! expanding each block's 4-bit codes through a 16-entry value LUT —
//! the block's codebook levels times the array's inverse scale (the
//! eq. 2/7/8 dequantization, fused) — into a 16 KB scratch buffer that
//! never leaves L1/L2. A full f32 weight tensor is never materialized.
//!
//! Because panel values are computed with exactly the operations
//! `fake_quantize` uses (`level * inv`, `0.0` for all-zero arrays) and
//! the panels then flow through the *same* micro-kernel as the f32 path,
//! `qgemm` is bit-exact with `gemm(x, fake_quantize(W))` — the W4A4
//! serving path and every eval table agree to the last bit
//! (`rust/tests/kernel_parity.rs`).

use super::gemm::{gemm_into_flat, PanelProvider, NR};
use crate::quant::codebook::CodebookFamily;
use crate::quant::encode::{encode_planar, EncodedTensor, PlanarCodes};
use crate::quant::lobcq::LobcqConfig;
use crate::tensor::Tensor;

/// A `[k, n]` GEMM weight held entirely in encoded form (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantLinear {
    k: usize,
    n: usize,
    cfg: LobcqConfig,
    family: CodebookFamily,
    /// One codeword index per scalar, K-major (`p = col * k + row`).
    codes: Vec<u8>,
    /// One codebook selector per block (`p / L_b`).
    sels: Vec<u8>,
    /// Effective inverse scale per block array (`p / L_A`); 0.0 for
    /// all-zero arrays (the eq. 7 degenerate case — decodes to exact 0).
    inv_scales: Vec<f32>,
}

impl QuantLinear {
    /// Encode a K-major gathered weight (`kmajor[c*k + r] = W[r, c]`).
    pub fn from_kmajor(
        kmajor: &[f32],
        k: usize,
        n: usize,
        cfg: LobcqConfig,
        family: &CodebookFamily,
    ) -> anyhow::Result<QuantLinear> {
        cfg.validate()?;
        anyhow::ensure!(kmajor.len() == k * n, "kmajor len {} != {k} x {n}", kmajor.len());
        anyhow::ensure!(
            kmajor.len() % cfg.la == 0,
            "weight size {} not a multiple of L_A {}",
            kmajor.len(),
            cfg.la
        );
        let planar = encode_planar(kmajor, &cfg, family);
        Ok(Self::from_planar(planar, k, n, cfg, family.clone()))
    }

    /// Rehydrate from a wire-format artifact whose shape is the K-major
    /// gathered view `[n, k]` (row `c` = column `c` of the `[k, n]`
    /// GEMM weight).
    pub fn from_encoded(enc: &EncodedTensor, family: &CodebookFamily) -> anyhow::Result<QuantLinear> {
        anyhow::ensure!(enc.shape.len() == 2, "expected K-major [n, k] shape, got {:?}", enc.shape);
        anyhow::ensure!(family.nc() == enc.cfg.nc, "family Nc {} != cfg Nc {}", family.nc(), enc.cfg.nc);
        anyhow::ensure!(family.b == enc.cfg.b, "family B {} != cfg B {}", family.b, enc.cfg.b);
        let (n, k) = (enc.shape[0], enc.shape[1]);
        Ok(Self::from_planar(enc.to_planar(), k, n, enc.cfg, family.clone()))
    }

    fn from_planar(planar: PlanarCodes, k: usize, n: usize, cfg: LobcqConfig, family: CodebookFamily) -> QuantLinear {
        // Decode each array's effective scale exactly the way
        // `encode::decode` / `quantize_arrays_into` do, so panel values
        // match the fake-quantize path bit-for-bit.
        let inv_scales = planar
            .scale_codes
            .iter()
            .map(|&c| {
                let rel = cfg.scale_format.decode_bits(c as u16);
                let eff = rel * planar.s_x;
                if eff != 0.0 {
                    1.0 / eff
                } else {
                    0.0
                }
            })
            .collect();
        QuantLinear {
            k,
            n,
            cfg,
            family,
            codes: planar.codes,
            sels: planar.selectors,
            inv_scales,
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.k, self.n)
    }

    pub fn cfg(&self) -> &LobcqConfig {
        &self.cfg
    }

    /// Encoded-state bytes (codes + selectors + scales) — what actually
    /// sits in memory instead of `4 * k * n` for a dense f32 weight.
    pub fn state_bytes(&self) -> usize {
        self.codes.len() + self.sels.len() + self.inv_scales.len() * 4
    }

    /// `x [m,k] · W [k,n] -> [m,n]` computed straight from the codes via
    /// the shared blocked driver. Leading dims of `x` are folded.
    pub fn qgemm(&self, x: &Tensor) -> Tensor {
        let k = x.cols();
        let m = x.len() / k;
        let mut out = vec![0.0f32; m * self.n];
        gemm_into_flat(&x.data, m, k, self, &mut out);
        Tensor::new(&[m, self.n], out)
    }

    /// Flat-slice [`qgemm`](Self::qgemm) with caller-owned output and
    /// panel-decode scratch — the batched decode loop's entry point
    /// (`out` must hold `m * n` elements). Bitwise identical to `qgemm`.
    pub fn qgemm_into(&self, x: &[f32], m: usize, out: &mut [f32], scratch: &mut Vec<f32>) {
        crate::kernels::gemm::gemm_into_flat_with(x, m, self.k, self, out, scratch);
    }
}

impl PanelProvider for QuantLinear {
    fn k(&self) -> usize {
        self.k
    }

    fn n(&self) -> usize {
        self.n
    }

    /// Decode the `(j0, k0, kc)` panel: for each of the NR columns, walk
    /// the contiguous K-major code segment block by block, refresh the
    /// 16-entry scaled LUT at block boundaries, and gather values at
    /// panel stride. Cost is one LUT build (≤ 16 muls) per `L_b` scalars
    /// plus one table load per scalar, amortized over every A row that
    /// reuses the panel.
    fn panel<'a>(&'a self, j0: usize, k0: usize, kc: usize, scratch: &'a mut Vec<f32>) -> &'a [f32] {
        let lb = self.cfg.lb;
        let la = self.cfg.la;
        scratch.resize(kc * NR, 0.0);
        for jr in 0..NR {
            let j = j0 + jr;
            if j >= self.n {
                // Zero-pad columns past the edge (matches PackedB).
                for kk in 0..kc {
                    scratch[kk * NR + jr] = 0.0;
                }
                continue;
            }
            let mut p = j * self.k + k0; // flat K-major position
            let end = p + kc;
            let mut kk = 0usize;
            while p < end {
                // One block-aligned segment: selector and array scale are
                // constant across it (L_A is a multiple of L_b).
                let seg_end = end.min((p / lb + 1) * lb);
                let inv = self.inv_scales[p / la];
                if inv == 0.0 {
                    // All-zero block array: exact +0.0, like fake_quantize.
                    for _ in p..seg_end {
                        scratch[kk * NR + jr] = 0.0;
                        kk += 1;
                    }
                } else {
                    let levels = &self.family.books[self.sels[p / lb] as usize].levels;
                    let mut lut = [0.0f32; 16];
                    for (slot, &lv) in lut.iter_mut().zip(levels) {
                        *slot = lv * inv;
                    }
                    for q in p..seg_end {
                        scratch[kk * NR + jr] = lut[(self.codes[q] & 15) as usize];
                        kk += 1;
                    }
                }
                p = seg_end;
            }
        }
        &scratch[..kc * NR]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::{gemm_packed, PackedB};
    use crate::quant::encode::encode;
    use crate::quant::lobcq::{calibrate_tensors, fake_quantize, CalibOpts};
    use crate::util::rng::{llm_like_sample, Pcg32};

    /// Random K-major weight + calibrated INT-B_c family.
    fn setup(seed: u64, cfg: &LobcqConfig, k: usize, n: usize) -> (Vec<f32>, CodebookFamily) {
        let mut rng = Pcg32::seeded(seed);
        let kmajor = llm_like_sample(&mut rng, k * n, 0.05, 4.0);
        let t = Tensor::new(&[k * n / cfg.la, cfg.la], kmajor.clone());
        let calib = calibrate_tensors(&[&t], cfg, CalibOpts { max_iters: 10, ..CalibOpts::default() }, &mut rng);
        (kmajor, calib.family.quantize_codewords(cfg.bc))
    }

    /// Dense reference: fake-quantize the K-major buffer, scatter to the
    /// `[k, n]` orientation, run the f32 blocked path.
    fn dense_reference(kmajor: &[f32], k: usize, n: usize, cfg: &LobcqConfig, fam: &CodebookFamily, x: &Tensor) -> Tensor {
        let fq = fake_quantize(kmajor, cfg, fam);
        let mut w = Tensor::zeros(&[k, n]);
        for c in 0..n {
            for r in 0..k {
                w.data[r * n + c] = fq[c * k + r];
            }
        }
        gemm_packed(x, &PackedB::pack(&w))
    }

    #[test]
    fn qgemm_bitexact_with_dense_fakequant_path() {
        let cfg = LobcqConfig::new(8, 8, 64);
        let (k, n) = (128, 96);
        let (kmajor, fam) = setup(0x96E1, &cfg, k, n);
        let ql = QuantLinear::from_kmajor(&kmajor, k, n, cfg, &fam).unwrap();
        let mut rng = Pcg32::seeded(0x96E2);
        for m in [1usize, 7, 33] {
            let x = Tensor::from_fn(&[m, k], |_| rng.normal());
            let got = ql.qgemm(&x);
            let want = dense_reference(&kmajor, k, n, &cfg, &fam, &x);
            assert_eq!(got.shape, want.shape);
            for (i, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "m={m}, element {i}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn qgemm_handles_ragged_n_and_column_straddling_arrays() {
        // k = 32 < L_A = 64: block arrays straddle column boundaries in
        // the K-major stream (exactly what the tiny test model produces);
        // n = 50 is not a multiple of NR.
        let cfg = LobcqConfig::new(8, 4, 64);
        let (k, n) = (32, 50);
        let (kmajor, fam) = setup(0x96E3, &cfg, k, n);
        let ql = QuantLinear::from_kmajor(&kmajor, k, n, cfg, &fam).unwrap();
        let mut rng = Pcg32::seeded(0x96E4);
        let x = Tensor::from_fn(&[5, k], |_| rng.normal());
        let got = ql.qgemm(&x);
        let want = dense_reference(&kmajor, k, n, &cfg, &fam, &x);
        for (g, w) in got.data.iter().zip(&want.data) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    #[test]
    fn zero_arrays_decode_to_exact_zero_products() {
        let cfg = LobcqConfig::new(8, 2, 64);
        let (k, n) = (64, 16);
        let (mut kmajor, fam) = setup(0x96E5, &cfg, k, n);
        kmajor[..cfg.la].fill(0.0); // first array (column 0) all-zero
        let ql = QuantLinear::from_kmajor(&kmajor, k, n, cfg, &fam).unwrap();
        let x = Tensor::new(&[1, k], vec![1.0; k]);
        let got = ql.qgemm(&x);
        assert_eq!(got.data[0].to_bits(), 0.0f32.to_bits(), "zero column leaked {}", got.data[0]);
    }

    #[test]
    fn from_encoded_round_trips() {
        let cfg = LobcqConfig::new(8, 8, 64);
        let (k, n) = (64, 32);
        let (kmajor, fam) = setup(0x96E6, &cfg, k, n);
        let enc = encode(&kmajor, &[n, k], &cfg, &fam);
        let a = QuantLinear::from_kmajor(&kmajor, k, n, cfg, &fam).unwrap();
        let b = QuantLinear::from_encoded(&enc, &fam).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn state_is_many_times_smaller_than_f32() {
        let cfg = LobcqConfig::new(8, 8, 64);
        let (k, n) = (128, 128);
        let (kmajor, fam) = setup(0x96E7, &cfg, k, n);
        let ql = QuantLinear::from_kmajor(&kmajor, k, n, cfg, &fam).unwrap();
        assert!(
            (ql.state_bytes() as f64) < (4 * k * n) as f64 / 2.5,
            "encoded state {} bytes vs dense {}",
            ql.state_bytes(),
            4 * k * n
        );
    }
}
