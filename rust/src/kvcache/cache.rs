//! The paged KV cache: per-sequence slots mapping (layer, head, token
//! range) to pool pages.
//!
//! Lifecycle: a decode session [`alloc_slot`](PagedKvCache::alloc_slot)s
//! one slot per admitted request, [`append`](PagedKvCache::append)s one
//! K/V row per layer per token (prefill appends the whole prompt, each
//! decode step appends one token), attention
//! [`gather`](PagedKvCache::gather)s a head's contiguous `[len,
//! head_dim]` history, and [`free_slot`](PagedKvCache::free_slot) returns
//! every page to the pool's free list the moment the request finishes —
//! which is what lets the continuous batcher backfill a new request into
//! the freed slot mid-batch.
//!
//! Cross-request reuse (DESIGN.md §Prefix cache): a new slot can
//! [`adopt_prefix`](PagedKvCache::adopt_prefix) pages already holding
//! its prompt's prefix — shared full pages are pinned by refcount,
//! a divergence inside a page is copied-on-write — and on release
//! [`full_page_groups`](PagedKvCache::full_page_groups) hands the
//! slot's whole pages to the prefix tree instead of dropping them.
//!
//! Storage is either exact f32 ("KV16"-style reference) or LO-BCQ
//! encoded ("KV4", ~4.9 bits/scalar at head_dim 64) — see
//! [`KvQuantizer`](super::quant::KvQuantizer) for the format.

use super::pool::{PageId, PagePool, Plane};
use super::quant::KvQuantizer;

/// Index of a live sequence slot.
pub type SlotId = usize;

/// Cache geometry, derived from the model config + serving shape.
#[derive(Debug, Clone)]
pub struct KvLayout {
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    /// Tokens per page.
    pub page_tokens: usize,
    /// Per-sequence token capacity (the model's position-table limit).
    pub max_tokens: usize,
    /// Concurrent sequences (lanes) the cache serves.
    pub max_slots: usize,
}

impl KvLayout {
    pub fn for_model(cfg: &crate::model::ModelConfig, page_tokens: usize, max_slots: usize) -> KvLayout {
        KvLayout {
            n_layers: cfg.n_layers,
            n_heads: cfg.n_heads,
            head_dim: cfg.head_dim(),
            page_tokens,
            max_tokens: cfg.max_t,
            max_slots,
        }
    }

    fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.n_layers >= 1 && self.n_heads >= 1 && self.head_dim >= 1, "degenerate layout");
        anyhow::ensure!(self.page_tokens >= 1, "page_tokens must be >= 1");
        anyhow::ensure!(self.max_tokens >= 1, "max_tokens must be >= 1");
        anyhow::ensure!(self.max_slots >= 1, "max_slots must be >= 1");
        Ok(())
    }
}

/// Occupancy snapshot of the paged cache: pages in use, their
/// high-water mark, and cached-state bytes — the numbers the serving
/// metrics surface so capacity planning can see the page working set
/// (`coordinator::metrics` records one per decode step).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvStats {
    pub live_slots: usize,
    pub pages_in_use: usize,
    /// High-water mark of `pages_in_use` over the cache's lifetime.
    pub pages_peak: usize,
    /// Pages ever allocated (the pool never shrinks).
    pub pages_capacity: usize,
    /// Page-capacity budget (`None` = unbounded).
    pub pages_budget: Option<usize>,
    pub state_bytes: usize,
    pub peak_bytes: usize,
}

/// Storage mode for cached K/V.
pub enum KvStore {
    /// Exact f32 (32 bits/scalar) — the parity reference.
    F32,
    /// LO-BCQ encoded pages.
    Encoded(KvQuantizer),
}

#[derive(Debug, Default)]
struct SlotState {
    live: bool,
    /// Tokens appended per layer (all equal between whole tokens; they
    /// drift by one transiently while a token's layers are processed).
    lens: Vec<usize>,
    /// Page table per layer: `pages[layer][page_idx * n_heads + head]`.
    pages: Vec<Vec<PageId>>,
}

/// Paged, optionally BCQ-encoded KV cache (see module docs).
pub struct PagedKvCache {
    layout: KvLayout,
    quant: Option<KvQuantizer>,
    pool: PagePool,
    slots: Vec<SlotState>,
    free_slots: Vec<SlotId>,
    /// Running total of live-page state bytes, maintained incrementally
    /// on append/claim/free so [`state_bytes`](Self::state_bytes) — and
    /// the per-step metrics snapshot built on it — is O(1) instead of a
    /// walk over every page of every live slot. Debug builds cross-check
    /// it against the full walk.
    cached_bytes: usize,
    peak_bytes: usize,
}

impl PagedKvCache {
    pub fn new(layout: KvLayout, store: KvStore) -> anyhow::Result<PagedKvCache> {
        layout.validate()?;
        let quant = match store {
            KvStore::F32 => None,
            KvStore::Encoded(q) => {
                anyhow::ensure!(
                    q.head_dim() == layout.head_dim,
                    "KV quantizer head_dim {} != layout head_dim {}",
                    q.head_dim(),
                    layout.head_dim
                );
                Some(q)
            }
        };
        let pool = PagePool::new(layout.page_tokens, layout.head_dim, quant.is_some());
        let slots = (0..layout.max_slots).map(|_| SlotState::default()).collect();
        let free_slots = (0..layout.max_slots).rev().collect();
        Ok(PagedKvCache { layout, quant, pool, slots, free_slots, cached_bytes: 0, peak_bytes: 0 })
    }

    pub fn layout(&self) -> &KvLayout {
        &self.layout
    }

    /// "KV16 (f32 pages)" / "KV4 (BCQ-encoded pages, x.xx bits/scalar)".
    pub fn store_name(&self) -> String {
        match &self.quant {
            None => "KV16 (f32 pages)".into(),
            Some(q) => format!("KV4 (BCQ-encoded pages, {:.2} bits/scalar)", q.bits_per_scalar()),
        }
    }

    /// Stored bits per cached scalar (32 for f32 pages).
    pub fn bits_per_scalar(&self) -> f64 {
        self.quant.as_ref().map(|q| q.bits_per_scalar()).unwrap_or(32.0)
    }

    pub fn free_slot_count(&self) -> usize {
        self.free_slots.len()
    }

    pub fn live_slot_count(&self) -> usize {
        self.layout.max_slots - self.free_slots.len()
    }

    /// Claim a slot for a new sequence. Errors when every lane is live —
    /// the scheduler checks [`free_slot_count`](Self::free_slot_count)
    /// before admitting, so this firing means a bookkeeping bug.
    pub fn alloc_slot(&mut self) -> anyhow::Result<SlotId> {
        let id = self.free_slots.pop().ok_or_else(|| {
            anyhow::anyhow!("no free KV slots ({} live)", self.layout.max_slots)
        })?;
        let st = &mut self.slots[id];
        st.live = true;
        st.lens = vec![0; self.layout.n_layers];
        st.pages = vec![Vec::new(); self.layout.n_layers];
        Ok(id)
    }

    /// Release a slot, dropping one reference on every page it holds
    /// (exclusively-owned pages return to the free list; pages shared
    /// with the prefix tree or other slots survive until their last
    /// holder lets go). Tolerates double-free and out-of-range ids
    /// (no-op on a dead slot).
    pub fn free_slot(&mut self, slot: SlotId) {
        if !self.is_live(slot) {
            return;
        }
        // Cached bytes only ever shrink here, so sampling the high-water
        // mark once per release (plus on query) captures the true peak
        // without walking the pages on the per-token append path.
        self.peak_bytes = self.peak_bytes.max(self.state_bytes());
        let st = &mut self.slots[slot];
        st.live = false;
        for layer_pages in st.pages.iter() {
            for &p in layer_pages {
                self.cached_bytes -= self.pool.get(p).state_bytes();
                self.pool.free(p);
            }
        }
        st.pages.clear();
        st.lens.clear();
        self.free_slots.push(slot);
    }

    /// Whether `slot` currently holds a live sequence (out-of-range ids
    /// are simply not live) — the graceful pre-check batched callers use
    /// where the accessors below assert.
    pub fn is_live(&self, slot: SlotId) -> bool {
        self.slots.get(slot).map(|s| s.live).unwrap_or(false)
    }

    /// Tokens cached for `slot` (valid between whole tokens; during a
    /// token's layer sweep the per-layer counters transiently differ).
    pub fn seq_len(&self, slot: SlotId) -> usize {
        let st = &self.slots[slot];
        assert!(st.live, "seq_len of a dead slot");
        st.lens.last().copied().unwrap_or(0)
    }

    /// Append one token's K and V rows (`d = n_heads * head_dim` floats
    /// each) for `layer`. Returns the layer's new token count — the
    /// attention span for this layer's gather.
    pub fn append(&mut self, slot: SlotId, layer: usize, k_row: &[f32], v_row: &[f32]) -> anyhow::Result<usize> {
        let (nh, hd, pt) = (self.layout.n_heads, self.layout.head_dim, self.layout.page_tokens);
        anyhow::ensure!(layer < self.layout.n_layers, "layer {layer} out of range");
        anyhow::ensure!(k_row.len() == nh * hd && v_row.len() == nh * hd, "K/V row length != n_heads * head_dim");
        {
            let st = &self.slots[slot];
            anyhow::ensure!(st.live, "append to dead slot {slot}");
            anyhow::ensure!(
                st.lens[layer] < self.layout.max_tokens,
                "slot {slot} full ({} tokens)",
                self.layout.max_tokens
            );
        }
        let pos = self.slots[slot].lens[layer];
        if pos % pt == 0 {
            // Page boundary: claim one fresh page per head. Check the
            // whole head group against the pool budget **before** the
            // first allocation, so a shortfall surfaces as a typed
            // KvPressure error with the cache untouched (a partial head
            // group would corrupt the slot's page table).
            self.pool.ensure_headroom(nh)?;
            for _ in 0..nh {
                let id = self.pool.alloc();
                // f32 pages carry their full pre-sized storage from the
                // moment they are claimed; encoded pages start at 0.
                self.cached_bytes += self.pool.get(id).state_bytes();
                self.slots[slot].pages[layer].push(id);
            }
        }
        let page_base = (pos / pt) * nh;
        for head in 0..nh {
            let id = self.slots[slot].pages[layer][page_base + head];
            let o = head * hd;
            let quant = self.quant.as_ref();
            let page = self.pool.get_mut(id);
            let before = page.state_bytes();
            page.append(pt, hd, quant, &k_row[o..o + hd], &v_row[o..o + hd]);
            self.cached_bytes += page.state_bytes() - before;
        }
        self.slots[slot].lens[layer] = pos + 1;
        Ok(pos + 1)
    }

    /// Roll `slot` back to its first `len` tokens — the KV-rollback
    /// primitive behind speculative decoding's reject path. Whole
    /// now-empty tail pages return to the pool's free list; a partially
    /// emptied boundary page is truncated **in place**, which routes
    /// through [`PagePool::get_mut`] and therefore bumps the page's
    /// generation — any decode-panel cache entry for it revalidates and
    /// re-decodes on next use. Per-slot token bookkeeping (`seq_len`,
    /// `full_page_groups`) reflects the rolled-back length immediately,
    /// so a later prefix-cache publish never sees rejected tokens.
    ///
    /// Only legal between whole tokens (every layer at the same length).
    /// Freed tail pages may be shared (the slot just drops its
    /// reference), but an in-place boundary rewrite needs exclusive
    /// ownership — truncating into a page another holder can read is
    /// refused with the cache untouched. In practice speculative appends
    /// land strictly after any adopted prefix, so rollback (which never
    /// goes below the pre-step position) only ever touches slot-owned
    /// tail pages.
    pub fn truncate(&mut self, slot: SlotId, len: usize) -> anyhow::Result<usize> {
        anyhow::ensure!(self.is_live(slot), "truncate of a dead slot {slot}");
        let cur = self.slots[slot].lens.last().copied().unwrap_or(0);
        anyhow::ensure!(
            self.slots[slot].lens.iter().all(|&l| l == cur),
            "truncate of slot {slot} mid-token (ragged per-layer lengths)"
        );
        anyhow::ensure!(len >= 1, "truncate to zero tokens (free the slot instead)");
        anyhow::ensure!(len <= cur, "truncate of slot {slot} to {len} of {cur} cached tokens");
        if len == cur {
            return Ok(len);
        }
        let (nl, nh, pt) = (self.layout.n_layers, self.layout.n_heads, self.layout.page_tokens);
        let keep_pages = len.div_ceil(pt);
        let boundary = len % pt; // tokens kept in the last page when nonzero
        // Validate exclusivity of every boundary page that must be
        // rewritten before mutating anything, so a refusal is atomic.
        if boundary != 0 {
            for layer_pages in self.slots[slot].pages.iter() {
                for &id in &layer_pages[(keep_pages - 1) * nh..keep_pages * nh] {
                    if self.pool.get(id).filled > boundary {
                        anyhow::ensure!(
                            !self.pool.is_shared(id),
                            "truncate into shared page {id} (adopted prefix is immutable)"
                        );
                    }
                }
            }
        }
        // Bytes only shrink from here: sample the high-water mark first,
        // exactly as free_slot does.
        self.peak_bytes = self.peak_bytes.max(self.state_bytes());
        for layer in 0..nl {
            while self.slots[slot].pages[layer].len() > keep_pages * nh {
                let id = self.slots[slot].pages[layer].pop().unwrap();
                self.cached_bytes -= self.pool.get(id).state_bytes();
                self.pool.free(id);
            }
            if boundary != 0 {
                for head in 0..nh {
                    let id = self.slots[slot].pages[layer][(keep_pages - 1) * nh + head];
                    if self.pool.get(id).filled > boundary {
                        let quant = self.quant.as_ref();
                        let page = self.pool.get_mut(id);
                        let before = page.state_bytes();
                        page.truncate_to(boundary, quant);
                        self.cached_bytes -= before - page.state_bytes();
                    }
                }
            }
            self.slots[slot].lens[layer] = len;
        }
        Ok(len)
    }

    /// Multi-slot append for one fused decode step: row `i` of the
    /// stacked row-major `rows` buffer (`stride` floats per row) carries
    /// lane `i`'s K head vectors at `[k_off, k_off + d)` and V at
    /// `[v_off, v_off + d)`, `d = n_heads * head_dim` — exactly the
    /// layout of a batched QKV projection output, so the decode loop
    /// appends straight from the GEMM result with no staging copy.
    /// Validates **every** lane (live, distinct, within capacity, row in
    /// bounds) before mutating anything: a failed call leaves the cache
    /// untouched, which is what lets the batched engine keep per-lane
    /// error isolation.
    pub fn append_batch(
        &mut self,
        slots: &[SlotId],
        layer: usize,
        rows: &[f32],
        stride: usize,
        k_off: usize,
        v_off: usize,
    ) -> anyhow::Result<()> {
        let d = self.layout.n_heads * self.layout.head_dim;
        anyhow::ensure!(layer < self.layout.n_layers, "layer {layer} out of range");
        anyhow::ensure!(k_off + d <= stride && v_off + d <= stride, "K/V offsets past row stride {stride}");
        anyhow::ensure!(rows.len() >= slots.len() * stride, "rows buffer shorter than {} lanes", slots.len());
        for (i, &slot) in slots.iter().enumerate() {
            anyhow::ensure!(self.is_live(slot), "append to dead slot {slot}");
            anyhow::ensure!(
                self.slots[slot].lens[layer] < self.layout.max_tokens,
                "slot {slot} full ({} tokens)",
                self.layout.max_tokens
            );
            anyhow::ensure!(
                !slots[..i].contains(&slot),
                "slot {slot} appears twice in one batched append"
            );
        }
        // Page-budget pre-check: every lane sitting at a page boundary
        // claims one fresh page per head. Validating the sum before the
        // first append keeps the call atomic under KV pressure — a
        // shortfall fails with a typed KvPressure error and an untouched
        // cache instead of a half-appended step.
        let pt = self.layout.page_tokens;
        let boundary_lanes =
            slots.iter().filter(|&&s| self.slots[s].lens[layer] % pt == 0).count();
        self.pool.ensure_headroom(boundary_lanes * self.layout.n_heads)?;
        for (i, &slot) in slots.iter().enumerate() {
            let row = &rows[i * stride..(i + 1) * stride];
            self.append(slot, layer, &row[k_off..k_off + d], &row[v_off..v_off + d])?;
        }
        Ok(())
    }

    /// The one page-table walk every gather flavour shares: visits each
    /// page of (slot, layer, head) covering the layer's cached history
    /// in order, handing the visitor the page plus the token range it
    /// contributes (`done..done + take`). Keeping the walk in one place
    /// means the single-plane and both-planes gathers cannot drift on
    /// page-boundary arithmetic.
    fn walk_pages(
        &self,
        slot: SlotId,
        layer: usize,
        head: usize,
        mut visit: impl FnMut(&super::pool::Page, usize, usize),
    ) -> usize {
        let (nh, pt) = (self.layout.n_heads, self.layout.page_tokens);
        let st = &self.slots[slot];
        assert!(st.live, "gather from dead slot {slot}");
        let len = st.lens[layer];
        let mut done = 0usize;
        let mut page_idx = 0usize;
        while done < len {
            let id = st.pages[layer][page_idx * nh + head];
            let page = self.pool.get(id);
            let take = page.filled.min(len - done);
            debug_assert_eq!(take, page.filled.min(pt));
            visit(page, done, take);
            done += take;
            page_idx += 1;
        }
        len
    }

    /// Decode the full cached history of one (slot, layer, head, plane)
    /// into `out` as a contiguous `[len, head_dim]` matrix (resized to
    /// fit). Returns `len`. f32 pages copy; encoded pages decode through
    /// the 16-entry codebook LUTs.
    pub fn gather(&self, slot: SlotId, layer: usize, head: usize, plane: Plane, out: &mut Vec<f32>) -> usize {
        let hd = self.layout.head_dim;
        let st = &self.slots[slot];
        assert!(st.live, "gather from dead slot {slot}");
        out.resize(st.lens[layer] * hd, 0.0);
        let quant = self.quant.as_ref();
        self.walk_pages(slot, layer, head, |page, done, take| {
            page.gather(hd, quant, plane, &mut out[done * hd..(done + take) * hd]);
        })
    }

    /// Gather **both planes** of one (slot, layer, head) in a single
    /// page-table walk: `k_out` and `v_out` are resized to the
    /// contiguous `[len, head_dim]` history. Returns `len`. Bitwise
    /// identical to two [`gather`](Self::gather) calls — the batched
    /// decode path uses it to halve the page lookups per head per step.
    pub fn gather_kv(
        &self,
        slot: SlotId,
        layer: usize,
        head: usize,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    ) -> usize {
        let hd = self.layout.head_dim;
        let st = &self.slots[slot];
        assert!(st.live, "gather from dead slot {slot}");
        k_out.resize(st.lens[layer] * hd, 0.0);
        v_out.resize(st.lens[layer] * hd, 0.0);
        let quant = self.quant.as_ref();
        self.walk_pages(slot, layer, head, |page, done, take| {
            page.gather(hd, quant, Plane::K, &mut k_out[done * hd..(done + take) * hd]);
            page.gather(hd, quant, Plane::V, &mut v_out[done * hd..(done + take) * hd]);
        })
    }

    /// Page ids owned by a slot (aliasing introspection for tests and
    /// debugging; order is layer-major then page-major then head).
    pub fn page_ids(&self, slot: SlotId) -> Vec<PageId> {
        let st = &self.slots[slot];
        assert!(st.live, "page_ids of a dead slot");
        st.pages.iter().flat_map(|ps| ps.iter().copied()).collect()
    }

    /// Pin an already-cached token prefix into a freshly-allocated empty
    /// slot (the prefix cache's admission-time hit path). `full` holds
    /// one **page group** per fully-matched page of tokens — `n_layers *
    /// n_heads` pool page ids, layer-major then head — and `partial`
    /// optionally names the group and token count of a divergence
    /// *inside* a page (the request shares only the first `m <
    /// page_tokens` tokens of that page).
    ///
    /// Fully-matched pages are **shared**: each gets one more pool
    /// reference and is never written through this slot (it is full, and
    /// appends only ever touch the last, non-full page). The partial
    /// group is **copy-on-write**: each page's first `m` vectors are
    /// copied bit-exactly into a fresh exclusively-owned page the slot
    /// can keep appending into. On success the slot reads as holding
    /// `full.len() * page_tokens + m` tokens and `prefill_from` computes
    /// only the suffix. Validates everything before mutating; on error
    /// the caller frees the slot, which releases any references already
    /// taken.
    pub fn adopt_prefix(
        &mut self,
        slot: SlotId,
        full: &[Vec<PageId>],
        partial: Option<(&[PageId], usize)>,
    ) -> anyhow::Result<()> {
        let (nl, nh, pt) = (self.layout.n_layers, self.layout.n_heads, self.layout.page_tokens);
        let group = nl * nh;
        anyhow::ensure!(self.is_live(slot), "adopt into dead slot {slot}");
        anyhow::ensure!(
            self.slots[slot].lens.iter().all(|&l| l == 0),
            "adopt into a non-empty slot {slot}"
        );
        let m_extra = match partial {
            Some((g, m)) => {
                anyhow::ensure!(g.len() == group, "partial group has {} pages, layout needs {group}", g.len());
                anyhow::ensure!(m >= 1 && m < pt, "partial adoption of {m} tokens in a {pt}-token page");
                for &id in g {
                    anyhow::ensure!(
                        self.pool.get(id).filled >= m,
                        "partial source page {id} holds {} tokens, need {m}",
                        self.pool.get(id).filled
                    );
                }
                m
            }
            None => 0,
        };
        for g in full {
            anyhow::ensure!(g.len() == group, "page group has {} pages, layout needs {group}", g.len());
            for &id in g {
                anyhow::ensure!(
                    self.pool.get(id).filled == pt,
                    "adopted page {id} holds {} tokens, not a full page",
                    self.pool.get(id).filled
                );
            }
        }
        let total = full.len() * pt + m_extra;
        anyhow::ensure!(total >= 1, "adopting an empty prefix");
        anyhow::ensure!(total <= self.layout.max_tokens, "adopted prefix {total} > slot capacity {}", self.layout.max_tokens);
        // Shared pages cost no headroom (retain only bumps a refcount),
        // but the copy-on-write group claims one fresh page per (layer,
        // head). Pre-check it with the rest of the validation so a
        // budget shortfall rejects the adoption before any retain.
        if partial.is_some() {
            self.pool.ensure_headroom(group)?;
        }

        for g in full {
            for layer in 0..nl {
                for head in 0..nh {
                    let id = g[layer * nh + head];
                    self.pool.retain(id);
                    self.cached_bytes += self.pool.get(id).state_bytes();
                    self.slots[slot].pages[layer].push(id);
                }
            }
        }
        if let Some((g, m)) = partial {
            for layer in 0..nl {
                for head in 0..nh {
                    let src = g[layer * nh + head];
                    let dst = self.pool.alloc();
                    self.pool.copy_prefix(src, dst, m, self.quant.as_ref());
                    self.cached_bytes += self.pool.get(dst).state_bytes();
                    self.slots[slot].pages[layer].push(dst);
                }
            }
        }
        for l in self.slots[slot].lens.iter_mut() {
            *l = total;
        }
        Ok(())
    }

    /// Page groups of the slot's fully-filled page chunks, in prefix
    /// order — what the prefix tree ingests when the slot is released.
    /// Group `c` covers tokens `[c * page_tokens, (c+1) * page_tokens)`
    /// and lists `n_layers * n_heads` page ids (layer-major then head),
    /// mirroring [`adopt_prefix`](Self::adopt_prefix)'s expectation. A
    /// slot caught mid-token (per-layer lengths ragged after a failed
    /// append) publishes nothing.
    pub fn full_page_groups(&self, slot: SlotId) -> Vec<Vec<PageId>> {
        let (nl, nh, pt) = (self.layout.n_layers, self.layout.n_heads, self.layout.page_tokens);
        let st = &self.slots[slot];
        assert!(st.live, "page groups of a dead slot");
        let len = st.lens.last().copied().unwrap_or(0);
        if st.lens.iter().any(|&l| l != len) {
            return Vec::new();
        }
        let chunks = len / pt;
        let mut out = Vec::with_capacity(chunks);
        for c in 0..chunks {
            let mut g = Vec::with_capacity(nl * nh);
            for layer in 0..nl {
                for head in 0..nh {
                    g.push(st.pages[layer][c * nh + head]);
                }
            }
            out.push(g);
        }
        out
    }

    /// The underlying page pool — read access for refcount inspection.
    pub fn pool(&self) -> &PagePool {
        &self.pool
    }

    /// Mutable pool access for the prefix tree's retain/release
    /// bookkeeping (publish and eviction). The tree only adjusts
    /// refcounts through this; slot page tables stay cache-private.
    pub fn pool_mut(&mut self) -> &mut PagePool {
        &mut self.pool
    }

    /// The KV quantizer, if this cache stores encoded pages. The decode
    /// panel cache decodes pages through this so its cached panels are
    /// bit-identical to what [`gather`](Self::gather) would produce.
    pub fn quantizer(&self) -> Option<&KvQuantizer> {
        self.quant.as_ref()
    }

    /// Fill `out` with the page-id run of (slot, layer, head) in token
    /// order and return the layer's cached token count. The
    /// encoded-domain attention path scores against these pages via the
    /// panel cache instead of gathering the decoded f32 history.
    pub fn page_run(&self, slot: SlotId, layer: usize, head: usize, out: &mut Vec<PageId>) -> usize {
        let (nh, pt) = (self.layout.n_heads, self.layout.page_tokens);
        let st = &self.slots[slot];
        assert!(st.live, "page_run of a dead slot {slot}");
        let len = st.lens[layer];
        out.clear();
        for page_idx in 0..len.div_ceil(pt) {
            out.push(st.pages[layer][page_idx * nh + head]);
        }
        len
    }

    /// Bytes of cached state summed over every live slot's page
    /// references — O(1), read from the incrementally-maintained counter
    /// (the serving metrics sample this once per decode step). A page
    /// shared by several slots via prefix adoption counts once **per
    /// slot** — this is the logical footprint the slots would need
    /// without sharing; physical residency is what the prefix cache's
    /// own `resident_bytes` plus the pool's live pages describe. Debug
    /// builds cross-check the counter against the full page walk.
    pub fn state_bytes(&self) -> usize {
        debug_assert_eq!(
            self.cached_bytes,
            self.walk_state_bytes(),
            "incremental byte counter drifted from the page walk"
        );
        self.cached_bytes
    }

    /// Reference implementation of [`state_bytes`](Self::state_bytes):
    /// the exhaustive live-page walk the counter is validated against.
    /// (Unreferenced in release builds, where the debug assert melts.)
    #[allow(dead_code)]
    fn walk_state_bytes(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.live)
            .flat_map(|s| s.pages.iter())
            .flat_map(|ps| ps.iter())
            .map(|&id| self.pool.get(id).state_bytes())
            .sum()
    }

    /// High-water mark of [`state_bytes`](Self::state_bytes). Bytes grow
    /// monotonically between slot releases, so sampling at `free_slot`
    /// and on query is exact.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes.max(self.state_bytes())
    }

    /// Pages ever allocated by the underlying pool.
    pub fn capacity_pages(&self) -> usize {
        self.pool.capacity_pages()
    }

    /// Cap (or uncap) the pool's page budget — the serving `--kv-pages`
    /// knob. `None` restores unbounded growth.
    pub fn set_page_budget(&mut self, budget: Option<usize>) {
        self.pool.set_budget_pages(budget);
    }

    /// Pages still allocatable under the budget (`usize::MAX` when
    /// unbounded) — what the scheduler's pressure ladder consults.
    pub fn page_headroom(&self) -> usize {
        self.pool.headroom_pages()
    }

    /// Fail with a typed [`KvPressure`](super::pool::KvPressure) error
    /// unless `needed` pages fit under the budget. Callers staging a
    /// multi-allocation unit of work (a prefill chunk, a fused decode
    /// step) pre-check the whole unit here so a shortfall never leaves
    /// the cache half-mutated.
    pub fn ensure_page_headroom(&self, needed: usize) -> anyhow::Result<()> {
        self.pool.ensure_headroom(needed)
    }

    /// Fresh pages appending `new_tokens` more tokens to `slot` will
    /// claim, over all layers and heads: the number of page-boundary
    /// crossings in `[len, len + new_tokens)` times `n_layers * n_heads`.
    /// The chunked-prefill and fused-decode paths size their headroom
    /// pre-checks with this.
    pub fn pages_needed(&self, slot: SlotId, new_tokens: usize) -> usize {
        let st = &self.slots[slot];
        debug_assert!(st.live, "pages_needed of a dead slot {slot}");
        let pt = self.layout.page_tokens;
        let len = st.lens.first().copied().unwrap_or(0);
        let crossings = (len + new_tokens).div_ceil(pt) - len.div_ceil(pt);
        crossings * self.layout.n_layers * self.layout.n_heads
    }

    /// Occupancy snapshot (pages in use / high-water mark / bytes) for
    /// the serving metrics.
    pub fn stats(&self) -> KvStats {
        KvStats {
            live_slots: self.live_slot_count(),
            pages_in_use: self.pool.live_pages(),
            pages_peak: self.pool.peak_live_pages(),
            pages_capacity: self.pool.capacity_pages(),
            pages_budget: self.pool.budget_pages(),
            state_bytes: self.state_bytes(),
            peak_bytes: self.peak_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{llm_like_sample, Pcg32};

    fn layout(pt: usize) -> KvLayout {
        KvLayout { n_layers: 2, n_heads: 2, head_dim: 16, page_tokens: pt, max_tokens: 16, max_slots: 3 }
    }

    fn rows(rng: &mut Pcg32, d: usize) -> (Vec<f32>, Vec<f32>) {
        (llm_like_sample(rng, d, 0.05, 4.0), llm_like_sample(rng, d, 0.05, 4.0))
    }

    #[test]
    fn f32_round_trip_across_page_boundaries() {
        let lay = layout(4);
        let (nh, hd) = (lay.n_heads, lay.head_dim);
        let mut cache = PagedKvCache::new(lay, KvStore::F32).unwrap();
        let slot = cache.alloc_slot().unwrap();
        let mut rng = Pcg32::seeded(0x9A6E);
        let mut want_k: Vec<Vec<f32>> = vec![Vec::new(); 2]; // per layer, flat [t, d]
        for _tok in 0..10 {
            for layer in 0..2 {
                let (k, v) = rows(&mut rng, nh * hd);
                cache.append(slot, layer, &k, &v).unwrap();
                want_k[layer].extend_from_slice(&k);
            }
        }
        assert_eq!(cache.seq_len(slot), 10);
        let mut out = Vec::new();
        for layer in 0..2 {
            for head in 0..nh {
                let n = cache.gather(slot, layer, head, Plane::K, &mut out);
                assert_eq!(n, 10);
                for t in 0..n {
                    let want = &want_k[layer][t * nh * hd + head * hd..t * nh * hd + (head + 1) * hd];
                    assert_eq!(&out[t * hd..(t + 1) * hd], want, "layer {layer} head {head} tok {t}");
                }
            }
        }
        // 10 tokens at 4 tokens/page = 3 pages per (layer, head).
        assert_eq!(cache.page_ids(slot).len(), 3 * 2 * nh);
    }

    #[test]
    fn encoded_gather_matches_per_vector_fake_quantize() {
        use crate::quant::lobcq::fake_quantize;
        let lay = layout(4);
        let (nh, hd) = (lay.n_heads, lay.head_dim);
        let mut rng = Pcg32::seeded(0x9A6F);
        let sample = llm_like_sample(&mut rng, hd * 32, 0.05, 4.0);
        let q = KvQuantizer::calibrated(hd, &sample, 11).unwrap();
        let reference = q.clone();
        let mut cache = PagedKvCache::new(lay, KvStore::Encoded(q)).unwrap();
        let slot = cache.alloc_slot().unwrap();
        let mut appended: Vec<Vec<f32>> = Vec::new();
        for _tok in 0..6 {
            let (k, v) = rows(&mut rng, nh * hd);
            cache.append(slot, 0, &k, &v).unwrap();
            cache.append(slot, 1, &k, &v).unwrap();
            appended.push(k);
        }
        let mut out = Vec::new();
        let n = cache.gather(slot, 0, 1, Plane::K, &mut out);
        assert_eq!(n, 6);
        for (t, krow) in appended.iter().enumerate() {
            let vec = &krow[hd..2 * hd]; // head 1
            let want = fake_quantize(vec, reference.cfg(), reference.family());
            for (g, w) in out[t * hd..(t + 1) * hd].iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "tok {t}");
            }
        }
        assert!(cache.state_bytes() > 0);
        assert!(cache.state_bytes() < 6 * 2 * 2 * hd * 2 * 4, "encoded cache not smaller than f32");
    }

    #[test]
    fn slot_free_recycles_pages_without_aliasing_live_slots() {
        let lay = layout(2);
        let d = lay.n_heads * lay.head_dim;
        let mut cache = PagedKvCache::new(lay, KvStore::F32).unwrap();
        let a = cache.alloc_slot().unwrap();
        let b = cache.alloc_slot().unwrap();
        let mut rng = Pcg32::seeded(0x9A70);
        let (ka, va) = rows(&mut rng, d);
        let (kb, vb) = rows(&mut rng, d);
        for layer in 0..2 {
            cache.append(a, layer, &ka, &va).unwrap();
            cache.append(b, layer, &kb, &vb).unwrap();
        }
        let a_pages = cache.page_ids(a);
        let b_pages = cache.page_ids(b);
        assert!(a_pages.iter().all(|p| !b_pages.contains(p)), "live slots share a page");
        cache.free_slot(a);
        cache.free_slot(a); // double free is a no-op
        let c = cache.alloc_slot().unwrap();
        for layer in 0..2 {
            cache.append(c, layer, &ka, &va).unwrap();
        }
        // c reuses a's freed pages, but b's contents must be untouched.
        assert!(cache.page_ids(c).iter().all(|p| a_pages.contains(p)), "free list not reused");
        let mut out = Vec::new();
        cache.gather(b, 0, 0, Plane::K, &mut out);
        assert_eq!(&out[..], &kb[..16], "live slot b corrupted by reuse (head 0 = first head_dim of the row)");
    }

    #[test]
    fn append_batch_matches_serial_appends_and_is_atomic() {
        let lay = layout(4);
        let (nh, hd) = (lay.n_heads, lay.head_dim);
        let d = nh * hd;
        let stride = 3 * d; // a (lanes, 3d) QKV row: Q | K | V
        let mut batched = PagedKvCache::new(lay.clone(), KvStore::F32).unwrap();
        let mut serial = PagedKvCache::new(lay, KvStore::F32).unwrap();
        let sb: Vec<SlotId> = (0..2).map(|_| batched.alloc_slot().unwrap()).collect();
        let ss: Vec<SlotId> = (0..2).map(|_| serial.alloc_slot().unwrap()).collect();
        let mut rng = Pcg32::seeded(0x9A71);
        for _tok in 0..5 {
            let rows = llm_like_sample(&mut rng, 2 * stride, 0.05, 4.0);
            for layer in 0..2 {
                batched.append_batch(&sb, layer, &rows, stride, d, 2 * d).unwrap();
                for (i, &slot) in ss.iter().enumerate() {
                    let row = &rows[i * stride..(i + 1) * stride];
                    serial.append(slot, layer, &row[d..2 * d], &row[2 * d..3 * d]).unwrap();
                }
            }
        }
        let (mut a, mut b) = (Vec::new(), Vec::new());
        let (mut k2, mut v2) = (Vec::new(), Vec::new());
        for lane in 0..2 {
            for layer in 0..2 {
                for head in 0..nh {
                    // gather_kv == two gathers, and batched == serial.
                    let n = batched.gather_kv(sb[lane], layer, head, &mut a, &mut b);
                    assert_eq!(n, 5);
                    serial.gather(ss[lane], layer, head, Plane::K, &mut k2);
                    serial.gather(ss[lane], layer, head, Plane::V, &mut v2);
                    assert_eq!(a, k2, "K mismatch lane {lane} layer {layer} head {head}");
                    assert_eq!(b, v2, "V mismatch lane {lane} layer {layer} head {head}");
                }
            }
        }
        // Atomicity: one dead lane fails the whole call before mutation.
        let rows = llm_like_sample(&mut rng, 2 * stride, 0.05, 4.0);
        batched.free_slot(sb[1]);
        let before = batched.seq_len(sb[0]);
        assert!(batched.append_batch(&sb, 0, &rows, stride, d, 2 * d).is_err());
        assert_eq!(batched.seq_len(sb[0]), before, "failed batched append mutated a live lane");
        // Duplicate slots rejected.
        assert!(batched.append_batch(&[sb[0], sb[0]], 0, &rows, stride, d, 2 * d).is_err());
    }

    #[test]
    fn stats_report_page_occupancy_and_peak() {
        let lay = layout(2);
        let d = lay.n_heads * lay.head_dim;
        let mut cache = PagedKvCache::new(lay, KvStore::F32).unwrap();
        assert_eq!(cache.stats(), KvStats::default());
        let s = cache.alloc_slot().unwrap();
        for _ in 0..3 {
            for layer in 0..2 {
                cache.append(s, layer, &vec![1.0; d], &vec![2.0; d]).unwrap();
            }
        }
        let st = cache.stats();
        // 3 tokens at 2 tokens/page = 2 pages per (layer, head) = 8.
        assert_eq!(st.pages_in_use, 8);
        assert_eq!(st.pages_peak, 8);
        assert_eq!(st.live_slots, 1);
        assert!(st.state_bytes > 0 && st.peak_bytes >= st.state_bytes);
        cache.free_slot(s);
        let st = cache.stats();
        assert_eq!(st.pages_in_use, 0);
        assert_eq!(st.pages_peak, 8, "peak lost on release");
        assert_eq!(st.pages_capacity, 8);
    }

    #[test]
    fn adopt_prefix_shares_full_pages_and_cows_the_partial_one() {
        let lay = layout(4); // 2 layers, 2 heads, pt 4, max 16 tokens
        let (nh, hd) = (lay.n_heads, lay.head_dim);
        let d = nh * hd;
        let mut cache = PagedKvCache::new(lay, KvStore::F32).unwrap();
        let donor = cache.alloc_slot().unwrap();
        let mut rng = Pcg32::seeded(0x9A80);
        let mut appended: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
        for _tok in 0..6 {
            // 6 tokens at pt 4 = 1 full page + 2 tokens per (layer, head)
            let (k, v) = rows(&mut rng, d);
            for layer in 0..2 {
                cache.append(donor, layer, &k, &v).unwrap();
            }
            appended.push((k, v));
        }
        let groups = cache.full_page_groups(donor);
        assert_eq!(groups.len(), 1, "6 tokens at pt=4 should yield one full group");
        assert_eq!(groups[0].len(), 2 * nh);
        // The donor's second (partial) page group, layer-major × head.
        let mut partial_group = Vec::new();
        for layer in 0..2 {
            for head in 0..nh {
                partial_group.push(cache.page_ids(donor)[layer * 2 * nh + nh + head]);
            }
        }

        let adopter = cache.alloc_slot().unwrap();
        cache.adopt_prefix(adopter, &groups, Some((&partial_group, 2))).unwrap();
        assert_eq!(cache.seq_len(adopter), 6);
        // Full pages are shared (refcount 2), CoW pages are private.
        for &id in &groups[0] {
            assert_eq!(cache.pool().ref_count(id), 2, "full page not shared");
        }
        let adopter_pages = cache.page_ids(adopter);
        for &id in &partial_group {
            assert!(!adopter_pages.contains(&id), "partial page aliased instead of copied");
        }
        // The adopted history reads back exactly what the donor wrote.
        let mut out = Vec::new();
        for layer in 0..2 {
            for head in 0..nh {
                let n = cache.gather(adopter, layer, head, Plane::K, &mut out);
                assert_eq!(n, 6);
                for (t, (k, _)) in appended.iter().enumerate() {
                    let want = &k[head * hd..(head + 1) * hd];
                    assert_eq!(&out[t * hd..(t + 1) * hd], want, "layer {layer} head {head} tok {t}");
                }
            }
        }
        // Divergence: appending to the adopter fills its CoW page and
        // must not disturb the donor.
        let (k7, v7) = rows(&mut rng, d);
        for layer in 0..2 {
            cache.append(adopter, layer, &k7, &v7).unwrap();
        }
        let n = cache.gather(donor, 0, 0, Plane::K, &mut out);
        assert_eq!(n, 6, "donor grew via the adopter's append");
        assert_eq!(&out[5 * hd..6 * hd], &appended[5].0[..hd], "donor history corrupted");
        // Donor release keeps the shared pages alive for the adopter.
        cache.free_slot(donor);
        let n = cache.gather(adopter, 1, 1, Plane::K, &mut out);
        assert_eq!(n, 7);
        assert_eq!(&out[..hd], &appended[0].0[hd..2 * hd], "shared page died with the donor");
        // Misuse: adopting into a non-empty slot is rejected.
        assert!(cache.adopt_prefix(adopter, &groups, None).is_err());
    }

    #[test]
    fn is_live_is_graceful_on_any_id() {
        let mut cache = PagedKvCache::new(layout(4), KvStore::F32).unwrap();
        assert!(!cache.is_live(0));
        assert!(!cache.is_live(999), "out-of-range id must not panic");
        let s = cache.alloc_slot().unwrap();
        assert!(cache.is_live(s));
        cache.free_slot(s);
        assert!(!cache.is_live(s));
    }

    #[test]
    fn page_budget_fails_typed_and_leaves_cache_resumable() {
        use super::super::pool::KvPressure;
        let lay = KvLayout { n_layers: 1, n_heads: 2, head_dim: 4, page_tokens: 2, max_tokens: 16, max_slots: 2 };
        let d = lay.n_heads * lay.head_dim;
        let mut cache = PagedKvCache::new(lay, KvStore::F32).unwrap();
        // Budget of 2 pages = exactly one 2-token page group (2 heads).
        cache.set_page_budget(Some(2));
        let s = cache.alloc_slot().unwrap();
        assert_eq!(cache.pages_needed(s, 2), 2);
        assert_eq!(cache.pages_needed(s, 3), 4);
        for _ in 0..2 {
            cache.append(s, 0, &vec![1.0; d], &vec![2.0; d]).unwrap();
        }
        assert_eq!(cache.page_headroom(), 0);
        // Third token needs a fresh page group: typed failure, no growth,
        // lane still resumable at its pre-failure length.
        let err = cache.append(s, 0, &vec![1.0; d], &vec![2.0; d]).unwrap_err();
        let p = err.downcast_ref::<KvPressure>().expect("append loses the KvPressure source");
        assert_eq!((p.needed, p.headroom), (2, 0));
        assert_eq!(cache.seq_len(s), 2, "failed append mutated the slot");
        assert_eq!(cache.stats().pages_budget, Some(2));
        // Batched flavour is atomic under pressure too.
        let rows = vec![0.5f32; 3 * d];
        let err = cache.append_batch(&[s], 0, &rows, 3 * d, d, 2 * d).unwrap_err();
        assert!(err.downcast_ref::<KvPressure>().is_some(), "append_batch loses the KvPressure source");
        assert_eq!(cache.seq_len(s), 2);
        // Raising the budget resumes the same lane bit-exactly.
        cache.set_page_budget(Some(4));
        cache.append(s, 0, &vec![3.0; d], &vec![4.0; d]).unwrap();
        assert_eq!(cache.seq_len(s), 3);
        let mut out = Vec::new();
        cache.gather(s, 0, 0, Plane::K, &mut out);
        assert_eq!(&out[..4], &[1.0; 4], "pre-pressure history corrupted");
        assert_eq!(&out[8..12], &[3.0; 4]);
    }

    #[test]
    fn adopt_prefix_cow_respects_page_budget() {
        let lay = layout(4);
        let d = lay.n_heads * lay.head_dim;
        let group = lay.n_layers * lay.n_heads;
        let mut cache = PagedKvCache::new(lay, KvStore::F32).unwrap();
        let donor = cache.alloc_slot().unwrap();
        let mut rng = Pcg32::seeded(0x9A90);
        for _tok in 0..6 {
            let (k, v) = rows(&mut rng, d);
            for layer in 0..2 {
                cache.append(donor, layer, &k, &v).unwrap();
            }
        }
        let groups = cache.full_page_groups(donor);
        let mut partial_group = Vec::new();
        for layer in 0..2 {
            for head in 0..cache.layout().n_heads {
                partial_group.push(cache.page_ids(donor)[layer * 2 * cache.layout().n_heads + cache.layout().n_heads + head]);
            }
        }
        // No headroom for the CoW group: adoption fails typed, before
        // any refcount moved, so freeing the adopter leaks nothing.
        cache.set_page_budget(Some(cache.capacity_pages()));
        let adopter = cache.alloc_slot().unwrap();
        let err = cache.adopt_prefix(adopter, &groups, Some((&partial_group, 2))).unwrap_err();
        assert!(err.downcast_ref::<super::super::pool::KvPressure>().is_some());
        for &id in &groups[0] {
            assert_eq!(cache.pool().ref_count(id), 1, "failed adoption leaked a retain");
        }
        // Full-group-only adoption is refcount-only and succeeds at zero
        // headroom; with room for the CoW group the partial path works.
        cache.adopt_prefix(adopter, &groups, None).unwrap();
        assert_eq!(cache.seq_len(adopter), 4);
        cache.free_slot(adopter);
        cache.set_page_budget(Some(cache.capacity_pages() + group));
        let adopter = cache.alloc_slot().unwrap();
        cache.adopt_prefix(adopter, &groups, Some((&partial_group, 2))).unwrap();
        assert_eq!(cache.seq_len(adopter), 6);
    }

    #[test]
    fn truncate_frees_tail_pages_and_matches_never_extended_twin() {
        // Twin caches, f32 and encoded: one appends 7 tokens then rolls
        // back to 3 and re-appends; the other only ever sees the kept
        // history. Gathers must agree bit for bit and the freed tail
        // pages must be back on the pool's free list.
        let mut rng = Pcg32::seeded(0x9AB0);
        let lay = layout(2); // pt 2: 7 tokens = 3 pages + 1 boundary token
        let d = lay.n_heads * lay.head_dim;
        let sample = llm_like_sample(&mut rng, lay.head_dim * 32, 0.05, 4.0);
        let mk = |enc: bool| {
            let store = if enc {
                KvStore::Encoded(KvQuantizer::calibrated(lay.head_dim, &sample, 11).unwrap())
            } else {
                KvStore::F32
            };
            PagedKvCache::new(lay.clone(), store).unwrap()
        };
        for enc in [false, true] {
            let mut spec = mk(enc);
            let mut clean = mk(enc);
            let ss = spec.alloc_slot().unwrap();
            let cs = clean.alloc_slot().unwrap();
            let toks: Vec<(Vec<f32>, Vec<f32>)> = (0..9).map(|_| rows(&mut rng, d)).collect();
            for (k, v) in &toks[..7] {
                for layer in 0..2 {
                    spec.append(ss, layer, k, v).unwrap();
                }
            }
            let live_before = spec.pool().live_pages();
            assert_eq!(spec.truncate(ss, 3).unwrap(), 3);
            assert_eq!(spec.seq_len(ss), 3);
            // 7 tokens = 4 pages/[layer,head]; keeping 3 tokens = 2 pages.
            assert_eq!(live_before - spec.pool().live_pages(), 2 * 2 * lay.n_heads);
            // Truncating to the current length is a no-op.
            assert_eq!(spec.truncate(ss, 3).unwrap(), 3);
            for (k, v) in &toks[7..] {
                for layer in 0..2 {
                    spec.append(ss, layer, k, v).unwrap();
                }
            }
            for (k, v) in toks[..3].iter().chain(&toks[7..]) {
                for layer in 0..2 {
                    clean.append(cs, layer, k, v).unwrap();
                }
            }
            let (mut a, mut b) = (Vec::new(), Vec::new());
            for layer in 0..2 {
                for head in 0..lay.n_heads {
                    for plane in [Plane::K, Plane::V] {
                        assert_eq!(spec.gather(ss, layer, head, plane, &mut a), 5);
                        clean.gather(cs, layer, head, plane, &mut b);
                        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                            assert_eq!(
                                x.to_bits(),
                                y.to_bits(),
                                "enc {enc} layer {layer} head {head} {plane:?} scalar {i}"
                            );
                        }
                    }
                }
            }
            // state_bytes() cross-checks the incremental counter against
            // the page walk in debug builds.
            assert!(spec.state_bytes() > 0);
        }
    }

    #[test]
    fn truncate_rejects_misuse_and_shared_boundary_pages() {
        let lay = layout(4);
        let d = lay.n_heads * lay.head_dim;
        let mut cache = PagedKvCache::new(lay, KvStore::F32).unwrap();
        let mut rng = Pcg32::seeded(0x9AB1);
        assert!(cache.truncate(0, 1).is_err(), "truncate of a dead slot accepted");
        let donor = cache.alloc_slot().unwrap();
        for _ in 0..4 {
            let (k, v) = rows(&mut rng, d);
            for layer in 0..2 {
                cache.append(donor, layer, &k, &v).unwrap();
            }
        }
        assert!(cache.truncate(donor, 0).is_err(), "truncate to zero accepted");
        assert!(cache.truncate(donor, 5).is_err(), "truncate past the history accepted");
        // Share the donor's full page with an adopter: cutting inside a
        // shared page must be refused with nothing mutated.
        let groups = cache.full_page_groups(donor);
        assert_eq!(groups.len(), 1);
        let adopter = cache.alloc_slot().unwrap();
        cache.adopt_prefix(adopter, &groups, None).unwrap();
        let err = cache.truncate(adopter, 2).unwrap_err();
        assert!(err.to_string().contains("shared"), "unexpected error: {err}");
        assert_eq!(cache.seq_len(adopter), 4, "refused truncate mutated the slot");
        // Once the adopter extends past the shared page, rolling back to
        // (but not into) it is fine: the slot-owned tail page is freed.
        let (k, v) = rows(&mut rng, d);
        for layer in 0..2 {
            cache.append(adopter, layer, &k, &v).unwrap();
        }
        assert_eq!(cache.truncate(adopter, 4).unwrap(), 4);
        assert_eq!(cache.seq_len(adopter), 4);
        for &id in &groups[0] {
            assert_eq!(cache.pool().ref_count(id), 2, "shared page lost a reference");
        }
    }

    #[test]
    fn capacity_limits_enforced() {
        let lay = KvLayout { n_layers: 1, n_heads: 1, head_dim: 4, page_tokens: 2, max_tokens: 3, max_slots: 1 };
        let mut cache = PagedKvCache::new(lay, KvStore::F32).unwrap();
        let s = cache.alloc_slot().unwrap();
        assert!(cache.alloc_slot().is_err(), "over-allocated slots");
        for _ in 0..3 {
            cache.append(s, 0, &[1.0; 4], &[2.0; 4]).unwrap();
        }
        assert!(cache.append(s, 0, &[1.0; 4], &[2.0; 4]).is_err(), "exceeded max_tokens");
        cache.free_slot(s);
        assert!(cache.alloc_slot().is_ok(), "slot not recycled");
    }
}
