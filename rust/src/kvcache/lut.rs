//! Encoded-domain decode attention: the per-page panel cache
//! (DESIGN.md §Encoded-domain attention).
//!
//! The gather-based decode path re-materializes the **entire** f32 K/V
//! history of a (slot, layer, head) from BCQ codes on every step —
//! O(len · head_dim) LUT decodes per head per token, even though every
//! page but the frontier one is immutable. This module applies the PR 2
//! qgemm trick to the KV cache instead: each encoded K page is expanded
//! **once** through its 16-entry scaled LUTs into a `[head_dim,
//! page_tokens]` transposed panel (`K^T`, exactly the B-panel layout the
//! blocked GEMM micro-kernel streams), the V plane into `[page_tokens,
//! head_dim]` rows for the context product, and both are cached per
//! `PageId` until the page's pool **generation** changes (append, CoW
//! seed, or free/realloc — see `PagePool::gen`). Steady-state decode
//! then re-decodes only the frontier page; full pages are scored
//! straight from the cache through the [`PanelProvider`] seam, SIMD
//! micro-kernel included.
//!
//! Bit-exactness: panels are decoded by the **same**
//! `KvQuantizer::decode_vectors` path `gather` uses (f32 pages memcpy),
//! and [`KtView`] feeds them to the same blocked driver in the same
//! per-element accumulation order as the scalar q·K loop — so
//! encoded-domain attention is bit-identical to the decode-then-dot
//! path (pinned in `model::decode` tests and `tests/decode_parity.rs`).
//!
//! Budgeting: decoded panels are cache state, not per-step scratch —
//! the cache holds at most [`budget_bytes`](KvPanelCache::set_budget_bytes)
//! of them, evicting least-recently-touched entries (never one touched
//! in the current attention call) and recycling their buffers through a
//! free list, so steady-state decode performs no panel allocations once
//! the working set is warm.

use super::pool::{PageId, PagePool, Plane};
use super::quant::KvQuantizer;
use crate::kernels::{PanelProvider, NR};

/// Default decoded-panel budget (32 MiB ≈ 4096 pages at hd 64, pt 16).
const DEFAULT_BUDGET_BYTES: usize = 32 << 20;

/// One page's cached decode.
#[derive(Debug)]
struct PageEntry {
    /// Pool generation the decode was taken at; stale when it drifts.
    gen: u64,
    /// Tokens decoded (the page's `filled` at decode time).
    filled: usize,
    /// Last-touched clock tick (LRU victim selection).
    stamp: u64,
    /// `K^T`: `[head_dim, page_tokens]` row-major (stride `page_tokens`),
    /// columns `>= filled` zeroed. When `page_tokens == NR` a full page
    /// is byte-for-byte a GEMM B-panel and is lent out with no copy.
    kt: Vec<f32>,
    /// V rows: `[page_tokens, head_dim]` row-major, rows `>= filled`
    /// zeroed.
    v: Vec<f32>,
}

/// Per-page decoded K^T/V panel cache, keyed by [`PageId`] and owned by
/// `DecodeScratch` (it rides along with the session, like the rest of
/// the decode working set, but its size scales with **cache state**, so
/// it is budgeted and excluded from the scratch footprint).
#[derive(Debug)]
pub struct KvPanelCache {
    /// `PagePool::instance_id` the entries belong to (0 = unset). A
    /// scratch reused against a different cache drops everything rather
    /// than serve another pool's pages under aliasing ids.
    pool_id: u64,
    /// Geometry the buffers are shaped for.
    pt: usize,
    hd: usize,
    budget_bytes: usize,
    /// Entry per `PageId` (dense: pool ids are table indices).
    entries: Vec<Option<PageEntry>>,
    /// Bytes across live entries (each `2 * hd * pt * 4`).
    bytes: usize,
    /// Monotonic touch clock.
    clock: u64,
    /// Recycled (kt, v) buffer pairs from evicted entries.
    free: Vec<(Vec<f32>, Vec<f32>)>,
    /// Row-major decode staging for the K transpose.
    tmp: Vec<f32>,
    /// Pages decoded since construction (cache-effectiveness metric).
    decodes: u64,
    /// Revalidated hits since construction.
    hits: u64,
    /// Fresh buffer-pair allocations (steady state: stops growing).
    buffer_allocs: u64,
}

impl Default for KvPanelCache {
    fn default() -> KvPanelCache {
        KvPanelCache {
            pool_id: 0,
            pt: 0,
            hd: 0,
            budget_bytes: DEFAULT_BUDGET_BYTES,
            entries: Vec::new(),
            bytes: 0,
            clock: 0,
            free: Vec::new(),
            tmp: Vec::new(),
            decodes: 0,
            hits: 0,
            buffer_allocs: 0,
        }
    }
}

impl KvPanelCache {
    pub fn new() -> KvPanelCache {
        KvPanelCache::default()
    }

    /// Cap on decoded-panel bytes (existing entries over a lowered
    /// budget are evicted on the next [`ensure`](Self::ensure)).
    pub fn set_budget_bytes(&mut self, bytes: usize) {
        self.budget_bytes = bytes;
    }

    /// Pages decoded since construction.
    pub fn decode_count(&self) -> u64 {
        self.decodes
    }

    /// Cache hits (revalidated entries) since construction.
    pub fn hit_count(&self) -> u64 {
        self.hits
    }

    /// Fresh buffer-pair allocations since construction — constant once
    /// the budgeted working set is warm (eviction recycles buffers).
    pub fn buffer_alloc_count(&self) -> u64 {
        self.buffer_allocs
    }

    /// Bytes of decoded panels currently held.
    pub fn resident_bytes(&self) -> usize {
        self.bytes
    }

    fn entry_bytes(&self) -> usize {
        2 * self.hd * self.pt * 4
    }

    /// Drop every entry (pool switch / geometry change), recycling the
    /// buffers.
    fn reset(&mut self) {
        for slot in self.entries.iter_mut() {
            if let Some(e) = slot.take() {
                self.free.push((e.kt, e.v));
            }
        }
        self.bytes = 0;
    }

    /// Make the decoded panels of every page in `ids` current: entries
    /// whose pool generation still matches are touched (a hit), the rest
    /// are (re)decoded through the same path `gather` uses. Evicts down
    /// to the byte budget afterwards, never evicting a page touched by
    /// **this** call (mid-attention eviction of a page the in-flight
    /// [`KtView`] still needs would be a correctness bug, so tiny
    /// budgets run over rather than break).
    pub fn ensure(
        &mut self,
        pool: &PagePool,
        quant: Option<&KvQuantizer>,
        hd: usize,
        ids: &[PageId],
    ) {
        let pt = pool.page_tokens();
        if self.pool_id != pool.instance_id() || self.pt != pt || self.hd != hd {
            self.reset();
            self.pool_id = pool.instance_id();
            self.pt = pt;
            self.hd = hd;
        }
        if self.entries.len() < pool.capacity_pages() {
            self.entries.resize_with(pool.capacity_pages(), || None);
        }
        let eb = self.entry_bytes();
        let floor = self.clock; // entries touched below get stamp > floor
        for &id in ids {
            let gen = pool.gen(id);
            self.clock += 1;
            let stamp = self.clock;
            let slot = &mut self.entries[id as usize];
            if let Some(e) = slot {
                if e.gen == gen {
                    e.stamp = stamp;
                    self.hits += 1;
                    continue;
                }
            }
            // Miss or stale: decode the page into (possibly recycled)
            // buffers.
            let (mut kt, mut v) = match slot.take() {
                Some(e) => {
                    self.bytes -= eb;
                    (e.kt, e.v)
                }
                None => match self.free.pop() {
                    Some(pair) => pair,
                    None => {
                        self.buffer_allocs += 1;
                        (Vec::new(), Vec::new())
                    }
                },
            };
            let page = pool.get(id);
            let filled = page.filled;
            // V rows decode straight into place; the tail stays zero.
            v.clear();
            v.resize(pt * hd, 0.0);
            page.gather(hd, quant, Plane::V, &mut v[..filled * hd]);
            // K decodes row-major into staging, then transposes into the
            // [hd, pt] panel layout (values untouched — bit-exact).
            self.tmp.resize(filled * hd, 0.0);
            page.gather(hd, quant, Plane::K, &mut self.tmp[..filled * hd]);
            kt.clear();
            kt.resize(hd * pt, 0.0);
            for (r, row) in self.tmp[..filled * hd].chunks_exact(hd).enumerate() {
                for (c, &x) in row.iter().enumerate() {
                    kt[c * pt + r] = x;
                }
            }
            *slot = Some(PageEntry { gen, filled, stamp, kt, v });
            self.bytes += eb;
            self.decodes += 1;
        }
        // Evict least-recently-touched entries not part of this call.
        while self.bytes > self.budget_bytes {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter_map(|(i, s)| s.as_ref().map(|e| (i, e.stamp)))
                .filter(|&(_, stamp)| stamp <= floor)
                .min_by_key(|&(_, stamp)| stamp)
                .map(|(i, _)| i);
            match victim {
                Some(i) => {
                    let e = self.entries[i].take().expect("victim vanished");
                    self.free.push((e.kt, e.v));
                    self.bytes -= eb;
                }
                None => break, // everything left is pinned by this call
            }
        }
    }

    /// The decoded entry for `id` — must have been covered by the
    /// current attention call's [`ensure`](Self::ensure).
    fn entry(&self, id: PageId) -> &PageEntry {
        self.entries[id as usize]
            .as_ref()
            .expect("panel cache entry missing — ensure() not called for this page run")
    }

    /// Decoded V row of token `j` within a page run (`ids[j / pt]`,
    /// local row `j % pt`) — the context product reads these in the same
    /// token order the gathered history had.
    pub fn v_row(&self, ids: &[PageId], j: usize) -> &[f32] {
        let e = self.entry(ids[j / self.pt]);
        let c = j % self.pt;
        debug_assert!(c < e.filled, "token {j} past the decoded fill");
        &e.v[c * self.hd..(c + 1) * self.hd]
    }

    /// Panel view over a page run: `K^T` as a [`PanelProvider`] with
    /// `k() = head_dim`, `n() = n` tokens — score rows `q · K[j]` come
    /// out of the blocked GEMM driver bit-identical to the scalar dot.
    pub fn kt_view<'a>(&'a self, ids: &'a [PageId], n: usize) -> KtView<'a> {
        debug_assert!(ids.len() >= n.div_ceil(self.pt.max(1)), "page run shorter than the token span");
        KtView { cache: self, ids, n }
    }
}

/// Borrowed `K^T` panel source over one (slot, layer, head) page run —
/// the KV-cache analogue of `QuantLinear`'s panel provider. Immutable
/// (`ensure` ran first), so it is `Sync` and the parallel GEMM driver
/// can share it across workers.
pub struct KtView<'a> {
    cache: &'a KvPanelCache,
    ids: &'a [PageId],
    /// Token span (B columns); tokens past `n` in a panel are masked by
    /// the driver's `jmax` write-back, same as packed zero-padding.
    n: usize,
}

impl PanelProvider for KtView<'_> {
    fn k(&self) -> usize {
        self.cache.hd
    }

    fn n(&self) -> usize {
        self.n
    }

    fn panel<'a>(&'a self, j0: usize, k0: usize, kc: usize, scratch: &'a mut Vec<f32>) -> &'a [f32] {
        let pt = self.cache.pt;
        if pt == NR {
            // Page-aligned fast path (the serving default, pt = 16): a
            // full page's kt IS the `kc × NR` panel — zero copies.
            let e = self.cache.entry(self.ids[j0 / pt]);
            if e.filled == pt {
                return &e.kt[k0 * NR..(k0 + kc) * NR];
            }
        }
        // General path: assemble the NR columns from (page, local-row)
        // coordinates, zero-filling columns past the span — exactly
        // PackedB's padding convention.
        scratch.resize(kc * NR, 0.0);
        for jr in 0..NR {
            let j = j0 + jr;
            if j >= self.n {
                for kk in 0..kc {
                    scratch[kk * NR + jr] = 0.0;
                }
                continue;
            }
            let e = self.cache.entry(self.ids[j / pt]);
            let c = j % pt;
            if c >= e.filled {
                for kk in 0..kc {
                    scratch[kk * NR + jr] = 0.0;
                }
                continue;
            }
            for kk in 0..kc {
                scratch[kk * NR + jr] = e.kt[(k0 + kk) * pt + c];
            }
        }
        scratch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KC;
    use crate::util::rng::{llm_like_sample, Pcg32};

    fn filled_pool(pt: usize, hd: usize, tokens: usize, seed: u64) -> (PagePool, Vec<PageId>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let mut rng = Pcg32::seeded(seed);
        let mut pool = PagePool::new(pt, hd, false);
        let mut ids = Vec::new();
        let (mut ks, mut vs) = (Vec::new(), Vec::new());
        for t in 0..tokens {
            if t % pt == 0 {
                ids.push(pool.alloc());
            }
            let k = llm_like_sample(&mut rng, hd, 0.05, 4.0);
            let v = llm_like_sample(&mut rng, hd, 0.05, 4.0);
            pool.get_mut(*ids.last().unwrap()).append(pt, hd, None, &k, &v);
            ks.push(k);
            vs.push(v);
        }
        (pool, ids, ks, vs)
    }

    #[test]
    fn panels_match_history_and_revalidate_without_redecoding() {
        let (pt, hd, tokens) = (4usize, 8usize, 10usize);
        let (mut pool, ids, ks, vs) = filled_pool(pt, hd, tokens, 0x17A);
        let mut pc = KvPanelCache::new();
        pc.ensure(&pool, None, hd, &ids);
        assert_eq!(pc.decode_count(), ids.len() as u64);

        // V rows and K^T panels reproduce the appended history exactly.
        let view = pc.kt_view(&ids, tokens);
        let mut scratch = Vec::new();
        for j in 0..tokens {
            assert_eq!(pc.v_row(&ids, j), &vs[j][..], "v row {j}");
        }
        for j0 in (0..tokens).step_by(NR) {
            let panel = view.panel(j0, 0, hd, &mut scratch).to_vec();
            for kk in 0..hd {
                for jr in 0..NR {
                    let want = if j0 + jr < tokens { ks[j0 + jr][kk] } else { 0.0 };
                    assert_eq!(panel[kk * NR + jr].to_bits(), want.to_bits(), "k^T[{kk}][{}]", j0 + jr);
                }
            }
        }

        // Re-ensure: pure hits, no decodes.
        pc.ensure(&pool, None, hd, &ids);
        assert_eq!(pc.decode_count(), ids.len() as u64, "unchanged pages re-decoded");
        assert!(pc.hit_count() >= ids.len() as u64);

        // Append to the frontier page → only that page re-decodes.
        let k = vec![1.5f32; hd];
        pool.get_mut(*ids.last().unwrap()).append(pt, hd, None, &k, &k);
        pc.ensure(&pool, None, hd, &ids);
        assert_eq!(pc.decode_count(), ids.len() as u64 + 1, "append should stale exactly one page");
        assert_eq!(pc.v_row(&ids, tokens), &k[..]);
    }

    #[test]
    fn realloc_and_pool_switch_invalidate() {
        let (pt, hd) = (2usize, 4usize);
        let (mut pool, ids, _, _) = filled_pool(pt, hd, 4, 0x17B);
        let mut pc = KvPanelCache::new();
        pc.ensure(&pool, None, hd, &ids);
        let base = pc.decode_count();

        // Free + realloc reuses the id; the entry must not survive.
        pool.free(ids[0]);
        let again = pool.alloc();
        assert_eq!(again, ids[0]);
        pool.get_mut(again).append(pt, hd, None, &[9.0; 4], &[8.0; 4]);
        pc.ensure(&pool, None, hd, &[again]);
        assert_eq!(pc.decode_count(), base + 1, "recycled page served from stale cache");
        assert_eq!(pc.v_row(&[again], 0), &[8.0; 4]);

        // A different pool under the same ids drops everything.
        let (pool2, ids2, _, vs2) = filled_pool(pt, hd, 4, 0x17C);
        pc.ensure(&pool2, None, hd, &ids2);
        assert_eq!(pc.v_row(&ids2, 0), &vs2[0][..], "entries leaked across pools");
    }

    #[test]
    fn truncate_invalidates_cached_panels() {
        // Speculative-decode rollback truncates the boundary page in
        // place through `get_mut`, which bumps its generation: a panel
        // decoded before the rollback must re-decode, not serve the
        // rolled-back tail.
        let (pt, hd) = (4usize, 8usize);
        let (mut pool, ids, _, vs) = filled_pool(pt, hd, 7, 0x180); // 2 pages, frontier holds 3
        let mut pc = KvPanelCache::new();
        pc.ensure(&pool, None, hd, &ids);
        let base = pc.decode_count();
        let frontier = *ids.last().unwrap();
        pool.get_mut(frontier).truncate_to(1, None);
        let fresh_v = vec![4.25f32; hd];
        pool.get_mut(frontier).append(pt, hd, None, &fresh_v, &fresh_v);
        pc.ensure(&pool, None, hd, &ids);
        assert_eq!(pc.decode_count(), base + 1, "truncated page served from a stale panel");
        assert_eq!(pc.v_row(&ids, 4), &vs[4][..], "kept token corrupted by rollback");
        assert_eq!(pc.v_row(&ids, 5), &fresh_v[..], "rolled-back token still visible");
    }

    #[test]
    fn encoded_panels_bit_match_gather() {
        let (pt, hd) = (4usize, 16usize);
        let mut rng = Pcg32::seeded(0x17D);
        let sample = llm_like_sample(&mut rng, hd * 32, 0.05, 4.0);
        let q = KvQuantizer::calibrated(hd, &sample, 5).unwrap();
        let mut pool = PagePool::new(pt, hd, true);
        let id = pool.alloc();
        for _ in 0..3 {
            let k = llm_like_sample(&mut rng, hd, 0.05, 4.0);
            let v = llm_like_sample(&mut rng, hd, 0.05, 4.0);
            pool.get_mut(id).append(pt, hd, Some(&q), &k, &v);
        }
        let mut pc = KvPanelCache::new();
        pc.ensure(&pool, Some(&q), hd, &[id]);
        let (mut gk, mut gv) = (vec![0.0f32; 3 * hd], vec![0.0f32; 3 * hd]);
        pool.get(id).gather(hd, Some(&q), Plane::K, &mut gk);
        pool.get(id).gather(hd, Some(&q), Plane::V, &mut gv);
        let view = pc.kt_view(&[id], 3);
        let mut scratch = Vec::new();
        let panel = view.panel(0, 0, hd, &mut scratch);
        for j in 0..3 {
            for kk in 0..hd {
                assert_eq!(panel[kk * NR + j].to_bits(), gk[j * hd + kk].to_bits(), "K tok {j} dim {kk}");
            }
            for kk in 0..hd {
                assert_eq!(pc.v_row(&[id], j)[kk].to_bits(), gv[j * hd + kk].to_bits(), "V tok {j} dim {kk}");
            }
        }
    }

    #[test]
    fn full_page_at_nr_tokens_lends_its_panel_without_copying() {
        let (pt, hd) = (NR, 8usize);
        let (pool, ids, ks, _) = filled_pool(pt, hd, NR, 0x17E);
        let mut pc = KvPanelCache::new();
        pc.ensure(&pool, None, hd, &ids);
        let view = pc.kt_view(&ids, NR);
        let mut scratch = Vec::new();
        let panel = view.panel(0, 0, hd, &mut scratch);
        assert!(scratch.is_empty(), "fast path materialized into scratch");
        assert!(hd <= KC);
        for kk in 0..hd {
            for j in 0..NR {
                assert_eq!(panel[kk * NR + j].to_bits(), ks[j][kk].to_bits());
            }
        }
    }

    #[test]
    fn budget_evicts_lru_and_recycles_buffers_but_never_the_current_run() {
        let (pt, hd) = (2usize, 4usize);
        let (pool, ids, _, vs) = filled_pool(pt, hd, 8, 0x17F); // 4 pages
        let mut pc = KvPanelCache::new();
        let entry_bytes = 2 * hd * pt * 4;
        pc.set_budget_bytes(2 * entry_bytes);

        // A run larger than the budget stays resident (round pinning)…
        pc.ensure(&pool, None, hd, &ids);
        assert_eq!(pc.resident_bytes(), 4 * entry_bytes, "current run must not be evicted");
        for j in 0..8 {
            assert_eq!(pc.v_row(&ids, j), &vs[j][..]);
        }
        // …and the next smaller run evicts down to budget, recycling.
        let allocs = pc.buffer_alloc_count();
        pc.ensure(&pool, None, hd, &ids[..1]);
        assert!(pc.resident_bytes() <= 2 * entry_bytes, "budget not enforced");
        let decodes = pc.decode_count();
        pc.ensure(&pool, None, hd, &ids); // evicted pages re-decode from recycled buffers
        assert!(pc.decode_count() > decodes);
        assert_eq!(pc.buffer_alloc_count(), allocs, "eviction churn allocated fresh buffers");
    }
}
