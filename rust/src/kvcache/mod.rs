//! Paged, BCQ-quantized KV cache (DESIGN.md §KV cache).
//!
//! The paper's block-cluster-codebook machinery extended from GEMM
//! operands to the attention state: cached K/V head vectors are stored
//! **encoded** (~4.9 bits/scalar at head_dim 64) in fixed-size pages with
//! free-list reuse, decoded per page through the same 16-entry codebook
//! LUTs `kernels::qgemm` uses. The incremental decode path
//! (`model::decode::{prefill, decode_step}`) appends to and attends
//! against this cache, so per-token attention work is O(current length)
//! instead of the full-forward O(t²) re-score.
//!
//! [`lut`] adds the encoded-domain attention seam: a per-page cache of
//! decoded `K^T`/V panels ([`KvPanelCache`]) that lets decode score
//! q·K straight off encoded pages through the blocked GEMM driver,
//! re-decoding only pages whose pool generation moved.

pub mod cache;
pub mod lut;
pub mod pool;
pub mod quant;

pub use cache::{KvLayout, KvStats, KvStore, PagedKvCache, SlotId};
pub use lut::{KtView, KvPanelCache};
pub use pool::{KvPressure, Page, PageId, PagePool, Plane};
pub use quant::{kv_cfg, KvQuantizer};
