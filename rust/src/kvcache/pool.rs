//! Fixed-size KV pages and the free-list page pool.
//!
//! One [`Page`] holds the K and V vectors of **one (layer, head)** for up
//! to `page_tokens` consecutive sequence positions — so a page's K plane
//! is exactly the contiguous `[tokens, head_dim]` matrix attention
//! consumes, with no per-head gather. Pages are append-only while owned
//! by a slot; freeing returns them to the pool's free list where the
//! next allocation reuses the storage (allocation-free steady state once
//! the pool has grown to the working set).

use super::quant::KvQuantizer;
use crate::quant::encode::BitWriter;

/// Index into the pool's page table.
pub type PageId = u32;

/// Which cached plane to address.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Plane {
    K,
    V,
}

/// Bit-packed encoded storage for one plane of one page: codeword and
/// selector streams (same `BitWriter` the Fig. 5 wire format uses) plus
/// one f32 inverse effective scale per stored vector.
#[derive(Debug, Default)]
pub struct EncPlane {
    pub codes: BitWriter,
    pub sels: BitWriter,
    pub invs: Vec<f32>,
}

impl EncPlane {
    fn clear(&mut self) {
        self.codes.clear();
        self.sels.clear();
        self.invs.clear();
    }

    fn bytes(&self) -> usize {
        self.codes.as_bytes().len() + self.sels.as_bytes().len() + self.invs.len() * 4
    }
}

/// Page payload: raw f32 vectors or LO-BCQ-encoded planes.
#[derive(Debug)]
pub enum PageStore {
    /// `page_tokens * head_dim` floats per plane, filled prefix valid.
    F32 { k: Vec<f32>, v: Vec<f32> },
    /// Encoded planes (see [`EncPlane`]).
    Encoded { k: EncPlane, v: EncPlane },
}

/// One (layer, head) page: storage plus the number of tokens written.
#[derive(Debug)]
pub struct Page {
    pub store: PageStore,
    /// Tokens written so far (≤ `page_tokens`).
    pub filled: usize,
}

impl Page {
    /// Actual bytes of cached state held by this page (encoded pages
    /// grow with fill; f32 pages are fully pre-sized).
    pub fn state_bytes(&self) -> usize {
        match &self.store {
            PageStore::F32 { k, v } => (k.len() + v.len()) * 4,
            PageStore::Encoded { k, v } => k.bytes() + v.bytes(),
        }
    }

    /// Append one token's K and V head vectors. Panics if full (the
    /// cache allocates a fresh page at every `page_tokens` boundary).
    pub fn append(&mut self, page_tokens: usize, head_dim: usize, quant: Option<&KvQuantizer>, kv: &[f32], vv: &[f32]) {
        assert!(self.filled < page_tokens, "append to a full page");
        assert_eq!(kv.len(), head_dim);
        assert_eq!(vv.len(), head_dim);
        match (&mut self.store, quant) {
            (PageStore::F32 { k, v }, None) => {
                let o = self.filled * head_dim;
                k[o..o + head_dim].copy_from_slice(kv);
                v[o..o + head_dim].copy_from_slice(vv);
            }
            (PageStore::Encoded { k, v }, Some(q)) => {
                q.encode_vector(kv, &mut k.codes, &mut k.sels, &mut k.invs);
                q.encode_vector(vv, &mut v.codes, &mut v.sels, &mut v.invs);
            }
            _ => panic!("page store / quantizer mode mismatch"),
        }
        self.filled += 1;
    }

    /// Decode this page's filled prefix of `plane` into `out`
    /// (`filled * head_dim` floats).
    pub fn gather(&self, head_dim: usize, quant: Option<&KvQuantizer>, plane: Plane, out: &mut [f32]) {
        assert_eq!(out.len(), self.filled * head_dim);
        match (&self.store, quant) {
            (PageStore::F32 { k, v }, None) => {
                let src = if plane == Plane::K { k } else { v };
                out.copy_from_slice(&src[..self.filled * head_dim]);
            }
            (PageStore::Encoded { k, v }, Some(q)) => {
                let p = if plane == Plane::K { k } else { v };
                q.decode_vectors(self.filled, p.codes.as_bytes(), p.sels.as_bytes(), &p.invs, out);
            }
            _ => panic!("page store / quantizer mode mismatch"),
        }
    }
}

/// Page allocator with free-list reuse. Grows on demand; never shrinks
/// (freed pages keep their storage for the next request).
#[derive(Debug)]
pub struct PagePool {
    pages: Vec<Page>,
    free: Vec<PageId>,
    page_tokens: usize,
    head_dim: usize,
    encoded: bool,
    /// High-water mark of pages simultaneously owned by live slots.
    peak_live: usize,
}

impl PagePool {
    pub fn new(page_tokens: usize, head_dim: usize, encoded: bool) -> PagePool {
        assert!(page_tokens >= 1 && head_dim >= 1);
        PagePool { pages: Vec::new(), free: Vec::new(), page_tokens, head_dim, encoded, peak_live: 0 }
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Allocate a page, reusing a freed one when available.
    pub fn alloc(&mut self) -> PageId {
        let id = if let Some(id) = self.free.pop() {
            debug_assert_eq!(self.pages[id as usize].filled, 0, "freed page not cleared");
            id
        } else {
            let store = if self.encoded {
                PageStore::Encoded { k: EncPlane::default(), v: EncPlane::default() }
            } else {
                let n = self.page_tokens * self.head_dim;
                PageStore::F32 { k: vec![0.0; n], v: vec![0.0; n] }
            };
            self.pages.push(Page { store, filled: 0 });
            (self.pages.len() - 1) as PageId
        };
        // Live count only grows inside alloc, so sampling here keeps the
        // high-water mark exact without a counter on the free path.
        self.peak_live = self.peak_live.max(self.live_pages());
        id
    }

    /// Return a page to the free list (contents cleared, storage kept).
    pub fn free(&mut self, id: PageId) {
        let page = &mut self.pages[id as usize];
        page.filled = 0;
        match &mut page.store {
            PageStore::F32 { .. } => {} // overwritten by the next owner's appends
            PageStore::Encoded { k, v } => {
                k.clear();
                v.clear();
            }
        }
        debug_assert!(!self.free.contains(&id), "double free of page {id}");
        self.free.push(id);
    }

    pub fn get(&self, id: PageId) -> &Page {
        &self.pages[id as usize]
    }

    pub fn get_mut(&mut self, id: PageId) -> &mut Page {
        &mut self.pages[id as usize]
    }

    /// Pages ever created.
    pub fn capacity_pages(&self) -> usize {
        self.pages.len()
    }

    /// Pages currently owned by live slots.
    pub fn live_pages(&self) -> usize {
        self.pages.len() - self.free.len()
    }

    /// High-water mark of [`live_pages`](Self::live_pages) — the page
    /// working set a deployment must provision for.
    pub fn peak_live_pages(&self) -> usize {
        self.peak_live
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_reuses_freed_pages() {
        let mut pool = PagePool::new(4, 8, false);
        let a = pool.alloc();
        let b = pool.alloc();
        assert_ne!(a, b);
        assert_eq!(pool.capacity_pages(), 2);
        pool.free(a);
        assert_eq!(pool.live_pages(), 1);
        let c = pool.alloc();
        assert_eq!(c, a, "free list not reused");
        assert_eq!(pool.capacity_pages(), 2, "pool grew despite free page");
    }

    #[test]
    fn peak_live_pages_tracks_high_water_not_current() {
        let mut pool = PagePool::new(4, 8, false);
        let a = pool.alloc();
        let b = pool.alloc();
        assert_eq!(pool.peak_live_pages(), 2);
        pool.free(a);
        pool.free(b);
        assert_eq!(pool.live_pages(), 0);
        assert_eq!(pool.peak_live_pages(), 2, "peak forgot the high-water mark");
        let _ = pool.alloc();
        assert_eq!(pool.peak_live_pages(), 2, "peak moved without a new high");
    }

    #[test]
    fn f32_page_round_trip_and_partial_fill() {
        let (pt, hd) = (4usize, 8usize);
        let mut pool = PagePool::new(pt, hd, false);
        let id = pool.alloc();
        let k0: Vec<f32> = (0..hd).map(|i| i as f32).collect();
        let v0: Vec<f32> = (0..hd).map(|i| -(i as f32)).collect();
        pool.get_mut(id).append(pt, hd, None, &k0, &v0);
        let k1: Vec<f32> = (0..hd).map(|i| 10.0 + i as f32).collect();
        pool.get_mut(id).append(pt, hd, None, &k1, &v0);
        let page = pool.get(id);
        assert_eq!(page.filled, 2);
        let mut out = vec![0.0f32; 2 * hd];
        page.gather(hd, None, Plane::K, &mut out);
        assert_eq!(&out[..hd], &k0[..]);
        assert_eq!(&out[hd..], &k1[..]);
        page.gather(hd, None, Plane::V, &mut out);
        assert_eq!(&out[..hd], &v0[..]);
        assert_eq!(page.state_bytes(), 2 * pt * hd * 4);
    }

    #[test]
    #[should_panic(expected = "append to a full page")]
    fn overfull_page_panics() {
        let mut pool = PagePool::new(1, 4, false);
        let id = pool.alloc();
        pool.get_mut(id).append(1, 4, None, &[1.0; 4], &[2.0; 4]);
        pool.get_mut(id).append(1, 4, None, &[1.0; 4], &[2.0; 4]);
    }
}
