//! Fixed-size KV pages and the free-list page pool.
//!
//! One [`Page`] holds the K and V vectors of **one (layer, head)** for up
//! to `page_tokens` consecutive sequence positions — so a page's K plane
//! is exactly the contiguous `[tokens, head_dim]` matrix attention
//! consumes, with no per-head gather. Pages are append-only while owned
//! by a slot; freeing returns them to the pool's free list where the
//! next allocation reuses the storage (allocation-free steady state once
//! the pool has grown to the working set).
//!
//! Pages are **refcounted** so the prefix cache can share one physical
//! page between the radix tree and any number of adopted slots:
//! [`alloc`](PagePool::alloc) hands out a page with one reference,
//! [`retain`](PagePool::retain) adds a reference, and
//! [`free`](PagePool::free) drops one — storage only returns to the free
//! list when the last reference goes. A page with a single reference is
//! **owned** (mutable: its holder may append); with more it is **shared**
//! (immutable — [`get_mut`](PagePool::get_mut) debug-asserts exclusive
//! ownership, so a write to a page another slot can see is caught in
//! debug builds rather than silently corrupting a neighbour's history).

use super::quant::KvQuantizer;
use crate::quant::encode::{BitReader, BitWriter};
use std::sync::atomic::{AtomicU64, Ordering};

/// Index into the pool's page table.
pub type PageId = u32;

/// Process-wide pool id source — every [`PagePool`] gets a distinct
/// nonzero [`instance_id`](PagePool::instance_id), so caches keyed on
/// `PageId` (the decode panel cache) can tell two pools' ids apart.
static POOL_INSTANCES: AtomicU64 = AtomicU64::new(1);

/// Which cached plane to address.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Plane {
    K,
    V,
}

/// Typed "out of KV pages" error: the pool's page budget cannot cover an
/// allocation. Carried as the **source** of the `anyhow::Result` chain
/// (via `?` / `From`), so the serving coordinator can
/// `err.downcast_ref::<KvPressure>()` and run its degradation ladder
/// (evict prefix cache → defer admission → preempt a lane) instead of
/// failing the request like a genuine fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPressure {
    /// Pages the failed operation needed.
    pub needed: usize,
    /// Pages the pool could still hand out (free list + budget headroom).
    pub headroom: usize,
}

impl std::fmt::Display for KvPressure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KV page budget exhausted: need {} pages, {} available", self.needed, self.headroom)
    }
}

impl std::error::Error for KvPressure {}

/// Bit-packed encoded storage for one plane of one page: codeword and
/// selector streams (same `BitWriter` the Fig. 5 wire format uses) plus
/// one f32 inverse effective scale per stored vector.
#[derive(Debug, Default)]
pub struct EncPlane {
    pub codes: BitWriter,
    pub sels: BitWriter,
    pub invs: Vec<f32>,
}

impl EncPlane {
    fn clear(&mut self) {
        self.codes.clear();
        self.sels.clear();
        self.invs.clear();
    }

    fn bytes(&self) -> usize {
        self.codes.as_bytes().len() + self.sels.as_bytes().len() + self.invs.len() * 4
    }
}

/// Page payload: raw f32 vectors or LO-BCQ-encoded planes.
#[derive(Debug)]
pub enum PageStore {
    /// `page_tokens * head_dim` floats per plane, filled prefix valid.
    F32 { k: Vec<f32>, v: Vec<f32> },
    /// Encoded planes (see [`EncPlane`]).
    Encoded { k: EncPlane, v: EncPlane },
}

/// One (layer, head) page: storage plus the number of tokens written.
#[derive(Debug)]
pub struct Page {
    pub store: PageStore,
    /// Tokens written so far (≤ `page_tokens`).
    pub filled: usize,
}

impl Page {
    /// Actual bytes of cached state held by this page (encoded pages
    /// grow with fill; f32 pages are fully pre-sized).
    pub fn state_bytes(&self) -> usize {
        match &self.store {
            PageStore::F32 { k, v } => (k.len() + v.len()) * 4,
            PageStore::Encoded { k, v } => k.bytes() + v.bytes(),
        }
    }

    /// Append one token's K and V head vectors. Panics if full (the
    /// cache allocates a fresh page at every `page_tokens` boundary).
    pub fn append(&mut self, page_tokens: usize, head_dim: usize, quant: Option<&KvQuantizer>, kv: &[f32], vv: &[f32]) {
        assert!(self.filled < page_tokens, "append to a full page");
        assert_eq!(kv.len(), head_dim);
        assert_eq!(vv.len(), head_dim);
        match (&mut self.store, quant) {
            (PageStore::F32 { k, v }, None) => {
                let o = self.filled * head_dim;
                k[o..o + head_dim].copy_from_slice(kv);
                v[o..o + head_dim].copy_from_slice(vv);
            }
            (PageStore::Encoded { k, v }, Some(q)) => {
                q.encode_vector(kv, &mut k.codes, &mut k.sels, &mut k.invs);
                q.encode_vector(vv, &mut v.codes, &mut v.sels, &mut v.invs);
            }
            _ => panic!("page store / quantizer mode mismatch"),
        }
        self.filled += 1;
    }

    /// Decode this page's filled prefix of `plane` into `out`
    /// (`filled * head_dim` floats).
    pub fn gather(&self, head_dim: usize, quant: Option<&KvQuantizer>, plane: Plane, out: &mut [f32]) {
        assert_eq!(out.len(), self.filled * head_dim);
        match (&self.store, quant) {
            (PageStore::F32 { k, v }, None) => {
                let src = if plane == Plane::K { k } else { v };
                out.copy_from_slice(&src[..self.filled * head_dim]);
            }
            (PageStore::Encoded { k, v }, Some(q)) => {
                let p = if plane == Plane::K { k } else { v };
                q.decode_vectors(self.filled, p.codes.as_bytes(), p.sels.as_bytes(), &p.invs, out);
            }
            _ => panic!("page store / quantizer mode mismatch"),
        }
    }

    /// Truncate to the first `m` token vectors — the page-level KV
    /// rollback primitive behind speculative decoding's reject path.
    /// f32 planes just shrink the valid prefix (the stale tail is
    /// overwritten by the next append and never read — `gather` stops at
    /// `filled`); encoded planes are append-only bit streams, so the
    /// kept prefix is replayed through a `BitReader` into fresh streams
    /// field by field, the same bit-exact mechanics as the CoW prefix
    /// copy: a truncated-then-reappended page is indistinguishable from
    /// one that never held the tail.
    pub fn truncate_to(&mut self, m: usize, quant: Option<&KvQuantizer>) {
        assert!(m <= self.filled, "truncate to {m} of a page holding {}", self.filled);
        if m == self.filled {
            return;
        }
        match (&mut self.store, quant) {
            (PageStore::F32 { .. }, None) => {}
            (PageStore::Encoded { k, v }, Some(q)) => {
                truncate_plane_to(k, m, q);
                truncate_plane_to(v, m, q);
            }
            _ => panic!("page store / quantizer mode mismatch"),
        }
        self.filled = m;
    }

    /// Copy-on-write seed: fill this (empty) page with the first `m`
    /// token vectors of `src` — the divergence-inside-a-page case of
    /// prefix adoption, where a request shares only part of a cached
    /// page and must append into a private copy. f32 planes memcpy;
    /// encoded planes copy the **bit streams** field by field (codes,
    /// selectors, inverse scales), so the copy is bit-identical to the
    /// source prefix with no decode/re-encode round trip (a re-encode
    /// would recompute the effective scale from already-quantized values
    /// and break bit-exactness).
    fn copy_prefix_from(&mut self, src: &Page, m: usize, head_dim: usize, quant: Option<&KvQuantizer>) {
        assert_eq!(self.filled, 0, "CoW copy into a non-empty page");
        assert!(m <= src.filled, "copy {m} tokens from a page holding {}", src.filled);
        match (&mut self.store, &src.store, quant) {
            (PageStore::F32 { k, v }, PageStore::F32 { k: sk, v: sv }, None) => {
                let n = m * head_dim;
                k[..n].copy_from_slice(&sk[..n]);
                v[..n].copy_from_slice(&sv[..n]);
            }
            (PageStore::Encoded { k, v }, PageStore::Encoded { k: sk, v: sv }, Some(q)) => {
                copy_plane_prefix(k, sk, m, q);
                copy_plane_prefix(v, sv, m, q);
            }
            _ => panic!("page store / quantizer mode mismatch"),
        }
        self.filled = m;
    }
}

/// Copy the first `m` vectors of an encoded plane: vector `i`'s codes
/// start at bit `i * head_dim * B` and its selectors at bit
/// `i * (head_dim / L_b) * sel_bits` (the append-only stream layout
/// `KvQuantizer::encode_vector` guarantees), so replaying the fields
/// through a `BitReader` reproduces the source prefix bit for bit even
/// when `m` vectors end mid-byte.
fn copy_plane_prefix(dst: &mut EncPlane, src: &EncPlane, m: usize, q: &KvQuantizer) {
    let (hd, lb, b) = (q.head_dim(), q.cfg().lb, q.cfg().b);
    let sel_bits = q.sel_bits();
    let mut cr = BitReader::new(src.codes.as_bytes());
    for _ in 0..m * hd {
        dst.codes.push(cr.read(b), b);
    }
    if sel_bits > 0 {
        let mut sr = BitReader::new(src.sels.as_bytes());
        for _ in 0..m * (hd / lb) {
            dst.sels.push(sr.read(sel_bits), sel_bits);
        }
    }
    dst.invs.extend_from_slice(&src.invs[..m]);
}

/// Rebuild an encoded plane holding only its first `m` vectors: take the
/// streams out, replay the prefix into the (now-empty) writers. The
/// replay reuses [`copy_plane_prefix`]'s layout guarantee.
fn truncate_plane_to(plane: &mut EncPlane, m: usize, q: &KvQuantizer) {
    let src = std::mem::take(plane);
    copy_plane_prefix(plane, &src, m, q);
}

/// Page allocator with free-list reuse and per-page refcounts. Grows on
/// demand; never shrinks (freed pages keep their storage for the next
/// request).
#[derive(Debug)]
pub struct PagePool {
    pages: Vec<Page>,
    /// References per page: 0 = on the free list, 1 = exclusively owned
    /// (mutable), >1 = shared between the prefix tree and/or slots.
    refs: Vec<u32>,
    /// Monotonic generation per page, bumped on every mutation path
    /// (realloc, mutable access, CoW seed) — how the decode panel cache
    /// detects that a cached decode of a page went stale.
    gens: Vec<u64>,
    gen_clock: u64,
    free: Vec<PageId>,
    page_tokens: usize,
    head_dim: usize,
    encoded: bool,
    /// Page-capacity budget (`None` = unbounded, the historical
    /// behaviour): [`try_alloc`](Self::try_alloc) refuses to grow the
    /// page table past this many pages. Freed pages stay reusable, so
    /// the budget caps *physical* page storage, not churn.
    budget_pages: Option<usize>,
    /// High-water mark of pages simultaneously owned by live slots.
    peak_live: usize,
    /// Process-unique nonzero id (see [`instance_id`](Self::instance_id)).
    instance: u64,
}

impl PagePool {
    pub fn new(page_tokens: usize, head_dim: usize, encoded: bool) -> PagePool {
        assert!(page_tokens >= 1 && head_dim >= 1);
        PagePool {
            pages: Vec::new(),
            refs: Vec::new(),
            gens: Vec::new(),
            gen_clock: 0,
            free: Vec::new(),
            page_tokens,
            head_dim,
            encoded,
            budget_pages: None,
            peak_live: 0,
            instance: POOL_INSTANCES.fetch_add(1, Ordering::Relaxed),
        }
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Process-unique nonzero id for this pool. `PageId`s are indices,
    /// so a cache keyed on them (the decode panel cache survives across
    /// `PagedKvCache` instances inside one `DecodeScratch`) must also
    /// compare pool identity to avoid reading another pool's entries.
    pub fn instance_id(&self) -> u64 {
        self.instance
    }

    /// Current generation of `id` — changes whenever the page *may* have
    /// been mutated (fresh allocation, `get_mut` access, CoW seed). A
    /// cache holding a decoded copy of a page revalidates against this.
    pub fn gen(&self, id: PageId) -> u64 {
        self.gens[id as usize]
    }

    fn bump_gen(&mut self, id: PageId) {
        self.gen_clock += 1;
        self.gens[id as usize] = self.gen_clock;
    }

    /// Set (or clear) the page-capacity budget. Lowering it below the
    /// current page-table size does not free anything — it only stops
    /// further growth; the free list keeps recycling existing pages.
    pub fn set_budget_pages(&mut self, budget: Option<usize>) {
        self.budget_pages = budget;
    }

    pub fn budget_pages(&self) -> Option<usize> {
        self.budget_pages
    }

    /// Pages the pool can still hand out without violating its budget:
    /// the free list plus the budget headroom (`usize::MAX` when
    /// unbudgeted). Callers that must allocate several pages atomically
    /// (one page group, one decode step) check this **before** the first
    /// allocation so a shortfall surfaces with nothing mutated.
    pub fn headroom_pages(&self) -> usize {
        match self.budget_pages {
            None => usize::MAX,
            Some(b) => self.free.len() + b.saturating_sub(self.pages.len()),
        }
    }

    /// Fail with a typed [`KvPressure`] error unless the pool can cover
    /// `needed` more pages (see [`headroom_pages`](Self::headroom_pages)).
    pub fn ensure_headroom(&self, needed: usize) -> anyhow::Result<()> {
        let headroom = self.headroom_pages();
        if headroom < needed {
            return Err(KvPressure { needed, headroom }.into());
        }
        Ok(())
    }

    /// Allocate a page (one reference), reusing a freed one when
    /// available; fails with a typed [`KvPressure`] error when the page
    /// budget is exhausted.
    pub fn try_alloc(&mut self) -> anyhow::Result<PageId> {
        self.ensure_headroom(1)?;
        Ok(self.alloc())
    }

    /// Infallible allocation — only correct when the pool is unbudgeted
    /// or the caller pre-checked [`ensure_headroom`](Self::ensure_headroom);
    /// a budget violation here is a bookkeeping bug, caught in debug.
    pub fn alloc(&mut self) -> PageId {
        debug_assert!(
            self.headroom_pages() >= 1,
            "alloc past the page budget (headroom pre-check missing)"
        );
        let id = if let Some(id) = self.free.pop() {
            debug_assert_eq!(self.pages[id as usize].filled, 0, "freed page not cleared");
            debug_assert_eq!(self.refs[id as usize], 0, "free-listed page still referenced");
            id
        } else {
            let store = if self.encoded {
                PageStore::Encoded { k: EncPlane::default(), v: EncPlane::default() }
            } else {
                let n = self.page_tokens * self.head_dim;
                PageStore::F32 { k: vec![0.0; n], v: vec![0.0; n] }
            };
            self.pages.push(Page { store, filled: 0 });
            self.refs.push(0);
            self.gens.push(0);
            (self.pages.len() - 1) as PageId
        };
        self.refs[id as usize] = 1;
        // A recycled id is a different logical page: invalidate any
        // cached decode of the previous owner's contents.
        self.bump_gen(id);
        // Live count only grows inside alloc, so sampling here keeps the
        // high-water mark exact without a counter on the free path.
        self.peak_live = self.peak_live.max(self.live_pages());
        id
    }

    /// Add a reference to a live page (prefix-tree publish / slot
    /// adoption). The page becomes shared and therefore immutable until
    /// references drop back to one.
    pub fn retain(&mut self, id: PageId) {
        assert!(self.refs[id as usize] > 0, "retain of a free page {id}");
        self.refs[id as usize] += 1;
    }

    /// References currently held on `id` (0 = free-listed).
    pub fn ref_count(&self, id: PageId) -> u32 {
        self.refs[id as usize]
    }

    /// Whether more than one holder references `id`.
    pub fn is_shared(&self, id: PageId) -> bool {
        self.refs[id as usize] > 1
    }

    /// Drop one reference. Storage returns to the free list (contents
    /// cleared, allocation kept) only when the **last** reference goes.
    /// Releasing a page that has no references is a double free — the
    /// debug assert below turns the silent pool corruption (one page
    /// handed to two owners) into an immediate failure; the refcount
    /// floor at zero keeps release builds from wrapping.
    pub fn free(&mut self, id: PageId) {
        let rc = &mut self.refs[id as usize];
        debug_assert!(*rc > 0, "double free of page {id} (no references held)");
        if *rc == 0 {
            return; // release-build double free: refuse rather than corrupt
        }
        *rc -= 1;
        if *rc > 0 {
            return; // still referenced by the tree or another slot
        }
        let page = &mut self.pages[id as usize];
        page.filled = 0;
        match &mut page.store {
            PageStore::F32 { .. } => {} // overwritten by the next owner's appends
            PageStore::Encoded { k, v } => {
                k.clear();
                v.clear();
            }
        }
        debug_assert!(!self.free.contains(&id), "double free of page {id} (already free-listed)");
        self.free.push(id);
    }

    pub fn get(&self, id: PageId) -> &Page {
        &self.pages[id as usize]
    }

    /// Mutable page access — only legal on an exclusively-owned page
    /// (refcount exactly 1): shared pages may be read by other slots or
    /// the prefix tree, so mutating one would corrupt a neighbour's
    /// history.
    pub fn get_mut(&mut self, id: PageId) -> &mut Page {
        debug_assert_eq!(
            self.refs[id as usize],
            1,
            "mutable access to page {id} with {} references",
            self.refs[id as usize]
        );
        // Conservative: any mutable access may append, so stale any
        // cached decode. Full (immutable-in-practice) pages are never
        // handed out mutably by the cache layer, so their gens settle.
        self.bump_gen(id);
        &mut self.pages[id as usize]
    }

    /// Seed `dst` (a fresh, empty, exclusively-owned page) with the
    /// first `m` token vectors of `src` — the copy-on-write step of
    /// prefix adoption. Bit-identical to the source prefix (see
    /// [`Page::copy_prefix_from`]).
    pub fn copy_prefix(&mut self, src: PageId, dst: PageId, m: usize, quant: Option<&KvQuantizer>) {
        assert_ne!(src, dst, "CoW copy onto the source page");
        debug_assert_eq!(self.refs[dst as usize], 1, "CoW target must be exclusively owned");
        // This path writes dst without going through get_mut.
        self.bump_gen(dst);
        let (s, d) = (src as usize, dst as usize);
        let (from, to) = if s < d {
            let (lo, hi) = self.pages.split_at_mut(d);
            (&lo[s], &mut hi[0])
        } else {
            let (lo, hi) = self.pages.split_at_mut(s);
            (&hi[0], &mut lo[d])
        };
        to.copy_prefix_from(from, m, self.head_dim, quant);
    }

    /// Pages ever created.
    pub fn capacity_pages(&self) -> usize {
        self.pages.len()
    }

    /// Pages currently owned by live slots.
    pub fn live_pages(&self) -> usize {
        self.pages.len() - self.free.len()
    }

    /// High-water mark of [`live_pages`](Self::live_pages) — the page
    /// working set a deployment must provision for.
    pub fn peak_live_pages(&self) -> usize {
        self.peak_live
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_reuses_freed_pages() {
        let mut pool = PagePool::new(4, 8, false);
        let a = pool.alloc();
        let b = pool.alloc();
        assert_ne!(a, b);
        assert_eq!(pool.capacity_pages(), 2);
        pool.free(a);
        assert_eq!(pool.live_pages(), 1);
        let c = pool.alloc();
        assert_eq!(c, a, "free list not reused");
        assert_eq!(pool.capacity_pages(), 2, "pool grew despite free page");
    }

    #[test]
    fn peak_live_pages_tracks_high_water_not_current() {
        let mut pool = PagePool::new(4, 8, false);
        let a = pool.alloc();
        let b = pool.alloc();
        assert_eq!(pool.peak_live_pages(), 2);
        pool.free(a);
        pool.free(b);
        assert_eq!(pool.live_pages(), 0);
        assert_eq!(pool.peak_live_pages(), 2, "peak forgot the high-water mark");
        let _ = pool.alloc();
        assert_eq!(pool.peak_live_pages(), 2, "peak moved without a new high");
    }

    #[test]
    fn f32_page_round_trip_and_partial_fill() {
        let (pt, hd) = (4usize, 8usize);
        let mut pool = PagePool::new(pt, hd, false);
        let id = pool.alloc();
        let k0: Vec<f32> = (0..hd).map(|i| i as f32).collect();
        let v0: Vec<f32> = (0..hd).map(|i| -(i as f32)).collect();
        pool.get_mut(id).append(pt, hd, None, &k0, &v0);
        let k1: Vec<f32> = (0..hd).map(|i| 10.0 + i as f32).collect();
        pool.get_mut(id).append(pt, hd, None, &k1, &v0);
        let page = pool.get(id);
        assert_eq!(page.filled, 2);
        let mut out = vec![0.0f32; 2 * hd];
        page.gather(hd, None, Plane::K, &mut out);
        assert_eq!(&out[..hd], &k0[..]);
        assert_eq!(&out[hd..], &k1[..]);
        page.gather(hd, None, Plane::V, &mut out);
        assert_eq!(&out[..hd], &v0[..]);
        assert_eq!(page.state_bytes(), 2 * pt * hd * 4);
    }

    #[test]
    #[should_panic(expected = "append to a full page")]
    fn overfull_page_panics() {
        let mut pool = PagePool::new(1, 4, false);
        let id = pool.alloc();
        pool.get_mut(id).append(1, 4, None, &[1.0; 4], &[2.0; 4]);
        pool.get_mut(id).append(1, 4, None, &[1.0; 4], &[2.0; 4]);
    }

    #[test]
    fn retained_page_survives_one_free_and_dies_on_the_last() {
        let mut pool = PagePool::new(2, 4, false);
        let id = pool.alloc();
        pool.get_mut(id).append(2, 4, None, &[1.0; 4], &[2.0; 4]);
        pool.retain(id);
        assert_eq!(pool.ref_count(id), 2);
        assert!(pool.is_shared(id));
        pool.free(id); // first holder lets go
        assert_eq!(pool.ref_count(id), 1);
        assert_eq!(pool.live_pages(), 1, "shared page freed too early");
        assert_eq!(pool.get(id).filled, 1, "contents cleared while still referenced");
        pool.free(id); // last holder
        assert_eq!(pool.ref_count(id), 0);
        assert_eq!(pool.live_pages(), 0);
        let again = pool.alloc();
        assert_eq!(again, id, "storage not recycled after last release");
        assert_eq!(pool.get(again).filled, 0);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_is_caught_in_debug_builds() {
        let mut pool = PagePool::new(2, 4, false);
        let id = pool.alloc();
        pool.free(id);
        pool.free(id);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "mutable access")]
    fn shared_pages_reject_mutation_in_debug_builds() {
        let mut pool = PagePool::new(2, 4, false);
        let id = pool.alloc();
        pool.retain(id);
        let _ = pool.get_mut(id);
    }

    #[test]
    fn generations_track_every_mutation_path() {
        let mut pool = PagePool::new(2, 4, false);
        let a = pool.alloc();
        let g0 = pool.gen(a);
        assert!(g0 > 0, "fresh page should start with a nonzero generation");
        pool.get_mut(a).append(2, 4, None, &[1.0; 4], &[2.0; 4]);
        let g1 = pool.gen(a);
        assert!(g1 > g0, "get_mut did not bump the generation");
        let b = pool.alloc();
        let gb0 = pool.gen(b);
        pool.copy_prefix(a, b, 1, None);
        assert!(pool.gen(b) > gb0, "CoW seed did not bump the target generation");
        let gb = pool.gen(b);
        let _ = pool.get(a);
        assert_eq!(pool.gen(a), g1, "reads must not bump generations");
        pool.free(a);
        let c = pool.alloc();
        assert_eq!(c, a, "free list not reused");
        assert!(pool.gen(c) > gb, "realloc did not bump the generation");
    }

    #[test]
    fn pools_have_distinct_instance_ids() {
        let p1 = PagePool::new(2, 4, false);
        let p2 = PagePool::new(2, 4, false);
        assert_ne!(p1.instance_id(), 0);
        assert_ne!(p1.instance_id(), p2.instance_id());
    }

    #[test]
    fn budget_bounds_growth_but_not_reuse() {
        let mut pool = PagePool::new(4, 8, false);
        assert_eq!(pool.headroom_pages(), usize::MAX);
        pool.set_budget_pages(Some(2));
        assert_eq!(pool.headroom_pages(), 2);
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        assert_eq!(pool.headroom_pages(), 0);
        let err = pool.try_alloc().unwrap_err();
        let p = err.downcast_ref::<KvPressure>().expect("not a typed pressure error");
        assert_eq!((p.needed, p.headroom), (1, 0));
        assert!(pool.ensure_headroom(1).is_err());
        // Freed pages come back under the same budget.
        pool.free(a);
        assert_eq!(pool.headroom_pages(), 1);
        let c = pool.try_alloc().unwrap();
        assert_eq!(c, a, "budgeted pool did not recycle the free list");
        assert_eq!(pool.capacity_pages(), 2, "budgeted pool grew instead of recycling");
        pool.free(b);
        pool.free(c);
        // Raising (or clearing) the budget restores growth.
        pool.set_budget_pages(None);
        assert!(pool.ensure_headroom(100).is_ok());
    }

    #[test]
    fn f32_copy_prefix_is_exact() {
        let (pt, hd) = (4usize, 8usize);
        let mut pool = PagePool::new(pt, hd, false);
        let src = pool.alloc();
        let rows: Vec<Vec<f32>> = (0..3).map(|t| (0..hd).map(|j| (t * hd + j) as f32).collect()).collect();
        for r in &rows {
            let neg: Vec<f32> = r.iter().map(|x| -x).collect();
            pool.get_mut(src).append(pt, hd, None, r, &neg);
        }
        let dst = pool.alloc();
        pool.copy_prefix(src, dst, 2, None);
        let page = pool.get(dst);
        assert_eq!(page.filled, 2);
        let mut out = vec![0.0f32; 2 * hd];
        page.gather(hd, None, Plane::K, &mut out);
        assert_eq!(&out[..hd], &rows[0][..]);
        assert_eq!(&out[hd..], &rows[1][..]);
        page.gather(hd, None, Plane::V, &mut out);
        assert_eq!(out[0], -rows[0][0]);
    }

    #[test]
    fn f32_truncate_then_reappend_matches_untruncated() {
        let (pt, hd) = (4usize, 8usize);
        let mut pool = PagePool::new(pt, hd, false);
        let id = pool.alloc();
        let rows: Vec<Vec<f32>> = (0..4).map(|t| (0..hd).map(|j| (t * hd + j) as f32).collect()).collect();
        for r in &rows[..3] {
            pool.get_mut(id).append(pt, hd, None, r, r);
        }
        pool.get_mut(id).truncate_to(1, None);
        assert_eq!(pool.get(id).filled, 1);
        // Refill with different rows: the stale tail must be invisible.
        pool.get_mut(id).append(pt, hd, None, &rows[3], &rows[3]);
        let mut out = vec![0.0f32; 2 * hd];
        pool.get(id).gather(hd, None, Plane::K, &mut out);
        assert_eq!(&out[..hd], &rows[0][..]);
        assert_eq!(&out[hd..], &rows[3][..]);
    }

    #[test]
    fn encoded_truncate_is_bit_identical_to_never_appended() {
        use crate::util::rng::{llm_like_sample, Pcg32};
        let (pt, hd) = (4usize, 16usize);
        let mut rng = Pcg32::seeded(0x7C2);
        let sample = llm_like_sample(&mut rng, hd * 32, 0.05, 4.0);
        let q = KvQuantizer::calibrated(hd, &sample, 7).unwrap();
        let mut pool = PagePool::new(pt, hd, true);
        let rows: Vec<Vec<f32>> = (0..4).map(|_| llm_like_sample(&mut rng, hd, 0.05, 4.0)).collect();
        // Twin pages: one appends 4 rows then truncates to 2 and
        // re-appends row 2'; the other only ever sees rows 0,1,2'.
        let spec = pool.alloc();
        let clean = pool.alloc();
        for r in &rows {
            pool.get_mut(spec).append(pt, hd, Some(&q), r, r);
        }
        pool.get_mut(spec).truncate_to(2, Some(&q));
        assert_eq!(pool.get(spec).filled, 2);
        let fresh = llm_like_sample(&mut rng, hd, 0.05, 4.0);
        pool.get_mut(spec).append(pt, hd, Some(&q), &fresh, &fresh);
        for r in [&rows[0], &rows[1], &fresh] {
            pool.get_mut(clean).append(pt, hd, Some(&q), r, r);
        }
        // Decoded planes (and the stored byte counts) must agree exactly.
        for plane in [Plane::K, Plane::V] {
            let (mut a, mut b) = (vec![0.0f32; 3 * hd], vec![0.0f32; 3 * hd]);
            pool.get(spec).gather(hd, Some(&q), plane, &mut a);
            pool.get(clean).gather(hd, Some(&q), plane, &mut b);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{plane:?} diverged at scalar {i}");
            }
        }
        assert_eq!(
            pool.get(spec).state_bytes(),
            pool.get(clean).state_bytes(),
            "truncated page retained tail bytes"
        );
    }

    #[test]
    fn encoded_copy_prefix_is_bit_identical_and_appendable() {
        use crate::util::rng::{llm_like_sample, Pcg32};
        // head_dim 16, L_b 8 → 6 selector bits per vector: vectors end
        // mid-byte, exercising the unaligned bit-stream copy.
        let (pt, hd) = (4usize, 16usize);
        let mut rng = Pcg32::seeded(0xC0E);
        let sample = llm_like_sample(&mut rng, hd * 32, 0.05, 4.0);
        let q = KvQuantizer::calibrated(hd, &sample, 7).unwrap();
        let mut pool = PagePool::new(pt, hd, true);
        let src = pool.alloc();
        let rows: Vec<Vec<f32>> = (0..3).map(|_| llm_like_sample(&mut rng, hd, 0.05, 4.0)).collect();
        for r in &rows {
            pool.get_mut(src).append(pt, hd, Some(&q), r, r);
        }
        let dst = pool.alloc();
        pool.copy_prefix(src, dst, 2, Some(&q));
        let (mut a, mut b) = (vec![0.0f32; 2 * hd], vec![0.0f32; 3 * hd]);
        pool.get(dst).gather(hd, Some(&q), Plane::K, &mut a);
        pool.get(src).gather(hd, Some(&q), Plane::K, &mut b);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "copied vector diverged at scalar {i}");
        }
        // The copy must be appendable: continue it with a new row and
        // check the appended vector decodes exactly like a fresh encode.
        pool.get_mut(dst).append(pt, hd, Some(&q), &rows[2], &rows[2]);
        let mut c = vec![0.0f32; 3 * hd];
        pool.get(dst).gather(hd, Some(&q), Plane::K, &mut c);
        for (i, (x, y)) in c.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "post-copy append diverged at scalar {i}");
        }
    }
}
