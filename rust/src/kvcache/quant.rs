//! Per-vector LO-BCQ quantization for cached K/V entries.
//!
//! KV entries are just more activation blocks (paper §3): each appended
//! K or V head vector (`head_dim` scalars) is treated as one block array
//! (`L_A = head_dim`) and quantized through exactly the machinery the
//! GEMM operands use — normalize against the vector's own scale (eq. 7/8
//! with the vector as the tensor, so the E4M3 relative scale is exactly
//! 1.0 and the effective scale is `s_X`), select a codebook per `L_b`
//! block (eq. 4), store one `B`-bit codeword index per scalar (eq. 2).
//! Decoding a vector is therefore **bit-exact** with
//! [`fake_quantize`](crate::quant::lobcq::fake_quantize) over that vector
//! (tested), the same contract `kernels::qgemm` keeps for weights.
//!
//! Storage per vector (the page planes bit-pack with the same
//! `BitWriter`/`BitReader` the Fig. 5 wire format uses):
//!
//! - `B` bits per scalar of codeword indices,
//! - `log2(N_c)` bits per block of codebook selectors,
//! - one f32 inverse effective scale (32 bits per vector).
//!
//! At the paper's serving head dims this lands at ≤ 5 bits/scalar:
//! `B + log2(N_c)/L_b + 32/head_dim` = 4 + 3/8 + 32/64 = **4.875** for
//! the defaults (B=4, N_c=8, L_b=8, head_dim=64) versus 32 for an f32
//! cache — the ratio the decode bench's peak-cache-bytes column reports.

use crate::quant::codebook::CodebookFamily;
use crate::quant::encode::{BitReader, BitWriter};
use crate::quant::lobcq::{tensor_scale, LobcqConfig};

/// Quantizer for fixed-length K/V head vectors (see module docs).
#[derive(Debug, Clone)]
pub struct KvQuantizer {
    cfg: LobcqConfig,
    family: CodebookFamily,
    head_dim: usize,
}

/// The KV-cache LO-BCQ shape for a head dimension: one block array per
/// vector (`L_A = head_dim`), `L_b` the largest power of two ≤ 8 that
/// divides it, paper-default `N_c = 8`, `B = 4`.
pub fn kv_cfg(head_dim: usize) -> LobcqConfig {
    let lb = [8usize, 4, 2, 1].into_iter().find(|lb| head_dim % lb == 0).unwrap();
    LobcqConfig::new(lb, 8, head_dim)
}

impl KvQuantizer {
    /// Wrap an already-calibrated (codeword-quantized) family — e.g. the
    /// same frozen universal books the weight path serves with.
    pub fn new(head_dim: usize, family: CodebookFamily) -> anyhow::Result<KvQuantizer> {
        anyhow::ensure!(head_dim >= 1, "head_dim must be >= 1");
        let cfg = kv_cfg(head_dim);
        cfg.validate()?;
        anyhow::ensure!(
            family.nc() == cfg.nc,
            "KV family has {} codebooks, cache layout needs {}",
            family.nc(),
            cfg.nc
        );
        anyhow::ensure!(family.b == cfg.b, "KV family B {} != cfg B {}", family.b, cfg.b);
        Ok(KvQuantizer { cfg, family, head_dim })
    }

    /// Calibrate a family on sample data (any `head_dim`-aligned flat
    /// buffer — in practice rows of the QKV projection weights, the same
    /// proxy-statistics protocol universal calibration uses, §4.1).
    pub fn calibrated(head_dim: usize, sample: &[f32], seed: u64) -> anyhow::Result<KvQuantizer> {
        let cfg = kv_cfg(head_dim);
        cfg.validate()?;
        anyhow::ensure!(
            !sample.is_empty() && sample.len() % head_dim == 0,
            "calibration sample ({} scalars) not a multiple of head_dim {head_dim}",
            sample.len()
        );
        let t = crate::tensor::Tensor::new(&[sample.len() / head_dim, head_dim], sample.to_vec());
        let opts = crate::quant::lobcq::CalibOpts { max_iters: 20, ..Default::default() };
        let family = crate::quant::calib::calibrate_universal(&[&t], &cfg, opts, seed);
        Self::new(head_dim, family)
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    pub fn cfg(&self) -> &LobcqConfig {
        &self.cfg
    }

    pub fn family(&self) -> &CodebookFamily {
        &self.family
    }

    /// Selector bits per block (`log2 N_c`).
    pub fn sel_bits(&self) -> u32 {
        self.cfg.nc.trailing_zeros()
    }

    /// Analytic stored bits per cached scalar:
    /// `B + sel_bits/L_b + 32/head_dim` (codes + selectors + f32 scale).
    pub fn bits_per_scalar(&self) -> f64 {
        self.cfg.b as f64
            + self.sel_bits() as f64 / self.cfg.lb as f64
            + 32.0 / self.head_dim as f64
    }

    /// Quantize one head vector, appending its codes/selectors to the
    /// plane streams and its inverse effective scale to `invs`. The
    /// streams are strictly append-only: vector `i`'s fields start at bit
    /// `i * head_dim * B` (codes) and `i * (head_dim / L_b) * sel_bits`
    /// (selectors), so a partially-filled page decodes from the front.
    pub fn encode_vector(&self, v: &[f32], codes: &mut BitWriter, sels: &mut BitWriter, invs: &mut Vec<f32>) {
        assert_eq!(v.len(), self.head_dim, "KV vector length {} != head_dim {}", v.len(), self.head_dim);
        let (lb, b, sel_bits) = (self.cfg.lb, self.cfg.b, self.sel_bits());
        let amax = crate::util::stats::amax(v);
        if amax == 0.0 {
            // All-zero vector: eq. 7 degenerate case. Zero-fill the
            // streams so later vectors stay bit-aligned; the stored
            // inverse scale 0.0 decodes to exact zeros.
            for _ in 0..v.len() / lb {
                if sel_bits > 0 {
                    sels.push(0, sel_bits);
                }
                for _ in 0..lb {
                    codes.push(0, b);
                }
            }
            invs.push(0.0);
            return;
        }
        // The vector is its own tensor AND its own block array, so
        // s_A == s_X, the E4M3 relative scale quantizes 1.0 → 1.0, and
        // the effective scale is exactly s_X (matching what
        // `quantize_arrays_into` computes for a [1, head_dim] tensor).
        let eff = tensor_scale(v, &self.cfg);
        invs.push(1.0 / eff);
        // Sampled encode telemetry (obs::quant_stats): reconstruction
        // NMSE plus selector occupancy, accumulated in stack locals and
        // recorded under one lock after the vector. Read-only on the
        // bit-streams; one relaxed load when telemetry is off.
        let sampled = crate::obs::quant_stats::sample_kv();
        let mut sum_err = 0.0f64;
        let mut sel_counts = [0u64; 16];
        let mut norm = [0.0f32; 8];
        for block in v.chunks_exact(lb) {
            let nb = &mut norm[..lb];
            for (o, &x) in nb.iter_mut().zip(block) {
                *o = x * eff;
            }
            let sel = self.family.select(nb);
            if sel_bits > 0 {
                sels.push(sel as u32, sel_bits);
            }
            let book = &self.family.books[sel];
            if sampled {
                sel_counts[sel.min(15)] += 1;
                for (&x, &orig) in nb.iter().zip(block) {
                    let code = book.encode(x);
                    codes.push(code as u32, b);
                    let recon = book.decode(code) / eff;
                    let d = orig as f64 - recon as f64;
                    sum_err += d * d;
                }
            } else {
                for &x in nb.iter() {
                    codes.push(book.encode(x) as u32, b);
                }
            }
        }
        if sampled {
            let nc = self.cfg.nc.min(sel_counts.len());
            crate::obs::quant_stats::record_kv(
                sum_err,
                crate::util::stats::sum_sq(v),
                v.len() as u64,
                &sel_counts[..nc],
            );
        }
    }

    /// Decode the first `n` vectors of a plane into `out` (`n * head_dim`
    /// floats). Values are bit-exact with `fake_quantize` over each
    /// source vector.
    pub fn decode_vectors(&self, n: usize, codes: &[u8], sels: &[u8], invs: &[f32], out: &mut [f32]) {
        assert!(n <= invs.len(), "decoding {n} vectors but only {} stored", invs.len());
        assert_eq!(out.len(), n * self.head_dim);
        let (lb, b, sel_bits) = (self.cfg.lb, self.cfg.b, self.sel_bits());
        let mut cr = BitReader::new(codes);
        let mut sr = BitReader::new(sels);
        for (vec_out, &inv) in out.chunks_exact_mut(self.head_dim).zip(invs.iter().take(n)) {
            for block in vec_out.chunks_exact_mut(lb) {
                let sel = if sel_bits > 0 { sr.read(sel_bits) as usize } else { 0 };
                if inv == 0.0 {
                    // Skip the codes but emit exact zeros.
                    for o in block.iter_mut() {
                        cr.read(b);
                        *o = 0.0;
                    }
                } else {
                    let book = &self.family.books[sel];
                    for o in block.iter_mut() {
                        *o = book.decode(cr.read(b) as usize) * inv;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::lobcq::fake_quantize;
    use crate::util::rng::{llm_like_sample, Pcg32};

    fn quantizer(hd: usize, seed: u64) -> KvQuantizer {
        let mut rng = Pcg32::seeded(seed);
        let sample = llm_like_sample(&mut rng, hd * 64, 0.05, 4.0);
        KvQuantizer::calibrated(hd, &sample, seed).unwrap()
    }

    #[test]
    fn round_trip_matches_fake_quantize_bitwise() {
        for hd in [16usize, 64] {
            let q = quantizer(hd, 0xCA5E ^ hd as u64);
            let mut rng = Pcg32::seeded(7 + hd as u64);
            let mut codes = BitWriter::new();
            let mut sels = BitWriter::new();
            let mut invs = Vec::new();
            let vectors: Vec<Vec<f32>> =
                (0..5).map(|_| llm_like_sample(&mut rng, hd, 0.05, 4.0)).collect();
            for v in &vectors {
                q.encode_vector(v, &mut codes, &mut sels, &mut invs);
            }
            let mut out = vec![0.0f32; 5 * hd];
            q.decode_vectors(5, codes.as_bytes(), sels.as_bytes(), &invs, &mut out);
            for (i, v) in vectors.iter().enumerate() {
                let want = fake_quantize(v, q.cfg(), &q.family);
                for (j, (&g, &w)) in out[i * hd..(i + 1) * hd].iter().zip(&want).enumerate() {
                    assert_eq!(g.to_bits(), w.to_bits(), "hd={hd} vec {i} scalar {j}: {g} vs {w}");
                }
            }
        }
    }

    #[test]
    fn zero_vector_keeps_streams_aligned_and_decodes_zero() {
        let hd = 16;
        let q = quantizer(hd, 3);
        let mut rng = Pcg32::seeded(9);
        let live = llm_like_sample(&mut rng, hd, 0.05, 4.0);
        let mut codes = BitWriter::new();
        let mut sels = BitWriter::new();
        let mut invs = Vec::new();
        q.encode_vector(&vec![0.0; hd], &mut codes, &mut sels, &mut invs);
        q.encode_vector(&live, &mut codes, &mut sels, &mut invs);
        let mut out = vec![1.0f32; 2 * hd];
        q.decode_vectors(2, codes.as_bytes(), sels.as_bytes(), &invs, &mut out);
        assert!(out[..hd].iter().all(|&x| x.to_bits() == 0.0f32.to_bits()), "zero vector leaked");
        let want = fake_quantize(&live, q.cfg(), &q.family);
        for (g, w) in out[hd..].iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits(), "live vector after a zero one corrupted");
        }
    }

    #[test]
    fn serving_head_dim_is_within_bit_budget() {
        let q = quantizer(64, 4);
        assert!(q.bits_per_scalar() <= 5.0, "{} bits/scalar", q.bits_per_scalar());
        assert_eq!(q.bits_per_scalar(), 4.0 + 3.0 / 8.0 + 0.5);
    }

    #[test]
    fn rejects_mismatched_family_and_bad_samples() {
        let q = quantizer(16, 5);
        // A 16-entry family for head_dim 16 does not fit head_dim 24's
        // L_b... it does; the failure mode is a sample misalignment.
        assert!(KvQuantizer::calibrated(16, &[1.0; 17], 0).is_err());
        assert!(KvQuantizer::calibrated(16, &[], 0).is_err());
        let _ = q;
    }
}
