//! # lobcq — Locally Optimal Block Clustered Quantization (W4A4)
//!
//! Production-quality reproduction of *LO-BCQ: Block Clustered Quantization
//! for 4-bit (W4A4) LLM Inference* as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! - **L1** (`python/compile/kernels/`): Pallas fake-quant + GEMM kernels.
//! - **L2** (`python/compile/model.py`): tiny-GPT forward in JAX, lowered
//!   AOT to HLO text artifacts.
//! - **L3** (this crate): the serving coordinator (router → dynamic
//!   batcher → scheduler → PJRT executor pool) with on-the-fly activation
//!   quantization, the full LO-BCQ algorithm + baselines, and the
//!   experiment harness reproducing every table and figure in the paper.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod formats;
pub mod tensor;
pub mod util;
pub mod quant;
pub mod data;
pub mod model;
pub mod runtime;
pub mod coordinator;
pub mod eval;
