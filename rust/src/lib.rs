//! # lobcq — Locally Optimal Block Clustered Quantization (W4A4)
//!
//! Production-quality reproduction of *LO-BCQ: Block Clustered Quantization
//! for 4-bit (W4A4) LLM Inference* as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! - **L1** (`python/compile/kernels/`): Pallas fake-quant + GEMM kernels.
//! - **L2** (`python/compile/model.py`): tiny-GPT forward in JAX, lowered
//!   AOT to HLO text artifacts.
//! - **L3** (this crate): the serving coordinator (router → dynamic
//!   batcher → scheduler → executor pool) with on-the-fly activation
//!   quantization, the full LO-BCQ algorithm + baselines, and the
//!   experiment harness reproducing every table and figure in the paper.
//!
//! Every quantizer — LO-BCQ and all five baselines — implements the one
//! [`QuantScheme`](quant::pipeline::QuantScheme) trait and runs through
//! the shared parallel in-place pipeline (`quant::pipeline`), so
//! calibration, every eval table, and the serving path exercise identical
//! code. The PJRT execution layer sits behind the off-by-default `pjrt`
//! cargo feature.
//!
//! See DESIGN.md for the system inventory (including the pipeline's
//! threading/buffer model) and EXPERIMENTS.md for paper-vs-measured
//! results.

// Style lints tuned for numeric-kernel code: indexed loops mirror the
// paper's equations and the Pallas kernels they must stay diffable with.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::identity_op,
    clippy::excessive_precision,
    clippy::uninlined_format_args,
    clippy::new_without_default,
    clippy::len_without_is_empty,
    clippy::type_complexity,
    clippy::manual_memcpy
)]

pub mod bench;
pub mod formats;
pub mod obs;
pub mod tensor;
pub mod util;
pub mod kernels;
pub mod kvcache;
pub mod prefixcache;
pub mod quant;
pub mod data;
pub mod model;
pub mod runtime;
pub mod coordinator;
pub mod eval;
