//! `lobcq` — leader binary: serving, evaluation, calibration, and the
//! experiment harness, all over the AOT artifacts (Python never runs on
//! the request path).

use lobcq::coordinator::{
    BatchPolicy, ContinuousOpts, CpuExecutor, DecodeSession, DrafterKind, KvCacheOpts, Limits, Priority,
    Sampling, Server,
};
use lobcq::data::corpus;
use lobcq::eval::{experiments, Env};
use lobcq::quant::calib::calibrate_universal;
use lobcq::quant::lobcq::{CalibOpts, LobcqConfig};
use lobcq::quant::pipeline::QuantPool;
use lobcq::runtime::Manifest;
use lobcq::tensor::Tensor;
use lobcq::util::cli::{render_help, Args, OptSpec};
use lobcq::util::json::Json;
use lobcq::util::rng::Pcg32;
use std::path::PathBuf;
use std::time::Instant;

fn main() {
    // Env-gated observability (`--trace`/`--metrics-out` enable the same
    // flags explicitly later; both paths are one relaxed load when off).
    lobcq::obs::trace::init_from_env();
    lobcq::obs::quant_stats::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&argv) {
        Ok(()) => 0,
        Err(e) => {
            lobcq::log_error!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(argv: &[String]) -> anyhow::Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    match cmd {
        "serve" => serve(rest),
        "serve-cpu" => serve_cpu(rest),
        "bench" => bench(rest),
        "eval" => eval(rest),
        "calibrate" => calibrate(rest),
        "gen-parity" => gen_parity(rest),
        "info" => info(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (try `lobcq help`)"),
    }
}

fn print_help() {
    println!(
        "lobcq — LO-BCQ W4A4 serving + experiment harness\n\n\
         commands:\n\
         \x20 serve       run the serving coordinator on a synthetic workload (PJRT)\n\
         \x20 serve-cpu   serve through the CPU decode engine: incremental decode\n\
         \x20             over a paged BCQ-quantized KV cache, continuous batching,\n\
         \x20             on-the-fly W4A4 activation quantization (no artifacts)\n\
         \x20 bench       run a paper experiment (--exp tab1..tab11, fig1..fig9, all),\n\
         \x20             or a declarative workload sweep (--workload workloads/<spec>.toml\n\
         \x20             [--sweep key=v1,v2,…]) emitting run-records into results/raw/\n\
         \x20 eval        perplexity of one artifact variant via PJRT\n\
         \x20 calibrate   run LO-BCQ calibration in rust, dump codebooks\n\
         \x20 gen-parity  emit cross-language parity vectors for pytest\n\
         \x20 info        summarize artifacts/manifest.json\n"
    );
}

fn artifacts_opt() -> OptSpec {
    OptSpec { name: "artifacts", help: "artifacts directory", takes_value: true, default: Some("artifacts") }
}

// ---- serve ----

#[cfg(not(feature = "pjrt"))]
fn serve(_argv: &[String]) -> anyhow::Result<()> {
    anyhow::bail!("`serve` needs the PJRT runtime: rebuild with --features pjrt (or use `serve-cpu`)")
}

#[cfg(feature = "pjrt")]
fn serve(argv: &[String]) -> anyhow::Result<()> {
    use lobcq::coordinator::PjrtExecutor;
    use lobcq::model::Weights;
    use lobcq::runtime::RuntimeService;
    let specs = [
        artifacts_opt(),
        OptSpec { name: "size", help: "model size (s|m|l)", takes_value: true, default: Some("m") },
        OptSpec { name: "variant", help: "artifact variant", takes_value: true, default: Some("lobcq_g64_nc8") },
        OptSpec { name: "requests", help: "synthetic request count", takes_value: true, default: Some("64") },
        OptSpec { name: "max-new", help: "tokens to generate per request", takes_value: true, default: Some("8") },
        OptSpec { name: "max-batch", help: "dynamic batch limit", takes_value: true, default: Some("8") },
        OptSpec { name: "max-wait-ms", help: "batcher wait", takes_value: true, default: Some("4") },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") {
        println!("{}", render_help("serve", "run the serving coordinator", &specs));
        return Ok(());
    }
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let size = args.str_or("size", "m").to_string();
    let variant = args.str_or("variant", "lobcq_g64_nc8").to_string();
    let n_requests = args.usize_or("requests", 64)?;
    let max_new = args.usize_or("max-new", 8)?;

    let env = Env::load_from(dir.clone());
    let manifest = Manifest::load(&dir)?;
    manifest.check_corpus_parity()?;
    let cfg = env.model_config(&size)?;
    let entry = manifest
        .find(&size, &variant, args.usize_or("max-batch", 8)?)
        .or_else(|| manifest.find(&size, &variant, 8))
        .ok_or_else(|| anyhow::anyhow!("no artifact {size}/{variant}"))?
        .clone();

    println!("[serve] starting runtime for {size}/{variant} (batch {})", entry.batch);
    let service = RuntimeService::start(&dir)?;
    let client = service.client();
    let weights = Weights::load(&manifest.weights_path(&size)?)?;
    let ordered: Vec<Tensor> = weights.ordered(&cfg)?.into_iter().cloned().collect();
    client.register_weights("w", &cfg, ordered)?;
    let books_key = if let Some(nc) = entry.books_nc {
        let fam = env.family(nc, 4, 6)?;
        client.register_books("books", Env::books_tensor(&fam))?;
        Some("books".to_string())
    } else {
        None
    };

    let exec = PjrtExecutor {
        client,
        entry: entry.clone(),
        weights_key: "w".into(),
        books_key,
        vocab: manifest.vocab,
    };
    let server = Server::start(
        exec,
        BatchPolicy {
            max_batch: entry.batch,
            max_wait: std::time::Duration::from_millis(args.u64_or("max-wait-ms", 4)?),
            queue_cap: None,
        },
        Limits { max_prompt: entry.t, max_new: 32, vocab: manifest.vocab as u32 },
        Sampling::Greedy,
    );

    // Synthetic client swarm.
    println!("[serve] firing {n_requests} requests (max_new {max_new})");
    let t0 = Instant::now();
    let server = std::sync::Arc::new(server);
    let mut handles = Vec::new();
    for i in 0..n_requests {
        let s = server.clone();
        handles.push(std::thread::spawn(move || {
            let prompt = corpus::generate(9000 + i as u64, 16);
            s.submit(prompt, max_new).unwrap().wait()
        }));
    }
    let mut ok = 0;
    for h in handles {
        if h.join().unwrap().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("[serve] {ok}/{n_requests} ok in {wall:.2}s");
    println!("[serve] {}", server.metrics.snapshot().report());
    if let Ok(s) = std::sync::Arc::try_unwrap(server) {
        s.shutdown();
    }
    Ok(())
}

// ---- serve-cpu ----

/// Serve through the CPU decode engine: weights quantized offline,
/// activations quantized on the fly at every GEMM by the unified
/// pipeline, and the attention state held in the paged — by default
/// BCQ-encoded — KV cache. The default `--engine continuous` path runs
/// the incremental `prefill`/`decode_step` forward with token-granular
/// backfill; `--engine batch` keeps the fixed-shape full-window executor
/// (the PJRT-compatible reference path).
fn serve_cpu(argv: &[String]) -> anyhow::Result<()> {
    let specs = [
        artifacts_opt(),
        OptSpec { name: "workload", help: "declarative workload spec file — serve its trace instead of the ad-hoc swarm (overrides scheme/kv/requests/… flags)", takes_value: true, default: None },
        OptSpec { name: "scheme", help: "bf16|lobcq|mx4|vsq|mxfp4", takes_value: true, default: Some("lobcq") },
        OptSpec { name: "engine", help: "continuous (cached decode) | batch (full-window executor)", takes_value: true, default: Some("continuous") },
        OptSpec { name: "kv", help: "KV cache store: bcq (~4.9 bits/scalar) | f32", takes_value: true, default: Some("bcq") },
        OptSpec { name: "page-tokens", help: "KV cache page size in tokens", takes_value: true, default: Some("16") },
        OptSpec { name: "prefix-cache", help: "cross-request prefix cache budget (bytes, k/m/g suffix ok) or 'off'", takes_value: true, default: Some("16m") },
        OptSpec { name: "prefix-k", help: "distinct system prompts in the synthetic workload", takes_value: true, default: Some("4") },
        OptSpec { name: "requests", help: "synthetic request count", takes_value: true, default: Some("32") },
        OptSpec { name: "max-new", help: "tokens to generate per request", takes_value: true, default: Some("4") },
        OptSpec { name: "max-batch", help: "dynamic batch limit / decode lanes", takes_value: true, default: Some("8") },
        OptSpec { name: "max-wait-ms", help: "batcher wait (batch engine only)", takes_value: true, default: Some("4") },
        OptSpec { name: "prefill-chunk", help: "prompt tokens prefilled per scheduler iteration (0 = inline: whole prompt at admission)", takes_value: true, default: Some("0") },
        OptSpec { name: "spec-k", help: "speculative decoding: max draft tokens verified per lane per step (0 = off); output is bit-identical at any k", takes_value: true, default: Some("0") },
        OptSpec { name: "drafter", help: "draft-token source for --spec-k: ngram | off", takes_value: true, default: Some("ngram") },
        OptSpec { name: "queue-cap", help: "admission queue capacity; submits beyond it are rejected (0 = unbounded)", takes_value: true, default: Some("0") },
        OptSpec { name: "deadline-ms", help: "per-request deadline; requests still queued past it are shed (0 = none)", takes_value: true, default: Some("0") },
        OptSpec { name: "kv-pages", help: "KV page budget across all lanes; pressure degrades evict->defer->preempt (0 = unbounded)", takes_value: true, default: Some("0") },
        OptSpec { name: "workers", help: "quantization worker threads (0 = all cores)", takes_value: true, default: Some("0") },
        OptSpec { name: "trace", help: "write a Chrome-trace JSON (plus <stem>.events.jsonl lifecycle log) to this path", takes_value: true, default: None },
        OptSpec { name: "metrics-out", help: "write a JSON metrics + quant-telemetry snapshot to this path", takes_value: true, default: None },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") {
        println!("{}", render_help("serve-cpu", "serve via the CPU decode engine + quant pipeline", &specs));
        return Ok(());
    }
    let trace_path = args.opt("trace").map(PathBuf::from);
    let metrics_out = args.opt("metrics-out").map(PathBuf::from);
    if trace_path.is_some() {
        lobcq::obs::trace::enable();
    }
    if trace_path.is_some() || metrics_out.is_some() {
        lobcq::obs::quant_stats::enable();
    }
    // Declarative path: a workload spec fully describes the server and
    // the traffic, so the ad-hoc swarm flags below don't apply.
    if let Some(wl) = args.opt("workload") {
        let spec = lobcq::bench::WorkloadSpec::load(&PathBuf::from(wl))?;
        let trace = lobcq::bench::expand(&spec)?;
        let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
        let (server, vocab) = lobcq::bench::runner::build_server(&spec, &artifacts)?;
        println!(
            "[serve-cpu] workload '{}': {} requests, {} arrivals, {} lanes, kv {}, weights {}, kernels {}",
            spec.name,
            trace.requests.len(),
            spec.arrival.name(),
            spec.lanes,
            spec.kv.name(),
            spec.weights.name(),
            lobcq::kernels::backend_name()
        );
        let stats = lobcq::bench::runner::drive(&server, &trace, &spec, vocab);
        println!("[serve-cpu] {} ok / {} failed in {:.2}s", stats.ok, stats.failed, stats.wall_s);
        let snapshot = server.metrics.snapshot();
        println!("[serve-cpu] {}", snapshot.report());
        server.shutdown();
        return export_obs(&snapshot, metrics_out.as_ref(), trace_path.as_ref());
    }
    let env = Env::load_from(PathBuf::from(args.str_or("artifacts", "artifacts")));
    let n_requests = args.usize_or("requests", 32)?;
    let max_new = args.usize_or("max-new", 4)?;
    let max_batch = args.usize_or("max-batch", 8)?.max(1);
    // SLO envelope: 0 means "off" for every knob (inline prefill,
    // unbounded queue, no deadline, unbounded KV pages).
    let prefill_chunk = args.usize_or("prefill-chunk", 0)?;
    let spec_k = args.usize_or("spec-k", 0)?;
    let drafter = DrafterKind::parse(args.str_or("drafter", "ngram"))?;
    let queue_cap = args.usize_or("queue-cap", 0)?;
    let deadline_ms = args.u64_or("deadline-ms", 0)?;
    let kv_pages = args.usize_or("kv-pages", 0)?;
    let deadline = (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms));
    let workers = args.usize_or("workers", 0)?;
    let pool = if workers == 0 { QuantPool::default() } else { QuantPool::with_workers(workers) };

    let scheme = match args.str_or("scheme", "lobcq") {
        "bf16" => lobcq::eval::Scheme::Bf16,
        "lobcq" => env.lobcq(8, 8, 64)?,
        "mx4" => lobcq::eval::scheme::mx4(),
        "vsq" => lobcq::eval::scheme::vsq(),
        "mxfp4" => lobcq::eval::scheme::mxfp4(),
        other => anyhow::bail!("unknown scheme '{other}'"),
    };

    // Model: trained artifacts when present, else a deterministic random
    // tiny-GPT over the corpus vocabulary.
    let (cfg, weights) = match (env.model_config("s"), env.weights("s")) {
        (Ok(c), Ok(w)) => (c, w),
        _ => {
            println!("[serve-cpu] no artifacts — using a random tiny-GPT");
            synthetic_model()
        }
    };

    let t = 32.min(cfg.max_t);
    let vocab = cfg.vocab as u32;
    let page_tokens = args.usize_or("page-tokens", 16)?.max(1);
    let engine = args.str_or("engine", "continuous");
    let server = match engine {
        "continuous" => {
            let encoded = match args.str_or("kv", "bcq") {
                "bcq" => true,
                "f32" => false,
                other => anyhow::bail!("unknown kv store '{other}' (bcq|f32)"),
            };
            let kv = KvCacheOpts {
                page_tokens,
                encoded,
                prefix_cache_bytes: args.bytes_opt("prefix-cache")?,
                page_budget: (kv_pages > 0).then_some(kv_pages),
            };
            let session = DecodeSession::new(cfg.clone(), &weights, &scheme, pool, max_batch, kv)?;
            println!(
                "[serve-cpu] model {} ({} params), scheme {}, weights {}, kv {}, kernels {}, lanes {max_batch}, prefix cache {}",
                cfg.name,
                cfg.param_count(),
                session.act_scheme_name(),
                session.weight_mode(),
                session.kv_mode(),
                lobcq::kernels::backend_name(),
                session.prefix_mode()
            );
            println!(
                "[serve-cpu] slo: prefill-chunk {}, queue-cap {}, deadline {}, kv-pages {}, spec {}",
                if prefill_chunk == 0 { "inline".into() } else { prefill_chunk.to_string() },
                if queue_cap == 0 { "unbounded".into() } else { queue_cap.to_string() },
                if deadline_ms == 0 { "none".into() } else { format!("{deadline_ms}ms") },
                if kv_pages == 0 { "unbounded".into() } else { kv_pages.to_string() },
                if spec_k == 0 || drafter == DrafterKind::Off {
                    "off".into()
                } else {
                    format!("k={spec_k} ({})", drafter.name())
                },
            );
            // The cached engine holds full histories (no sliding window);
            // any prompt up to `t` prefills, and the scheduler caps each
            // request's generation budget at the lane's remaining token
            // capacity, so prompt+max_new past max_t shortens the output
            // instead of rejecting the request.
            Server::start_continuous_with(
                session,
                Limits { max_prompt: t, max_new: max_new.max(1), vocab },
                Sampling::Greedy,
                BatchPolicy {
                    max_batch,
                    max_wait: std::time::Duration::from_millis(args.u64_or("max-wait-ms", 4)?),
                    queue_cap: (queue_cap > 0).then_some(queue_cap),
                },
                ContinuousOpts {
                    prefill_chunk: if prefill_chunk == 0 { usize::MAX } else { prefill_chunk },
                    spec_k,
                    drafter,
                },
            )
        }
        "batch" => {
            let exec = CpuExecutor::new(cfg.clone(), &weights, &scheme, pool, max_batch, t)?;
            println!(
                "[serve-cpu] model {} ({} params), scheme {}, weights {}, kernels {}, batch {max_batch}, t {t}",
                cfg.name,
                cfg.param_count(),
                exec.act_scheme_name(),
                exec.weight_mode(),
                lobcq::kernels::backend_name()
            );
            Server::start(
                exec,
                BatchPolicy {
                    max_batch,
                    max_wait: std::time::Duration::from_millis(args.u64_or("max-wait-ms", 4)?),
                    queue_cap: (queue_cap > 0).then_some(queue_cap),
                },
                Limits { max_prompt: t, max_new: max_new.max(1), vocab },
                Sampling::Greedy,
            )
        }
        other => anyhow::bail!("unknown engine '{other}' (continuous|batch)"),
    };

    // Shared-prefix swarm: K distinct system prompts, request-unique
    // suffixes — the traffic shape that exercises the prefix cache (the
    // batch engine serves the same prompts, just without reuse). The
    // shared prefix spans at least one full page (else no page would
    // ever be publishable), capped so prefix + suffix still fits the
    // prompt limit.
    let prefix_k = args.usize_or("prefix-k", 4)?.max(1);
    let suffix_len = 8usize.min(t.saturating_sub(2).max(1));
    let prefix_len = page_tokens.clamp(1, t.saturating_sub(suffix_len).max(1));
    if prefix_len < page_tokens {
        // The shared prefix must span one whole page to ever be
        // published/adopted; with this page size and prompt limit it
        // can't, so the run would report 0% hits by construction.
        lobcq::log_warn!(
            "[serve-cpu] WARNING: --page-tokens {page_tokens} exceeds the {prefix_len}-token shared \
             prefix that fits max_prompt {t}; the prefix cache cannot get hits at this page size"
        );
    }
    let workload = corpus::shared_prefix_workload(9100, prefix_k, n_requests, prefix_len, suffix_len);
    println!("[serve-cpu] firing {n_requests} requests (max_new {max_new}, {prefix_k} shared prefixes)");
    let t0 = Instant::now();
    let server = std::sync::Arc::new(server);
    let mut handles = Vec::new();
    for (_, prompt) in workload.requests {
        let s = server.clone();
        let prompt: Vec<u32> = prompt.into_iter().map(|x| x % vocab).collect();
        handles.push(std::thread::spawn(move || {
            // A bounded queue may reject at submit time; count that as a
            // failed request rather than panicking the client thread.
            match s.submit_with(prompt, max_new, Priority::Normal, deadline) {
                Ok(ticket) => ticket.wait(),
                Err(e) => Err(anyhow::Error::new(e)),
            }
        }));
    }
    let mut ok = 0;
    for h in handles {
        if h.join().unwrap().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("[serve-cpu] {ok}/{n_requests} ok in {wall:.2}s");
    let snapshot = server.metrics.snapshot();
    println!("[serve-cpu] {}", snapshot.report());
    if let Ok(s) = std::sync::Arc::try_unwrap(server) {
        // Joins the scheduler thread, which flushes its trace ring.
        s.shutdown();
    }
    export_obs(&snapshot, metrics_out.as_ref(), trace_path.as_ref())
}

/// Shared `--metrics-out` / `--trace` export tail for both `serve-cpu`
/// paths. The metrics snapshot carries the span-ring drop count so a
/// truncated trace is visible (and CI-failable) from the metrics file
/// alone.
fn export_obs(
    snapshot: &lobcq::coordinator::MetricsSnapshot,
    metrics_out: Option<&PathBuf>,
    trace_path: Option<&PathBuf>,
) -> anyhow::Result<()> {
    if let Some(path) = metrics_out {
        let mut j = Json::obj();
        j.set("server", snapshot.to_json());
        j.set("quant", lobcq::obs::quant_stats::snapshot_json());
        j.set("registry", lobcq::obs::registry::snapshot());
        j.set("kernel_backend", Json::Str(lobcq::kernels::backend_name().into()));
        j.set("system", lobcq::obs::report::system_info());
        j.set("trace_dropped", Json::Num(lobcq::obs::trace::dropped() as f64));
        j.to_file(path)?;
        println!("[serve-cpu] metrics written to {}", path.display());
    }
    if let Some(path) = trace_path {
        let events = lobcq::obs::trace::drain();
        lobcq::obs::trace::export_chrome_trace(path, &events)?;
        let jsonl = lobcq::obs::trace::lifecycle_path(path);
        lobcq::obs::trace::export_lifecycle_jsonl(&jsonl, &events)?;
        println!(
            "[serve-cpu] trace: {} events to {} (lifecycle log {})",
            events.len(),
            path.display(),
            jsonl.display()
        );
    }
    Ok(())
}

/// Deterministic random tiny-GPT over the corpus vocab (no artifacts);
/// shared with the workload harness so spec-driven and flag-driven runs
/// serve the identical model.
fn synthetic_model() -> (lobcq::model::ModelConfig, lobcq::model::Weights) {
    lobcq::bench::runner::demo_model()
}

// ---- bench (experiments) ----

fn bench(argv: &[String]) -> anyhow::Result<()> {
    let specs = [
        artifacts_opt(),
        OptSpec { name: "exp", help: "experiment id or 'all'", takes_value: true, default: Some("all") },
        OptSpec { name: "quick", help: "reduced workload", takes_value: false, default: None },
        OptSpec { name: "out", help: "write report to file", takes_value: true, default: None },
        OptSpec { name: "workload", help: "declarative workload spec file — runs the sweep harness (one run-record JSON per point) instead of paper experiments", takes_value: true, default: None },
        OptSpec { name: "sweep", help: "with --workload: sweep one spec key over values (key=v1,v2,…)", takes_value: true, default: None },
        OptSpec { name: "raw-out", help: "with --workload: run-record output directory", takes_value: true, default: Some("results/raw") },
        OptSpec { name: "help", help: "show help", takes_value: false, default: None },
    ];
    let args = Args::parse(argv, &specs)?;
    if args.flag("help") {
        println!("{}", render_help("bench", "paper experiments, or workload sweeps with --workload", &specs));
        return Ok(());
    }
    if let Some(wl) = args.opt("workload") {
        let spec = lobcq::bench::WorkloadSpec::load(&PathBuf::from(wl))?;
        let sweep = args.opt("sweep").map(lobcq::bench::SweepSpec::parse).transpose()?;
        let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
        let out_dir = PathBuf::from(args.str_or("raw-out", "results/raw"));
        let t0 = Instant::now();
        let paths = lobcq::bench::run_sweep(&spec, sweep.as_ref(), &artifacts, &out_dir)?;
        println!("[bench] {} run-record(s) in {:.1}s:", paths.len(), t0.elapsed().as_secs_f64());
        for p in &paths {
            println!("  {}", p.display());
        }
        return Ok(());
    }
    let env = Env::load_from(PathBuf::from(args.str_or("artifacts", "artifacts")));
    let quick = args.flag("quick");
    let ids: Vec<&str> = match args.str_or("exp", "all") {
        "all" => experiments::ALL_EXPERIMENTS.to_vec(),
        one => vec![one],
    };
    let mut full = String::new();
    for id in ids {
        let t0 = Instant::now();
        println!("== running {id} ==");
        match experiments::run(id, &env, quick) {
            Ok(report) => {
                println!("{report}");
                println!("[{id}] done in {:.1}s\n", t0.elapsed().as_secs_f64());
                full.push_str(&report);
                full.push('\n');
            }
            Err(e) => {
                println!("[{id}] SKIPPED/FAILED: {e:#}\n");
                full.push_str(&format!("# {id}: FAILED — {e:#}\n\n"));
            }
        }
    }
    if let Some(out) = args.opt("out") {
        std::fs::write(out, &full)?;
        println!("report written to {out}");
    }
    Ok(())
}

// ---- eval (PJRT perplexity) ----

#[cfg(not(feature = "pjrt"))]
fn eval(_argv: &[String]) -> anyhow::Result<()> {
    anyhow::bail!("`eval` needs the PJRT runtime: rebuild with --features pjrt (CPU-path tables run via `bench`)")
}

#[cfg(feature = "pjrt")]
fn eval(argv: &[String]) -> anyhow::Result<()> {
    let specs = [
        artifacts_opt(),
        OptSpec { name: "size", help: "model size", takes_value: true, default: Some("s") },
        OptSpec { name: "variant", help: "artifact variant", takes_value: true, default: Some("bf16") },
        OptSpec { name: "windows", help: "eval windows", takes_value: true, default: Some("32") },
    ];
    let args = Args::parse(argv, &specs)?;
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let env = Env::load_from(dir.clone());
    let size = args.str_or("size", "s").to_string();
    let variant = args.str_or("variant", "bf16").to_string();

    let mut eng = lobcq::runtime::Engine::from_dir(&dir)?;
    let cfg = env.model_config(&size)?;
    let weights = env.weights(&size)?;
    let ordered: Vec<Tensor> = weights.ordered(&cfg)?.into_iter().cloned().collect();
    let refs: Vec<&Tensor> = ordered.iter().collect();
    eng.register_weights("w", &cfg, &refs)?;
    let entry = eng
        .manifest
        .find(&size, &variant, 8)
        .ok_or_else(|| anyhow::anyhow!("no artifact {size}/{variant}/b8"))?
        .clone();
    let books_key = if let Some(nc) = entry.books_nc {
        let fam = env.family(nc, 4, 6)?;
        eng.register_books("books", &Env::books_tensor(&fam))?;
        Some("books")
    } else {
        None
    };
    let opts = lobcq::eval::EvalOpts { n_windows: args.usize_or("windows", 32)?, ..Default::default() };
    let ppl = lobcq::eval::ppl_pjrt(&mut eng, &size, &variant, "w", books_key, &opts)?;
    println!("ppl[{size}/{variant}] = {ppl:.4}");
    Ok(())
}

// ---- calibrate ----

fn calibrate(argv: &[String]) -> anyhow::Result<()> {
    let specs = [
        artifacts_opt(),
        OptSpec { name: "nc", help: "number of codebooks", takes_value: true, default: Some("8") },
        OptSpec { name: "b", help: "index bits", takes_value: true, default: Some("4") },
        OptSpec { name: "out", help: "output json", takes_value: true, default: Some("artifacts/codebooks_rust.json") },
    ];
    let args = Args::parse(argv, &specs)?;
    let env = Env::load_from(PathBuf::from(args.str_or("artifacts", "artifacts")));
    let nc = args.usize_or("nc", 8)?;
    let b = args.usize_or("b", 4)? as u32;
    let cfg = LobcqConfig::new(8, nc, 64).with_bits(b);
    let weights = env.weights("s")?;
    let model_cfg = env.model_config("s")?;
    let gemms: Vec<&Tensor> = model_cfg
        .param_shapes()
        .iter()
        .filter(|(n, _)| lobcq::eval::scheme::is_gemm_weight(n))
        .map(|(n, _)| weights.get(n).unwrap())
        .collect();
    let t0 = Instant::now();
    let fam = calibrate_universal(&gemms, &cfg, CalibOpts::default(), 0x5EED);
    println!("calibrated nc{nc}_b{b} in {:.1}s", t0.elapsed().as_secs_f64());
    let out = PathBuf::from(args.str_or("out", "artifacts/codebooks_rust.json"));
    fam.save(&out)?;
    println!("saved to {}", out.display());
    Ok(())
}

// ---- gen-parity ----

/// Emit cross-language parity vectors for `python/tests/test_parity.py`.
fn gen_parity(argv: &[String]) -> anyhow::Result<()> {
    let specs = [OptSpec { name: "out", help: "output json", takes_value: true, default: Some("artifacts/parity.json") }];
    let args = Args::parse(argv, &specs)?;

    let mut root = Json::obj();

    // PCG streams (seeds chosen f64-exact for the JSON layer).
    let mut pcg_cases = Vec::new();
    let mut pcg_f32_cases = Vec::new();
    for (seed, stream) in [(42u64, 7u64), (0, 0), (123456789, 12345)] {
        let mut rng = Pcg32::new(seed, stream);
        let u32s: Vec<Json> = (0..16).map(|_| Json::Num(rng.next_u32() as f64)).collect();
        pcg_cases.push(
            Json::obj()
                .with("seed", Json::Num(seed as f64))
                .with("stream", Json::Num(stream as f64))
                .with("u32", Json::Arr(u32s)),
        );
        let mut rng = Pcg32::new(seed, stream);
        let f32s: Vec<f32> = (0..16).map(|_| rng.next_f32()).collect();
        pcg_f32_cases.push(
            Json::obj()
                .with("seed", Json::Num(seed as f64))
                .with("stream", Json::Num(stream as f64))
                .with("f32", Json::from_f32s(&f32s)),
        );
    }
    root.set("pcg", Json::Arr(pcg_cases));
    root.set("pcg_f32", Json::Arr(pcg_f32_cases));

    // Corpus (fingerprint as string: u64 exceeds f64-exact range).
    let toks = corpus::generate(5678, 40_000);
    root.set(
        "corpus",
        Json::obj()
            .with("seed", Json::Num(5678.0))
            .with("n", Json::Num(40_000.0))
            .with(
                "head",
                Json::from_usizes(&toks[..64].iter().map(|&t| t as usize).collect::<Vec<_>>()),
            )
            .with("fingerprint", Json::Str(corpus::fingerprint(&toks).to_string())),
    );

    // Float formats on a deterministic sweep of values.
    let mut rng = Pcg32::seeded(0xFA117);
    let mut xs: Vec<f32> = vec![0.0, -0.0, 1.0, -1.0, 0.5, 6.0, 448.0, 1e-8, 1e8, 3.1415927];
    for _ in 0..200 {
        xs.push(lobcq::util::prop::gen_wide_f32(&mut rng));
    }
    let mut fmt_cases = Vec::new();
    for fmt in [
        lobcq::formats::E1M2,
        lobcq::formats::E2M1,
        lobcq::formats::E3M0,
        lobcq::formats::E4M3,
        lobcq::formats::E5M2,
        lobcq::formats::E3M3,
        lobcq::formats::E3M2,
        lobcq::formats::E4M0,
    ] {
        let q: Vec<f32> = xs.iter().map(|&x| fmt.quantize(x)).collect();
        fmt_cases.push(
            Json::obj()
                .with("format", Json::Str(fmt.name.into()))
                .with("x", Json::from_f32s(&xs))
                .with("q", Json::from_f32s(&q)),
        );
    }
    root.set("formats", Json::Arr(fmt_cases));

    // INT4.
    let ints: Vec<f32> = xs.iter().map(|&x| lobcq::formats::INT4.quantize(x)).collect();
    root.set(
        "int4",
        Json::obj().with("x", Json::from_f32s(&xs)).with("q", Json::from_f32s(&ints)),
    );

    // LO-BCQ fake-quantize with a frozen family.
    let env = Env::load();
    let cfg = LobcqConfig::new(8, 8, 64);
    let fam = env.family(8, 4, 6)?;
    let mut rng = Pcg32::seeded(0x10BC);
    let x = lobcq::util::rng::llm_like_sample(&mut rng, 16 * 256, 0.05, 4.0);
    let q = lobcq::quant::lobcq::fake_quantize(&x, &cfg, &fam);
    let books: Vec<Json> = fam.books.iter().map(|b| Json::from_f32s(&b.levels)).collect();
    root.set(
        "lobcq",
        Json::obj()
            .with("lb", Json::Num(cfg.lb as f64))
            .with("la", Json::Num(cfg.la as f64))
            .with("nc", Json::Num(cfg.nc as f64))
            .with("b", Json::Num(cfg.b as f64))
            .with("bc", Json::Num(cfg.bc as f64))
            .with("books", Json::Arr(books))
            .with("x", Json::from_f32s(&x))
            .with("q", Json::from_f32s(&q)),
    );

    let out = PathBuf::from(args.str_or("out", "artifacts/parity.json"));
    root.to_file(&out)?;
    println!("parity vectors written to {}", out.display());
    Ok(())
}

// ---- info ----

fn info(argv: &[String]) -> anyhow::Result<()> {
    let specs = [artifacts_opt()];
    let args = Args::parse(argv, &specs)?;
    let dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let m = Manifest::load(&dir)?;
    println!("artifacts: {}", dir.display());
    println!("vocab {} max_t {}", m.vocab, m.max_t);
    for (name, cfg) in &m.models {
        println!(
            "model {name}: d={} layers={} heads={} params={}",
            cfg.d,
            cfg.n_layers,
            cfg.n_heads,
            cfg.param_count()
        );
    }
    println!("{} model artifacts:", m.artifacts.len());
    for a in &m.artifacts {
        println!("  {} (books_nc {:?})", a.key(), a.books_nc);
    }
    println!("{} ops: {:?}", m.ops.len(), m.ops.keys().collect::<Vec<_>>());
    m.check_corpus_parity()?;
    println!("corpus parity: OK");
    Ok(())
}
