//! Model configuration — mirror of `python/compile/model.py::ModelConfig`
//! plus the manifest-driven loading used by the runtime.

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: String,
    pub d: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub vocab: usize,
    pub max_t: usize,
}

impl ModelConfig {
    pub fn d_ff(&self) -> usize {
        4 * self.d
    }

    pub fn head_dim(&self) -> usize {
        assert!(self.d % self.n_heads == 0);
        self.d / self.n_heads
    }

    /// Ordered (name, shape) list — the weights-as-inputs calling
    /// convention shared with `python/compile/model.py::param_shapes`.
    pub fn param_shapes(&self) -> Vec<(String, Vec<usize>)> {
        let mut v: Vec<(String, Vec<usize>)> = vec![
            ("embed".into(), vec![self.vocab, self.d]),
            ("pos".into(), vec![self.max_t, self.d]),
        ];
        for i in 0..self.n_layers {
            v.push((format!("l{i}.ln1.g"), vec![self.d]));
            v.push((format!("l{i}.ln1.b"), vec![self.d]));
            v.push((format!("l{i}.attn.wqkv"), vec![self.d, 3 * self.d]));
            v.push((format!("l{i}.attn.wo"), vec![self.d, self.d]));
            v.push((format!("l{i}.ln2.g"), vec![self.d]));
            v.push((format!("l{i}.ln2.b"), vec![self.d]));
            v.push((format!("l{i}.mlp.w1"), vec![self.d, self.d_ff()]));
            v.push((format!("l{i}.mlp.w2"), vec![self.d_ff(), self.d]));
        }
        v.push(("lnf.g".into(), vec![self.d]));
        v.push(("lnf.b".into(), vec![self.d]));
        v
    }

    pub fn param_count(&self) -> usize {
        self.param_shapes().iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// Parse from a manifest `models.<size>` entry.
    pub fn from_manifest(name: &str, j: &Json) -> anyhow::Result<ModelConfig> {
        Ok(ModelConfig {
            name: name.to_string(),
            d: j.get("d")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            vocab: j.get("vocab")?.as_usize()?,
            max_t: j.get("max_t")?.as_usize()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s_cfg() -> ModelConfig {
        ModelConfig { name: "s".into(), d: 128, n_layers: 2, n_heads: 4, vocab: 168, max_t: 64 }
    }

    #[test]
    fn shapes_match_python_convention() {
        let cfg = s_cfg();
        let shapes = cfg.param_shapes();
        assert_eq!(shapes[0], ("embed".to_string(), vec![168, 128]));
        assert_eq!(shapes[1], ("pos".to_string(), vec![64, 128]));
        assert_eq!(shapes[2].0, "l0.ln1.g");
        assert_eq!(shapes[4], ("l0.attn.wqkv".to_string(), vec![128, 384]));
        assert_eq!(shapes.last().unwrap().0, "lnf.b");
        // 2 + 8 per layer + 2
        assert_eq!(shapes.len(), 2 + 8 * 2 + 2);
    }

    #[test]
    fn param_count_s_model() {
        // Matches python: embed 168*128 + pos 64*128 + per-layer
        // (2*128 + 128*384 + 128*128 + 2*128 + 128*512 + 512*128) * 2 + 2*128.
        let cfg = s_cfg();
        let per_layer = 2 * 128 + 128 * 384 + 128 * 128 + 2 * 128 + 128 * 512 + 512 * 128;
        let want = 168 * 128 + 64 * 128 + 2 * per_layer + 2 * 128;
        assert_eq!(cfg.param_count(), want);
        assert_eq!(cfg.param_count(), 424192); // pinned vs python test run
    }

    #[test]
    fn from_manifest_json() {
        let j = Json::parse(
            r#"{"d":256,"n_layers":3,"n_heads":8,"vocab":168,"max_t":64,"params":1}"#,
        )
        .unwrap();
        let cfg = ModelConfig::from_manifest("m", &j).unwrap();
        assert_eq!(cfg.d, 256);
        assert_eq!(cfg.head_dim(), 32);
        assert_eq!(cfg.d_ff(), 1024);
    }
}
