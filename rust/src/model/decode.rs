//! Incremental forward: `prefill_from` fills the KV cache for the
//! uncached part of a prompt (all of it when cold; only the suffix
//! after a prefix-cache hit), `decode_step` runs **one token** against
//! the cached history, and `decode_step_batch` runs **one fused forward
//! for every live lane** of a scheduler step — O(len) attention work
//! per token instead of the full forward's O(t²) re-score, and only the
//! frontier rows of logits are ever materialized.
//!
//! All three entry points are thin drivers over one shared set of
//! per-layer helpers ([`layer_qkv`], [`layer_wo_residual`],
//! [`layer_mlp`], [`lm_head`], [`attend_span`]) parameterized by the
//! stacked row count — the only thing that differs between a prefill
//! suffix (`m` rows), a single decode token (1 row), and a fused batch
//! (`lanes` rows).
//!
//! Numerics: with an f32 (KV16) cache the pair (prefill, decode_step)
//! reproduces [`forward`](super::forward::forward) — every sub-step is
//! row-independent in the reference forward (layer norm, GELU, per-row
//! GEMM accumulation, causal softmax whose masked tail contributes exact
//! `+0.0`), and the attention reductions here mirror the blocked
//! kernel's accumulation order (scores reduce over `head_dim < KC` in
//! one block; context reduces over tokens in the same `KC`-sized chunks
//! `kernels::gemm` uses). The decode-parity suite pins this. With a
//! BCQ-encoded (KV4) cache **all** attention — prefill included — reads
//! the quantized history back from the cache, so the K/V at a position
//! depends only on the token prefix, never on where the prefill/decode
//! boundary fell: the invariant that lets the prefix cache share pages
//! across requests bit-exactly (see `prefill_from`), and the KV4-vs-KV16
//! ablation in EXPERIMENTS.md.
//!
//! Attention has two interchangeable paths ([`AttnPath`], DESIGN.md
//! §Encoded-domain attention). [`AttnPath::Gather`] re-materializes the
//! full f32 history per (lane, head) and runs the scalar score/context
//! loops — the reference. [`AttnPath::Encoded`] (the default; opt out
//! with `LOBCQ_DECODE_ATTN=gather`) scores q·K **directly against the
//! cached pages**: each page is LUT-decoded once into a `K^T`/V panel
//! pair cached per `PageId` in the scratch's [`KvPanelCache`] and
//! revalidated against the page pool's generation counters, so
//! steady-state decode re-decodes only the frontier page and streams
//! full pages through the blocked (SIMD) GEMM driver. Both paths are
//! **bit-identical**: the panels hold the same decoded values a gather
//! would produce, and the GEMM driver accumulates q·K[j] in the same
//! per-element order as the scalar loop (pinned by a module test and
//! the decode-parity suite).
//!
//! Batching (DESIGN.md §Batched decode): `decode_step_batch` stacks the
//! per-lane frontier tokens into a `(lanes, d)` activation matrix and
//! runs each projection / FFN / LM-head GEMM **once per step** with
//! `M = lanes`, so the packed (or LO-BCQ-encoded) B panel is streamed
//! once per step instead of once per lane — the weight-traffic
//! amortization that makes W4A4 decode throughput scale with batch
//! size. Only attention splits per lane, against each lane's own paged
//! KV history at its own (ragged) position. Activations are quantized
//! **per lane row**, and GEMM rows accumulate independently in the
//! blocked kernel, so one batched step is **bit-identical** to running
//! `decode_step` once per lane — a lane's numerics never depend on
//! which other lanes are co-scheduled (`tests/decode_parity.rs`).
//!
//! Speculation (DESIGN.md §Speculative decoding): [`decode_step_batch_spec`]
//! stacks each lane's frontier token **plus its drafted tokens** as extra
//! rows of the same fused step — logits at every drafted position for a
//! single weight-panel stream, which is what makes verify rows nearly
//! free under W4A4 — and a rejected draft tail is erased bit-exactly by
//! [`PagedKvCache::truncate`], so the speculative round trip is invisible
//! to later steps and to prefix-cache publishing.

use crate::kernels::{self, KC};
use crate::kvcache::{KvPanelCache, PagedKvCache, PageId, SlotId};
use crate::model::config::ModelConfig;
use crate::model::forward::{gelu, layer_norm_flat, qmatmul_rows_into, softmax_rows, ActQuant};
use crate::model::weights::Weights;
use std::sync::OnceLock;

/// Which implementation decode attention runs (see the module doc).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnPath {
    /// Score q·K straight off the cached pages, each LUT-decoded once
    /// into a cached `K^T`/V panel and streamed through the blocked
    /// (SIMD) GEMM driver. The serving default.
    Encoded,
    /// Re-gather the full f32 history per (lane, head), then the scalar
    /// score/context loops — the reference path the encoded one is
    /// verified against.
    Gather,
}

impl AttnPath {
    pub fn name(self) -> &'static str {
        match self {
            AttnPath::Encoded => "encoded",
            AttnPath::Gather => "gather",
        }
    }
}

impl Default for AttnPath {
    /// `Encoded` unless `LOBCQ_DECODE_ATTN=gather` opts the process out
    /// (read once, like the kernel backend's `LOBCQ_FORCE_SCALAR`).
    fn default() -> AttnPath {
        static FROM_ENV: OnceLock<AttnPath> = OnceLock::new();
        *FROM_ENV.get_or_init(|| match std::env::var("LOBCQ_DECODE_ATTN") {
            Ok(v) if v.eq_ignore_ascii_case("gather") => AttnPath::Gather,
            _ => AttnPath::Encoded,
        })
    }
}

/// Reusable state for [`decode_step`] / [`decode_step_batch`]: every
/// per-token temporary of the decode hot loop — the stacked activation
/// matrices (residual stream, layer-norm copy, QKV, attention output,
/// projection, FFN hidden, logits), the activation-quantization staging
/// buffer, the GEMM panel scratch (the encoded path's LUT-decode
/// target), the gathered K/V history with score/context accumulators,
/// per-lane positions, the per-page decoded-panel cache, and the
/// pre-rendered per-layer weight names (decode runs per token, so the
/// `format!` allocations are hoisted out of the hot loop). A session
/// that keeps one across steps performs **no steady-state allocations**
/// once the buffers reach the working size —
/// [`footprint`](Self::footprint) exposes the total capacity so the
/// zero-alloc property test can pin that.
#[derive(Debug, Default)]
pub struct DecodeScratch {
    /// Residual stream, `(lanes, d)`.
    x: Vec<f32>,
    /// Layer-norm input copy, `(lanes, d)`.
    h: Vec<f32>,
    /// QKV projection output, `(lanes, 3d)`.
    qkv: Vec<f32>,
    /// Attention output, `(lanes, d)`.
    attn: Vec<f32>,
    /// Projection / FFN-down output, `(lanes, d)`.
    proj: Vec<f32>,
    /// FFN hidden, `(lanes, d_ff)`.
    ff: Vec<f32>,
    /// Frontier logits, `(lanes, vocab)`.
    logits: Vec<f32>,
    /// Per-row activation-quantization staging.
    aq: Vec<f32>,
    /// Kernel panel scratch (`KC × NR`; the encoded path's LUT target).
    panel: Vec<f32>,
    /// Gathered K/V history for one (lane, head).
    k: Vec<f32>,
    v: Vec<f32>,
    scores: Vec<f32>,
    ctx: Vec<f32>,
    acc: Vec<f32>,
    /// Per-lane cache positions for the current step.
    pos: Vec<usize>,
    /// Per-lane first-row offsets of a stacked-verify step (prefix sums
    /// of `1 + k_i`).
    row0: Vec<usize>,
    /// Page ids of the (slot, layer, head) run being attended.
    page_run: Vec<PageId>,
    /// Per-page decoded `K^T`/V panels for [`AttnPath::Encoded`]. Its
    /// memory scales with **cache state** (budgeted, generation-
    /// revalidated — see `kvcache::lut`), not with the per-step working
    /// set, so it is deliberately NOT part of [`footprint`](Self::footprint)
    /// — the same reason KV pages themselves aren't.
    panels: KvPanelCache,
    attn_path: AttnPath,
    names: Vec<LayerNames>,
}

impl DecodeScratch {
    pub fn new() -> DecodeScratch {
        DecodeScratch::default()
    }

    /// Which attention path this scratch drives (defaults from
    /// `LOBCQ_DECODE_ATTN`).
    pub fn attn_path(&self) -> AttnPath {
        self.attn_path
    }

    /// Force the attention path (benches pin both sides; tests pin
    /// bit-equality across them).
    pub fn set_attn_path(&mut self, path: AttnPath) {
        self.attn_path = path;
    }

    /// The per-page decoded-panel cache — read-only metrics surface
    /// (hit/decode counters, resident bytes).
    pub fn panel_cache(&self) -> &KvPanelCache {
        &self.panels
    }

    /// Total f32/usize capacity (in elements) held across every
    /// per-step scratch buffer. Constant across steps once the working
    /// set is reached — any hidden steady-state allocation in the
    /// decode loop would grow it, which the zero-alloc property test
    /// asserts never happens. (The decoded-panel cache is excluded: its
    /// size tracks cache state, not the step working set.)
    pub fn footprint(&self) -> usize {
        self.x.capacity()
            + self.h.capacity()
            + self.qkv.capacity()
            + self.attn.capacity()
            + self.proj.capacity()
            + self.ff.capacity()
            + self.logits.capacity()
            + self.aq.capacity()
            + self.panel.capacity()
            + self.k.capacity()
            + self.v.capacity()
            + self.scores.capacity()
            + self.ctx.capacity()
            + self.acc.capacity()
            + self.pos.capacity()
            + self.row0.capacity()
            + self.page_run.capacity()
    }

    fn ensure_names(&mut self, n_layers: usize) {
        if self.names.len() != n_layers {
            self.names = (0..n_layers).map(LayerNames::new).collect();
        }
    }

    /// Pin the length-proportional attention buffers (gathered K/V,
    /// score row, page run) at the cache's per-slot token capacity once,
    /// so the decode loop never reallocates them at **any** sequence
    /// length — the zero-steady-state-allocation property holds by
    /// construction instead of by amortized-doubling luck. Gathers only
    /// ever resize within this capacity afterwards.
    fn pin_attention_capacity(&mut self, max_tokens: usize, head_dim: usize, page_tokens: usize) {
        if self.k.capacity() < max_tokens * head_dim {
            self.k.resize(max_tokens * head_dim, 0.0);
            self.v.resize(max_tokens * head_dim, 0.0);
            self.scores.resize(max_tokens, 0.0);
        }
        let pages = max_tokens.div_ceil(page_tokens);
        if self.page_run.capacity() < pages {
            self.page_run.resize(pages, 0);
        }
    }
}

/// One layer's weight-map keys, rendered once.
#[derive(Debug)]
struct LayerNames {
    ln1_g: String,
    ln1_b: String,
    wqkv: String,
    wo: String,
    ln2_g: String,
    ln2_b: String,
    w1: String,
    w2: String,
}

impl LayerNames {
    fn new(i: usize) -> LayerNames {
        LayerNames {
            ln1_g: format!("l{i}.ln1.g"),
            ln1_b: format!("l{i}.ln1.b"),
            wqkv: format!("l{i}.attn.wqkv"),
            wo: format!("l{i}.attn.wo"),
            ln2_g: format!("l{i}.ln2.g"),
            ln2_b: format!("l{i}.ln2.b"),
            w1: format!("l{i}.mlp.w1"),
            w2: format!("l{i}.mlp.w2"),
        }
    }
}

// ---------------------------------------------------------------------
// Shared per-layer building blocks. Each takes the stacked row count
// `m` — 1 for a decode token, `lanes` for a fused batch, the suffix
// length for prefill — and works on `s.x` as an `(m, d)` matrix.
// ---------------------------------------------------------------------

/// Embed `(token, position)` pairs into consecutive rows of `x`
/// (`x[r] = embed[tok_r] + pos[p_r]`); callers size `x` first.
fn embed_rows(
    w: &Weights,
    x: &mut [f32],
    d: usize,
    rows: impl Iterator<Item = (u32, usize)>,
) -> anyhow::Result<()> {
    let embed = w.get("embed")?;
    let ppos = w.get("pos")?;
    for (r, (tok, pos)) in rows.enumerate() {
        let (e, p) = (embed.row(tok as usize), ppos.row(pos));
        for (o, (&a, &b)) in x[r * d..(r + 1) * d].iter_mut().zip(e.iter().zip(p)) {
            *o = a + b;
        }
    }
    Ok(())
}

/// LN1(x) → one fused QKV projection over `m` stacked rows into
/// `s.qkv` (`(m, 3d)`).
fn layer_qkv(w: &Weights, s: &mut DecodeScratch, li: usize, m: usize, d: usize, act_q: ActQuant) -> anyhow::Result<()> {
    let _span = crate::obs::trace::span_id("op", "qkv", li as u64);
    s.h.clear();
    s.h.extend_from_slice(&s.x);
    layer_norm_flat(&mut s.h, d, w.get(&s.names[li].ln1_g)?, w.get(&s.names[li].ln1_b)?, 1e-5);
    qmatmul_rows_into(w, &s.names[li].wqkv, &s.h, m, d, act_q, &mut s.qkv, &mut s.aq, &mut s.panel)?;
    Ok(())
}

/// Output projection of the attention block + residual add into `x`.
fn layer_wo_residual(w: &Weights, s: &mut DecodeScratch, li: usize, m: usize, d: usize, act_q: ActQuant) -> anyhow::Result<()> {
    let _span = crate::obs::trace::span_id("op", "wo", li as u64);
    qmatmul_rows_into(w, &s.names[li].wo, &s.attn, m, d, act_q, &mut s.proj, &mut s.aq, &mut s.panel)?;
    for (xv, pv) in s.x.iter_mut().zip(&s.proj) {
        *xv += pv;
    }
    Ok(())
}

/// MLP block over `m` stacked rows: LN2 → W1 → GELU → W2 + residual.
fn layer_mlp(w: &Weights, s: &mut DecodeScratch, li: usize, m: usize, d: usize, act_q: ActQuant) -> anyhow::Result<()> {
    let _span = crate::obs::trace::span_id("op", "mlp", li as u64);
    s.h.clear();
    s.h.extend_from_slice(&s.x);
    layer_norm_flat(&mut s.h, d, w.get(&s.names[li].ln2_g)?, w.get(&s.names[li].ln2_b)?, 1e-5);
    let d_ff = qmatmul_rows_into(w, &s.names[li].w1, &s.h, m, d, act_q, &mut s.ff, &mut s.aq, &mut s.panel)?;
    gelu(&mut s.ff);
    qmatmul_rows_into(w, &s.names[li].w2, &s.ff, m, d_ff, act_q, &mut s.proj, &mut s.aq, &mut s.panel)?;
    for (xv, dv) in s.x.iter_mut().zip(&s.proj) {
        *xv += dv;
    }
    Ok(())
}

/// Final layer norm over **every** stacked row (row-independent, cheap)
/// + the tied LM-head GEMM over rows `row0..row0 + rows` only — decode
/// samples frontier rows, so the vocab GEMM never runs on a row nobody
/// reads.
fn lm_head(cfg: &ModelConfig, w: &Weights, s: &mut DecodeScratch, row0: usize, rows: usize) -> anyhow::Result<()> {
    let _span = crate::obs::trace::span("op", "lm_head");
    let d = cfg.d;
    layer_norm_flat(&mut s.x, d, w.get("lnf.g")?, w.get("lnf.b")?, 1e-5);
    let head = w.packed_transposed("embed")?;
    s.logits.resize(rows * cfg.vocab, 0.0);
    kernels::gemm_into_flat_with(&s.x[row0 * d..(row0 + rows) * d], rows, d, &*head, &mut s.logits, &mut s.panel);
    Ok(())
}

/// Make one (slot, layer, head)'s cached history attendable under the
/// scratch's [`AttnPath`] and return its length: `Gather` decodes the
/// whole history into `s.k`/`s.v`; `Encoded` resolves the page run and
/// revalidates its decoded `K^T`/V panels (only pages whose pool
/// generation moved — in steady state, just the frontier page — are
/// re-decoded).
fn resolve_head(cache: &PagedKvCache, s: &mut DecodeScratch, slot: SlotId, li: usize, head: usize) -> usize {
    match s.attn_path {
        AttnPath::Gather => cache.gather_kv(slot, li, head, &mut s.k, &mut s.v),
        AttnPath::Encoded => {
            let lay = cache.layout();
            let len = cache.page_run(slot, li, head, &mut s.page_run);
            let pages = len.div_ceil(lay.page_tokens);
            s.panels.ensure(cache.pool(), cache.quantizer(), lay.head_dim, &s.page_run[..pages]);
            len
        }
    }
}

/// One (row, head) of decode attention over the first `n` cached
/// tokens: scores = (q · K) * scale, causal softmax, ctx = p · V,
/// written to `s.attn[out_off..out_off + hd]`. The query is
/// `s.qkv[q_off..q_off + hd]`; [`resolve_head`] must have run for this
/// head.
///
/// Both paths produce identical bits. `Gather` is the scalar reference:
/// a per-element dot over `head_dim` ascending, then the same
/// `KC`-chunked context reduction the blocked kernel uses. `Encoded`
/// feeds the cached `K^T` panels to the blocked GEMM driver — one
/// `k`-block (`head_dim <= KC`), accumulators starting at the zeroed
/// output, products added in the same per-element order (the dispatch
/// contract: no FMA, no reassociation) — and scales after, `acc * scale`
/// either way; its context product reads the decoded V rows in the same
/// token order the gathered copy would have.
fn attend_span(s: &mut DecodeScratch, pt: usize, hd: usize, n: usize, q_off: usize, out_off: usize, scale: f32) {
    debug_assert!(hd <= KC, "head_dim {hd} spans multiple k-blocks");
    s.scores.resize(n, 0.0);
    match s.attn_path {
        AttnPath::Gather => {
            for (j, sc) in s.scores.iter_mut().enumerate() {
                let q = &s.qkv[q_off..q_off + hd];
                let krow = &s.k[j * hd..(j + 1) * hd];
                let mut acc = 0.0f32;
                for (a, b) in q.iter().zip(krow) {
                    acc += a * b;
                }
                *sc = acc * scale;
            }
        }
        AttnPath::Encoded => {
            // During prefill a page can hold tokens past this row's
            // causal span; the view's `n` masks them — the driver
            // discards the columns past `n`, same as packed zero-pad.
            let view = s.panels.kt_view(&s.page_run[..n.div_ceil(pt)], n);
            kernels::gemm_into_flat_with(&s.qkv[q_off..q_off + hd], 1, hd, &view, &mut s.scores, &mut s.panel);
            for sc in s.scores[..n].iter_mut() {
                *sc *= scale;
            }
        }
    }
    softmax_rows(&mut s.scores, n);
    // ctx = p · V, reduced over tokens in KC-sized chunks with a fresh
    // accumulator per chunk — the blocked driver's order.
    s.ctx.fill(0.0);
    let mut j0 = 0usize;
    while j0 < n {
        let jc = KC.min(n - j0);
        s.acc.fill(0.0);
        for j in j0..j0 + jc {
            let pj = s.scores[j];
            let vrow = match s.attn_path {
                AttnPath::Gather => &s.v[j * hd..(j + 1) * hd],
                AttnPath::Encoded => s.panels.v_row(&s.page_run, j),
            };
            for (a, &b) in s.acc.iter_mut().zip(vrow) {
                *a += pj * b;
            }
        }
        for (c, &a) in s.ctx.iter_mut().zip(s.acc.iter()) {
            *c += a;
        }
        j0 += jc;
    }
    s.attn[out_off..out_off + hd].copy_from_slice(&s.ctx);
}

/// Fill `slot` with a whole prompt — [`prefill_from`] at offset 0 with
/// a scratch of its own. Kept as the convenience entry point for tests
/// and benches; the serving session calls [`prefill_from`] directly so
/// prefix-cache hits skip the cached tokens and the session's scratch
/// is reused across requests.
pub fn prefill(
    cfg: &ModelConfig,
    w: &Weights,
    cache: &mut PagedKvCache,
    slot: SlotId,
    tokens: &[u32],
    act_q: ActQuant,
) -> anyhow::Result<Vec<f32>> {
    let mut scratch = DecodeScratch::new();
    prefill_from(cfg, w, cache, slot, tokens, 0, act_q, &mut scratch)
}

/// Prefill `slot` with the **uncached suffix** of a prompt: the cache
/// already holds `offset` tokens (0 for a cold prompt; the adopted
/// prefix length on a prefix-cache hit), and this computes positions
/// `offset..tokens.len()` only — the saved prefill work is exactly what
/// the prefix cache exists to harvest. Returns the **last position's**
/// logits (`vocab` floats), the only row the decode loop samples.
///
/// Numerics: the suffix runs as one `(m, d)` stacked forward — each
/// projection/FFN GEMM once over all suffix rows — and attention is
/// computed **against the cache** (per row, over the history at that
/// row's position), in the same accumulation order `decode_step` uses.
/// Consequences, both load-bearing:
///
/// - With an f32 cache the cached history equals the in-flight values,
///   so prefill reproduces the full forward bit for bit (pinned by the
///   decode-parity suite).
/// - With a BCQ (KV4) cache, attention reads the **quantized** history —
///   the same values any later decode step would read. The K/V appended
///   at position `p` is therefore a deterministic function of
///   `tokens[..=p]` and the weights alone, independent of where the
///   prefill/decode boundary fell or which pages were adopted — which is
///   what makes a warm (adopted-prefix) prefill bit-identical to a cold
///   one (`tests/prefix_parity.rs`) and cached pages safe to share
///   across requests.
#[allow(clippy::too_many_arguments)]
pub fn prefill_from(
    cfg: &ModelConfig,
    w: &Weights,
    cache: &mut PagedKvCache,
    slot: SlotId,
    tokens: &[u32],
    offset: usize,
    act_q: ActQuant,
    scratch: &mut DecodeScratch,
) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(!tokens.is_empty(), "empty prompt");
    anyhow::ensure!(offset < tokens.len(), "prefill offset {offset} >= prompt length {}", tokens.len());
    let lay = cache.layout();
    anyhow::ensure!(
        lay.n_layers == cfg.n_layers && lay.n_heads == cfg.n_heads && lay.head_dim == cfg.head_dim(),
        "cache layout does not match model config"
    );
    anyhow::ensure!(tokens.len() <= lay.max_tokens, "prompt {} > cache capacity {}", tokens.len(), lay.max_tokens);
    anyhow::ensure!(tokens.len() <= cfg.max_t, "prompt {} > max_t {}", tokens.len(), cfg.max_t);
    let (max_tokens, pt) = (lay.max_tokens, lay.page_tokens);
    anyhow::ensure!(
        cache.seq_len(slot) == offset,
        "cache holds {} tokens for slot {slot}, prefill expects {offset}",
        cache.seq_len(slot)
    );
    for &tok in &tokens[offset..] {
        anyhow::ensure!((tok as usize) < cfg.vocab, "token {tok} out of vocab");
    }
    let (d, hd) = (cfg.d, cfg.head_dim());
    let m = tokens.len() - offset;
    let mut prefill_span = crate::obs::trace::span_id("model", "prefill_chunk", slot as u64);
    prefill_span.set_arg(m as u64);
    let scale = 1.0 / (hd as f32).sqrt();
    // Reserve the whole chunk's pages up front: a KV-page shortfall must
    // surface as a typed KvPressure error *before* any layer appends, so
    // the slot still holds exactly `offset` tokens and the scheduler can
    // retry the same prefill_from call once pressure clears.
    cache.ensure_page_headroom(cache.pages_needed(slot, m))?;
    scratch.pin_attention_capacity(max_tokens, hd, pt);

    // ---- embed the suffix: x[r] = embed[tok_{offset+r}] + pos[offset+r] ----
    scratch.x.resize(m * d, 0.0);
    embed_rows(w, &mut scratch.x, d, (offset..tokens.len()).map(|p| (tokens[p], p)))?;

    scratch.ctx.resize(hd, 0.0);
    scratch.acc.resize(hd, 0.0);
    scratch.ensure_names(cfg.n_layers);
    for li in 0..cfg.n_layers {
        let _layer_span = crate::obs::trace::span_id("layer", "layer", li as u64);
        // --- attention block: one fused QKV GEMM over the suffix, then
        // append every row's K/V before attending, so one history
        // resolve per head serves all suffix rows (row r reads its
        // causal prefix) ---
        layer_qkv(w, scratch, li, m, d, act_q)?;
        for r in 0..m {
            let row = &scratch.qkv[r * 3 * d..(r + 1) * 3 * d];
            cache.append(slot, li, &row[d..2 * d], &row[2 * d..3 * d])?;
        }
        scratch.attn.resize(m * d, 0.0);
        let attn_span = crate::obs::trace::span_id("op", "attn", li as u64);
        for head in 0..cfg.n_heads {
            let off = head * hd;
            let len = resolve_head(cache, scratch, slot, li, head);
            debug_assert_eq!(len, offset + m);
            for r in 0..m {
                let n = offset + r + 1; // this row's causal span
                attend_span(scratch, pt, hd, n, r * 3 * d + off, r * d + off, scale);
            }
        }
        drop(attn_span);
        layer_wo_residual(w, scratch, li, m, d, act_q)?;
        layer_mlp(w, scratch, li, m, d, act_q)?;
    }

    lm_head(cfg, w, scratch, m - 1, 1)?;
    Ok(scratch.logits[..cfg.vocab].to_vec())
}

/// Per-lane admission check for a decode step, shared by
/// [`decode_step_batch`] (whole-call validation) and the engine layer's
/// per-lane screening (`DecodeSession::decode_batch`) — **one source of
/// truth**, so the screen can never drift from what the fused step
/// enforces and let a bad lane poison its step-mates. Returns the
/// lane's current cache position.
pub fn validate_decode_lane(
    cfg: &ModelConfig,
    cache: &PagedKvCache,
    slots: &[SlotId],
    i: usize,
    token: u32,
) -> anyhow::Result<usize> {
    let slot = slots[i];
    anyhow::ensure!(cache.is_live(slot), "decode on dead slot {slot}");
    anyhow::ensure!(!slots[..i].contains(&slot), "slot {slot} appears twice in one batched step");
    let pos = cache.seq_len(slot);
    anyhow::ensure!(pos > 0, "decode_step before prefill (slot {slot})");
    anyhow::ensure!(pos < cache.layout().max_tokens, "cache slot {slot} full ({pos} tokens)");
    anyhow::ensure!(pos < cfg.max_t, "position {pos} >= max_t {} (slot {slot})", cfg.max_t);
    anyhow::ensure!((token as usize) < cfg.vocab, "token {token} out of vocab");
    Ok(pos)
}

/// Decode one token against the cached history: appends its K/V per
/// layer, attends over the cache (O(len) per head), and returns the new
/// position's logits (`vocab` floats). Attention reductions follow the
/// blocked kernel's accumulation order, so with an f32 cache the result
/// is bit-exact with the corresponding row of the full forward.
///
/// This is the single-lane **reference** the batched step is verified
/// against — it shares the scratch buffers and per-layer helpers but
/// keeps the straightforward one-lane control flow.
pub fn decode_step(
    cfg: &ModelConfig,
    w: &Weights,
    cache: &mut PagedKvCache,
    slot: SlotId,
    token: u32,
    act_q: ActQuant,
    scratch: &mut DecodeScratch,
) -> anyhow::Result<Vec<f32>> {
    let pos = validate_decode_lane(cfg, cache, &[slot], 0, token)?;
    // One token touches every layer; reserve its pages before the first
    // append so a budget shortfall leaves the lane resumable at `pos`.
    cache.ensure_page_headroom(cache.pages_needed(slot, 1))?;
    let (d, hd) = (cfg.d, cfg.head_dim());
    let lay = cache.layout();
    let pt = lay.page_tokens;
    let scale = 1.0 / (hd as f32).sqrt();
    scratch.pin_attention_capacity(lay.max_tokens, hd, pt);

    // Embed the frontier token at its position.
    scratch.x.resize(d, 0.0);
    embed_rows(w, &mut scratch.x, d, std::iter::once((token, pos)))?;

    scratch.ctx.resize(hd, 0.0);
    scratch.acc.resize(hd, 0.0);
    scratch.ensure_names(cfg.n_layers);
    for li in 0..cfg.n_layers {
        let _layer_span = crate::obs::trace::span_id("layer", "layer", li as u64);
        // --- attention block ---
        layer_qkv(w, scratch, li, 1, d, act_q)?;
        let n = cache.append(slot, li, &scratch.qkv[d..2 * d], &scratch.qkv[2 * d..3 * d])?;
        scratch.attn.resize(d, 0.0);
        let attn_span = crate::obs::trace::span_id("op", "attn", li as u64);
        for head in 0..cfg.n_heads {
            let off = head * hd;
            let len = resolve_head(cache, scratch, slot, li, head);
            debug_assert_eq!(len, n);
            attend_span(scratch, pt, hd, n, off, off, scale);
        }
        drop(attn_span);
        layer_wo_residual(w, scratch, li, 1, d, act_q)?;
        layer_mlp(w, scratch, li, 1, d, act_q)?;
    }

    lm_head(cfg, w, scratch, 0, 1)?;
    Ok(scratch.logits.clone())
}

/// One **fused decode step across every listed lane**: stacks the
/// frontier tokens into a `(lanes, d)` activation matrix, runs each
/// projection / FFN / LM-head GEMM once with `M = lanes` (the packed or
/// encoded weight panel is streamed **once per step**, not once per
/// lane), and splits per lane only for attention against each lane's
/// paged KV history at its own ragged position. Appends one K/V row per
/// lane per layer through the cache's multi-slot
/// [`append_batch`](crate::kvcache::PagedKvCache::append_batch).
///
/// Returns the stacked `(lanes, vocab)` frontier logits, row `i` for
/// `slots[i]`, borrowed from `scratch` (zero-copy; callers that need
/// owned per-lane vectors split it). **Bit-identical** to calling
/// [`decode_step`] once per lane in any order: activations are
/// quantized per row, GEMM rows accumulate independently, and each
/// lane's attention reads only its own slot.
///
/// Validates every lane **before** touching the cache, so a bad lane
/// (dead slot, full slot, out-of-vocab token, duplicate) fails the call
/// with the cache unmodified — the engine layer uses that to fail one
/// request without poisoning its batch.
pub fn decode_step_batch<'s>(
    cfg: &ModelConfig,
    w: &Weights,
    cache: &mut PagedKvCache,
    slots: &[SlotId],
    tokens: &[u32],
    act_q: ActQuant,
    scratch: &'s mut DecodeScratch,
) -> anyhow::Result<&'s [f32]> {
    let lanes = slots.len();
    anyhow::ensure!(lanes >= 1, "decode_step_batch with no lanes");
    anyhow::ensure!(tokens.len() == lanes, "{} tokens for {lanes} lanes", tokens.len());
    let mut step_span = crate::obs::trace::span("model", "decode_step");
    step_span.set_arg(lanes as u64);
    let (d, hd) = (cfg.d, cfg.head_dim());
    let lay = cache.layout();
    let pt = lay.page_tokens;
    let scale = 1.0 / (hd as f32).sqrt();

    // ---- validate everything up front (shared per-lane check); no
    // cache mutation on failure ----
    scratch.pos.clear();
    for (i, &tok) in tokens.iter().enumerate() {
        let pos = validate_decode_lane(cfg, cache, slots, i, tok)?;
        scratch.pos.push(pos);
    }
    // Whole-step page pre-check: lanes at a page boundary each claim one
    // fresh page per (layer, head) this step. Failing here — before the
    // first layer's append — keeps every lane resumable at its current
    // position, so the scheduler can shed load and replay the step.
    let needed: usize = slots.iter().map(|&s| cache.pages_needed(s, 1)).sum();
    cache.ensure_page_headroom(needed)?;
    scratch.pin_attention_capacity(lay.max_tokens, hd, pt);

    // ---- embed all frontier tokens: x[i] = embed[tok_i] + pos[p_i] ----
    scratch.x.resize(lanes * d, 0.0);
    embed_rows(w, &mut scratch.x, d, tokens.iter().zip(&scratch.pos).map(|(&t, &p)| (t, p)))?;

    scratch.ctx.resize(hd, 0.0);
    scratch.acc.resize(hd, 0.0);
    scratch.ensure_names(cfg.n_layers);
    for li in 0..cfg.n_layers {
        let _layer_span = crate::obs::trace::span_id("layer", "layer", li as u64);
        // --- attention block: one fused QKV GEMM, per-lane attention ---
        layer_qkv(w, scratch, li, lanes, d, act_q)?;
        cache.append_batch(slots, li, &scratch.qkv, 3 * d, d, 2 * d)?;
        scratch.attn.resize(lanes * d, 0.0);
        let attn_span = crate::obs::trace::span_id("op", "attn", li as u64);
        for i in 0..lanes {
            let n = scratch.pos[i] + 1; // this lane's attention span
            let qbase = i * 3 * d;
            for head in 0..cfg.n_heads {
                let off = head * hd;
                let len = resolve_head(cache, scratch, slots[i], li, head);
                debug_assert_eq!(len, n);
                attend_span(scratch, pt, hd, n, qbase + off, i * d + off, scale);
            }
        }
        drop(attn_span);
        layer_wo_residual(w, scratch, li, lanes, d, act_q)?;
        layer_mlp(w, scratch, li, lanes, d, act_q)?;
    }

    lm_head(cfg, w, scratch, 0, lanes)?;
    Ok(&scratch.logits[..lanes * cfg.vocab])
}

/// One fused **stacked-verify** step ([`decode_step_batch`] with
/// speculative drafts): lane `i` contributes `1 + drafts[i].len()`
/// consecutive rows — its frontier token followed by its draft — so a
/// step of `Σ (1 + k_i)` rows runs every projection / FFN / LM-head
/// GEMM **once**, streaming the packed/encoded weight panels a single
/// time for all drafted positions. Appends reuse the [`prefill_from`]
/// suffix mechanics (each lane's rows land contiguously per layer before
/// any attention runs), and attention is ragged both across lanes and
/// across rows: row `r` of lane `i` attends over `pos_i + r + 1` tokens.
///
/// Returns stacked `(Σ rows_i, vocab)` logits; lane `i`'s rows start at
/// offset `Σ_{j<i} (1 + k_j)` (the caller mirrors the prefix sums). Row
/// `r` holds the logits **after** the lane's `r`-th stacked token, so
/// greedy verification walks the rows: accept `drafts[i][m]` while it
/// equals row `m`'s sampled token; the first mismatching row still
/// yields the corrected token — the bonus row that makes a fully
/// rejected draft cost nothing over a plain decode step.
///
/// Numerics: every row attends over exactly the causal history a
/// sequential [`decode_step`] at that position would see, and with
/// either KV store the cached K/V at a position is a function of the
/// token prefix alone — so the stacked rows are **bit-identical** to
/// feeding the same tokens one `decode_step` at a time (pinned by a
/// module test), and rejected rows erased by
/// [`PagedKvCache::truncate`] leave the cache bit-identical to a
/// never-speculated session.
///
/// Validates every lane, draft token, and capacity bound and
/// pre-reserves **all** pages before the first append: a failure
/// (including typed `KvPressure`) leaves every slot at its pre-step
/// length, so the scheduler can drop the whole speculative step
/// atomically and replay it plain.
#[allow(clippy::too_many_arguments)]
pub fn decode_step_batch_spec<'s>(
    cfg: &ModelConfig,
    w: &Weights,
    cache: &mut PagedKvCache,
    slots: &[SlotId],
    tokens: &[u32],
    drafts: &[Vec<u32>],
    act_q: ActQuant,
    scratch: &'s mut DecodeScratch,
) -> anyhow::Result<&'s [f32]> {
    let lanes = slots.len();
    anyhow::ensure!(lanes >= 1, "decode_step_batch_spec with no lanes");
    anyhow::ensure!(tokens.len() == lanes, "{} tokens for {lanes} lanes", tokens.len());
    anyhow::ensure!(drafts.len() == lanes, "{} drafts for {lanes} lanes", drafts.len());
    let mut step_span = crate::obs::trace::span("model", "decode_step_spec");
    let (d, hd) = (cfg.d, cfg.head_dim());
    let lay = cache.layout();
    let pt = lay.page_tokens;
    let scale = 1.0 / (hd as f32).sqrt();

    // ---- validate lanes, draft tokens, and capacity up front (shared
    // per-lane check); no cache mutation on failure ----
    scratch.pos.clear();
    scratch.row0.clear();
    let mut total_rows = 0usize;
    for i in 0..lanes {
        let pos = validate_decode_lane(cfg, cache, slots, i, tokens[i])?;
        for &t in &drafts[i] {
            anyhow::ensure!((t as usize) < cfg.vocab, "draft token {t} out of vocab");
        }
        let rows = 1 + drafts[i].len();
        anyhow::ensure!(
            pos + rows <= lay.max_tokens && pos + rows <= cfg.max_t,
            "draft of {} overruns capacity at position {pos} (slot {})",
            drafts[i].len(),
            slots[i]
        );
        scratch.pos.push(pos);
        scratch.row0.push(total_rows);
        total_rows += rows;
    }
    step_span.set_arg(total_rows as u64);
    // Whole-step page pre-check over every stacked row: a shortfall
    // surfaces as typed KvPressure before the first append, keeping the
    // whole speculative step atomic.
    let needed: usize =
        slots.iter().zip(drafts).map(|(&s, dr)| cache.pages_needed(s, 1 + dr.len())).sum();
    cache.ensure_page_headroom(needed)?;
    scratch.pin_attention_capacity(lay.max_tokens, hd, pt);

    // ---- embed all stacked rows: lane i's row r is its r-th fed token
    // at position pos_i + r ----
    scratch.x.resize(total_rows * d, 0.0);
    embed_rows(
        w,
        &mut scratch.x,
        d,
        tokens.iter().zip(drafts).zip(&scratch.pos).flat_map(|((&t, dr), &p)| {
            std::iter::once((t, p))
                .chain(dr.iter().enumerate().map(move |(r, &dt)| (dt, p + 1 + r)))
        }),
    )?;

    scratch.ctx.resize(hd, 0.0);
    scratch.acc.resize(hd, 0.0);
    scratch.ensure_names(cfg.n_layers);
    for li in 0..cfg.n_layers {
        let _layer_span = crate::obs::trace::span_id("layer", "layer", li as u64);
        // --- attention block: one fused QKV GEMM over every stacked
        // row, then prefill_from-style contiguous per-lane appends so
        // each head resolves its history once for all of a lane's rows ---
        layer_qkv(w, scratch, li, total_rows, d, act_q)?;
        for i in 0..lanes {
            for r in 0..1 + drafts[i].len() {
                let row = scratch.row0[i] + r;
                let qkv = &scratch.qkv[row * 3 * d..(row + 1) * 3 * d];
                cache.append(slots[i], li, &qkv[d..2 * d], &qkv[2 * d..3 * d])?;
            }
        }
        scratch.attn.resize(total_rows * d, 0.0);
        let attn_span = crate::obs::trace::span_id("op", "attn", li as u64);
        for i in 0..lanes {
            let rows = 1 + drafts[i].len();
            for head in 0..cfg.n_heads {
                let off = head * hd;
                let len = resolve_head(cache, scratch, slots[i], li, head);
                debug_assert_eq!(len, scratch.pos[i] + rows);
                for r in 0..rows {
                    let n = scratch.pos[i] + r + 1; // this row's causal span
                    let row = scratch.row0[i] + r;
                    attend_span(scratch, pt, hd, n, row * 3 * d + off, row * d + off, scale);
                }
            }
        }
        drop(attn_span);
        layer_wo_residual(w, scratch, li, total_rows, d, act_q)?;
        layer_mlp(w, scratch, li, total_rows, d, act_q)?;
    }

    lm_head(cfg, w, scratch, 0, total_rows)?;
    Ok(&scratch.logits[..total_rows * cfg.vocab])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{KvLayout, KvQuantizer, KvStore};
    use crate::model::forward::forward;
    use crate::model::forward::tests_support::{random_weights, tiny_cfg};

    fn f32_cache(cfg: &ModelConfig, slots: usize) -> PagedKvCache {
        PagedKvCache::new(KvLayout::for_model(cfg, 4, slots), KvStore::F32).unwrap()
    }

    #[test]
    fn prefill_plus_decode_matches_full_forward_bitwise() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 41);
        let tokens: Vec<u32> = (0..12).map(|i| (i * 7 % 40) as u32).collect();
        let full = forward(&cfg, &w, &tokens, 1, None).unwrap();
        for split in [1usize, 5, 11] {
            let mut cache = f32_cache(&cfg, 1);
            let slot = cache.alloc_slot().unwrap();
            let mut scratch = DecodeScratch::new();
            let mut got = vec![prefill(&cfg, &w, &mut cache, slot, &tokens[..split], None).unwrap()];
            for &tok in &tokens[split..] {
                got.push(decode_step(&cfg, &w, &mut cache, slot, tok, None, &mut scratch).unwrap());
            }
            // got[0] is logits at position split-1; got[k] at split-1+k.
            for (k, logits) in got.iter().enumerate() {
                let pos = split - 1 + k;
                for (c, &g) in logits.iter().enumerate() {
                    let want = full.at(pos, c);
                    assert_eq!(
                        g.to_bits(),
                        want.to_bits(),
                        "split {split} pos {pos} col {c}: {g} vs {want}"
                    );
                }
            }
            assert_eq!(cache.seq_len(slot), tokens.len());
        }
    }

    #[test]
    fn encoded_attention_is_bit_identical_to_gather() {
        // Twin sessions, one scratch pinned per path, over both KV
        // stores: every prefill and decode logit row must agree to the
        // bit — the contract that lets the encoded path replace the
        // gather path silently (and the property the gather path is
        // retained to witness).
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 47);
        let hd = cfg.head_dim();
        let sample: Vec<f32> = w.get("l0.attn.wqkv").unwrap().data.clone();
        let tokens: Vec<u32> = (0..11).map(|i| (i * 11 % 40) as u32).collect();
        for encoded in [false, true] {
            let mk = || {
                let store = if encoded {
                    KvStore::Encoded(KvQuantizer::calibrated(hd, &sample[..hd * 32], 23).unwrap())
                } else {
                    KvStore::F32
                };
                PagedKvCache::new(KvLayout::for_model(&cfg, 4, 1), store).unwrap()
            };
            let (mut cg, mut ce) = (mk(), mk());
            let sg = cg.alloc_slot().unwrap();
            let se = ce.alloc_slot().unwrap();
            let (mut scr_g, mut scr_e) = (DecodeScratch::new(), DecodeScratch::new());
            scr_g.set_attn_path(AttnPath::Gather);
            scr_e.set_attn_path(AttnPath::Encoded);
            // Split prefill so the encoded path sees both a partially
            // filled frontier page and rows whose causal span ends
            // mid-page (the masked-columns case).
            let a = prefill_from(&cfg, &w, &mut cg, sg, &tokens[..6], 0, None, &mut scr_g).unwrap();
            let b = prefill_from(&cfg, &w, &mut ce, se, &tokens[..6], 0, None, &mut scr_e).unwrap();
            for (c, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "encoded={encoded} prefill col {c}");
            }
            for (t, &tok) in tokens[6..].iter().enumerate() {
                let x = decode_step(&cfg, &w, &mut cg, sg, tok, None, &mut scr_g).unwrap();
                let y = decode_step(&cfg, &w, &mut ce, se, tok, None, &mut scr_e).unwrap();
                for (c, (x, y)) in x.iter().zip(&y).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "encoded={encoded} step {t} col {c}");
                }
            }
        }
    }

    #[test]
    fn suffix_prefill_matches_whole_prompt_prefill_bitwise() {
        // prefill(tokens[..k]) then prefill_from(tokens, k) must equal
        // prefill(tokens) to the bit — the property a prefix-cache warm
        // hit relies on (the adopted prefix plays the role of the first
        // chunk). Checked on f32 and BCQ-encoded KV stores.
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 46);
        let tokens: Vec<u32> = (0..12).map(|i| (i * 5 % 40) as u32).collect();
        let hd = cfg.head_dim();
        let sample: Vec<f32> = w.get("l0.attn.wqkv").unwrap().data.clone();
        for encoded in [false, true] {
            let mk = || {
                let store = if encoded {
                    KvStore::Encoded(KvQuantizer::calibrated(hd, &sample[..hd * 32], 9).unwrap())
                } else {
                    KvStore::F32
                };
                PagedKvCache::new(KvLayout::for_model(&cfg, 4, 1), store).unwrap()
            };
            let mut cold = mk();
            let cs = cold.alloc_slot().unwrap();
            let want = prefill(&cfg, &w, &mut cold, cs, &tokens, None).unwrap();
            for split in [1usize, 4, 6, 11] {
                let mut warm = mk();
                let ws = warm.alloc_slot().unwrap();
                let mut scratch = DecodeScratch::new();
                prefill(&cfg, &w, &mut warm, ws, &tokens[..split], None).unwrap();
                let got =
                    prefill_from(&cfg, &w, &mut warm, ws, &tokens, split, None, &mut scratch).unwrap();
                assert_eq!(warm.seq_len(ws), tokens.len());
                for (c, (&g, &x)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(g.to_bits(), x.to_bits(), "encoded={encoded} split {split} col {c}");
                }
                // Misuse: wrong offset for the cache position.
                assert!(prefill_from(&cfg, &w, &mut warm, ws, &tokens, 3, None, &mut scratch).is_err());
            }
        }
    }

    #[test]
    fn batched_step_matches_single_lane_bitwise() {
        // Twin caches: one driven per-lane by decode_step, one by the
        // fused batch step, over ragged prefill lengths. Every lane's
        // logits must agree to the bit at every step.
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 44);
        let prompts: [&[u32]; 3] = [&[1, 2, 3, 4, 5], &[7], &[9, 10, 11]];
        let mut serial = f32_cache(&cfg, 3);
        let mut batched = f32_cache(&cfg, 3);
        let mut ss = DecodeScratch::new();
        let mut sb = DecodeScratch::new();
        let mut slots_s = Vec::new();
        let mut slots_b = Vec::new();
        for p in prompts {
            let a = serial.alloc_slot().unwrap();
            let b = batched.alloc_slot().unwrap();
            prefill(&cfg, &w, &mut serial, a, p, None).unwrap();
            prefill(&cfg, &w, &mut batched, b, p, None).unwrap();
            slots_s.push(a);
            slots_b.push(b);
        }
        for step in 0..4u32 {
            let tokens: Vec<u32> = (0..3).map(|i| (step * 3 + i + 12) % 40).collect();
            let fused = decode_step_batch(&cfg, &w, &mut batched, &slots_b, &tokens, None, &mut sb)
                .unwrap()
                .to_vec();
            for (i, &slot) in slots_s.iter().enumerate() {
                let lone = decode_step(&cfg, &w, &mut serial, slot, tokens[i], None, &mut ss).unwrap();
                for (c, (&g, &want)) in fused[i * cfg.vocab..(i + 1) * cfg.vocab].iter().zip(&lone).enumerate() {
                    assert_eq!(g.to_bits(), want.to_bits(), "step {step} lane {i} col {c}");
                }
            }
        }
    }

    #[test]
    fn batched_step_rejects_misuse_without_mutating() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 45);
        let mut cache = f32_cache(&cfg, 2);
        let a = cache.alloc_slot().unwrap();
        let b = cache.alloc_slot().unwrap();
        let mut scratch = DecodeScratch::new();
        prefill(&cfg, &w, &mut cache, a, &[1, 2], None).unwrap();
        // b has no prefill; duplicate slots; token/lane count mismatch;
        // out-of-vocab token — all rejected, none advance slot a.
        assert!(decode_step_batch(&cfg, &w, &mut cache, &[a, b], &[3, 4], None, &mut scratch).is_err());
        assert!(decode_step_batch(&cfg, &w, &mut cache, &[a, a], &[3, 4], None, &mut scratch).is_err());
        assert!(decode_step_batch(&cfg, &w, &mut cache, &[a], &[3, 4], None, &mut scratch).is_err());
        assert!(decode_step_batch(&cfg, &w, &mut cache, &[a], &[999], None, &mut scratch).is_err());
        assert!(decode_step_batch(&cfg, &w, &mut cache, &[], &[], None, &mut scratch).is_err());
        assert_eq!(cache.seq_len(a), 2, "failed batched step mutated the cache");
        let ok = decode_step_batch(&cfg, &w, &mut cache, &[a], &[3], None, &mut scratch).unwrap();
        assert_eq!(ok.len(), cfg.vocab);
    }

    #[test]
    fn spec_step_matches_sequential_decode_bitwise() {
        // Stacked-verify rows must equal feeding the same tokens one
        // decode_step at a time — per lane, per row, to the bit, on both
        // KV stores, with ragged drafts including an empty one (the
        // k = 0 lane degenerates to a plain decode row).
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 48);
        let hd = cfg.head_dim();
        let sample: Vec<f32> = w.get("l0.attn.wqkv").unwrap().data.clone();
        let prompts: [&[u32]; 3] = [&[1, 2, 3], &[7, 8], &[9]];
        let tokens = [4u32, 14, 10];
        let drafts: Vec<Vec<u32>> = vec![vec![5, 6], vec![], vec![11, 12, 13]];
        for encoded in [false, true] {
            let mk = || {
                let store = if encoded {
                    KvStore::Encoded(KvQuantizer::calibrated(hd, &sample[..hd * 32], 13).unwrap())
                } else {
                    KvStore::F32
                };
                PagedKvCache::new(KvLayout::for_model(&cfg, 4, 3), store).unwrap()
            };
            let (mut serial, mut spec) = (mk(), mk());
            let mut ss = DecodeScratch::new();
            let mut sb = DecodeScratch::new();
            let mut slots_s = Vec::new();
            let mut slots_b = Vec::new();
            for p in prompts {
                let a = serial.alloc_slot().unwrap();
                let b = spec.alloc_slot().unwrap();
                prefill(&cfg, &w, &mut serial, a, p, None).unwrap();
                prefill(&cfg, &w, &mut spec, b, p, None).unwrap();
                slots_s.push(a);
                slots_b.push(b);
            }
            let got =
                decode_step_batch_spec(&cfg, &w, &mut spec, &slots_b, &tokens, &drafts, None, &mut sb)
                    .unwrap()
                    .to_vec();
            let mut row = 0usize;
            for (i, &slot) in slots_s.iter().enumerate() {
                for (r, &tok) in std::iter::once(&tokens[i]).chain(&drafts[i]).enumerate() {
                    let lone = decode_step(&cfg, &w, &mut serial, slot, tok, None, &mut ss).unwrap();
                    for (c, (&g, &want)) in
                        got[row * cfg.vocab..(row + 1) * cfg.vocab].iter().zip(&lone).enumerate()
                    {
                        assert_eq!(
                            g.to_bits(),
                            want.to_bits(),
                            "encoded={encoded} lane {i} row {r} col {c}"
                        );
                    }
                    row += 1;
                }
                assert_eq!(spec.seq_len(slots_b[i]), serial.seq_len(slot));
            }
        }
    }

    #[test]
    fn spec_rollback_is_invisible_to_later_steps() {
        // A speculative step followed by truncate back to the accepted
        // frontier must leave the session bit-identical to one that
        // never speculated — full rejection and partial acceptance, on
        // both KV stores (the encoded store replays its append-only
        // pages bit-exactly on truncate).
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 49);
        let hd = cfg.head_dim();
        let sample: Vec<f32> = w.get("l0.attn.wqkv").unwrap().data.clone();
        for encoded in [false, true] {
            for accept in [0usize, 1] {
                let mk = || {
                    let store = if encoded {
                        KvStore::Encoded(KvQuantizer::calibrated(hd, &sample[..hd * 32], 19).unwrap())
                    } else {
                        KvStore::F32
                    };
                    PagedKvCache::new(KvLayout::for_model(&cfg, 4, 1), store).unwrap()
                };
                let (mut plain, mut spec) = (mk(), mk());
                let sp = plain.alloc_slot().unwrap();
                let sq = spec.alloc_slot().unwrap();
                let mut scr_p = DecodeScratch::new();
                let mut scr_q = DecodeScratch::new();
                prefill(&cfg, &w, &mut plain, sp, &[1, 2, 3], None).unwrap();
                prefill(&cfg, &w, &mut spec, sq, &[1, 2, 3], None).unwrap();
                // Draft [5, 30]: with accept = 1 the first token is kept
                // (plain twin decodes it too); the tail is rolled back.
                let draft = vec![5u32, 30];
                let got = decode_step_batch_spec(
                    &cfg, &w, &mut spec, &[sq], &[4], std::slice::from_ref(&draft), None, &mut scr_q,
                )
                .unwrap()
                .to_vec();
                let want = decode_step(&cfg, &w, &mut plain, sp, 4, None, &mut scr_p).unwrap();
                for (c, (&g, &x)) in got[..cfg.vocab].iter().zip(&want).enumerate() {
                    assert_eq!(g.to_bits(), x.to_bits(), "encoded={encoded} frontier row col {c}");
                }
                for &tok in &draft[..accept] {
                    decode_step(&cfg, &w, &mut plain, sp, tok, None, &mut scr_p).unwrap();
                }
                // Keep frontier + accepted prefix, erase the rejected tail.
                spec.truncate(sq, 3 + 1 + accept).unwrap();
                assert_eq!(spec.seq_len(sq), plain.seq_len(sp));
                for &tok in &[17u32, 18] {
                    let a = decode_step(&cfg, &w, &mut spec, sq, tok, None, &mut scr_q).unwrap();
                    let b = decode_step(&cfg, &w, &mut plain, sp, tok, None, &mut scr_p).unwrap();
                    for (c, (&g, &x)) in a.iter().zip(&b).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            x.to_bits(),
                            "encoded={encoded} accept={accept} post-rollback tok {tok} col {c}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn spec_footprint_is_constant_across_steps_at_fixed_k() {
        // The zero-steady-state-allocation property extends to the
        // speculative loop: at fixed k the scratch footprint must not
        // grow across draft → verify → rollback rounds.
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 50);
        let mut cache = f32_cache(&cfg, 2);
        let a = cache.alloc_slot().unwrap();
        let b = cache.alloc_slot().unwrap();
        let mut scratch = DecodeScratch::new();
        prefill_from(&cfg, &w, &mut cache, a, &[1, 2], 0, None, &mut scratch).unwrap();
        prefill_from(&cfg, &w, &mut cache, b, &[3], 0, None, &mut scratch).unwrap();
        let mut base = 0usize;
        for step in 0..5u32 {
            let tokens = [(4 + step) % 40, (9 + step) % 40];
            let drafts = vec![vec![(6 + step) % 40, (7 + step) % 40], vec![(8 + step) % 40, step % 40]];
            decode_step_batch_spec(&cfg, &w, &mut cache, &[a, b], &tokens, &drafts, None, &mut scratch)
                .unwrap();
            // Reject both lanes' draft tails every round so truncate is
            // in the loop (and the slots stay within capacity).
            for slot in [a, b] {
                let keep = cache.seq_len(slot) - 2;
                cache.truncate(slot, keep).unwrap();
            }
            if step == 0 {
                base = scratch.footprint();
                assert!(base > 0);
            } else {
                assert_eq!(scratch.footprint(), base, "speculative step {step} grew the scratch");
            }
        }
    }

    #[test]
    fn spec_step_rejects_misuse_without_mutating() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 51);
        let mut cache = f32_cache(&cfg, 1);
        let a = cache.alloc_slot().unwrap();
        let mut scratch = DecodeScratch::new();
        prefill(&cfg, &w, &mut cache, a, &[1, 2], None).unwrap();
        let cap = cache.layout().max_tokens;
        // Out-of-vocab draft token; draft overrunning slot capacity;
        // drafts/lanes arity mismatch — all rejected, none advance a.
        let bad_tok = vec![vec![999u32]];
        let too_long = vec![vec![1u32; cap]];
        let arity = vec![vec![1u32], vec![2]];
        assert!(decode_step_batch_spec(&cfg, &w, &mut cache, &[a], &[3], &bad_tok, None, &mut scratch)
            .is_err());
        assert!(decode_step_batch_spec(&cfg, &w, &mut cache, &[a], &[3], &too_long, None, &mut scratch)
            .is_err());
        assert!(decode_step_batch_spec(&cfg, &w, &mut cache, &[a], &[3], &arity, None, &mut scratch)
            .is_err());
        assert_eq!(cache.seq_len(a), 2, "failed speculative step mutated the cache");
        // An all-empty draft set is legal and equals one decode row per lane.
        let ok = decode_step_batch_spec(&cfg, &w, &mut cache, &[a], &[3], &[vec![]], None, &mut scratch)
            .unwrap();
        assert_eq!(ok.len(), cfg.vocab);
        assert_eq!(cache.seq_len(a), 3);
    }

    #[test]
    fn encoded_cache_decodes_finitely_and_differs_from_f32() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 42);
        let hd = cfg.head_dim();
        let sample: Vec<f32> = w.get("l0.attn.wqkv").unwrap().data.clone();
        let quant = KvQuantizer::calibrated(hd, &sample[..hd * 64], 17).unwrap();
        let mut enc_cache =
            PagedKvCache::new(KvLayout::for_model(&cfg, 4, 1), KvStore::Encoded(quant)).unwrap();
        let mut f32_cache = f32_cache(&cfg, 1);
        let se = enc_cache.alloc_slot().unwrap();
        let sf = f32_cache.alloc_slot().unwrap();
        let tokens: Vec<u32> = (0..6).map(|i| (i * 3 % 40) as u32).collect();
        let mut scratch = DecodeScratch::new();
        prefill(&cfg, &w, &mut enc_cache, se, &tokens[..2], None).unwrap();
        prefill(&cfg, &w, &mut f32_cache, sf, &tokens[..2], None).unwrap();
        let mut diff = 0.0f32;
        for &tok in &tokens[2..] {
            let a = decode_step(&cfg, &w, &mut enc_cache, se, tok, None, &mut scratch).unwrap();
            let b = decode_step(&cfg, &w, &mut f32_cache, sf, tok, None, &mut scratch).unwrap();
            assert!(a.iter().all(|x| x.is_finite()), "encoded-cache logits not finite");
            diff += a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum::<f32>();
        }
        assert!(diff > 0.0, "KV4 cache had no effect at all");
        assert!(enc_cache.state_bytes() < f32_cache.state_bytes(), "encoded cache not smaller");
    }

    #[test]
    fn decode_rejects_misuse() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 43);
        let mut cache = f32_cache(&cfg, 1);
        let slot = cache.alloc_slot().unwrap();
        let mut scratch = DecodeScratch::new();
        // decode before prefill, bad token, over-capacity prompt
        assert!(decode_step(&cfg, &w, &mut cache, slot, 0, None, &mut scratch).is_err());
        assert!(prefill(&cfg, &w, &mut cache, slot, &[999], None).is_err());
        assert!(prefill(&cfg, &w, &mut cache, slot, &vec![0; cfg.max_t + 1], None).is_err());
        prefill(&cfg, &w, &mut cache, slot, &[1, 2], None).unwrap();
        assert!(prefill(&cfg, &w, &mut cache, slot, &[1], None).is_err(), "re-prefill of a live slot");
    }
}
