//! Incremental forward: `prefill` fills the KV cache for a prompt,
//! `decode_step` runs **one token** against the cached history — O(len)
//! attention work per token instead of the full forward's O(t²)
//! re-score, and only the frontier row of logits is ever materialized.
//!
//! Numerics: with an f32 (KV16) cache the pair (prefill, decode_step)
//! reproduces [`forward`](super::forward::forward) — every sub-step is
//! row-independent in the reference forward (layer norm, GELU, per-row
//! GEMM accumulation, causal softmax whose masked tail contributes exact
//! `+0.0`), and the attention reductions here mirror the blocked
//! kernel's accumulation order (scores reduce over `head_dim < KC` in
//! one block; context reduces over tokens in the same `KC`-sized chunks
//! `kernels::gemm` uses). The decode-parity suite pins this. With a
//! BCQ-encoded (KV4) cache the gathered history is the quantized
//! decode of each vector — the KV4-vs-KV16 ablation in EXPERIMENTS.md.

use crate::kernels::KC;
use crate::kvcache::{PagedKvCache, Plane, SlotId};
use crate::model::config::ModelConfig;
use crate::model::forward::{gelu, layer_norm, qmatmul, softmax_rows, ActQuant};
use crate::model::weights::Weights;
use crate::tensor::Tensor;

/// Reusable state for [`decode_step`]: gathered K/V history, score row,
/// context accumulators, and the pre-rendered per-layer weight names
/// (decode runs per token, so the `format!` allocations are hoisted out
/// of the hot loop). A session that keeps one across steps performs no
/// per-step attention or name allocations once the buffers reach the
/// sequence's working size.
#[derive(Debug, Default)]
pub struct DecodeScratch {
    k: Vec<f32>,
    v: Vec<f32>,
    scores: Vec<f32>,
    ctx: Vec<f32>,
    acc: Vec<f32>,
    names: Vec<LayerNames>,
}

impl DecodeScratch {
    pub fn new() -> DecodeScratch {
        DecodeScratch::default()
    }
}

/// One layer's weight-map keys, rendered once.
#[derive(Debug)]
struct LayerNames {
    ln1_g: String,
    ln1_b: String,
    wqkv: String,
    wo: String,
    ln2_g: String,
    ln2_b: String,
    w1: String,
    w2: String,
}

impl LayerNames {
    fn new(i: usize) -> LayerNames {
        LayerNames {
            ln1_g: format!("l{i}.ln1.g"),
            ln1_b: format!("l{i}.ln1.b"),
            wqkv: format!("l{i}.attn.wqkv"),
            wo: format!("l{i}.attn.wo"),
            ln2_g: format!("l{i}.ln2.g"),
            ln2_b: format!("l{i}.ln2.b"),
            w1: format!("l{i}.mlp.w1"),
            w2: format!("l{i}.mlp.w2"),
        }
    }
}

/// Embed one token at `pos` into a `(1, d)` tensor.
fn embed_token(cfg: &ModelConfig, w: &Weights, token: u32, pos: usize) -> anyhow::Result<Tensor> {
    anyhow::ensure!((token as usize) < cfg.vocab, "token {token} out of vocab");
    anyhow::ensure!(pos < cfg.max_t, "position {pos} >= max_t {}", cfg.max_t);
    let embed = w.get("embed")?;
    let ppos = w.get("pos")?;
    let e = embed.row(token as usize);
    let p = ppos.row(pos);
    let mut x = Tensor::zeros(&[1, cfg.d]);
    for (o, (&a, &b)) in x.data.iter_mut().zip(e.iter().zip(p)) {
        *o = a + b;
    }
    Ok(x)
}

/// Fill `slot` with a prompt: runs the **reference transformer stack
/// itself** (`forward_hidden_with`, batch = 1) with a per-layer K/V sink
/// that appends every position's K/V rows to the cache as each layer's
/// QKV projection completes — attention runs over the exact in-flight
/// values, decode steps are what read the cache back (quantized, in
/// encoded mode). Because the layer code is shared rather than
/// mirrored, prefill cannot drift numerically from the full forward.
/// Returns the **last position's** logits (`vocab` floats) — the only
/// row the decode loop samples. Requires an empty slot (chunked prefill
/// is future work).
pub fn prefill(
    cfg: &ModelConfig,
    w: &Weights,
    cache: &mut PagedKvCache,
    slot: SlotId,
    tokens: &[u32],
    act_q: ActQuant,
) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(!tokens.is_empty(), "empty prompt");
    anyhow::ensure!(cache.seq_len(slot) == 0, "prefill into a non-empty slot");
    let lay = cache.layout();
    anyhow::ensure!(
        lay.n_layers == cfg.n_layers && lay.n_heads == cfg.n_heads && lay.head_dim == cfg.head_dim(),
        "cache layout does not match model config"
    );
    anyhow::ensure!(tokens.len() <= lay.max_tokens, "prompt {} > cache capacity {}", tokens.len(), lay.max_tokens);
    let (t, d) = (tokens.len(), cfg.d);

    let mut sink = |layer: usize, qkv: &Tensor| -> anyhow::Result<()> {
        for r in 0..t {
            let row = qkv.row(r);
            cache.append(slot, layer, &row[d..2 * d], &row[2 * d..3 * d])?;
        }
        Ok(())
    };
    let x = crate::model::forward::forward_hidden_with(cfg, w, tokens, 1, act_q, &mut sink)?;

    // Frontier-only LM head: one (1, d) row against the cached panel.
    let last = Tensor::new(&[1, d], x.row(t - 1).to_vec());
    let head = w.packed_transposed("embed")?;
    Ok(crate::kernels::gemm_packed(&last, &head).data)
}

/// Decode one token against the cached history: appends its K/V per
/// layer, attends over the cache (O(len) per head), and returns the new
/// position's logits (`vocab` floats). Attention reductions follow the
/// blocked kernel's accumulation order, so with an f32 cache the result
/// is bit-exact with the corresponding row of the full forward.
pub fn decode_step(
    cfg: &ModelConfig,
    w: &Weights,
    cache: &mut PagedKvCache,
    slot: SlotId,
    token: u32,
    act_q: ActQuant,
    scratch: &mut DecodeScratch,
) -> anyhow::Result<Vec<f32>> {
    let pos = cache.seq_len(slot);
    anyhow::ensure!(pos > 0, "decode_step before prefill");
    anyhow::ensure!(pos < cache.layout().max_tokens, "cache slot full ({pos} tokens)");
    let (d, hd) = (cfg.d, cfg.head_dim());
    let scale = 1.0 / (hd as f32).sqrt();
    let mut x = embed_token(cfg, w, token, pos)?;

    scratch.ctx.resize(hd, 0.0);
    scratch.acc.resize(hd, 0.0);
    if scratch.names.len() != cfg.n_layers {
        scratch.names = (0..cfg.n_layers).map(LayerNames::new).collect();
    }
    for i in 0..cfg.n_layers {
        let names = &scratch.names[i];
        let mut h = x.clone();
        layer_norm(&mut h, w.get(&names.ln1_g)?, w.get(&names.ln1_b)?, 1e-5);
        let qkv = qmatmul(&h, w, &names.wqkv, act_q)?; // (1, 3D)
        let row = qkv.row(0);
        let n = cache.append(slot, i, &row[d..2 * d], &row[2 * d..3 * d])?;
        let mut attn_out = Tensor::zeros(&[1, d]);
        for head in 0..cfg.n_heads {
            let off = head * hd;
            let q = &row[off..off + hd];
            cache.gather(slot, i, head, Plane::K, &mut scratch.k);
            cache.gather(slot, i, head, Plane::V, &mut scratch.v);
            // scores[j] = (q · K[j]) * scale — reduction over head_dim,
            // ascending, one KC block (head_dim < KC always here).
            scratch.scores.resize(n, 0.0);
            for (j, s) in scratch.scores.iter_mut().enumerate() {
                let krow = &scratch.k[j * hd..(j + 1) * hd];
                let mut acc = 0.0f32;
                for (a, b) in q.iter().zip(krow) {
                    acc += a * b;
                }
                *s = acc * scale;
            }
            softmax_rows(&mut scratch.scores, n);
            // ctx = p · V, reduced over tokens in KC-sized chunks with a
            // fresh accumulator per chunk — the blocked driver's order.
            scratch.ctx.fill(0.0);
            let mut j0 = 0usize;
            while j0 < n {
                let jc = KC.min(n - j0);
                scratch.acc.fill(0.0);
                for j in j0..j0 + jc {
                    let p = scratch.scores[j];
                    let vrow = &scratch.v[j * hd..(j + 1) * hd];
                    for (a, &b) in scratch.acc.iter_mut().zip(vrow) {
                        *a += p * b;
                    }
                }
                for (c, &a) in scratch.ctx.iter_mut().zip(scratch.acc.iter()) {
                    *c += a;
                }
                j0 += jc;
            }
            attn_out.data[off..off + hd].copy_from_slice(&scratch.ctx);
        }
        let proj = qmatmul(&attn_out, w, &names.wo, act_q)?;
        for (xv, pv) in x.data.iter_mut().zip(&proj.data) {
            *xv += pv;
        }

        let mut h = x.clone();
        layer_norm(&mut h, w.get(&names.ln2_g)?, w.get(&names.ln2_b)?, 1e-5);
        let mut ff = qmatmul(&h, w, &names.w1, act_q)?;
        gelu(&mut ff.data);
        let down = qmatmul(&ff, w, &names.w2, act_q)?;
        for (xv, dv) in x.data.iter_mut().zip(&down.data) {
            *xv += dv;
        }
    }

    layer_norm(&mut x, w.get("lnf.g")?, w.get("lnf.b")?, 1e-5);
    let head = w.packed_transposed("embed")?;
    Ok(crate::kernels::gemm_packed(&x, &head).data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{KvLayout, KvQuantizer, KvStore};
    use crate::model::forward::forward;
    use crate::model::forward::tests_support::{random_weights, tiny_cfg};

    fn f32_cache(cfg: &ModelConfig, slots: usize) -> PagedKvCache {
        PagedKvCache::new(KvLayout::for_model(cfg, 4, slots), KvStore::F32).unwrap()
    }

    #[test]
    fn prefill_plus_decode_matches_full_forward_bitwise() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 41);
        let tokens: Vec<u32> = (0..12).map(|i| (i * 7 % 40) as u32).collect();
        let full = forward(&cfg, &w, &tokens, 1, None).unwrap();
        for split in [1usize, 5, 11] {
            let mut cache = f32_cache(&cfg, 1);
            let slot = cache.alloc_slot().unwrap();
            let mut scratch = DecodeScratch::new();
            let mut got = vec![prefill(&cfg, &w, &mut cache, slot, &tokens[..split], None).unwrap()];
            for &tok in &tokens[split..] {
                got.push(decode_step(&cfg, &w, &mut cache, slot, tok, None, &mut scratch).unwrap());
            }
            // got[0] is logits at position split-1; got[k] at split-1+k.
            for (k, logits) in got.iter().enumerate() {
                let pos = split - 1 + k;
                for (c, &g) in logits.iter().enumerate() {
                    let want = full.at(pos, c);
                    assert_eq!(
                        g.to_bits(),
                        want.to_bits(),
                        "split {split} pos {pos} col {c}: {g} vs {want}"
                    );
                }
            }
            assert_eq!(cache.seq_len(slot), tokens.len());
        }
    }

    #[test]
    fn encoded_cache_decodes_finitely_and_differs_from_f32() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 42);
        let hd = cfg.head_dim();
        let sample: Vec<f32> = w.get("l0.attn.wqkv").unwrap().data.clone();
        let quant = KvQuantizer::calibrated(hd, &sample[..hd * 64], 17).unwrap();
        let mut enc_cache =
            PagedKvCache::new(KvLayout::for_model(&cfg, 4, 1), KvStore::Encoded(quant)).unwrap();
        let mut f32_cache = f32_cache(&cfg, 1);
        let se = enc_cache.alloc_slot().unwrap();
        let sf = f32_cache.alloc_slot().unwrap();
        let tokens: Vec<u32> = (0..6).map(|i| (i * 3 % 40) as u32).collect();
        let mut scratch = DecodeScratch::new();
        prefill(&cfg, &w, &mut enc_cache, se, &tokens[..2], None).unwrap();
        prefill(&cfg, &w, &mut f32_cache, sf, &tokens[..2], None).unwrap();
        let mut diff = 0.0f32;
        for &tok in &tokens[2..] {
            let a = decode_step(&cfg, &w, &mut enc_cache, se, tok, None, &mut scratch).unwrap();
            let b = decode_step(&cfg, &w, &mut f32_cache, sf, tok, None, &mut scratch).unwrap();
            assert!(a.iter().all(|x| x.is_finite()), "encoded-cache logits not finite");
            diff += a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum::<f32>();
        }
        assert!(diff > 0.0, "KV4 cache had no effect at all");
        assert!(enc_cache.state_bytes() < f32_cache.state_bytes(), "encoded cache not smaller");
    }

    #[test]
    fn decode_rejects_misuse() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 43);
        let mut cache = f32_cache(&cfg, 1);
        let slot = cache.alloc_slot().unwrap();
        let mut scratch = DecodeScratch::new();
        // decode before prefill, bad token, over-capacity prompt
        assert!(decode_step(&cfg, &w, &mut cache, slot, 0, None, &mut scratch).is_err());
        assert!(prefill(&cfg, &w, &mut cache, slot, &[999], None).is_err());
        assert!(prefill(&cfg, &w, &mut cache, slot, &vec![0; cfg.max_t + 1], None).is_err());
        prefill(&cfg, &w, &mut cache, slot, &[1, 2], None).unwrap();
        assert!(prefill(&cfg, &w, &mut cache, slot, &[1], None).is_err(), "re-prefill of a live slot");
    }
}
