//! Incremental forward: `prefill_from` fills the KV cache for the
//! uncached part of a prompt (all of it when cold; only the suffix
//! after a prefix-cache hit), `decode_step` runs **one token** against
//! the cached history, and `decode_step_batch` runs **one fused forward
//! for every live lane** of a scheduler step — O(len) attention work
//! per token instead of the full forward's O(t²) re-score, and only the
//! frontier rows of logits are ever materialized.
//!
//! Numerics: with an f32 (KV16) cache the pair (prefill, decode_step)
//! reproduces [`forward`](super::forward::forward) — every sub-step is
//! row-independent in the reference forward (layer norm, GELU, per-row
//! GEMM accumulation, causal softmax whose masked tail contributes exact
//! `+0.0`), and the attention reductions here mirror the blocked
//! kernel's accumulation order (scores reduce over `head_dim < KC` in
//! one block; context reduces over tokens in the same `KC`-sized chunks
//! `kernels::gemm` uses). The decode-parity suite pins this. With a
//! BCQ-encoded (KV4) cache **all** attention — prefill included — reads
//! the quantized history back from the cache, so the K/V at a position
//! depends only on the token prefix, never on where the prefill/decode
//! boundary fell: the invariant that lets the prefix cache share pages
//! across requests bit-exactly (see `prefill_from`), and the KV4-vs-KV16
//! ablation in EXPERIMENTS.md.
//!
//! Batching (DESIGN.md §Batched decode): `decode_step_batch` stacks the
//! per-lane frontier tokens into a `(lanes, d)` activation matrix and
//! runs each projection / FFN / LM-head GEMM **once per step** with
//! `M = lanes`, so the packed (or LO-BCQ-encoded) B panel is streamed
//! once per step instead of once per lane — the weight-traffic
//! amortization that makes W4A4 decode throughput scale with batch
//! size. Only attention splits per lane, against each lane's own paged
//! KV history at its own (ragged) position. Activations are quantized
//! **per lane row**, and GEMM rows accumulate independently in the
//! blocked kernel, so one batched step is **bit-identical** to running
//! `decode_step` once per lane — a lane's numerics never depend on
//! which other lanes are co-scheduled (`tests/decode_parity.rs`).

use crate::kernels::{self, KC};
use crate::kvcache::{PagedKvCache, SlotId};
use crate::model::config::ModelConfig;
use crate::model::forward::{gelu, layer_norm_flat, qmatmul_rows_into, softmax_rows, ActQuant};
use crate::model::weights::Weights;

/// Reusable state for [`decode_step`] / [`decode_step_batch`]: every
/// per-token temporary of the decode hot loop — the stacked activation
/// matrices (residual stream, layer-norm copy, QKV, attention output,
/// projection, FFN hidden, logits), the activation-quantization staging
/// buffer, the GEMM panel scratch (the encoded path's LUT-decode
/// target), the gathered K/V history with score/context accumulators,
/// per-lane positions, and the pre-rendered per-layer weight names
/// (decode runs per token, so the `format!` allocations are hoisted out
/// of the hot loop). A session that keeps one across steps performs
/// **no steady-state allocations** once the buffers reach the working
/// size — [`footprint`](Self::footprint) exposes the total capacity so
/// the zero-alloc property test can pin that.
#[derive(Debug, Default)]
pub struct DecodeScratch {
    /// Residual stream, `(lanes, d)`.
    x: Vec<f32>,
    /// Layer-norm input copy, `(lanes, d)`.
    h: Vec<f32>,
    /// QKV projection output, `(lanes, 3d)`.
    qkv: Vec<f32>,
    /// Attention output, `(lanes, d)`.
    attn: Vec<f32>,
    /// Projection / FFN-down output, `(lanes, d)`.
    proj: Vec<f32>,
    /// FFN hidden, `(lanes, d_ff)`.
    ff: Vec<f32>,
    /// Frontier logits, `(lanes, vocab)`.
    logits: Vec<f32>,
    /// Per-row activation-quantization staging.
    aq: Vec<f32>,
    /// Kernel panel scratch (`KC × NR`; the encoded path's LUT target).
    panel: Vec<f32>,
    /// Gathered K/V history for one (lane, head).
    k: Vec<f32>,
    v: Vec<f32>,
    scores: Vec<f32>,
    ctx: Vec<f32>,
    acc: Vec<f32>,
    /// Per-lane cache positions for the current step.
    pos: Vec<usize>,
    names: Vec<LayerNames>,
}

impl DecodeScratch {
    pub fn new() -> DecodeScratch {
        DecodeScratch::default()
    }

    /// Total f32/usize capacity (in elements) held across every scratch
    /// buffer. Constant across steps once the working set is reached —
    /// any hidden steady-state allocation in the decode loop would grow
    /// it, which the zero-alloc property test asserts never happens.
    pub fn footprint(&self) -> usize {
        self.x.capacity()
            + self.h.capacity()
            + self.qkv.capacity()
            + self.attn.capacity()
            + self.proj.capacity()
            + self.ff.capacity()
            + self.logits.capacity()
            + self.aq.capacity()
            + self.panel.capacity()
            + self.k.capacity()
            + self.v.capacity()
            + self.scores.capacity()
            + self.ctx.capacity()
            + self.acc.capacity()
            + self.pos.capacity()
    }

    fn ensure_names(&mut self, n_layers: usize) {
        if self.names.len() != n_layers {
            self.names = (0..n_layers).map(LayerNames::new).collect();
        }
    }

    /// Pin the length-proportional attention buffers (gathered K/V,
    /// score row) at the cache's per-slot token capacity once, so the
    /// decode loop never reallocates them at **any** sequence length —
    /// the zero-steady-state-allocation property holds by construction
    /// instead of by amortized-doubling luck. Gathers only ever resize
    /// within this capacity afterwards.
    fn pin_attention_capacity(&mut self, max_tokens: usize, head_dim: usize) {
        if self.k.capacity() < max_tokens * head_dim {
            self.k.resize(max_tokens * head_dim, 0.0);
            self.v.resize(max_tokens * head_dim, 0.0);
            self.scores.resize(max_tokens, 0.0);
        }
    }
}

/// One layer's weight-map keys, rendered once.
#[derive(Debug)]
struct LayerNames {
    ln1_g: String,
    ln1_b: String,
    wqkv: String,
    wo: String,
    ln2_g: String,
    ln2_b: String,
    w1: String,
    w2: String,
}

impl LayerNames {
    fn new(i: usize) -> LayerNames {
        LayerNames {
            ln1_g: format!("l{i}.ln1.g"),
            ln1_b: format!("l{i}.ln1.b"),
            wqkv: format!("l{i}.attn.wqkv"),
            wo: format!("l{i}.attn.wo"),
            ln2_g: format!("l{i}.ln2.g"),
            ln2_b: format!("l{i}.ln2.b"),
            w1: format!("l{i}.mlp.w1"),
            w2: format!("l{i}.mlp.w2"),
        }
    }
}

/// Fill `slot` with a whole prompt — [`prefill_from`] at offset 0 with
/// a scratch of its own. Kept as the convenience entry point for tests
/// and benches; the serving session calls [`prefill_from`] directly so
/// prefix-cache hits skip the cached tokens and the session's scratch
/// is reused across requests.
pub fn prefill(
    cfg: &ModelConfig,
    w: &Weights,
    cache: &mut PagedKvCache,
    slot: SlotId,
    tokens: &[u32],
    act_q: ActQuant,
) -> anyhow::Result<Vec<f32>> {
    let mut scratch = DecodeScratch::new();
    prefill_from(cfg, w, cache, slot, tokens, 0, act_q, &mut scratch)
}

/// Prefill `slot` with the **uncached suffix** of a prompt: the cache
/// already holds `offset` tokens (0 for a cold prompt; the adopted
/// prefix length on a prefix-cache hit), and this computes positions
/// `offset..tokens.len()` only — the saved prefill work is exactly what
/// the prefix cache exists to harvest. Returns the **last position's**
/// logits (`vocab` floats), the only row the decode loop samples.
///
/// Numerics: the suffix runs as one `(m, d)` stacked forward — each
/// projection/FFN GEMM once over all suffix rows — and attention is
/// computed **against the cache** (per row, over the gathered history at
/// that row's position), in the same accumulation order `decode_step`
/// uses. Consequences, both load-bearing:
///
/// - With an f32 cache the gathered history equals the in-flight values,
///   so prefill reproduces the full forward bit for bit (pinned by the
///   decode-parity suite).
/// - With a BCQ (KV4) cache, attention reads the **quantized** history —
///   the same values any later decode step would read. The K/V appended
///   at position `p` is therefore a deterministic function of
///   `tokens[..=p]` and the weights alone, independent of where the
///   prefill/decode boundary fell or which pages were adopted — which is
///   what makes a warm (adopted-prefix) prefill bit-identical to a cold
///   one (`tests/prefix_parity.rs`) and cached pages safe to share
///   across requests.
///
/// Known tradeoff: the per-row score/context reductions here are the
/// scalar decode-mirror of the blocked kernel, not the packed-GEMM
/// attention the old full-prompt prefill ran — bit-identical by the
/// kernel's KC-accumulation contract, but without its SIMD constants,
/// so a cold prefill's O(t²·hd) attention runs slower than the PR2
/// kernels could make it. Routing the gathered history through
/// `PackedB` panels (plus a causal mask) would keep the same bits and
/// recover that speed; it is left as follow-up rather than risked
/// here.
#[allow(clippy::too_many_arguments)]
pub fn prefill_from(
    cfg: &ModelConfig,
    w: &Weights,
    cache: &mut PagedKvCache,
    slot: SlotId,
    tokens: &[u32],
    offset: usize,
    act_q: ActQuant,
    scratch: &mut DecodeScratch,
) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(!tokens.is_empty(), "empty prompt");
    anyhow::ensure!(offset < tokens.len(), "prefill offset {offset} >= prompt length {}", tokens.len());
    let lay = cache.layout();
    anyhow::ensure!(
        lay.n_layers == cfg.n_layers && lay.n_heads == cfg.n_heads && lay.head_dim == cfg.head_dim(),
        "cache layout does not match model config"
    );
    anyhow::ensure!(tokens.len() <= lay.max_tokens, "prompt {} > cache capacity {}", tokens.len(), lay.max_tokens);
    anyhow::ensure!(tokens.len() <= cfg.max_t, "prompt {} > max_t {}", tokens.len(), cfg.max_t);
    let max_tokens = lay.max_tokens;
    anyhow::ensure!(
        cache.seq_len(slot) == offset,
        "cache holds {} tokens for slot {slot}, prefill expects {offset}",
        cache.seq_len(slot)
    );
    for &tok in &tokens[offset..] {
        anyhow::ensure!((tok as usize) < cfg.vocab, "token {tok} out of vocab");
    }
    let (d, hd) = (cfg.d, cfg.head_dim());
    let m = tokens.len() - offset;
    let scale = 1.0 / (hd as f32).sqrt();
    scratch.pin_attention_capacity(max_tokens, hd);

    // ---- embed the suffix: x[r] = embed[tok_{offset+r}] + pos[offset+r] ----
    let embed = w.get("embed")?;
    let ppos = w.get("pos")?;
    scratch.x.resize(m * d, 0.0);
    for r in 0..m {
        let (e, p) = (embed.row(tokens[offset + r] as usize), ppos.row(offset + r));
        for (o, (&a, &b)) in scratch.x[r * d..(r + 1) * d].iter_mut().zip(e.iter().zip(p)) {
            *o = a + b;
        }
    }

    scratch.ctx.resize(hd, 0.0);
    scratch.acc.resize(hd, 0.0);
    scratch.ensure_names(cfg.n_layers);
    for li in 0..cfg.n_layers {
        let names = &scratch.names[li];
        // --- attention block: one fused QKV GEMM over the suffix, then
        // append every row's K/V before attending, so one gather per
        // head serves all suffix rows (row r reads its causal prefix of
        // the gathered history) ---
        scratch.h.clear();
        scratch.h.extend_from_slice(&scratch.x);
        layer_norm_flat(&mut scratch.h, d, w.get(&names.ln1_g)?, w.get(&names.ln1_b)?, 1e-5);
        qmatmul_rows_into(w, &names.wqkv, &scratch.h, m, d, act_q, &mut scratch.qkv, &mut scratch.aq, &mut scratch.panel)?; // (m, 3D)
        for r in 0..m {
            let row = &scratch.qkv[r * 3 * d..(r + 1) * 3 * d];
            cache.append(slot, li, &row[d..2 * d], &row[2 * d..3 * d])?;
        }
        scratch.attn.resize(m * d, 0.0);
        for head in 0..cfg.n_heads {
            let off = head * hd;
            let len = cache.gather_kv(slot, li, head, &mut scratch.k, &mut scratch.v);
            debug_assert_eq!(len, offset + m);
            for r in 0..m {
                let n = offset + r + 1; // this row's causal span
                let qbase = r * 3 * d;
                scratch.scores.resize(n, 0.0);
                for (j, s) in scratch.scores.iter_mut().enumerate() {
                    let q = &scratch.qkv[qbase + off..qbase + off + hd];
                    let krow = &scratch.k[j * hd..(j + 1) * hd];
                    let mut acc = 0.0f32;
                    for (a, b) in q.iter().zip(krow) {
                        acc += a * b;
                    }
                    *s = acc * scale;
                }
                softmax_rows(&mut scratch.scores, n);
                scratch.ctx.fill(0.0);
                let mut j0 = 0usize;
                while j0 < n {
                    let jc = KC.min(n - j0);
                    scratch.acc.fill(0.0);
                    for j in j0..j0 + jc {
                        let pj = scratch.scores[j];
                        let vrow = &scratch.v[j * hd..(j + 1) * hd];
                        for (a, &b) in scratch.acc.iter_mut().zip(vrow) {
                            *a += pj * b;
                        }
                    }
                    for (c, &a) in scratch.ctx.iter_mut().zip(scratch.acc.iter()) {
                        *c += a;
                    }
                    j0 += jc;
                }
                scratch.attn[r * d + off..r * d + off + hd].copy_from_slice(&scratch.ctx);
            }
        }
        qmatmul_rows_into(w, &names.wo, &scratch.attn, m, d, act_q, &mut scratch.proj, &mut scratch.aq, &mut scratch.panel)?;
        for (xv, pv) in scratch.x.iter_mut().zip(&scratch.proj) {
            *xv += pv;
        }

        // --- MLP block: two fused GEMMs over the suffix ---
        scratch.h.clear();
        scratch.h.extend_from_slice(&scratch.x);
        layer_norm_flat(&mut scratch.h, d, w.get(&names.ln2_g)?, w.get(&names.ln2_b)?, 1e-5);
        let d_ff = qmatmul_rows_into(w, &names.w1, &scratch.h, m, d, act_q, &mut scratch.ff, &mut scratch.aq, &mut scratch.panel)?;
        gelu(&mut scratch.ff);
        qmatmul_rows_into(w, &names.w2, &scratch.ff, m, d_ff, act_q, &mut scratch.proj, &mut scratch.aq, &mut scratch.panel)?;
        for (xv, dv) in scratch.x.iter_mut().zip(&scratch.proj) {
            *xv += dv;
        }
    }

    // Frontier-only LM head: layer-norm is row-independent, so norm the
    // whole suffix (cheap) but run the vocab GEMM on the last row only.
    layer_norm_flat(&mut scratch.x, d, w.get("lnf.g")?, w.get("lnf.b")?, 1e-5);
    let head = w.packed_transposed("embed")?;
    scratch.logits.resize(cfg.vocab, 0.0);
    kernels::gemm_into_flat_with(&scratch.x[(m - 1) * d..m * d], 1, d, &*head, &mut scratch.logits, &mut scratch.panel);
    Ok(scratch.logits[..cfg.vocab].to_vec())
}

/// Per-lane admission check for a decode step, shared by
/// [`decode_step_batch`] (whole-call validation) and the engine layer's
/// per-lane screening (`DecodeSession::decode_batch`) — **one source of
/// truth**, so the screen can never drift from what the fused step
/// enforces and let a bad lane poison its step-mates. Returns the
/// lane's current cache position.
pub fn validate_decode_lane(
    cfg: &ModelConfig,
    cache: &PagedKvCache,
    slots: &[SlotId],
    i: usize,
    token: u32,
) -> anyhow::Result<usize> {
    let slot = slots[i];
    anyhow::ensure!(cache.is_live(slot), "decode on dead slot {slot}");
    anyhow::ensure!(!slots[..i].contains(&slot), "slot {slot} appears twice in one batched step");
    let pos = cache.seq_len(slot);
    anyhow::ensure!(pos > 0, "decode_step before prefill (slot {slot})");
    anyhow::ensure!(pos < cache.layout().max_tokens, "cache slot {slot} full ({pos} tokens)");
    anyhow::ensure!(pos < cfg.max_t, "position {pos} >= max_t {} (slot {slot})", cfg.max_t);
    anyhow::ensure!((token as usize) < cfg.vocab, "token {token} out of vocab");
    Ok(pos)
}

/// Decode one token against the cached history: appends its K/V per
/// layer, attends over the cache (O(len) per head), and returns the new
/// position's logits (`vocab` floats). Attention reductions follow the
/// blocked kernel's accumulation order, so with an f32 cache the result
/// is bit-exact with the corresponding row of the full forward.
///
/// This is the single-lane **reference** the batched step is verified
/// against — it shares the scratch buffers and row-level helpers but
/// keeps the straightforward one-lane control flow.
pub fn decode_step(
    cfg: &ModelConfig,
    w: &Weights,
    cache: &mut PagedKvCache,
    slot: SlotId,
    token: u32,
    act_q: ActQuant,
    scratch: &mut DecodeScratch,
) -> anyhow::Result<Vec<f32>> {
    let pos = validate_decode_lane(cfg, cache, &[slot], 0, token)?;
    let (d, hd) = (cfg.d, cfg.head_dim());
    let scale = 1.0 / (hd as f32).sqrt();
    scratch.pin_attention_capacity(cache.layout().max_tokens, hd);

    // Embed the frontier token at its position.
    let embed = w.get("embed")?;
    let ppos = w.get("pos")?;
    scratch.x.resize(d, 0.0);
    let (e, p) = (embed.row(token as usize), ppos.row(pos));
    for (o, (&a, &b)) in scratch.x.iter_mut().zip(e.iter().zip(p)) {
        *o = a + b;
    }

    scratch.ctx.resize(hd, 0.0);
    scratch.acc.resize(hd, 0.0);
    scratch.ensure_names(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let names = &scratch.names[i];
        // --- attention block ---
        scratch.h.clear();
        scratch.h.extend_from_slice(&scratch.x);
        layer_norm_flat(&mut scratch.h, d, w.get(&names.ln1_g)?, w.get(&names.ln1_b)?, 1e-5);
        qmatmul_rows_into(w, &names.wqkv, &scratch.h, 1, d, act_q, &mut scratch.qkv, &mut scratch.aq, &mut scratch.panel)?; // (1, 3D)
        let n = cache.append(slot, i, &scratch.qkv[d..2 * d], &scratch.qkv[2 * d..3 * d])?;
        scratch.attn.resize(d, 0.0);
        for head in 0..cfg.n_heads {
            let off = head * hd;
            cache.gather_kv(slot, i, head, &mut scratch.k, &mut scratch.v);
            // scores[j] = (q · K[j]) * scale — reduction over head_dim,
            // ascending, one KC block (head_dim < KC always here).
            scratch.scores.resize(n, 0.0);
            for (j, s) in scratch.scores.iter_mut().enumerate() {
                let q = &scratch.qkv[off..off + hd];
                let krow = &scratch.k[j * hd..(j + 1) * hd];
                let mut acc = 0.0f32;
                for (a, b) in q.iter().zip(krow) {
                    acc += a * b;
                }
                *s = acc * scale;
            }
            softmax_rows(&mut scratch.scores, n);
            // ctx = p · V, reduced over tokens in KC-sized chunks with a
            // fresh accumulator per chunk — the blocked driver's order.
            scratch.ctx.fill(0.0);
            let mut j0 = 0usize;
            while j0 < n {
                let jc = KC.min(n - j0);
                scratch.acc.fill(0.0);
                for j in j0..j0 + jc {
                    let pj = scratch.scores[j];
                    let vrow = &scratch.v[j * hd..(j + 1) * hd];
                    for (a, &b) in scratch.acc.iter_mut().zip(vrow) {
                        *a += pj * b;
                    }
                }
                for (c, &a) in scratch.ctx.iter_mut().zip(scratch.acc.iter()) {
                    *c += a;
                }
                j0 += jc;
            }
            scratch.attn[off..off + hd].copy_from_slice(&scratch.ctx);
        }
        qmatmul_rows_into(w, &names.wo, &scratch.attn, 1, d, act_q, &mut scratch.proj, &mut scratch.aq, &mut scratch.panel)?;
        for (xv, pv) in scratch.x.iter_mut().zip(&scratch.proj) {
            *xv += pv;
        }

        // --- MLP block ---
        scratch.h.clear();
        scratch.h.extend_from_slice(&scratch.x);
        layer_norm_flat(&mut scratch.h, d, w.get(&names.ln2_g)?, w.get(&names.ln2_b)?, 1e-5);
        let d_ff = qmatmul_rows_into(w, &names.w1, &scratch.h, 1, d, act_q, &mut scratch.ff, &mut scratch.aq, &mut scratch.panel)?;
        gelu(&mut scratch.ff);
        qmatmul_rows_into(w, &names.w2, &scratch.ff, 1, d_ff, act_q, &mut scratch.proj, &mut scratch.aq, &mut scratch.panel)?;
        for (xv, dv) in scratch.x.iter_mut().zip(&scratch.proj) {
            *xv += dv;
        }
    }

    layer_norm_flat(&mut scratch.x, d, w.get("lnf.g")?, w.get("lnf.b")?, 1e-5);
    let head = w.packed_transposed("embed")?;
    scratch.logits.resize(cfg.vocab, 0.0);
    kernels::gemm_into_flat_with(&scratch.x, 1, d, &*head, &mut scratch.logits, &mut scratch.panel);
    Ok(scratch.logits.clone())
}

/// One **fused decode step across every listed lane**: stacks the
/// frontier tokens into a `(lanes, d)` activation matrix, runs each
/// projection / FFN / LM-head GEMM once with `M = lanes` (the packed or
/// encoded weight panel is streamed **once per step**, not once per
/// lane), and splits per lane only for attention against each lane's
/// paged KV history at its own ragged position. Appends one K/V row per
/// lane per layer through the cache's multi-slot
/// [`append_batch`](crate::kvcache::PagedKvCache::append_batch).
///
/// Returns the stacked `(lanes, vocab)` frontier logits, row `i` for
/// `slots[i]`, borrowed from `scratch` (zero-copy; callers that need
/// owned per-lane vectors split it). **Bit-identical** to calling
/// [`decode_step`] once per lane in any order: activations are
/// quantized per row, GEMM rows accumulate independently, and each
/// lane's attention reads only its own slot.
///
/// Validates every lane **before** touching the cache, so a bad lane
/// (dead slot, full slot, out-of-vocab token, duplicate) fails the call
/// with the cache unmodified — the engine layer uses that to fail one
/// request without poisoning its batch.
pub fn decode_step_batch<'s>(
    cfg: &ModelConfig,
    w: &Weights,
    cache: &mut PagedKvCache,
    slots: &[SlotId],
    tokens: &[u32],
    act_q: ActQuant,
    scratch: &'s mut DecodeScratch,
) -> anyhow::Result<&'s [f32]> {
    let lanes = slots.len();
    anyhow::ensure!(lanes >= 1, "decode_step_batch with no lanes");
    anyhow::ensure!(tokens.len() == lanes, "{} tokens for {lanes} lanes", tokens.len());
    let (d, hd) = (cfg.d, cfg.head_dim());
    let scale = 1.0 / (hd as f32).sqrt();

    // ---- validate everything up front (shared per-lane check); no
    // cache mutation on failure ----
    scratch.pos.clear();
    for (i, &tok) in tokens.iter().enumerate() {
        let pos = validate_decode_lane(cfg, cache, slots, i, tok)?;
        scratch.pos.push(pos);
    }
    scratch.pin_attention_capacity(cache.layout().max_tokens, hd);

    // ---- embed all frontier tokens: x[i] = embed[tok_i] + pos[p_i] ----
    let embed = w.get("embed")?;
    let ppos = w.get("pos")?;
    scratch.x.resize(lanes * d, 0.0);
    for i in 0..lanes {
        let (e, p) = (embed.row(tokens[i] as usize), ppos.row(scratch.pos[i]));
        for (o, (&a, &b)) in scratch.x[i * d..(i + 1) * d].iter_mut().zip(e.iter().zip(p)) {
            *o = a + b;
        }
    }

    scratch.ctx.resize(hd, 0.0);
    scratch.acc.resize(hd, 0.0);
    scratch.ensure_names(cfg.n_layers);
    for li in 0..cfg.n_layers {
        let names = &scratch.names[li];
        // --- attention block: one fused QKV GEMM, per-lane attention ---
        scratch.h.clear();
        scratch.h.extend_from_slice(&scratch.x);
        layer_norm_flat(&mut scratch.h, d, w.get(&names.ln1_g)?, w.get(&names.ln1_b)?, 1e-5);
        qmatmul_rows_into(w, &names.wqkv, &scratch.h, lanes, d, act_q, &mut scratch.qkv, &mut scratch.aq, &mut scratch.panel)?; // (lanes, 3D)
        cache.append_batch(slots, li, &scratch.qkv, 3 * d, d, 2 * d)?;
        scratch.attn.resize(lanes * d, 0.0);
        for i in 0..lanes {
            let n = scratch.pos[i] + 1; // this lane's attention span
            let qbase = i * 3 * d;
            for head in 0..cfg.n_heads {
                let off = head * hd;
                cache.gather_kv(slots[i], li, head, &mut scratch.k, &mut scratch.v);
                scratch.scores.resize(n, 0.0);
                for (j, s) in scratch.scores.iter_mut().enumerate() {
                    let q = &scratch.qkv[qbase + off..qbase + off + hd];
                    let krow = &scratch.k[j * hd..(j + 1) * hd];
                    let mut acc = 0.0f32;
                    for (a, b) in q.iter().zip(krow) {
                        acc += a * b;
                    }
                    *s = acc * scale;
                }
                softmax_rows(&mut scratch.scores, n);
                scratch.ctx.fill(0.0);
                let mut j0 = 0usize;
                while j0 < n {
                    let jc = KC.min(n - j0);
                    scratch.acc.fill(0.0);
                    for j in j0..j0 + jc {
                        let pj = scratch.scores[j];
                        let vrow = &scratch.v[j * hd..(j + 1) * hd];
                        for (a, &b) in scratch.acc.iter_mut().zip(vrow) {
                            *a += pj * b;
                        }
                    }
                    for (c, &a) in scratch.ctx.iter_mut().zip(scratch.acc.iter()) {
                        *c += a;
                    }
                    j0 += jc;
                }
                scratch.attn[i * d + off..i * d + off + hd].copy_from_slice(&scratch.ctx);
            }
        }
        qmatmul_rows_into(w, &names.wo, &scratch.attn, lanes, d, act_q, &mut scratch.proj, &mut scratch.aq, &mut scratch.panel)?;
        for (xv, pv) in scratch.x.iter_mut().zip(&scratch.proj) {
            *xv += pv;
        }

        // --- MLP block: two fused GEMMs over all lanes ---
        scratch.h.clear();
        scratch.h.extend_from_slice(&scratch.x);
        layer_norm_flat(&mut scratch.h, d, w.get(&names.ln2_g)?, w.get(&names.ln2_b)?, 1e-5);
        let d_ff = qmatmul_rows_into(w, &names.w1, &scratch.h, lanes, d, act_q, &mut scratch.ff, &mut scratch.aq, &mut scratch.panel)?;
        gelu(&mut scratch.ff);
        qmatmul_rows_into(w, &names.w2, &scratch.ff, lanes, d_ff, act_q, &mut scratch.proj, &mut scratch.aq, &mut scratch.panel)?;
        for (xv, dv) in scratch.x.iter_mut().zip(&scratch.proj) {
            *xv += dv;
        }
    }

    layer_norm_flat(&mut scratch.x, d, w.get("lnf.g")?, w.get("lnf.b")?, 1e-5);
    let head = w.packed_transposed("embed")?;
    scratch.logits.resize(lanes * cfg.vocab, 0.0);
    kernels::gemm_into_flat_with(&scratch.x, lanes, d, &*head, &mut scratch.logits, &mut scratch.panel);
    Ok(&scratch.logits[..lanes * cfg.vocab])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{KvLayout, KvQuantizer, KvStore};
    use crate::model::forward::forward;
    use crate::model::forward::tests_support::{random_weights, tiny_cfg};

    fn f32_cache(cfg: &ModelConfig, slots: usize) -> PagedKvCache {
        PagedKvCache::new(KvLayout::for_model(cfg, 4, slots), KvStore::F32).unwrap()
    }

    #[test]
    fn prefill_plus_decode_matches_full_forward_bitwise() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 41);
        let tokens: Vec<u32> = (0..12).map(|i| (i * 7 % 40) as u32).collect();
        let full = forward(&cfg, &w, &tokens, 1, None).unwrap();
        for split in [1usize, 5, 11] {
            let mut cache = f32_cache(&cfg, 1);
            let slot = cache.alloc_slot().unwrap();
            let mut scratch = DecodeScratch::new();
            let mut got = vec![prefill(&cfg, &w, &mut cache, slot, &tokens[..split], None).unwrap()];
            for &tok in &tokens[split..] {
                got.push(decode_step(&cfg, &w, &mut cache, slot, tok, None, &mut scratch).unwrap());
            }
            // got[0] is logits at position split-1; got[k] at split-1+k.
            for (k, logits) in got.iter().enumerate() {
                let pos = split - 1 + k;
                for (c, &g) in logits.iter().enumerate() {
                    let want = full.at(pos, c);
                    assert_eq!(
                        g.to_bits(),
                        want.to_bits(),
                        "split {split} pos {pos} col {c}: {g} vs {want}"
                    );
                }
            }
            assert_eq!(cache.seq_len(slot), tokens.len());
        }
    }

    #[test]
    fn suffix_prefill_matches_whole_prompt_prefill_bitwise() {
        // prefill(tokens[..k]) then prefill_from(tokens, k) must equal
        // prefill(tokens) to the bit — the property a prefix-cache warm
        // hit relies on (the adopted prefix plays the role of the first
        // chunk). Checked on f32 and BCQ-encoded KV stores.
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 46);
        let tokens: Vec<u32> = (0..12).map(|i| (i * 5 % 40) as u32).collect();
        let hd = cfg.head_dim();
        let sample: Vec<f32> = w.get("l0.attn.wqkv").unwrap().data.clone();
        for encoded in [false, true] {
            let mk = || {
                let store = if encoded {
                    KvStore::Encoded(KvQuantizer::calibrated(hd, &sample[..hd * 32], 9).unwrap())
                } else {
                    KvStore::F32
                };
                PagedKvCache::new(KvLayout::for_model(&cfg, 4, 1), store).unwrap()
            };
            let mut cold = mk();
            let cs = cold.alloc_slot().unwrap();
            let want = prefill(&cfg, &w, &mut cold, cs, &tokens, None).unwrap();
            for split in [1usize, 4, 6, 11] {
                let mut warm = mk();
                let ws = warm.alloc_slot().unwrap();
                let mut scratch = DecodeScratch::new();
                prefill(&cfg, &w, &mut warm, ws, &tokens[..split], None).unwrap();
                let got =
                    prefill_from(&cfg, &w, &mut warm, ws, &tokens, split, None, &mut scratch).unwrap();
                assert_eq!(warm.seq_len(ws), tokens.len());
                for (c, (&g, &x)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(g.to_bits(), x.to_bits(), "encoded={encoded} split {split} col {c}");
                }
                // Misuse: wrong offset for the cache position.
                assert!(prefill_from(&cfg, &w, &mut warm, ws, &tokens, 3, None, &mut scratch).is_err());
            }
        }
    }

    #[test]
    fn batched_step_matches_single_lane_bitwise() {
        // Twin caches: one driven per-lane by decode_step, one by the
        // fused batch step, over ragged prefill lengths. Every lane's
        // logits must agree to the bit at every step.
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 44);
        let prompts: [&[u32]; 3] = [&[1, 2, 3, 4, 5], &[7], &[9, 10, 11]];
        let mut serial = f32_cache(&cfg, 3);
        let mut batched = f32_cache(&cfg, 3);
        let mut ss = DecodeScratch::new();
        let mut sb = DecodeScratch::new();
        let mut slots_s = Vec::new();
        let mut slots_b = Vec::new();
        for p in prompts {
            let a = serial.alloc_slot().unwrap();
            let b = batched.alloc_slot().unwrap();
            prefill(&cfg, &w, &mut serial, a, p, None).unwrap();
            prefill(&cfg, &w, &mut batched, b, p, None).unwrap();
            slots_s.push(a);
            slots_b.push(b);
        }
        for step in 0..4u32 {
            let tokens: Vec<u32> = (0..3).map(|i| (step * 3 + i + 12) % 40).collect();
            let fused = decode_step_batch(&cfg, &w, &mut batched, &slots_b, &tokens, None, &mut sb)
                .unwrap()
                .to_vec();
            for (i, &slot) in slots_s.iter().enumerate() {
                let lone = decode_step(&cfg, &w, &mut serial, slot, tokens[i], None, &mut ss).unwrap();
                for (c, (&g, &want)) in fused[i * cfg.vocab..(i + 1) * cfg.vocab].iter().zip(&lone).enumerate() {
                    assert_eq!(g.to_bits(), want.to_bits(), "step {step} lane {i} col {c}");
                }
            }
        }
    }

    #[test]
    fn batched_step_rejects_misuse_without_mutating() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 45);
        let mut cache = f32_cache(&cfg, 2);
        let a = cache.alloc_slot().unwrap();
        let b = cache.alloc_slot().unwrap();
        let mut scratch = DecodeScratch::new();
        prefill(&cfg, &w, &mut cache, a, &[1, 2], None).unwrap();
        // b has no prefill; duplicate slots; token/lane count mismatch;
        // out-of-vocab token — all rejected, none advance slot a.
        assert!(decode_step_batch(&cfg, &w, &mut cache, &[a, b], &[3, 4], None, &mut scratch).is_err());
        assert!(decode_step_batch(&cfg, &w, &mut cache, &[a, a], &[3, 4], None, &mut scratch).is_err());
        assert!(decode_step_batch(&cfg, &w, &mut cache, &[a], &[3, 4], None, &mut scratch).is_err());
        assert!(decode_step_batch(&cfg, &w, &mut cache, &[a], &[999], None, &mut scratch).is_err());
        assert!(decode_step_batch(&cfg, &w, &mut cache, &[], &[], None, &mut scratch).is_err());
        assert_eq!(cache.seq_len(a), 2, "failed batched step mutated the cache");
        let ok = decode_step_batch(&cfg, &w, &mut cache, &[a], &[3], None, &mut scratch).unwrap();
        assert_eq!(ok.len(), cfg.vocab);
    }

    #[test]
    fn encoded_cache_decodes_finitely_and_differs_from_f32() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 42);
        let hd = cfg.head_dim();
        let sample: Vec<f32> = w.get("l0.attn.wqkv").unwrap().data.clone();
        let quant = KvQuantizer::calibrated(hd, &sample[..hd * 64], 17).unwrap();
        let mut enc_cache =
            PagedKvCache::new(KvLayout::for_model(&cfg, 4, 1), KvStore::Encoded(quant)).unwrap();
        let mut f32_cache = f32_cache(&cfg, 1);
        let se = enc_cache.alloc_slot().unwrap();
        let sf = f32_cache.alloc_slot().unwrap();
        let tokens: Vec<u32> = (0..6).map(|i| (i * 3 % 40) as u32).collect();
        let mut scratch = DecodeScratch::new();
        prefill(&cfg, &w, &mut enc_cache, se, &tokens[..2], None).unwrap();
        prefill(&cfg, &w, &mut f32_cache, sf, &tokens[..2], None).unwrap();
        let mut diff = 0.0f32;
        for &tok in &tokens[2..] {
            let a = decode_step(&cfg, &w, &mut enc_cache, se, tok, None, &mut scratch).unwrap();
            let b = decode_step(&cfg, &w, &mut f32_cache, sf, tok, None, &mut scratch).unwrap();
            assert!(a.iter().all(|x| x.is_finite()), "encoded-cache logits not finite");
            diff += a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum::<f32>();
        }
        assert!(diff > 0.0, "KV4 cache had no effect at all");
        assert!(enc_cache.state_bytes() < f32_cache.state_bytes(), "encoded cache not smaller");
    }

    #[test]
    fn decode_rejects_misuse() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 43);
        let mut cache = f32_cache(&cfg, 1);
        let slot = cache.alloc_slot().unwrap();
        let mut scratch = DecodeScratch::new();
        // decode before prefill, bad token, over-capacity prompt
        assert!(decode_step(&cfg, &w, &mut cache, slot, 0, None, &mut scratch).is_err());
        assert!(prefill(&cfg, &w, &mut cache, slot, &[999], None).is_err());
        assert!(prefill(&cfg, &w, &mut cache, slot, &vec![0; cfg.max_t + 1], None).is_err());
        prefill(&cfg, &w, &mut cache, slot, &[1, 2], None).unwrap();
        assert!(prefill(&cfg, &w, &mut cache, slot, &[1], None).is_err(), "re-prefill of a live slot");
    }
}
