//! CPU reference forward pass — numerically mirrors
//! `python/compile/model.py::forward` (layer norm, tanh-GELU, causal
//! attention, tied LM head).
//!
//! Role in the stack: the PJRT artifacts are the *serving* path; this
//! forward exists so the evaluation harness can sweep quantization
//! configurations (Tables 4/5/8/9/10 vary L_b/L_A/N_c/B_c across dozens
//! of settings) without lowering one HLO graph per grid point. An
//! integration test cross-checks its logits against the executed PJRT
//! artifact to ~1e-4 (`rust/tests/artifact_integration.rs`).

use crate::kernels::{self, PackedB, PanelProvider};
use crate::model::config::ModelConfig;
use crate::model::weights::{Linear, Weights};
use crate::quant::pipeline::QuantPipeline;
use crate::tensor::Tensor;

/// Activation fake-quantizer applied at every GEMM input (the in-graph
/// counterpart of the actq artifact variants). `None` = bf16 path.
///
/// The pipeline's scratch pool makes the steady-state forward
/// allocation-free on the quantization path: each GEMM input is
/// quantized into a pooled buffer that is recycled right after the
/// matmul.
pub type ActQuant<'a> = Option<&'a QuantPipeline>;

pub(crate) fn layer_norm(x: &mut Tensor, g: &Tensor, b: &Tensor, eps: f32) {
    let d = x.cols();
    layer_norm_flat(&mut x.data, d, g, b, eps);
}

/// [`layer_norm`] over a flat row-major `(rows, d)` buffer — the decode
/// loop's allocation-free entry point (same arithmetic, same order).
pub(crate) fn layer_norm_flat(x: &mut [f32], d: usize, g: &Tensor, b: &Tensor, eps: f32) {
    for row in x.chunks_exact_mut(d) {
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for (j, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * g.data[j] + b.data[j];
        }
    }
}

pub(crate) fn gelu(x: &mut [f32]) {
    for v in x.iter_mut() {
        let c = 0.797_884_56_f32;
        *v = 0.5 * *v * (1.0 + (c * (*v + 0.044715 * *v * *v * *v)).tanh());
    }
}

pub(crate) fn softmax_rows(x: &mut [f32], cols: usize) {
    for row in x.chunks_exact_mut(cols) {
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// GEMM against a named weight, with optional activation
/// fake-quantization. Resolves through `Weights::linear`: packed f32
/// panels for dense weights (pre-quantized by the caller when evaluating
/// weight quant), or the encoded-domain `qgemm` when the weight is bound
/// as LO-BCQ codes — in which case no f32 copy of the weight ever exists.
pub(crate) fn qmatmul(x: &Tensor, w: &Weights, name: &str, act_q: ActQuant) -> anyhow::Result<Tensor> {
    let lin = w.linear(name)?;
    let run = |xq: &Tensor| match &lin {
        Linear::Dense(pb) => kernels::gemm_packed(xq, pb),
        Linear::Encoded(ql) => ql.qgemm(xq),
    };
    Ok(match act_q {
        None => run(x),
        Some(pipe) => {
            let xq = Tensor::new(&x.shape, pipe.quantize_pooled(&x.data));
            if crate::obs::quant_stats::sample_act() {
                crate::obs::quant_stats::record_act(name, &x.data, &xq.data);
            }
            let out = run(&xq);
            pipe.recycle(xq.data);
            out
        }
    })
}

/// Row-batched GEMM against a named weight **into caller-owned
/// buffers** — the decode hot loop's flavour of [`qmatmul`]. `x` is a
/// stacked `(m, k)` activation (one row per live lane). Activations are
/// quantized **per row**, so each lane's numerics are bit-identical to
/// that lane quantizing its own `(1, k)` activation alone — a lane's
/// output never depends on which other lanes share the step — while the
/// GEMM itself runs **once**, streaming the packed/encoded B panel once
/// per step instead of once per lane. `out` is resized to `(m, n)`;
/// `aq` stages the quantized rows; `panel` is the kernel's panel
/// scratch. Returns `n`. Zero allocations once the buffers reach their
/// working size.
#[allow(clippy::too_many_arguments)]
pub(crate) fn qmatmul_rows_into(
    w: &Weights,
    name: &str,
    x: &[f32],
    m: usize,
    k: usize,
    act_q: ActQuant,
    out: &mut Vec<f32>,
    aq: &mut Vec<f32>,
    panel: &mut Vec<f32>,
) -> anyhow::Result<usize> {
    debug_assert_eq!(x.len(), m * k);
    let lin = w.linear(name)?;
    let n = match &lin {
        Linear::Dense(pb) => pb.n(),
        Linear::Encoded(ql) => ql.shape().1,
    };
    out.resize(m * n, 0.0);
    let src: &[f32] = match act_q {
        None => x,
        Some(pipe) => {
            aq.resize(m * k, 0.0);
            for (sr, dr) in x.chunks_exact(k).zip(aq.chunks_exact_mut(k)) {
                pipe.quantize_into(sr, dr);
                // Sampled NMSE telemetry; read-only on the numerics and
                // one relaxed load when telemetry is off.
                if crate::obs::quant_stats::sample_act() {
                    crate::obs::quant_stats::record_act(name, sr, dr);
                }
            }
            &aq[..]
        }
    };
    match &lin {
        Linear::Dense(pb) => kernels::gemm_into_flat_with(src, m, k, &**pb, out, panel),
        Linear::Encoded(ql) => ql.qgemm_into(src, m, out, panel),
    }
    Ok(n)
}

/// Forward pass: `tokens` is (B, T) with T ≤ cfg.max_t; returns logits
/// as a (B*T, vocab) tensor (row r = batch r/T, position r%T).
pub fn forward(cfg: &ModelConfig, w: &Weights, tokens: &[u32], batch: usize, act_q: ActQuant) -> anyhow::Result<Tensor> {
    let x = forward_hidden(cfg, w, tokens, batch, act_q)?;
    // Tied LM head: logits = x @ embedᵀ (unquantized, as in python). The
    // transposed panel is packed once and cached in `Weights` — no
    // per-forward re-materialization of the [d, vocab] transpose.
    let head = w.packed_transposed("embed")?;
    Ok(kernels::gemm_packed(&x, &head))
}

/// Last-position-only forward: full transformer stack, but the tied LM
/// head runs over **one row per lane** (`positions[i]` for lane `i`)
/// instead of all `B·T` rows — the decode loop samples only each
/// sequence's frontier, so materializing `batch·t·vocab` logits there is
/// pure waste (the LM-head GEMM is the largest single product in the
/// step). Returns a `(positions.len(), vocab)` tensor whose row `i` is
/// bit-exact with row `i·t + positions[i]` of [`forward`] (same hidden
/// states, same panel, same kernel — rows of a GEMM are independent).
pub fn forward_logits_at(
    cfg: &ModelConfig,
    w: &Weights,
    tokens: &[u32],
    batch: usize,
    act_q: ActQuant,
    positions: &[usize],
) -> anyhow::Result<Tensor> {
    let t = tokens.len() / batch.max(1);
    anyhow::ensure!(positions.len() <= batch, "{} positions for {batch} lanes", positions.len());
    let x = forward_hidden(cfg, w, tokens, batch, act_q)?;
    let mut picked = Tensor::zeros(&[positions.len(), cfg.d]);
    for (i, &p) in positions.iter().enumerate() {
        anyhow::ensure!(p < t, "position {p} >= sequence length {t}");
        picked.row_mut(i).copy_from_slice(x.row(i * t + p));
    }
    let head = w.packed_transposed("embed")?;
    Ok(kernels::gemm_packed(&picked, &head))
}

/// The transformer stack up to and including the final layer norm:
/// returns hidden states `(B*T, d)`. Shared by [`forward`] (full LM
/// head) and [`forward_logits_at`] (frontier-only LM head). The cached
/// serving path (`model::decode::prefill_from`) runs its own stacked
/// suffix forward that attends against the paged KV cache; the
/// decode-parity suite pins the two bit-identical on an f32 cache.
pub(crate) fn forward_hidden(cfg: &ModelConfig, w: &Weights, tokens: &[u32], batch: usize, act_q: ActQuant) -> anyhow::Result<Tensor> {
    anyhow::ensure!(batch >= 1, "batch must be >= 1");
    anyhow::ensure!(tokens.len() % batch == 0, "tokens not divisible by batch");
    let t = tokens.len() / batch;
    anyhow::ensure!(t <= cfg.max_t, "sequence {t} > max_t {}", cfg.max_t);
    let d = cfg.d;
    let embed = w.get("embed")?;
    let pos = w.get("pos")?;

    // x: (B*T, D)
    let mut x = Tensor::zeros(&[batch * t, d]);
    for (r, &tok) in tokens.iter().enumerate() {
        anyhow::ensure!((tok as usize) < cfg.vocab, "token {tok} out of vocab");
        let e = embed.row(tok as usize);
        let p = pos.row(r % t);
        let row = x.row_mut(r);
        for j in 0..d {
            row[j] = e[j] + p[j];
        }
    }

    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    // Per-(batch, head) scratch, reused across layers: contiguous Q/K/V
    // head slices so the score/context products run through the blocked
    // kernel instead of strided scalar loops.
    let mut qh = vec![0.0f32; t * hd];
    let mut kh = vec![0.0f32; t * hd];
    let mut vh = vec![0.0f32; t * hd];
    let mut scores = vec![0.0f32; t * t];
    let mut ctx = vec![0.0f32; t * hd];
    for i in 0..cfg.n_layers {
        // --- attention block ---
        let mut h = x.clone();
        layer_norm(&mut h, w.get(&format!("l{i}.ln1.g"))?, w.get(&format!("l{i}.ln1.b"))?, 1e-5);
        let qkv = qmatmul(&h, w, &format!("l{i}.attn.wqkv"), act_q)?; // (B*T, 3D)
        let mut attn_out = Tensor::zeros(&[batch * t, d]);
        for b in 0..batch {
            for head in 0..cfg.n_heads {
                let off = head * hd;
                for qi in 0..t {
                    let row = qkv.row(b * t + qi);
                    qh[qi * hd..(qi + 1) * hd].copy_from_slice(&row[off..off + hd]);
                    kh[qi * hd..(qi + 1) * hd].copy_from_slice(&row[d + off..d + off + hd]);
                    vh[qi * hd..(qi + 1) * hd].copy_from_slice(&row[2 * d + off..2 * d + off + hd]);
                }
                // scores = Qh · Khᵀ (rows of Kh are columns of Khᵀ),
                // then causal mask + scale before the softmax.
                let kt = PackedB::from_rows_flat(&kh, t, hd);
                kernels::gemm_into_flat(&qh, t, hd, &kt, &mut scores);
                for qi in 0..t {
                    let srow = &mut scores[qi * t..(qi + 1) * t];
                    for s in srow[..=qi].iter_mut() {
                        *s *= scale;
                    }
                    for s in srow[qi + 1..].iter_mut() {
                        *s = f32::NEG_INFINITY;
                    }
                }
                softmax_rows(&mut scores, t);
                // ctx = P · Vh.
                let vp = PackedB::pack_flat(&vh, t, hd);
                kernels::gemm_into_flat(&scores, t, t, &vp, &mut ctx);
                for qi in 0..t {
                    attn_out.row_mut(b * t + qi)[off..off + hd]
                        .copy_from_slice(&ctx[qi * hd..(qi + 1) * hd]);
                }
            }
        }
        let proj = qmatmul(&attn_out, w, &format!("l{i}.attn.wo"), act_q)?;
        for (xv, pv) in x.data.iter_mut().zip(&proj.data) {
            *xv += pv;
        }

        // --- MLP block ---
        let mut h = x.clone();
        layer_norm(&mut h, w.get(&format!("l{i}.ln2.g"))?, w.get(&format!("l{i}.ln2.b"))?, 1e-5);
        let mut ff = qmatmul(&h, w, &format!("l{i}.mlp.w1"), act_q)?;
        gelu(&mut ff.data);
        let down = qmatmul(&ff, w, &format!("l{i}.mlp.w2"), act_q)?;
        for (xv, dv) in x.data.iter_mut().zip(&down.data) {
            *xv += dv;
        }
    }

    layer_norm(&mut x, w.get("lnf.g")?, w.get("lnf.b")?, 1e-5);
    Ok(x)
}

/// Test-only fixtures shared by eval/coordinator unit tests.
#[cfg(test)]
pub mod tests_support {
    use super::*;
    use crate::util::rng::Pcg32;
    use std::collections::BTreeMap;

    pub fn tiny_cfg() -> ModelConfig {
        ModelConfig { name: "t".into(), d: 32, n_layers: 2, n_heads: 2, vocab: 40, max_t: 16 }
    }

    pub fn random_weights(cfg: &ModelConfig, seed: u64) -> Weights {
        let mut rng = Pcg32::seeded(seed);
        let mut tensors = BTreeMap::new();
        for (name, shape) in cfg.param_shapes() {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = if name.ends_with(".g") {
                vec![1.0; n]
            } else if name.ends_with(".b") {
                vec![0.0; n]
            } else {
                (0..n).map(|_| rng.normal() * 0.05).collect()
            };
            tensors.insert(name, Tensor::new(&shape, data));
        }
        Weights::new(tensors)
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::{random_weights, tiny_cfg};
    use super::*;

    #[test]
    fn forward_shapes_and_finite() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 1);
        w.validate(&cfg).unwrap();
        let tokens: Vec<u32> = (0..2 * 8).map(|i| (i % 40) as u32).collect();
        let logits = forward(&cfg, &w, &tokens, 2, None).unwrap();
        assert_eq!(logits.shape, vec![16, 40]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 2);
        let mut tok1: Vec<u32> = (0..8).map(|i| (i % 40) as u32).collect();
        let l1 = forward(&cfg, &w, &tok1, 1, None).unwrap();
        tok1[7] = 39;
        let l2 = forward(&cfg, &w, &tok1, 1, None).unwrap();
        // Positions 0..6 unchanged, position 7 changed.
        for r in 0..7 {
            for c in 0..40 {
                assert!((l1.at(r, c) - l2.at(r, c)).abs() < 1e-5, "row {r} changed");
            }
        }
        let diff: f32 = (0..40).map(|c| (l1.at(7, c) - l2.at(7, c)).abs()).sum();
        assert!(diff > 1e-3, "last position insensitive to its token");
    }

    #[test]
    fn batch_rows_independent() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 3);
        let a: Vec<u32> = (0..8).map(|i| (i * 3 % 40) as u32).collect();
        let b: Vec<u32> = (0..8).map(|i| (i * 7 % 40) as u32).collect();
        let together: Vec<u32> = a.iter().chain(&b).cloned().collect();
        let lt = forward(&cfg, &w, &together, 2, None).unwrap();
        let la = forward(&cfg, &w, &a, 1, None).unwrap();
        for r in 0..8 {
            for c in 0..40 {
                assert!((lt.at(r, c) - la.at(r, c)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn act_quant_hook_changes_logits_boundedly() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 4);
        let tokens: Vec<u32> = (0..8).map(|i| (i % 40) as u32).collect();
        let base = forward(&cfg, &w, &tokens, 1, None).unwrap();
        // Coarse 3-bit-ish quantizer as a stand-in hook.
        let crush = QuantPipeline::from_fn("crush", |src, dst| {
            for (o, &v) in dst.iter_mut().zip(src) {
                *o = (v * 4.0).round() / 4.0;
            }
        });
        let q = forward(&cfg, &w, &tokens, 1, Some(&crush)).unwrap();
        let num: f64 = base.data.iter().zip(&q.data).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let den: f64 = base.data.iter().map(|a| (*a as f64).powi(2)).sum();
        let rel = (num / den).sqrt();
        assert!(rel > 0.0 && rel < 1.0, "rel {rel}");
    }

    #[test]
    fn logits_at_matches_full_forward_rows() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 7);
        let t = 8;
        let tokens: Vec<u32> = (0..2 * t).map(|i| (i * 5 % 40) as u32).collect();
        let full = forward(&cfg, &w, &tokens, 2, None).unwrap();
        let positions = [3usize, 7];
        let slim = forward_logits_at(&cfg, &w, &tokens, 2, None, &positions).unwrap();
        assert_eq!(slim.shape, vec![2, 40]);
        for (i, &p) in positions.iter().enumerate() {
            for c in 0..40 {
                assert_eq!(
                    slim.at(i, c).to_bits(),
                    full.at(i * t + p, c).to_bits(),
                    "lane {i} pos {p} col {c}"
                );
            }
        }
        assert!(forward_logits_at(&cfg, &w, &tokens, 2, None, &[0, 99]).is_err());
    }

    #[test]
    fn rejects_bad_tokens() {
        let cfg = tiny_cfg();
        let w = random_weights(&cfg, 6);
        assert!(forward(&cfg, &w, &[999], 1, None).is_err());
        assert!(forward(&cfg, &w, &vec![0; cfg.max_t + 1], 1, None).is_err());
    }
}
