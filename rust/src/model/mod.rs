//! The tiny-GPT model family (mirror of `python/compile/model.py`):
//! configuration, LWTS weight loading, and a CPU reference forward used
//! by the ablation-grid evaluator (cross-checked against the PJRT
//! artifacts in integration tests).

pub mod config;
pub mod decode;
pub mod forward;
pub mod weights;

pub use config::ModelConfig;
pub use decode::{decode_step, prefill, AttnPath, DecodeScratch};
pub use forward::{forward, forward_logits_at};
pub use weights::{Linear, Weights};
