//! The tiny-GPT model family (mirror of `python/compile/model.py`):
//! configuration, LWTS weight loading, and a CPU reference forward used
//! by the ablation-grid evaluator (cross-checked against the PJRT
//! artifacts in integration tests).

pub mod config;
pub mod forward;
pub mod weights;

pub use config::ModelConfig;
pub use forward::{forward, matmul_par};
pub use weights::{Linear, Weights};
